"""Blueprint + oracle for the Rust NativeBackend (rust/src/backend/native.rs).

The numpy code below is a line-for-line mirror of the Rust native engine's
forward AND hand-derived backward pass.  It is asserted here against
jax.value_and_grad of the L2 reference model (compile/model.py) on every
head (lm / cls / reg), so the Rust transcription has a machine-checked
mathematical blueprint.  Run as a script to print the deterministic-filler
golden losses pinned in rust/tests/native_golden.rs.
"""

import numpy as np
import jax
import jax.numpy as jnp

try:  # package import (pytest from repo root via conftest)
    from compile import model
    from compile.presets import PRESETS
except ImportError:  # script execution from python/
    import os, sys

    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    from compile import model
    from compile.presets import PRESETS

RMS_EPS = 1e-6


# ---------------------------------------------------------------------------
# numpy forward (mirrors rust backend/native.rs exactly)
# ---------------------------------------------------------------------------

def rmsnorm_fwd(x, g):
    # x: (B,T,D), g: (D,)
    r = 1.0 / np.sqrt(np.mean(x * x, axis=-1, keepdims=True) + RMS_EPS)
    return x * r * g, r


def rmsnorm_bwd(dy, x, g, r):
    d = x.shape[-1]
    dg = np.sum(dy * x * r, axis=(0, 1))
    s = np.sum(dy * g * x, axis=-1, keepdims=True)
    dx = dy * g * r - x * (r ** 3) * s / d
    return dx, dg


def rope_tables(t, dh):
    half = dh // 2
    freq = 1.0 / (10000.0 ** (np.arange(half, dtype=np.float64) / half))
    ang = np.arange(t, dtype=np.float64)[:, None] * freq[None, :]
    return np.cos(ang), np.sin(ang)  # (T, half)


def rope_fwd(x, cos, sin):
    # x: (B,T,H,Dh)
    half = x.shape[-1] // 2
    c = cos[None, :, None, :]
    s = sin[None, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return np.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)


def rope_bwd(dy, cos, sin):
    # rotation is orthogonal: backward = inverse rotation
    half = dy.shape[-1] // 2
    c = cos[None, :, None, :]
    s = sin[None, :, None, :]
    d1, d2 = dy[..., :half], dy[..., half:]
    return np.concatenate([d1 * c + d2 * s, -d1 * s + d2 * c], axis=-1)


def softmax_rows(x):
    m = np.max(x, axis=-1, keepdims=True)
    e = np.exp(x - m)
    return e / np.sum(e, axis=-1, keepdims=True)


def silu(x):
    return x / (1.0 + np.exp(-x))


class LayerCache:
    pass


def trunk_fwd(params, tokens, p):
    """params: dict name->array (f32). tokens: (B,T) int. Returns xf, caches."""
    b, t = tokens.shape
    d, h = p.d_model, p.n_heads
    dh = d // h
    scale = 1.0 / np.sqrt(dh)
    cos, sin = rope_tables(t, dh)
    x = params["tok_emb"][tokens]  # (B,T,D) gather rows
    caches = []
    for i in range(p.n_layers):
        pre = f"layers.{i}."
        c = LayerCache()
        c.x0 = x
        c.ha, c.ra = rmsnorm_fwd(x, params[pre + "attn_norm"])
        q = (c.ha @ params[pre + "wq"]).reshape(b, t, h, dh)
        k = (c.ha @ params[pre + "wk"]).reshape(b, t, h, dh)
        c.v = (c.ha @ params[pre + "wv"]).reshape(b, t, h, dh)
        c.q = rope_fwd(q, cos, sin)
        c.k = rope_fwd(k, cos, sin)
        # per (b, head): S = q k^T * scale, causal mask, softmax, ctx = P v
        c.probs = np.zeros((b, h, t, t), dtype=x.dtype)
        ctx = np.zeros((b, t, h, dh), dtype=x.dtype)
        for bi in range(b):
            for hi in range(h):
                qh = c.q[bi, :, hi, :]  # (T, Dh)
                kh = c.k[bi, :, hi, :]
                vh = c.v[bi, :, hi, :]
                s_mat = (qh @ kh.T) * scale
                mask = np.triu(np.ones((t, t), dtype=bool), 1)
                s_mat = np.where(mask, -np.inf, s_mat)
                pr = softmax_rows(s_mat)
                c.probs[bi, hi] = pr
                ctx[bi, :, hi, :] = pr @ vh
        c.ctx = ctx.reshape(b, t, d)
        x = x + c.ctx @ params[pre + "wo"]
        c.x1 = x
        c.hm, c.rm = rmsnorm_fwd(x, params[pre + "mlp_norm"])
        c.g = c.hm @ params[pre + "w_gate"]
        c.u = c.hm @ params[pre + "w_up"]
        c.sg = 1.0 / (1.0 + np.exp(-c.g))  # sigmoid(g)
        c.prod = (c.g * c.sg) * c.u  # silu(g) * u
        x = x + c.prod @ params[pre + "w_down"]
        c.x2 = x
        caches.append(c)
    xf, rf = rmsnorm_fwd(x, params["final_norm"])
    return xf, rf, caches, (cos, sin, scale)


def trunk_bwd(dxf, params, tokens, p, xf_inputs, caches, tables, grads):
    b, t = tokens.shape
    d, h = p.d_model, p.n_heads
    dh = d // h
    cos, sin, scale = tables
    x2 = caches[-1].x2 if caches else params["tok_emb"][tokens]
    dx, dgf = rmsnorm_bwd(dxf, x2, params["final_norm"], xf_inputs)
    grads["final_norm"] += dgf
    for i in reversed(range(p.n_layers)):
        pre = f"layers.{i}."
        c = caches[i]
        # mlp residual: x2 = x1 + prod @ w_down
        dprod = dx @ params[pre + "w_down"].T
        grads[pre + "w_down"] += c.prod.reshape(b * t, -1).T @ dx.reshape(b * t, d)
        sil = c.g * c.sg
        du = dprod * sil
        dg = dprod * c.u * (c.sg * (1.0 + c.g * (1.0 - c.sg)))  # dsilu/dg
        grads[pre + "w_up"] += c.hm.reshape(b * t, d).T @ du.reshape(b * t, -1)
        grads[pre + "w_gate"] += c.hm.reshape(b * t, d).T @ dg.reshape(b * t, -1)
        dhm = dg @ params[pre + "w_gate"].T + du @ params[pre + "w_up"].T
        dx1_from_norm, dgm = rmsnorm_bwd(dhm, c.x1, params[pre + "mlp_norm"], c.rm)
        grads[pre + "mlp_norm"] += dgm
        dx = dx + dx1_from_norm  # residual add
        # attn residual: x1 = x0 + ctx @ wo
        dctx = (dx @ params[pre + "wo"].T).reshape(b, t, h, dh)
        grads[pre + "wo"] += c.ctx.reshape(b * t, d).T @ dx.reshape(b * t, d)
        dq = np.zeros_like(c.q)
        dk = np.zeros_like(c.k)
        dv = np.zeros_like(c.v)
        for bi in range(b):
            for hi in range(h):
                pr = c.probs[bi, hi]  # (T,T)
                do = dctx[bi, :, hi, :]  # (T,Dh)
                vh = c.v[bi, :, hi, :]
                dv[bi, :, hi, :] = pr.T @ do
                dp = do @ vh.T
                ds = pr * (dp - np.sum(dp * pr, axis=-1, keepdims=True))
                dq[bi, :, hi, :] = (ds @ c.k[bi, :, hi, :]) * scale
                dk[bi, :, hi, :] = (ds.T @ c.q[bi, :, hi, :]) * scale
        dq = rope_bwd(dq, cos, sin).reshape(b, t, d)
        dk = rope_bwd(dk, cos, sin).reshape(b, t, d)
        dv = dv.reshape(b, t, d)
        grads[pre + "wq"] += c.ha.reshape(b * t, d).T @ dq.reshape(b * t, d)
        grads[pre + "wk"] += c.ha.reshape(b * t, d).T @ dk.reshape(b * t, d)
        grads[pre + "wv"] += c.ha.reshape(b * t, d).T @ dv.reshape(b * t, d)
        dha = dq @ params[pre + "wq"].T + dk @ params[pre + "wk"].T + dv @ params[pre + "wv"].T
        dx0_from_norm, dga = rmsnorm_bwd(dha, c.x0, params[pre + "attn_norm"], c.ra)
        grads[pre + "attn_norm"] += dga
        dx = dx + dx0_from_norm
    # embedding scatter-add
    demb = grads["tok_emb"]
    flat_tok = tokens.reshape(-1)
    flat_dx = dx.reshape(-1, d)
    for j, tok in enumerate(flat_tok):
        demb[tok] += flat_dx[j]


def lm_fwd_bwd(params, tokens, targets, p):
    """Returns (mean loss, grads dict). targets: -1 = ignore."""
    b, t = tokens.shape
    xf, rf, caches, tables = trunk_fwd(params, tokens, p)
    logits = xf @ params["lm_head"]  # (B,T,V)
    probs = softmax_rows(logits)
    valid = targets >= 0
    count = max(float(np.sum(valid)), 1.0)
    # loss accumulated in f64
    m = np.max(logits, axis=-1)
    lse = m + np.log(np.sum(np.exp(logits - m[..., None]), axis=-1))
    tgt = np.where(valid, targets, 0)
    picked = np.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
    loss = float(np.sum(np.where(valid, lse - picked, 0.0)) / count)
    # backward
    dlogits = probs.copy()
    flat = dlogits.reshape(-1, dlogits.shape[-1])
    for j, (tok, ok) in enumerate(zip(tgt.reshape(-1), valid.reshape(-1))):
        if ok:
            flat[j, tok] -= 1.0
        else:
            flat[j, :] = 0.0
    dlogits = flat.reshape(dlogits.shape) / count
    grads = {k: np.zeros_like(v) for k, v in params.items()}
    d = p.d_model
    grads["lm_head"] += xf.reshape(b * t, d).T @ dlogits.reshape(b * t, -1)
    dxf = dlogits @ params["lm_head"].T
    trunk_bwd(dxf, params, tokens, p, rf, caches, tables, grads)
    return loss, grads


def cls_fwd_bwd(params, tokens, labels, p, regression=False):
    b, t = tokens.shape
    d = p.d_model
    xf, rf, caches, tables = trunk_fwd(params, tokens, p)
    pooled = np.mean(xf, axis=1)  # (B, D)
    logits = pooled @ params["cls_head"] + params["cls_bias"]
    grads = {k: np.zeros_like(v) for k, v in params.items()}
    if regression:
        pred = logits[:, 0]
        loss = float(np.mean((pred - labels) ** 2))
        dpred = 2.0 * (pred - labels) / b
        dlogits = dpred[:, None]
    else:
        probs = softmax_rows(logits)
        m = np.max(logits, axis=-1)
        lse = m + np.log(np.sum(np.exp(logits - m[:, None]), axis=-1))
        picked = logits[np.arange(b), labels]
        loss = float(np.mean(lse - picked))
        dlogits = probs.copy()
        dlogits[np.arange(b), labels] -= 1.0
        dlogits /= b
    grads["cls_head"] += pooled.T @ dlogits
    grads["cls_bias"] += np.sum(dlogits, axis=0)
    dpooled = dlogits @ params["cls_head"].T
    dxf = np.repeat(dpooled[:, None, :], t, axis=1) / t
    trunk_bwd(dxf, params, tokens, p, rf, caches, tables, grads)
    return loss, grads


# ---------------------------------------------------------------------------
# oracles
# ---------------------------------------------------------------------------

def named_params(p, head, n_out, seed=0):
    specs = model.param_specs(p, head, n_out)
    flat = model.init_params(jax.random.PRNGKey(seed), p, head, n_out)
    return specs, {name: np.asarray(a, np.float32) for (name, _), a in zip(specs, flat)}


def filler_tokens(b, t, vocab, salt):
    out = np.zeros((b, t), np.int32)
    for i in range(b):
        for j in range(t):
            out[i, j] = (7 * i + 13 * j + salt) % vocab
    return out


def _assert_grads_close(specs, got, want_flat, rtol=2e-3, atol=2e-4):
    for (name, _), w in zip(specs, want_flat):
        g = got[name]
        w = np.asarray(w)
        err = np.max(np.abs(g - w))
        ref = np.max(np.abs(w)) + 1e-8
        assert err <= atol + rtol * ref, f"{name}: max |Δgrad| {err} vs ref {ref}"


def test_lm_mirror_matches_jax():
    p = PRESETS["nano"]
    b, t = 2, 16
    specs, params = named_params(p, "lm", 0, seed=3)
    tokens = filler_tokens(b, t, p.vocab, 0)
    targets = filler_tokens(b, t, p.vocab, 3)
    targets[0, :3] = -1  # exercise the ignore path
    loss, grads = lm_fwd_bwd(params, tokens, targets, p)

    flat = [jnp.asarray(params[name]) for name, _ in specs]
    jloss, jgrads = jax.value_and_grad(
        lambda ps: model.lm_loss_mean(ps, jnp.asarray(tokens), jnp.asarray(targets), p)
    )(flat)
    assert abs(loss - float(jloss)) < 1e-4 * max(1.0, abs(float(jloss))), (loss, float(jloss))
    _assert_grads_close(specs, grads, jgrads)


def test_cls_mirror_matches_jax():
    p = PRESETS["nano"]
    b, t, n_out = 4, 12, 3
    specs, params = named_params(p, "cls", n_out, seed=5)
    tokens = filler_tokens(b, t, p.vocab, 1)
    labels = np.array([0, 1, 2, 1], np.int32)
    loss, grads = cls_fwd_bwd(params, tokens, labels, p, regression=False)

    flat = [jnp.asarray(params[name]) for name, _ in specs]
    jloss, jgrads = jax.value_and_grad(
        lambda ps: model.cls_loss_mean(ps, jnp.asarray(tokens), jnp.asarray(labels), p)
    )(flat)
    assert abs(loss - float(jloss)) < 1e-4 * max(1.0, abs(float(jloss)))
    _assert_grads_close(specs, grads, jgrads)


def test_reg_mirror_matches_jax():
    p = PRESETS["nano"]
    b, t = 4, 12
    specs, params = named_params(p, "reg", 1, seed=7)
    tokens = filler_tokens(b, t, p.vocab, 2)
    labels = np.array([0.1, 0.9, 0.4, 0.6], np.float32)
    loss, grads = cls_fwd_bwd(params, tokens, labels, p, regression=True)

    flat = [jnp.asarray(params[name]) for name, _ in specs]
    jloss, jgrads = jax.value_and_grad(
        lambda ps: model.reg_loss_mean(ps, jnp.asarray(tokens), jnp.asarray(labels), p)
    )(flat)
    assert abs(loss - float(jloss)) < 1e-4 * max(1.0, abs(float(jloss)))
    _assert_grads_close(specs, grads, jgrads)


def deterministic_filler(specs):
    """Mirror of rust ParamStore::fill_deterministic / aot.filler_params."""
    out = {}
    for pi, (name, shape) in enumerate(specs):
        n = int(np.prod(shape))
        if "norm" in name:
            w = np.ones(n, np.float32)
        elif name.endswith("bias"):
            w = np.zeros(n, np.float32)
        else:
            j = np.arange(n, dtype=np.float32)
            w = (0.02 * np.sin(0.1 * (j + 31.0 * pi))).astype(np.float32)
        out[name] = w.reshape(shape)
    return out


def golden_native_losses():
    """The constants pinned in rust/tests/native_golden.rs."""
    p = PRESETS["nano"]
    specs = model.param_specs(p, "lm")
    params = deterministic_filler(specs)
    b, t = 8, 64
    tokens = filler_tokens(b, t, p.vocab, 0)
    targets = filler_tokens(b, t, p.vocab, 3)
    loss, grads = lm_fwd_bwd(params, tokens, targets, p)
    norms = [float(np.linalg.norm(grads[name])) for name, _ in specs[:3]]
    return loss, norms


def golden_grain_losses():
    """Odd-dims pins (rust/tests/native_golden.rs grain cases): run the
    mirror in float64 end-to-end so the pins are JAX-grade references; the
    Rust f32 engine lands within ~1e-6 of them (asserted at 1e-5)."""
    p = PRESETS["grain"]
    out = {}
    # lm head, b=3 t=13
    specs = model.param_specs(p, "lm")
    params = {k: v.astype(np.float64) for k, v in deterministic_filler(specs).items()}
    tokens = filler_tokens(3, 13, p.vocab, 0)
    targets = filler_tokens(3, 13, p.vocab, 3)
    loss, grads = lm_fwd_bwd(params, tokens, targets, p)
    out["lm"] = (loss, [float(np.linalg.norm(grads[n])) for n, _ in specs])
    # cls head (n_out=3), b=2 t=7, labels [0, 2]
    cspecs = model.param_specs(p, "cls", 3)
    cparams = {k: v.astype(np.float64) for k, v in deterministic_filler(cspecs).items()}
    ctokens = filler_tokens(2, 7, p.vocab, 1)
    labels = np.array([0, 2], np.int32)
    closs, cgrads = cls_fwd_bwd(cparams, ctokens, labels, p)
    out["cls"] = (closs, [float(np.linalg.norm(cgrads[n])) for n, _ in cspecs])
    return out


def test_grain_mirror_matches_jax():
    """The odd-dims preset exercises shapes the nano tests never hit; keep
    the mirror JAX-validated there too."""
    p = PRESETS["grain"]
    b, t = 3, 13
    specs, params = named_params(p, "lm", 0, seed=11)
    tokens = filler_tokens(b, t, p.vocab, 0)
    targets = filler_tokens(b, t, p.vocab, 3)
    loss, grads = lm_fwd_bwd(params, tokens, targets, p)
    flat = [jnp.asarray(params[name]) for name, _ in specs]
    jloss, jgrads = jax.value_and_grad(
        lambda ps: model.lm_loss_mean(ps, jnp.asarray(tokens), jnp.asarray(targets), p)
    )(flat)
    assert abs(loss - float(jloss)) < 1e-4 * max(1.0, abs(float(jloss)))
    _assert_grads_close(specs, grads, jgrads)


def test_golden_matches_jax_reference():
    p = PRESETS["nano"]
    specs = model.param_specs(p, "lm")
    params = deterministic_filler(specs)
    tokens = filler_tokens(8, 64, p.vocab, 0)
    targets = filler_tokens(8, 64, p.vocab, 3)
    flat = [jnp.asarray(params[name]) for name, _ in specs]
    jloss = model.lm_loss_mean(flat, jnp.asarray(tokens), jnp.asarray(targets), p)
    loss, _ = golden_native_losses()
    assert abs(loss - float(jloss)) < 1e-4 * abs(float(jloss))


if __name__ == "__main__":
    test_lm_mirror_matches_jax()
    print("lm mirror OK")
    test_cls_mirror_matches_jax()
    print("cls mirror OK")
    test_reg_mirror_matches_jax()
    print("reg mirror OK")
    test_grain_mirror_matches_jax()
    print("grain (odd dims) mirror OK")
    loss, norms = golden_native_losses()
    print(f"native golden: nano lm b8t64 loss = {loss!r}")
    print(f"grad_norms_first3 = {norms!r}")
    for head, (gl, gn) in golden_grain_losses().items():
        print(f"grain golden {head}: loss = {gl!r}")
        print(f"  grad_norms = {gn!r}")
