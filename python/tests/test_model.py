"""L2 model tests: shapes, loss semantics, gradient integrity, pallas parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.presets import PRESETS


NANO = PRESETS["nano"]


def nano_params(head="lm", n_out=2, seed=0):
    return model.init_params(jax.random.PRNGKey(seed), NANO, head, n_out)


def test_param_specs_count_matches_preset():
    for name, p in PRESETS.items():
        specs = model.param_specs(p, "lm")
        total = sum(int(np.prod(s)) for _, s in specs)
        assert total == p.param_count(), name


def test_cls_param_specs_count():
    for n_out in (1, 2, 3, 5):
        specs = model.param_specs(NANO, "cls", n_out)
        total = sum(int(np.prod(s)) for _, s in specs)
        assert total == NANO.cls_param_count(n_out)


def test_param_order_is_stable():
    names = [n for n, _ in model.param_specs(NANO, "lm")]
    assert names[0] == "tok_emb"
    assert names[1] == "layers.0.attn_norm"
    assert names[-1] == "lm_head"
    assert names[-2] == "final_norm"
    # the ABI order: 9 tensors per layer
    assert len(names) == 2 + 9 * NANO.n_layers + 1


def test_lm_loss_at_init_near_uniform():
    """At (near-)random init, next-token CE should be ~ log(vocab)."""
    params = nano_params()
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, NANO.vocab)
    targets = jax.random.randint(jax.random.PRNGKey(2), (4, 32), 0, NANO.vocab)
    loss = model.lm_loss_mean(params, tokens, targets, NANO)
    assert abs(float(loss) - np.log(NANO.vocab)) < 0.5


def test_lm_loss_ignores_masked_targets():
    params = nano_params()
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, NANO.vocab)
    targets = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, NANO.vocab)
    masked = targets.at[:, :8].set(-1)
    s_all, c_all = model.lm_loss_terms(params, tokens, targets, NANO)
    s_m, c_m = model.lm_loss_terms(params, tokens, masked, NANO)
    assert float(c_all) == 32.0 and float(c_m) == 16.0
    assert float(s_m) < float(s_all)


def test_train_step_outputs_match_param_specs():
    params = nano_params()
    specs = model.param_specs(NANO, "lm")
    fn = model.make_lm_train(NANO)
    tokens = jnp.zeros((2, 8), jnp.int32)
    targets = jnp.zeros((2, 8), jnp.int32)
    out = fn(*params, tokens, targets)
    assert len(out) == 1 + len(specs)
    for g, (_, shape) in zip(out[1:], specs):
        assert g.shape == shape


def test_gradients_nonzero_everywhere():
    """Every parameter tensor must receive gradient signal (no dead layers)."""
    params = nano_params()
    fn = model.make_lm_train(NANO)
    tokens = jax.random.randint(jax.random.PRNGKey(3), (4, 16), 0, NANO.vocab)
    targets = jax.random.randint(jax.random.PRNGKey(4), (4, 16), 0, NANO.vocab)
    out = fn(*params, tokens, targets)
    for g, (name, _) in zip(out[1:], model.param_specs(NANO, "lm")):
        assert float(jnp.linalg.norm(g)) > 0, f"dead gradient in {name}"


def test_grad_matches_finite_difference():
    """Directional finite-difference check of the full fwd/bwd on nano."""
    params = nano_params()
    tokens = jax.random.randint(jax.random.PRNGKey(5), (2, 8), 0, NANO.vocab)
    targets = jax.random.randint(jax.random.PRNGKey(6), (2, 8), 0, NANO.vocab)

    loss_fn = lambda ps: model.lm_loss_mean(ps, tokens, targets, NANO)
    loss, grads = jax.value_and_grad(loss_fn)(params)

    key = jax.random.PRNGKey(7)
    dirs = [jax.random.normal(k, p.shape) for k, p in
            zip(jax.random.split(key, len(params)), params)]
    eps = 1e-3
    plus = [p + eps * d for p, d in zip(params, dirs)]
    minus = [p - eps * d for p, d in zip(params, dirs)]
    fd = (loss_fn(plus) - loss_fn(minus)) / (2 * eps)
    analytic = sum(jnp.vdot(g, d) for g, d in zip(grads, dirs))
    np.testing.assert_allclose(float(fd), float(analytic), rtol=2e-2)


def test_pallas_and_jnp_model_agree():
    """The pallas-attention model and the jnp-attention model are the same
    function — this is what licenses shipping jnp-path artifacts for speed."""
    params = nano_params()
    tokens = jax.random.randint(jax.random.PRNGKey(8), (2, 64), 0, NANO.vocab)
    targets = jax.random.randint(jax.random.PRNGKey(9), (2, 64), 0, NANO.vocab)
    l1 = model.lm_loss_mean(params, tokens, targets, NANO, use_pallas=False)
    l2 = model.lm_loss_mean(params, tokens, targets, NANO, use_pallas=True)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


def test_pallas_grads_match_jnp_grads():
    params = nano_params()
    tokens = jax.random.randint(jax.random.PRNGKey(10), (2, 32), 0, NANO.vocab)
    targets = jax.random.randint(jax.random.PRNGKey(11), (2, 32), 0, NANO.vocab)
    g1 = jax.grad(lambda ps: model.lm_loss_mean(ps, tokens, targets, NANO, False))(params)
    g2 = jax.grad(lambda ps: model.lm_loss_mean(ps, tokens, targets, NANO, True))(params)
    for a, b, (name, _) in zip(g1, g2, model.param_specs(NANO, "lm")):
        np.testing.assert_allclose(a, b, rtol=5e-4, atol=1e-6, err_msg=name)


def test_cls_head_shapes_and_loss():
    for n_out in (2, 3):
        params = nano_params("cls", n_out)
        tokens = jax.random.randint(jax.random.PRNGKey(12), (4, 16), 0, NANO.vocab)
        labels = jnp.array([0, 1, 0, 1], jnp.int32) % n_out
        logits = model.cls_logits(params, tokens, NANO)
        assert logits.shape == (4, n_out)
        loss = model.cls_loss_mean(params, tokens, labels, NANO)
        assert abs(float(loss) - np.log(n_out)) < 0.5


def test_reg_head():
    params = nano_params("reg", 1)
    tokens = jax.random.randint(jax.random.PRNGKey(13), (4, 16), 0, NANO.vocab)
    labels = jnp.array([0.0, 0.5, 1.0, 0.25], jnp.float32)
    loss = model.reg_loss_mean(params, tokens, labels, NANO)
    assert float(loss) >= 0


def test_cls_eval_outputs():
    params = nano_params("cls", 2)
    fn = model.make_cls_eval(NANO, 2)
    tokens = jax.random.randint(jax.random.PRNGKey(14), (4, 16), 0, NANO.vocab)
    labels = jnp.array([0, 1, 0, 1], jnp.int32)
    loss_sum, correct, preds = fn(*params, tokens, labels)
    assert preds.shape == (4,)
    assert 0 <= float(correct) <= 4
    assert float(loss_sum) > 0


def test_causal_model_property():
    """Changing tokens at position j must not affect logits before j."""
    params = nano_params()
    tokens = jax.random.randint(jax.random.PRNGKey(15), (1, 16), 0, NANO.vocab)
    x1, it = model.trunk(params, tokens, NANO)
    pert = tokens.at[0, 10].set((tokens[0, 10] + 1) % NANO.vocab)
    x2, _ = model.trunk(params, pert, NANO)
    np.testing.assert_allclose(x1[:, :10], x2[:, :10], rtol=1e-5, atol=1e-6)
    assert not np.allclose(x1[:, 10:], x2[:, 10:])
