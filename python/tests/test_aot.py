"""AOT pipeline tests: lowering produces parseable HLO text, the manifest
ABI matches model.param_specs, and golden probes are self-consistent.

These run the same code path as `make artifacts` on the nano preset only
(kept fast); the shipped artifacts' integrity is separately asserted by the
Rust side (rust/tests/golden.rs)."""

import json
import math
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model
from compile.presets import PRESETS


def test_to_hlo_text_emits_parseable_header(tmp_path):
    p = PRESETS["nano"]
    fn = model.make_lm_eval(p)
    specs = model.param_specs(p, "lm")
    args = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in specs]
    args += [jax.ShapeDtypeStruct((2, 8), jnp.int32)] * 2
    lowered = jax.jit(fn).lower(*args)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule"), text[:80]
    # one entry computation, tuple root (return_tuple=True)
    assert "ENTRY" in text


def test_filler_params_deterministic_and_spec_shaped():
    specs = model.param_specs(PRESETS["nano"], "lm")
    a = aot.filler_params(specs)
    b = aot.filler_params(specs)
    for x, y, (name, shape) in zip(a, b, specs):
        assert x.shape == shape, name
        assert (x == y).all(), name
    # norms are ones
    names = [n for n, _ in specs]
    i = names.index("layers.0.attn_norm")
    assert float(a[i].min()) == 1.0


def test_filler_tokens_formula():
    t = aot.filler_tokens(2, 3, 256, salt=3)
    # tokens[i,j] = (7i + 13j + 3) % 256
    assert t.tolist() == [[3, 16, 29], [10, 23, 36]]


def test_build_artifact_writes_manifest_entry_and_golden(tmp_path):
    golden = []
    entry = aot.build_model_artifact(
        str(tmp_path), "nano", "lm", "eval", 2, 8, golden=golden
    )
    assert os.path.exists(tmp_path / entry["file"])
    n_total = sum(math.prod(p["shape"]) for p in entry["params"])
    assert n_total == PRESETS["nano"].param_count()
    assert entry["outputs"] == ["loss_sum", "valid_count"]
    assert golden and golden[0]["valid_count"] == 16.0
    # golden loss is sane: ~ln(256) per token at filler params
    per_tok = golden[0]["loss"] / golden[0]["valid_count"]
    assert 4.5 < per_tok < 6.5


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")),
    reason="run `make artifacts` first",
)
def test_shipped_manifest_consistent_with_code():
    root = os.path.join(os.path.dirname(__file__), "../../artifacts")
    man = json.load(open(os.path.join(root, "manifest.json")))
    assert man["version"] == 1
    for name, pj in man["presets"].items():
        p = PRESETS[name]
        assert pj["param_count"] == p.param_count(), name
    for a in man["artifacts"]:
        assert os.path.exists(os.path.join(root, a["file"])), a["id"]
        if a["kind"] == "masked_adam":
            continue
        specs = model.param_specs(
            PRESETS[a["preset"]], a["head"], a["n_out"] or 2
        )
        assert [p["name"] for p in a["params"]] == [n for n, _ in specs], a["id"]
        assert [tuple(p["shape"]) for p in a["params"]] == [s for _, s in specs], a["id"]

    golden = json.load(open(os.path.join(root, "golden.json")))
    ids = {a["id"] for a in man["artifacts"]}
    for g in golden:
        assert g["artifact"] in ids
