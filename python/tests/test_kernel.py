"""Pallas kernel vs pure-jnp oracle — the CORE correctness signal.

hypothesis sweeps shapes; every property is an allclose against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention as attn_k
from compile.kernels import masked_adam as madam_k
from compile.kernels import ref

SETTINGS = dict(max_examples=20, deadline=None)


def rand(key, *shape):
    return jax.random.normal(key, shape, jnp.float32)


# ---------------------------------------------------------------------------
# Attention kernel
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    bh=st.integers(1, 4),
    t=st.integers(1, 96),
    dh=st.sampled_from([4, 8, 16, 32]),
    bq=st.sampled_from([8, 16, 32]),
    bk=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_pallas_matches_ref(bh, t, dh, bq, bk, seed):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    q, k, v = rand(k1, bh, t, dh), rand(k2, bh, t, dh), rand(k3, bh, t, dh)
    got = attn_k.causal_attention_pallas(q, k, v, block_q=bq, block_k=bk)
    want = ref.causal_attention_ref_bhtd(q, k, v)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_attention_non_divisible_seq():
    """T not a multiple of the block sizes exercises the padding path."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q, k, v = rand(k1, 2, 37, 16), rand(k2, 2, 37, 16), rand(k3, 2, 37, 16)
    got = attn_k.causal_attention_pallas(q, k, v, block_q=16, block_k=16)
    want = ref.causal_attention_ref_bhtd(q, k, v)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_attention_causality():
    """Perturbing future positions must not change earlier outputs."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    q, k, v = rand(k1, 1, 32, 8), rand(k2, 1, 32, 8), rand(k3, 1, 32, 8)
    base = attn_k.causal_attention_pallas(q, k, v)
    k2v = k.at[:, 20:, :].add(100.0)
    v2v = v.at[:, 20:, :].add(-50.0)
    pert = attn_k.causal_attention_pallas(q, k2v, v2v)
    np.testing.assert_allclose(base[:, :20], pert[:, :20], rtol=1e-5, atol=1e-5)
    assert not np.allclose(base[:, 20:], pert[:, 20:])


def test_attention_custom_vjp_matches_jnp_grad():
    """The custom_vjp backward must equal jax.grad of the reference."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(2), 3)
    q, k, v = rand(k1, 2, 16, 8), rand(k2, 2, 16, 8), rand(k3, 2, 16, 8)

    def loss_kernel(q, k, v):
        return jnp.sum(attn_k.causal_attention(q, k, v, True) ** 2)

    def loss_ref(q, k, v):
        return jnp.sum(ref.causal_attention_ref_bhtd(q, k, v) ** 2)

    g1 = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_attention_softmax_rows_sum_to_one_property():
    """With v = identity-ish basis, outputs are convex combinations: row sums
    of attention weights == 1 -> output of v=ones is ones."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    q, k = rand(k1, 2, 40, 8), rand(k2, 2, 40, 8)
    v = jnp.ones((2, 40, 8), jnp.float32)
    got = attn_k.causal_attention_pallas(q, k, v)
    np.testing.assert_allclose(got, jnp.ones_like(got), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Masked-Adam kernel
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    n=st.integers(1, 5000),
    block=st.sampled_from([64, 256, 1024, 4096]),
    step=st.integers(1, 10_000),
    density=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_masked_adam_matches_ref(n, block, step, density, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    w, g = rand(ks[0], n), rand(ks[1], n)
    m = 0.1 * rand(ks[2], n)
    v = jnp.abs(0.01 * rand(ks[3], n))
    mask = (jax.random.uniform(ks[4], (n,)) < density).astype(jnp.float32)
    lr, b1, b2, eps = 3e-4, 0.9, 0.999, 1e-8
    got = madam_k.masked_adam_pallas(w, m, v, g, mask, lr, b1, b2, eps, step, block=block)
    want = ref.masked_adam_ref(w, m, v, g, mask, lr, b1, b2, eps, step)
    for a, b in zip(got, want):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_masked_adam_zero_mask_is_identity():
    n = 300
    ks = jax.random.split(jax.random.PRNGKey(7), 4)
    w, m, v, g = rand(ks[0], n), rand(ks[1], n), jnp.abs(rand(ks[2], n)), rand(ks[3], n)
    mask = jnp.zeros(n)
    w2, m2, v2 = madam_k.masked_adam_pallas(w, m, v, g, mask, 1e-3, 0.9, 0.999, 1e-8, 1)
    np.testing.assert_array_equal(w2, w)
    np.testing.assert_array_equal(m2, m)
    np.testing.assert_array_equal(v2, v)


def test_masked_adam_full_mask_equals_dense_adam():
    """mask=1 everywhere must reduce to the textbook Adam step."""
    n = 257
    ks = jax.random.split(jax.random.PRNGKey(8), 2)
    w, g = rand(ks[0], n), rand(ks[1], n)
    m = jnp.zeros(n)
    v = jnp.zeros(n)
    lr, b1, b2, eps, step = 1e-2, 0.9, 0.999, 1e-8, 1
    w2, m2, v2 = madam_k.masked_adam_pallas(w, m, v, g, jnp.ones(n), lr, b1, b2, eps, step)
    m_t = (1 - b1) * g
    v_t = (1 - b2) * g * g
    upd = lr * (m_t / (1 - b1)) / (jnp.sqrt(v_t / (1 - b2)) + eps)
    np.testing.assert_allclose(w2, w - upd, rtol=5e-5, atol=1e-7)
    np.testing.assert_allclose(m2, m_t, rtol=5e-5, atol=1e-8)
    np.testing.assert_allclose(v2, v_t, rtol=5e-5, atol=1e-8)


def test_masked_adam_monotone_memory_semantics():
    """Unmasked coordinates carry NO state update — the whole point of
    BlockLLM's memory model (state only for the active block)."""
    n = 128
    ks = jax.random.split(jax.random.PRNGKey(9), 4)
    w, m, v, g = rand(ks[0], n), rand(ks[1], n), jnp.abs(rand(ks[2], n)), rand(ks[3], n)
    mask = (jnp.arange(n) < 64).astype(jnp.float32)
    w2, m2, v2 = madam_k.masked_adam_pallas(w, m, v, g, mask, 1e-3, 0.9, 0.999, 1e-8, 5)
    np.testing.assert_array_equal(w2[64:], w[64:])
    np.testing.assert_array_equal(m2[64:], m[64:])
    np.testing.assert_array_equal(v2[64:], v[64:])
    assert not np.allclose(w2[:64], w[:64])
