"""AOT compile path: lower the L2 model (with L1 kernels) to HLO TEXT artifacts.

Python runs ONLY here (`make artifacts`); the Rust coordinator is
self-contained afterwards.

Interchange format is HLO *text*, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the published `xla` 0.1.6 crate) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs (artifacts/):
  <id>.hlo.txt        one per (preset, head, phase, batch-shape[, pallas])
  masked_adam_<n>.hlo.txt   fused masked-Adam update artifact (L1 kernel)
  manifest.json       the ABI: parameter order/shapes, io signature per artifact
  golden.json         golden vectors: deterministic-filler loss probes +
                      masked-Adam input/output vectors, consumed by Rust tests

Usage: cd python && python -m compile.aot --out ../artifacts [--full]
"""

import argparse
import json
import math
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .presets import PRESETS
from .kernels import masked_adam as madam_k
from .kernels import ref as kref


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def filler_params(specs, scale=0.02):
    """Deterministic parameter filler reproduced bit-compatibly in Rust
    (rust/src/model/store.rs::fill_deterministic): w[j] = scale*sin(0.1*(j+31*pi))
    for matrix params, 1.0 for norms, 0.0 for biases."""
    out = []
    for pi, (name, shape) in enumerate(specs):
        n = math.prod(shape)
        if "norm" in name:
            arr = jnp.ones(n, jnp.float32)
        elif name.endswith("bias"):
            arr = jnp.zeros(n, jnp.float32)
        else:
            j = jnp.arange(n, dtype=jnp.float32)
            arr = (scale * jnp.sin(0.1 * (j + 31.0 * pi))).astype(jnp.float32)
        out.append(arr.reshape(shape))
    return out


def filler_tokens(b, t, vocab, salt=0):
    """tokens[i,j] = (7*i + 13*j + salt) % vocab — same in Rust."""
    i = jnp.arange(b)[:, None]
    j = jnp.arange(t)[None, :]
    return ((7 * i + 13 * j + salt) % vocab).astype(jnp.int32)


def lower_artifact(fn, example_args, out_path):
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    with open(out_path, "w") as f:
        f.write(text)
    return len(text)


def build_model_artifact(out_dir, preset_name, head, phase, b, t, n_out=2,
                         regression=False, use_pallas=False, golden=None):
    p = PRESETS[preset_name]
    specs = model.param_specs(p, head, n_out)
    pshapes = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in specs]
    tok = jax.ShapeDtypeStruct((b, t), jnp.int32)
    if head == "lm":
        tgt = jax.ShapeDtypeStruct((b, t), jnp.int32)
        fn = (model.make_lm_train if phase == "train" else model.make_lm_eval)(p, use_pallas)
        outputs = (["loss"] + [f"grad:{n}" for n, _ in specs]) if phase == "train" else [
            "loss_sum", "valid_count"]
    else:
        regression = head == "reg"
        tgt = jax.ShapeDtypeStruct((b,), jnp.float32 if regression else jnp.int32)
        if phase == "train":
            fn = model.make_cls_train(p, n_out, regression, use_pallas)
            outputs = ["loss"] + [f"grad:{n}" for n, _ in specs]
        else:
            fn = model.make_cls_eval(p, n_out, regression, use_pallas)
            outputs = ["loss_sum", "metric_sum", "preds"]

    suffix = "_pallas" if use_pallas else ""
    art_id = f"{preset_name}_{head}{n_out if head == 'cls' else ''}_{phase}_b{b}t{t}{suffix}"
    fname = art_id + ".hlo.txt"
    nchars = lower_artifact(fn, (*pshapes, tok, tgt), os.path.join(out_dir, fname))
    print(f"  {fname}: {nchars} chars")

    entry = {
        "id": art_id,
        "file": fname,
        "kind": f"{head}_{phase}",
        "preset": preset_name,
        "head": head,
        "n_out": (1 if head == "reg" else (n_out if head == "cls" else 0)),
        "batch": b,
        "seq": t,
        "pallas": bool(use_pallas),
        "params": [{"name": n, "shape": list(s)} for n, s in specs],
        "outputs": outputs,
    }

    # Golden probe: run the fn eagerly on deterministic inputs, record loss.
    if golden is not None:
        params = filler_params(specs)
        tokens = filler_tokens(b, t, p.vocab)
        if head == "lm":
            targets = filler_tokens(b, t, p.vocab, salt=3)
            res = fn(*params, tokens, targets)
        else:
            if regression:
                targets = (jnp.arange(b, dtype=jnp.float32) % 5.0) / 5.0
            else:
                targets = (jnp.arange(b) % n_out).astype(jnp.int32)
            res = fn(*params, tokens, targets)
        probe = {"artifact": art_id, "loss": float(res[0])}
        if phase == "train":
            # also record a few gradient norms to pin the grad path
            gnorms = [float(jnp.linalg.norm(g)) for g in res[1:4]]
            probe["grad_norms_first3"] = gnorms
        elif head == "lm":
            probe["valid_count"] = float(res[1])
        golden.append(probe)
    return entry


def build_masked_adam_artifact(out_dir, n, golden):
    fn = madam_k.masked_adam_xla_fn(n)
    spec = jax.ShapeDtypeStruct((n,), jnp.float32)
    h = jax.ShapeDtypeStruct((6,), jnp.float32)
    fname = f"masked_adam_{n}.hlo.txt"
    nchars = lower_artifact(fn, (spec, spec, spec, spec, spec, h), os.path.join(out_dir, fname))
    print(f"  {fname}: {nchars} chars")

    # Golden vectors: deterministic inputs + jnp-reference outputs, so the
    # Rust-native masked Adam can be asserted against the same semantics.
    j = jnp.arange(n, dtype=jnp.float32)
    w = jnp.sin(0.05 * j)
    m = 0.01 * jnp.cos(0.07 * j)
    v = 0.001 * (1.0 + jnp.sin(0.11 * j) ** 2)
    g = jnp.cos(0.13 * j) * 0.5
    mask = (jnp.arange(n) % 3 == 0).astype(jnp.float32)
    lr, b1, b2, eps, step = 1e-3, 0.9, 0.999, 1e-8, 7
    w2, m2, v2 = kref.masked_adam_ref(w, m, v, g, mask, lr, b1, b2, eps, step)
    golden.append({
        "artifact": fname[:-8],
        "kind": "masked_adam",
        "n": n,
        "hypers": {"lr": lr, "beta1": b1, "beta2": b2, "eps": eps, "step": step},
        "checksums": {
            "w_out_sum": float(jnp.sum(w2)), "m_out_sum": float(jnp.sum(m2)),
            "v_out_sum": float(jnp.sum(v2)),
            "w_out_l2": float(jnp.linalg.norm(w2)),
        },
    })
    return {
        "id": fname[:-8], "file": fname, "kind": "masked_adam", "n": n,
        "outputs": ["w", "m", "v"],
    }


# Artifact plan: (preset, head, n_out, [(batch, seq)], pallas_variant_too)
DEFAULT_PLAN = [
    ("nano", "lm", 0, [(8, 64)], True),    # pallas twin proves kernel-in-HLO parity
    ("micro", "lm", 0, [(8, 64)], False),
    ("tiny", "lm", 0, [(8, 64)], False),
    ("small", "lm", 0, [(8, 64)], False),
    ("nano", "cls", 2, [(16, 32)], False),
    ("nano", "cls", 3, [(16, 32)], False),
    ("nano", "reg", 1, [(16, 32)], False),
    ("micro", "cls", 2, [(16, 32)], False),
]
FULL_EXTRA = [
    ("base", "lm", 0, [(8, 64)], False),
    ("micro", "cls", 3, [(16, 32)], False),
    ("micro", "reg", 1, [(16, 32)], False),
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--full", action="store_true", help="also build the base preset + extra heads")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    plan = DEFAULT_PLAN + (FULL_EXTRA if args.full else [])
    artifacts, golden = [], []
    for preset, head, n_out, shapes, pallas_too in plan:
        for b, t in shapes:
            print(f"[aot] {preset} {head}{n_out or ''} b{b}t{t}")
            for phase in ("train", "eval"):
                artifacts.append(build_model_artifact(
                    args.out, preset, head, phase, b, t, n_out=n_out or 2,
                    use_pallas=False, golden=golden))
            if pallas_too:
                for phase in ("train", "eval"):
                    artifacts.append(build_model_artifact(
                        args.out, preset, head, phase, b, t, n_out=n_out or 2,
                        use_pallas=True, golden=golden))

    print("[aot] masked_adam kernel artifact")
    artifacts.append(build_masked_adam_artifact(args.out, 4096, golden))

    manifest = {
        "version": 1,
        "presets": {
            name: {"vocab": p.vocab, "d_model": p.d_model, "n_layers": p.n_layers,
                   "n_heads": p.n_heads, "d_ff": p.d_ff, "max_seq": p.max_seq,
                   "param_count": p.param_count()}
            for name, p in PRESETS.items()
        },
        "artifacts": artifacts,
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    with open(os.path.join(args.out, "golden.json"), "w") as f:
        json.dump(golden, f, indent=1)
    print(f"[aot] wrote {len(artifacts)} artifacts + manifest + golden to {args.out}")


if __name__ == "__main__":
    main()
