"""Pallas fused masked-Adam kernel (BlockLLM's coordinate update, paper eq. 1).

This is the optimizer-side hot-spot: given the active block's weights W,
optimizer state (M, V), processed gradient input G and the BlockLLM binary
mask, advance only the masked coordinates:

    M' = b1*M + (1-b1)*G            (masked coords)
    V' = b2*V + (1-b2)*G^2          (masked coords)
    W' = W - lr * M'hat/(sqrt(V'hat)+eps)

HARDWARE-ADAPTATION NOTE: the paper's memory saving is that (M, V) exist only
for the active block.  On TPU this becomes a streaming schedule: the grid
tiles the flat coordinate space; per tile the kernel pulls (W, M, V, G, mask)
HBM->VMEM, updates, writes back.  VMEM per program = 5 tiles * BLOCK * 4 B
(~2.5 MiB at BLOCK=131072) — the whole optimizer never resides on-chip, and
tiles whose mask population is zero could be skipped at dispatch time by the
coordinator (rust/src/optim/masked_adam.rs does exactly that skip on CPU).

All elementwise — VPU work, no MXU.  interpret=True as everywhere.

The same semantics are implemented natively in Rust for the request path;
this kernel (a) validates the semantics vs ref.masked_adam_ref under
hypothesis sweeps and (b) is exported as its own HLO artifact
(masked_adam.hlo.txt) so the runtime can optionally execute the update
through XLA (runtime::masked_adam_xla, used by the kernel-parity test).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 4096


def _madam_kernel(w_ref, m_ref, v_ref, g_ref, mask_ref, h_ref, w_o, m_o, v_o):
    """One tile of the flat coordinate space.

    h_ref packs scalars [lr, beta1, beta2, eps, bc1, bc2] where bc{1,2} are
    the precomputed bias corrections (1 - beta^step).
    """
    lr = h_ref[0]
    b1 = h_ref[1]
    b2 = h_ref[2]
    eps = h_ref[3]
    bc1 = h_ref[4]
    bc2 = h_ref[5]

    w = w_ref[...]
    m = m_ref[...]
    v = v_ref[...]
    g = g_ref[...]
    mask = mask_ref[...] > 0

    m_new = b1 * m + (1.0 - b1) * g
    v_new = b2 * v + (1.0 - b2) * g * g
    m_hat = m_new / bc1
    v_hat = v_new / bc2
    upd = lr * m_hat / (jnp.sqrt(v_hat) + eps)

    w_o[...] = jnp.where(mask, w - upd, w)
    m_o[...] = jnp.where(mask, m_new, m)
    v_o[...] = jnp.where(mask, v_new, v)


def masked_adam_pallas(w, m, v, g, mask, lr, beta1, beta2, eps, step, *, block=DEFAULT_BLOCK):
    """Fused masked Adam over flat f32[N] buffers.  Returns (w', m', v').

    `step` is the 1-based Adam timestep (python int or traced scalar).
    N must be positive; it is padded up to a multiple of `block` internally.
    """
    n = w.shape[0]
    block = min(block, n)
    pad = (-n) % block
    if pad:
        zpad = lambda a: jnp.pad(a, (0, pad))
        w, m, v, g, mask = map(zpad, (w, m, v, g, mask))
    np_ = w.shape[0]

    step_f = jnp.asarray(step, jnp.float32)
    h = jnp.stack(
        [
            jnp.asarray(lr, jnp.float32),
            jnp.asarray(beta1, jnp.float32),
            jnp.asarray(beta2, jnp.float32),
            jnp.asarray(eps, jnp.float32),
            1.0 - jnp.asarray(beta1, jnp.float32) ** step_f,
            1.0 - jnp.asarray(beta2, jnp.float32) ** step_f,
        ]
    )

    grid = (np_ // block,)
    tile = pl.BlockSpec((block,), lambda i: (i,))
    full = pl.BlockSpec((6,), lambda i: (0,))
    out = jax.ShapeDtypeStruct((np_,), jnp.float32)
    w2, m2, v2 = pl.pallas_call(
        _madam_kernel,
        grid=grid,
        in_specs=[tile, tile, tile, tile, tile, full],
        out_specs=[tile, tile, tile],
        out_shape=[out, out, out],
        interpret=True,
    )(w, m, v, g, mask, h)
    if pad:
        w2, m2, v2 = w2[:n], m2[:n], v2[:n]
    return w2, m2, v2


def masked_adam_xla_fn(n: int):
    """Returns a jittable fixed-shape fn for AOT export (flat size n).

    Signature: (w, m, v, g, mask f32[n], h f32[6]) -> (w', m', v')
    where h = [lr, beta1, beta2, eps, step, unused]; bias corrections are
    computed inside so the artifact takes the raw step counter.
    """

    def fn(w, m, v, g, mask, h):
        lr, b1, b2, eps, step = h[0], h[1], h[2], h[3], h[4]
        return masked_adam_pallas(w, m, v, g, mask, lr, b1, b2, eps, step, block=min(DEFAULT_BLOCK, n))

    return fn
