"""Pure-jnp reference oracles for the Pallas kernels.

These are the CORE correctness signal: every Pallas kernel in this package is
asserted allclose against its oracle here (python/tests/test_kernel.py), and
the Rust-native hot paths (rust/src/optim/) are asserted against the same
semantics through golden vectors emitted by aot.py.
"""

import jax
import jax.numpy as jnp


def causal_attention_ref(q, k, v, scale=None):
    """Causal scaled-dot-product attention, single head.

    q, k, v: f32[T, Dh].  Returns f32[T, Dh].
    """
    t = q.shape[0]
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    logits = (q @ k.T) * scale
    mask = jnp.tril(jnp.ones((t, t), dtype=bool))
    logits = jnp.where(mask, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    return p @ v


def causal_attention_ref_bhtd(q, k, v, scale=None):
    """Batched-heads version: q,k,v f32[BH, T, Dh] -> f32[BH, T, Dh]."""
    return jax.vmap(lambda a, b, c: causal_attention_ref(a, b, c, scale))(q, k, v)


def masked_adam_ref(w, m, v, g, mask, lr, beta1, beta2, eps, step):
    """Masked Adam coordinate update (BlockLLM inner update, paper eq. 1).

    All arrays are flat f32[N]; mask is {0,1} f32[N]; step is the 1-based Adam
    timestep used for bias correction.  Only masked coordinates advance their
    optimizer state and weight; unmasked coordinates are left untouched (this
    is the BlockLLM semantics: optimizer state exists only for the active
    block, and within the block only masked coordinates move).

    Returns (w', m', v').
    """
    m_new = beta1 * m + (1.0 - beta1) * g
    v_new = beta2 * v + (1.0 - beta2) * g * g
    m_hat = m_new / (1.0 - beta1**step)
    v_hat = v_new / (1.0 - beta2**step)
    upd = lr * m_hat / (jnp.sqrt(v_hat) + eps)
    w_out = jnp.where(mask > 0, w - upd, w)
    m_out = jnp.where(mask > 0, m_new, m)
    v_out = jnp.where(mask > 0, v_new, v)
    return w_out, m_out, v_out


def rmsnorm_ref(x, weight, eps=1e-6):
    """RMSNorm over the last axis. x: f32[..., D], weight: f32[D]."""
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * weight


def swiglu_ref(x, w_gate, w_up, w_down):
    """SwiGLU MLP: silu(x@Wg) * (x@Wu) @ Wd."""
    return (jax.nn.silu(x @ w_gate) * (x @ w_up)) @ w_down
