# L1: Pallas kernels for the paper's compute hot-spots.
#
# attention.py   — tiled causal attention (the model's FLOP hot-spot)
# masked_adam.py — fused masked-Adam coordinate update (BlockLLM's inner loop)
# ref.py         — pure-jnp oracles both kernels are tested against
