"""Pallas causal flash-attention kernel (L1 compute hot-spot).

The kernel implements the online-softmax (flash) schedule: the grid iterates
over (batch*heads, q_blocks); each program streams kv blocks through VMEM,
maintaining running max / running denominator / output accumulator, so the
full [T, T] logits matrix is never materialized.

HARDWARE-ADAPTATION NOTE (GPU paper -> TPU kernel, DESIGN.md §3): the paper's
memory argument lives at the optimizer level, but its models are standard
LLaMA attention stacks.  On GPU one would tile over threadblocks with shared
memory; here the BlockSpec grid expresses the HBM->VMEM schedule instead:
  - q tile:  [BLOCK_Q, Dh]   resident in VMEM for the whole row of kv steps,
  - kv tile: [BLOCK_K, Dh]x2 streamed per inner step,
  - accum:   [BLOCK_Q, Dh] f32 accumulator + [BLOCK_Q] running (m, l) stats.
VMEM per program = (BLOCK_Q + 2*BLOCK_K)*Dh*4 + O(BLOCK_Q) bytes; with the
default BLOCK_Q=BLOCK_K=32, Dh<=64 this is ~24 KiB, far under the ~16 MiB
VMEM budget — chosen small so interpret-mode lowering stays compact.  The
inner matmuls are [BLOCK_Q, Dh] @ [Dh, BLOCK_K] and [BLOCK_Q, BLOCK_K] @
[BLOCK_K, Dh] — MXU-shaped (pad Dh to 128 on real TPU for full utilization).

interpret=True ALWAYS: real-TPU lowering emits a Mosaic custom-call the CPU
PJRT plugin cannot execute (see /opt/xla-example/README.md).

Differentiation: pallas_call has no autodiff rule, so `causal_attention`
wraps the kernel in jax.custom_vjp with a pure-jnp backward (recomputation
style, like flash-attention's bwd).  The forward in the lowered train HLO is
the Pallas schedule; the backward is the reference gradient.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

DEFAULT_BLOCK_Q = 32
DEFAULT_BLOCK_K = 32
NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, block_q, block_k, scale, seq_len):
    """One (batch*head, q_block) program: stream kv blocks, online softmax."""
    q_blk = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32) * scale  # [block_q, dh]

    dh = q.shape[-1]
    q_base = q_blk * block_q
    q_ids = q_base + jax.lax.iota(jnp.int32, block_q)

    num_k_blocks = pl.cdiv(seq_len, block_k)

    def body(kb, carry):
        acc, m_i, l_i = carry
        k = pl.load(k_ref, (pl.dslice(kb * block_k, block_k), slice(None)))
        v = pl.load(v_ref, (pl.dslice(kb * block_k, block_k), slice(None)))
        k_ids = kb * block_k + jax.lax.iota(jnp.int32, block_k)
        s = q @ k.astype(jnp.float32).T  # [block_q, block_k]
        causal = q_ids[:, None] >= k_ids[None, :]
        s = jnp.where(causal, s, NEG_INF)
        m_new = jnp.maximum(m_i, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_i - m_new)
        l_new = alpha * l_i + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + p @ v.astype(jnp.float32)
        return acc, m_new, l_new

    # Causality: kv blocks strictly above the diagonal contribute nothing;
    # stop the stream at the q block's diagonal block.
    last_kb = jnp.minimum((q_base + block_q - 1) // block_k + 1, num_k_blocks)
    acc = jnp.zeros((block_q, dh), jnp.float32)
    m_i = jnp.full((block_q,), NEG_INF, jnp.float32)
    l_i = jnp.zeros((block_q,), jnp.float32)
    acc, m_i, l_i = jax.lax.fori_loop(0, last_kb, body, (acc, m_i, l_i))
    o_ref[...] = (acc / l_i[:, None]).astype(o_ref.dtype)


def causal_attention_pallas(q, k, v, *, block_q=DEFAULT_BLOCK_Q, block_k=DEFAULT_BLOCK_K, scale=None):
    """Raw Pallas forward. q,k,v: f32[BH, T, Dh] -> f32[BH, T, Dh].

    T is padded up to a multiple of the block sizes so every tile is full —
    padded kv rows carry key-ids > every valid query-id and are therefore
    annihilated by the causal mask; padded q rows are sliced off the output.
    """
    bh, t, dh = q.shape
    block_q = min(block_q, t)
    block_k = min(block_k, t)
    if scale is None:
        scale = 1.0 / (dh**0.5)

    # pad T to a common multiple of both block sizes (zeros; masked out)
    tp = t
    while tp % block_q or tp % block_k:
        tp += block_q - (tp % block_q) if tp % block_q else block_k - (tp % block_k)
    if tp != t:
        zpad = lambda a: jnp.pad(a, ((0, 0), (0, tp - t), (0, 0)))
        q, k, v = zpad(q), zpad(k), zpad(v)

    grid = (bh, tp // block_q)
    kernel = functools.partial(
        _attn_kernel, block_q=block_q, block_k=block_k, scale=scale, seq_len=tp
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, dh), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, tp, dh), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((None, tp, dh), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, dh), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(q, k, v)
    return out[:, :t, :] if tp != t else out


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def causal_attention(q, k, v, use_pallas=True):
    """Causal attention over [BH, T, Dh] with a Pallas fwd + jnp bwd."""
    if use_pallas:
        return causal_attention_pallas(q, k, v)
    return ref.causal_attention_ref_bhtd(q, k, v)


def _fwd(q, k, v, use_pallas):
    return causal_attention(q, k, v, use_pallas), (q, k, v)


def _bwd(use_pallas, res, g):
    q, k, v = res
    # Recomputation-style backward through the jnp reference (numerically
    # identical attention function; the kernel is only a schedule change).
    _, vjp = jax.vjp(ref.causal_attention_ref_bhtd, q, k, v)
    return vjp(g)


causal_attention.defvjp(_fwd, _bwd)
