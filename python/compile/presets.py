"""Model preset registry shared by model.py / aot.py / tests and mirrored in
rust/src/config/presets.rs.

Presets are LLaMA-architecture decoders scaled down to single-CPU-core scale
(see DESIGN.md §5 for the substitution argument).  The *structure* (RMSNorm,
RoPE attention, SwiGLU MLP, untied LM head) matches the paper's LLaMA 60M-7B
family; only the widths/depths are reduced.
"""

from dataclasses import dataclass


@dataclass(frozen=True)
class Preset:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    max_seq: int

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def param_count(self) -> int:
        """Total trainable parameter count (matches model.param_specs)."""
        v, d, f = self.vocab, self.d_model, self.d_ff
        per_layer = 2 * d + 4 * d * d + 3 * d * f
        return v * d + self.n_layers * per_layer + d + d * v

    def cls_param_count(self, n_out: int) -> int:
        """Classifier variant: trunk + pooled head, no LM head."""
        v, d, f = self.vocab, self.d_model, self.d_ff
        per_layer = 2 * d + 4 * d * d + 3 * d * f
        return v * d + self.n_layers * per_layer + d + d * n_out + n_out


# Stand-ins for the paper's LLaMA 60M / 130M / 350M / 7B ladder, scaled for a
# single CPU core.  Ratios between rungs (~2.4-3x) roughly match the paper's.
# "grain" is test-only: deliberately odd dims (non-multiples of the Rust GEMM
# block/unroll sizes) whose golden pins lock the kernels' remainder paths.
PRESETS = {
    "nano": Preset("nano", vocab=256, d_model=64, n_layers=2, n_heads=2, d_ff=176, max_seq=64),
    "grain": Preset("grain", vocab=101, d_model=18, n_layers=2, n_heads=1, d_ff=29, max_seq=32),
    "micro": Preset("micro", vocab=256, d_model=128, n_layers=4, n_heads=4, d_ff=352, max_seq=64),
    "tiny": Preset("tiny", vocab=256, d_model=256, n_layers=6, n_heads=4, d_ff=688, max_seq=64),
    "small": Preset("small", vocab=256, d_model=320, n_layers=8, n_heads=8, d_ff=864, max_seq=64),
    "base": Preset("base", vocab=256, d_model=448, n_layers=10, n_heads=8, d_ff=1216, max_seq=64),
}


def get(name: str) -> Preset:
    return PRESETS[name]


if __name__ == "__main__":
    for p in PRESETS.values():
        print(f"{p.name:6s} params={p.param_count()/1e6:7.3f}M cls2={p.cls_param_count(2)/1e6:7.3f}M")
