"""L2: LLaMA-style transformer in JAX — the paper's model family, build-time only.

Architecture (matches the paper's LLaMA 60M..7B ladder, scaled by presets.py):
token embedding -> N x [RMSNorm -> RoPE causal attention -> residual ->
RMSNorm -> SwiGLU MLP -> residual] -> final RMSNorm -> head.

Heads:
  - "lm":   untied LM head, next-token cross-entropy (pretraining / Alpaca-sim
            finetuning; targets of -1 are ignored, which is how the Alpaca-sim
            data masks the instruction prefix).
  - "cls":  mean-pooled K-way classification head (GLUE-sim, DistilBERT-sim).
  - "reg":  mean-pooled scalar regression head (STS-B-sim).

Parameters travel as a FLAT TUPLE in the canonical order of param_specs() —
this order is the ABI between aot.py's manifest and the Rust runtime
(rust/src/model/spec.rs).  Do not reorder.

The attention hot-spot calls the L1 Pallas kernel (kernels/attention.py) when
use_pallas=True, so the kernel lowers into the same train/eval HLO artifact.
"""

import functools

import jax
import jax.numpy as jnp

from .presets import Preset
from .kernels import attention as attn_k
from .kernels import ref as kref

RMS_EPS = 1e-6


# ---------------------------------------------------------------------------
# Parameter specs (the ABI)
# ---------------------------------------------------------------------------

def param_specs(p: Preset, head: str = "lm", n_out: int = 2):
    """Ordered [(name, shape)] for a preset+head — mirrored by the manifest."""
    specs = [("tok_emb", (p.vocab, p.d_model))]
    for i in range(p.n_layers):
        pre = f"layers.{i}."
        specs += [
            (pre + "attn_norm", (p.d_model,)),
            (pre + "wq", (p.d_model, p.d_model)),
            (pre + "wk", (p.d_model, p.d_model)),
            (pre + "wv", (p.d_model, p.d_model)),
            (pre + "wo", (p.d_model, p.d_model)),
            (pre + "mlp_norm", (p.d_model,)),
            (pre + "w_gate", (p.d_model, p.d_ff)),
            (pre + "w_up", (p.d_model, p.d_ff)),
            (pre + "w_down", (p.d_ff, p.d_model)),
        ]
    specs.append(("final_norm", (p.d_model,)))
    if head == "lm":
        specs.append(("lm_head", (p.d_model, p.vocab)))
    elif head == "cls":
        specs.append(("cls_head", (p.d_model, n_out)))
        specs.append(("cls_bias", (n_out,)))
    elif head == "reg":
        specs.append(("cls_head", (p.d_model, 1)))
        specs.append(("cls_bias", (1,)))
    else:
        raise ValueError(f"unknown head {head!r}")
    return specs


def init_params(key, p: Preset, head: str = "lm", n_out: int = 2):
    """Reference init (tests only; Rust owns the real init with the same scheme):
    normals scaled 0.02 for embeddings/heads, 1/sqrt(fan_in) for matrices,
    ones for norms, zeros for biases."""
    out = []
    for name, shape in param_specs(p, head, n_out):
        key, sub = jax.random.split(key)
        if "norm" in name:
            out.append(jnp.ones(shape, jnp.float32))
        elif name == "cls_bias":
            out.append(jnp.zeros(shape, jnp.float32))
        elif name in ("tok_emb", "lm_head") or name == "cls_head":
            out.append(0.02 * jax.random.normal(sub, shape, jnp.float32))
        else:
            out.append(jax.random.normal(sub, shape, jnp.float32) / jnp.sqrt(shape[0]))
    return out


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _rope(x, positions):
    """Rotary embedding. x: [B, T, H, Dh]; rotate half-dims pairwise."""
    b, t, h, dh = x.shape
    half = dh // 2
    freq = 1.0 / (10000.0 ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions[:, None].astype(jnp.float32) * freq[None, :]  # [T, half]
    cos = jnp.cos(angles)[None, :, None, :]
    sin = jnp.sin(angles)[None, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _attention_block(x, wq, wk, wv, wo, p: Preset, use_pallas: bool):
    b, t, d = x.shape
    h, dh = p.n_heads, p.d_head
    pos = jnp.arange(t)
    q = _rope((x @ wq).reshape(b, t, h, dh), pos)
    k = _rope((x @ wk).reshape(b, t, h, dh), pos)
    v = (x @ wv).reshape(b, t, h, dh)
    # [B, T, H, Dh] -> [B*H, T, Dh]
    to_bh = lambda a: a.transpose(0, 2, 1, 3).reshape(b * h, t, dh)
    o = attn_k.causal_attention(to_bh(q), to_bh(k), to_bh(v), use_pallas)
    o = o.reshape(b, h, t, dh).transpose(0, 2, 1, 3).reshape(b, t, d)
    return o @ wo


def trunk(params, tokens, p: Preset, use_pallas: bool = False):
    """Embedding + transformer stack + final norm. tokens: i32[B,T] -> f32[B,T,D]."""
    it = iter(params)
    nxt = lambda: next(it)
    tok_emb = nxt()
    x = tok_emb[tokens]
    for _ in range(p.n_layers):
        attn_norm, wq, wk, wv, wo = nxt(), nxt(), nxt(), nxt(), nxt()
        mlp_norm, w_gate, w_up, w_down = nxt(), nxt(), nxt(), nxt()
        hx = kref.rmsnorm_ref(x, attn_norm, RMS_EPS)
        x = x + _attention_block(hx, wq, wk, wv, wo, p, use_pallas)
        hx = kref.rmsnorm_ref(x, mlp_norm, RMS_EPS)
        x = x + kref.swiglu_ref(hx, w_gate, w_up, w_down)
    final_norm = nxt()
    return kref.rmsnorm_ref(x, final_norm, RMS_EPS), it


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def lm_loss_terms(params, tokens, targets, p: Preset, use_pallas: bool = False):
    """Next-token CE. targets: i32[B,T], -1 = ignore. Returns (sum, count)."""
    x, it = trunk(params, tokens, p, use_pallas)
    lm_head = next(it)
    logits = x @ lm_head  # [B, T, V]
    logp = jax.nn.log_softmax(logits, axis=-1)
    valid = targets >= 0
    tgt = jnp.where(valid, targets, 0)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    nll = jnp.where(valid, nll, 0.0)
    return jnp.sum(nll), jnp.sum(valid).astype(jnp.float32)


def lm_loss_mean(params, tokens, targets, p: Preset, use_pallas: bool = False):
    s, c = lm_loss_terms(params, tokens, targets, p, use_pallas)
    return s / jnp.maximum(c, 1.0)


def cls_logits(params, tokens, p: Preset, use_pallas: bool = False):
    """Mean-pooled classification/regression logits: f32[B, n_out]."""
    x, it = trunk(params, tokens, p, use_pallas)
    pooled = jnp.mean(x, axis=1)  # [B, D]
    w, b = next(it), next(it)
    return pooled @ w + b


def cls_loss_mean(params, tokens, labels, p: Preset, use_pallas: bool = False):
    """K-way CE; labels i32[B]."""
    logits = cls_logits(params, tokens, p, use_pallas)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(nll)


def reg_loss_mean(params, tokens, labels, p: Preset, use_pallas: bool = False):
    """MSE regression; labels f32[B]."""
    pred = cls_logits(params, tokens, p, use_pallas)[:, 0]
    return jnp.mean((pred - labels) ** 2)


# ---------------------------------------------------------------------------
# AOT entrypoints (fixed signature: (*params, tokens, targets) -> tuple)
# ---------------------------------------------------------------------------

def make_lm_train(p: Preset, use_pallas: bool = False):
    """(params..., tokens i32[B,T], targets i32[B,T]) -> (loss, *grads)."""

    def f(*args):
        params, tokens, targets = list(args[:-2]), args[-2], args[-1]
        loss, grads = jax.value_and_grad(
            lambda ps: lm_loss_mean(ps, tokens, targets, p, use_pallas)
        )(params)
        return (loss, *grads)

    return f


def make_lm_eval(p: Preset, use_pallas: bool = False):
    """(params..., tokens, targets) -> (loss_sum, valid_count)."""

    def f(*args):
        params, tokens, targets = list(args[:-2]), args[-2], args[-1]
        return lm_loss_terms(params, tokens, targets, p, use_pallas)

    return f


def make_cls_train(p: Preset, n_out: int, regression: bool = False, use_pallas: bool = False):
    """(params..., tokens i32[B,T], labels) -> (loss, *grads)."""
    loss_fn = reg_loss_mean if regression else cls_loss_mean

    def f(*args):
        params, tokens, labels = list(args[:-2]), args[-2], args[-1]
        loss, grads = jax.value_and_grad(
            lambda ps: loss_fn(ps, tokens, labels, p, use_pallas)
        )(params)
        return (loss, *grads)

    return f


def make_cls_eval(p: Preset, n_out: int, regression: bool = False, use_pallas: bool = False):
    """(params..., tokens, labels) -> (loss_sum, metric_sum, preds f32[B]).

    metric_sum = #correct for classification; sum of squared error for
    regression (preds let Rust compute Spearman/Matthews exactly).
    """

    def f(*args):
        params, tokens, labels = list(args[:-2]), args[-2], args[-1]
        logits = cls_logits(params, tokens, p, use_pallas)
        if regression:
            pred = logits[:, 0]
            se = (pred - labels) ** 2
            return jnp.sum(se), jnp.sum(se), pred
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
        pred = jnp.argmax(logits, axis=-1)
        correct = jnp.sum((pred == labels).astype(jnp.float32))
        return jnp.sum(nll), correct, pred.astype(jnp.float32)

    return f
