# Repo driver targets. `make check` is the tier-1 gate (ROADMAP.md); it
# needs only a Rust toolchain — no Python, no artifacts: tests fall back to
# the pure-Rust NativeBackend when artifacts/ is absent.

.PHONY: check build test bench artifacts clean

check: build test

build:
	cargo build --release

test:
	cargo test -q

bench:
	cargo bench

# AOT-lower the JAX model to HLO artifacts (enables the PJRT backend).
# Requires jax; run from a machine with the Python toolchain.
artifacts:
	cd python && python -m compile.aot --out ../artifacts

clean:
	cargo clean
