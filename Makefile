# Repo driver targets. `make check` is the tier-1 gate (ROADMAP.md); it
# needs only a Rust toolchain — no Python, no artifacts: tests fall back to
# the pure-Rust NativeBackend when artifacts/ is absent.

.PHONY: check build test lint bench bench-attention bench-baseline dist-check profile artifacts clean

check: build test

build:
	cargo build --release

test:
	cargo test -q

# Mirrors CI's lint job (scoped to the blockllm package; the vendored
# offline crates under rust/vendor/ are frozen subsets, not house code).
lint:
	cargo fmt -p blockllm --check
	cargo clippy --release -p blockllm -- -D warnings
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps -p blockllm

bench:
	cargo bench

# Isolated attention ms/step: batched strided-GEMM path vs per-head loop.
bench-attention:
	cargo bench --bench attention -- --preset tiny --out BENCH_attention.json

# Regenerate the checked-in bench-smoke baseline (run on the host class that
# gates CI; ms/step is host-ratio-rescaled via calib_ms, but a same-class
# baseline keeps the 25% regression margin tight). --threads must match the
# pinned worker count in ci.yml: the gate only arms when the baseline's
# recorded thread count equals the gated run's.
bench-baseline:
	cargo bench --bench train_step -- --preset tiny --warmup 1 --iters 4 --threads 4 --out BENCH_train_step.baseline.json

# Mirrors CI's replicated dist leg locally: the full tier-1 suite with the
# process default forced to 2 in-process replicas, then the targeted
# replica-count pins (bitwise {1,2,4}-replica parity, ZeRO state-shard
# shrinkage, replicated suspend/resume) under their own knob grids.
dist-check:
	PALLAS_REPLICAS=2 cargo test -q
	cargo test -q --test grad_check replicated_training_bitwise_identical_across_replica_counts
	cargo test -q --test grad_check blockllm_state_shard_bytes_shrink_with_replicas
	cargo test -q --test session_resume replicated_suspend_resume_is_bitwise_and_matches_sequential

# Profile a short training run: span table + counters on stderr, profile
# block in the run output, and a Perfetto/chrome://tracing trace-event file
# (open trace_grain.json at ui.perfetto.dev). See README.md "Profiling a
# run"; swap --preset/--method/--steps freely.
profile:
	cargo run --release -- train --preset grain --method blockllm --task c4 \
		--steps 5 --eval-every 0 --trace 1 --trace-out trace_grain.json

# AOT-lower the JAX model to HLO artifacts (enables the PJRT backend).
# Requires jax; run from a machine with the Python toolchain.
artifacts:
	cd python && python -m compile.aot --out ../artifacts

clean:
	cargo clean
