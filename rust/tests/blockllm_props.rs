//! Property tests for the BlockLLM core (selector / mask / patience): many
//! randomized instances checked against the algorithm's contracts rather
//! than hand-picked examples. The offline crate set has no proptest, so the
//! repo's own Pcg64 drives the case generation.

use blockllm::blockllm::mask::{active_coords, build_masks};
use blockllm::blockllm::scorer::NormDictionary;
use blockllm::blockllm::selector::{select_layers, SelectionRule};
use blockllm::blockllm::PatienceController;
use blockllm::config::{MaskMode, NormKind};
use blockllm::optim::masked_adam::BitMask;
use blockllm::util::rng::Pcg64;

fn rand_sizes(rng: &mut Pcg64, max_layers: usize, max_size: usize) -> Vec<usize> {
    let n_layers = 1 + rng.below(max_layers);
    (0..n_layers).map(|_| 1 + rng.below(max_size)).collect()
}

fn rand_dict(rng: &mut Pcg64, n_layers: usize) -> NormDictionary {
    let mut d = NormDictionary::new(n_layers, NormKind::Rms, rng.next_u64());
    for l in 0..n_layers {
        d.record_norm(l, rng.uniform() * 10.0, 0);
    }
    d
}

fn rand_grads(rng: &mut Pcg64, sizes: &[usize]) -> Vec<Vec<f32>> {
    sizes
        .iter()
        .map(|&n| (0..n).map(|_| rng.normal_f32()).collect())
        .collect()
}

/// `build_masks` respects the configured sparsity level EXACTLY: for any
/// layer-size vector and any s, active_coords <= max(1, floor((1-s)·n)).
#[test]
fn masks_never_exceed_the_sparsity_budget() {
    let mut rng = Pcg64::new(0xB10C);
    for trial in 0..200 {
        let sizes = rand_sizes(&mut rng, 12, 2000);
        let n: usize = sizes.iter().sum();
        // sparsity across the whole operating range, incl. extremes
        let sparsity = match trial % 4 {
            0 => 0.95,
            1 => 0.5,
            2 => rng.uniform() * 0.999,
            _ => 0.999,
        };
        let dict = rand_dict(&mut rng, sizes.len());
        let grads = rand_grads(&mut rng, &sizes);
        let budget = (((1.0 - sparsity) * n as f64).floor() as usize).max(1);
        for mode in [MaskMode::Alg2, MaskMode::OvershootOnly] {
            let sel = select_layers(&dict, &sizes, sparsity, SelectionRule::TopScore);
            let masks = build_masks(&sel, &grads, mode);
            let active = active_coords(&masks);
            assert!(
                active <= budget,
                "trial {trial} {mode:?}: active {active} > budget {budget} \
                 (s={sparsity}, sizes={sizes:?})"
            );
            // the budget must also be reasonably used, not just bounded
            assert!(
                active * 2 + sizes.len() >= budget.min(sel.sigma_p),
                "trial {trial} {mode:?}: active {active} far below budget {budget}"
            );
        }
    }
}

/// `select_layers` never returns duplicates or out-of-range indices, covers
/// the budget (or runs out of layers), and reports a consistent Σ_p.
#[test]
fn selection_indices_are_unique_in_range_and_cover_the_budget() {
    let mut rng = Pcg64::new(0x5E1E);
    for trial in 0..300 {
        let sizes = rand_sizes(&mut rng, 16, 5000);
        let n: usize = sizes.iter().sum();
        let sparsity = rng.uniform();
        let dict = rand_dict(&mut rng, sizes.len());
        let rule = match trial % 3 {
            0 => SelectionRule::TopScore,
            1 => SelectionRule::BottomScore,
            _ => SelectionRule::TopScoreNoFreq,
        };
        let sel = select_layers(&dict, &sizes, sparsity, rule);
        assert!(!sel.layers.is_empty(), "trial {trial}: empty selection");
        let mut seen = std::collections::HashSet::new();
        for &l in &sel.layers {
            assert!(l < sizes.len(), "trial {trial}: layer {l} out of range");
            assert!(seen.insert(l), "trial {trial}: duplicate layer {l}");
        }
        let sum: usize = sel.layers.iter().map(|&l| sizes[l]).sum();
        assert_eq!(sum, sel.sigma_p, "trial {trial}: Σ_p inconsistent");
        assert!(sel.n_s >= 1 && sel.n_s <= n.max(1));
        assert!(
            sel.sigma_p >= sel.n_s || sel.layers.len() == sizes.len(),
            "trial {trial}: budget not covered and layers remain"
        );
        assert!(sel.keep_frac > 0.0 && sel.keep_frac <= 1.0);
        assert!((0.0..=1.0).contains(&sel.zeta));
    }
}

/// `PatienceController::observe` fires iff the loss window stagnates: an
/// independent reference model (t=0 always fires; otherwise fire iff the
/// window holds m entries and loss >= window mean; reset on fire) must agree
/// on every step of random loss trajectories.
#[test]
fn patience_fires_iff_the_loss_window_stagnates() {
    let mut rng = Pcg64::new(0xA71E);
    for trial in 0..50 {
        let m = 1 + rng.below(8);
        let mut p = PatienceController::new(m);
        let mut window: Vec<f64> = Vec::new();
        let mut started = false;
        let mut loss = 5.0 + rng.uniform();
        let mut fires = 0u64;
        for step in 0..400 {
            // random walk with a downward drift and occasional spikes
            loss += rng.normal() * 0.1 - 0.02;
            if rng.below(20) == 0 {
                loss += rng.uniform() * 2.0;
            }
            let want = if !started {
                true
            } else {
                window.len() >= m && loss >= window.iter().sum::<f64>() / window.len() as f64
            };
            let got = p.observe(loss);
            assert_eq!(got, want, "trial {trial} step {step} (m={m}): {got} vs reference {want}");
            if !started {
                started = true;
                window.push(loss);
            } else {
                if want {
                    fires += 1;
                    window.clear();
                }
                if window.len() == m {
                    window.remove(0);
                }
                window.push(loss);
            }
        }
        assert_eq!(p.triggers, fires + 1, "trial {trial}: trigger count");
        assert!(p.history_len() <= m);
    }
}

/// `BitMask::top_k` picks exactly min(k, #nonzero) coordinates and they
/// dominate every unselected coordinate by |value|.
#[test]
fn top_k_is_exact_and_magnitude_dominant() {
    let mut rng = Pcg64::new(0x70C0);
    for trial in 0..200 {
        let n = 1 + rng.below(500);
        let mut g: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
        // inject zeros and ties
        for _ in 0..rng.below(n / 2 + 1) {
            let i = rng.below(n);
            g[i] = 0.0;
        }
        if n > 3 {
            let v = g[0];
            g[n / 2] = v;
            g[n - 1] = -v;
        }
        let k = rng.below(n + 2);
        let mask = BitMask::top_k(&g, k);
        let nz = g.iter().filter(|x| **x != 0.0).count();
        assert_eq!(mask.popcount, k.min(nz), "trial {trial}: popcount");
        let mut min_sel = f32::INFINITY;
        let mut max_unsel = 0.0f32;
        for (i, &x) in g.iter().enumerate() {
            if mask.get(i) {
                assert!(x != 0.0, "trial {trial}: zero coordinate selected");
                min_sel = min_sel.min(x.abs());
            } else {
                max_unsel = max_unsel.max(x.abs());
            }
        }
        if mask.popcount > 0 && mask.popcount < nz {
            assert!(
                min_sel >= max_unsel,
                "trial {trial}: unselected |{max_unsel}| beats selected |{min_sel}|"
            );
        }
        // determinism: identical input -> identical mask
        assert_eq!(mask, BitMask::top_k(&g, k), "trial {trial}: nondeterministic");
    }
}
