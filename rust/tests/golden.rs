//! Golden integration tests against the AOT artifacts: prove the full PJRT
//! ABI — parameter ordering, literal marshaling, HLO loading, execution —
//! reproduces the numbers jax computed at lowering time
//! (artifacts/golden.json), and that the Rust-native masked Adam matches the
//! Pallas kernel artifact bit-for-bit semantics.
//!
//! These tests exercise the PJRT side of the backend layer, so they require
//! `make artifacts` AND a working PJRT client (the real xla_extension
//! binding, not the vendored stub); they are skipped otherwise. The
//! artifact-free twin of this file is tests/native_golden.rs, which pins the
//! SAME jax-computed numbers against the pure-Rust native backend and always
//! runs.

use blockllm::model::ParamStore;
use blockllm::runtime::{lit_f32, lit_i32, scalar_f32, Runtime};
use blockllm::util::json::Json;

fn open_runtime() -> Option<(Runtime, Json)> {
    // artifacts/ lives at the REPO root (make artifacts -> <repo>/artifacts),
    // one level above this crate's manifest dir (<repo>/rust)
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate dir has a parent");
    let dir = root.join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP (pjrt-only test): artifacts/ missing; run `make artifacts`");
        return None;
    }
    let rt = match Runtime::open(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("SKIP (pjrt-only test): runtime unavailable: {e}");
            return None;
        }
    };
    let golden = Json::parse(&std::fs::read_to_string(dir.join("golden.json")).unwrap()).unwrap();
    Some((rt, golden))
}

/// tokens[i,j] = (7i + 13j + salt) % vocab — mirror of aot.filler_tokens.
fn filler_tokens(b: usize, t: usize, vocab: usize, salt: i64) -> Vec<i32> {
    let mut out = Vec::with_capacity(b * t);
    for i in 0..b as i64 {
        for j in 0..t as i64 {
            out.push(((7 * i + 13 * j + salt) % vocab as i64) as i32);
        }
    }
    out
}

fn golden_for<'j>(golden: &'j Json, artifact: &str) -> Option<&'j Json> {
    golden
        .as_arr()
        .unwrap()
        .iter()
        .find(|g| g.get("artifact").and_then(|a| a.as_str().ok()) == Some(artifact))
}

fn check_lm_train(rt: &mut Runtime, golden: &Json, id: &str) {
    let art = rt.artifact(id).unwrap().clone();
    let store = ParamStore::fill_deterministic(&art.params);
    let (b, t) = (art.batch, art.seq);
    let vocab = rt.manifest.presets[&art.preset].vocab;
    let mut inputs = store.to_literals().unwrap();
    inputs.push(lit_i32(&filler_tokens(b, t, vocab, 0), &[b, t]).unwrap());
    inputs.push(lit_i32(&filler_tokens(b, t, vocab, 3), &[b, t]).unwrap());
    let outs = rt.execute(id, &inputs).unwrap();
    assert_eq!(outs.len(), 1 + art.params.len(), "output arity");

    let g = golden_for(golden, id).expect("golden probe");
    let want_loss = g.req("loss").unwrap().as_f64().unwrap();
    let got_loss = scalar_f32(&outs[0]).unwrap() as f64;
    assert!(
        (got_loss - want_loss).abs() < 1e-3 * want_loss.abs().max(1.0),
        "{id}: loss {got_loss} vs golden {want_loss}"
    );

    // gradient-path pin: first three grad norms
    if let Some(norms) = g.get("grad_norms_first3") {
        for (k, want) in norms.as_arr().unwrap().iter().enumerate() {
            let want = want.as_f64().unwrap();
            let gv = outs[1 + k].to_vec::<f32>().unwrap();
            let got: f64 = gv.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
            assert!(
                (got - want).abs() < 2e-3 * want.abs().max(1e-3),
                "{id}: grad norm {k}: {got} vs {want}"
            );
        }
    }
}

#[test]
fn lm_train_artifact_matches_jax_golden() {
    let Some((mut rt, golden)) = open_runtime() else { return };
    check_lm_train(&mut rt, &golden, "nano_lm_train_b8t64");
}

#[test]
fn pallas_twin_matches_jax_golden_and_jnp_twin() {
    let Some((mut rt, golden)) = open_runtime() else { return };
    // the pallas-attention artifact must satisfy ITS golden...
    check_lm_train(&mut rt, &golden, "nano_lm_train_b8t64_pallas");
    // ...and its golden must equal the jnp twin's golden (same function)
    let a = golden_for(&golden, "nano_lm_train_b8t64").unwrap();
    let b = golden_for(&golden, "nano_lm_train_b8t64_pallas").unwrap();
    let la = a.req("loss").unwrap().as_f64().unwrap();
    let lb = b.req("loss").unwrap().as_f64().unwrap();
    assert!((la - lb).abs() < 1e-4 * la.abs().max(1.0), "pallas {lb} vs jnp {la}");
}

#[test]
fn lm_eval_artifact_matches_jax_golden() {
    let Some((mut rt, golden)) = open_runtime() else { return };
    let id = "nano_lm_eval_b8t64";
    let art = rt.artifact(id).unwrap().clone();
    let store = ParamStore::fill_deterministic(&art.params);
    let (b, t) = (art.batch, art.seq);
    let vocab = rt.manifest.presets[&art.preset].vocab;
    let mut inputs = store.to_literals().unwrap();
    inputs.push(lit_i32(&filler_tokens(b, t, vocab, 0), &[b, t]).unwrap());
    inputs.push(lit_i32(&filler_tokens(b, t, vocab, 3), &[b, t]).unwrap());
    let outs = rt.execute(id, &inputs).unwrap();
    let g = golden_for(&golden, id).unwrap();
    let want = g.req("loss").unwrap().as_f64().unwrap();
    let got = scalar_f32(&outs[0]).unwrap() as f64;
    assert!((got - want).abs() < 1e-3 * want.abs(), "{got} vs {want}");
    let want_cnt = g.req("valid_count").unwrap().as_f64().unwrap();
    assert_eq!(scalar_f32(&outs[1]).unwrap() as f64, want_cnt);
}

/// The Pallas masked-Adam kernel artifact and the Rust-native hot path must
/// produce identical updates (same golden vectors as aot.py computed).
#[test]
fn masked_adam_kernel_parity_rust_vs_pallas_artifact() {
    let Some((mut rt, golden)) = open_runtime() else { return };
    let id = "masked_adam_4096";
    let g = golden_for(&golden, id).expect("masked_adam golden");
    let n = g.req("n").unwrap().as_usize().unwrap();
    let h = g.req("hypers").unwrap();
    let (lr, b1, b2, eps) = (
        h.req("lr").unwrap().as_f64().unwrap(),
        h.req("beta1").unwrap().as_f64().unwrap(),
        h.req("beta2").unwrap().as_f64().unwrap(),
        h.req("eps").unwrap().as_f64().unwrap(),
    );
    let step = h.req("step").unwrap().as_usize().unwrap() as u64;

    // deterministic inputs — mirror of aot.build_masked_adam_artifact
    let j: Vec<f32> = (0..n).map(|i| i as f32).collect();
    let w0: Vec<f32> = j.iter().map(|x| (0.05 * x).sin()).collect();
    let m0: Vec<f32> = j.iter().map(|x| 0.01 * (0.07 * x).cos()).collect();
    let v0: Vec<f32> = j.iter().map(|x| 0.001 * (1.0 + (0.11 * x).sin().powi(2))).collect();
    let g0: Vec<f32> = j.iter().map(|x| 0.5 * (0.13 * x).cos()).collect();
    let maskf: Vec<f32> = (0..n).map(|i| if i % 3 == 0 { 1.0 } else { 0.0 }).collect();

    // (a) execute the Pallas artifact
    let hyp = vec![lr as f32, b1 as f32, b2 as f32, eps as f32, step as f32, 0.0];
    let inputs = vec![
        lit_f32(&w0, &[n]).unwrap(),
        lit_f32(&m0, &[n]).unwrap(),
        lit_f32(&v0, &[n]).unwrap(),
        lit_f32(&g0, &[n]).unwrap(),
        lit_f32(&maskf, &[n]).unwrap(),
        lit_f32(&hyp, &[6]).unwrap(),
    ];
    let outs = rt.execute(id, &inputs).unwrap();
    let w_pallas = outs[0].to_vec::<f32>().unwrap();

    // (b) run the Rust-native hot path
    let mut w_rust = w0.clone();
    let mask = blockllm::optim::masked_adam::BitMask::from_threshold(&maskf, 0.5);
    let mut st = blockllm::optim::masked_adam::LayerState { m: m0.clone(), v: v0.clone(), mask };
    let hypers = blockllm::optim::AdamHypers { beta1: b1, beta2: b2, eps, weight_decay: 0.0 };
    blockllm::optim::masked_adam_step(&mut w_rust, &g0, &mut st, step, lr, &hypers);

    // (c) both must match the jnp-reference checksums AND each other
    let sum = |xs: &[f32]| xs.iter().map(|&x| x as f64).sum::<f64>();
    let want_sum = g.req("checksums").unwrap().req("w_out_sum").unwrap().as_f64().unwrap();
    assert!(
        (sum(&w_pallas) - want_sum).abs() < 1e-2,
        "pallas sum {} vs {}",
        sum(&w_pallas),
        want_sum
    );
    assert!((sum(&w_rust) - want_sum).abs() < 1e-2, "rust sum {} vs {}", sum(&w_rust), want_sum);
    for i in 0..n {
        assert!(
            (w_pallas[i] - w_rust[i]).abs() < 1e-6,
            "coord {i}: pallas {} vs rust {}",
            w_pallas[i],
            w_rust[i]
        );
    }
}

/// End-to-end smoke: twelve BlockLLM steps through the PJRT backend reduce
/// the loss (full L3 -> backend -> PJRT -> L3 loop).
#[test]
fn pjrt_steps_reduce_loss_on_fixed_batch() {
    let Some((_rt, _)) = open_runtime() else { return };
    let mut cfg = blockllm::config::TrainConfig::default();
    cfg.preset = "nano".into();
    cfg.backend = blockllm::config::BackendKind::Pjrt;
    cfg.steps = 12;
    cfg.eval_every = 0;
    cfg.eval_batches = 2;
    cfg.lr = 3e-3;
    cfg.sparsity = 0.5;
    cfg.cosine_lr = false;
    let res = blockllm::experiments::common::run_config(&cfg, None).unwrap();
    assert_eq!(res.backend, "pjrt");
    let first = res.train_losses[0];
    let last = res.tail_train_loss(3);
    assert!(
        last < first,
        "loss did not improve: first {first} last {last} ({:?})",
        res.train_losses
    );
}
