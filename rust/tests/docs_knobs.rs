//! Keeps docs/KNOBS.md and the CLI usage text (`cli::USAGE`) from
//! drifting apart: every `PALLAS_*` env var must be named by both, every
//! CLI flag documented in the knob tables must exist in the usage text,
//! and the serve spec keys must be described in both places. The README
//! must link both documentation pages.
//!
//! Extraction is plain string scanning (no regex crate in the offline
//! universe): `PALLAS_`-prefixed uppercase tokens, and `--flag` tokens
//! from the markdown table rows only (prose mentions like `--help` or
//! bench-only flags are deliberately out of scope).

use std::collections::BTreeSet;

use blockllm::cli::USAGE;

fn repo_doc(rel: &str) -> String {
    let path = format!("{}/../{rel}", env!("CARGO_MANIFEST_DIR"));
    std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("read {path}: {e}"))
}

/// All `PALLAS_<UPPER>` tokens in `text` (trailing underscores trimmed,
/// so the wildcard `PALLAS_*` in prose never matches).
fn pallas_vars(text: &str) -> BTreeSet<String> {
    let bytes = text.as_bytes();
    let pat = b"PALLAS_";
    let mut out = BTreeSet::new();
    let mut i = 0;
    while i + pat.len() <= bytes.len() {
        if &bytes[i..i + pat.len()] == pat {
            let mut j = i + pat.len();
            while j < bytes.len() && (bytes[j].is_ascii_uppercase() || bytes[j] == b'_') {
                j += 1;
            }
            let tok = text[i..j].trim_end_matches('_');
            if tok.len() > pat.len() {
                out.insert(tok.to_string());
            }
            i = j.max(i + 1);
        } else {
            i += 1;
        }
    }
    out
}

/// `--flag` tokens found in markdown TABLE rows (lines starting with `|`).
/// The char after `--` must be a lowercase letter, which skips the
/// `|---|---|` separator rows.
fn table_flags(md: &str) -> BTreeSet<String> {
    let mut out = BTreeSet::new();
    for line in md.lines().filter(|l| l.trim_start().starts_with('|')) {
        let mut rest = line;
        while let Some(p) = rest.find("--") {
            let tail = &rest[p + 2..];
            if tail.chars().next().map_or(false, |c| c.is_ascii_lowercase()) {
                let end = tail
                    .find(|c: char| !(c.is_ascii_lowercase() || c == '-'))
                    .unwrap_or(tail.len());
                out.insert(format!("--{}", &tail[..end]));
                rest = &tail[end..];
            } else {
                rest = tail;
            }
        }
    }
    out
}

#[test]
fn pallas_env_vars_agree_between_knobs_md_and_usage() {
    let md = repo_doc("docs/KNOBS.md");
    let doc_vars = pallas_vars(&md);
    let usage_vars = pallas_vars(USAGE);
    assert!(!usage_vars.is_empty(), "usage text names no PALLAS_* vars?");
    assert_eq!(
        doc_vars, usage_vars,
        "PALLAS_* env vars drifted between docs/KNOBS.md and cli::USAGE"
    );
}

#[test]
fn every_documented_flag_exists_in_usage() {
    let md = repo_doc("docs/KNOBS.md");
    let flags = table_flags(&md);
    // sanity: the extraction actually found the knob tables
    for expect in ["--threads", "--grad-stream", "--replicas", "--sched", "--watch-spec"] {
        assert!(flags.contains(expect), "KNOBS.md table lost {expect}");
    }
    for f in &flags {
        assert!(
            USAGE.contains(f.as_str()),
            "docs/KNOBS.md documents {f} but cli::USAGE does not mention it"
        );
    }
}

#[test]
fn serve_spec_keys_documented_in_both() {
    let md = repo_doc("docs/KNOBS.md");
    for key in [
        "slice_steps",
        "sched",
        "total_budget_mb",
        "starvation_turns",
        "budget_mb",
        "weight",
        "deadline",
    ] {
        assert!(md.contains(key), "docs/KNOBS.md lost serve spec key {key:?}");
        assert!(USAGE.contains(key), "cli::USAGE lost serve spec key {key:?}");
    }
}

#[test]
fn readme_links_the_docs_pages() {
    let readme = repo_doc("README.md");
    for page in ["docs/ARCHITECTURE.md", "docs/KNOBS.md"] {
        assert!(readme.contains(page), "README.md does not link {page}");
    }
}
