//! Integration tests across trainer + data + strategies, end-to-end through
//! the L2.5 backend layer. They run UNCONDITIONALLY: with AOT artifacts
//! present the `auto` backend executes via PJRT; without them (tier-1 CI,
//! any machine with no Python toolchain) every test drives the pure-Rust
//! `NativeBackend` — nothing here is allowed to skip.

use blockllm::config::{BackendKind, MaskMode, Method, Task, TrainConfig};
use blockllm::experiments::common::{run_config, run_config_with_params};

fn nano_cfg(method: Method) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.preset = "nano".into();
    cfg.task = Task::C4Pretrain;
    cfg.method = method;
    cfg.steps = 30;
    cfg.eval_every = 0;
    cfg.eval_batches = 2;
    cfg.lr = 3e-3;
    cfg.sparsity = 0.8;
    cfg.patience = 10;
    cfg
}

#[test]
fn every_method_learns_on_c4sim() {
    for method in [
        Method::BlockLlm,
        Method::FullAdam,
        Method::GaLore,
        Method::LoRa,
        Method::BAdam,
        Method::Magnitude,
    ] {
        let mut cfg = nano_cfg(method);
        if method == Method::LoRa {
            cfg.lr = 1e-2; // adapters need a hotter LR at this scale
        }
        let res = run_config(&cfg, None).unwrap();
        let first = res.train_losses[..3].iter().sum::<f64>() / 3.0;
        let last = res.tail_train_loss(3);
        assert!(
            last < first - 0.05,
            "{} [{}]: no learning ({first:.3} -> {last:.3})",
            method.name(),
            res.backend
        );
    }
}

#[test]
fn memory_ordering_matches_paper() {
    // Fig. 5 / Table 1 claim: blockllm < galore < fft on peak memory; badam
    // below fft too. Activation bytes are backend-constant, so the ordering
    // is invariant to which engine ran.
    let mut peak = std::collections::HashMap::new();
    for method in [Method::BlockLlm, Method::GaLore, Method::FullAdam, Method::BAdam] {
        let mut cfg = nano_cfg(method);
        cfg.sparsity = 0.95;
        cfg.steps = 10;
        let res = run_config(&cfg, None).unwrap();
        peak.insert(method.name(), res.peak_mem_bytes);
    }
    assert!(peak["blockllm"] < peak["galore"], "{peak:?}");
    assert!(peak["galore"] < peak["adam"], "{peak:?}");
    assert!(peak["badam"] < peak["adam"], "{peak:?}");
}

#[test]
fn blockllm_sparsity_budget_is_respected_end_to_end() {
    for s in [0.5, 0.9] {
        let mut cfg = nano_cfg(Method::BlockLlm);
        cfg.sparsity = s;
        cfg.steps = 5;
        let res = run_config(&cfg, None).unwrap();
        let n = 133_440.0; // nano param count
        let active = res.telem("active_coords").unwrap();
        let budget = (1.0 - s) * n;
        assert!(
            active <= budget * 1.1 + 64.0,
            "s={s}: active {active} exceeds budget {budget}"
        );
        assert!(active >= budget * 0.5, "s={s}: active {active} far below budget {budget}");
    }
}

#[test]
fn warm_start_transfers_trunk_lm_to_cls() {
    // short LM pretrain
    let mut lm_cfg = nano_cfg(Method::FullAdam);
    lm_cfg.steps = 30;
    let (_r, lm_store) = run_config_with_params(&lm_cfg, None).unwrap();

    // cls finetune warm vs cold on the domain-shift source task
    let mut cls_cfg = nano_cfg(Method::FullAdam);
    cls_cfg.task = Task::DomainShift;
    cls_cfg.steps = 25;
    cls_cfg.lr = 1e-3;
    cls_cfg.eval_batches = 8;
    let warm = run_config(&cls_cfg, Some(&lm_store)).unwrap();
    // the transfer itself is the assertion: loading worked, training runs,
    // and eval produces sane numbers
    assert!(warm.final_metric() >= 0.3, "warm acc {}", warm.final_metric());
    assert!(warm.final_train_loss.is_finite());
}

#[test]
fn checkpoint_roundtrip_through_eval() {
    let mut cfg = nano_cfg(Method::BlockLlm);
    cfg.steps = 10;
    let (res, store) = run_config_with_params(&cfg, None).unwrap();
    let path = std::env::temp_dir().join("blockllm_it_ckpt.bin");
    store.save(&path).unwrap();
    let loaded = blockllm::model::ParamStore::load(&path).unwrap();
    // re-evaluate with the loaded params: same eval loss
    let mut tr = blockllm::trainer::Trainer::open(cfg.clone(), Some(&loaded)).unwrap();
    let mut eval = blockllm::data::c4sim::C4Sim::new(cfg.seed ^ 0xEEEE);
    let ev = tr.eval_lm(&mut eval).unwrap();
    let want = res.final_eval_loss();
    assert!(
        (ev.loss - want).abs() < 1e-4 * want.abs().max(1.0),
        "{} vs {want}",
        ev.loss
    );
    std::fs::remove_file(&path).ok();
}

#[test]
fn runs_are_seed_reproducible() {
    let mut cfg = nano_cfg(Method::BlockLlm);
    cfg.steps = 8;
    let a = run_config(&cfg, None).unwrap();
    let b = run_config(&cfg, None).unwrap();
    assert_eq!(a.train_losses, b.train_losses, "same seed must reproduce bitwise");
    cfg.seed = 43;
    let c = run_config(&cfg, None).unwrap();
    assert_ne!(a.train_losses, c.train_losses, "different seed must differ");
}

#[test]
fn mask_modes_all_train() {
    for mode in [MaskMode::Alg2, MaskMode::OvershootOnly, MaskMode::DenseLayers] {
        let mut cfg = nano_cfg(Method::BlockLlm);
        cfg.mask_mode = mode;
        cfg.steps = 15;
        let res = run_config(&cfg, None).unwrap();
        assert!(
            res.tail_train_loss(3) < res.train_losses[0],
            "{mode:?} failed to learn"
        );
    }
}

#[test]
fn classification_task_learns_above_chance() {
    let mut cfg = nano_cfg(Method::FullAdam);
    cfg.task = Task::Glue(4); // sst2-sim: lexicon counting, easiest task
    cfg.steps = 60;
    cfg.lr = 1e-3;
    cfg.eval_batches = 8;
    let res = run_config(&cfg, None).unwrap();
    assert!(
        res.final_metric() > 0.6,
        "sst2-sim accuracy {} not above chance",
        res.final_metric()
    );
}

#[test]
fn regression_task_beats_mean_predictor() {
    let mut cfg = nano_cfg(Method::FullAdam);
    cfg.task = Task::Glue(2); // stsb-sim
    cfg.steps = 80;
    cfg.lr = 1e-3;
    cfg.eval_batches = 8;
    let res = run_config(&cfg, None).unwrap();
    // labels ~ U{0, 1/h, ..., 1}: variance ≈ 0.09; must beat that MSE
    assert!(res.final_metric() < 0.09, "stsb-sim MSE {}", res.final_metric());
}

#[test]
fn grad_accumulation_matches_single_batch_semantics() {
    // accum=2 must (a) run, (b) learn, and (c) consume 2x the data per step
    let mut cfg = nano_cfg(Method::FullAdam);
    cfg.steps = 10;
    cfg.grad_accum = 2;
    let res = run_config(&cfg, None).unwrap();
    assert_eq!(res.train_losses.len(), 10);
    assert!(res.tail_train_loss(3) < res.train_losses[0]);

    // a duplicate-microbatch accumulation must equal the single-batch step:
    // drive the trainer manually with the same batch twice
    let mut cfg1 = nano_cfg(Method::FullAdam);
    cfg1.steps = 1;
    cfg1.cosine_lr = false;
    let mut tr1 = blockllm::trainer::Trainer::open(cfg1.clone(), None).unwrap();
    let (b, t) = tr1.batch_shape();
    let mut stream = blockllm::data::c4sim::C4Sim::new(99);
    let batch = {
        use blockllm::data::LmStream;
        stream.next_batch(b, t)
    };
    let l1 = tr1.bench_step(&batch).unwrap();
    let d1 = params_digest(&tr1.store);
    drop(tr1);
    let mut cfg2 = cfg1.clone();
    cfg2.grad_accum = 2;
    let mut tr2 = blockllm::trainer::Trainer::open(cfg2, None).unwrap();
    // same batch twice == accumulating identical grads == single step
    let l2a = tr2.bench_accum_step(&[batch.clone(), batch.clone()]).unwrap();
    assert!((l1 - l2a).abs() < 1e-6, "{l1} vs {l2a}");
    assert_eq!(
        params_digest(&tr2.store),
        d1,
        "accumulated duplicate microbatches must equal the single-batch step"
    );
}

fn params_digest(store: &blockllm::model::ParamStore) -> u64 {
    // cheap deterministic digest over all parameters
    let mut h = 1469598103934665603u64;
    for b in &store.bufs {
        for &x in b {
            h = (h ^ x.to_bits() as u64).wrapping_mul(1099511628211);
        }
    }
    h
}

#[test]
fn state_offload_policy_trains() {
    let mut cfg = nano_cfg(Method::BlockLlm);
    cfg.steps = 20;
    cfg.patience = 3;
    cfg.state_policy = blockllm::config::StatePolicy::Offload;
    let res = run_config(&cfg, None).unwrap();
    assert!(res.tail_train_loss(3) < res.train_losses[0]);
    // after several reselections something should be stashed host-side
    assert!(res.telem("offloaded_host_bytes").unwrap_or(0.0) >= 0.0);
}

#[test]
fn pallas_flag_is_inert_on_the_native_backend() {
    // under PJRT the pallas flag picks the kernel-bearing artifact twin (see
    // grad_check.rs for the artifact-parity test); under native it must be
    // a no-op — same model, bitwise-identical run
    let mut cfg = nano_cfg(Method::BlockLlm);
    cfg.backend = BackendKind::Native;
    cfg.steps = 6;
    let a = run_config(&cfg, None).unwrap();
    cfg.use_pallas_artifact = true;
    let b = run_config(&cfg, None).unwrap();
    assert_eq!(a.train_losses, b.train_losses);
    assert_eq!(a.backend, "native");
}

#[test]
fn native_backend_runs_where_auto_resolves() {
    // the acceptance gate for the backend layer: a forced-native run always
    // works, and auto never fails to produce a backend
    let mut cfg = nano_cfg(Method::BlockLlm);
    cfg.steps = 5;
    cfg.backend = BackendKind::Native;
    let res = run_config(&cfg, None).unwrap();
    assert_eq!(res.backend, "native");
    assert!(res.train_losses.iter().all(|l| l.is_finite()));
    cfg.backend = BackendKind::Auto;
    let res2 = run_config(&cfg, None).unwrap();
    assert!(res2.backend == "native" || res2.backend == "pjrt");
}
