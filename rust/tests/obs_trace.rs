//! Observability acceptance tests: tracing must observe, never steer.
//!
//! 1. Bit-neutrality: a traced run produces the exact same losses and
//!    parameter bits as an untraced run, on both gradient-retention routes.
//! 2. Leg-invariance: span counts for the phase spans and every
//!    `Counter::leg_invariant()` counter are identical across the CI matrix
//!    {1,4} threads x {direct,packed} kernels x {gs0,gs1} retention (adam,
//!    which never replays) and across {1,4} x {direct,packed} for blockllm
//!    at fixed retention.
//! 3. The exported `profile` block reflects the run's actual structure
//!    (train_step count == steps, fwd_bwd count == steps * grad_accum).
//!
//! Every test mutates process-global knobs, so they serialize on a
//! file-local mutex (same pattern as grad_check.rs).

use std::sync::Mutex;

use blockllm::config::{BackendKind, Method, Task, TrainConfig};
use blockllm::experiments::common::run_config_with_params;
use blockllm::obs::{self, Counter, Span};

static KNOB_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    KNOB_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Restore every knob this file touches, even if an assert fires.
struct ResetKnobs;
impl Drop for ResetKnobs {
    fn drop(&mut self) {
        blockllm::util::reset_pack_min();
        blockllm::util::reset_par_min();
        blockllm::util::reset_grad_stream();
        obs::reset_trace();
    }
}

fn nano_cfg(method: Method) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.preset = "nano".into();
    cfg.task = Task::C4Pretrain;
    cfg.method = method;
    cfg.backend = BackendKind::Native; // the instrumented engine
    cfg.steps = 6;
    cfg.grad_accum = 2;
    cfg.eval_every = 0;
    cfg.eval_batches = 2;
    cfg.lr = 3e-3;
    cfg.sparsity = 0.8;
    cfg.patience = 3;
    cfg
}

/// Phase spans whose COUNTS are deterministic across the whole CI matrix:
/// everything except the per-kernel-path GEMM spans (which split between
/// direct/packed depending on PALLAS_PACK_MIN) and replay (route-dependent).
const INVARIANT_SPANS: [Span; 13] = [
    Span::TrainStep,
    Span::FwdBwd,
    Span::FwdEmbed,
    Span::FwdAttn,
    Span::FwdMlp,
    Span::FwdHeadLoss,
    Span::BwdHead,
    Span::BwdMlp,
    Span::BwdAttn,
    Span::BwdEmbed,
    Span::Eval,
    Span::SinkConsume,
    Span::AdamStep,
];

const INVARIANT_COUNTERS: [Counter; 4] = [
    Counter::GemmFlops,
    Counter::SinkConsumeCalls,
    Counter::SinkConsumedElems,
    Counter::SelectionEvents,
];

fn leg_fingerprint(d: &obs::Snapshot, losses: &[f64]) -> (Vec<u64>, Vec<u64>, Vec<u64>) {
    let spans = INVARIANT_SPANS.iter().map(|&s| d.span_count[s as usize]).collect();
    let counters = INVARIANT_COUNTERS.iter().map(|&c| d.counters[c as usize]).collect();
    let bits = losses.iter().map(|l| l.to_bits()).collect();
    (spans, counters, bits)
}

#[test]
fn tracing_never_changes_bits() {
    let _g = lock();
    let _reset = ResetKnobs;
    for stream in [false, true] {
        blockllm::util::set_grad_stream(stream);
        let cfg = nano_cfg(Method::BlockLlm);
        obs::set_trace(false);
        let (res_off, store_off) = run_config_with_params(&cfg, None).unwrap();
        assert!(res_off.profile.is_none(), "untraced runs must not export a profile");
        obs::set_trace(true);
        let (res_on, store_on) = run_config_with_params(&cfg, None).unwrap();
        assert!(res_on.profile.is_some(), "traced runs must export a profile");
        obs::set_trace(false);
        let off_bits: Vec<u64> = res_off.train_losses.iter().map(|l| l.to_bits()).collect();
        let on_bits: Vec<u64> = res_on.train_losses.iter().map(|l| l.to_bits()).collect();
        assert_eq!(off_bits, on_bits, "gs={stream}: tracing changed the loss trajectory");
        assert_eq!(
            store_off.bufs, store_on.bufs,
            "gs={stream}: tracing changed trained parameter bits"
        );
    }
}

#[test]
fn adam_counters_and_span_counts_invariant_across_full_matrix() {
    let _g = lock();
    let _reset = ResetKnobs;
    obs::set_trace(true);
    let mut legs: Vec<((usize, bool, bool), (Vec<u64>, Vec<u64>, Vec<u64>))> = Vec::new();
    for threads in [1usize, 4] {
        for packed in [false, true] {
            for stream in [false, true] {
                blockllm::util::set_num_threads(threads);
                blockllm::util::set_pack_min(if packed { 0 } else { usize::MAX });
                blockllm::util::set_grad_stream(stream);
                let cfg = nano_cfg(Method::FullAdam);
                let base = obs::snapshot();
                let (res, _) = run_config_with_params(&cfg, None).unwrap();
                let d = obs::delta(&base);
                // the per-path split must cover every GEMM call on each leg
                let calls = d.counters[Counter::GemmDirectCalls as usize]
                    + d.counters[Counter::GemmPackedCalls as usize];
                assert!(calls > 0, "no GEMM calls counted");
                legs.push(((threads, packed, stream), leg_fingerprint(&d, &res.train_losses)));
            }
        }
    }
    let (_, first) = &legs[0];
    for (leg, fp) in &legs[1..] {
        assert_eq!(
            fp, first,
            "adam leg {leg:?} diverged from (1, direct, gs0) in spans/counters/loss bits"
        );
    }
}

#[test]
fn blockllm_counters_invariant_across_threads_and_kernels() {
    let _g = lock();
    let _reset = ResetKnobs;
    obs::set_trace(true);
    blockllm::util::set_grad_stream(true); // fixed: replays are route-dependent
    let mut legs: Vec<((usize, bool), (Vec<u64>, Vec<u64>, Vec<u64>))> = Vec::new();
    for threads in [1usize, 4] {
        for packed in [false, true] {
            blockllm::util::set_num_threads(threads);
            blockllm::util::set_pack_min(if packed { 0 } else { usize::MAX });
            let cfg = nano_cfg(Method::BlockLlm);
            let base = obs::snapshot();
            let (res, _) = run_config_with_params(&cfg, None).unwrap();
            let d = obs::delta(&base);
            assert!(
                d.counters[Counter::SelectionEvents as usize] >= 1,
                "blockllm run recorded no selection events"
            );
            legs.push(((threads, packed), leg_fingerprint(&d, &res.train_losses)));
        }
    }
    let (_, first) = &legs[0];
    for (leg, fp) in &legs[1..] {
        assert_eq!(fp, first, "blockllm leg {leg:?} diverged from (1, direct)");
    }
}

#[test]
fn profile_block_reflects_run_structure() {
    let _g = lock();
    let _reset = ResetKnobs;
    obs::set_trace(true);
    let cfg = nano_cfg(Method::FullAdam);
    let (res, _) = run_config_with_params(&cfg, None).unwrap();
    obs::set_trace(false);
    let p = res.profile.as_ref().expect("traced run exports a profile");
    let spans = p.req("spans").unwrap();
    let step = spans.req("train_step").unwrap();
    assert_eq!(step.req("count").unwrap().as_usize().unwrap(), cfg.steps);
    let fwd = spans.req("fwd_bwd").unwrap();
    assert_eq!(
        fwd.req("count").unwrap().as_usize().unwrap(),
        cfg.steps * cfg.grad_accum,
        "fwd_bwd must run once per microbatch (adam never replays)"
    );
    // eval_every=0 still evals once at the end, one span per eval batch
    let eval = spans.req("eval").unwrap();
    assert_eq!(eval.req("count").unwrap().as_usize().unwrap(), cfg.eval_batches);
    // nesting invariant: a child's total is bounded by its parent's total
    let step_total = step.req("total_ms").unwrap().as_f64().unwrap();
    let fwd_total = fwd.req("total_ms").unwrap().as_f64().unwrap();
    let step_self = step.req("self_ms").unwrap().as_f64().unwrap();
    assert!(fwd_total <= step_total, "fwd_bwd total exceeds train_step total");
    assert!(step_self <= step_total, "self time exceeds total");
    // the phase spans under train_step account for most of its wall-clock
    assert!(
        fwd_total + step_self > 0.0,
        "train_step recorded no time at all: {step_total} ms"
    );
    let counters = p.req("counters").unwrap();
    assert!(counters.req("gemm.flops").unwrap().as_f64().unwrap() > 0.0);
    assert!(counters.req("sink.consume_calls").unwrap().as_f64().unwrap() > 0.0);
    // the block must survive a JSONL round-trip exactly
    let reparsed = blockllm::util::json::Json::parse(&p.to_string()).unwrap();
    assert_eq!(&reparsed, p);
}
