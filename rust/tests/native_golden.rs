//! Golden tests for the NATIVE backend — the artifact-free twin of
//! tests/golden.rs. The constants below were computed by the JAX reference
//! model (python/compile/model.py) through the numpy mirror in
//! python/tests/test_native_mirror.py (run it as a script to regenerate):
//! deterministic filler parameters + filler tokens on the nano lm model at
//! the shipped artifact batch shape (8, 64). These tests always run, so the
//! full cross-language ABI — parameter ordering, init formulas, model
//! semantics — is pinned even on machines with no Python and no artifacts.

use blockllm::backend::native::NativeBackend;
use blockllm::backend::{Backend, Targets};
use blockllm::model::ParamStore;

/// jax: lm_loss_mean(filler params, filler tokens salt 0, targets salt 3)
const GOLDEN_LOSS: f64 = 5.531864166259766;
/// jax: ||grad||_2 for the first three tensors (tok_emb, layers.0.attn_norm,
/// layers.0.wq)
const GOLDEN_GRAD_NORMS: [f64; 3] = [
    0.05102282017469406,
    0.0018501117592677474,
    0.01897336170077324,
];

fn filler_tokens(b: usize, t: usize, vocab: i64, salt: i64) -> Vec<i32> {
    let mut out = Vec::with_capacity(b * t);
    for i in 0..b as i64 {
        for j in 0..t as i64 {
            out.push(((7 * i + 13 * j + salt) % vocab) as i32);
        }
    }
    out
}

fn setup() -> (NativeBackend, ParamStore, Vec<i32>, Vec<i32>) {
    let be = NativeBackend::with_shape("nano", "lm", 0, 8, 64).unwrap();
    let store = ParamStore::fill_deterministic(be.param_specs());
    let tokens = filler_tokens(8, 64, 256, 0);
    let targets = filler_tokens(8, 64, 256, 3);
    (be, store, tokens, targets)
}

#[test]
fn native_lm_train_matches_jax_golden() {
    let (mut be, store, tokens, targets) = setup();
    let mut grads: Vec<Vec<f32>> =
        store.bufs.iter().map(|b| vec![0.0f32; b.len()]).collect();
    let loss = be
        .forward_backward(&store, &tokens, Targets::Lm(&targets), &mut grads)
        .unwrap();
    assert!(
        (loss - GOLDEN_LOSS).abs() < 2e-3 * GOLDEN_LOSS,
        "loss {loss} vs jax golden {GOLDEN_LOSS}"
    );
    for (k, want) in GOLDEN_GRAD_NORMS.iter().enumerate() {
        let got: f64 = grads[k].iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
        assert!(
            (got - want).abs() < 1e-2 * want.max(1e-4),
            "grad norm {k}: {got} vs jax golden {want}"
        );
    }
}

#[test]
fn native_lm_eval_matches_jax_golden() {
    let (mut be, store, tokens, targets) = setup();
    let out = be.eval_batch(&store, &tokens, Targets::Lm(&targets)).unwrap();
    // no ignored targets in the filler batch: every token counts
    assert_eq!(out.aux, (8 * 64) as f64);
    let mean = out.loss_sum / out.aux;
    assert!(
        (mean - GOLDEN_LOSS).abs() < 2e-3 * GOLDEN_LOSS,
        "eval mean {mean} vs jax golden {GOLDEN_LOSS}"
    );
}

#[test]
fn native_train_and_eval_agree() {
    // the train path's mean loss and the eval path's loss_sum/count are two
    // different code paths over the same math
    let (mut be, store, tokens, targets) = setup();
    let mut grads: Vec<Vec<f32>> =
        store.bufs.iter().map(|b| vec![0.0f32; b.len()]).collect();
    let train_loss = be
        .forward_backward(&store, &tokens, Targets::Lm(&targets), &mut grads)
        .unwrap();
    let out = be.eval_batch(&store, &tokens, Targets::Lm(&targets)).unwrap();
    let eval_mean = out.loss_sum / out.aux;
    assert!(
        (train_loss - eval_mean).abs() < 1e-9,
        "{train_loss} vs {eval_mean}"
    );
}
