//! Golden tests for the NATIVE backend — the artifact-free twin of
//! tests/golden.rs. The constants below were computed by the JAX reference
//! model (python/compile/model.py) through the numpy mirror in
//! python/tests/test_native_mirror.py (run it as a script to regenerate):
//! deterministic filler parameters + filler tokens on the nano lm model at
//! the shipped artifact batch shape (8, 64). These tests always run, so the
//! full cross-language ABI — parameter ordering, init formulas, model
//! semantics — is pinned even on machines with no Python and no artifacts.

use blockllm::backend::native::NativeBackend;
use blockllm::backend::{Backend, Targets};
use blockllm::model::ParamStore;

/// jax: lm_loss_mean(filler params, filler tokens salt 0, targets salt 3)
const GOLDEN_LOSS: f64 = 5.531864166259766;
/// jax: ||grad||_2 for the first three tensors (tok_emb, layers.0.attn_norm,
/// layers.0.wq)
const GOLDEN_GRAD_NORMS: [f64; 3] = [
    0.05102282017469406,
    0.0018501117592677474,
    0.01897336170077324,
];

fn filler_tokens(b: usize, t: usize, vocab: i64, salt: i64) -> Vec<i32> {
    let mut out = Vec::with_capacity(b * t);
    for i in 0..b as i64 {
        for j in 0..t as i64 {
            out.push(((7 * i + 13 * j + salt) % vocab) as i32);
        }
    }
    out
}

fn setup() -> (NativeBackend, ParamStore, Vec<i32>, Vec<i32>) {
    let be = NativeBackend::with_shape("nano", "lm", 0, 8, 64).unwrap();
    let store = ParamStore::fill_deterministic(be.param_specs());
    let tokens = filler_tokens(8, 64, 256, 0);
    let targets = filler_tokens(8, 64, 256, 3);
    (be, store, tokens, targets)
}

#[test]
fn native_lm_train_matches_jax_golden() {
    let (mut be, store, tokens, targets) = setup();
    let mut grads: Vec<Vec<f32>> =
        store.bufs.iter().map(|b| vec![0.0f32; b.len()]).collect();
    let loss = be
        .forward_backward_dense(&store, &tokens, Targets::Lm(&targets), &mut grads)
        .unwrap();
    assert!(
        (loss - GOLDEN_LOSS).abs() < 2e-3 * GOLDEN_LOSS,
        "loss {loss} vs jax golden {GOLDEN_LOSS}"
    );
    for (k, want) in GOLDEN_GRAD_NORMS.iter().enumerate() {
        let got: f64 = grads[k].iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
        assert!(
            (got - want).abs() < 1e-2 * want.max(1e-4),
            "grad norm {k}: {got} vs jax golden {want}"
        );
    }
}

#[test]
fn native_lm_eval_matches_jax_golden() {
    let (mut be, store, tokens, targets) = setup();
    let out = be.eval_batch(&store, &tokens, Targets::Lm(&targets)).unwrap();
    // no ignored targets in the filler batch: every token counts
    assert_eq!(out.aux, (8 * 64) as f64);
    let mean = out.loss_sum / out.aux;
    assert!(
        (mean - GOLDEN_LOSS).abs() < 2e-3 * GOLDEN_LOSS,
        "eval mean {mean} vs jax golden {GOLDEN_LOSS}"
    );
}

// ---------------------------------------------------------------------------
// Odd-dims parity pins: the "grain" preset (v=101, d=18, ff=29, t=13/7,
// b=3/2) has NO dimension that is a multiple of the blocked GEMM's KB/NB
// blocks or its 4-way unroll, so these cases pin the kernels' remainder
// paths. Golden values come from the float64 numpy mirror (JAX-validated;
// regenerate with `python python/tests/test_native_mirror.py`, see
// golden_grain_losses). The f32 engine lands within ~1e-6 of them; asserted
// at 1e-5.
// ---------------------------------------------------------------------------

/// mirror: golden_grain_losses()["lm"] — filler params, tokens salt 0,
/// targets salt 3, (b, t) = (3, 13)
const GRAIN_LM_LOSS: f64 = 4.608152463840966;
const GRAIN_LM_GRAD_NORMS: [f64; 21] = [
    0.7307277678227266,
    6.0990452800571496e-05,
    1.2805135984673522e-06,
    1.2956168494659113e-06,
    0.016204647305952252,
    0.02149497187481469,
    8.290231075846103e-07,
    0.00015879214897147117,
    0.00015827774594933918,
    8.026144240261488e-05,
    5.031065231873463e-05,
    1.5587186345717306e-06,
    7.975754823038487e-07,
    0.01635317434898373,
    0.02378788311953013,
    9.009749377725506e-07,
    0.00016147162145876298,
    0.00016164008593300526,
    7.900937481637934e-05,
    0.02153335969548067,
    0.6758566517019924,
];

/// mirror: golden_grain_losses()["cls"] — filler params, tokens salt 1,
/// labels [0, 2], n_out 3, (b, t) = (2, 7)
const GRAIN_CLS_LOSS: f64 = 1.0985748746524464;
const GRAIN_CLS_GRAD_NORMS: [f64; 22] = [
    0.10501974299128472,
    4.783108435741511e-06,
    5.722350153666073e-08,
    6.096284690356095e-08,
    0.0014340001532515934,
    0.00031591753329916425,
    1.033045420273216e-07,
    2.3835909423749646e-05,
    2.3805808487454553e-05,
    2.4876490045341995e-06,
    4.8639088334505295e-06,
    2.99282612526539e-08,
    2.4447588139842704e-08,
    0.0014320110507252488,
    0.0003171126560535573,
    5.110270628023705e-08,
    2.408738316046105e-05,
    2.403472835628223e-05,
    2.4694977983615985e-06,
    1.5389346806884024e-05,
    0.10626326040308176,
    0.40825246425598793,
];

fn grad_norm(g: &[f32]) -> f64 {
    g.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt()
}

/// |got - want| <= 1e-5 scaled by the quantity's magnitude (the mixed
/// abs/rel reading of "within 1e-5"; measured f32 spread is ~1e-6).
fn assert_pin(got: f64, want: f64, what: &str) {
    assert!(
        (got - want).abs() <= 1e-5 * (1.0 + want.abs()),
        "{what}: {got} vs golden {want}"
    );
}

fn check_grain_lm(what: &str) {
    let mut be = NativeBackend::with_shape("grain", "lm", 0, 3, 13).unwrap();
    let store = ParamStore::fill_deterministic(be.param_specs());
    let tokens = filler_tokens(3, 13, 101, 0);
    let targets = filler_tokens(3, 13, 101, 3);
    let mut grads: Vec<Vec<f32>> =
        store.bufs.iter().map(|b| vec![0.0f32; b.len()]).collect();
    let loss = be
        .forward_backward_dense(&store, &tokens, Targets::Lm(&targets), &mut grads)
        .unwrap();
    assert_pin(loss, GRAIN_LM_LOSS, &format!("grain lm loss [{what}]"));
    assert_eq!(grads.len(), GRAIN_LM_GRAD_NORMS.len());
    for (k, want) in GRAIN_LM_GRAD_NORMS.iter().enumerate() {
        assert_pin(grad_norm(&grads[k]), *want, &format!("grain lm grad norm {k} [{what}]"));
    }
    // the forward-only path crosses the same remainder kernels
    let ev = be.eval_batch(&store, &tokens, Targets::Lm(&targets)).unwrap();
    assert_eq!(ev.aux, (3 * 13) as f64);
    assert_pin(ev.loss_sum / ev.aux, GRAIN_LM_LOSS, &format!("grain lm eval mean [{what}]"));
}

fn check_grain_cls(what: &str) {
    let mut be = NativeBackend::with_shape("grain", "cls", 3, 2, 7).unwrap();
    let store = ParamStore::fill_deterministic(be.param_specs());
    let tokens = filler_tokens(2, 7, 101, 1);
    let labels = vec![0i32, 2];
    let mut grads: Vec<Vec<f32>> =
        store.bufs.iter().map(|b| vec![0.0f32; b.len()]).collect();
    let loss = be
        .forward_backward_dense(&store, &tokens, Targets::Cls(&labels), &mut grads)
        .unwrap();
    assert_pin(loss, GRAIN_CLS_LOSS, &format!("grain cls loss [{what}]"));
    assert_eq!(grads.len(), GRAIN_CLS_GRAD_NORMS.len());
    for (k, want) in GRAIN_CLS_GRAD_NORMS.iter().enumerate() {
        assert_pin(grad_norm(&grads[k]), *want, &format!("grain cls grad norm {k} [{what}]"));
    }
}

#[test]
fn native_grain_lm_matches_jax_golden_at_odd_dims() {
    check_grain_lm("default path");
}

#[test]
fn native_grain_cls_matches_jax_golden_at_odd_dims() {
    check_grain_cls("default path");
}

/// The odd-dims pins must hold on BOTH kernel paths: once with every GEMM
/// forced through the direct kernels, once forced through the packed-panel
/// microkernel with every rowwise sweep parallel — so the packed path's
/// remainder handling (partial NR strips, sub-MR row tiles, fused bias
/// epilogue on the cls head, SiLU·mul in the MLP) and the direct kernels
/// each get DETERMINISTIC golden coverage in one test, regardless of test
/// scheduling — and on BOTH attention paths (the batched strided-GEMM
/// default AND the legacy per-head loop), extending the same pin lattice
/// over the batched rework instead of forking it. Flipping the
/// process-global knobs is safe for concurrent tests (all paths agree
/// bitwise — they see identical results), and a drop guard restores the
/// defaults even if an assert fires mid-test.
#[test]
fn native_grain_pins_hold_on_both_kernel_paths() {
    struct ResetKnobs;
    impl Drop for ResetKnobs {
        fn drop(&mut self) {
            blockllm::util::reset_pack_min();
            blockllm::util::reset_par_min();
            blockllm::util::reset_attn_batched();
        }
    }
    let _reset = ResetKnobs;
    blockllm::util::set_pack_min(usize::MAX); // every GEMM direct
    check_grain_lm("forced direct");
    check_grain_cls("forced direct");
    blockllm::util::set_attn_batched(false); // direct + per-head attention
    check_grain_lm("forced direct, per-head attention");
    check_grain_cls("forced direct, per-head attention");
    blockllm::util::set_attn_batched(true);
    blockllm::util::set_pack_min(0); // every GEMM packed, sweeps parallel
    blockllm::util::set_par_min(0);
    check_grain_lm("forced packed");
    check_grain_cls("forced packed");
    blockllm::util::set_attn_batched(false); // packed + per-head attention
    check_grain_lm("forced packed, per-head attention");
    check_grain_cls("forced packed, per-head attention");
}

#[test]
fn native_train_and_eval_agree() {
    // the train path's mean loss and the eval path's loss_sum/count are two
    // different code paths over the same math
    let (mut be, store, tokens, targets) = setup();
    let mut grads: Vec<Vec<f32>> =
        store.bufs.iter().map(|b| vec![0.0f32; b.len()]).collect();
    let train_loss = be
        .forward_backward_dense(&store, &tokens, Targets::Lm(&targets), &mut grads)
        .unwrap();
    let out = be.eval_batch(&store, &tokens, Targets::Lm(&targets)).unwrap();
    let eval_mean = out.loss_sum / out.aux;
    assert!(
        (train_loss - eval_mean).abs() < 1e-9,
        "{train_loss} vs {eval_mean}"
    );
}
