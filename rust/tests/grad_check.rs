//! Gradient-correctness tests for the native backend.
//!
//! 1. Finite-difference checks: for randomly chosen coordinates of every
//!    parameter tensor of the nano model (lm AND cls heads), the analytic
//!    gradient from `NativeBackend::forward_backward` (streamed into dense
//!    buffers via `forward_backward_dense`) must match the
//!    central-difference quotient of the loss to 1e-3.
//! 2. PJRT-vs-native parity: when AOT artifacts and a working PJRT client
//!    are available, both backends must produce the same loss and
//!    per-tensor gradient norms on an identical batch.
//! 3. Streaming-vs-dense gradient retention: full trainer runs (blockllm,
//!    selection events included) must be bitwise-identical between
//!    `--grad-stream 1` and `--grad-stream 0` across the
//!    {1,4 threads} × {accum 1,4} grid, `NormProbeSink` norms must pin
//!    against `DenseSink`-computed norms, and blockllm at sparsity 0.95
//!    must MEASURE ≤ dense/4 gradient bytes on the grain preset.

use blockllm::backend::native::NativeBackend;
use blockllm::backend::{Backend, Targets};
use blockllm::config::{BackendKind, Method, TrainConfig};
use blockllm::data::LmBatch;
use blockllm::grads::NormProbeSink;
use blockllm::model::ParamStore;
use blockllm::trainer::Trainer;
use blockllm::util::rng::Pcg64;

/// Serializes the tests that flip the process-global grad-stream knob (the
/// kernels are knob-invariant, but these tests ASSERT on which retention
/// path ran, so concurrent flipping would cross-contaminate them).
static STREAM_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

/// Restore the grad-stream knob (re-arming any CI-leg env forcing) even if
/// an assertion fires mid-test.
struct ResetStream;
impl Drop for ResetStream {
    fn drop(&mut self) {
        blockllm::util::reset_grad_stream();
    }
}

/// tokens[i*t + j] = (7i + 13j + salt) % vocab — aot.filler_tokens.
fn filler_tokens(b: usize, t: usize, vocab: i64, salt: i64) -> Vec<i32> {
    let mut out = Vec::with_capacity(b * t);
    for i in 0..b as i64 {
        for j in 0..t as i64 {
            out.push(((7 * i + 13 * j + salt) % vocab) as i32);
        }
    }
    out
}

fn zeros_like(store: &ParamStore) -> Vec<Vec<f32>> {
    store.bufs.iter().map(|b| vec![0.0f32; b.len()]).collect()
}

/// Central-difference check of `grads` (d mean-loss / d w) at ~3 random
/// coordinates per tensor.
fn finite_difference_check(
    be: &mut NativeBackend,
    store: &mut ParamStore,
    tokens: &[i32],
    targets: Targets<'_>,
    grads: &[Vec<f32>],
) {
    let mut scratch = zeros_like(store);
    let mut rng = Pcg64::new(0xFD);
    let eps = 3e-2f32;
    let n_tensors = store.bufs.len();
    for pi in 0..n_tensors {
        let name = store.specs[pi].name.clone();
        let numel = store.bufs[pi].len();
        for _ in 0..3 {
            let c = rng.below(numel);
            let w0 = store.bufs[pi][c];
            store.bufs[pi][c] = w0 + eps;
            let lp = be.forward_backward_dense(store, tokens, targets, &mut scratch).unwrap();
            store.bufs[pi][c] = w0 - eps;
            let lm = be.forward_backward_dense(store, tokens, targets, &mut scratch).unwrap();
            store.bufs[pi][c] = w0;
            let fd = (lp - lm) / (2.0 * eps as f64);
            let an = grads[pi][c] as f64;
            let tol = 1e-3 * (1.0 + fd.abs().max(an.abs()));
            assert!(
                (fd - an).abs() <= tol,
                "{name}[{c}]: finite-diff {fd} vs analytic {an} (tol {tol})"
            );
        }
    }
}

#[test]
fn native_lm_gradients_match_finite_differences() {
    let mut be = NativeBackend::with_shape("nano", "lm", 0, 2, 8).unwrap();
    let specs = be.param_specs().to_vec();
    let mut store = ParamStore::init(&specs, 17);
    let tokens = filler_tokens(2, 8, 256, 0);
    let mut targets = filler_tokens(2, 8, 256, 3);
    targets[0] = -1; // exercise the ignore path
    targets[1] = -1;
    let mut grads = zeros_like(&store);
    let loss = be
        .forward_backward_dense(&store, &tokens, Targets::Lm(&targets), &mut grads)
        .unwrap();
    assert!(loss.is_finite() && loss > 0.0);
    finite_difference_check(&mut be, &mut store, &tokens, Targets::Lm(&targets), &grads);
}

#[test]
fn native_cls_gradients_match_finite_differences() {
    let mut be = NativeBackend::with_shape("nano", "cls", 3, 2, 6).unwrap();
    let specs = be.param_specs().to_vec();
    let mut store = ParamStore::init(&specs, 23);
    let tokens = filler_tokens(2, 6, 256, 1);
    let labels = vec![2i32, 0];
    let mut grads = zeros_like(&store);
    let loss = be
        .forward_backward_dense(&store, &tokens, Targets::Cls(&labels), &mut grads)
        .unwrap();
    assert!(loss.is_finite() && loss > 0.0);
    finite_difference_check(&mut be, &mut store, &tokens, Targets::Cls(&labels), &grads);
}

#[test]
fn native_reg_gradients_match_finite_differences() {
    let mut be = NativeBackend::with_shape("nano", "reg", 1, 2, 6).unwrap();
    let specs = be.param_specs().to_vec();
    let mut store = ParamStore::init(&specs, 29);
    let tokens = filler_tokens(2, 6, 256, 2);
    let labels = vec![0.25f32, 0.75];
    let mut grads = zeros_like(&store);
    let loss = be
        .forward_backward_dense(&store, &tokens, Targets::Reg(&labels), &mut grads)
        .unwrap();
    assert!(loss.is_finite() && loss >= 0.0);
    finite_difference_check(&mut be, &mut store, &tokens, Targets::Reg(&labels), &grads);
}

/// The GEMM layer partitions output rows across workers with a fixed
/// per-element summation order, so the whole fwd/bwd must be bit-for-bit
/// identical at ANY thread count — and still pass the finite-difference
/// check at each. Runs the full matrix {1, 2, 4, 8 threads} x {direct
/// kernels, forced packed-microkernel + forced-parallel sweeps} on the
/// odd-dims "grain" preset, so every remainder path of BOTH kernel paths
/// is crossed AND the packed/direct paths are pinned bitwise-equal on a
/// real model. Every leg runs the BATCHED strided-GEMM attention path
/// (the default) under the FD sweep, then re-runs the identical batch
/// through the legacy per-head attention loop and pins the two bitwise
/// equal — the batched-attention acceptance criterion, crossed with every
/// thread count and kernel path.
#[test]
fn blocked_kernels_identical_and_fd_correct_across_thread_counts() {
    struct ResetKnobs;
    impl Drop for ResetKnobs {
        fn drop(&mut self) {
            blockllm::util::reset_pack_min();
            blockllm::util::reset_par_min();
            blockllm::util::reset_attn_batched();
            blockllm::util::reset_pool();
        }
    }
    let _reset = ResetKnobs; // restore defaults even if an assert fires
    let mut results: Vec<(f64, Vec<Vec<f32>>)> = Vec::new();
    // the 8-thread legs exceed both b·h = 2 heads and the per-head row
    // count, so batched grid chunks split mid-head on each kernel path
    let cases: &[(usize, bool)] = &[
        (1, false),
        (2, false),
        (4, false),
        (8, false),
        (1, true),
        (2, true),
        (4, true),
        (8, true),
    ];
    for &(threads, forced_packed) in cases {
        blockllm::util::set_num_threads(threads);
        if forced_packed {
            // every GEMM through the packed microkernel, every rowwise
            // sweep parallel, no matter how small the model is
            blockllm::util::set_pack_min(0);
            blockllm::util::set_par_min(0);
        } else {
            // every GEMM through the direct kernels
            blockllm::util::set_pack_min(usize::MAX);
        }
        let mut be = NativeBackend::with_shape("grain", "lm", 0, 2, 5).unwrap();
        let specs = be.param_specs().to_vec();
        let mut store = ParamStore::init(&specs, 41);
        let tokens = filler_tokens(2, 5, 101, 0);
        let targets = filler_tokens(2, 5, 101, 3);
        let mut grads = zeros_like(&store);
        blockllm::util::set_attn_batched(true);
        let loss = be
            .forward_backward_dense(&store, &tokens, Targets::Lm(&targets), &mut grads)
            .unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        // full finite-difference sweep at THIS thread count / kernel path
        finite_difference_check(&mut be, &mut store, &tokens, Targets::Lm(&targets), &grads);
        // the legacy per-head attention loop must reproduce the exact bits
        blockllm::util::set_attn_batched(false);
        let mut grads_loop = zeros_like(&store);
        let loss_loop = be
            .forward_backward_dense(&store, &tokens, Targets::Lm(&targets), &mut grads_loop)
            .unwrap();
        blockllm::util::set_attn_batched(true);
        assert_eq!(
            loss.to_bits(),
            loss_loop.to_bits(),
            "per-head attention loss differs at {threads} threads (packed={forced_packed})"
        );
        assert_eq!(
            grads, grads_loop,
            "per-head attention grads differ at {threads} threads (packed={forced_packed})"
        );
        // pooled vs scoped dispatch: the persistent pool only picks WHICH
        // thread runs a chunk, so both paths must reproduce the leg's
        // exact loss and gradient bits (both forced explicitly — the CI
        // legs pin PALLAS_POOL either way)
        for pooled in [true, false] {
            blockllm::util::set_pool(pooled);
            let mut grads_d = zeros_like(&store);
            let loss_d = be
                .forward_backward_dense(&store, &tokens, Targets::Lm(&targets), &mut grads_d)
                .unwrap();
            assert_eq!(
                loss.to_bits(),
                loss_d.to_bits(),
                "pool={pooled} loss differs at {threads} threads (packed={forced_packed})"
            );
            assert_eq!(
                grads, grads_d,
                "pool={pooled} grads differ at {threads} threads (packed={forced_packed})"
            );
        }
        blockllm::util::reset_pool();
        results.push((loss, grads));
    }
    let (l0, g0) = &results[0];
    for (i, (l, g)) in results.iter().enumerate().skip(1) {
        let (threads, packed) = cases[i];
        assert_eq!(
            l0.to_bits(),
            l.to_bits(),
            "loss at {threads} threads (packed={packed}) differs from 1-thread direct: {l0} vs {l}"
        );
        assert_eq!(g0, g, "gradients differ at {threads} threads (packed={packed})");
    }
}

/// PJRT-vs-native parity on an identical deterministic batch. Runs only
/// when artifacts exist and the real PJRT client opens (skipped under the
/// vendored xla stub).
#[test]
fn pjrt_and_native_agree_on_loss_and_grad_norms() {
    // artifacts/ lives at the REPO root (one level above <repo>/rust)
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate dir has a parent")
        .join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP (pjrt-only test): artifacts/ missing; run `make artifacts`");
        return;
    }
    let rt = match blockllm::runtime::Runtime::open(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("SKIP (pjrt-only test): runtime unavailable: {e}");
            return;
        }
    };
    let cfg = blockllm::config::TrainConfig::default(); // nano, C4Pretrain
    let mut pjrt =
        blockllm::backend::pjrt::PjrtBackend::with_runtime(rt, &cfg, "lm", 0).unwrap();
    let (b, t) = pjrt.batch_shape();
    let mut native = NativeBackend::with_shape("nano", "lm", 0, b, t).unwrap();
    assert_eq!(pjrt.param_specs(), native.param_specs(), "spec-table ABI mismatch");

    let store = ParamStore::fill_deterministic(pjrt.param_specs());
    let tokens = filler_tokens(b, t, 256, 0);
    let targets = filler_tokens(b, t, 256, 3);
    let mut gp = zeros_like(&store);
    let mut gn = zeros_like(&store);
    let lp = pjrt
        .forward_backward_dense(&store, &tokens, Targets::Lm(&targets), &mut gp)
        .unwrap();
    let ln = native
        .forward_backward_dense(&store, &tokens, Targets::Lm(&targets), &mut gn)
        .unwrap();
    assert!((lp - ln).abs() < 1e-3 * lp.abs().max(1.0), "loss: pjrt {lp} vs native {ln}");
    for (i, (a, c)) in gp.iter().zip(&gn).enumerate() {
        let na: f64 = a.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt();
        let nc: f64 = c.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt();
        assert!(
            (na - nc).abs() < 5e-3 * na.max(1e-3),
            "grad norm {i}: pjrt {na} vs native {nc}"
        );
    }
}

/// Build a grain-preset blockllm trainer over an explicit small-shape
/// native backend (the streaming-retention tests drive it with filler
/// batches; vocab 101).
fn grain_trainer(sparsity: f64, patience: usize, accum: usize) -> Trainer {
    let mut cfg = TrainConfig::default();
    cfg.preset = "grain".into();
    cfg.method = Method::BlockLlm;
    cfg.backend = BackendKind::Native;
    cfg.sparsity = sparsity;
    cfg.patience = patience;
    cfg.grad_accum = accum;
    cfg.steps = 1_000; // schedule horizon; steps are driven manually
    cfg.cosine_lr = false;
    cfg.lr = 1e-2;
    let be = NativeBackend::with_shape("grain", "lm", 0, 4, 8).unwrap();
    Trainer::new(Box::new(be), cfg, None).unwrap()
}

fn grain_micro(step: usize, accum: usize) -> Vec<LmBatch> {
    (0..accum)
        .map(|k| {
            let salt = (step * accum + k) as i64;
            LmBatch {
                tokens: filler_tokens(4, 8, 101, 2 * salt),
                targets: filler_tokens(4, 8, 101, 2 * salt + 1),
                batch: 4,
                seq: 8,
            }
        })
        .collect()
}

/// THE streaming acceptance pin, end to end: with identical configs and
/// batches, the streaming retention path (`--grad-stream 1`: compact
/// MaskedSink + selection replays) and the dense staging path
/// (`--grad-stream 0`) must produce bit-for-bit identical losses AND
/// post-training parameters, across the {1, 4 threads} × {accum 1, 4}
/// grid. Step 0 is always a selection event, so every leg crosses the
/// replay path (compact top-k at accum 1, dense replay at accum 4).
#[test]
fn streaming_and_dense_retention_bitwise_identical() {
    let _g = STREAM_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let _reset = ResetStream;
    for &threads in &[1usize, 4] {
        for &accum in &[1usize, 4] {
            let run = |stream: bool| -> (Vec<f64>, Vec<Vec<f32>>, f64) {
                blockllm::util::set_num_threads(threads);
                blockllm::util::set_grad_stream(stream);
                // patience 2 gives later re-selections a chance on top of
                // the guaranteed t=0 selection
                let mut tr = grain_trainer(0.9, 2, accum);
                let mut losses = Vec::new();
                for s in 0..6 {
                    let micro = grain_micro(s, accum);
                    losses.push(tr.bench_accum_step(&micro).unwrap());
                }
                let sel = tr.strategy.telemetry().iter().find_map(|(k, v)| {
                    (k == "n_selections").then_some(*v)
                });
                (losses, tr.store.bufs, sel.unwrap_or(-1.0))
            };
            let (ls, ps, sel_s) = run(true);
            let (ld, pd, sel_d) = run(false);
            for (i, (a, b)) in ls.iter().zip(&ld).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "loss bits diverged at step {i} ({threads} threads, accum {accum}): {a} vs {b}"
                );
            }
            assert_eq!(sel_s, sel_d, "selection count diverged ({threads} threads, accum {accum})");
            assert!(sel_s >= 1.0, "no selection event exercised");
            for (li, (a, b)) in ps.iter().zip(&pd).enumerate() {
                for (ci, (x, y)) in a.iter().zip(b).enumerate() {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "param {li}[{ci}] diverged ({threads} threads, accum {accum})"
                    );
                }
            }
        }
    }
}

/// `NormProbeSink` is the scorer's streaming reduction: its per-tensor Σg²
/// must equal the sum computed over `DenseSink`-materialized gradients,
/// bit for bit (same f64 fold, ascending coordinate order).
#[test]
fn norm_probe_sink_matches_dense_sink_norms_bitwise() {
    let mut be = NativeBackend::with_shape("grain", "lm", 0, 2, 6).unwrap();
    let specs = be.param_specs().to_vec();
    let store = ParamStore::init(&specs, 51);
    let tokens = filler_tokens(2, 6, 101, 4);
    let targets = filler_tokens(2, 6, 101, 9);
    let mut grads: Vec<Vec<f32>> = specs.iter().map(|s| vec![0.0f32; s.numel()]).collect();
    let ld = be
        .forward_backward_dense(&store, &tokens, Targets::Lm(&targets), &mut grads)
        .unwrap();
    let mut probe = NormProbeSink::new(specs.len());
    let lp = be.forward_backward(&store, &tokens, Targets::Lm(&targets), &mut probe).unwrap();
    assert_eq!(ld.to_bits(), lp.to_bits(), "loss must not depend on the sink");
    for (i, g) in grads.iter().enumerate() {
        let want: f64 = g.iter().map(|&x| (x as f64) * (x as f64)).sum();
        assert_eq!(
            probe.sq[i].to_bits(),
            want.to_bits(),
            "tensor {i} ({}): streamed {} vs dense {}",
            specs[i].name,
            probe.sq[i],
            want
        );
    }
    // nothing retained: the probe's live footprint is one transient shard
    let largest = specs.iter().map(|s| s.numel() as u64).max().unwrap();
    assert_eq!(probe.peak_grad_elems(), largest);
}

/// Restore the dist-layer knobs (replica count, kernel-path forcing) even
/// if an assertion fires mid-test.
struct ResetDistKnobs;
impl Drop for ResetDistKnobs {
    fn drop(&mut self) {
        blockllm::util::reset_replicas();
        blockllm::util::reset_pack_min();
    }
}

/// THE dist acceptance pin, end to end: with identical configs and batches,
/// `--replicas {2, 4}` must produce bit-for-bit identical losses AND
/// post-training parameters to the 1-replica reference, across the
/// {direct, packed} kernel paths and {accum 1, 4}. Accum 4 exercises the
/// real replicated fan-out (round-robin microbatch ownership + the
/// reducer's ascending-microbatch fold); accum 1 has a single microbatch
/// per step, so dist takes the sequential fallback but the ZeRO-sharded
/// compact Adam update still runs per-replica moment-shard ranges.
#[test]
fn replicated_training_bitwise_identical_across_replica_counts() {
    let _g = STREAM_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let _reset_stream = ResetStream;
    let _reset = ResetDistKnobs;
    blockllm::util::set_num_threads(4);
    for &forced_packed in &[false, true] {
        if forced_packed {
            blockllm::util::set_pack_min(0);
        } else {
            blockllm::util::set_pack_min(usize::MAX);
        }
        for &accum in &[1usize, 4] {
            let run = |replicas: usize| -> (Vec<f64>, Vec<Vec<f32>>) {
                blockllm::util::set_grad_stream(true);
                blockllm::util::set_replicas(replicas);
                let mut tr = grain_trainer(0.9, 2, accum);
                let mut losses = Vec::new();
                for s in 0..6 {
                    let micro = grain_micro(s, accum);
                    losses.push(tr.bench_accum_step(&micro).unwrap());
                }
                (losses, tr.store.bufs)
            };
            let (l1, p1) = run(1);
            for &r in &[2usize, 4] {
                let (lr, pr) = run(r);
                for (i, (a, b)) in l1.iter().zip(&lr).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "loss bits diverged at step {i} (replicas {r}, accum {accum}, \
                         packed={forced_packed}): {a} vs {b}"
                    );
                }
                for (li, (a, b)) in p1.iter().zip(&pr).enumerate() {
                    for (ci, (x, y)) in a.iter().zip(b).enumerate() {
                        assert_eq!(
                            x.to_bits(),
                            y.to_bits(),
                            "param {li}[{ci}] diverged (replicas {r}, accum {accum}, \
                             packed={forced_packed})"
                        );
                    }
                }
            }
        }
    }
}

/// The ZeRO acceptance pin: blockllm at sparsity 0.95 on grain must MEASURE
/// per-replica optimizer-state bytes at `--replicas 4` of at most 1/3 the
/// `--replicas 1` full state (per-layer `⌈c_l/4⌉` rounding keeps the shard
/// above an exact 1/4, hence the 1/3 bound), while 4 such shards always
/// cover the whole state.
#[test]
fn blockllm_state_shard_bytes_shrink_with_replicas() {
    let _g = STREAM_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let _reset_stream = ResetStream;
    let _reset = ResetDistKnobs;
    let run = |replicas: usize| -> u64 {
        blockllm::util::set_grad_stream(true);
        blockllm::util::set_replicas(replicas);
        let mut tr = grain_trainer(0.95, 2, 1);
        for s in 0..6 {
            let micro = grain_micro(s, 1);
            tr.bench_accum_step(&micro).unwrap();
        }
        tr.mem.peak_state_shard_measured
    };
    let full = run(1);
    let quarter = run(4);
    assert!(full > 0, "no optimizer state was measured");
    assert!(
        quarter * 3 <= full,
        "state shard at 4 replicas ({quarter} bytes) not ≤ 1/3 of the full state ({full})"
    );
    assert!(quarter * 4 >= full, "4 shards of {quarter} bytes cannot cover {full}");
}

/// The memory acceptance pin: blockllm at sparsity 0.95 on grain, streamed,
/// must MEASURE ≤ dense/4 gradient bytes — and stay within the modeled
/// `active coords + largest layer` residency (+ slack), selection events
/// included. The dense reference run measures ≈ n + largest layer.
#[test]
fn blockllm_streaming_measures_compact_grad_memory() {
    let _g = STREAM_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let _reset = ResetStream;
    let run = |stream: bool| -> u64 {
        blockllm::util::set_grad_stream(stream);
        let mut tr = grain_trainer(0.95, 2, 1);
        for s in 0..6 {
            let micro = grain_micro(s, 1);
            tr.bench_accum_step(&micro).unwrap();
        }
        tr.mem.peak_grad_measured
    };
    let streamed = run(true);
    let dense = run(false);
    // grain lm: n = 9450 params, largest tensor (tok_emb / lm_head) = 1818
    let n: u64 = 9450;
    let largest: u64 = 1818;
    let n_s = (0.05f64 * n as f64).floor() as u64; // 472 active-coord budget
    assert_eq!(dense, 4 * (n + largest), "dense path must measure n + largest layer");
    assert!(
        streamed * 4 <= dense,
        "streaming grad bytes {streamed} not ≤ dense/4 ({dense} / 4 = {})",
        dense / 4
    );
    assert!(
        streamed <= 4 * (n_s + largest + 64),
        "streaming grad bytes {streamed} exceed the active+largest-layer bound {}",
        4 * (n_s + largest + 64)
    );
    // no full-size dense grad table was ever allocated on the streamed run
    assert!(streamed < 4 * n, "streamed peak {streamed} ≥ a dense table ({})", 4 * n);
}
