//! Session checkpointing end-to-end:
//!
//! 1. Suspend-at-N + resume + train-to-end is BITWISE identical (train-loss
//!    bits, eval-loss bits, final parameter bits, telemetry) to an
//!    uninterrupted run — for blockllm, magnitude (which re-selects between
//!    the suspend point and the end, so the checkpoint provably crosses
//!    selection machinery), and the dense full-Adam route — across the
//!    {1,4 threads} × {grad-stream 0,1} knob grid.
//! 2. Truncated/corrupt/version-bumped checkpoints fail with a clean `Err`
//!    (no panic, no partially-loaded session).
//! 3. The serve scheduler's time-sliced sessions finish with results
//!    identical to solo runs, and admission control rejects a session whose
//!    budget can't cover its modeled footprint.
//! 4. The preemptive policies (`slack`, `weighted`) force mid-slice
//!    preemptions and still reproduce every tenant's solo bits, across
//!    {1,4} threads.

use std::sync::Mutex;

use blockllm::config::{Method, TrainConfig};
use blockllm::session::scheduler::{serve, SchedPolicy, ServeSpec};
use blockllm::session::Session;
use blockllm::trainer::RunResult;

/// Knob state is process-global and these tests drive it — serialize them.
static KNOBS: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    KNOBS.lock().unwrap_or_else(|e| e.into_inner())
}

/// Restore env-resolved knob defaults even if an assert fires mid-grid.
struct ResetKnobs;
impl Drop for ResetKnobs {
    fn drop(&mut self) {
        blockllm::util::reset_all_knobs();
    }
}

fn grain_cfg(method: Method, steps: usize) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.preset = "grain".into();
    cfg.method = method;
    cfg.steps = steps;
    cfg.eval_every = 5;
    cfg.eval_batches = 1;
    cfg.seed = 11;
    // keep selection machinery busy inside short runs: magnitude re-selects
    // every 3 steps; blockllm's patience window is small enough to trigger
    // on the noisy grain stream
    cfg.mag_update_every = 3;
    cfg.patience = 2;
    cfg
}

fn run_uninterrupted(cfg: &TrainConfig) -> (RunResult, Vec<Vec<f32>>) {
    let mut sess = Session::new(cfg, None).unwrap();
    sess.run_to_completion().unwrap();
    let (res, store) = sess.finish().unwrap();
    (res, store.bufs)
}

fn run_suspended(cfg: &TrainConfig, at: usize) -> (RunResult, Vec<Vec<f32>>) {
    let mut sess = Session::new(cfg, None).unwrap();
    sess.run_steps(at).unwrap();
    assert_eq!(sess.step(), at.min(cfg.steps));
    let bytes = sess.suspend();
    drop(sess);
    let mut sess = Session::resume(&bytes).unwrap();
    assert_eq!(sess.step(), at.min(cfg.steps));
    sess.run_to_completion().unwrap();
    let (res, store) = sess.finish().unwrap();
    (res, store.bufs)
}

fn assert_runs_identical(
    tag: &str,
    a: &RunResult,
    b: &RunResult,
    pa: &[Vec<f32>],
    pb: &[Vec<f32>],
) {
    assert_eq!(a.train_losses.len(), b.train_losses.len(), "{tag}: step count");
    for (i, (x, y)) in a.train_losses.iter().zip(&b.train_losses).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{tag}: train loss bits at step {i}");
    }
    assert_eq!(a.evals.len(), b.evals.len(), "{tag}: eval count");
    for (x, y) in a.evals.iter().zip(&b.evals) {
        assert_eq!(x.step, y.step, "{tag}: eval step");
        assert_eq!(x.loss.to_bits(), y.loss.to_bits(), "{tag}: eval loss bits");
        assert_eq!(x.metric.to_bits(), y.metric.to_bits(), "{tag}: eval metric bits");
    }
    assert_eq!(a.telemetry.len(), b.telemetry.len(), "{tag}: telemetry");
    for ((ka, va), (kb, vb)) in a.telemetry.iter().zip(&b.telemetry) {
        assert_eq!(ka, kb, "{tag}: telemetry key");
        assert_eq!(va.to_bits(), vb.to_bits(), "{tag}: telemetry {ka}");
    }
    assert_eq!(pa.len(), pb.len(), "{tag}: tensor count");
    for (t, (ba, bb)) in pa.iter().zip(pb).enumerate() {
        assert_eq!(ba.len(), bb.len(), "{tag}: tensor {t} size");
        for (j, (x, y)) in ba.iter().zip(bb).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{tag}: param bits, tensor {t} elem {j}");
        }
    }
}

#[test]
fn suspend_resume_is_bitwise_across_knob_grid() {
    let _g = lock();
    let _r = ResetKnobs;
    // suspend at 5: past the eval at step 5, before magnitude's re-selects
    // at 6 and 9 and before the final eval — every post-resume event runs
    // from restored state
    let cases = [
        (Method::BlockLlm, 12usize, 5usize),
        (Method::Magnitude, 12, 5),
        (Method::FullAdam, 8, 3),
    ];
    for threads in [1usize, 4] {
        for stream in [false, true] {
            blockllm::util::reset_all_knobs();
            blockllm::util::set_num_threads(threads);
            blockllm::util::set_grad_stream(stream);
            for (method, steps, at) in cases {
                let cfg = grain_cfg(method, steps);
                let (want, want_p) = run_uninterrupted(&cfg);
                let (got, got_p) = run_suspended(&cfg, at);
                let tag = format!("{method:?} t{threads} gs{}", stream as u8);
                assert_runs_identical(&tag, &want, &got, &want_p, &got_p);
            }
        }
    }
}

/// The dist layer's suspend/resume leg: a `--replicas 2` run (grad accum 4,
/// so every optimizer step genuinely fans its microbatches out over both
/// replicas) suspends mid-run, resumes, and must match BOTH its own
/// uninterrupted twin and the 1-replica uninterrupted reference bit for
/// bit — replication is invisible to the checkpoint format and to the
/// training trajectory.
#[test]
fn replicated_suspend_resume_is_bitwise_and_matches_sequential() {
    let _g = lock();
    let _r = ResetKnobs;
    blockllm::util::reset_all_knobs();
    let mut cfg = grain_cfg(Method::BlockLlm, 12);
    cfg.grad_accum = 4;
    let (want_seq, want_seq_p) = run_uninterrupted(&cfg);
    blockllm::util::set_replicas(2);
    let (want, want_p) = run_uninterrupted(&cfg);
    let (got, got_p) = run_suspended(&cfg, 5);
    assert_runs_identical("replicas=2 resume", &want, &got, &want_p, &got_p);
    assert_runs_identical("replicas=2 vs sequential", &want_seq, &want, &want_seq_p, &want_p);
}

#[test]
fn glue_cls_sessions_resume_bitwise_too() {
    let _g = lock();
    let _r = ResetKnobs;
    blockllm::util::reset_all_knobs();
    let mut cfg = grain_cfg(Method::FullAdam, 6);
    cfg.set("task", "glue-cola").unwrap();
    cfg.eval_every = 0;
    let (want, want_p) = run_uninterrupted(&cfg);
    let (got, got_p) = run_suspended(&cfg, 2);
    assert_runs_identical("glue", &want, &got, &want_p, &got_p);
}

#[test]
fn corrupt_checkpoints_fail_cleanly() {
    let _g = lock();
    let _r = ResetKnobs;
    blockllm::util::reset_all_knobs();
    let cfg = grain_cfg(Method::FullAdam, 3);
    let mut sess = Session::new(&cfg, None).unwrap();
    sess.run_steps(2).unwrap();
    let bytes = sess.suspend();
    drop(sess);

    // truncation at a spread of offsets: clean Err, never a panic
    for cut in [0, 4, 7, bytes.len() / 3, bytes.len() / 2, bytes.len() - 1] {
        assert!(Session::resume(&bytes[..cut]).is_err(), "accepted {cut}-byte truncation");
    }

    // a future format version must be refused, not misread
    let needle = b"\"version\":\"1\"";
    let at = bytes
        .windows(needle.len())
        .position(|w| w == needle)
        .expect("version key in checkpoint metadata");
    let mut bumped = bytes.clone();
    bumped[at + needle.len() - 2] = b'9';
    let err = Session::resume(&bumped).unwrap_err();
    assert!(format!("{err:#}").contains("version"), "{err:#}");

    // flipping the magic is 'not a checkpoint', not a crash
    let mut bad_magic = bytes.clone();
    bad_magic[0] ^= 0xFF;
    assert!(Session::resume(&bad_magic).is_err());

    // the intact original still resumes
    assert!(Session::resume(&bytes).is_ok());
}

#[test]
fn serve_matches_solo_runs_and_enforces_admission() {
    let _g = lock();
    let _r = ResetKnobs;
    blockllm::util::reset_all_knobs();
    // three admitted tenants (different methods/seeds/lengths, one shared
    // grain backend) + one starved tenant that must be rejected up front
    let spec_src = r#"{
        "slice_steps": 2,
        "sessions": [
            {"name": "adam",  "config": {"preset": "grain", "method": "adam",
             "steps": 7, "eval-every": 0, "eval-batches": 1, "seed": 3}},
            {"name": "bllm",  "config": {"preset": "grain", "method": "blockllm",
             "steps": 5, "eval-every": 0, "eval-batches": 1, "seed": 4}},
            {"name": "mag",   "config": {"preset": "grain", "method": "magnitude",
             "steps": 6, "eval-every": 0, "eval-batches": 1, "seed": 5,
             "mag-update-every": 3}},
            {"name": "starved", "budget_mb": 0.001,
             "config": {"preset": "grain", "method": "adam",
             "steps": 4, "eval-every": 0, "eval-batches": 1, "seed": 6}}
        ]
    }"#;
    let spec = ServeSpec::parse(spec_src).unwrap();
    let outcomes = serve(&spec, &|| {}).unwrap();
    assert_eq!(outcomes.len(), 4);

    let starved = &outcomes[3];
    assert!(!starved.admitted);
    assert!(starved.result.is_none());
    assert!(starved.fate.as_deref().unwrap().contains("modeled footprint"));

    for (i, o) in outcomes.iter().take(3).enumerate() {
        assert!(o.admitted, "{} not admitted", o.name);
        let got = o.result.as_ref().unwrap_or_else(|| panic!("{} has no result", o.name));
        blockllm::util::reset_all_knobs();
        let (want, _) = run_uninterrupted(&spec.sessions[i].cfg);
        assert_eq!(want.train_losses.len(), got.train_losses.len(), "{}", o.name);
        for (s, (x, y)) in want.train_losses.iter().zip(&got.train_losses).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{}: time-sliced loss diverged from solo at step {s}",
                o.name
            );
        }
        assert_eq!(want.evals.len(), got.evals.len(), "{}", o.name);
        for (x, y) in want.evals.iter().zip(&got.evals) {
            assert_eq!(x.loss.to_bits(), y.loss.to_bits(), "{}: eval diverged", o.name);
        }
    }
}

#[test]
fn preemptive_policies_match_solo_runs_across_threads() {
    let _g = lock();
    let _r = ResetKnobs;
    // Deadlines chosen so the slack ranking flips every few steps: a
    // (deadline 12, 8 steps) and b (deadline 10, 6 steps) start tied at
    // slack 4, and whichever runs makes the waiter's slack strictly
    // smaller after 1-2 steps — forcing mid-slice preemptions well before
    // the 6-step slice is up. Under `weighted` (weights 1:3) the stride
    // ranking flips the same way. Methods differ so the checkpoint churn
    // crosses selection machinery, not just dense Adam state.
    let spec_src = r#"{
        "slice_steps": 6,
        "sessions": [
            {"name": "a", "deadline": 12, "weight": 1,
             "config": {"preset": "grain", "method": "adam",
             "steps": 8, "eval-every": 0, "eval-batches": 1, "seed": 3}},
            {"name": "b", "deadline": 10, "weight": 3,
             "config": {"preset": "grain", "method": "blockllm",
             "steps": 6, "eval-every": 0, "eval-batches": 1, "seed": 4,
             "patience": 2}}
        ]
    }"#;
    for threads in [1usize, 4] {
        for sched in ["slack", "weighted"] {
            blockllm::util::reset_all_knobs();
            blockllm::util::set_num_threads(threads);
            let mut spec = ServeSpec::parse(spec_src).unwrap();
            spec.policy = SchedPolicy::parse(sched).unwrap();
            let rearm = move || blockllm::util::set_num_threads(threads);
            let outcomes = serve(&spec, &rearm).unwrap();
            let preemptions: u64 = outcomes.iter().map(|o| o.sched.preemptions).sum();
            assert!(preemptions > 0, "{sched} t{threads}: no mid-slice preemption fired");
            for (i, o) in outcomes.iter().enumerate() {
                let got =
                    o.result.as_ref().unwrap_or_else(|| panic!("{} has no result", o.name));
                blockllm::util::reset_all_knobs();
                blockllm::util::set_num_threads(threads);
                let (want, _) = run_uninterrupted(&spec.sessions[i].cfg);
                assert_eq!(
                    want.train_losses.len(),
                    got.train_losses.len(),
                    "{sched} t{threads} {}",
                    o.name
                );
                for (s, (x, y)) in
                    want.train_losses.iter().zip(&got.train_losses).enumerate()
                {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "{sched} t{threads} {}: preempted loss diverged from solo at step {s}",
                        o.name
                    );
                }
            }
        }
    }
}
