//! Bench: the masked sparse Adam hot path (BlockLLM's per-step update) at
//! the paper's operating sparsities, vs the dense Adam baseline — the L3
//! cost the paper's "BlockLLM is faster per step" claim rests on.

#[path = "harness.rs"]
mod harness;

use blockllm::optim::masked_adam::{masked_adam_step, BitMask, LayerState};
use blockllm::optim::AdamHypers;
use blockllm::util::rng::Pcg64;
use harness::{bench, black_box, throughput};

fn main() {
    let n = 1 << 20; // one 1M-coordinate layer
    let mut rng = Pcg64::new(1);
    let g: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
    let h = AdamHypers::default();

    println!("masked Adam over a {n}-coordinate layer:");
    for density in [1.0, 0.5, 0.05, 0.005] {
        let tau_idx = ((n as f64) * density) as usize;
        let tau = if tau_idx == 0 {
            f32::INFINITY
        } else {
            blockllm::tensor::kth_largest_abs(&g, tau_idx.max(1))
        };
        let mask = BitMask::from_threshold(&g, tau);
        let mut st = LayerState { m: vec![0.0; n], v: vec![0.0; n], mask };
        let mut w: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
        let mut step = 0u64;
        let r = bench(&format!("masked_adam density={density}"), 3, 30, || {
            step += 1;
            black_box(masked_adam_step(&mut w, &g, &mut st, step, 1e-3, &h));
        });
        println!("    -> {} active-coord throughput", throughput(&r, st.mask.popcount.max(1)));
    }

    // dense baseline for the same layer
    let mut dense = blockllm::optim::DenseAdam::new(&[n], h);
    let mut w = vec![vec![0.5f32; n]];
    let r = bench("dense_adam (baseline)", 3, 30, || {
        let gr: Vec<&[f32]> = vec![&g];
        dense.step(&mut w, &gr, 1e-3);
    });
    println!("    -> {} coord throughput", throughput(&r, n));
}
