//! Bench: the BlockLLM selection path — per-layer norm scoring, greedy
//! selection, and percentile mask construction — at model-ladder scales.
//! This is the cost paid once per patience window, amortized to near-zero
//! per step; the bench verifies that claim.

#[path = "harness.rs"]
mod harness;

use blockllm::blockllm::scorer::NormDictionary;
use blockllm::blockllm::selector::{select_layers, SelectionRule};
use blockllm::blockllm::build_masks;
use blockllm::config::{MaskMode, NormKind};
use blockllm::util::rng::Pcg64;
use harness::{bench, black_box};

fn main() {
    let mut rng = Pcg64::new(2);
    // a tiny-preset-shaped layer table: 56 tensors, ~4.9M params
    let mut sizes = vec![65536usize];
    for _ in 0..6 {
        sizes.extend_from_slice(&[256, 65536, 65536, 65536, 65536, 256, 176128, 176128, 176128]);
    }
    sizes.push(256);
    sizes.push(65536);
    let grads: Vec<Vec<f32>> = sizes
        .iter()
        .map(|&n| (0..n).map(|_| rng.normal_f32()).collect())
        .collect();
    let n: usize = sizes.iter().sum();
    println!("layer table: {} tensors, {n} params", sizes.len());

    let mut dict = NormDictionary::new(sizes.len(), NormKind::Rms, 3);
    bench("score_all_layers (selection event)", 3, 30, || {
        for (l, g) in grads.iter().enumerate() {
            dict.record(l, g, 0);
        }
        black_box(&dict);
    });

    bench("greedy_select (Alg. 2 core)", 10, 200, || {
        black_box(select_layers(&dict, &sizes, 0.95, SelectionRule::TopScore));
    });

    for s in [0.5, 0.95] {
        let sel = select_layers(&dict, &sizes, s, SelectionRule::TopScore);
        bench(&format!("build_masks s={s} (percentile+bitmask)"), 3, 30, || {
            black_box(build_masks(&sel, &grads, MaskMode::Alg2));
        });
    }

    // p-layer probe bookkeeping (every step)
    bench("layers_to_probe p=2 (per-step)", 10, 500, || {
        black_box(dict.layers_to_probe(&[3, 7, 11], 2, 100));
    });
}
