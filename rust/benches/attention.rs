//! Bench: isolates the attention core's ms/step — QKᵀ scores + causal
//! mask/scale + row softmax + probs·V on the forward side, plus the four
//! backward contractions (PᵀdO, dO·Vᵀ, dS·K, dSᵀ·Q) — at a preset's lm
//! batch shape, comparing the BATCHED strided-GEMM path (one
//! `gemm_batched` call per contraction over all b·h heads) against the
//! legacy per-head loop (head-slice copies + `parallel_map` fan-out with a
//! `threads/(b·h)` inner budget). The two paths are bitwise-identical by
//! contract; this harness measures what the batching buys in wall clock.
//!
//! Args (after `cargo bench --bench attention --`):
//!   --preset NAME     model preset (default tiny)
//!   --iters N         timed iterations per case (default 16)
//!   --warmup N        warmup iterations per case (default 2)
//!   --threads N       pin the kernel worker count
//!   --out PATH        JSON output path (default BENCH_attention.json)

#[path = "harness.rs"]
mod harness;

use blockllm::backend::native::mask_scale_causal;
use blockllm::config::presets;
use blockllm::linalg::{gemm, gemm_batched};
use blockllm::tensor::{BatchView, Tensor};
use blockllm::util::json::Json;
use blockllm::util::rng::Pcg64;
use harness::{arg, arg_usize, bench};

/// Copy one head's [t, dh] block out of interleaved [b*t, h*dh] (what the
/// per-head loop pays that the batched path does not).
fn head_copy(x: &Tensor, bi: usize, t: usize, hi: usize, dh: usize) -> Tensor {
    let d = x.cols();
    let mut out = Tensor::zeros(&[t, dh]);
    for ti in 0..t {
        let src = &x.data[(bi * t + ti) * d + hi * dh..(bi * t + ti) * d + (hi + 1) * dh];
        out.data[ti * dh..(ti + 1) * dh].copy_from_slice(src);
    }
    out
}

fn main() {
    let preset_name = arg("--preset").unwrap_or_else(|| "tiny".to_string());
    let iters = arg_usize("--iters", 16).max(1);
    let warmup = arg_usize("--warmup", 2);
    if let Some(v) = arg("--threads") {
        match v.parse() {
            Ok(n) => blockllm::util::set_num_threads(n),
            Err(_) => {
                eprintln!("--threads wants a number, got {v:?}");
                std::process::exit(2);
            }
        }
    }
    let out_path = arg("--out").unwrap_or_else(|| "BENCH_attention.json".to_string());
    let threads = blockllm::util::num_threads();
    let calib_ms = harness::calibrate_ms();

    let Some(p) = presets::get(&preset_name) else {
        eprintln!("unknown preset {preset_name:?}");
        std::process::exit(2);
    };
    let (b, t) = p.lm_batch();
    let (h, dh) = (p.n_heads, p.d_head());
    let (bh, d) = (b * h, p.d_model);
    let scale = 1.0 / (dh as f32).sqrt();
    println!(
        "attention bench: preset {preset_name} b={b} t={t} h={h} dh={dh} ({threads} threads)"
    );

    let mut rng = Pcg64::new(0xA77);
    let mut q = Tensor::zeros(&[b * t, d]);
    let mut k = Tensor::zeros(&[b * t, d]);
    let mut v = Tensor::zeros(&[b * t, d]);
    let mut dctx = Tensor::zeros(&[b * t, d]);
    for x in [&mut q, &mut k, &mut v, &mut dctx] {
        rng.fill_normal(&mut x.data, 1.0);
    }
    // probs stand in for the softmax output / dS in the backward timings
    let mut probs = gemm_batched::matmul_batched_nt(
        &BatchView::heads(&q, b, t, h, dh),
        &BatchView::heads(&k, b, t, h, dh),
        threads,
    );
    mask_scale_causal(&mut probs, t, scale, threads);
    probs.softmax_rows_threads(threads);

    let mut rows: Vec<Json> = Vec::new();
    let mut push = |path: &str, phase: &str, r: &harness::BenchResult| {
        rows.push(Json::obj(vec![
            ("path", Json::str(path)),
            ("phase", Json::str(phase)),
            ("ms_per_step", Json::num(r.median_ns / 1e6)),
            ("p10_ms", Json::num(r.p10_ns / 1e6)),
            ("p90_ms", Json::num(r.p90_ns / 1e6)),
            ("iters", Json::num(r.iters as f64)),
        ]));
    };

    // ---- batched strided-GEMM path
    let r = bench(&format!("attn fwd {preset_name} [batched]"), warmup, iters, || {
        let qv = BatchView::heads(&q, b, t, h, dh);
        let kv = BatchView::heads(&k, b, t, h, dh);
        let vv = BatchView::heads(&v, b, t, h, dh);
        let mut s = gemm_batched::matmul_batched_nt(&qv, &kv, threads);
        mask_scale_causal(&mut s, t, scale, threads);
        s.softmax_rows_threads(threads);
        let ctx = gemm_batched::matmul_batched_nn(
            &BatchView::dense(&s.data, bh, t, t),
            &vv,
            threads,
        );
        harness::black_box(ctx);
    });
    push("batched", "fwd", &r);
    let r = bench(&format!("attn bwd {preset_name} [batched]"), warmup, iters, || {
        let pv = BatchView::dense(&probs.data, bh, t, t);
        let dov = BatchView::heads(&dctx, b, t, h, dh);
        let vv = BatchView::heads(&v, b, t, h, dh);
        let qv = BatchView::heads(&q, b, t, h, dh);
        let kv = BatchView::heads(&k, b, t, h, dh);
        let dv_heads = gemm_batched::matmul_batched_tn(&pv, &dov, threads);
        let dp = gemm_batched::matmul_batched_nt(&dov, &vv, threads);
        let dq = gemm_batched::matmul_batched_nn(&pv, &kv, threads);
        let dk = gemm_batched::matmul_batched_tn(&pv, &qv, threads);
        harness::black_box((dv_heads, dp, dq, dk));
    });
    push("batched", "bwd", &r);

    // ---- legacy per-head loop
    let inner = (threads / bh.max(1)).max(1);
    let r = bench(&format!("attn fwd {preset_name} [looped]"), warmup, iters, || {
        let heads = gemm::parallel_map(bh, |i| {
            let (bi, hi) = (i / h, i % h);
            let qh = head_copy(&q, bi, t, hi, dh);
            let kh = head_copy(&k, bi, t, hi, dh);
            let vh = head_copy(&v, bi, t, hi, dh);
            let mut s = gemm::matmul_nt_threads(&qh, &kh, inner);
            mask_scale_causal(&mut s, t, scale, 1);
            s.softmax_rows_threads(inner);
            gemm::matmul_threads(&s, &vh, inner)
        });
        harness::black_box(heads);
    });
    push("looped", "fwd", &r);
    let r = bench(&format!("attn bwd {preset_name} [looped]"), warmup, iters, || {
        let heads = gemm::parallel_map(bh, |i| {
            let (bi, hi) = (i / h, i % h);
            let pr =
                blockllm::tensor::View::new(&[t, t], &probs.data[i * t * t..(i + 1) * t * t]);
            let do_h = head_copy(&dctx, bi, t, hi, dh);
            let vh = head_copy(&v, bi, t, hi, dh);
            let qh = head_copy(&q, bi, t, hi, dh);
            let kh = head_copy(&k, bi, t, hi, dh);
            let dv_h = gemm::matmul_tn_threads(&pr, &do_h, inner);
            let dp = gemm::matmul_nt_threads(&do_h, &vh, inner);
            let dq_h = gemm::matmul_threads(&pr, &kh, inner);
            let dk_h = gemm::matmul_tn_threads(&pr, &qh, inner);
            (dv_h, dp, dq_h, dk_h)
        });
        harness::black_box(heads);
    });
    push("looped", "bwd", &r);

    let doc = Json::obj(vec![
        ("bench", Json::str("attention")),
        ("preset", Json::str(preset_name.clone())),
        ("threads", Json::num(threads as f64)),
        ("calib_ms", Json::num(calib_ms)),
        ("rows", Json::Arr(rows)),
    ]);
    match std::fs::write(&out_path, doc.to_string() + "\n") {
        Ok(()) => println!("wrote {out_path}"),
        Err(e) => eprintln!("could not write {out_path}: {e}"),
    }
}
