//! Bench: GaLore's per-step projection cost (the baseline's L3 overhead) —
//! low-rank project/backproject matmuls every step plus the periodic
//! randomized range-finder refresh. Contrast with BlockLLM's masked-Adam
//! bench: this is the structural reason BlockLLM wins wall-clock in Fig. 5.

#[path = "harness.rs"]
mod harness;

use blockllm::linalg::range_finder;
use blockllm::tensor::Tensor;
use blockllm::util::rng::Pcg64;
use harness::{bench, black_box};

fn main() {
    let mut rng = Pcg64::new(4);
    for (m, n, r) in [(256, 256, 8), (256, 688, 8), (256, 688, 64)] {
        let mut g = Tensor::zeros(&[m, n]);
        rng.fill_normal(&mut g.data, 1.0);

        let p = range_finder(&g, r, 2, &mut rng);
        bench(&format!("project+backproject {m}x{n} r={r} (per step)"), 5, 50, || {
            let low = p.matmul_tn(&g); // [r, n]
            black_box(p.matmul(&low)); // back to [m, n]
        });

        bench(&format!("range_finder {m}x{n} r={r} (per refresh)"), 2, 10, || {
            black_box(range_finder(&g, r, 2, &mut rng));
        });
    }
}
