//! Bench: full end-to-end training steps through the execution backend for
//! each method — the repo's equivalent of the paper's wall-clock comparison
//! (Fig. 5 bottom-right), isolated from data generation.
//!
//! Always produces numbers: with AOT artifacts present it drives PJRT,
//! otherwise the pure-Rust native backend. The backend that ran is printed
//! with every row, and the results are written as JSON (default
//! `BENCH_train_step.json`) so CI's bench-smoke step can track the perf
//! trajectory across PRs.
//!
//! Args (after `cargo bench --bench train_step --`):
//!   --preset NAME   model preset (default micro)
//!   --iters N       timed iterations per method (default 24)
//!   --warmup N      warmup iterations per method (default 3)
//!   --threads N     pin the kernel worker count (default: PALLAS_NUM_THREADS
//!                   or all cores; results are identical at any setting)
//!   --out PATH      JSON output path (default BENCH_train_step.json)

#[path = "harness.rs"]
mod harness;

use blockllm::config::{Method, Task, TrainConfig};
use blockllm::data::c4sim::C4Sim;
use blockllm::data::LmStream;
use blockllm::trainer::Trainer;
use blockllm::util::json::Json;
use harness::bench;

fn arg(name: &str) -> Option<String> {
    std::env::args().skip_while(|a| a != name).nth(1)
}

fn arg_usize(name: &str, default: usize) -> usize {
    arg(name).and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let preset = arg("--preset").unwrap_or_else(|| "micro".to_string());
    let iters = arg_usize("--iters", 24).max(1);
    let warmup = arg_usize("--warmup", 3);
    if let Some(v) = arg("--threads") {
        match v.parse() {
            Ok(t) => blockllm::util::set_num_threads(t),
            Err(_) => {
                eprintln!("--threads wants a number, got {v:?}");
                std::process::exit(2);
            }
        }
    }
    let out_path = arg("--out").unwrap_or_else(|| "BENCH_train_step.json".to_string());
    let threads = blockllm::util::num_threads();

    let mut rows: Vec<Json> = Vec::new();
    for method in [Method::BlockLlm, Method::FullAdam, Method::GaLore, Method::LoRa, Method::BAdam] {
        let mut cfg = TrainConfig::default();
        cfg.preset = preset.clone();
        cfg.task = Task::C4Pretrain;
        cfg.method = method;
        cfg.steps = 1_000_000; // schedule horizon; we drive steps manually
        cfg.sparsity = 0.95;
        cfg.cosine_lr = false;
        let mut tr = match Trainer::open(cfg, None) {
            Ok(tr) => tr,
            Err(e) => {
                eprintln!("SKIP {preset} {}: {e:#}", method.name());
                continue;
            }
        };
        let backend = tr.backend.name().to_string();
        let (b, t) = tr.batch_shape();
        let mut stream = C4Sim::new(9);
        // pre-generate batches so data gen is outside the timed region
        let batches: Vec<_> = (0..12).map(|_| stream.next_batch(b, t)).collect();
        let mut i = 0;
        let r = bench(
            &format!("train_step {preset} {} [{backend}]", method.name()),
            warmup,
            iters,
            || {
                let batch = &batches[i % batches.len()];
                i += 1;
                tr.bench_step(batch).expect("step");
            },
        );
        rows.push(Json::obj(vec![
            ("method", Json::str(method.name())),
            ("backend", Json::str(backend)),
            ("ms_per_step", Json::num(r.median_ns / 1e6)),
            ("p10_ms", Json::num(r.p10_ns / 1e6)),
            ("p90_ms", Json::num(r.p90_ns / 1e6)),
            ("iters", Json::num(r.iters as f64)),
        ]));
    }

    let doc = Json::obj(vec![
        ("bench", Json::str("train_step")),
        ("preset", Json::str(preset.clone())),
        ("threads", Json::num(threads as f64)),
        ("rows", Json::Arr(rows)),
    ]);
    match std::fs::write(&out_path, doc.to_string() + "\n") {
        Ok(()) => println!("wrote {out_path}"),
        Err(e) => eprintln!("could not write {out_path}: {e}"),
    }
}
