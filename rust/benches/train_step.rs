//! Bench: full end-to-end training steps through the execution backend for
//! each method — the repo's equivalent of the paper's wall-clock comparison
//! (Fig. 5 bottom-right), isolated from data generation.
//!
//! Always produces numbers: with AOT artifacts present it drives PJRT,
//! otherwise the pure-Rust native backend. The backend that ran is printed
//! with every row, and the results are written as JSON (default
//! `BENCH_train_step.json`) so CI's bench-smoke step can track the perf
//! trajectory across PRs.
//!
//! Args (after `cargo bench --bench train_step --`):
//!   --preset NAME     model preset (default micro)
//!   --iters N         timed iterations per method (default 24)
//!   --warmup N        warmup iterations per method (default 3)
//!   --threads N       pin the kernel worker count (default: PALLAS_NUM_THREADS
//!                     or all cores; results are identical at any setting)
//!   --out PATH        JSON output path (default BENCH_train_step.json)
//!   --baseline PATH   diff against a checked-in baseline JSON and exit 1 on
//!                     a >25% regression in EITHER ms/step or measured
//!                     peak_grad_bytes. ms numbers are rescaled by the ratio
//!                     of the two hosts' `calib_ms` (a fixed arithmetic loop
//!                     timed at startup), so a baseline recorded on one
//!                     machine gates another; memory is deterministic and
//!                     compares unscaled (only when the baseline's
//!                     `grad_stream` matches the run's retention route).
//!                     Regenerate with `make bench-baseline`.
//!   --trace-check 1   tracing-overhead smoke instead of the method sweep:
//!                     bench one method untraced, then traced, and exit 1 if
//!                     the traced median exceeds untraced * 1.10 + 0.5 ms.

#[path = "harness.rs"]
mod harness;

use blockllm::config::{Method, Task, TrainConfig};
use blockllm::data::c4sim::C4Sim;
use blockllm::data::LmStream;
use blockllm::trainer::Trainer;
use blockllm::util::json::Json;
use harness::{arg, arg_usize, bench};

fn main() {
    let preset = arg("--preset").unwrap_or_else(|| "micro".to_string());
    let iters = arg_usize("--iters", 24).max(1);
    let warmup = arg_usize("--warmup", 3);
    if let Some(v) = arg("--threads") {
        match v.parse() {
            Ok(t) => blockllm::util::set_num_threads(t),
            Err(_) => {
                eprintln!("--threads wants a number, got {v:?}");
                std::process::exit(2);
            }
        }
    }
    let out_path = arg("--out").unwrap_or_else(|| "BENCH_train_step.json".to_string());
    let baseline_path = arg("--baseline");
    if arg_usize("--trace-check", 0) != 0 {
        std::process::exit(trace_overhead_check(&preset, warmup, iters));
    }
    let threads = blockllm::util::num_threads();
    let calib_ms = harness::calibrate_ms();

    let mut rows: Vec<Json> = Vec::new();
    // (method, backend, ms, peak_grad_bytes)
    let mut measured: Vec<(String, String, f64, u64)> = Vec::new();
    for method in [
        Method::BlockLlm,
        Method::FullAdam,
        Method::GaLore,
        Method::LoRa,
        Method::BAdam,
    ] {
        let mut cfg = TrainConfig::default();
        cfg.preset = preset.clone();
        cfg.task = Task::C4Pretrain;
        cfg.method = method;
        cfg.steps = 1_000_000; // schedule horizon; we drive steps manually
        cfg.sparsity = 0.95;
        cfg.cosine_lr = false;
        let mut tr = match Trainer::open(cfg, None) {
            Ok(tr) => tr,
            Err(e) => {
                eprintln!("SKIP {preset} {}: {e:#}", method.name());
                continue;
            }
        };
        let backend = tr.backend.name().to_string();
        let (b, t) = tr.batch_shape();
        let mut stream = C4Sim::new(9);
        // pre-generate batches so data gen is outside the timed region
        let batches: Vec<_> = (0..12).map(|_| stream.next_batch(b, t)).collect();
        let mut i = 0;
        let obs_base = blockllm::obs::snapshot();
        let r = bench(
            &format!("train_step {preset} {} [{backend}]", method.name()),
            warmup,
            iters,
            || {
                let batch = &batches[i % batches.len()];
                i += 1;
                tr.bench_step(batch).expect("step");
            },
        );
        // with --trace/PALLAS_TRACE on, attach this method's span/counter
        // delta to its row (the bench drives steps manually, so the
        // trainer's own end-of-run export never fires)
        let res_profile = blockllm::obs::on()
            .then(|| blockllm::obs::export::profile_json(&blockllm::obs::delta(&obs_base)));
        let peak = tr.mem.peak_grad_measured;
        measured.push((method.name().to_string(), backend.clone(), r.median_ns / 1e6, peak));
        let mut row = vec![
            ("method", Json::str(method.name())),
            ("backend", Json::str(backend)),
            ("ms_per_step", Json::num(r.median_ns / 1e6)),
            ("p10_ms", Json::num(r.p10_ns / 1e6)),
            ("p90_ms", Json::num(r.p90_ns / 1e6)),
            ("iters", Json::num(r.iters as f64)),
            // measured peak gradient-buffer bytes over the timed steps
            // (sink retention + transient shard; the streaming-vs-dense
            // memory trajectory per method). Gated by --baseline alongside
            // ms/step when the retention route matches the baseline's.
            ("peak_grad_bytes", Json::num(peak as f64)),
            // per-replica optimizer-state bytes under the dist layer's
            // ZeRO-style sharding (full state bytes at --replicas 1) —
            // informational, not gated
            ("state_shard_bytes", Json::num(tr.mem.peak_state_shard_measured as f64)),
        ];
        if let Some(p) = res_profile.as_ref() {
            row.push(("profile", p.clone()));
        }
        rows.push(Json::obj(row));
    }

    let doc = Json::obj(vec![
        ("bench", Json::str("train_step")),
        ("preset", Json::str(preset.clone())),
        ("threads", Json::num(threads as f64)),
        ("grad_stream", Json::num(u64::from(blockllm::util::grad_stream()) as f64)),
        ("calib_ms", Json::num(calib_ms)),
        ("rows", Json::Arr(rows)),
    ]);
    match std::fs::write(&out_path, doc.to_string() + "\n") {
        Ok(()) => println!("wrote {out_path}"),
        Err(e) => eprintln!("could not write {out_path}: {e}"),
    }

    if let Some(path) = baseline_path {
        let regressions = check_baseline(&path, &preset, threads, &measured, calib_ms);
        if regressions > 0 {
            eprintln!("BENCH GATE: {regressions} method(s) regressed >25% vs {path}");
            std::process::exit(1);
        }
    }
}

/// Tracing-overhead smoke (`--trace-check 1`): bench blockllm on `preset`
/// untraced, then with the span profiler live, and compare medians. The
/// margin is 10% plus a 0.5 ms absolute slack so sub-millisecond presets
/// don't gate on scheduler noise. Returns the process exit code.
fn trace_overhead_check(preset: &str, warmup: usize, iters: usize) -> i32 {
    let run = |traced: bool| -> f64 {
        blockllm::obs::set_trace(traced);
        let mut cfg = TrainConfig::default();
        cfg.preset = preset.to_string();
        cfg.task = Task::C4Pretrain;
        cfg.method = Method::BlockLlm;
        cfg.steps = 1_000_000;
        cfg.sparsity = 0.95;
        cfg.cosine_lr = false;
        let mut tr = Trainer::open(cfg, None).expect("trainer");
        let (b, t) = tr.batch_shape();
        let mut stream = C4Sim::new(9);
        let batches: Vec<_> = (0..12).map(|_| stream.next_batch(b, t)).collect();
        let mut i = 0;
        let label = if traced { "traced" } else { "untraced" };
        let r = bench(&format!("trace-check {preset} blockllm [{label}]"), warmup, iters, || {
            let batch = &batches[i % batches.len()];
            i += 1;
            tr.bench_step(batch).expect("step");
        });
        r.median_ns / 1e6
    };
    let off_ms = run(false);
    let on_ms = run(true);
    blockllm::obs::reset_trace();
    let limit = off_ms * 1.10 + 0.5;
    let overhead = (on_ms / off_ms - 1.0) * 100.0;
    if on_ms > limit {
        eprintln!(
            "TRACE OVERHEAD: {on_ms:.2} ms traced vs {off_ms:.2} ms untraced \
             (+{overhead:.1}%, limit {limit:.2} ms)"
        );
        1
    } else {
        println!(
            "trace-check ok: {on_ms:.2} ms traced vs {off_ms:.2} ms untraced \
             (+{overhead:.1}%, limit {limit:.2} ms)"
        );
        0
    }
}

/// Diff measured ms/step against a baseline JSON (same schema as --out).
/// The baseline's numbers are rescaled by the single-core host-speed ratio
/// `calib_now / calib_base` (clamped to [0.25, 4] as a fabrication guard)
/// before the 25% margin is applied, so baselines travel across same-shape
/// machines. The gate only arms when the baseline's `threads` matches the
/// current worker count — calib measures one core, so a different thread
/// count would make the rescale meaningless. Measured `peak_grad_bytes`
/// gates too (deterministic, so no rescale or clamp) when the baseline
/// carries a `grad_stream` field matching this run's retention route and
/// the row carries the byte count. Methods missing from the baseline,
/// backend mismatches (pjrt vs native), preset and thread-count mismatches
/// are reported but never gate. Returns the regression count.
fn check_baseline(
    path: &str,
    preset: &str,
    threads: usize,
    measured: &[(String, String, f64, u64)],
    calib_now: f64,
) -> usize {
    let src = match std::fs::read_to_string(path) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("baseline {path} unreadable ({e}); skipping bench gate");
            return 0;
        }
    };
    let base = match Json::parse(&src) {
        Ok(j) => j,
        Err(e) => {
            eprintln!("baseline {path} unparseable ({e}); skipping bench gate");
            return 0;
        }
    };
    let base_preset = base.get("preset").and_then(|j| j.as_str().ok()).unwrap_or("");
    if base_preset != preset {
        eprintln!("baseline preset {base_preset:?} != current {preset:?}; skipping bench gate");
        return 0;
    }
    let base_threads = base.get("threads").and_then(|j| j.as_usize().ok()).unwrap_or(0);
    if base_threads != threads {
        eprintln!(
            "baseline recorded {base_threads} worker threads, this run uses {threads}; \
             skipping bench gate (regenerate with `make bench-baseline` on this host class)"
        );
        return 0;
    }
    let calib_base = base.get("calib_ms").and_then(|j| j.as_f64().ok()).unwrap_or(0.0);
    let scale = if calib_base > 0.0 && calib_now > 0.0 {
        (calib_now / calib_base).clamp(0.25, 4.0)
    } else {
        1.0
    };
    // memory gating is route-dependent (streaming vs dense retention), so
    // it arms only when the baseline says which route it recorded and that
    // route is the one running now
    let mem_armed = match base.get("grad_stream").and_then(|j| j.as_usize().ok()) {
        Some(gs) => (gs != 0) == blockllm::util::grad_stream(),
        None => false,
    };
    if !mem_armed {
        println!("bench-gate: baseline grad_stream absent or mismatched — memory gate skipped");
    }
    let empty: Vec<Json> = Vec::new();
    let base_rows = base
        .get("rows")
        .and_then(|j| j.as_arr().ok().map(<[Json]>::to_vec))
        .unwrap_or(empty);
    let mut regressions = 0usize;
    for (method, backend, ms, peak) in measured {
        let found = base_rows.iter().find(|r| {
            r.get("method").and_then(|j| j.as_str().ok()) == Some(method.as_str())
        });
        let Some(row) = found else {
            println!("bench-gate {method:12} {ms:9.2} ms  (no baseline row — skipped)");
            continue;
        };
        let base_backend = row.get("backend").and_then(|j| j.as_str().ok()).unwrap_or("");
        let base_ms = row.get("ms_per_step").and_then(|j| j.as_f64().ok()).unwrap_or(0.0);
        if base_backend != backend.as_str() || base_ms <= 0.0 {
            println!(
                "bench-gate {method:12} {ms:9.2} ms  (backend/ms mismatch vs \
                 baseline — skipped)"
            );
            continue;
        }
        let limit = base_ms * scale * 1.25;
        if *ms > limit {
            println!(
                "bench-gate {method:12} {ms:9.2} ms  REGRESSION: limit {limit:.2} ms \
                 (baseline {base_ms:.2} ms x host-scale {scale:.2} x 1.25)"
            );
            regressions += 1;
        } else {
            println!(
                "bench-gate {method:12} {ms:9.2} ms  ok (limit {limit:.2} ms, \
                 baseline {base_ms:.2} ms, host-scale {scale:.2})"
            );
        }
        // memory: deterministic, unscaled, >25% over baseline fails
        let base_peak = row.get("peak_grad_bytes").and_then(|j| j.as_usize().ok()).unwrap_or(0);
        if mem_armed && base_peak > 0 {
            let mem_limit = base_peak as u64 * 5 / 4;
            if *peak > mem_limit {
                println!(
                    "bench-gate {method:12} {peak:>9} grad bytes  REGRESSION: limit {mem_limit} \
                     (baseline {base_peak} x 1.25)"
                );
                regressions += 1;
            } else {
                println!(
                    "bench-gate {method:12} {peak:>9} grad bytes  ok (limit {mem_limit}, \
                     baseline {base_peak})"
                );
            }
        } else if base_peak == 0 {
            println!(
                "bench-gate {method:12} {peak:>9} grad bytes  (no baseline \
                 memory — skipped)"
            );
        }
    }
    regressions
}
