//! Bench: full end-to-end training steps through the execution backend for
//! each method — the repo's equivalent of the paper's wall-clock comparison
//! (Fig. 5 bottom-right), isolated from data generation.
//!
//! Always produces numbers: with AOT artifacts present it drives PJRT,
//! otherwise the pure-Rust native backend. The backend that ran is printed
//! with every row.

#[path = "harness.rs"]
mod harness;

use blockllm::config::{Method, Task, TrainConfig};
use blockllm::data::c4sim::C4Sim;
use blockllm::data::LmStream;
use blockllm::trainer::Trainer;
use harness::bench;

fn main() {
    let preset = std::env::args()
        .skip_while(|a| a != "--preset")
        .nth(1)
        .unwrap_or_else(|| "micro".to_string());

    for method in [Method::BlockLlm, Method::FullAdam, Method::GaLore, Method::LoRa, Method::BAdam] {
        let mut cfg = TrainConfig::default();
        cfg.preset = preset.clone();
        cfg.task = Task::C4Pretrain;
        cfg.method = method;
        cfg.steps = 1_000_000; // schedule horizon; we drive steps manually
        cfg.sparsity = 0.95;
        cfg.cosine_lr = false;
        let mut tr = match Trainer::open(cfg, None) {
            Ok(tr) => tr,
            Err(e) => {
                eprintln!("SKIP {preset} {}: {e:#}", method.name());
                continue;
            }
        };
        let backend = tr.backend.name();
        let (b, t) = tr.batch_shape();
        let mut stream = C4Sim::new(9);
        // pre-generate batches so data gen is outside the timed region
        let batches: Vec<_> = (0..12).map(|_| stream.next_batch(b, t)).collect();
        let mut i = 0;
        bench(
            &format!("train_step {preset} {} [{backend}]", method.name()),
            3,
            24,
            || {
                let batch = &batches[i % batches.len()];
                i += 1;
                tr.bench_step(batch).expect("step");
            },
        );
    }
}
