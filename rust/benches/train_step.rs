//! Bench: full end-to-end training steps through the PJRT artifact for each
//! method — the repo's equivalent of the paper's wall-clock comparison
//! (Fig. 5 bottom-right), isolated from data generation.
//!
//! Requires `make artifacts`.

#[path = "harness.rs"]
mod harness;

use blockllm::config::{Method, Task, TrainConfig};
use blockllm::data::c4sim::C4Sim;
use blockllm::data::LmStream;
use blockllm::runtime::Runtime;
use blockllm::trainer::Trainer;
use harness::bench;

fn main() {
    let Ok(mut rt) = Runtime::open_default() else {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    };
    let preset = std::env::args()
        .skip_while(|a| a != "--preset")
        .nth(1)
        .unwrap_or_else(|| "micro".to_string());

    for method in [Method::BlockLlm, Method::FullAdam, Method::GaLore, Method::LoRa, Method::BAdam] {
        let mut cfg = TrainConfig::default();
        cfg.preset = preset.clone();
        cfg.task = Task::C4Pretrain;
        cfg.method = method;
        cfg.steps = 1_000_000; // schedule horizon; we drive steps manually
        cfg.sparsity = 0.95;
        cfg.cosine_lr = false;
        let mut tr = Trainer::new(&mut rt, cfg, None).expect("trainer");
        let (b, t) = tr.batch_shape();
        let mut stream = C4Sim::new(9);
        // pre-generate batches so data gen is outside the timed region
        let batches: Vec<_> = (0..12).map(|_| stream.next_batch(b, t)).collect();
        let mut i = 0;
        bench(&format!("train_step {preset} {}", method.name()), 3, 24, || {
            let batch = &batches[i % batches.len()];
            i += 1;
            tr.bench_step(batch).expect("step");
        });
    }
}
