//! Minimal statistics-reporting bench harness (criterion is not in the
//! offline crate set — DESIGN.md §8). Each bench binary is built with
//! `harness = false` and uses `bench()` to report median/p10/p90 over
//! timed iterations after warmup.

use std::time::Instant;

/// `--key value` CLI lookup shared by the bench binaries (each bench is a
/// separate bin including this module, so unused helpers are expected).
#[allow(dead_code)]
pub fn arg(name: &str) -> Option<String> {
    std::env::args().skip_while(|a| a != name).nth(1)
}

#[allow(dead_code)]
pub fn arg_usize(name: &str, default: usize) -> usize {
    arg(name).and_then(|v| v.parse().ok()).unwrap_or(default)
}

pub struct BenchResult {
    pub name: String,
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
    pub iters: usize,
}

/// Time `f` repeatedly: `warmup` throwaway runs, then `iters` timed runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<f64> = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q = |p: f64| samples[((samples.len() - 1) as f64 * p) as usize];
    let r = BenchResult {
        name: name.to_string(),
        median_ns: q(0.5),
        p10_ns: q(0.1),
        p90_ns: q(0.9),
        iters,
    };
    println!(
        "{:48} median {:>12}  p10 {:>12}  p90 {:>12}  ({} iters)",
        r.name,
        fmt_ns(r.median_ns),
        fmt_ns(r.p10_ns),
        fmt_ns(r.p90_ns),
        r.iters
    );
    r
}

/// Single-core calibration: time a fixed integer-arithmetic loop (an LCG
/// with a xor fold the optimizer cannot elide). The result normalizes
/// ms/step across machines, so a checked-in bench baseline from one host is
/// comparable on another: scale the baseline's numbers by
/// `calibrate_ms(now) / calibrate_ms(baseline)` before diffing.
#[allow(dead_code)]
pub fn calibrate_ms() -> f64 {
    let t0 = Instant::now();
    let mut x = 0x9E37_79B9_7F4A_7C15u64;
    let mut acc = 0u64;
    for _ in 0..30_000_000u64 {
        x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        acc ^= x >> 33;
    }
    std::hint::black_box(acc);
    t0.elapsed().as_secs_f64() * 1e3
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Throughput helper: elements processed per second at the median.
pub fn throughput(r: &BenchResult, elems: usize) -> String {
    let eps = elems as f64 / (r.median_ns / 1e9);
    if eps > 1e9 {
        format!("{:.2} Gelem/s", eps / 1e9)
    } else {
        format!("{:.2} Melem/s", eps / 1e6)
    }
}

/// Keep a value alive / opaque to the optimizer.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}
