//! Bench binary regenerating Fig. 5 (Alpaca-sim finetune: loss/memory/time
//! across BlockLLM, LoRA, BAdam, GaLore). `cargo bench` runs the quick
//! variant; pass `--full` for the tiny-preset run. Same harness as
//! `blockllm exp --id fig5` / examples/finetune_alpaca_sim.rs.
//!
//! Always produces numbers: the experiment harness resolves its execution
//! backend per run (PJRT with artifacts, pure-Rust native without) and each
//! run's table records which backend ran.

fn main() {
    let quick = !std::env::args().any(|a| a == "--full");
    if let Err(e) = blockllm::experiments::run("fig5", quick) {
        eprintln!("fig5 bench failed: {e:#}");
        std::process::exit(1);
    }
}
