//! Bench binary regenerating Table 1 (C4-sim pretraining ladder, BlockLLM
//! vs GaLore: perplexity + memory). `cargo bench` runs the quick ladder;
//! pass `--full` for the full one. Same harness as
//! `blockllm exp --id table1` / examples/pretrain_c4_sim.rs.

fn main() {
    let quick = !std::env::args().any(|a| a == "--full");
    if let Err(e) = blockllm::experiments::run("table1", quick) {
        eprintln!("table1 bench failed: {e:#} (did you run `make artifacts`?)");
    }
}
