//! Bench binary regenerating Table 1 (C4-sim pretraining ladder, BlockLLM
//! vs GaLore: perplexity + memory). `cargo bench` runs the quick ladder;
//! pass `--full` for the full one. Same harness as
//! `blockllm exp --id table1` / examples/pretrain_c4_sim.rs.
//!
//! Always produces numbers: the experiment harness resolves its execution
//! backend per run (PJRT with artifacts, pure-Rust native without) and each
//! run's table records which backend ran.

fn main() {
    let quick = !std::env::args().any(|a| a == "--full");
    if let Err(e) = blockllm::experiments::run("table1", quick) {
        eprintln!("table1 bench failed: {e:#}");
        std::process::exit(1);
    }
}
