//! Hand-rolled CLI argument parsing (no `clap` in the offline crate set).
//!
//! Grammar: `blockllm <command> [--key value]... [--flag]...`
//! Unknown keys are surfaced to the caller so `TrainConfig::set` can reject
//! typos loudly.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: String,
    pub kv: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(cmd) = it.peek() {
            if !cmd.starts_with("--") {
                out.command = it.next().expect("peeked").clone();
            }
        }
        while let Some(tok) = it.next() {
            let Some(key) = tok.strip_prefix("--") else {
                bail!("positional argument {tok:?} after command; use --key value");
            };
            match it.peek() {
                Some(v) if !v.starts_with("--") => {
                    out.kv.insert(key.to_string(), it.next().expect("peeked").clone());
                }
                _ => out.flags.push(key.to_string()),
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Self::parse(&argv)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.kv.get(key).map(String::as_str)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }
}

pub const USAGE: &str = "\
blockllm — BlockLLM (Ramesh et al., 2024) reproduction, Rust+JAX+Pallas

USAGE:
  blockllm train [--preset tiny] [--task c4|alpaca|glue-<t>] [--method blockllm|adam|galore|lora|badam]
                 [--backend auto|native|pjrt] [--steps N] [--s 0.95] [--m 100] [--lr 1e-3] [--seed 42]
                 [--suspend-at N --session path] ...
  blockllm resume --session path [--save ckpt]
  blockllm serve --spec path [--slice K] [--sched rr|slack|weighted] [--watch-spec path]
                 [--plan] [--out dir]
  blockllm exp --id <fig1|table1|table2|table3|table4|table5|fig3|fig5|fig6|fig7|fig9|table7|table8>
  blockllm exp --all [--quick]
  blockllm eval --ckpt path [--preset tiny] [--task c4]
  blockllm info                 # preset registry + artifact inventory
  blockllm help

Sessions: `train --suspend-at N --session PATH` stops after N optimizer
steps and writes ONE versioned checkpoint holding everything the run needs
to continue — config, step counter, optimizer moments, active masks,
scorer/patience state, data-stream cursors, rng positions, loss/eval
history, and every parameter tensor. `resume --session PATH` continues it:
the resumed run's remaining losses and final parameters are bit-for-bit
identical to a never-suspended run (the `train_loss_bits:` line printed by
both commands is the proof CI diffs). `resume` reads its config from the
checkpoint; config flags on the resume command line are ignored.
`serve --spec PATH` multiplexes many named sessions over one shared
backend, `--slice K` optimizer steps per turn (suspending and resuming at
every boundary). The spec is JSON: {\"slice_steps\": 8, \"sched\":
\"rr|slack|weighted\", \"total_budget_mb\": F, \"starvation_turns\": N,
\"sessions\": [{\"name\": ..., \"budget_mb\": ..., \"weight\": W,
\"deadline\": D, \"config\": {any TrainConfig key: value}}, ...]}; all
sessions must share one preset, task and backend kind. --sched (or the
spec's \"sched\" key; default rr) picks the turn order: `rr` is fair-share
round-robin; `slack` runs the tenant whose deadline slack (deadline minus
clock minus remaining steps, on the global clock of total optimizer steps)
is smallest, preempting the runner MID-slice as soon as a waiter's slack
drops strictly below its own (deadline-less tenants are protected by the
spec's starvation_turns aging bound, default 8); `weighted` gives each
tenant a step share proportional to its \"weight\" (stride scheduling,
also preemptive). Any interleaving is bitwise-safe: each tenant's losses
and final parameters are identical to its solo run regardless of policy,
preemption points, or eviction history.
A session with an explicit budget_mb is admitted only if the budget covers
its modeled footprint (weights + modeled gradient retention + modeled
optimizer state + activations) and is evicted if its MEASURED footprint
(the grads layer's peak gradient bytes) exceeds the budget. Sessions
without budget_mb share the spec-level total_budget_mb pool, split
weight-proportionally among live pool tenants and re-planned whenever the
roster changes: an evicted pool tenant is queued (checkpoint kept) and
automatically re-admitted once headroom frees up — shares grow as other
tenants finish. --watch-spec PATH re-reads a spec file between turns and
injects any session whose name is new into the RUNNING roster (a changed
total_budget_mb is adopted too; malformed updates are warned about and
ignored). --plan prints each tenant's modeled footprint and planned
budget, then exits without training. Per-tenant schedule summaries (turns,
steps, preemptions, evictions, re-admissions, deadline slack) are printed
and included in the --out JSON reports; evicted checkpoints are saved
under --out for later resume. `--out DIR` also writes one JSON report per
session.

Any TrainConfig key can be overridden with --key value (see config/mod.rs).
--backend selects the execution engine: `pjrt` runs the AOT HLO artifacts
(`make artifacts`), `native` runs the pure-Rust model engine, and `auto`
(default) prefers pjrt when artifacts exist, falling back to native.
--threads N (or the PALLAS_NUM_THREADS env var) pins the worker count of the
native engine's GEMM kernels and rowwise sweeps; default is all cores.
--pack-min N (or PALLAS_PACK_MIN) sets the minimum m*n*k before a GEMM runs
through the packed-panel SIMD microkernel instead of the direct kernels
(0 = always pack; default 32768); the batched attention GEMMs apply the
same threshold to their per-head shape. --par-min N (or PALLAS_PAR_MIN)
sets the minimum work size before kernels go multi-threaded (0 = always
parallel). --attn-batched {0|1} (or PALLAS_ATTN_BATCHED; default 1) selects
between the batched strided-GEMM attention path (one kernel call over all
batch*heads per contraction) and the legacy per-head loop.
--grad-stream {0|1} (or PALLAS_GRAD_STREAM; default 1) selects the gradient
retention path: 1 streams per-layer gradient shards into compact sinks so
BlockLLM/magnitude runs keep only the active block's coordinates (+ one
transient layer) instead of a full dense gradient table; 0 stages dense
gradients for every method — the legacy parity reference. Measured peak
gradient bytes are reported either way (MemTracker / results JSONL).
--pool {0|1} (or PALLAS_POOL; default 1) selects the kernel dispatch path:
1 runs parallel chunks on the process-wide persistent worker pool (workers
park between dispatches — no per-call thread spawn/join); 0 falls back to
the legacy scoped-thread spawn per dispatch. The row partition is fixed by
the thread-count knob either way, so both paths produce identical bits.
--replicas N (or PALLAS_REPLICAS; default 1) runs each optimizer step's
microbatches on N in-process data-parallel replicas of the native engine
(one thread each, round-robin microbatch ownership), all-reducing gradient
shards on the calling thread in a fixed ascending-microbatch order and
ZeRO-sharding the optimizer moments so per-replica state residency is
~1/N (reported as state_shard_bytes next to peak_grad_bytes). Backends
that cannot replicate (pjrt) fall back to the sequential path.
All seven are pure reproducibility-safe knobs: the packed and direct paths
agree bit for bit, batched and per-head attention agree bit for bit,
streaming and dense gradient retention agree bit for bit, pooled and
scoped dispatch agree bit for bit, replicated and sequential training
agree bit for bit at any replica count, and every kernel is deterministic
at any thread count.
--trace {0|1} (or PALLAS_TRACE; default 0) turns on the span profiler +
metrics registry: per-phase timings (fwd/bwd per sublayer, GEMM kernels,
pack time, sink consume, optimizer steps), kernel/FLOP/pack-byte counters,
and sink retention gauges. A profile table is printed on stderr at run end
and a `profile` block is appended to the run's JSONL. Tracing observes but
never steers: losses and parameter bits are identical with it on or off.
--trace-out PATH (implies --trace 1) additionally records every span as a
trace event and writes a chrome://tracing / Perfetto JSON file at exit.
Results are written to results/ as JSONL + printed tables.";

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_command_kv_flags() {
        let a = Args::parse(&sv(&["train", "--steps", "100", "--quick", "--lr", "1e-3"])).unwrap();
        assert_eq!(a.command, "train");
        assert_eq!(a.get("steps"), Some("100"));
        assert_eq!(a.get("lr"), Some("1e-3"));
        assert!(a.flag("quick"));
        assert!(!a.flag("slow"));
    }

    #[test]
    fn no_command_is_ok() {
        let a = Args::parse(&sv(&["--all"])).unwrap();
        assert_eq!(a.command, "");
        assert!(a.flag("all"));
    }

    #[test]
    fn rejects_stray_positional() {
        assert!(Args::parse(&sv(&["train", "oops"])).is_err());
    }

    #[test]
    fn negative_numbers_are_values() {
        let a = Args::parse(&sv(&["train", "--lr", "-3"])).unwrap();
        // "-3" does not start with "--" so it is a value
        assert_eq!(a.get("lr"), Some("-3"));
    }

    #[test]
    fn helpers() {
        let a = Args::parse(&sv(&["x", "--n", "5"])).unwrap();
        assert_eq!(a.usize_or("n", 1).unwrap(), 5);
        assert_eq!(a.usize_or("missing", 7).unwrap(), 7);
        assert_eq!(a.get_or("missing", "d"), "d");
        assert!(a.usize_or("n", 1).is_ok());
    }
}
