//! The pure-Rust reference execution engine.
//!
//! Implements the L2 model (python/compile/model.py) — token embedding, N x
//! [RMSNorm -> RoPE causal attention -> residual -> RMSNorm -> SwiGLU ->
//! residual], final RMSNorm, lm/cls/reg head — forward AND hand-derived
//! backward, on top of `tensor::Tensor`. Parameter shapes and order mirror
//! `Preset::param_specs`, so `ParamStore` works unchanged against either
//! backend.
//!
//! Correctness provenance: python/tests/test_native_mirror.py holds a
//! line-for-line numpy mirror of this file asserted against
//! jax.value_and_grad on every head; rust/tests/grad_check.rs
//! finite-difference-checks this implementation directly (including
//! thread-count invariance), and rust/tests/native_golden.rs pins the
//! deterministic-filler losses against JAX-computed golden values.
//!
//! Performance: every matmul runs on the packed-panel microkernel GEMM
//! layer in `linalg::gemm`; parameters are read through borrowed
//! `tensor::View`s straight out of the `ParamStore` (the pass allocates only
//! activations). Attention — QKᵀ scores, probs·V, and all four backward
//! contractions — runs as ONE batched strided GEMM per contraction over all
//! b·h heads (`linalg::gemm_batched`; head operands are `BatchView` column
//! slices of the interleaved activations, zero gather copies, threads
//! scheduled across the whole (batch·head × row) grid). The legacy
//! per-head `gemm::parallel_map` fan-out is kept behind
//! `PALLAS_ATTN_BATCHED=0` / `--attn-batched 0` as the bitwise-identical
//! parity reference. The formerly-serial rowwise sweeps — rmsnorm fwd/bwd,
//! rope, attention softmax, embedding gather/scatter, and the LM-head
//! loss/softmax sweep — are row-partitioned the same way; cross-row
//! reductions (rmsnorm's dγ, the LM loss sum, the embedding scatter) use
//! thread-count-INDEPENDENT grouping (fixed row blocks / destination-row
//! ownership), so the whole fwd/bwd stays bit-for-bit deterministic at any
//! `PALLAS_NUM_THREADS` setting.

use anyhow::{bail, Result};

use super::{EvalOut, Targets};
use crate::config::presets::{self, Preset};
use crate::config::TrainConfig;
use crate::grads::GradSink;
use crate::linalg::{gemm, gemm_batched};
use crate::model::ParamStore;
use crate::obs::{self, Span};
use crate::runtime::ParamSpec;
use crate::tensor::{BatchView, Tensor, View};
use crate::util::{self, pool};

const RMS_EPS: f32 = 1e-6;

/// Fixed row-block size for parallel reductions (rmsnorm's dγ, the LM-head
/// loss/count sums): partial sums are grouped by these CONSTANT blocks and
/// combined in block order, so the reduction tree never depends on the
/// thread count.
const REDUCE_ROWS: usize = 64;

/// Pure-Rust model engine for one (preset, head, batch-shape).
pub struct NativeBackend {
    preset: Preset,
    head: &'static str,
    n_out: usize,
    specs: Vec<ParamSpec>,
    batch: usize,
    seq: usize,
    /// rope tables [seq * d_head/2]
    cos: Vec<f32>,
    sin: Vec<f32>,
    act_bytes: u64,
    exec_secs: f64,
    exec_calls: u64,
}

impl NativeBackend {
    /// Engine for a config's preset+task head at the preset's default batch
    /// shape (the same shapes aot.py lowers: lm (8,64), cls/reg (16,32)).
    pub fn new(cfg: &TrainConfig, head: &str, n_out: usize) -> Result<NativeBackend> {
        let preset = match presets::get(&cfg.preset) {
            Some(p) => *p,
            None => bail!("unknown preset {:?}", cfg.preset),
        };
        let (b, t) = if head == "lm" { preset.lm_batch() } else { preset.cls_batch() };
        Self::with_shape(&cfg.preset, head, n_out, b, t)
    }

    /// Engine with an explicit batch shape (tests use small b/t).
    pub fn with_shape(
        preset: &str,
        head: &str,
        n_out: usize,
        batch: usize,
        seq: usize,
    ) -> Result<NativeBackend> {
        let preset = match presets::get(preset) {
            Some(p) => *p,
            None => bail!("unknown preset {preset:?}"),
        };
        let head: &'static str = match head {
            "lm" => "lm",
            "cls" => "cls",
            "reg" => "reg",
            other => bail!("unknown head {other:?}"),
        };
        let n_out = if head == "reg" { 1 } else { n_out.max(1) };
        if seq > preset.max_seq {
            bail!("seq {seq} exceeds preset max_seq {}", preset.max_seq);
        }
        let specs = preset.param_specs(head, n_out);
        let (cos, sin) = rope_tables(seq, preset.d_head());
        let act_bytes = model_activation_bytes(&preset, head, n_out, batch, seq);
        Ok(NativeBackend {
            preset,
            head,
            n_out,
            specs,
            batch,
            seq,
            cos,
            sin,
            act_bytes,
            exec_secs: 0.0,
            exec_calls: 0,
        })
    }

    /// Borrow a parameter tensor out of the store by spec index (zero-copy;
    /// the old per-use clone was the native engine's biggest waste).
    fn paramv<'s>(&self, store: &'s ParamStore, idx: usize) -> View<'s> {
        View::new(&self.specs[idx].shape, &store.bufs[idx])
    }

    fn tok_indices(&self, tokens: &[i32]) -> Result<Vec<usize>> {
        let n = self.batch * self.seq;
        if tokens.len() != n {
            bail!("tokens len {} != b*t {}", tokens.len(), n);
        }
        let v = self.preset.vocab as i32;
        tokens
            .iter()
            .map(|&x| {
                if x < 0 || x >= v {
                    bail!("token {x} outside vocab {v}");
                }
                Ok(x as usize)
            })
            .collect()
    }

    /// The targets variant must match the head this engine was built for
    /// (a mismatch would otherwise index past the spec table).
    fn check_targets(&self, targets: &Targets<'_>) -> Result<()> {
        let ok = matches!(
            (self.head, targets),
            ("lm", Targets::Lm(_)) | ("cls", Targets::Cls(_)) | ("reg", Targets::Reg(_))
        );
        if !ok {
            bail!("targets kind does not match model head {:?}", self.head);
        }
        Ok(())
    }

    // spec-table index helpers (order fixed by Preset::param_specs)
    fn idx_layer(&self, layer: usize, off: usize) -> usize {
        1 + layer * 9 + off
    }
    fn numel(&self, idx: usize) -> usize {
        self.specs[idx].numel()
    }
    fn idx_final_norm(&self) -> usize {
        1 + self.preset.n_layers * 9
    }
    fn idx_head(&self) -> usize {
        self.idx_final_norm() + 1
    }
    fn idx_bias(&self) -> usize {
        self.idx_final_norm() + 2
    }

    /// Full trunk forward. Returns (xf, rf, final_x, caches); caches are
    /// only built when `want_grads` (eval skips them).
    fn trunk_forward(
        &self,
        store: &ParamStore,
        tok_idx: &[usize],
        want_grads: bool,
    ) -> (Tensor, Vec<f32>, Tensor, Vec<LayerCache>) {
        let (b, t) = (self.batch, self.seq);
        let h = self.preset.n_heads;
        let dh = self.preset.d_head();
        let scale = 1.0 / (dh as f32).sqrt();
        let sp_embed = obs::span(Span::FwdEmbed);
        let mut x = self.paramv(store, 0).gather_rows(tok_idx); // [N, D]
        drop(sp_embed);
        let mut caches = Vec::with_capacity(if want_grads { self.preset.n_layers } else { 0 });
        for layer in 0..self.preset.n_layers {
            let attn_norm = &store.bufs[self.idx_layer(layer, 0)];
            let wq = self.paramv(store, self.idx_layer(layer, 1));
            let wk = self.paramv(store, self.idx_layer(layer, 2));
            let wv = self.paramv(store, self.idx_layer(layer, 3));
            let wo = self.paramv(store, self.idx_layer(layer, 4));
            let mlp_norm = &store.bufs[self.idx_layer(layer, 5)];
            let w_gate = self.paramv(store, self.idx_layer(layer, 6));
            let w_up = self.paramv(store, self.idx_layer(layer, 7));
            let w_down = self.paramv(store, self.idx_layer(layer, 8));

            // -- attention sublayer
            let sp_attn = obs::span(Span::FwdAttn);
            let (ha, ra) = rmsnorm_fwd(&x, attn_norm);
            let mut q = ha.matmul(&wq);
            let mut k = ha.matmul(&wk);
            let v = ha.matmul(&wv);
            rope_apply(&mut q, t, h, dh, &self.cos, &self.sin, false);
            rope_apply(&mut k, t, h, dh, &self.cos, &self.sin, false);
            // attention core. Batched path (default): QKᵀ scores and
            // probs·V for ALL b·h heads in one strided batched GEMM each —
            // head operands are read in place out of the interleaved
            // activations, and threads schedule across the whole
            // (batch·head × row) grid. The per-head fan-out below it is the
            // bitwise-identical legacy reference (`--attn-batched 0`).
            let bh = b * h;
            let mut scores = Tensor::zeros(&[bh * t, t]); // head-dense probs
            let ctx = if util::attn_batched() {
                let threads = util::num_threads();
                gemm_batched::gemm_batched_nt(
                    &BatchView::heads(&q, b, t, h, dh),
                    &BatchView::heads(&k, b, t, h, dh),
                    &mut scores.data,
                    false,
                    threads,
                );
                mask_scale_causal(&mut scores, t, scale, threads);
                scores.softmax_rows_threads(threads);
                let mut ctx_heads = Tensor::zeros(&[bh * t, dh]);
                gemm_batched::gemm_batched_nn(
                    &BatchView::dense(&scores.data, bh, t, t),
                    &BatchView::heads(&v, b, t, h, dh),
                    &mut ctx_heads.data,
                    false,
                    threads,
                );
                interleave_heads(&ctx_heads, b, t, h, dh) // [N, d]
            } else {
                // fan the (batch, head) pairs out across threads; each
                // head's inner GEMMs + per-row softmax get the leftover
                // thread budget (1 when heads >= workers)
                let inner = inner_threads(bh);
                let heads = gemm::parallel_map(bh, |i| {
                    let (bi, hi) = (i / h, i % h);
                    let qh = head_slice(&q, bi, t, hi, dh);
                    let kh = head_slice(&k, bi, t, hi, dh);
                    let vh = head_slice(&v, bi, t, hi, dh);
                    let mut s = gemm::matmul_nt_threads(&qh, &kh, inner); // [t, t]
                    mask_scale_causal(&mut s, t, scale, 1);
                    s.softmax_rows_threads(inner);
                    let ctx_h = gemm::matmul_threads(&s, &vh, inner); // [t, dh]
                    (s, ctx_h)
                });
                let mut ctx = Tensor::zeros(&[b * t, h * dh]);
                for (i, (s, ctx_h)) in heads.into_iter().enumerate() {
                    let (bi, hi) = (i / h, i % h);
                    scores.data[i * t * t..(i + 1) * t * t].copy_from_slice(&s.data);
                    write_head_slice(&mut ctx, bi, t, hi, dh, &ctx_h);
                }
                ctx
            };
            let x1 = {
                let mut out = ctx.matmul(&wo);
                out.axpy(1.0, &x); // residual
                out
            };
            drop(sp_attn);

            // -- mlp sublayer
            let sp_mlp = obs::span(Span::FwdMlp);
            let (hm, rm) = rmsnorm_fwd(&x1, mlp_norm);
            let g = hm.matmul(&w_gate); // [N, ff]
            let u = hm.matmul(&w_up);
            let prod = gemm::silu_mul(&g, &u); // silu(g) * u
            let x2 = {
                let mut out = prod.matmul(&w_down);
                out.axpy(1.0, &x1); // residual
                out
            };
            drop(sp_mlp);
            if want_grads {
                caches.push(LayerCache {
                    x0: x,
                    ha,
                    ra,
                    q,
                    k,
                    v,
                    probs: scores,
                    ctx,
                    x1,
                    hm,
                    rm,
                    g,
                    u,
                    prod,
                });
            }
            x = x2;
        }
        let final_norm = &store.bufs[self.idx_final_norm()];
        let (xf, rf) = rmsnorm_fwd(&x, final_norm);
        (xf, rf, x, caches)
    }

    /// Backward through the trunk given d(loss)/d(xf). Emits each
    /// parameter's finalized gradient shard through `em` the moment it is
    /// complete — reverse-layer order, one shard per spec-table entry —
    /// so at most one dense weight-gradient (the emitter's reused scratch)
    /// is ever live inside the engine.
    #[allow(clippy::too_many_arguments)]
    fn trunk_backward(
        &self,
        store: &ParamStore,
        tok_idx: &[usize],
        dxf: &Tensor,
        rf: &[f32],
        final_x: &Tensor,
        caches: &[LayerCache],
        em: &mut ShardEmitter<'_>,
    ) {
        let (b, t) = (self.batch, self.seq);
        let (d, h) = (self.preset.d_model, self.preset.n_heads);
        let dh = self.preset.d_head();
        let scale = 1.0 / (dh as f32).sqrt();

        let final_norm = &store.bufs[self.idx_final_norm()];
        let ifn = self.idx_final_norm();
        let mut dx = {
            let (dx, dg) = rmsnorm_bwd(dxf, final_x, final_norm, rf);
            em.emit_slice(ifn, &dg);
            dx
        };

        for layer in (0..self.preset.n_layers).rev() {
            let c = &caches[layer];
            let wq = self.paramv(store, self.idx_layer(layer, 1));
            let wk = self.paramv(store, self.idx_layer(layer, 2));
            let wv = self.paramv(store, self.idx_layer(layer, 3));
            let wo = self.paramv(store, self.idx_layer(layer, 4));
            let w_gate = self.paramv(store, self.idx_layer(layer, 6));
            let w_up = self.paramv(store, self.idx_layer(layer, 7));
            let w_down = self.paramv(store, self.idx_layer(layer, 8));

            // -- mlp sublayer: x2 = x1 + prod @ w_down
            let sp_mlp = obs::span(Span::BwdMlp);
            let dprod = dx.matmul_nt(&w_down); // [N, ff]
            gemm::matmul_tn_acc(em.zeroed(self.numel(self.idx_layer(layer, 8))), &c.prod, &dx);
            em.emit(self.idx_layer(layer, 8));
            let (dg_t, du_t) = gemm::silu_mul_vjp(&dprod, &c.g, &c.u);
            gemm::matmul_tn_acc(em.zeroed(self.numel(self.idx_layer(layer, 7))), &c.hm, &du_t);
            em.emit(self.idx_layer(layer, 7));
            gemm::matmul_tn_acc(em.zeroed(self.numel(self.idx_layer(layer, 6))), &c.hm, &dg_t);
            em.emit(self.idx_layer(layer, 6));
            let mut dhm = dg_t.matmul_nt(&w_gate); // [N, d]
            gemm::matmul_nt_acc(&mut dhm, &du_t, &w_up);
            let mlp_norm = &store.bufs[self.idx_layer(layer, 5)];
            let (dx1_norm, dgm) = rmsnorm_bwd(&dhm, &c.x1, mlp_norm, &c.rm);
            em.emit_slice(self.idx_layer(layer, 5), &dgm);
            dx.axpy(1.0, &dx1_norm); // + residual path
            drop(sp_mlp);

            // -- attention sublayer: x1 = x0 + ctx @ wo
            let sp_attn = obs::span(Span::BwdAttn);
            let dctx = dx.matmul_nt(&wo); // [N, d]
            gemm::matmul_tn_acc(em.zeroed(self.numel(self.idx_layer(layer, 4))), &c.ctx, &dx);
            em.emit(self.idx_layer(layer, 4));
            let bh = b * h;
            let (mut dq, mut dk, dv) = if util::attn_batched() {
                // all four contractions over all b·h heads, one batched
                // strided GEMM each: dV = PᵀdO, dP = dO·Vᵀ, then the
                // rowwise softmax VJP, dQ = scale·(dS·K), dK = scale·(dSᵀ·Q)
                let threads = util::num_threads();
                let pv = BatchView::dense(&c.probs.data, bh, t, t);
                let dov = BatchView::heads(&dctx, b, t, h, dh);
                let vv = BatchView::heads(&c.v, b, t, h, dh);
                let qv = BatchView::heads(&c.q, b, t, h, dh);
                let kv = BatchView::heads(&c.k, b, t, h, dh);
                let dv_heads = gemm_batched::matmul_batched_tn(&pv, &dov, threads);
                let dp = gemm_batched::matmul_batched_nt(&dov, &vv, threads);
                let ds = softmax_rows_bwd(&c.probs, &dp);
                let dsv = BatchView::dense(&ds.data, bh, t, t);
                let mut dq_heads = gemm_batched::matmul_batched_nn(&dsv, &kv, threads);
                dq_heads.scale(scale);
                let mut dk_heads = gemm_batched::matmul_batched_tn(&dsv, &qv, threads);
                dk_heads.scale(scale);
                (
                    interleave_heads(&dq_heads, b, t, h, dh),
                    interleave_heads(&dk_heads, b, t, h, dh),
                    interleave_heads(&dv_heads, b, t, h, dh),
                )
            } else {
                // legacy per-head fan-out (bitwise-identical reference)
                let inner = inner_threads(bh);
                let heads = gemm::parallel_map(bh, |i| {
                    let (bi, hi) = (i / h, i % h);
                    let pr = View::new(&[t, t], &c.probs.data[i * t * t..(i + 1) * t * t]);
                    let do_h = head_slice(&dctx, bi, t, hi, dh);
                    let vh = head_slice(&c.v, bi, t, hi, dh);
                    let qh = head_slice(&c.q, bi, t, hi, dh);
                    let kh = head_slice(&c.k, bi, t, hi, dh);
                    let dv_h = gemm::matmul_tn_threads(&pr, &do_h, inner); // P^T dO
                    let dp = gemm::matmul_nt_threads(&do_h, &vh, inner); // dO V^T  [t, t]
                    let ds = softmax_rows_bwd_slice(pr.data, &dp.data, t, t, 1);
                    let mut dq_h = gemm::matmul_threads(&ds, &kh, inner); // [t, dh]
                    dq_h.scale(scale);
                    let mut dk_h = gemm::matmul_tn_threads(&ds, &qh, inner); // dS^T Q
                    dk_h.scale(scale);
                    (dq_h, dk_h, dv_h)
                });
                let mut dq = Tensor::zeros(&[b * t, d]);
                let mut dk = Tensor::zeros(&[b * t, d]);
                let mut dv = Tensor::zeros(&[b * t, d]);
                for (i, (dq_h, dk_h, dv_h)) in heads.into_iter().enumerate() {
                    let (bi, hi) = (i / h, i % h);
                    write_head_slice(&mut dq, bi, t, hi, dh, &dq_h);
                    write_head_slice(&mut dk, bi, t, hi, dh, &dk_h);
                    write_head_slice(&mut dv, bi, t, hi, dh, &dv_h);
                }
                (dq, dk, dv)
            };
            // undo rope (orthogonal rotation: backward = inverse rotation)
            rope_apply(&mut dq, t, h, dh, &self.cos, &self.sin, true);
            rope_apply(&mut dk, t, h, dh, &self.cos, &self.sin, true);
            gemm::matmul_tn_acc(em.zeroed(self.numel(self.idx_layer(layer, 1))), &c.ha, &dq);
            em.emit(self.idx_layer(layer, 1));
            gemm::matmul_tn_acc(em.zeroed(self.numel(self.idx_layer(layer, 2))), &c.ha, &dk);
            em.emit(self.idx_layer(layer, 2));
            gemm::matmul_tn_acc(em.zeroed(self.numel(self.idx_layer(layer, 3))), &c.ha, &dv);
            em.emit(self.idx_layer(layer, 3));
            let mut dha = dq.matmul_nt(&wq);
            gemm::matmul_nt_acc(&mut dha, &dk, &wk);
            gemm::matmul_nt_acc(&mut dha, &dv, &wv);
            let attn_norm = &store.bufs[self.idx_layer(layer, 0)];
            let (dx0_norm, dga) = rmsnorm_bwd(&dha, &c.x0, attn_norm, &c.ra);
            em.emit_slice(self.idx_layer(layer, 0), &dga);
            dx.axpy(1.0, &dx0_norm);
            drop(sp_attn);
        }

        // embedding scatter-add: wrap the emitter's zeroed scratch as a
        // [vocab, d] tensor (zero-copy via take/restore), scatter dx's rows
        // into it, and emit it as the final shard of the pass
        let _sp_embed = obs::span(Span::BwdEmbed);
        let mut demb = Tensor {
            shape: vec![self.preset.vocab, d],
            data: em.take_zeroed(self.preset.vocab * d),
        };
        demb.scatter_rows_add(tok_idx, &dx);
        em.restore_and_emit(0, demb.data);
    }

    /// LM loss + dlogits. `logits` is consumed and overwritten with dloss/
    /// dlogits. Returns (loss_sum, valid_count).
    ///
    /// Rows are independent (per-row log-sum-exp + softmax), so the sweep —
    /// formerly the last serial slice of the lm path — row-partitions
    /// across threads. The cross-row loss/count sums are grouped by FIXED
    /// `REDUCE_ROWS` blocks (the rmsnorm-dγ pattern): thread chunks split
    /// at block boundaries and the per-block partials are combined in block
    /// order, so the reduction tree — and therefore the loss bits — never
    /// depends on the thread count.
    fn lm_loss_grad(&self, logits: &mut Tensor, targets: &[i32], want_grad: bool) -> (f64, f64) {
        let v = self.preset.vocab;
        let rows = targets.len();
        debug_assert_eq!(logits.data.len(), rows * v);
        let nblocks = rows.div_ceil(REDUCE_ROWS).max(1);
        let threads = if logits.numel() < util::par_min_elems() {
            1
        } else {
            util::num_threads().min(nblocks)
        };
        let mut parts = vec![(0.0f64, 0.0f64); nblocks];
        if threads <= 1 {
            lm_loss_blocks(&mut logits.data, targets, v, want_grad, &mut parts);
        } else {
            // contiguous BLOCK ranges per chunk (blocks, not raw rows, so
            // every fixed block is computed whole by exactly one thread),
            // dispatched onto the persistent pool like every other sweep
            let chunks = gemm::split_rows(nblocks, threads);
            let logits_base = pool::SendPtr(logits.data.as_mut_ptr());
            let parts_base = pool::SendPtr(parts.as_mut_ptr());
            pool::run(chunks.len(), &|ci| {
                let (c0, c1) = chunks[ci];
                let r0 = c0 * REDUCE_ROWS;
                let r1 = (c1 * REDUCE_ROWS).min(rows);
                // SAFETY: chunks are disjoint block ranges, so the logits
                // row slices and `parts` slices never alias; `pool::run`
                // joins before returning.
                let rh = unsafe {
                    std::slice::from_raw_parts_mut(logits_base.0.add(r0 * v), (r1 - r0) * v)
                };
                let ph =
                    unsafe { std::slice::from_raw_parts_mut(parts_base.0.add(c0), c1 - c0) };
                lm_loss_blocks(rh, &targets[r0..r1], v, want_grad, ph);
            });
        }
        let mut loss_sum = 0.0f64;
        let mut count = 0.0f64;
        for &(l, c) in &parts {
            loss_sum += l;
            count += c;
        }
        (loss_sum, count)
    }
}

/// One thread's span of the LM-head loss sweep: `rows_data` holds the
/// logits rows for `tgts` (the span starts on a `REDUCE_ROWS` boundary),
/// and `parts` receives one (loss_sum, count) partial per fixed block, each
/// accumulated in ascending row order. Rows with a negative target are
/// ignored (the Alpaca-sim prefix mask); out-of-vocab would be a data bug —
/// treated as ignored rather than a panic. With `want_grad`, each live row
/// is overwritten with softmax(row); the -1 at the target is applied by the
/// caller once it knows the final 1/count scale.
fn lm_loss_blocks(
    rows_data: &mut [f32],
    tgts: &[i32],
    v: usize,
    want_grad: bool,
    parts: &mut [(f64, f64)],
) {
    let nrows = tgts.len();
    debug_assert_eq!(rows_data.len(), nrows * v);
    debug_assert_eq!(parts.len(), nrows.div_ceil(REDUCE_ROWS).max(1));
    for (pbi, part) in parts.iter_mut().enumerate() {
        let l0 = pbi * REDUCE_ROWS;
        let l1 = ((pbi + 1) * REDUCE_ROWS).min(nrows);
        let mut loss = 0.0f64;
        let mut count = 0.0f64;
        for li in l0..l1 {
            let r = &mut rows_data[li * v..(li + 1) * v];
            let tgt = tgts[li];
            if tgt < 0 || tgt as usize >= v {
                if want_grad {
                    r.fill(0.0);
                }
                continue;
            }
            let m = r.iter().fold(f32::NEG_INFINITY, |a, &x| a.max(x));
            let mut sum = 0.0f64;
            for &x in r.iter() {
                sum += ((x - m) as f64).exp();
            }
            let lse = m as f64 + sum.ln();
            loss += lse - r[tgt as usize] as f64;
            count += 1.0;
            if want_grad {
                for x in r.iter_mut() {
                    *x = ((*x as f64 - lse).exp()) as f32;
                }
            }
        }
        *part = (loss, count);
    }
}

/// Emits finalized gradient shards into a [`GradSink`] through ONE reused
/// scratch buffer — the engine-side half of the streaming grad contract.
/// GEMM-produced weight gradients are accumulated into `zeroed(n)` scratch
/// (identical arithmetic to the old zeroed dense buffers) and handed to the
/// sink with `emit`; reduction outputs that already own their buffer
/// (rmsnorm dγ, the cls bias) go out directly via `emit_slice`. The scratch
/// grows once to the largest tensor and is reused for every later shard, so
/// the engine's dense-gradient residency is exactly one largest-tensor
/// buffer.
struct ShardEmitter<'s> {
    sink: &'s mut dyn GradSink,
    scratch: Vec<f32>,
}

impl ShardEmitter<'_> {
    /// Zeroed scratch of length `n` for the next shard's accumulation.
    fn zeroed(&mut self, n: usize) -> &mut [f32] {
        self.scratch.clear();
        self.scratch.resize(n, 0.0);
        &mut self.scratch
    }

    /// Hand the current scratch contents to the sink as shard `idx`.
    fn emit(&mut self, idx: usize) {
        self.sink.consume(idx, &self.scratch);
    }

    /// Emit a shard the caller already owns (no scratch staging).
    fn emit_slice(&mut self, idx: usize, data: &[f32]) {
        self.sink.consume(idx, data);
    }

    /// Take the zeroed scratch by value (the embedding scatter wraps it in
    /// a `Tensor`); pair with [`Self::restore_and_emit`].
    fn take_zeroed(&mut self, n: usize) -> Vec<f32> {
        self.zeroed(n);
        std::mem::take(&mut self.scratch)
    }

    fn restore_and_emit(&mut self, idx: usize, data: Vec<f32>) {
        self.scratch = data;
        self.emit(idx);
    }
}

/// Per-layer forward activations kept for the backward pass.
struct LayerCache {
    x0: Tensor,
    ha: Tensor,
    ra: Vec<f32>,
    q: Tensor,
    k: Tensor,
    v: Tensor,
    /// softmaxed attention probabilities, head-dense [b*h*t, t]
    probs: Tensor,
    ctx: Tensor,
    x1: Tensor,
    hm: Tensor,
    rm: Vec<f32>,
    g: Tensor,
    u: Tensor,
    prod: Tensor,
}

impl super::Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn param_specs(&self) -> &[ParamSpec] {
        &self.specs
    }

    fn batch_shape(&self) -> (usize, usize) {
        (self.batch, self.seq)
    }

    fn forward_backward(
        &mut self,
        store: &ParamStore,
        tokens: &[i32],
        targets: Targets<'_>,
        sink: &mut dyn GradSink,
    ) -> Result<f64> {
        let t0 = std::time::Instant::now();
        self.check_targets(&targets)?;
        let tok_idx = self.tok_indices(tokens)?;
        let (b, t) = (self.batch, self.seq);
        let d = self.preset.d_model;
        let (xf, rf, final_x, caches) = self.trunk_forward(store, &tok_idx, true);
        let mut em = ShardEmitter { sink, scratch: Vec::new() };

        let loss = match targets {
            Targets::Lm(tgts) => {
                if tgts.len() != b * t {
                    bail!("lm targets len {} != b*t {}", tgts.len(), b * t);
                }
                let sp_head = obs::span(Span::FwdHeadLoss);
                let lm_head = self.paramv(store, self.idx_head()); // [d, v]
                let mut logits = xf.matmul(&lm_head); // [N, v]
                let (loss_sum, count) = self.lm_loss_grad(&mut logits, tgts, true);
                let count = count.max(1.0);
                // finish dlogits: (p - onehot) / count
                let inv = (1.0 / count) as f32;
                let v = self.preset.vocab;
                for (row, &tgt) in tgts.iter().enumerate() {
                    if tgt >= 0 && (tgt as usize) < v {
                        logits.data[row * v + tgt as usize] -= 1.0;
                    }
                }
                logits.scale(inv);
                drop(sp_head);
                let sp_bwd = obs::span(Span::BwdHead);
                gemm::matmul_tn_acc(em.zeroed(self.numel(self.idx_head())), &xf, &logits);
                em.emit(self.idx_head());
                let dxf = logits.matmul_nt(&lm_head); // [N, d]
                drop(sp_bwd);
                self.trunk_backward(store, &tok_idx, &dxf, &rf, &final_x, &caches, &mut em);
                loss_sum / count
            }
            Targets::Cls(_) | Targets::Reg(_) => {
                let (labels_i, labels_f): (&[i32], &[f32]) = match targets {
                    Targets::Cls(l) => (l, &[]),
                    Targets::Reg(l) => (&[], l),
                    Targets::Lm(_) => unreachable!(),
                };
                let regression = matches!(targets, Targets::Reg(_));
                let n_lab = if regression { labels_f.len() } else { labels_i.len() };
                if n_lab != b {
                    bail!("labels len {n_lab} != batch {b}");
                }
                let sp_head = obs::span(Span::FwdHeadLoss);
                // pooled = mean over T of xf
                let mut pooled = Tensor::zeros(&[b, d]);
                for bi in 0..b {
                    for ti in 0..t {
                        let src = &xf.data[(bi * t + ti) * d..(bi * t + ti + 1) * d];
                        let dst = &mut pooled.data[bi * d..(bi + 1) * d];
                        for (a, s) in dst.iter_mut().zip(src) {
                            *a += s;
                        }
                    }
                }
                pooled.scale(1.0 / t as f32);
                let w = self.paramv(store, self.idx_head()); // [d, n_out]
                let bias = &store.bufs[self.idx_bias()];
                // fused bias epilogue: logits = pooled @ w + bias
                let logits = gemm::matmul_bias(&pooled, &w, bias);
                let (loss, dlogits) = if regression {
                    let mut dl = Tensor::zeros(&[b, 1]);
                    let mut loss = 0.0f64;
                    for bi in 0..b {
                        let e = logits.data[bi * self.n_out] - labels_f[bi];
                        loss += (e as f64) * (e as f64);
                        dl.data[bi] = 2.0 * e / b as f32;
                    }
                    (loss / b as f64, dl)
                } else {
                    let mut dl = logits.clone();
                    let mut loss = 0.0f64;
                    let no = self.n_out;
                    for bi in 0..b {
                        let r = &mut dl.data[bi * no..(bi + 1) * no];
                        let m = r.iter().fold(f32::NEG_INFINITY, |a, &x| a.max(x));
                        let mut sum = 0.0f64;
                        for &x in r.iter() {
                            sum += ((x - m) as f64).exp();
                        }
                        let lse = m as f64 + sum.ln();
                        let lab = labels_i[bi];
                        if lab < 0 || lab as usize >= no {
                            // out-of-range label: contributes nothing
                            r.fill(0.0);
                            continue;
                        }
                        loss += lse - r[lab as usize] as f64;
                        for x in r.iter_mut() {
                            *x = ((*x as f64 - lse).exp()) as f32;
                        }
                        r[lab as usize] -= 1.0;
                    }
                    let mut dl2 = dl;
                    dl2.scale(1.0 / b as f32);
                    (loss / b as f64, dl2)
                };
                drop(sp_head);
                let sp_bwd = obs::span(Span::BwdHead);
                gemm::matmul_tn_acc(em.zeroed(self.numel(self.idx_head())), &pooled, &dlogits);
                em.emit(self.idx_head());
                let mut dbias = vec![0.0f32; self.specs[self.idx_bias()].numel()];
                for bi in 0..b {
                    for j in 0..dlogits.cols() {
                        dbias[j] += dlogits.data[bi * dlogits.cols() + j];
                    }
                }
                em.emit_slice(self.idx_bias(), &dbias);
                let dpooled = dlogits.matmul_nt(&w); // [b, d]
                // dxf[bi, ti, :] = dpooled[bi, :] / t
                let mut dxf = Tensor::zeros(&[b * t, d]);
                let invt = 1.0 / t as f32;
                for bi in 0..b {
                    let src = &dpooled.data[bi * d..(bi + 1) * d];
                    for ti in 0..t {
                        let dst = &mut dxf.data[(bi * t + ti) * d..(bi * t + ti + 1) * d];
                        for (a, s) in dst.iter_mut().zip(src) {
                            *a = s * invt;
                        }
                    }
                }
                drop(sp_bwd);
                self.trunk_backward(store, &tok_idx, &dxf, &rf, &final_x, &caches, &mut em);
                loss
            }
        };
        self.exec_secs += t0.elapsed().as_secs_f64();
        self.exec_calls += 1;
        Ok(loss)
    }

    fn eval_batch(
        &mut self,
        store: &ParamStore,
        tokens: &[i32],
        targets: Targets<'_>,
    ) -> Result<EvalOut> {
        let t0 = std::time::Instant::now();
        let _sp = obs::span(Span::Eval);
        self.check_targets(&targets)?;
        let tok_idx = self.tok_indices(tokens)?;
        let (b, t) = (self.batch, self.seq);
        let d = self.preset.d_model;
        let (xf, _rf, _final_x, _caches) = self.trunk_forward(store, &tok_idx, false);
        let out = match targets {
            Targets::Lm(tgts) => {
                if tgts.len() != b * t {
                    bail!("lm targets len {} != b*t {}", tgts.len(), b * t);
                }
                let lm_head = self.paramv(store, self.idx_head());
                let mut logits = xf.matmul(&lm_head);
                let (loss_sum, count) = self.lm_loss_grad(&mut logits, tgts, false);
                EvalOut { loss_sum, aux: count, preds: Vec::new() }
            }
            Targets::Cls(_) | Targets::Reg(_) => {
                let mut pooled = Tensor::zeros(&[b, d]);
                for bi in 0..b {
                    for ti in 0..t {
                        let src = &xf.data[(bi * t + ti) * d..(bi * t + ti + 1) * d];
                        let dst = &mut pooled.data[bi * d..(bi + 1) * d];
                        for (a, s) in dst.iter_mut().zip(src) {
                            *a += s;
                        }
                    }
                }
                pooled.scale(1.0 / t as f32);
                let w = self.paramv(store, self.idx_head());
                let bias = &store.bufs[self.idx_bias()];
                let logits = gemm::matmul_bias(&pooled, &w, bias);
                let no = self.n_out;
                match targets {
                    Targets::Reg(labels) => {
                        if labels.len() != b {
                            bail!("reg labels len {} != batch {b}", labels.len());
                        }
                        let mut se = 0.0f64;
                        let mut preds = Vec::with_capacity(b);
                        for bi in 0..b {
                            let p = logits.data[bi * no];
                            preds.push(p);
                            let e = (p - labels[bi]) as f64;
                            se += e * e;
                        }
                        EvalOut { loss_sum: se, aux: se, preds }
                    }
                    Targets::Cls(labels) => {
                        if labels.len() != b {
                            bail!("cls labels len {} != batch {b}", labels.len());
                        }
                        let mut nll_sum = 0.0f64;
                        let mut correct = 0.0f64;
                        let mut preds = Vec::with_capacity(b);
                        for bi in 0..b {
                            let r = &logits.data[bi * no..(bi + 1) * no];
                            let m = r.iter().fold(f32::NEG_INFINITY, |a, &x| a.max(x));
                            let mut sum = 0.0f64;
                            for &x in r.iter() {
                                sum += ((x - m) as f64).exp();
                            }
                            let lse = m as f64 + sum.ln();
                            let argmax = r
                                .iter()
                                .enumerate()
                                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                                .map(|(i, _)| i)
                                .unwrap_or(0);
                            preds.push(argmax as f32);
                            let lab = labels[bi];
                            if lab >= 0 && (lab as usize) < no {
                                nll_sum += lse - r[lab as usize] as f64;
                                if argmax == lab as usize {
                                    correct += 1.0;
                                }
                            }
                        }
                        EvalOut { loss_sum: nll_sum, aux: correct, preds }
                    }
                    Targets::Lm(_) => unreachable!(),
                }
            }
        };
        self.exec_secs += t0.elapsed().as_secs_f64();
        self.exec_calls += 1;
        Ok(out)
    }

    fn params_updated(&mut self, _active_layers: &[usize]) {
        // stateless w.r.t. parameters: reads the store fresh every call
    }

    fn exec_secs(&self) -> f64 {
        self.exec_secs
    }

    fn exec_calls(&self) -> u64 {
        self.exec_calls
    }

    fn phase_secs(&self) -> [f64; 3] {
        // the native engine has no host<->device marshaling: everything is
        // "execute"
        [0.0, self.exec_secs, 0.0]
    }

    fn activation_bytes(&self) -> u64 {
        self.act_bytes
    }

    fn replicate(&self) -> Option<Box<dyn super::Backend + Send>> {
        // The engine is a pure function of (preset, head, shape) plus
        // per-call scratch: a field copy with zeroed perf counters computes
        // bit-identical fwd/bwd on any thread. Counters start at zero so a
        // replica's exec time is its own, never double-booked with the
        // parent's.
        Some(Box::new(NativeBackend {
            preset: self.preset,
            head: self.head,
            n_out: self.n_out,
            specs: self.specs.clone(),
            batch: self.batch,
            seq: self.seq,
            cos: self.cos.clone(),
            sin: self.sin.clone(),
            act_bytes: self.act_bytes,
            exec_secs: 0.0,
            exec_calls: 0,
        }))
    }
}

// ---------------------------------------------------------------------------
// math helpers (module-level so unit tests can hit them directly)
// ---------------------------------------------------------------------------

/// Bytes of forward activations the engine materializes host-side (the
/// memory-accounting contract: forward caches kept for backward, plus the
/// head tensors). Backward temporaries — including the batched attention
/// path's transient head-dense buffers (one N·d ctx/dq/dk/dv staging
/// tensor at a time) — are bounded by one extra layer-set and are charged
/// implicitly via the same formula's margin. Parameters are
/// read through borrowed views (never cloned per use), so this formula
/// charges genuine activations only — weights are already accounted in
/// `MemBreakdown::weights`.
fn model_activation_bytes(p: &Preset, head: &str, n_out: usize, b: usize, t: usize) -> u64 {
    let n = (b * t) as u64;
    let (d, ff, v) = (p.d_model as u64, p.d_ff as u64, p.vocab as u64);
    let (h, tt) = (p.n_heads as u64, t as u64);
    // per layer: x0, ha, q, k, v, ctx, x1, hm (8 N*d) + probs (b*h*t*t)
    //            + g, u, prod (3 N*ff) + ra, rm (2 N)
    let per_layer = 8 * n * d + (b as u64) * h * tt * tt + 3 * n * ff + 2 * n;
    let head_elems = match head {
        "lm" => n * d + n * v + n, // xf + logits + rf
        _ => n * d + n + (b as u64) * (d + n_out as u64), // xf + rf + pooled/logits
    };
    4 * (p.n_layers as u64 * per_layer + head_elems)
}

/// Thread budget for work nested inside a `parallel_map` over `items`:
/// whatever the outer fan-out cannot use. Purely a throughput decision —
/// every kernel is thread-count-invariant, so any value computes the same
/// bits.
fn inner_threads(items: usize) -> usize {
    (util::num_threads() / items.max(1)).max(1)
}

/// y = x * g / rms(x), rms = sqrt(mean(x^2) + eps). Returns (y, 1/rms per
/// row). Rows are independent, so the sweep row-partitions across threads
/// (both outputs split by the same chunks via `par_rows2`).
fn rmsnorm_fwd(x: &Tensor, g: &[f32]) -> (Tensor, Vec<f32>) {
    let d = x.cols();
    assert_eq!(g.len(), d);
    let rows = x.rows();
    let mut y = Tensor::zeros(&[rows, d]);
    let mut r = vec![0.0f32; rows];
    let threads = if x.numel() < util::par_min_elems() { 1 } else { util::num_threads() };
    let xd = &x.data;
    gemm::par_rows2(&mut y.data, &mut r, rows, d, 1, threads, |i0, i1, yc, rc| {
        for li in 0..(i1 - i0) {
            let xr = &xd[(i0 + li) * d..(i0 + li + 1) * d];
            let ms: f32 = xr.iter().map(|&v| v * v).sum::<f32>() / d as f32;
            let ri = 1.0 / (ms + RMS_EPS).sqrt();
            rc[li] = ri;
            let yr = &mut yc[li * d..(li + 1) * d];
            for j in 0..d {
                yr[j] = xr[j] * ri * g[j];
            }
        }
    });
    (y, r)
}

/// Backward of rmsnorm_fwd. Returns (dx, dg).
///
/// dx rows are independent; dγ is a cross-row reduction, so rows are
/// grouped into FIXED `REDUCE_ROWS` blocks whose partial dγ sums are
/// combined in block order — the grouping depends only on the row count,
/// never the thread count, keeping the result bitwise thread-invariant.
fn rmsnorm_bwd(dy: &Tensor, x: &Tensor, g: &[f32], r: &[f32]) -> (Tensor, Vec<f32>) {
    let d = x.cols();
    let rows = x.rows();
    let nblocks = rows.div_ceil(REDUCE_ROWS).max(1);
    let block = |bi: usize| -> (Vec<f32>, Vec<f32>) {
        let i0 = bi * REDUCE_ROWS;
        let i1 = ((bi + 1) * REDUCE_ROWS).min(rows);
        let mut dxb = vec![0.0f32; (i1 - i0) * d];
        let mut dgb = vec![0.0f32; d];
        for li in 0..(i1 - i0) {
            let i = i0 + li;
            let xr = &x.data[i * d..(i + 1) * d];
            let dyr = &dy.data[i * d..(i + 1) * d];
            let ri = r[i];
            let mut s = 0.0f32; // sum_j dy_j * g_j * x_j
            for j in 0..d {
                s += dyr[j] * g[j] * xr[j];
                dgb[j] += dyr[j] * xr[j] * ri;
            }
            let k = ri * ri * ri * s / d as f32;
            let dxr = &mut dxb[li * d..(li + 1) * d];
            for j in 0..d {
                dxr[j] = dyr[j] * g[j] * ri - xr[j] * k;
            }
        }
        (dxb, dgb)
    };
    let parts: Vec<(Vec<f32>, Vec<f32>)> = if x.numel() < util::par_min_elems() {
        (0..nblocks).map(block).collect()
    } else {
        gemm::parallel_map(nblocks, block)
    };
    let mut dx = Tensor::zeros(&[rows, d]);
    let mut dg = vec![0.0f32; d];
    let mut off = 0;
    for (dxb, dgb) in parts {
        dx.data[off..off + dxb.len()].copy_from_slice(&dxb);
        off += dxb.len();
        for (a, b) in dg.iter_mut().zip(&dgb) {
            *a += b;
        }
    }
    (dx, dg)
}

/// Row-wise softmax VJP over dense [m, n] slice pairs:
/// ds[i] = p[i] ⊙ (dp[i] - ⟨dp[i], p[i]⟩), row-partitioned at `threads`
/// (each row is self-contained, so any worker count computes identical
/// bits; small inputs stay serial via `util::par_min_elems`).
///
/// A fully-masked attention row has p ≡ 0 (`softmax_rows` maps all-(-inf)
/// rows to zeros rather than NaN); here that propagates an exactly-zero
/// gradient row — consistent "no probability mass, no gradient" semantics,
/// pinned by `softmax_bwd_zero_row_gives_zero_grad` below.
fn softmax_rows_bwd_slice(p: &[f32], dp: &[f32], m: usize, n: usize, threads: usize) -> Tensor {
    debug_assert_eq!(p.len(), m * n);
    debug_assert_eq!(dp.len(), m * n);
    let mut ds = Tensor::zeros(&[m, n]);
    let threads = if m * n < util::par_min_elems() { 1 } else { threads };
    gemm::par_rows(&mut ds.data, m, n, threads, |i0, i1, chunk| {
        for li in 0..(i1 - i0) {
            let pr = &p[(i0 + li) * n..(i0 + li + 1) * n];
            let dpr = &dp[(i0 + li) * n..(i0 + li + 1) * n];
            let mut dot = 0.0f32;
            for j in 0..n {
                dot += dpr[j] * pr[j];
            }
            let dsr = &mut chunk[li * n..(li + 1) * n];
            for j in 0..n {
                dsr[j] = pr[j] * (dpr[j] - dot);
            }
        }
    });
    ds
}

/// [`softmax_rows_bwd_slice`] over whole tensors at the shared worker
/// count — the batched attention backward runs all b·h·t rows in one call.
fn softmax_rows_bwd(p: &Tensor, dp: &Tensor) -> Tensor {
    debug_assert_eq!(dp.shape, p.shape);
    softmax_rows_bwd_slice(&p.data, &dp.data, p.rows(), p.cols(), util::num_threads())
}

/// Causal mask + 1/√dh scale over head-dense scores [rows, t] (row `r`
/// belongs to query position `r % t`): entries past the diagonal become
/// -inf, the rest are scaled. Elementwise per row → thread-count-invariant.
/// Public so the attention bench drives the exact production sweep.
pub fn mask_scale_causal(s: &mut Tensor, t: usize, scale: f32, threads: usize) {
    debug_assert_eq!(s.cols(), t);
    let rows = s.rows();
    let threads = if s.numel() < util::par_min_elems() { 1 } else { threads };
    gemm::par_rows(&mut s.data, rows, t, threads, |i0, _i1, chunk| {
        for (li, row) in chunk.chunks_mut(t).enumerate() {
            let ti = (i0 + li) % t;
            for (j, cell) in row.iter_mut().enumerate() {
                if j > ti {
                    *cell = f32::NEG_INFINITY; // causal mask
                } else {
                    *cell *= scale;
                }
            }
        }
    });
}

/// Head-dense [b*h*t, dh] → interleaved [b*t, h*dh] (the batched attention
/// outputs back into the model's activation layout). Pure copies
/// partitioned by destination row, so any thread count writes the same
/// bits.
fn interleave_heads(src: &Tensor, b: usize, t: usize, h: usize, dh: usize) -> Tensor {
    let d = h * dh;
    debug_assert_eq!(src.rows(), b * h * t);
    debug_assert_eq!(src.cols(), dh);
    let mut dst = Tensor::zeros(&[b * t, d]);
    let threads = if dst.numel() < util::par_min_elems() { 1 } else { util::num_threads() };
    let sd = &src.data;
    gemm::par_rows(&mut dst.data, b * t, d, threads, |i0, i1, rows| {
        for li in 0..(i1 - i0) {
            let (bi, ti) = ((i0 + li) / t, (i0 + li) % t);
            let drow = &mut rows[li * d..(li + 1) * d];
            for hi in 0..h {
                let s0 = ((bi * h + hi) * t + ti) * dh;
                drow[hi * dh..(hi + 1) * dh].copy_from_slice(&sd[s0..s0 + dh]);
            }
        }
    });
    dst
}

/// cos/sin rope tables: [t, dh/2] flattened row-major.
fn rope_tables(t: usize, dh: usize) -> (Vec<f32>, Vec<f32>) {
    let half = dh / 2;
    let mut cos = Vec::with_capacity(t * half);
    let mut sin = Vec::with_capacity(t * half);
    for pos in 0..t {
        for j in 0..half {
            let freq = 1.0 / 10000f64.powf(j as f64 / half as f64);
            let ang = pos as f64 * freq;
            cos.push(ang.cos() as f32);
            sin.push(ang.sin() as f32);
        }
    }
    (cos, sin)
}

/// Apply rotary embedding in place on [B*T, H*Dh] (backward = inverse
/// rotation, since the rotation matrix is orthogonal). Rows are independent
/// pure rotations, so the sweep row-partitions across threads.
#[allow(clippy::too_many_arguments)]
fn rope_apply(
    x: &mut Tensor,
    t: usize,
    h: usize,
    dh: usize,
    cos: &[f32],
    sin: &[f32],
    backward: bool,
) {
    let half = dh / 2;
    let d = h * dh;
    debug_assert_eq!(x.cols(), d);
    let rows = x.rows();
    let threads = if x.numel() < util::par_min_elems() { 1 } else { util::num_threads() };
    gemm::par_rows(&mut x.data, rows, d, threads, |i0, i1, chunk| {
        for li in 0..(i1 - i0) {
            let ti = (i0 + li) % t;
            let tab = ti * half;
            let xr = &mut chunk[li * d..(li + 1) * d];
            for hi in 0..h {
                let base = hi * dh;
                for j in 0..half {
                    let (c, s) = (cos[tab + j], sin[tab + j]);
                    let x1 = xr[base + j];
                    let x2 = xr[base + half + j];
                    if backward {
                        xr[base + j] = x1 * c + x2 * s;
                        xr[base + half + j] = -x1 * s + x2 * c;
                    } else {
                        xr[base + j] = x1 * c - x2 * s;
                        xr[base + half + j] = x1 * s + x2 * c;
                    }
                }
            }
        }
    });
}

/// Copy one attention head's [t, dh] block out of an [B*T, H*Dh] tensor.
fn head_slice(x: &Tensor, bi: usize, t: usize, hi: usize, dh: usize) -> Tensor {
    let d = x.cols();
    let mut out = Tensor::zeros(&[t, dh]);
    for ti in 0..t {
        let src = &x.data[(bi * t + ti) * d + hi * dh..(bi * t + ti) * d + (hi + 1) * dh];
        out.data[ti * dh..(ti + 1) * dh].copy_from_slice(src);
    }
    out
}

/// Write one head's [t, dh] block back into [B*T, H*Dh].
fn write_head_slice(dst: &mut Tensor, bi: usize, t: usize, hi: usize, dh: usize, src: &Tensor) {
    let d = dst.cols();
    for ti in 0..t {
        let s = &src.data[ti * dh..(ti + 1) * dh];
        dst.data[(bi * t + ti) * d + hi * dh..(bi * t + ti) * d + (hi + 1) * dh]
            .copy_from_slice(s);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::Backend;
    use crate::util::rng::Pcg64;

    fn rand_tensor(shape: &[usize], rng: &mut Pcg64) -> Tensor {
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(&mut t.data, 1.0);
        t
    }

    #[test]
    fn rmsnorm_bwd_matches_finite_difference() {
        let mut rng = Pcg64::new(11);
        let x = rand_tensor(&[3, 8], &mut rng);
        let mut g = vec![0.0f32; 8];
        rng.fill_normal(&mut g, 1.0);
        // scalar objective: sum of squares of y (so dy = 2y)
        let (y, r) = rmsnorm_fwd(&x, &g);
        let mut dy = y.clone();
        dy.scale(2.0);
        let (dx, dg) = rmsnorm_bwd(&dy, &x, &g, &r);
        let f = |x: &Tensor, g: &[f32]| -> f64 {
            let (y, _) = rmsnorm_fwd(x, g);
            y.data.iter().map(|&v| (v as f64) * (v as f64)).sum()
        };
        let eps = 1e-3f32;
        for &(i, j) in &[(0usize, 0usize), (1, 3), (2, 7)] {
            let mut xp = x.clone();
            xp.data[i * 8 + j] += eps;
            let mut xm = x.clone();
            xm.data[i * 8 + j] -= eps;
            let fd = (f(&xp, &g) - f(&xm, &g)) / (2.0 * eps as f64);
            let an = dx.data[i * 8 + j] as f64;
            assert!((fd - an).abs() < 1e-2 * fd.abs().max(1.0), "dx[{i},{j}]: {fd} vs {an}");
        }
        for j in [0usize, 5] {
            let mut gp = g.clone();
            gp[j] += eps;
            let mut gm = g.clone();
            gm[j] -= eps;
            let fd = (f(&x, &gp) - f(&x, &gm)) / (2.0 * eps as f64);
            let an = dg[j] as f64;
            assert!((fd - an).abs() < 1e-2 * fd.abs().max(1.0), "dg[{j}]: {fd} vs {an}");
        }
    }

    #[test]
    fn softmax_bwd_zero_row_gives_zero_grad() {
        // a fully-masked (all -inf) attention scores row softmaxes to zeros;
        // its backward must be exactly zero — never NaN
        let t = 4;
        let mut s = Tensor::zeros(&[t, t]);
        for i in 0..t {
            for j in 0..t {
                s.data[i * t + j] = if i == 0 || j > i {
                    f32::NEG_INFINITY
                } else {
                    (i * t + j) as f32 * 0.1
                };
            }
        }
        s.softmax_rows();
        assert!(s.data[..t].iter().all(|&p| p == 0.0), "masked row must be zeros");
        assert!(s.data.iter().all(|p| p.is_finite()));
        let mut dp = Tensor::zeros(&[t, t]);
        for (i, x) in dp.data.iter_mut().enumerate() {
            *x = (i as f32) * 0.3 - 1.0;
        }
        let ds = softmax_rows_bwd(&s, &dp);
        assert!(ds.data.iter().all(|x| x.is_finite()), "softmax bwd produced NaN/inf");
        assert!(
            ds.data[..t].iter().all(|&x| x == 0.0),
            "zero-probability row must propagate exactly zero gradient"
        );
        // live rows: softmax VJP is mean-free under p (Σ_j ds_j = 0)
        for i in 1..t {
            let sum: f32 = ds.data[i * t..(i + 1) * t].iter().sum();
            assert!(sum.abs() < 1e-5, "row {i} ds sum {sum}");
        }
    }

    #[test]
    fn rmsnorm_block_reduction_matches_serial_reference() {
        let mut rng = Pcg64::new(31);
        let rows = 3 * REDUCE_ROWS + 7; // dγ partials cross several fixed blocks
        let d = 5;
        let x = rand_tensor(&[rows, d], &mut rng);
        let mut g = vec![0.0f32; d];
        rng.fill_normal(&mut g, 1.0);
        let (y, r) = rmsnorm_fwd(&x, &g);
        let dy = y.clone();
        let (dx, dg) = rmsnorm_bwd(&dy, &x, &g, &r);
        assert_eq!(dx.rows(), rows);
        // f64 serial reference for the dγ reduction
        let mut want = vec![0.0f64; d];
        for i in 0..rows {
            for j in 0..d {
                want[j] += dy.data[i * d + j] as f64 * x.data[i * d + j] as f64 * r[i] as f64;
            }
        }
        for j in 0..d {
            assert!(
                (dg[j] as f64 - want[j]).abs() < 1e-3 * (1.0 + want[j].abs()),
                "dg[{j}]: {} vs {}",
                dg[j],
                want[j]
            );
        }
    }

    #[test]
    fn rope_roundtrips() {
        let mut rng = Pcg64::new(5);
        let (t, h, dh) = (6, 2, 8);
        let (cos, sin) = rope_tables(t, dh);
        let x = rand_tensor(&[2 * t, h * dh], &mut rng);
        let mut y = x.clone();
        rope_apply(&mut y, t, h, dh, &cos, &sin, false);
        // norms preserved per row (rotation)
        for i in 0..x.rows() {
            let nx: f32 = x.data[i * h * dh..(i + 1) * h * dh].iter().map(|v| v * v).sum();
            let ny: f32 = y.data[i * h * dh..(i + 1) * h * dh].iter().map(|v| v * v).sum();
            assert!((nx - ny).abs() < 1e-3 * nx.max(1.0));
        }
        rope_apply(&mut y, t, h, dh, &cos, &sin, true);
        for (a, b) in x.data.iter().zip(&y.data) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn head_slice_roundtrip() {
        let mut rng = Pcg64::new(7);
        let (b, t, h, dh) = (2, 3, 2, 4);
        let x = rand_tensor(&[b * t, h * dh], &mut rng);
        let mut y = Tensor::zeros(&[b * t, h * dh]);
        for bi in 0..b {
            for hi in 0..h {
                let s = head_slice(&x, bi, t, hi, dh);
                write_head_slice(&mut y, bi, t, hi, dh, &s);
            }
        }
        assert_eq!(x.data, y.data);
    }

    #[test]
    fn interleave_heads_inverts_head_slice() {
        let mut rng = Pcg64::new(13);
        let (b, t, h, dh) = (2, 5, 3, 4);
        let x = rand_tensor(&[b * t, h * dh], &mut rng);
        // head-dense staging built the way the batched path sees it
        let mut dense = Tensor::zeros(&[b * h * t, dh]);
        for bi in 0..b {
            for hi in 0..h {
                let s = head_slice(&x, bi, t, hi, dh);
                let i = bi * h + hi;
                dense.data[i * t * dh..(i + 1) * t * dh].copy_from_slice(&s.data);
            }
        }
        let back = interleave_heads(&dense, b, t, h, dh);
        assert_eq!(back.data, x.data);
    }

    #[test]
    fn mask_scale_causal_matches_per_head_reference() {
        let mut rng = Pcg64::new(17);
        let (bh, t) = (3usize, 5usize);
        let s = rand_tensor(&[bh * t, t], &mut rng);
        let mut got = s.clone();
        mask_scale_causal(&mut got, t, 0.37, 2);
        for head in 0..bh {
            for i in 0..t {
                for j in 0..t {
                    let x = got.data[(head * t + i) * t + j];
                    if j > i {
                        assert_eq!(x, f32::NEG_INFINITY);
                    } else {
                        assert_eq!(x, s.data[(head * t + i) * t + j] * 0.37);
                    }
                }
            }
        }
    }

    /// THE attention acceptance pin: with identical params and batch, the
    /// batched strided-GEMM path and the legacy per-head loop produce
    /// bit-for-bit identical loss AND gradients (grad_check.rs extends this
    /// across the full {threads} x {kernel path} matrix).
    #[test]
    fn batched_attention_matches_per_head_loop_bitwise() {
        let _g = crate::util::test_knob_lock();
        let run = |batched: bool| {
            crate::util::set_attn_batched(batched);
            let mut be = NativeBackend::with_shape("nano", "lm", 0, 2, 8).unwrap();
            let specs = be.param_specs().to_vec();
            let store = ParamStore::init(&specs, 3);
            let tokens: Vec<i32> = (0..16).map(|i| (7 * i + 3) % 256).collect();
            let targets: Vec<i32> = (0..16).map(|i| (7 * i + 10) % 256).collect();
            let mut g: Vec<Vec<f32>> = specs.iter().map(|s| vec![0.0; s.numel()]).collect();
            let l = be
                .forward_backward_dense(&store, &tokens, Targets::Lm(&targets), &mut g)
                .unwrap();
            (l, g)
        };
        let (lb, gb) = run(true);
        let (ll, gl) = run(false);
        crate::util::reset_attn_batched();
        assert_eq!(lb.to_bits(), ll.to_bits(), "loss: batched {lb} vs looped {ll}");
        assert_eq!(gb, gl, "gradients differ between batched and per-head attention");
    }

    #[test]
    fn lm_loss_blocked_reduction_matches_serial_reference() {
        // enough rows to cross several fixed blocks AND several threads;
        // v comes from the backend's preset (lm_loss_grad reads it there)
        let mut rng = Pcg64::new(37);
        let be = NativeBackend::with_shape("grain", "lm", 0, 2, 8).unwrap();
        let v = be.preset.vocab;
        let rows = 3 * REDUCE_ROWS + 5;
        let logits0 = rand_tensor(&[rows, v], &mut rng);
        let targets: Vec<i32> =
            (0..rows).map(|i| if i % 7 == 3 { -1 } else { (i % v) as i32 }).collect();
        // serial f64 reference (plain row loop, no blocking)
        let mut want_loss = 0.0f64;
        let mut want_count = 0.0f64;
        for (i, &tgt) in targets.iter().enumerate() {
            if tgt < 0 {
                continue;
            }
            let r = &logits0.data[i * v..(i + 1) * v];
            let m = r.iter().fold(f32::NEG_INFINITY, |a, &x| a.max(x));
            let sum: f64 = r.iter().map(|&x| ((x - m) as f64).exp()).sum();
            want_loss += m as f64 + sum.ln() - r[tgt as usize] as f64;
            want_count += 1.0;
        }
        // blocked sweep, serial and forced-parallel, must agree with the
        // reference to f64 regrouping tolerance and with EACH OTHER exactly
        let _g = crate::util::test_knob_lock();
        crate::util::set_par_min(0);
        let mut l1 = logits0.clone();
        let (ls1, c1) = be.lm_loss_grad(&mut l1, &targets, true);
        crate::util::reset_par_min();
        let mut l2 = logits0.clone();
        let (ls2, c2) = be.lm_loss_grad(&mut l2, &targets, true);
        assert_eq!(ls1.to_bits(), ls2.to_bits(), "loss bits depend on threading");
        assert_eq!(c1, c2);
        assert_eq!(l1.data, l2.data, "dlogits bits depend on threading");
        assert!((ls1 - want_loss).abs() < 1e-9 * (1.0 + want_loss.abs()), "{ls1} vs {want_loss}");
        assert_eq!(c1, want_count);
        // ignored rows must have exactly-zero grad rows
        for (i, &tgt) in targets.iter().enumerate() {
            if tgt < 0 {
                assert!(l1.data[i * v..(i + 1) * v].iter().all(|&x| x == 0.0));
            }
        }
    }

    #[test]
    fn activation_bytes_scale_with_model() {
        let nano = presets::get("nano").unwrap();
        let micro = presets::get("micro").unwrap();
        let a = model_activation_bytes(nano, "lm", 0, 8, 64);
        let b = model_activation_bytes(micro, "lm", 0, 8, 64);
        assert!(a > 0 && b > a, "{a} vs {b}");
    }

    #[test]
    fn native_lm_smoke_and_determinism() {
        let mut be = NativeBackend::with_shape("nano", "lm", 0, 2, 8).unwrap();
        let specs = be.param_specs().to_vec();
        let store = ParamStore::init(&specs, 3);
        let tokens: Vec<i32> = (0..16).map(|i| (7 * i + 3) % 256).collect();
        let targets: Vec<i32> = (0..16).map(|i| (7 * i + 10) % 256).collect();
        let mut g1: Vec<Vec<f32>> = specs.iter().map(|s| vec![0.0; s.numel()]).collect();
        let mut g2 = g1.clone();
        let l1 = be
            .forward_backward_dense(&store, &tokens, Targets::Lm(&targets), &mut g1)
            .unwrap();
        let l2 = be
            .forward_backward_dense(&store, &tokens, Targets::Lm(&targets), &mut g2)
            .unwrap();
        assert_eq!(l1, l2, "native engine must be bitwise deterministic");
        assert_eq!(g1, g2);
        assert!(l1 > 0.0 && l1.is_finite());
        // near-uniform logits at init: loss ~ ln(256)
        assert!((l1 - (256f64).ln()).abs() < 1.0, "loss {l1}");
        // every parameter the batch touches gets a gradient
        assert!(g1.iter().any(|g| g.iter().any(|&x| x != 0.0)));
        // eval on the same batch reports the same mean loss
        let ev = be.eval_batch(&store, &tokens, Targets::Lm(&targets)).unwrap();
        assert!((ev.loss_sum / ev.aux - l1).abs() < 1e-6, "{} vs {l1}", ev.loss_sum / ev.aux);
        assert_eq!(ev.aux, 16.0);
    }

    #[test]
    fn native_cls_and_reg_smoke() {
        let mut be = NativeBackend::with_shape("nano", "cls", 3, 4, 8).unwrap();
        let specs = be.param_specs().to_vec();
        let store = ParamStore::init(&specs, 4);
        let tokens: Vec<i32> = (0..32).map(|i| (5 * i + 1) % 256).collect();
        let labels = vec![0i32, 1, 2, 1];
        let mut g: Vec<Vec<f32>> = specs.iter().map(|s| vec![0.0; s.numel()]).collect();
        let loss = be
            .forward_backward_dense(&store, &tokens, Targets::Cls(&labels), &mut g)
            .unwrap();
        assert!((loss - (3f64).ln()).abs() < 0.5, "cls loss {loss}"); // ~uniform
        let ev = be.eval_batch(&store, &tokens, Targets::Cls(&labels)).unwrap();
        assert_eq!(ev.preds.len(), 4);
        assert!(ev.aux >= 0.0 && ev.aux <= 4.0);

        let mut rb = NativeBackend::with_shape("nano", "reg", 1, 4, 8).unwrap();
        let rspecs = rb.param_specs().to_vec();
        let rstore = ParamStore::init(&rspecs, 5);
        let labels_f = vec![0.1f32, 0.9, 0.4, 0.6];
        let mut rg: Vec<Vec<f32>> = rspecs.iter().map(|s| vec![0.0; s.numel()]).collect();
        let rloss =
            rb.forward_backward_dense(&rstore, &tokens, Targets::Reg(&labels_f), &mut rg).unwrap();
        assert!(rloss.is_finite() && rloss >= 0.0);
        let rev = rb.eval_batch(&rstore, &tokens, Targets::Reg(&labels_f)).unwrap();
        assert_eq!(rev.preds.len(), 4);
        assert!((rev.loss_sum / 4.0 - rloss).abs() < 1e-5);
    }

    #[test]
    fn invalid_inputs_rejected() {
        let mut be = NativeBackend::with_shape("nano", "lm", 0, 2, 8).unwrap();
        let specs = be.param_specs().to_vec();
        let store = ParamStore::init(&specs, 3);
        let mut g: Vec<Vec<f32>> = specs.iter().map(|s| vec![0.0; s.numel()]).collect();
        let bad_tok = vec![300i32; 16];
        let tgts = vec![0i32; 16];
        assert!(be.forward_backward_dense(&store, &bad_tok, Targets::Lm(&tgts), &mut g).is_err());
        let short = vec![0i32; 4];
        assert!(be.forward_backward_dense(&store, &short, Targets::Lm(&tgts), &mut g).is_err());
        assert!(NativeBackend::with_shape("nope", "lm", 0, 2, 8).is_err());
        assert!(NativeBackend::with_shape("nano", "wat", 0, 2, 8).is_err());
        // targets kind must match the head
        let ok_tok = vec![0i32; 16];
        let labels = vec![0i32, 1];
        assert!(be.forward_backward_dense(&store, &ok_tok, Targets::Cls(&labels), &mut g).is_err());
    }
}
