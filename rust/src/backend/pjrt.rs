//! PJRT execution backend: wraps the `runtime` module (AOT HLO artifacts)
//! behind the `Backend` trait.
//!
//! Owns everything XLA-specific that used to live inside the trainer: the
//! persistent parameter literals (built once, refreshed in place only for
//! layers the strategy touched — the first hot-path optimization recorded in
//! EXPERIMENTS.md §Perf), the input marshaling, and the output untupling.
//!
//! The runtime itself is PROCESS-SHARED (`runtime::open_shared`): backends
//! are constructed per run, but every backend pointing at the same
//! artifacts dir reuses one `Runtime` and therefore one compiled-executable
//! cache — the experiment harnesses no longer recompile identical HLO on
//! every run. Perf counters are tracked per backend at the execute call
//! site, so concurrent backends on one shared runtime never cross-attribute
//! each other's executions.

use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Result};

use super::{EvalOut, Targets};
use crate::config::TrainConfig;
use crate::grads::GradSink;
use crate::model::ParamStore;
use crate::runtime::{
    self, copy_f32_into, lit_f32, lit_i32, scalar_f32, ArtifactInfo, ParamSpec, Runtime,
};

pub struct PjrtBackend {
    rt: Arc<Mutex<Runtime>>,
    train_art: ArtifactInfo,
    eval_art: ArtifactInfo,
    /// persistent parameter literals; built lazily from the store on first
    /// use so warm-starts applied after construction are picked up
    param_lits: Option<Vec<xla::Literal>>,
    dirty: Vec<bool>,
    /// [param upload, execute, grad download] cumulative seconds
    phase: [f64; 3],
    /// THIS backend's execute time/count (the shared runtime's counters
    /// aggregate across every backend on it, so they cannot be used here)
    exec_secs: f64,
    exec_calls: u64,
}

impl PjrtBackend {
    /// Resolve the train/eval artifacts for a config from the default
    /// artifacts directory, sharing the process-wide runtime (and its
    /// compiled-executable cache). Fails cleanly when artifacts are absent
    /// or the PJRT client cannot start (e.g. the vendored xla stub) —
    /// `auto` backend selection falls back to native in that case.
    pub fn open(cfg: &TrainConfig, head: &str, n_out: usize) -> Result<PjrtBackend> {
        let rt = runtime::open_default_shared()?;
        Self::with_shared(rt, cfg, head, n_out)
    }

    /// Wrap an exclusively-owned runtime (tests construct these directly).
    pub fn with_runtime(
        rt: Runtime,
        cfg: &TrainConfig,
        head: &str,
        n_out: usize,
    ) -> Result<PjrtBackend> {
        Self::with_shared(Arc::new(Mutex::new(rt)), cfg, head, n_out)
    }

    pub fn with_shared(
        rt: Arc<Mutex<Runtime>>,
        cfg: &TrainConfig,
        head: &str,
        n_out: usize,
    ) -> Result<PjrtBackend> {
        let (train_art, eval_art) = {
            let g = rt.lock().expect("runtime lock");
            let find = |phase: &str| -> Result<ArtifactInfo> {
                g.manifest
                    .artifacts
                    .values()
                    .find(|a| {
                        a.preset == cfg.preset
                            && a.head == head
                            && a.kind.ends_with(phase)
                            && a.pallas == cfg.use_pallas_artifact
                            && (head == "lm" || a.n_out == n_out.max(1))
                    })
                    .cloned()
                    .ok_or_else(|| {
                        anyhow!(
                            "no artifact preset={} head={head} n_out={n_out} phase={phase} pallas={} — run `make artifacts`",
                            cfg.preset,
                            cfg.use_pallas_artifact
                        )
                    })
            };
            (find("train")?, find("eval")?)
        };
        // the trainer generates both train and eval batches at one shape
        // (Backend::batch_shape); reject manifests where the pair disagrees
        // rather than marshaling wrongly-shaped eval literals later
        if (train_art.batch, train_art.seq) != (eval_art.batch, eval_art.seq) {
            bail!(
                "train artifact {} is ({}, {}) but eval artifact {} is ({}, {}); \
                 the backend contract requires one batch shape per run",
                train_art.id,
                train_art.batch,
                train_art.seq,
                eval_art.id,
                eval_art.batch,
                eval_art.seq
            );
        }
        let n_tensors = train_art.params.len();
        Ok(PjrtBackend {
            rt,
            train_art,
            eval_art,
            param_lits: None,
            dirty: vec![false; n_tensors],
            phase: [0.0; 3],
            exec_secs: 0.0,
            exec_calls: 0,
        })
    }

    /// Build or refresh the persistent parameter literals from the store.
    fn sync_param_lits(&mut self, store: &ParamStore) -> Result<()> {
        let t0 = std::time::Instant::now();
        match &mut self.param_lits {
            None => {
                self.param_lits = Some(store.to_literals()?);
                self.dirty.iter_mut().for_each(|d| *d = false);
            }
            Some(lits) => {
                for (i, d) in self.dirty.iter_mut().enumerate() {
                    if *d {
                        lits[i]
                            .copy_raw_from::<f32>(&store.bufs[i])
                            .map_err(|e| anyhow!("param upload {i}: {e}"))?;
                        *d = false;
                    }
                }
            }
        }
        self.phase[0] += t0.elapsed().as_secs_f64();
        Ok(())
    }

    fn target_literal(&self, targets: Targets<'_>, b: usize, t: usize) -> Result<xla::Literal> {
        match targets {
            Targets::Lm(x) => lit_i32(x, &[b, t]),
            Targets::Cls(x) => lit_i32(x, &[b]),
            Targets::Reg(x) => lit_f32(x, &[b]),
        }
    }

    fn execute(
        &mut self,
        art_id: &str,
        tok_lit: &xla::Literal,
        tgt_lit: &xla::Literal,
    ) -> Result<Vec<xla::Literal>> {
        let lits = self.param_lits.as_ref().expect("synced before execute");
        let mut inputs: Vec<&xla::Literal> = lits.iter().collect();
        inputs.push(tok_lit);
        inputs.push(tgt_lit);
        let t0 = std::time::Instant::now();
        let outs = self.rt.lock().expect("runtime lock").execute(art_id, &inputs)?;
        let dt = t0.elapsed().as_secs_f64();
        self.phase[1] += dt;
        self.exec_secs += dt;
        self.exec_calls += 1;
        Ok(outs)
    }
}

impl super::Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn param_specs(&self) -> &[ParamSpec] {
        &self.train_art.params
    }

    fn batch_shape(&self) -> (usize, usize) {
        (self.train_art.batch, self.train_art.seq)
    }

    fn forward_backward(
        &mut self,
        store: &ParamStore,
        tokens: &[i32],
        targets: Targets<'_>,
        sink: &mut dyn GradSink,
    ) -> Result<f64> {
        let (b, t) = (self.train_art.batch, self.train_art.seq);
        self.sync_param_lits(store)?;
        let tok_lit = lit_i32(tokens, &[b, t])?;
        let tgt_lit = self.target_literal(targets, b, t)?;
        let art_id = self.train_art.id.clone();
        let outs = self.execute(&art_id, &tok_lit, &tgt_lit)?;
        let n_params = self.train_art.params.len();
        if outs.len() != 1 + n_params {
            bail!("artifact returned {} outputs, want {}", outs.len(), 1 + n_params);
        }
        let t2 = std::time::Instant::now();
        let loss = scalar_f32(&outs[0])? as f64;
        // untuple the device result through ONE reusable host buffer, one
        // shard per sink call in spec order — host-side grad residency is
        // `sink retention + largest tensor`, same bound as the native engine
        let mut scratch: Vec<f32> = Vec::new();
        for (i, o) in outs[1..].iter().enumerate() {
            copy_f32_into(o, &mut scratch)?;
            sink.consume(i, &scratch);
        }
        self.phase[2] += t2.elapsed().as_secs_f64();
        Ok(loss)
    }

    fn eval_batch(
        &mut self,
        store: &ParamStore,
        tokens: &[i32],
        targets: Targets<'_>,
    ) -> Result<EvalOut> {
        let (b, t) = (self.eval_art.batch, self.eval_art.seq);
        self.sync_param_lits(store)?;
        let tok_lit = lit_i32(tokens, &[b, t])?;
        let tgt_lit = self.target_literal(targets, b, t)?;
        let art_id = self.eval_art.id.clone();
        let outs = self.execute(&art_id, &tok_lit, &tgt_lit)?;
        let loss_sum = scalar_f32(&outs[0])? as f64;
        let aux = scalar_f32(&outs[1])? as f64;
        let preds = match targets {
            Targets::Lm(_) => Vec::new(),
            _ => outs
                .get(2)
                .map(|o| o.to_vec::<f32>().map_err(|e| anyhow!("preds: {e}")))
                .transpose()?
                .unwrap_or_default(),
        };
        Ok(EvalOut { loss_sum, aux, preds })
    }

    fn params_updated(&mut self, active_layers: &[usize]) {
        if active_layers.is_empty() {
            self.dirty.iter_mut().for_each(|d| *d = true);
        } else {
            for &l in active_layers {
                if l < self.dirty.len() {
                    self.dirty[l] = true;
                }
            }
        }
    }

    fn exec_secs(&self) -> f64 {
        self.exec_secs
    }

    fn exec_calls(&self) -> u64 {
        self.exec_calls
    }

    fn phase_secs(&self) -> [f64; 3] {
        self.phase
    }

    fn activation_bytes(&self) -> u64 {
        // activations live inside XLA's arena; the modeled comparison
        // charges them to the artifact, not the host
        0
    }
}
