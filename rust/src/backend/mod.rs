//! L2.5 — the pluggable execution backend layer.
//!
//! BlockLLM's claim is that coordinate-block selection works without touching
//! the model or training procedure; this layer makes the claim testable
//! against more than one execution engine. A `Backend` owns exactly one
//! contract: *given parameters and a batch, return the loss and STREAM the
//! per-parameter gradients* (plus the forward-only eval variant). The
//! backward pass emits each parameter tensor's gradient shard — `(param
//! index, &[f32])`, in the order the shards finalize, reverse-layer order on
//! the native engine — into a caller-supplied [`crate::grads::GradSink`],
//! which decides what survives. The engine itself holds at most ONE dense
//! shard at a time (a reused scratch buffer), so total gradient residency is
//! `sink retention + largest tensor`: the paper's O(active + largest-layer)
//! memory argument, made real at the API boundary instead of contradicted by
//! it. `forward_backward_dense` (a provided method over
//! [`crate::grads::DenseSink`]) recovers the legacy fill-every-buffer
//! behavior for tests, finite-difference sweeps, and the `--grad-stream 0`
//! parity reference; both retention paths consume identical shard bits, so
//! they agree bit for bit end to end.
//!
//! Everything above this layer — trainer, strategies, experiments — is
//! backend-agnostic.
//!
//! Two implementations ship:
//! * [`pjrt::PjrtBackend`] — executes the AOT HLO artifacts via PJRT
//!   (requires `make artifacts` + the real xla_extension binding); the
//!   device result is untupled through one reusable host buffer, one shard
//!   per `consume`, in spec-table order.
//! * [`native::NativeBackend`] — the pure-Rust reference engine: the same
//!   LLaMA-style model (embedding, RMSNorm, RoPE causal attention, SwiGLU,
//!   lm/cls/reg heads) with a hand-derived backward pass, validated against
//!   jax.value_and_grad by python/tests/test_native_mirror.py and by the
//!   finite-difference check in rust/tests/grad_check.rs.
//!
//! Selection: `--backend {auto|native|pjrt}` (config::BackendKind). `auto`
//! prefers PJRT when artifacts are present and the runtime opens, and falls
//! back to native otherwise — so the whole repo is self-verifying in pure
//! Rust on a machine with no Python toolchain.

pub mod native;
pub mod pjrt;

use anyhow::Result;

use crate::config::{BackendKind, Task, TrainConfig};
use crate::grads::{DenseSink, GradSink};
use crate::model::ParamStore;
use crate::runtime::ParamSpec;

/// Per-batch training targets, tagged by head.
#[derive(Debug, Clone, Copy)]
pub enum Targets<'a> {
    /// next-token targets i32[B*T], -1 = ignore
    Lm(&'a [i32]),
    /// class labels i32[B]
    Cls(&'a [i32]),
    /// regression labels f32[B]
    Reg(&'a [f32]),
}

/// Raw eval-batch outputs (the AOT eval artifact's signature, which the
/// native backend mirrors):
/// * lm:  `loss_sum` = summed token NLL, `aux` = valid-token count
/// * cls: `loss_sum` = summed example NLL, `aux` = #correct, `preds` = argmax
/// * reg: `loss_sum` = summed squared error, `aux` = same, `preds` = ŷ
#[derive(Debug, Clone)]
pub struct EvalOut {
    pub loss_sum: f64,
    pub aux: f64,
    pub preds: Vec<f32>,
}

/// An execution engine for the model fwd/bwd contract.
pub trait Backend {
    fn name(&self) -> &'static str;

    /// Canonical parameter table (the ParamStore ABI).
    fn param_specs(&self) -> &[ParamSpec];

    /// (batch, seq) the engine is built for.
    fn batch_shape(&self) -> (usize, usize);

    /// One fwd+bwd microbatch: streams the gradient of the mean loss for
    /// every parameter tensor into `sink` — exactly one
    /// `sink.consume(idx, shard)` per `param_specs` entry, in the order the
    /// backward pass finalizes them — and returns the loss. Shard buffers
    /// are engine-owned and reused; a sink must copy what it keeps.
    fn forward_backward(
        &mut self,
        store: &ParamStore,
        tokens: &[i32],
        targets: Targets<'_>,
        sink: &mut dyn GradSink,
    ) -> Result<f64>;

    /// Legacy dense convenience: stream into a [`DenseSink`] over
    /// caller-owned full-size buffers (one per `param_specs` entry, already
    /// sized). Bitwise-identical values to the streaming path — only the
    /// retention differs.
    fn forward_backward_dense(
        &mut self,
        store: &ParamStore,
        tokens: &[i32],
        targets: Targets<'_>,
        grads_out: &mut [Vec<f32>],
    ) -> Result<f64> {
        if grads_out.len() != self.param_specs().len() {
            anyhow::bail!(
                "grads_out has {} tensors, want {}",
                grads_out.len(),
                self.param_specs().len()
            );
        }
        let mut sink = DenseSink::new(grads_out);
        self.forward_backward(store, tokens, targets, &mut sink)
    }

    /// Forward-only eval batch.
    fn eval_batch(
        &mut self,
        store: &ParamStore,
        tokens: &[i32],
        targets: Targets<'_>,
    ) -> Result<EvalOut>;

    /// Notify the backend that the strategy updated these layers (empty =
    /// all) — backends that cache device-side parameters invalidate here.
    fn params_updated(&mut self, active_layers: &[usize]);

    /// Cumulative execution seconds (the "XLA time" perf counter).
    fn exec_secs(&self) -> f64;

    fn exec_calls(&self) -> u64;

    /// Cumulative [param-upload, execute, grad-download] seconds.
    fn phase_secs(&self) -> [f64; 3];

    /// Bytes of activations the engine materializes host-side per step
    /// (0 for PJRT, where activations live inside XLA's arena) — feeds
    /// memory::MemBreakdown so cross-backend comparisons stay honest.
    fn activation_bytes(&self) -> u64;

    /// Clone this engine for a data-parallel worker replica (`dist`
    /// layer): a fresh instance computing the SAME function — identical
    /// specs, shape, and fwd/bwd bits for identical inputs — with its own
    /// scratch and zeroed perf counters, safe to drive from another
    /// thread. `None` (the default) means the engine can't replicate
    /// (PJRT's device handles aren't shareable); the dist driver then
    /// falls back to the bitwise-identical sequential path, so replication
    /// support is a pure throughput capability, never a results change.
    fn replicate(&self) -> Option<Box<dyn Backend + Send>> {
        None
    }
}

/// Head + output arity implied by a task (the artifact-resolution logic that
/// used to live inside `Trainer::new`).
pub fn head_for_task(task: Task, seed: u64) -> (&'static str, usize) {
    match task {
        Task::C4Pretrain | Task::AlpacaFinetune => ("lm", 0),
        Task::Glue(i) => {
            let g = crate::data::gluesim::GlueSim::new(i, seed);
            if g.regression() {
                ("reg", 1)
            } else {
                ("cls", g.n_classes())
            }
        }
        Task::DomainShift => ("cls", 2),
    }
}

/// True when an artifacts manifest is reachable by walking up from cwd —
/// i.e. the user has run `make artifacts` and likely expects PJRT.
fn artifacts_nearby() -> bool {
    let mut dir = std::env::current_dir().unwrap_or_default();
    loop {
        if dir.join("artifacts").join("manifest.json").exists() {
            return true;
        }
        if !dir.pop() {
            return false;
        }
    }
}

/// Build the backend a config asks for. `Auto` prefers PJRT when artifacts
/// are present and the runtime opens; otherwise falls back to native. A
/// fallback on a machine that HAS artifacts (stale manifest, broken PJRT
/// binding) is reported on stderr so degraded runs are observable.
pub fn open(cfg: &TrainConfig) -> Result<Box<dyn Backend>> {
    let (head, n_out) = head_for_task(cfg.task, cfg.seed);
    match cfg.backend {
        BackendKind::Native => Ok(Box::new(native::NativeBackend::new(cfg, head, n_out)?)),
        BackendKind::Pjrt => Ok(Box::new(pjrt::PjrtBackend::open(cfg, head, n_out)?)),
        BackendKind::Auto => match pjrt::PjrtBackend::open(cfg, head, n_out) {
            Ok(be) => Ok(Box::new(be)),
            Err(e) => {
                if artifacts_nearby() {
                    eprintln!("[backend] pjrt unavailable ({e:#}); falling back to native");
                }
                Ok(Box::new(native::NativeBackend::new(cfg, head, n_out)?))
            }
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TrainConfig;

    #[test]
    fn head_resolution_matches_tasks() {
        assert_eq!(head_for_task(Task::C4Pretrain, 1), ("lm", 0));
        assert_eq!(head_for_task(Task::AlpacaFinetune, 1), ("lm", 0));
        assert_eq!(head_for_task(Task::DomainShift, 1), ("cls", 2));
        // glue task 2 is stsb-sim: regression
        assert_eq!(head_for_task(Task::Glue(2), 1), ("reg", 1));
        let (h, n) = head_for_task(Task::Glue(4), 1);
        assert_eq!(h, "cls");
        assert!(n >= 2);
    }

    #[test]
    fn auto_backend_always_opens() {
        // whatever the machine (artifacts or not), Auto must produce a
        // working backend for the default config
        let cfg = TrainConfig::default();
        let be = open(&cfg).unwrap();
        let (b, t) = be.batch_shape();
        assert!(b > 0 && t > 0);
        assert!(!be.param_specs().is_empty());
    }

    #[test]
    fn native_backend_kind_is_forced() {
        let mut cfg = TrainConfig::default();
        cfg.backend = BackendKind::Native;
        let be = open(&cfg).unwrap();
        assert_eq!(be.name(), "native");
    }
}
