//! Synthetic data substrates standing in for the paper's corpora
//! (DESIGN.md §5): C4 -> `c4sim`, Alpaca -> `alpacasim`, GLUE -> `gluesim`.
//!
//! All generators are deterministic functions of a seed, emit byte-level
//! token ids in [0, 256), and produce batches shaped exactly like the AOT
//! artifacts expect: LM batches (tokens, targets) i32[B,T] with -1 = ignore,
//! classification batches (tokens i32[B,T], labels).

pub mod alpacasim;
pub mod c4sim;
pub mod gluesim;

pub const VOCAB: usize = 256;
/// Token 0 doubles as padding (targets at pad positions are -1 = ignored).
pub const PAD: i32 = 0;
/// Separator token for pair tasks / instruction boundaries.
pub const SEP: i32 = 1;
/// Begin-of-sequence.
pub const BOS: i32 = 2;

/// An LM batch matching the `*_lm_*` artifacts.
#[derive(Debug, Clone)]
pub struct LmBatch {
    pub tokens: Vec<i32>,  // [b*t]
    pub targets: Vec<i32>, // [b*t], -1 = ignore
    pub batch: usize,
    pub seq: usize,
}

/// A classification/regression batch matching the `*_cls*`/`*_reg*` artifacts.
#[derive(Debug, Clone)]
pub struct ClsBatch {
    pub tokens: Vec<i32>,    // [b*t]
    pub labels_i: Vec<i32>,  // [b] (classification)
    pub labels_f: Vec<f32>,  // [b] (regression)
    pub regression: bool,
    pub batch: usize,
    pub seq: usize,
}

/// Anything that can feed the LM trainer.
pub trait LmStream {
    fn next_batch(&mut self, batch: usize, seq: usize) -> LmBatch;
}

/// Anything that can feed the classifier trainer. `train` selects split.
pub trait ClsSource {
    fn n_classes(&self) -> usize;
    fn regression(&self) -> bool;
    fn batch(&mut self, batch: usize, seq: usize, train: bool) -> ClsBatch;
}

#[cfg(test)]
mod tests {
    use super::c4sim::C4Sim;
    use super::*;

    #[test]
    fn lm_batch_shapes_and_ranges() {
        let mut s = C4Sim::new(1);
        let b = s.next_batch(4, 32);
        assert_eq!(b.tokens.len(), 4 * 32);
        assert_eq!(b.targets.len(), 4 * 32);
        assert!(b.tokens.iter().all(|&t| (0..VOCAB as i32).contains(&t)));
        assert!(b.targets.iter().all(|&t| t >= -1 && t < VOCAB as i32));
    }

    #[test]
    fn lm_targets_are_shifted_tokens() {
        let mut s = C4Sim::new(2);
        let b = s.next_batch(2, 16);
        for row in 0..2 {
            for j in 0..15 {
                let tgt = b.targets[row * 16 + j];
                if tgt >= 0 {
                    assert_eq!(tgt, b.tokens[row * 16 + j + 1]);
                }
            }
        }
    }

    #[test]
    fn streams_are_deterministic_per_seed() {
        let mut a = C4Sim::new(7);
        let mut b = C4Sim::new(7);
        assert_eq!(a.next_batch(2, 32).tokens, b.next_batch(2, 32).tokens);
        let mut c = C4Sim::new(8);
        assert_ne!(a.next_batch(2, 32).tokens, c.next_batch(2, 32).tokens);
    }
}
