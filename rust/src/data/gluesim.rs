//! GLUE-sim: eight synthetic sequence-classification/regression tasks
//! mirroring the GLUE suite used in the paper's Tables 7/8 (DESIGN.md §5).
//!
//! Each task's label is a computable property of the token sequence so that
//! a small transformer can learn it, and the tasks differ in the *kind* of
//! structure (lexical counting, pair similarity, containment, grammar),
//! mirroring how GLUE tasks differ. Task order matches the paper's tables:
//! MRPC, CoLA, STS-B, RTE, SST-2, MNLI, QNLI, QQP.
//!
//! `vocab_offset` shifts the payload alphabet, which is how the DistilBERT
//! IMDb->CoLA *domain shift* protocol of the paper's §2 is reproduced:
//! pretrain on sst2-sim at offset 0, finetune on cola-sim at offset 48.

use super::{ClsBatch, ClsSource, BOS, SEP};
use crate::util::rng::Pcg64;

pub const TASK_NAMES: [&str; 8] =
    ["mrpc", "cola", "stsb", "rte", "sst2", "mnli", "qnli", "qqp"];

/// Relative "dataset sizes" (in thousands of examples) mirroring GLUE; the
/// experiment harness scales per-task training steps by these (Tables 7/8
/// vary memory/score per task partly because of size).
pub const TASK_SIZES_K: [usize; 8] = [4, 9, 6, 3, 67, 393, 105, 364];

#[derive(Debug, Clone)]
pub struct GlueSim {
    pub task: usize,
    pub vocab_offset: i32,
    rng_train: Pcg64,
    rng_eval: Pcg64,
}

const PAYLOAD_LO: i32 = 32;
const PAYLOAD_SPAN: i32 = 96;

impl GlueSim {
    pub fn new(task: usize, seed: u64) -> Self {
        assert!(task < 8);
        GlueSim {
            task,
            vocab_offset: 0,
            rng_train: Pcg64::with_stream(seed, 0x61 + task as u64),
            rng_eval: Pcg64::with_stream(seed, 0xE0 + task as u64),
        }
    }

    pub fn with_offset(mut self, off: i32) -> Self {
        self.vocab_offset = off;
        self
    }

    /// Serialize both split cursors (train + eval rng positions). Task and
    /// vocab offset come from config at reconstruction time.
    pub fn state_save(&self, bag: &mut crate::session::state::StateBag, prefix: &str) {
        bag.put_u64s(&format!("{prefix}.rng_train"), self.rng_train.to_parts().to_vec());
        bag.put_u64s(&format!("{prefix}.rng_eval"), self.rng_eval.to_parts().to_vec());
    }

    /// Restore cursors written by [`Self::state_save`].
    pub fn state_load(
        &mut self,
        bag: &crate::session::state::StateBag,
        prefix: &str,
    ) -> anyhow::Result<()> {
        let tr = bag.u64s(&format!("{prefix}.rng_train"))?;
        let ev = bag.u64s(&format!("{prefix}.rng_eval"))?;
        if tr.len() != 4 || ev.len() != 4 {
            anyhow::bail!("gluesim rng state wants 4 words per split");
        }
        self.rng_train = Pcg64::from_parts([tr[0], tr[1], tr[2], tr[3]]);
        self.rng_eval = Pcg64::from_parts([ev[0], ev[1], ev[2], ev[3]]);
        Ok(())
    }

    fn tok(&self, raw: i32) -> i32 {
        PAYLOAD_LO + (raw + self.vocab_offset).rem_euclid(PAYLOAD_SPAN)
    }

    /// One labelled example. Returns (tokens[seq], label_i, label_f).
    fn example(&self, rng: &mut Pcg64, seq: usize) -> (Vec<i32>, i32, f32) {
        let mut tokens = vec![BOS];
        let (label_i, label_f): (i32, f32);
        let body = seq.saturating_sub(2);
        match self.task {
            0 | 7 => {
                // mrpc / qqp: paraphrase detection, modelled topically — a
                // paraphrase pair draws both segments from one topic's
                // lexicon region, a non-pair mixes topics (lexical pair
                // similarity; the scale-appropriate analogue, DESIGN.md §5).
                // qqp negatives are harder: half the second segment still
                // comes from the first topic.
                let half = (body - 1) / 2;
                let n_topics = 8usize;
                let region = PAYLOAD_SPAN as usize / n_topics;
                let t = rng.below(n_topics);
                let draw = |rng: &mut Pcg64, topic: usize| -> i32 {
                    (topic * region + rng.below(region)) as i32
                };
                let a: Vec<i32> = (0..half).map(|_| self.tok(draw(rng, t))).collect();
                let pos = rng.uniform() < 0.5;
                let b: Vec<i32> = if pos {
                    (0..half).map(|_| self.tok(draw(rng, t))).collect()
                } else {
                    let u = (t + 1 + rng.below(n_topics - 1)) % n_topics;
                    (0..half)
                        .map(|i| {
                            if self.task == 7 && i % 2 == 0 {
                                self.tok(draw(rng, t)) // qqp hard negative
                            } else {
                                self.tok(draw(rng, u))
                            }
                        })
                        .collect()
                };
                tokens.extend(&a);
                tokens.push(SEP);
                tokens.extend(&b);
                label_i = pos as i32;
                label_f = label_i as f32;
            }
            1 => {
                // cola: lexical acceptability. "Acceptable" sequences draw
                // every token from the in-grammar half of the alphabet;
                // violations splice in 1-3 out-of-grammar tokens. (The
                // paper's CoLA is syntactic; a positional-grammar variant is
                // beyond the nano trunk's capacity — DESIGN.md §5 keeps the
                // task's experimental role: binary acceptability under
                // domain shift.)
                let half_span = PAYLOAD_SPAN as usize / 2;
                let mut a: Vec<i32> = (0..body)
                    .map(|_| self.tok(rng.below(half_span) as i32))
                    .collect();
                let ok = rng.uniform() < 0.5;
                if !ok {
                    let k = 1 + rng.below(3);
                    for _ in 0..k {
                        let pos = rng.below(a.len());
                        a[pos] = self.tok((half_span + rng.below(half_span)) as i32);
                    }
                }
                tokens.extend(&a);
                label_i = ok as i32;
                label_f = label_i as f32;
            }
            2 => {
                // stsb: similarity regression = Jaccard overlap of segments.
                let half = (body - 1) / 2;
                let a: Vec<i32> = (0..half).map(|_| self.tok(rng.below(24) as i32)).collect();
                let shared = rng.below(half + 1);
                let mut b = Vec::with_capacity(half);
                b.extend_from_slice(&a[..shared]);
                for _ in shared..half {
                    b.push(self.tok(24 + rng.below(24) as i32));
                }
                rng.shuffle(&mut b);
                tokens.extend(&a);
                tokens.push(SEP);
                tokens.extend(&b);
                label_f = shared as f32 / half.max(1) as f32;
                label_i = 0;
            }
            3 | 5 => {
                // rte (2-class) / mnli (3-class): lexical containment — the
                // premise commits to one half of the alphabet; an entailed
                // hypothesis stays inside it, a contradicting one leaves it,
                // a neutral one (mnli) straddles (DESIGN.md §5: containment
                // reduced to lexical scope at this model scale).
                let half_len = (body - 1) / 2;
                let hs = PAYLOAD_SPAN as usize / 2;
                let side = rng.below(2); // premise half: [0,hs) or [hs,2hs)
                let in_side = |rng: &mut Pcg64, s: usize| (s * hs + rng.below(hs)) as i32;
                let prem: Vec<i32> =
                    (0..half_len).map(|_| self.tok(in_side(rng, side))).collect();
                let class = if self.task == 3 { rng.below(2) } else { rng.below(3) };
                let hyp_len = (half_len / 2).max(1);
                let hyp: Vec<i32> = (0..hyp_len)
                    .map(|i| match class {
                        1 => prem[rng.below(prem.len())], // entail: copy
                        0 => self.tok(in_side(rng, 1 - side)), // contradict
                        _ => {
                            if i % 2 == 0 {
                                prem[rng.below(prem.len())]
                            } else {
                                self.tok(in_side(rng, 1 - side)) // neutral mix
                            }
                        }
                    })
                    .collect();
                tokens.extend(&prem);
                tokens.push(SEP);
                tokens.extend(&hyp);
                label_i = class as i32;
                label_f = label_i as f32;
            }
            4 => {
                // sst2: sentiment = which lexicon half dominates the counts.
                let pos_words: i32 = 0; // region [0, 16)
                let neg_words: i32 = 16; // region [16, 32)
                let n_pos = rng.below(body);
                let mut a = Vec::with_capacity(body);
                for i in 0..body {
                    if i < n_pos {
                        a.push(self.tok(pos_words + rng.below(16) as i32));
                    } else {
                        a.push(self.tok(neg_words + rng.below(16) as i32));
                    }
                }
                rng.shuffle(&mut a);
                tokens.extend(&a);
                label_i = (n_pos * 2 > body) as i32;
                label_f = label_i as f32;
            }
            6 => {
                // qnli: question answerability as region matching — the
                // question token names a lexicon region; "answerable" means
                // the passage contains several tokens from that region.
                let n_regions = 8usize;
                let region = PAYLOAD_SPAN as usize / n_regions;
                let qr = rng.below(n_regions);
                let q = self.tok((qr * region + rng.below(region)) as i32);
                let plen = body - 2;
                let present = rng.uniform() < 0.5;
                let passage: Vec<i32> = (0..plen)
                    .map(|i| {
                        if present && i % 4 == 0 {
                            q // answer: exact copies of the question token
                        } else {
                            // other regions only
                            let or = (qr + 1 + rng.below(n_regions - 1)) % n_regions;
                            self.tok((or * region + rng.below(region)) as i32)
                        }
                    })
                    .collect();
                tokens.push(q);
                tokens.push(SEP);
                tokens.extend(&passage);
                label_i = present as i32;
                label_f = label_i as f32;
            }
            _ => unreachable!(),
        }
        tokens.truncate(seq);
        tokens.resize(seq, super::PAD);
        (tokens, label_i, label_f)
    }
}

impl ClsSource for GlueSim {
    fn n_classes(&self) -> usize {
        match self.task {
            5 => 3,
            2 => 1,
            _ => 2,
        }
    }

    fn regression(&self) -> bool {
        self.task == 2
    }

    fn batch(&mut self, batch: usize, seq: usize, train: bool) -> ClsBatch {
        let mut tokens = Vec::with_capacity(batch * seq);
        let mut labels_i = Vec::with_capacity(batch);
        let mut labels_f = Vec::with_capacity(batch);
        // split rngs; eval stream is disjoint from train by stream id
        let task = self.task;
        let off = self.vocab_offset;
        let mut tmp = self.clone();
        tmp.task = task;
        tmp.vocab_offset = off;
        let rng = if train { &mut self.rng_train } else { &mut self.rng_eval };
        for _ in 0..batch {
            let (t, li, lf) = tmp.example(rng, seq);
            tokens.extend(t);
            labels_i.push(li);
            labels_f.push(lf);
        }
        ClsBatch {
            tokens,
            labels_i,
            labels_f,
            regression: task == 2,
            batch,
            seq,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_tasks_generate_valid_batches() {
        for task in 0..8 {
            let mut g = GlueSim::new(task, 1);
            let b = g.batch(8, 32, true);
            assert_eq!(b.tokens.len(), 8 * 32, "task {task}");
            assert!(b.tokens.iter().all(|&t| (0..256).contains(&t)));
            let k = g.n_classes() as i32;
            if !g.regression() {
                assert!(b.labels_i.iter().all(|&l| l >= 0 && l < k), "task {task}");
            } else {
                assert!(b.labels_f.iter().all(|&l| (0.0..=1.0).contains(&l)));
            }
        }
    }

    #[test]
    fn labels_are_roughly_balanced() {
        for task in [0usize, 1, 3, 4, 6, 7] {
            let mut g = GlueSim::new(task, 2);
            let mut ones = 0;
            let n = 400;
            let b = g.batch(n, 32, true);
            for &l in &b.labels_i {
                ones += (l == 1) as usize;
            }
            let frac = ones as f64 / n as f64;
            assert!((0.25..=0.75).contains(&frac), "task {task} frac {frac}");
        }
    }

    #[test]
    fn train_and_eval_splits_differ() {
        let mut g = GlueSim::new(4, 3);
        let tr = g.batch(4, 32, true);
        let ev = g.batch(4, 32, false);
        assert_ne!(tr.tokens, ev.tokens);
    }

    #[test]
    fn sst2_label_matches_lexicon_majority() {
        let mut g = GlueSim::new(4, 4);
        let b = g.batch(64, 32, true);
        // recompute the label from the token stream for each row
        for r in 0..64 {
            let row = &b.tokens[r * 32..(r + 1) * 32];
            let pos = row.iter().filter(|&&t| (32..48).contains(&t)).count();
            let neg = row.iter().filter(|&&t| (48..64).contains(&t)).count();
            if pos + neg > 0 {
                let want = (pos > neg) as i32;
                // ties can go either way at generation; skip exact ties
                if pos != neg {
                    assert_eq!(b.labels_i[r], want, "row {r}: pos={pos} neg={neg}");
                }
            }
        }
    }

    #[test]
    fn vocab_offset_shifts_distribution() {
        let mut a = GlueSim::new(1, 5);
        let mut b = GlueSim::new(1, 5).with_offset(48);
        let ba = a.batch(16, 32, true);
        let bb = b.batch(16, 32, true);
        // offset task should use a visibly different token histogram
        let hist = |xs: &[i32]| {
            let mut h = [0u32; 256];
            for &t in xs {
                h[t as usize] += 1;
            }
            h
        };
        let ha = hist(&ba.tokens);
        let hb = hist(&bb.tokens);
        let l1: u32 = ha.iter().zip(&hb).map(|(x, y)| x.abs_diff(*y)).sum();
        assert!(l1 > 100, "offset did not shift distribution (l1={l1})");
    }

    #[test]
    fn stsb_is_regression() {
        let g = GlueSim::new(2, 6);
        assert!(g.regression());
        assert_eq!(g.n_classes(), 1);
    }

    #[test]
    fn mnli_has_three_classes() {
        let mut g = GlueSim::new(5, 7);
        assert_eq!(g.n_classes(), 3);
        let b = g.batch(200, 32, true);
        let mut seen = [false; 3];
        for &l in &b.labels_i {
            seen[l as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
