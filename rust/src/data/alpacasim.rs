//! Alpaca-sim: synthetic instruction-following pairs (DESIGN.md §5).
//!
//! Each example is `BOS <instruction> SEP <response> PERIOD pad...` where the
//! response is a *computable function* of the instruction, drawn from a small
//! task grammar:
//!
//!   reverse   — respond with the payload tokens reversed
//!   echo      — respond with the payload verbatim
//!   last      — respond with the final payload token repeated 3x
//!   swapcase  — respond with each payload token xor'd within its alphabet
//!
//! Finetuning on this distribution is a strong-format domain shift relative
//! to the C4-sim pretraining stream (new control structure, new conditional
//! dependencies), which is exactly the regime the paper's §3.1 targets.
//! Loss is masked to the response span (targets = -1 elsewhere), matching
//! instruction-tuning practice.

use super::{LmBatch, LmStream, SEP};
use crate::util::rng::Pcg64;

const PERIOD: i32 = 4;
const TASK_TOKENS: [i32; 4] = [16, 17, 18, 19]; // one marker token per task
const PAYLOAD_LO: i32 = 32;
const PAYLOAD_SPAN: i32 = 64;

pub struct AlpacaSim {
    rng: Pcg64,
    /// restrict to a subset of tasks (ablations / eval splits)
    pub tasks: Vec<usize>,
}

impl AlpacaSim {
    pub fn new(seed: u64) -> Self {
        AlpacaSim { rng: Pcg64::with_stream(seed, 0xA1), tasks: vec![0, 1, 2, 3] }
    }

    /// Build one example; returns (tokens, targets) of length `seq`.
    fn example(&mut self, seq: usize) -> (Vec<i32>, Vec<i32>) {
        let task = self.tasks[self.rng.below(self.tasks.len())];
        let payload_len = 3 + self.rng.below(8);
        let payload: Vec<i32> = (0..payload_len)
            .map(|_| PAYLOAD_LO + self.rng.below(PAYLOAD_SPAN as usize) as i32)
            .collect();

        let response: Vec<i32> = match task {
            0 => payload.iter().rev().copied().collect(),
            1 => payload.clone(),
            2 => vec![payload[payload.len() - 1]; 3],
            _ => payload.iter().map(|&t| PAYLOAD_LO + ((t - PAYLOAD_LO) ^ 1)).collect(),
        };

        let mut tokens = Vec::with_capacity(seq);
        tokens.push(super::BOS);
        tokens.push(TASK_TOKENS[task]);
        tokens.extend_from_slice(&payload);
        tokens.push(SEP);
        let resp_start = tokens.len();
        tokens.extend_from_slice(&response);
        tokens.push(PERIOD);
        tokens.truncate(seq);
        let used = tokens.len();
        tokens.resize(seq, super::PAD);

        // next-token targets, masked to the response span (the token BEFORE
        // each response position predicts it, so the mask starts at
        // resp_start-1 in target space).
        let mut targets = vec![-1i32; seq];
        for j in 0..seq - 1 {
            let predicts = j + 1; // position the target lives at
            if predicts >= resp_start && predicts < used {
                targets[j] = tokens[predicts];
            }
        }
        (tokens, targets)
    }

    /// Serialize the stream cursor (rng position + active task subset).
    pub fn state_save(&self, bag: &mut crate::session::state::StateBag, prefix: &str) {
        bag.put_u64s(&format!("{prefix}.rng"), self.rng.to_parts().to_vec());
        bag.put_u64s(&format!("{prefix}.tasks"), self.tasks.iter().map(|&t| t as u64).collect());
    }

    /// Restore a cursor written by [`Self::state_save`].
    pub fn state_load(
        &mut self,
        bag: &crate::session::state::StateBag,
        prefix: &str,
    ) -> anyhow::Result<()> {
        let rng = bag.u64s(&format!("{prefix}.rng"))?;
        if rng.len() != 4 {
            anyhow::bail!("alpacasim rng state wants 4 words, checkpoint has {}", rng.len());
        }
        let tasks: Vec<usize> =
            bag.u64s(&format!("{prefix}.tasks"))?.iter().map(|&t| t as usize).collect();
        if tasks.is_empty() || tasks.iter().any(|&t| t >= 4) {
            anyhow::bail!("alpacasim cursor has invalid task subset {tasks:?}");
        }
        self.rng = Pcg64::from_parts([rng[0], rng[1], rng[2], rng[3]]);
        self.tasks = tasks;
        Ok(())
    }
}

impl LmStream for AlpacaSim {
    fn next_batch(&mut self, batch: usize, seq: usize) -> LmBatch {
        let mut tokens = Vec::with_capacity(batch * seq);
        let mut targets = Vec::with_capacity(batch * seq);
        for _ in 0..batch {
            let (t, g) = self.example(seq);
            tokens.extend(t);
            targets.extend(g);
        }
        LmBatch { tokens, targets, batch, seq }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn responses_are_functions_of_instructions() {
        let mut a = AlpacaSim::new(1);
        a.tasks = vec![0]; // reverse only
        let (tokens, _) = a.example(64);
        // parse: BOS task payload... SEP response... PERIOD
        let sep = tokens.iter().position(|&t| t == SEP).unwrap();
        let payload = &tokens[2..sep];
        let period = tokens[sep + 1..].iter().position(|&t| t == PERIOD).unwrap() + sep + 1;
        let response = &tokens[sep + 1..period];
        let want: Vec<i32> = payload.iter().rev().copied().collect();
        assert_eq!(response, &want[..]);
    }

    #[test]
    fn loss_mask_covers_only_response() {
        let mut a = AlpacaSim::new(2);
        let (tokens, targets) = a.example(64);
        let sep = tokens.iter().position(|&t| t == SEP).unwrap();
        // everything predicting positions <= sep must be masked
        for j in 0..sep {
            assert_eq!(targets[j], -1, "instruction position {j} not masked");
        }
        // at least one unmasked target exists and matches the next token
        let live: Vec<usize> = (0..63).filter(|&j| targets[j] >= 0).collect();
        assert!(!live.is_empty());
        for &j in &live {
            assert_eq!(targets[j], tokens[j + 1]);
        }
    }

    #[test]
    fn batches_have_variety() {
        let mut a = AlpacaSim::new(3);
        let b = a.next_batch(8, 64);
        let first_row = &b.tokens[..64];
        let any_diff = (1..8).any(|r| &b.tokens[r * 64..(r + 1) * 64] != first_row);
        assert!(any_diff);
    }

    #[test]
    fn swapcase_is_involution() {
        let mut a = AlpacaSim::new(4);
        a.tasks = vec![3];
        let (tokens, _) = a.example(64);
        let sep = tokens.iter().position(|&t| t == SEP).unwrap();
        let payload = &tokens[2..sep];
        let period = tokens[sep + 1..].iter().position(|&t| t == PERIOD).unwrap() + sep + 1;
        let response = &tokens[sep + 1..period];
        let back: Vec<i32> =
            response.iter().map(|&t| PAYLOAD_LO + ((t - PAYLOAD_LO) ^ 1)).collect();
        assert_eq!(&back[..], payload);
    }
}
