//! C4-sim: a synthetic web-text stand-in for LM pretraining (DESIGN.md §5).
//!
//! Structure mirrors what makes natural text learnable by a byte-level LM:
//!   * a ~1.5k-"word" lexicon whose byte spellings follow per-character
//!     bigram structure (so even within words there is local predictability),
//!   * Zipf-distributed word frequencies,
//!   * topic states: a hidden topic biases which lexicon slice is sampled
//!     and switches with small probability per word (mid-range structure),
//!   * spaces (a dedicated token) between words, sentences ended by a
//!     period token followed by a capital-ish marker.
//!
//! A transformer trained on this stream drops from ~ln(256) nats/token to a
//! much lower plateau, giving perplexity curves with the same qualitative
//! shape as C4 pretraining in the paper.

use super::{LmBatch, LmStream};
use crate::util::rng::Pcg64;

const SPACE: i32 = 3;
const PERIOD: i32 = 4;
/// Byte alphabet for word spellings (avoid the reserved control tokens).
const ALPHA_LO: i32 = 8;
const ALPHA_HI: i32 = 255;

const N_WORDS: usize = 1536;
const N_TOPICS: usize = 8;
const TOPIC_SWITCH_P: f64 = 0.03;

pub struct C4Sim {
    lexicon: Vec<Vec<i32>>,
    /// cumulative Zipf weights per topic (each topic re-ranks a slice)
    topic_cum: Vec<Vec<f64>>,
    topic: usize,
    rng: Pcg64,
    /// carry-over tokens between batches so the stream is continuous
    pending: Vec<i32>,
    words_until_sentence_end: usize,
}

impl C4Sim {
    pub fn new(seed: u64) -> Self {
        let mut lex_rng = Pcg64::with_stream(seed, 0xC4);
        // per-character bigram tendency: next char ~ prev char + small jump
        let mut lexicon = Vec::with_capacity(N_WORDS);
        for _ in 0..N_WORDS {
            let len = 2 + lex_rng.below(5);
            let mut w = Vec::with_capacity(len);
            let span = (ALPHA_HI - ALPHA_LO + 1) as usize;
            let mut c = ALPHA_LO + lex_rng.below(span) as i32;
            for _ in 0..len {
                w.push(c);
                let jump = lex_rng.below(17) as i32 - 8; // local moves
                c = ALPHA_LO + (((c - ALPHA_LO + jump).rem_euclid(span as i32)) as i32);
            }
            lexicon.push(w);
        }

        // Zipf ranks permuted per topic: each topic prefers its own slice.
        let mut topic_cum = Vec::with_capacity(N_TOPICS);
        for t in 0..N_TOPICS {
            let mut perm_rng = Pcg64::with_stream(seed, 0x700 + t as u64);
            let mut ranks: Vec<usize> = (0..N_WORDS).collect();
            perm_rng.shuffle(&mut ranks);
            let mut cum = Vec::with_capacity(N_WORDS);
            let mut acc = 0.0;
            for w in 0..N_WORDS {
                // weight of word w under this topic = 1/(rank+1)
                let r = ranks[w];
                acc += 1.0 / (r as f64 + 1.0);
                cum.push(acc);
            }
            topic_cum.push(cum);
        }

        C4Sim {
            lexicon,
            topic_cum,
            topic: 0,
            rng: Pcg64::with_stream(seed, 0x5EED),
            pending: vec![super::BOS],
            words_until_sentence_end: 8,
        }
    }

    fn emit_word(&mut self) {
        if self.rng.uniform() < TOPIC_SWITCH_P {
            self.topic = self.rng.below(N_TOPICS);
        }
        let w = self.rng.categorical_cum(&self.topic_cum[self.topic]);
        self.pending.extend_from_slice(&self.lexicon[w]);
        if self.words_until_sentence_end == 0 {
            self.pending.push(PERIOD);
            self.words_until_sentence_end = 3 + self.rng.below(12);
        } else {
            self.pending.push(SPACE);
            self.words_until_sentence_end -= 1;
        }
    }

    fn fill(&mut self, n: usize) {
        while self.pending.len() < n {
            self.emit_word();
        }
    }

    /// Serialize the stream cursor (topic, rng, carry-over tokens) under
    /// `prefix`. The lexicon and topic tables are pure functions of the
    /// seed and are rebuilt by `new` on resume.
    pub fn state_save(&self, bag: &mut crate::session::state::StateBag, prefix: &str) {
        bag.put_usize(&format!("{prefix}.topic"), self.topic);
        bag.put_u64s(&format!("{prefix}.rng"), self.rng.to_parts().to_vec());
        bag.put_u64s(
            &format!("{prefix}.pending"),
            self.pending.iter().map(|&t| t as u32 as u64).collect(),
        );
        bag.put_usize(&format!("{prefix}.wuse"), self.words_until_sentence_end);
    }

    /// Restore a cursor written by [`Self::state_save`] into a stream built
    /// with the same seed.
    pub fn state_load(
        &mut self,
        bag: &crate::session::state::StateBag,
        prefix: &str,
    ) -> anyhow::Result<()> {
        let topic = bag.get_usize(&format!("{prefix}.topic"))?;
        if topic >= N_TOPICS {
            anyhow::bail!("c4sim cursor topic {topic} out of range {N_TOPICS}");
        }
        let rng = bag.u64s(&format!("{prefix}.rng"))?;
        if rng.len() != 4 {
            anyhow::bail!("c4sim rng state wants 4 words, checkpoint has {}", rng.len());
        }
        let pending: Vec<i32> =
            bag.u64s(&format!("{prefix}.pending"))?.iter().map(|&w| w as u32 as i32).collect();
        let wuse = bag.get_usize(&format!("{prefix}.wuse"))?;
        self.topic = topic;
        self.rng = Pcg64::from_parts([rng[0], rng[1], rng[2], rng[3]]);
        self.pending = pending;
        self.words_until_sentence_end = wuse;
        Ok(())
    }
}

impl LmStream for C4Sim {
    fn next_batch(&mut self, batch: usize, seq: usize) -> LmBatch {
        // We need seq+1 tokens per row to form (tokens, next-token targets).
        let mut tokens = Vec::with_capacity(batch * seq);
        let mut targets = Vec::with_capacity(batch * seq);
        for _ in 0..batch {
            self.fill(seq + 1);
            let row: Vec<i32> = self.pending.drain(..seq + 1).collect();
            // keep the last token as the head of the next row for continuity
            self.pending.insert(0, row[seq]);
            tokens.extend_from_slice(&row[..seq]);
            targets.extend_from_slice(&row[1..seq + 1]);
        }
        LmBatch { tokens, targets, batch, seq }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_has_low_order_structure() {
        // Empirical unigram entropy must sit well below log2(256): Zipf +
        // separators concentrate mass.
        let mut s = C4Sim::new(3);
        let mut counts = [0u64; 256];
        let mut total = 0u64;
        for _ in 0..50 {
            let b = s.next_batch(4, 64);
            for &t in &b.tokens {
                counts[t as usize] += 1;
                total += 1;
            }
        }
        let mut h = 0.0f64;
        for &c in &counts {
            if c > 0 {
                let p = c as f64 / total as f64;
                h -= p * p.log2();
            }
        }
        assert!(h < 7.5, "unigram entropy {h} too close to uniform 8.0");
        assert!(h > 3.0, "unigram entropy {h} suspiciously low");
    }

    #[test]
    fn bigram_beats_unigram() {
        // conditional entropy H(x_t | x_{t-1}) must be clearly below H(x_t):
        // that's the structure the LM is supposed to learn.
        let mut s = C4Sim::new(4);
        let mut uni = std::collections::HashMap::<i32, u64>::new();
        let mut bi = std::collections::HashMap::<(i32, i32), u64>::new();
        let mut prev: Option<i32> = None;
        for _ in 0..100 {
            let b = s.next_batch(2, 64);
            for &t in &b.tokens {
                *uni.entry(t).or_default() += 1;
                if let Some(p) = prev {
                    *bi.entry((p, t)).or_default() += 1;
                }
                prev = Some(t);
            }
        }
        let total: u64 = uni.values().sum();
        let h_uni: f64 = uni
            .values()
            .map(|&c| {
                let p = c as f64 / total as f64;
                -p * p.log2()
            })
            .sum();
        let bt: u64 = bi.values().sum();
        let mut h_joint = 0.0;
        for &c in bi.values() {
            let p = c as f64 / bt as f64;
            h_joint -= p * p.log2();
        }
        let h_cond = h_joint - h_uni; // H(Y|X) = H(X,Y) - H(X)
        assert!(
            h_cond < h_uni - 0.5,
            "conditional {h_cond} not below unigram {h_uni}"
        );
    }

    #[test]
    fn continuity_across_batches() {
        // the stream must not reset between batches (pretraining semantics)
        let mut a = C4Sim::new(5);
        let b1 = a.next_batch(1, 32);
        let b2 = a.next_batch(1, 32);
        assert_ne!(b1.tokens, b2.tokens);
        // the carried token: last target of row == first token of next row
        assert_eq!(b1.targets[31], b2.tokens[0]);
    }
}
