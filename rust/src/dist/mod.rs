//! The distributed layer: data-parallel worker replicas over the
//! streaming `GradSink` contract.
//!
//! ROADMAP open item 4 ("the millions-of-users scaling axis"): PR 5's
//! streaming gradient emission order is a ready-made communication
//! schedule. Each of N in-process worker replicas (threads today; the
//! message protocol is process-ready — see below) runs the backend's
//! forward/backward on its OWN microbatch slice, and every gradient shard
//! is shipped to the reducer the moment it finalizes, so reduction of
//! microbatch k overlaps the remaining backward work of microbatches
//! k+1.. on the other replicas.
//!
//! ## Reduction-order contract (the bitwise-invariance argument)
//!
//! Float addition is not associative, so a naive partial-sum-per-replica +
//! tree all-reduce would change bits with the replica count. This layer
//! never sums across replicas at all: microbatch OWNERSHIP is round-robin
//! (replica j owns global microbatches j, j+N, j+2N, ...), each replica
//! computes its microbatches' full shard values independently (the
//! backend is bitwise-deterministic, so replica placement cannot change a
//! shard's bits), and the single reducer folds microbatch k's shards into
//! the step's real `GradSink` in ascending k — for each micro, shards in
//! the backend's emission order, exactly one `begin_micro(k == 0)` +
//! `consume` sequence per micro. That is ARITHMETIC-IDENTICAL to the
//! sequential loop the trainer runs at `--replicas 1`: the same
//! additions, on the same values, in the same order. **Replica count is
//! therefore bitwise-invariant by construction** (1 == 2 == 4 replicas:
//! loss bits, eval bits, post-step param bits) — pinned by the unit tests
//! below, the replicated grid in tests/grad_check.rs, and the replicated
//! suspend/resume leg in tests/session_resume.rs.
//!
//! ## Scheduling / residency
//!
//! * Replica threads are wrapped in [`pool::run_inline`], so every kernel
//!   dispatch they issue runs inline on the replica's own thread: N
//!   replicas use N threads total and never grab the process-wide kernel
//!   pool (or spawn scoped workers) underneath each other.
//! * Shards travel over bounded channels ([`CHANNEL_SHARDS`] slots per
//!   replica), so in-flight gradient residency is capped at
//!   `replicas × CHANNEL_SHARDS × largest shard` on top of the sink's own
//!   retention — streaming, never a per-replica dense gradient table.
//! * The reducer is the CALLING thread (it owns the step's sink), so the
//!   sink needs no `Sync` and the sink-side counters
//!   (`SinkConsumeCalls`/`SinkConsumedElems`, the leg-invariant obs
//!   subset) are bumped exactly as often as on the sequential path.
//!
//! ## Process-readiness
//!
//! [`Msg`] is deliberately a plain owned-data protocol (param index +
//! `Vec<f32>` | loss | error string): replacing the mpsc channel with a
//! socket/shared-memory transport and the `Backend::replicate()` call
//! with process spawn is a transport swap, not a redesign. The reduction
//! order contract is transport-independent.
//!
//! `--replicas 1` (the default, `PALLAS_REPLICAS`) takes the exact
//! sequential path — byte-for-byte the loop the trainer always ran — and
//! backends that cannot replicate (PJRT's device handles) fall back to it
//! at any setting, so replication is a pure throughput/residency
//! capability, never a results change.

use anyhow::Result;

use crate::backend::{Backend, Targets};
use crate::grads::GradSink;
use crate::model::ParamStore;
use crate::obs::{self, Counter, Span};
use crate::util::pool;

/// Bounded per-replica channel capacity, in messages (≈ shards): caps
/// in-flight gradient bytes at `replicas × CHANNEL_SHARDS × largest
/// shard` while still letting a replica run ahead into its next shard
/// during the reducer's fold.
const CHANNEL_SHARDS: usize = 2;

/// One replica→reducer message. Owned data only — see the module docs'
/// process-readiness note.
enum Msg {
    /// One finalized gradient shard of the replica's CURRENT microbatch.
    Shard { idx: usize, grad: Vec<f32> },
    /// The current microbatch finished; `loss` is its mean-loss term.
    End { loss: f64 },
    /// The replica's forward/backward failed; the run must abort.
    Err(String),
}

/// Worker-side capture sink: forwards each shard to the reducer the
/// moment the backward pass finalizes it. Deliberately does NOT run the
/// `sink_probe` instrumentation — only the reducer's fold into the real
/// sink counts, so `SinkConsumeCalls`/`SinkConsumedElems` (leg-invariant
/// counters) match the sequential path exactly.
struct ChannelSink<'a> {
    tx: &'a std::sync::mpsc::SyncSender<Msg>,
    /// The reducer hung up (it bailed on another replica's error); stop
    /// producing.
    dead: bool,
}

impl GradSink for ChannelSink<'_> {
    fn consume(&mut self, idx: usize, grad: &[f32]) {
        if self.dead {
            return;
        }
        if self.tx.send(Msg::Shard { idx, grad: grad.to_vec() }).is_err() {
            self.dead = true;
        }
    }
}

/// Drive one optimizer step's microbatches through `sink` — arm it
/// (`begin_micro(k == 0)`), run the fwd/bwd, fold, repeat — returning the
/// SUMMED microbatch loss. THE entry point for every gradient route in
/// the trainer (main streaming pass, selection replays, dense staging):
/// sequential at `--replicas 1`, data-parallel over
/// `min(replicas, micro.len())` worker replicas otherwise, with bitwise
/// identical results either way (module docs).
pub fn drive_micros(
    backend: &mut dyn Backend,
    store: &ParamStore,
    micro: &[(&[i32], Targets<'_>)],
    sink: &mut dyn GradSink,
) -> Result<f64> {
    let r = crate::util::replicas().min(micro.len());
    if r <= 1 {
        return drive_sequential(backend, store, micro, sink);
    }
    let mut engines = Vec::with_capacity(r);
    for _ in 0..r {
        match backend.replicate() {
            Some(be) => engines.push(be),
            // engine can't replicate (PJRT): the sequential path computes
            // the same bits, so this is a silent capability fallback
            None => return drive_sequential(backend, store, micro, sink),
        }
    }
    drive_replicated(engines, store, micro, sink)
}

/// The exact per-microbatch loop the trainer always ran — byte-for-byte
/// the `--replicas 1` path and the arithmetic reference the replicated
/// fold must (and does) reproduce.
fn drive_sequential(
    backend: &mut dyn Backend,
    store: &ParamStore,
    micro: &[(&[i32], Targets<'_>)],
    sink: &mut dyn GradSink,
) -> Result<f64> {
    let mut loss = 0.0f64;
    for (k, (tokens, targets)) in micro.iter().enumerate() {
        let _sp = obs::span(Span::FwdBwd);
        sink.begin_micro(k == 0);
        loss += backend.forward_backward(store, tokens, *targets, sink)?;
    }
    Ok(loss)
}

fn drive_replicated(
    engines: Vec<Box<dyn Backend + Send>>,
    store: &ParamStore,
    micro: &[(&[i32], Targets<'_>)],
    sink: &mut dyn GradSink,
) -> Result<f64> {
    let r = engines.len();
    let mut txs = Vec::with_capacity(r);
    let mut rxs = Vec::with_capacity(r);
    for _ in 0..r {
        let (tx, rx) = std::sync::mpsc::sync_channel::<Msg>(CHANNEL_SHARDS);
        txs.push(tx);
        rxs.push(rx);
    }
    std::thread::scope(|s| -> Result<f64> {
        for (j, (engine, tx)) in engines.into_iter().zip(txs).enumerate() {
            s.spawn(move || replica_worker(engine, store, micro, j, r, tx));
        }
        // The reducer: fold microbatch k's stream from replica k % r, in
        // ascending k — the arithmetic twin of `drive_sequential`.
        let mut loss = 0.0f64;
        for k in 0..micro.len() {
            obs::add(Counter::DistMicros, 1);
            let _sp = obs::span(Span::DistReduce);
            sink.begin_micro(k == 0);
            loop {
                match rxs[k % r].recv() {
                    Ok(Msg::Shard { idx, grad }) => {
                        obs::add(Counter::DistReducedBytes, crate::memory::F32 * grad.len() as u64);
                        sink.consume(idx, &grad);
                    }
                    Ok(Msg::End { loss: l }) => {
                        loss += l;
                        break;
                    }
                    Ok(Msg::Err(e)) => {
                        anyhow::bail!("dist: replica {} failed on microbatch {k}: {e}", k % r)
                    }
                    Err(_) => {
                        anyhow::bail!("dist: replica {} hung up mid-microbatch {k}", k % r)
                    }
                }
            }
        }
        Ok(loss)
        // on an early bail the receivers drop here: every blocked replica
        // send fails, ChannelSink marks itself dead, and the workers wind
        // down before the scope joins them
    })
}

/// One replica thread: run the owned microbatches (global indices
/// `j, j+r, j+2r, ...`, ascending) on a private engine, streaming each
/// shard to the reducer as it finalizes. Inline-marked so the replica's
/// kernel dispatches never touch the shared pool.
fn replica_worker(
    mut engine: Box<dyn Backend + Send>,
    store: &ParamStore,
    micro: &[(&[i32], Targets<'_>)],
    j: usize,
    r: usize,
    tx: std::sync::mpsc::SyncSender<Msg>,
) {
    pool::run_inline(|| {
        let mut sink = ChannelSink { tx: &tx, dead: false };
        for k in (j..micro.len()).step_by(r) {
            let (tokens, targets) = micro[k];
            let _sp = obs::span(Span::FwdBwd);
            match engine.forward_backward(store, tokens, targets, &mut sink) {
                Ok(l) => {
                    if sink.dead || tx.send(Msg::End { loss: l }).is_err() {
                        return; // reducer bailed; nothing left to ship
                    }
                }
                Err(e) => {
                    let _ = tx.send(Msg::Err(format!("{e:#}")));
                    return;
                }
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeBackend;
    use crate::grads::DenseSink;
    use crate::runtime::ParamSpec;
    use crate::util;

    fn grain_backend() -> NativeBackend {
        NativeBackend::with_shape("grain", "lm", 0, 4, 8).unwrap()
    }

    fn filler(n: usize, vocab: usize, salt: usize) -> Vec<i32> {
        (0..n).map(|i| ((i * 31 + salt * 7 + 3) % vocab) as i32).collect()
    }

    /// Run `micros` microbatches through drive_micros into a dense sink,
    /// returning (loss, dense grad tables).
    fn run_once(micros: usize) -> (f64, Vec<Vec<f32>>) {
        let mut be = grain_backend();
        let specs: Vec<ParamSpec> = be.param_specs().to_vec();
        let store = ParamStore::init(&specs, 17);
        let (b, t) = be.batch_shape();
        let vocab = 101usize;
        let data: Vec<(Vec<i32>, Vec<i32>)> = (0..micros)
            .map(|k| (filler(b * t, vocab, k), filler(b * t, vocab, k + 100)))
            .collect();
        let micro: Vec<(&[i32], Targets<'_>)> =
            data.iter().map(|(tok, tgt)| (tok.as_slice(), Targets::Lm(tgt))).collect();
        let mut bufs: Vec<Vec<f32>> = specs.iter().map(|s| vec![0.0f32; s.numel()]).collect();
        let loss = {
            let mut sink = DenseSink::new(&mut bufs);
            drive_micros(&mut be, &store, &micro, &mut sink).unwrap()
        };
        (loss, bufs)
    }

    #[test]
    fn replicated_fold_is_bitwise_identical_to_sequential() {
        let _g = util::test_knob_lock();
        util::set_replicas(1);
        let (loss1, grads1) = run_once(4);
        for &r in &[2usize, 3, 4, 8] {
            util::set_replicas(r); // 8 > micros exercises the min() clamp
            let (lossr, gradsr) = run_once(4);
            assert_eq!(loss1.to_bits(), lossr.to_bits(), "loss bits, replicas={r}");
            for (i, (a, b)) in grads1.iter().zip(&gradsr).enumerate() {
                for (j, (x, y)) in a.iter().zip(b).enumerate() {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "grad bits diverged: tensor {i} elem {j}, replicas={r}"
                    );
                }
            }
        }
        util::reset_replicas();
    }

    #[test]
    fn single_microbatch_takes_the_sequential_path() {
        let _g = util::test_knob_lock();
        util::set_replicas(1);
        let (loss1, grads1) = run_once(1);
        util::set_replicas(4); // clamped to min(4, 1 micro) = sequential
        let (loss4, grads4) = run_once(1);
        assert_eq!(loss1.to_bits(), loss4.to_bits());
        assert_eq!(grads1.len(), grads4.len());
        for (a, b) in grads1.iter().zip(&grads4) {
            assert!(a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits()));
        }
        util::reset_replicas();
    }

    /// A backend that refuses to replicate must silently take the
    /// sequential fallback at any replica setting.
    struct NoReplicate(NativeBackend);

    impl Backend for NoReplicate {
        fn name(&self) -> &'static str {
            "no-replicate"
        }
        fn param_specs(&self) -> &[ParamSpec] {
            self.0.param_specs()
        }
        fn batch_shape(&self) -> (usize, usize) {
            self.0.batch_shape()
        }
        fn forward_backward(
            &mut self,
            store: &ParamStore,
            tokens: &[i32],
            targets: Targets<'_>,
            sink: &mut dyn GradSink,
        ) -> Result<f64> {
            self.0.forward_backward(store, tokens, targets, sink)
        }
        fn eval_batch(
            &mut self,
            store: &ParamStore,
            tokens: &[i32],
            targets: Targets<'_>,
        ) -> Result<crate::backend::EvalOut> {
            self.0.eval_batch(store, tokens, targets)
        }
        fn params_updated(&mut self, active_layers: &[usize]) {
            self.0.params_updated(active_layers)
        }
        fn exec_secs(&self) -> f64 {
            self.0.exec_secs()
        }
        fn exec_calls(&self) -> u64 {
            self.0.exec_calls()
        }
        fn phase_secs(&self) -> [f64; 3] {
            self.0.phase_secs()
        }
        fn activation_bytes(&self) -> u64 {
            self.0.activation_bytes()
        }
        // inherits the default replicate() -> None
    }

    #[test]
    fn non_replicable_backend_falls_back_to_sequential() {
        let _g = util::test_knob_lock();
        util::set_replicas(4);
        let mut be = NoReplicate(grain_backend());
        assert!(be.replicate().is_none());
        let specs: Vec<ParamSpec> = be.param_specs().to_vec();
        let store = ParamStore::init(&specs, 17);
        let (b, t) = be.batch_shape();
        let data: Vec<(Vec<i32>, Vec<i32>)> =
            (0..3).map(|k| (filler(b * t, 101, k), filler(b * t, 101, k + 100))).collect();
        let micro: Vec<(&[i32], Targets<'_>)> =
            data.iter().map(|(tok, tgt)| (tok.as_slice(), Targets::Lm(tgt))).collect();
        let mut bufs: Vec<Vec<f32>> = specs.iter().map(|s| vec![0.0f32; s.numel()]).collect();
        let mut sink = DenseSink::new(&mut bufs);
        let loss = drive_micros(&mut be, &store, &micro, &mut sink).unwrap();
        assert!(loss.is_finite());
        util::reset_replicas();
    }

    #[test]
    fn native_replicas_compute_identical_shard_bits() {
        // placement invariance: a replicate()d engine produces the same
        // fwd/bwd bits as its parent for identical inputs
        let mut parent = grain_backend();
        let mut child = parent.replicate().unwrap();
        assert_eq!(child.exec_calls(), 0, "replica counters start at zero");
        let specs: Vec<ParamSpec> = parent.param_specs().to_vec();
        let store = ParamStore::init(&specs, 23);
        let (b, t) = parent.batch_shape();
        let tok = filler(b * t, 101, 1);
        let tgt = filler(b * t, 101, 2);
        let mut bufs_p: Vec<Vec<f32>> = specs.iter().map(|s| vec![0.0f32; s.numel()]).collect();
        let mut bufs_c = bufs_p.clone();
        let lp = parent
            .forward_backward_dense(&store, &tok, Targets::Lm(&tgt), &mut bufs_p)
            .unwrap();
        let lc =
            child.forward_backward_dense(&store, &tok, Targets::Lm(&tgt), &mut bufs_c).unwrap();
        assert_eq!(lp.to_bits(), lc.to_bits());
        for (a, b) in bufs_p.iter().zip(&bufs_c) {
            assert!(a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits()));
        }
    }
}
