//! Deterministic PRNG (PCG64-XSL-RR) + distributions.
//!
//! The offline crate universe has no `rand` (only `rand_core`), so the
//! project carries its own generator. Determinism matters: every experiment
//! in EXPERIMENTS.md is reproducible from a seed recorded in its config.

/// PCG64 XSL-RR: 128-bit LCG state, 64-bit xor-shift/rotate output.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Independent stream for the same seed (used to split data/init/noise).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Pcg64 { state: 0, inc };
        rng.step();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.step();
        rng
    }

    fn step(&mut self) {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
    }

    pub fn next_u64(&mut self) -> u64 {
        self.step();
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn uniform_f32(&mut self) -> f32 {
        self.uniform() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free-enough for our purposes (n << 2^64).
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller (one draw per call; partner discarded
    /// for simplicity — we're not throughput-bound on init).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-300 {
                let u2 = self.uniform();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fill a slice with N(0, std^2) values.
    pub fn fill_normal(&mut self, buf: &mut [f32], std: f32) {
        for x in buf.iter_mut() {
            *x = self.normal_f32() * std;
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n) (k <= n), order randomized.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        // partial Fisher-Yates: only the first k positions need shuffling
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Snapshot the generator position as four u64 words
    /// `[state_hi, state_lo, inc_hi, inc_lo]` — the checkpoint codec has no
    /// native u128, so the 128-bit LCG state round-trips as halves.
    pub fn to_parts(&self) -> [u64; 4] {
        [
            (self.state >> 64) as u64,
            self.state as u64,
            (self.inc >> 64) as u64,
            self.inc as u64,
        ]
    }

    /// Rebuild a generator at an exact saved position (inverse of
    /// [`Self::to_parts`]): the next draw matches the next draw the saved
    /// generator would have produced, bit for bit.
    pub fn from_parts(parts: [u64; 4]) -> Pcg64 {
        Pcg64 {
            state: ((parts[0] as u128) << 64) | parts[1] as u128,
            inc: ((parts[2] as u128) << 64) | parts[3] as u128,
        }
    }

    /// Sample from a categorical distribution given cumulative weights
    /// (cum must be nondecreasing, last element = total mass).
    pub fn categorical_cum(&mut self, cum: &[f64]) -> usize {
        let total = *cum.last().expect("empty categorical");
        let r = self.uniform() * total;
        match cum.binary_search_by(|c| c.partial_cmp(&r).unwrap()) {
            Ok(i) => (i + 1).min(cum.len() - 1),
            Err(i) => i.min(cum.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Pcg64::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn streams_are_independent() {
        let mut a = Pcg64::with_stream(1, 0);
        let mut b = Pcg64::with_stream(1, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Pcg64::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(11);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_covers_range() {
        let mut r = Pcg64::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg64::new(5);
        for _ in 0..50 {
            let s = r.sample_indices(20, 8);
            assert_eq!(s.len(), 8);
            let mut u = s.clone();
            u.sort_unstable();
            u.dedup();
            assert_eq!(u.len(), 8);
            assert!(u.iter().all(|&i| i < 20));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(9);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn parts_roundtrip_resumes_the_exact_sequence() {
        let mut a = Pcg64::with_stream(42, 0x5EED);
        for _ in 0..17 {
            a.next_u64();
        }
        let mut b = Pcg64::from_parts(a.to_parts());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Pcg64::new(13);
        let cum = [1.0, 1.0, 11.0]; // p = [0.09, 0.0, 0.909]
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.categorical_cum(&cum)] += 1;
        }
        assert!(counts[0] > 500 && counts[0] < 1400, "{counts:?}");
        assert_eq!(counts[1], 0);
        assert!(counts[2] > 8500, "{counts:?}");
    }
}
