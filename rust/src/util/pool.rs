//! Process-wide persistent worker pool for the blocked kernel layer.
//!
//! Every parallel site in the crate (`linalg::gemm::{par_rows, par_rows2,
//! parallel_map}`, `gemm_batched` through them, `NativeBackend`'s rowwise
//! sweeps and LM-head loss) used to pay a fresh `std::thread::scope`
//! spawn/join per call. At serving-scale small shapes (the `grain` preset,
//! the CI matrix legs) that per-call overhead rivals the kernels
//! themselves. This module replaces it with ONE pool of parked workers
//! woken by a per-dispatch work descriptor.
//!
//! ## Determinism contract
//!
//! The pool decides WHICH thread runs a chunk, never WHAT a chunk
//! computes: callers partition their output exactly as before
//! (`gemm::split_rows` on the caller-resolved thread count) and pass only
//! the chunk count here. Each chunk's bits are fixed by the kernel
//! summation contract and disjoint chunks share nothing, so pooled,
//! scoped and inline execution are bitwise identical — pinned by the unit
//! tests below and the pooled-vs-scoped grid in tests/grad_check.rs.
//! The pool's size read (`util::num_threads()`) happens once per dispatch
//! and CANNOT skew a partition: the partition was already fixed by the
//! caller's own read, and first resolution of the knob is CAS-protected
//! so concurrent readers can never observe two different counts
//! (regression-pinned in util's knob tests).
//!
//! ## Lifecycle
//!
//! * Lazy: workers spawn on the first multi-chunk dispatch.
//! * Sized to `util::num_threads() - 1` parked workers
//!   (`PALLAS_NUM_THREADS` / `--threads`) — the dispatching thread always
//!   works the queue too.
//! * `set_num_threads` takes effect on the NEXT dispatch: the dispatch
//!   prologue grows (spawns) or shrinks (parks doomed workers out, then
//!   joins them) the pool while no job is in flight, so resizes are
//!   deterministic and leak-free (pinned by the resize test).
//! * Dispatches serialize: one job is in flight at a time and concurrent
//!   dispatchers queue on the pool's condvar. A dispatch issued from
//!   INSIDE a dispatch (a GEMM inside a `parallel_map` item, on a worker
//!   or on the dispatching thread) runs inline — same bits, no deadlock
//!   (pinned by the reentrancy test).
//! * `PALLAS_POOL=0` / `--pool 0` / `util::set_pool(false)` routes every
//!   dispatch through the legacy per-call `std::thread::scope` path,
//!   kept as the structural parity reference.
//!
//! A job body panic is caught per chunk (the default panic hook still
//! reports it at the throw site), the dispatch drains so nothing touches
//! the job closure after `run` returns, and the dispatcher then re-raises
//! — mirroring `std::thread::scope`'s propagate-on-join semantics.
//! Workers are long-lived, so after each dispatch they clear their
//! thread-local open-span stack (`obs::reset_thread_spans`) — scoped
//! threads got that hygiene for free by dying.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock};

use crate::obs::{self, Counter};
use crate::util;

/// Raw-pointer wrapper asserting cross-thread shareability for the
/// DISJOINT chunk slices the kernel layer reconstructs inside pool jobs
/// (`gemm::par_rows` and friends). Sound because every job touches a
/// distinct index range and [`run`] does not return until every job
/// finished: no two threads alias and no pointer outlives its buffer.
#[derive(Clone, Copy)]
pub(crate) struct SendPtr<T>(pub *mut T);

unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

/// The in-flight job closure, lifetime-erased. Only dereferenced between
/// a worker's adoption and its `active` release; the dispatcher blocks
/// until `active == 0`, so the borrow outlives every use.
type Job = *const (dyn Fn(usize) + Sync + 'static);

#[derive(Clone, Copy)]
struct SendJob(Job);

unsafe impl Send for SendJob {}

struct State {
    /// In-flight dispatch: erased job closure + its job count. `None`
    /// between dispatches — the prologue waits on it, so jobs serialize.
    job: Option<(SendJob, usize)>,
    /// Bumped once per dispatch; a worker adopts a job only when the
    /// epoch moved past the last one it ran, so stale wakeups are inert.
    epoch: u64,
    /// Workers with id `>= target` park out and exit (shrink).
    target: usize,
    /// Workers currently alive (spawned minus exited); ids stay the
    /// contiguous range `0..live` because resizes complete in-prologue.
    live: usize,
    /// Workers currently inside a dispatch's run loop. The dispatcher
    /// drains to 0 so no worker can touch the job closure or the shared
    /// counters after `run` returns.
    active: usize,
}

struct Shared {
    state: Mutex<State>,
    /// Wakes workers: a new epoch, or a lowered `target`.
    work: Condvar,
    /// Wakes dispatchers: job complete, worker exited, or job slot freed.
    done: Condvar,
    /// Next unclaimed job index of the in-flight dispatch.
    next: AtomicUsize,
    /// Jobs finished so far in the in-flight dispatch.
    completed: AtomicUsize,
    /// A job body panicked (re-raised by the dispatcher after the drain).
    panicked: AtomicBool,
}

struct Pool {
    shared: Arc<Shared>,
    /// Join handles, index == worker id (contiguous `0..live`).
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

thread_local! {
    /// True while this thread executes inside a dispatch (pool workers
    /// for their whole life, the dispatcher while its dispatch is live).
    /// Nested [`run`] calls from such a thread execute inline.
    static BUSY: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// Marks the dispatching thread busy for the dispatch's extent; Drop
/// restores the prior flag even when the dispatch re-raises a job panic
/// (restore, not clear, so a nested [`run_inline`] scope can't strip an
/// outer scope's busy marking).
struct BusyGuard {
    prev: bool,
}

impl BusyGuard {
    fn set() -> BusyGuard {
        BusyGuard { prev: BUSY.with(|b| b.replace(true)) }
    }
}

impl Drop for BusyGuard {
    fn drop(&mut self) {
        let prev = self.prev;
        BUSY.with(|b| b.set(prev));
    }
}

fn pool() -> &'static Pool {
    static POOL: OnceLock<Pool> = OnceLock::new();
    POOL.get_or_init(|| Pool {
        shared: Arc::new(Shared {
            state: Mutex::new(State { job: None, epoch: 0, target: 0, live: 0, active: 0 }),
            work: Condvar::new(),
            done: Condvar::new(),
            next: AtomicUsize::new(0),
            completed: AtomicUsize::new(0),
            panicked: AtomicBool::new(false),
        }),
        handles: Mutex::new(Vec::new()),
    })
}

fn lock<'a, T>(m: &'a Mutex<T>) -> MutexGuard<'a, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

fn wait<'a, T>(cv: &Condvar, g: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(g).unwrap_or_else(|e| e.into_inner())
}

/// Execute `f(0), f(1), ..., f(jobs - 1)` exactly once each, possibly
/// concurrently, returning only after ALL of them finished. The sole
/// entry point for the kernel layer's chunk fan-out: pooled by default,
/// per-call scoped threads under `PALLAS_POOL=0`, inline when nested
/// inside another dispatch or when `jobs <= 1`.
pub(crate) fn run(jobs: usize, f: &(dyn Fn(usize) + Sync)) {
    match jobs {
        0 => return,
        1 => return f(0),
        _ => {}
    }
    if BUSY.with(|b| b.get()) {
        // nested dispatch: chunking never changes bits, and waiting on
        // the pool from inside the pool would deadlock — run inline.
        // Checked BEFORE the pool knob so a busy-marked thread (a pool
        // worker, a dispatching caller, or a dist replica thread inside
        // `run_inline`) stays inline even under PALLAS_POOL=0, where
        // scoped spawns would oversubscribe the machine.
        for i in 0..jobs {
            f(i);
        }
        return;
    }
    if !util::pool_on() {
        return run_scoped(jobs, f);
    }
    obs::add(Counter::PoolDispatches, 1);
    pool().dispatch(jobs, f);
}

/// Run `f` with this thread marked busy, so every kernel dispatch it
/// issues executes inline on this thread (no pool hand-off, no scoped
/// spawns). The `dist` layer wraps each replica worker's forward/backward
/// in this: N replica threads already saturate the machine, and chunking
/// never changes bits, so inline execution is the non-oversubscribing
/// schedule with identical results.
pub(crate) fn run_inline<R>(f: impl FnOnce() -> R) -> R {
    let _busy = BusyGuard::set();
    f()
}

/// The legacy per-call spawn/join path (`PALLAS_POOL=0`): the exact
/// scoped-thread shape every call site used before the pool existed —
/// the caller runs job 0 while one scoped worker per remaining job runs
/// the rest. Kept as the structural parity reference for the
/// pooled-vs-scoped bitwise pins.
fn run_scoped(jobs: usize, f: &(dyn Fn(usize) + Sync)) {
    std::thread::scope(|s| {
        for i in 1..jobs {
            s.spawn(move || f(i));
        }
        f(0);
    });
}

/// Live worker count (tests; 0 before the first pooled dispatch).
#[cfg(test)]
pub(crate) fn worker_count() -> usize {
    lock(&pool().shared.state).live
}

impl Pool {
    fn dispatch(&self, jobs: usize, f: &(dyn Fn(usize) + Sync)) {
        let _busy = BusyGuard::set();
        let sh = &*self.shared;
        let mut st = lock(&sh.state);
        while st.job.is_some() {
            st = wait(&sh.done, st); // queue behind the in-flight dispatch
        }
        // Resize between dispatches. One knob read sizes the pool; the
        // partition (and therefore every result bit) was already fixed by
        // the CALLER's own thread-count read, so pool size is pure
        // throughput — the knob-race audit lives in util's tests.
        let target = util::num_threads().saturating_sub(1);
        if target > st.live {
            let mut handles = lock(&self.handles);
            for wid in st.live..target {
                let shared = Arc::clone(&self.shared);
                let h = std::thread::Builder::new()
                    .name(format!("pallas-pool-{wid}"))
                    .spawn(move || worker_loop(&shared, wid))
                    .expect("pool: worker thread spawn failed");
                handles.push(h);
            }
            st.live = target;
            st.target = target;
        } else if target < st.live {
            st.target = target;
            sh.work.notify_all();
            while st.live > target {
                st = wait(&sh.done, st); // doomed workers park out
            }
            let doomed: Vec<_> = lock(&self.handles).drain(target..).collect();
            for h in doomed {
                let _ = h.join(); // leak-free: threads are fully reaped
            }
        }
        // arm the dispatch and wake the workers
        sh.next.store(0, Ordering::Relaxed);
        sh.completed.store(0, Ordering::Relaxed);
        sh.panicked.store(false, Ordering::Relaxed);
        // SAFETY(lifetime erasure): reference-to-raw of the same fat
        // pointee — see `Job`; the drain below keeps the borrow alive
        // past every dereference.
        let raw: Job = unsafe { std::mem::transmute(f) };
        st.job = Some((SendJob(raw), jobs));
        st.epoch = st.epoch.wrapping_add(1);
        drop(st);
        sh.work.notify_all();
        // the dispatching thread works the queue alongside the workers
        let mut own_panic = None;
        loop {
            let i = sh.next.fetch_add(1, Ordering::Relaxed);
            if i >= jobs {
                break;
            }
            if let Err(p) = catch_unwind(AssertUnwindSafe(|| f(i))) {
                sh.panicked.store(true, Ordering::Relaxed);
                own_panic.get_or_insert(p);
            }
            sh.completed.fetch_add(1, Ordering::Relaxed);
        }
        // drain: every job done AND every worker out of its run loop, so
        // nothing touches `f` or the counters past this point (the mutex
        // hand-off also publishes every worker's output writes)
        let mut st = lock(&sh.state);
        while sh.completed.load(Ordering::Relaxed) < jobs || st.active > 0 {
            st = wait(&sh.done, st);
        }
        st.job = None;
        drop(st);
        sh.done.notify_all(); // free the job slot for queued dispatchers
        if let Some(p) = own_panic {
            std::panic::resume_unwind(p);
        }
        if sh.panicked.load(Ordering::Relaxed) {
            panic!("pool: a worker panicked inside a parallel dispatch (reported above)");
        }
    }
}

fn worker_loop(sh: &Shared, wid: usize) {
    BUSY.with(|b| b.set(true)); // job bodies that fan out again run inline
    let mut last_epoch = 0u64;
    loop {
        let job;
        let jobs;
        {
            let mut st = lock(&sh.state);
            loop {
                if wid >= st.target {
                    st.live -= 1;
                    sh.done.notify_all();
                    return; // shrink: park out (the dispatcher joins us)
                }
                if st.epoch != last_epoch {
                    if let Some((j, n)) = st.job {
                        last_epoch = st.epoch;
                        st.active += 1;
                        job = j;
                        jobs = n;
                        break;
                    }
                }
                st = wait(&sh.work, st);
            }
        }
        loop {
            let i = sh.next.fetch_add(1, Ordering::Relaxed);
            if i >= jobs {
                break;
            }
            // SAFETY: the dispatcher keeps the closure alive until this
            // worker's `active` release below.
            let f = unsafe { &*job.0 };
            if catch_unwind(AssertUnwindSafe(|| f(i))).is_err() {
                sh.panicked.store(true, Ordering::Relaxed);
            }
            sh.completed.fetch_add(1, Ordering::Relaxed);
        }
        // long-lived workers must not carry one dispatch's open-span
        // bookkeeping into the next (scoped threads died instead)
        obs::reset_thread_spans();
        let mut st = lock(&sh.state);
        st.active -= 1;
        sh.done.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    // Every test here mutates the process-global thread knob, so they
    // serialize on util's knob lock and restore the previous value.

    #[test]
    fn pooled_scoped_and_inline_agree() {
        let _g = util::test_knob_lock();
        let prev = util::num_threads();
        util::set_pool(true); // the pooled path is under test on EVERY CI leg
        util::set_num_threads(4);
        let want: Vec<u64> = (0..23u64).map(|i| (i + 1) * 7).collect();
        let mut pooled = vec![0u64; 23];
        let base = SendPtr(pooled.as_mut_ptr());
        run(23, &|i| unsafe { *base.0.add(i) = (i as u64 + 1) * 7 });
        assert_eq!(pooled, want);
        let mut scoped = vec![0u64; 23];
        let base = SendPtr(scoped.as_mut_ptr());
        run_scoped(23, &|i| unsafe { *base.0.add(i) = (i as u64 + 1) * 7 });
        assert_eq!(scoped, want);
        util::reset_pool();
        util::set_num_threads(prev);
    }

    #[test]
    fn nested_dispatch_runs_inline_without_deadlock() {
        let _g = util::test_knob_lock();
        let prev = util::num_threads();
        util::set_pool(true); // the pooled path is under test on EVERY CI leg
        util::set_num_threads(4);
        let hits = AtomicUsize::new(0);
        run(4, &|_i| {
            // a dispatch from inside a dispatch (worker OR the
            // dispatching caller) must run inline, not deadlock
            run(3, &|_j| {
                hits.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(hits.load(Ordering::Relaxed), 12);
        util::reset_pool();
        util::set_num_threads(prev);
    }

    #[test]
    fn resize_across_thread_flips_is_leak_free() {
        let _g = util::test_knob_lock();
        let prev = util::num_threads();
        util::set_pool(true); // the pooled path is under test on EVERY CI leg
        for &t in &[8usize, 2, 4, 1, 8] {
            util::set_num_threads(t);
            let n = AtomicUsize::new(0);
            run(8, &|_| {
                n.fetch_add(1, Ordering::Relaxed);
            });
            assert_eq!(n.load(Ordering::Relaxed), 8);
            // the prologue resized to exactly threads - 1 live workers,
            // joining every parked-out thread (no leaked handles)
            assert_eq!(worker_count(), t - 1, "pool must track set_num_threads({t})");
        }
        util::reset_pool();
        util::set_num_threads(prev);
    }

    #[test]
    fn pool_stress_many_tiny_dispatches() {
        let _g = util::test_knob_lock();
        let prev = util::num_threads();
        util::set_pool(true); // the pooled path is under test on EVERY CI leg
        util::set_num_threads(4);
        let total = AtomicU64::new(0);
        for round in 0..2000u64 {
            let jobs = 2 + (round % 7) as usize;
            run(jobs, &|i| {
                total.fetch_add(round * 31 + i as u64, Ordering::Relaxed);
            });
        }
        let mut want = 0u64;
        for round in 0..2000u64 {
            let jobs = 2 + round % 7;
            want += round * 31 * jobs + jobs * (jobs - 1) / 2;
        }
        assert_eq!(total.load(Ordering::Relaxed), want);
        util::reset_pool();
        util::set_num_threads(prev);
    }

    #[test]
    fn run_inline_keeps_dispatches_on_the_calling_thread() {
        let _g = util::test_knob_lock();
        let prev = util::num_threads();
        util::set_num_threads(4);
        // under BOTH dispatch knob settings: a busy-marked thread must run
        // its fan-outs inline (a dist replica thread must never grab the
        // pool or spawn scoped workers underneath N sibling replicas)
        for &pooled in &[true, false] {
            util::set_pool(pooled);
            let caller = std::thread::current().id();
            let ran_on = Mutex::new(Vec::new());
            run_inline(|| {
                run(6, &|_i| {
                    lock(&ran_on).push(std::thread::current().id());
                });
            });
            let ids = lock(&ran_on);
            assert_eq!(ids.len(), 6);
            assert!(
                ids.iter().all(|&id| id == caller),
                "inline scope leaked a dispatch to another thread (pool={pooled})"
            );
        }
        // the busy marking must not outlive the scope
        assert!(!BUSY.with(|b| b.get()));
        util::reset_pool();
        util::set_num_threads(prev);
    }

    #[test]
    fn job_panic_propagates_after_drain_and_pool_survives() {
        let _g = util::test_knob_lock();
        let prev = util::num_threads();
        util::set_pool(true); // the pooled path is under test on EVERY CI leg
        util::set_num_threads(4);
        let done = AtomicUsize::new(0);
        let r = catch_unwind(AssertUnwindSafe(|| {
            run(8, &|i| {
                if i == 3 {
                    panic!("pool test: deliberate job panic");
                }
                done.fetch_add(1, Ordering::Relaxed);
            });
        }));
        assert!(r.is_err(), "a panicking job must fail the dispatch");
        assert_eq!(done.load(Ordering::Relaxed), 7, "every non-panicking job still ran");
        // the pool must stay serviceable after a panicked dispatch
        let n = AtomicUsize::new(0);
        run(4, &|_| {
            n.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(n.load(Ordering::Relaxed), 4);
        util::reset_pool();
        util::set_num_threads(prev);
    }
}
