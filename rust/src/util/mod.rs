//! Hand-rolled substrate utilities (the offline crate universe has no
//! serde/rand/clap — DESIGN.md §8): JSON, PRNG, timing helpers.

pub mod json;
pub mod pool;
pub mod rng;

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Parse an env knob's raw value, warning ONCE per variable on garbage
/// instead of silently falling back: `PALLAS_NUM_THREADS=abc` or
/// `PALLAS_PACK_MIN=-1` used to run with the built-in default and leave
/// no trace of the misconfiguration. The fallback behavior is unchanged —
/// only the silence is fixed.
fn parse_env_knob(env: &str, raw: &str) -> Option<usize> {
    match raw.trim().parse::<usize>() {
        Ok(n) => Some(n),
        Err(_) => {
            static WARNED: Mutex<Vec<String>> = Mutex::new(Vec::new());
            let mut warned = WARNED.lock().unwrap_or_else(|e| e.into_inner());
            if !warned.iter().any(|w| w == env) {
                warned.push(env.to_string());
                eprintln!(
                    "blockllm: warning: ignoring {env}={raw:?} (not an unsigned integer); \
                     using the built-in default"
                );
            }
            None
        }
    }
}

/// Read an env tuning knob: `None` when unset, or unparseable (warned
/// once to stderr). Shared by every `PALLAS_*` resolution path, including
/// `obs`'s `PALLAS_TRACE`.
pub(crate) fn env_knob(env: &str) -> Option<usize> {
    parse_env_knob(env, &std::env::var(env).ok()?)
}

/// Resolved kernel worker count; 0 = not yet resolved. One shared knob so
/// every blocked kernel agrees (DESIGN: the env var is parsed exactly once).
static NUM_THREADS: AtomicUsize = AtomicUsize::new(0);

/// Worker-thread count for the blocked kernel layer (`linalg::gemm`).
///
/// Resolution order: an explicit [`set_num_threads`] call (CLI `--threads`,
/// tests), else the `PALLAS_NUM_THREADS` env var, else the machine's
/// available parallelism. Always >= 1; parsed once and cached. The kernels
/// are bit-for-bit deterministic at ANY setting (they only partition output
/// rows), so this is a pure throughput knob.
pub fn num_threads() -> usize {
    let cur = NUM_THREADS.load(Ordering::Relaxed);
    if cur != 0 {
        return cur;
    }
    let n = env_knob("PALLAS_NUM_THREADS")
        .unwrap_or_else(|| std::thread::available_parallelism().map_or(1, |n| n.get()))
        .max(1);
    // first-time resolution must never clobber a concurrent explicit
    // set_num_threads() override — on a lost race, honor the winner
    match NUM_THREADS.compare_exchange(0, n, Ordering::Relaxed, Ordering::Relaxed) {
        Ok(_) => n,
        Err(winner) => winner,
    }
}

/// Override the kernel worker count (clamped >= 1). Used by `--threads` and
/// by the thread-count-invariance tests; takes effect on the next kernel
/// call.
pub fn set_num_threads(n: usize) {
    NUM_THREADS.store(n.max(1), Ordering::Relaxed);
}

/// Default minimum m*n*k before `linalg::gemm` packs B into column panels
/// and runs the register-tiled microkernel (below it, the direct kernels
/// win — packing a panel costs one pass over B).
pub const DEFAULT_PACK_MIN: usize = 32 * 1024;
/// Default minimum m*n*k before a GEMM fans output rows out across threads.
pub const DEFAULT_PAR_MIN: usize = 64 * 1024;
/// Default minimum element count before an elementwise/rowwise sweep
/// (rmsnorm, rope, softmax, gather/scatter, SiLU·mul) goes parallel.
pub const DEFAULT_PAR_ELEMS: usize = 1 << 15;

// Tuning knobs follow the NUM_THREADS pattern: 0 = unresolved sentinel, the
// resolved value is stored +1 so an explicit 0 ("always on") is
// representable. All knobs are pure THROUGHPUT controls: the packed and
// direct GEMM paths agree bitwise and every parallel sweep is
// thread-count-invariant, so flipping them never changes results.
static PACK_MIN: AtomicUsize = AtomicUsize::new(0);
static PAR_MIN: AtomicUsize = AtomicUsize::new(0);
static PAR_ELEMS_MIN: AtomicUsize = AtomicUsize::new(0);

fn resolve_knob(cell: &AtomicUsize, env: &str, default: usize) -> usize {
    let cur = cell.load(Ordering::Relaxed);
    if cur != 0 {
        return cur - 1;
    }
    let n = env_knob(env).unwrap_or(default);
    let stored = n.saturating_add(1);
    match cell.compare_exchange(0, stored, Ordering::Relaxed, Ordering::Relaxed) {
        Ok(_) => n,
        Err(winner) => winner - 1,
    }
}

/// Minimum m*n*k for the packed-panel microkernel GEMM path
/// (`PALLAS_PACK_MIN` / `--pack-min`; 0 = always pack).
pub fn pack_min_mnk() -> usize {
    resolve_knob(&PACK_MIN, "PALLAS_PACK_MIN", DEFAULT_PACK_MIN)
}

/// Override the packing threshold (tests force 0 = packed everywhere or
/// usize::MAX = direct everywhere; saturates at usize::MAX - 1).
pub fn set_pack_min(n: usize) {
    PACK_MIN.store(n.saturating_add(1), Ordering::Relaxed);
}

/// Minimum m*n*k before a GEMM call goes multi-threaded
/// (`PALLAS_PAR_MIN` / `--par-min`; 0 = parallelize everything).
pub fn par_min_mnk() -> usize {
    resolve_knob(&PAR_MIN, "PALLAS_PAR_MIN", DEFAULT_PAR_MIN)
}

/// Minimum element count before a rowwise/elementwise sweep goes
/// multi-threaded. Shares the `PALLAS_PAR_MIN` knob (with its own default
/// when the knob is unset).
pub fn par_min_elems() -> usize {
    resolve_knob(&PAR_ELEMS_MIN, "PALLAS_PAR_MIN", DEFAULT_PAR_ELEMS)
}

/// Override both parallelism thresholds at once.
pub fn set_par_min(n: usize) {
    let stored = n.saturating_add(1);
    PAR_MIN.store(stored, Ordering::Relaxed);
    PAR_ELEMS_MIN.store(stored, Ordering::Relaxed);
}

/// Restore the packing threshold to its unresolved state: the next read
/// re-resolves `PALLAS_PACK_MIN` (or the built-in default). Re-arming the
/// env var matters in CI's {direct, packed} matrix legs — a test that
/// forced a path must hand back the LEG's forcing, not the built-in
/// default, or every test scheduled after it silently loses the leg's
/// coverage.
pub fn reset_pack_min() {
    PACK_MIN.store(0, Ordering::Relaxed);
}

static ATTN_BATCHED: AtomicUsize = AtomicUsize::new(0);

/// Whether `NativeBackend` attention runs the batched strided-GEMM path
/// (one `gemm_batched` call covering all b·h heads per contraction) or the
/// legacy per-head loop (`PALLAS_ATTN_BATCHED` / `--attn-batched`; default
/// on). The two paths are BITWISE identical at any thread count — pinned
/// by grad_check's matrix test and native.rs unit tests — so this is a
/// pure throughput knob kept for A/B benching and as the parity reference.
pub fn attn_batched() -> bool {
    resolve_knob(&ATTN_BATCHED, "PALLAS_ATTN_BATCHED", 1) != 0
}

/// Override the attention-path selection (tests pin the per-head loop
/// against the batched path with this).
pub fn set_attn_batched(on: bool) {
    ATTN_BATCHED.store(usize::from(on) + 1, Ordering::Relaxed);
}

/// Restore the attention-path knob to its unresolved state: the next read
/// re-resolves `PALLAS_ATTN_BATCHED` (else the batched default) — the same
/// env-re-arming contract as [`reset_pack_min`], so a CI leg forcing the
/// per-head path keeps its coverage after a knob-flipping test finishes.
pub fn reset_attn_batched() {
    ATTN_BATCHED.store(0, Ordering::Relaxed);
}

static GRAD_STREAM: AtomicUsize = AtomicUsize::new(0);

/// Whether the trainer routes gradients through the streaming `GradSink`
/// retention path (`PALLAS_GRAD_STREAM` / `--grad-stream`; default on).
/// On: sparse-capable strategies (BlockLLM, magnitude) retain only compact
/// masked coordinates + streamed norms, so gradient residency is
/// O(active + largest layer). Off: every strategy stages full dense
/// gradients — the legacy behavior, kept as the bitwise parity reference
/// (shard values are identical on both paths; only retention differs, so
/// flipping this never changes results — pinned by grad_check's
/// streaming-vs-dense grid).
pub fn grad_stream() -> bool {
    resolve_knob(&GRAD_STREAM, "PALLAS_GRAD_STREAM", 1) != 0
}

/// Override the gradient-retention path selection (tests pin the dense
/// path against the streaming path with this).
pub fn set_grad_stream(on: bool) {
    GRAD_STREAM.store(usize::from(on) + 1, Ordering::Relaxed);
}

/// Restore the grad-stream knob to its unresolved state: the next read
/// re-resolves `PALLAS_GRAD_STREAM` (else the streaming default) — the
/// same env-re-arming contract as [`reset_pack_min`], so a CI leg forcing
/// the dense path keeps its coverage after a knob-flipping test finishes.
pub fn reset_grad_stream() {
    GRAD_STREAM.store(0, Ordering::Relaxed);
}

static POOL_ON: AtomicUsize = AtomicUsize::new(0);

/// Whether multi-chunk kernel dispatches run on the persistent worker
/// pool ([`pool`]) or on per-call `std::thread::scope` spawns
/// (`PALLAS_POOL` / `--pool`; default on). The pool only changes WHICH
/// thread runs a chunk, never the chunk partition or any summation
/// order, so the two paths are BITWISE identical at any thread count —
/// pinned by pool unit tests and grad_check's pooled-vs-scoped grid.
/// A pure throughput knob, kept as the parity/rollback reference.
pub fn pool_on() -> bool {
    resolve_knob(&POOL_ON, "PALLAS_POOL", 1) != 0
}

/// Override the dispatch-path selection (tests pin scoped vs pooled).
pub fn set_pool(on: bool) {
    POOL_ON.store(usize::from(on) + 1, Ordering::Relaxed);
}

/// Restore the dispatch-path knob to its unresolved state: the next read
/// re-resolves `PALLAS_POOL` (else the pooled default) — the same
/// env-re-arming contract as [`reset_pack_min`], so a CI leg forcing the
/// scoped path keeps its coverage after a knob-flipping test finishes.
pub fn reset_pool() {
    POOL_ON.store(0, Ordering::Relaxed);
}

static REPLICAS: AtomicUsize = AtomicUsize::new(0);

/// Data-parallel replica count for the `dist` layer (`PALLAS_REPLICAS` /
/// `--replicas`; default 1 = the exact sequential path). At N > 1 the
/// trainer fans each optimizer step's microbatches out over N in-process
/// worker replicas and all-reduces gradient shards in a FIXED ascending
/// microbatch order, so the folded bits are identical to the sequential
/// fold at any replica count — pinned by grad_check's replicated grid and
/// session_resume's replicated leg. A pure throughput/residency knob:
/// flipping it never changes a single loss, eval, or parameter bit.
pub fn replicas() -> usize {
    resolve_knob(&REPLICAS, "PALLAS_REPLICAS", 1).max(1)
}

/// Override the replica count (clamped >= 1). Used by `--replicas` and the
/// replica-count-invariance tests; takes effect on the next optimizer step.
pub fn set_replicas(n: usize) {
    REPLICAS.store(n.max(1).saturating_add(1), Ordering::Relaxed);
}

/// Restore the replica-count knob to its unresolved state: the next read
/// re-resolves `PALLAS_REPLICAS` (else the sequential default of 1) — the
/// same env-re-arming contract as [`reset_pack_min`], so a CI leg pinning
/// a replica count keeps its coverage after a knob-flipping test finishes.
pub fn reset_replicas() {
    REPLICAS.store(0, Ordering::Relaxed);
}

/// Restore the worker-count knob to its unresolved state: the next read
/// re-resolves `PALLAS_NUM_THREADS` (else available parallelism) — the
/// same env-re-arming contract as [`reset_pack_min`]. Used by the
/// first-resolution regression test: the CAS in [`num_threads`] must
/// hand every concurrent reader (the chunk partitioner AND the pool's
/// size read) ONE value.
pub fn reset_num_threads() {
    NUM_THREADS.store(0, Ordering::Relaxed);
}

/// Restore BOTH parallelism thresholds to their unresolved state: the next
/// read re-resolves `PALLAS_PAR_MIN` per knob (each with its own distinct
/// default when the env var is unset — `set_par_min` collapses them to one
/// value). Like [`reset_pack_min`], this re-arms an env override rather
/// than pinning the built-in default.
pub fn reset_par_min() {
    PAR_MIN.store(0, Ordering::Relaxed);
    PAR_ELEMS_MIN.store(0, Ordering::Relaxed);
}

/// Restore EVERY throughput knob (threads, pack-min, both par-mins,
/// attn-batched, grad-stream, pool, replicas) to its unresolved state in
/// one sweep — the next read of each re-resolves its env var (else its
/// built-in default). One entry point instead of seven scattered `reset_*`
/// calls so a knob-flipping test — or the serve scheduler handing the
/// backend from one session to the next — can't forget one and leak a
/// forced path (or a tenant's replica count) into whatever runs after it.
/// All seven knobs are bitwise-neutral, so this is hygiene, never a
/// results change.
pub fn reset_all_knobs() {
    reset_num_threads();
    reset_pack_min();
    reset_par_min();
    reset_attn_batched();
    reset_grad_stream();
    reset_pool();
    reset_replicas();
}

/// Serializes tests that mutate the process-global tuning knobs AND assert
/// on their values (the kernels themselves are knob-invariant, so only
/// value assertions need the lock).
#[cfg(test)]
pub(crate) fn test_knob_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Simple wall-clock stopwatch used by the trainer and bench harness.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn millis(&self) -> f64 {
        self.secs() * 1e3
    }

    pub fn restart(&mut self) -> f64 {
        let s = self.secs();
        self.start = Instant::now();
        s
    }
}

/// Format a byte count human-readably (memory tables).
pub fn human_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Current process resident set size in bytes (linux /proc/self/statm).
pub fn rss_bytes() -> u64 {
    let page = 4096u64;
    std::fs::read_to_string("/proc/self/statm")
        .ok()
        .and_then(|s| s.split_whitespace().nth(1).map(str::to_owned))
        .and_then(|f| f.parse::<u64>().ok())
        .map(|pages| pages * page)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_bytes_formats() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn rss_is_positive() {
        assert!(rss_bytes() > 0);
    }

    #[test]
    fn thread_knob_is_clamped_and_overridable() {
        let _g = test_knob_lock(); // value assertions on a global knob
        let prev = num_threads();
        assert!(prev >= 1);
        set_num_threads(3);
        assert_eq!(num_threads(), 3);
        set_num_threads(0); // clamped to >= 1
        assert_eq!(num_threads(), 1);
        set_num_threads(2);
        assert_eq!(num_threads(), 2);
        set_num_threads(prev);
    }

    #[test]
    fn thread_knob_first_resolution_is_single_valued() {
        // Knob-race regression (pool PR): the chunk partitioner and the
        // pool's size read both call num_threads(); if two concurrent
        // FIRST resolutions could return different values, one dispatch
        // could partition for one count and size the pool for another.
        // The CAS hands every racer the winner's value.
        let _g = test_knob_lock();
        let prev = num_threads();
        reset_num_threads();
        let seen: Vec<usize> = std::thread::scope(|s| {
            let hs: Vec<_> = (0..8).map(|_| s.spawn(num_threads)).collect();
            hs.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert!(
            seen.iter().all(|&v| v == seen[0]),
            "concurrent first resolutions diverged: {seen:?}"
        );
        set_num_threads(prev);
    }

    #[test]
    fn env_knob_parse_warns_and_falls_back() {
        // garbage values fall back (warned once to stderr, not asserted
        // here); valid values parse with whitespace trimmed
        assert_eq!(parse_env_knob("PALLAS_TEST_KNOB", "abc"), None);
        assert_eq!(parse_env_knob("PALLAS_TEST_KNOB", "abc"), None); // warn-once path
        assert_eq!(parse_env_knob("PALLAS_TEST_KNOB_B", "-1"), None);
        assert_eq!(parse_env_knob("PALLAS_TEST_KNOB_C", ""), None);
        assert_eq!(parse_env_knob("PALLAS_TEST_KNOB", " 8 "), Some(8));
        assert_eq!(parse_env_knob("PALLAS_TEST_KNOB", "0"), Some(0));
    }

    #[test]
    fn tuning_knobs_resolve_and_override() {
        let _g = test_knob_lock(); // other tests mutate the same atomics
        set_pack_min(7);
        assert_eq!(pack_min_mnk(), 7);
        set_pack_min(0); // "always pack" must be representable
        assert_eq!(pack_min_mnk(), 0);
        set_pack_min(usize::MAX); // saturates one below MAX: effectively "never"
        assert_eq!(pack_min_mnk(), usize::MAX - 1);
        set_par_min(5);
        assert_eq!(par_min_mnk(), 5);
        assert_eq!(par_min_elems(), 5);
        set_attn_batched(false);
        assert!(!attn_batched());
        set_attn_batched(true);
        assert!(attn_batched());
        reset_attn_batched(); // re-arms any env override
        set_grad_stream(false);
        assert!(!grad_stream());
        set_grad_stream(true);
        assert!(grad_stream());
        reset_grad_stream(); // re-arms any env override (CI's dense leg)
        let env_on = |name: &str, default: usize| {
            std::env::var(name).ok().and_then(|s| s.trim().parse::<usize>().ok()).unwrap_or(default)
                != 0
        };
        assert_eq!(grad_stream(), env_on("PALLAS_GRAD_STREAM", 1));
        set_pool(false);
        assert!(!pool_on());
        set_pool(true);
        assert!(pool_on());
        reset_pool(); // re-arms any env override (CI's scoped-dispatch leg)
        assert_eq!(pool_on(), env_on("PALLAS_POOL", 1));
        set_replicas(4);
        assert_eq!(replicas(), 4);
        set_replicas(0); // clamped to >= 1 (0 replicas is meaningless)
        assert_eq!(replicas(), 1);
        reset_replicas(); // re-arms any env override (CI's replicated leg)
        // the reset must re-resolve: the env override when present (CI's
        // {direct, packed} matrix legs), else the DISTINCT built-in defaults
        let env = |name: &str, default: usize| {
            std::env::var(name).ok().and_then(|s| s.trim().parse().ok()).unwrap_or(default)
        };
        reset_pack_min();
        reset_par_min();
        assert_eq!(pack_min_mnk(), env("PALLAS_PACK_MIN", DEFAULT_PACK_MIN));
        assert_eq!(par_min_mnk(), env("PALLAS_PAR_MIN", DEFAULT_PAR_MIN));
        assert_eq!(par_min_elems(), env("PALLAS_PAR_MIN", DEFAULT_PAR_ELEMS));
        assert_eq!(replicas(), env("PALLAS_REPLICAS", 1).max(1));
    }

    #[test]
    fn reset_all_knobs_rearms_every_knob() {
        let _g = test_knob_lock();
        let prev_threads = num_threads();
        // force every knob away from its env/default resolution...
        set_num_threads(prev_threads + 1);
        set_pack_min(1);
        set_par_min(1);
        set_attn_batched(false);
        set_grad_stream(false);
        set_pool(false);
        set_replicas(3);
        // ...then the sweep must hand each back to env-var resolution
        reset_all_knobs();
        let env = |name: &str, default: usize| {
            std::env::var(name).ok().and_then(|s| s.trim().parse().ok()).unwrap_or(default)
        };
        assert_eq!(pack_min_mnk(), env("PALLAS_PACK_MIN", DEFAULT_PACK_MIN));
        assert_eq!(par_min_mnk(), env("PALLAS_PAR_MIN", DEFAULT_PAR_MIN));
        assert_eq!(par_min_elems(), env("PALLAS_PAR_MIN", DEFAULT_PAR_ELEMS));
        assert_eq!(attn_batched(), env("PALLAS_ATTN_BATCHED", 1) != 0);
        assert_eq!(grad_stream(), env("PALLAS_GRAD_STREAM", 1) != 0);
        assert_eq!(pool_on(), env("PALLAS_POOL", 1) != 0);
        assert_eq!(replicas(), env("PALLAS_REPLICAS", 1).max(1));
        assert!(num_threads() >= 1);
        set_num_threads(prev_threads);
    }

    #[test]
    fn stopwatch_monotonic() {
        let sw = Stopwatch::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(sw.secs() >= 0.004);
    }
}
