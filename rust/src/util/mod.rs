//! Hand-rolled substrate utilities (the offline crate universe has no
//! serde/rand/clap — DESIGN.md §8): JSON, PRNG, timing helpers.

pub mod json;
pub mod rng;

use std::time::Instant;

/// Simple wall-clock stopwatch used by the trainer and bench harness.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn millis(&self) -> f64 {
        self.secs() * 1e3
    }

    pub fn restart(&mut self) -> f64 {
        let s = self.secs();
        self.start = Instant::now();
        s
    }
}

/// Format a byte count human-readably (memory tables).
pub fn human_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Current process resident set size in bytes (linux /proc/self/statm).
pub fn rss_bytes() -> u64 {
    let page = 4096u64;
    std::fs::read_to_string("/proc/self/statm")
        .ok()
        .and_then(|s| s.split_whitespace().nth(1).map(str::to_owned))
        .and_then(|f| f.parse::<u64>().ok())
        .map(|pages| pages * page)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_bytes_formats() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn rss_is_positive() {
        assert!(rss_bytes() > 0);
    }

    #[test]
    fn stopwatch_monotonic() {
        let sw = Stopwatch::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(sw.secs() >= 0.004);
    }
}
