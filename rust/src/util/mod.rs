//! Hand-rolled substrate utilities (the offline crate universe has no
//! serde/rand/clap — DESIGN.md §8): JSON, PRNG, timing helpers.

pub mod json;
pub mod rng;

use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Instant;

/// Resolved kernel worker count; 0 = not yet resolved. One shared knob so
/// every blocked kernel agrees (DESIGN: the env var is parsed exactly once).
static NUM_THREADS: AtomicUsize = AtomicUsize::new(0);

/// Worker-thread count for the blocked kernel layer (`linalg::gemm`).
///
/// Resolution order: an explicit [`set_num_threads`] call (CLI `--threads`,
/// tests), else the `PALLAS_NUM_THREADS` env var, else the machine's
/// available parallelism. Always >= 1; parsed once and cached. The kernels
/// are bit-for-bit deterministic at ANY setting (they only partition output
/// rows), so this is a pure throughput knob.
pub fn num_threads() -> usize {
    let cur = NUM_THREADS.load(Ordering::Relaxed);
    if cur != 0 {
        return cur;
    }
    let n = std::env::var("PALLAS_NUM_THREADS")
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
        .unwrap_or_else(|| std::thread::available_parallelism().map_or(1, |n| n.get()))
        .max(1);
    // first-time resolution must never clobber a concurrent explicit
    // set_num_threads() override — on a lost race, honor the winner
    match NUM_THREADS.compare_exchange(0, n, Ordering::Relaxed, Ordering::Relaxed) {
        Ok(_) => n,
        Err(winner) => winner,
    }
}

/// Override the kernel worker count (clamped >= 1). Used by `--threads` and
/// by the thread-count-invariance tests; takes effect on the next kernel
/// call.
pub fn set_num_threads(n: usize) {
    NUM_THREADS.store(n.max(1), Ordering::Relaxed);
}

/// Simple wall-clock stopwatch used by the trainer and bench harness.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn millis(&self) -> f64 {
        self.secs() * 1e3
    }

    pub fn restart(&mut self) -> f64 {
        let s = self.secs();
        self.start = Instant::now();
        s
    }
}

/// Format a byte count human-readably (memory tables).
pub fn human_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Current process resident set size in bytes (linux /proc/self/statm).
pub fn rss_bytes() -> u64 {
    let page = 4096u64;
    std::fs::read_to_string("/proc/self/statm")
        .ok()
        .and_then(|s| s.split_whitespace().nth(1).map(str::to_owned))
        .and_then(|f| f.parse::<u64>().ok())
        .map(|pages| pages * page)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_bytes_formats() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn rss_is_positive() {
        assert!(rss_bytes() > 0);
    }

    #[test]
    fn thread_knob_is_clamped_and_overridable() {
        assert!(num_threads() >= 1);
        set_num_threads(3);
        assert_eq!(num_threads(), 3);
        set_num_threads(0); // clamped to >= 1
        assert_eq!(num_threads(), 1);
        set_num_threads(2);
        assert_eq!(num_threads(), 2);
    }

    #[test]
    fn stopwatch_monotonic() {
        let sw = Stopwatch::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(sw.secs() >= 0.004);
    }
}
