//! Minimal JSON parser/emitter.
//!
//! The offline crate universe for this image has no `serde`/`serde_json`
//! (see DESIGN.md §8), so the manifest/golden/config/metrics interchange is
//! handled by this hand-rolled module. It supports the full JSON grammar
//! (objects, arrays, strings with escapes, numbers, bools, null) which is
//! all aot.py emits.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value. Object keys keep sorted order via BTreeMap (the
/// manifest is order-insensitive; parameter order lives in arrays).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("expected non-negative integer, got {n}");
        }
        Ok(n as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("expected object, got {self:?}"),
        }
    }

    // ---- construction helpers -------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|x| Json::Num(*x)).collect())
    }

    pub fn arr_f32(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|x| Json::Num(*x as f64)).collect())
    }

    // ---- emission ---------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.emit(&mut s);
        s
    }

    fn emit(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    if n.fract() == 0.0 && n.abs() < 1e15 {
                        let _ = write!(out, "{}", *n as i64);
                    } else {
                        let _ = write!(out, "{n}");
                    }
                } else {
                    out.push_str("null"); // JSON has no inf/nan
                }
            }
            Json::Str(s) => emit_str(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.emit(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    emit_str(k, out);
                    out.push(':');
                    v.emit(out);
                }
                out.push('}');
            }
        }
    }
}

fn emit_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected EOF"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}, got {:?}", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected byte {:?} at {}", c as char, self.i),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                c => bail!("expected ',' or ']', got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                self.b.get(self.i..self.i + 4).ok_or_else(|| anyhow!("bad \\u"))?,
                            )?;
                            self.i += 4;
                            let cp = u32::from_str_radix(hex, 16)?;
                            // surrogate pairs
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.b.get(self.i) == Some(&b'\\')
                                    && self.b.get(self.i + 1) == Some(&b'u')
                                {
                                    let hex2 = std::str::from_utf8(
                                        self.b
                                            .get(self.i + 2..self.i + 6)
                                            .ok_or_else(|| anyhow!("bad surrogate"))?,
                                    )?;
                                    self.i += 6;
                                    let lo = u32::from_str_radix(hex2, 16)?;
                                    let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(c).ok_or_else(|| anyhow!("bad codepoint"))?
                                } else {
                                    bail!("lone high surrogate");
                                }
                            } else {
                                char::from_u32(cp).ok_or_else(|| anyhow!("bad codepoint"))?
                            };
                            s.push(ch);
                        }
                        e => bail!("bad escape \\{}", e as char),
                    }
                }
                c => {
                    // re-decode UTF-8 sequences directly from the source
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c)?;
                        let bytes = self
                            .b
                            .get(start..start + len)
                            .ok_or_else(|| anyhow!("truncated utf8"))?;
                        s.push_str(std::str::from_utf8(bytes)?);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }
}

fn utf8_len(b: u8) -> Result<usize> {
    match b {
        0xC0..=0xDF => Ok(2),
        0xE0..=0xEF => Ok(3),
        0xF0..=0xF7 => Ok(4),
        _ => bail!("invalid utf8 lead byte {b:#x}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("  -3.5e2 ").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse(r#""hi""#).unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.req("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.req("c").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let v = Json::parse(r#""é\t\\\" 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é\t\\\" 😀");
    }

    #[test]
    fn parse_utf8_passthrough() {
        let v = Json::parse("\"héllo wörld\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo wörld");
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,-3],"b":false,"nested":{"x":"y"},"s":"a\"b"}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("07x").is_err());
        assert!(Json::parse("{} extra").is_err());
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"n": 7, "f": 1.5}"#).unwrap();
        assert_eq!(v.req("n").unwrap().as_usize().unwrap(), 7);
        assert!(v.req("f").unwrap().as_usize().is_err());
        assert!(v.req("missing").is_err());
    }

    #[test]
    fn roundtrip_profile_record_shape() {
        // The obs `profile` block is the deepest record the logger emits:
        // obj -> obj -> obj with mixed integer counts and fractional ms.
        let rec = Json::obj(vec![
            (
                "profile",
                Json::obj(vec![
                    (
                        "spans",
                        Json::obj(vec![
                            (
                                "fwd.attn",
                                Json::obj(vec![
                                    ("count", Json::num(128.0)),
                                    ("total_ms", Json::num(3.141592653589793)),
                                    ("self_ms", Json::num(0.000001)),
                                ]),
                            ),
                            (
                                "gemm.packed",
                                Json::obj(vec![
                                    ("count", Json::num(1.0e12)),
                                    ("total_ms", Json::num(0.125)),
                                    ("self_ms", Json::num(0.125)),
                                ]),
                            ),
                        ]),
                    ),
                    (
                        "counters",
                        Json::obj(vec![
                            ("gemm.flops", Json::num((1u64 << 53) as f64)),
                            ("log.writes_dropped", Json::num(0.0)),
                        ]),
                    ),
                    ("gauges", Json::obj(vec![])),
                ]),
            ),
            ("run", Json::str("blockllm grain \"quoted\" \\ path\n")),
        ]);
        let text = rec.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back, rec, "profile record must round-trip exactly");
        // integers emit without a fractional part; 2^53 is exact in f64
        assert!(text.contains("\"count\":128"));
        assert!(text.contains("\"gemm.flops\":9007199254740992"));
        // fractional ms survive with full precision
        let spans = back.req("profile").unwrap().req("spans").unwrap();
        let attn = spans.req("fwd.attn").unwrap();
        assert_eq!(attn.req("total_ms").unwrap().as_f64().unwrap(), 3.141592653589793);
        assert_eq!(attn.req("self_ms").unwrap().as_f64().unwrap(), 0.000001);
        // strings with quotes, backslashes and newlines escape correctly
        assert_eq!(
            back.req("run").unwrap().as_str().unwrap(),
            "blockllm grain \"quoted\" \\ path\n"
        );
    }

    #[test]
    fn roundtrip_deep_nesting() {
        // 24 levels of {"p": {"p": ... 7 ...}} — deeper than any profile
        // block we emit, still well inside the parser's recursion budget.
        let mut v = Json::num(7.0);
        for _ in 0..24 {
            v = Json::obj(vec![("p", v)]);
        }
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(back, v);
        let mut cur = &back;
        for _ in 0..24 {
            cur = cur.req("p").unwrap();
        }
        assert_eq!(cur.as_f64().unwrap(), 7.0);
    }
}
