//! `pallas serve`: multiplex many named training sessions over ONE shared
//! execution backend, under a pluggable scheduling policy.
//!
//! # Policies (`--sched`, spec key `"sched"`)
//!
//! * **`rr`** (default) — fair-share round-robin: every runnable tenant
//!   gets a slice of `slice_steps` optimizer steps in roster order.
//! * **`slack`** — earliest-slack-first. A tenant may carry a `deadline`
//!   expressed on the *global clock* (total optimizer steps executed
//!   across all tenants); its slack is `deadline - (clock + remaining)`.
//!   The runnable tenant with the least slack runs next; a running tenant
//!   is preempted mid-slice (at optimizer-step granularity) as soon as a
//!   waiter's slack drops strictly below its own. Deadline-less tenants
//!   have infinite slack and are protected from starvation by an aging
//!   bound: after `starvation_turns` consecutive skipped scheduling
//!   decisions they run next regardless of slack.
//! * **`weighted`** — stride scheduling on per-tenant `weight`: the tenant
//!   with the least virtual time (`steps_run / weight`) runs next, which
//!   converges to weight-proportional step shares; mid-slice preemption
//!   fires when another runnable tenant's virtual time drops strictly
//!   below the runner's.
//!
//! Preemption reuses the same bitwise [`Session::suspend`]/resume
//! machinery as slice boundaries, so *any* interleaving — including
//! evictions and re-admissions — leaves every tenant's losses and final
//! parameters identical to a solo run of the same config
//! (tests/session_resume.rs pins this across policies and thread counts).
//!
//! # Elastic memory budgets
//!
//! Budgets are enforced twice, exactly as before:
//! * **admission** — a tenant's budget must cover
//!   [`Session::modeled_footprint_bytes`] before it runs a single step;
//! * **runtime** — after every turn the budget is re-checked against
//!   [`Session::measured_footprint_bytes`] (the grads layer's MEASURED
//!   `peak_grad_bytes` swapped in for the modeled term).
//!
//! What changed is what "over budget" means for the roster. A tenant with
//! an explicit `budget_mb` keeps the PR 8 semantics: too small at
//! admission is a permanent rejection. Tenants *without* one can instead
//! draw from a spec-level `total_budget_mb` pool, split weight-
//! proportionally among live pool tenants and **re-planned** whenever the
//! roster changes: a tenant whose share shrinks below its demand is
//! evicted (checkpoint kept, state queued), and a queued tenant is
//! automatically re-admitted as soon as headroom frees up — shares grow
//! when other tenants finish. New tenants can be injected into a RUNNING
//! loop via [`ServeLoop::refresh_spec`] (`serve --watch-spec` re-reads the
//! spec file between turns), which triggers the same re-planning.
//!
//! # Observability
//!
//! Scheduling decisions run under obs spans (`serve.schedule`,
//! `serve.preempt`, `serve.readmit`); preemptions, evictions,
//! re-admissions and deadline misses bump counters; peak deadline
//! lateness is tracked by a gauge. Per-tenant totals (turns, preemptions,
//! evictions, re-admissions, final slack) are surfaced in
//! [`ServeOutcome::sched`] and the serve JSON reports. These counters are
//! leg-VARIANT: evictions depend on measured footprints, which differ
//! across the grad-stream CI legs.
//!
//! One backend means one model shape: every session in a spec must agree
//! on preset, task, and backend kind (validated at parse time). Per-turn
//! knob hygiene — `util::reset_all_knobs()` plus the caller's `rearm`
//! closure — guarantees no tenant inherits another's thread-count or
//! gradient-path resolution.

use anyhow::{bail, Context, Result};

use super::Session;
use crate::backend::{self, Backend};
use crate::config::TrainConfig;
use crate::obs::{self, Counter, Gauge, Span};
use crate::trainer::RunResult;
use crate::util::json::Json;

/// Steps per turn when the spec doesn't say.
pub const DEFAULT_SLICE_STEPS: usize = 8;

/// Aging bound (in skipped scheduling decisions) protecting deadline-less
/// tenants from starvation under `slack`, when the spec doesn't say.
pub const DEFAULT_STARVATION_TURNS: u64 = 8;

/// Turn-ordering policy for the serve loop (`--sched`, spec key `"sched"`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedPolicy {
    /// Fair-share round-robin in roster order (the PR 8 behavior).
    RoundRobin,
    /// Earliest-slack-first over per-tenant deadlines, with mid-slice
    /// preemption and an anti-starvation aging bound.
    Slack,
    /// Stride scheduling: weight-proportional step shares.
    Weighted,
}

impl SchedPolicy {
    /// Parse a policy name as accepted by `--sched` / the spec.
    pub fn parse(s: &str) -> Result<SchedPolicy> {
        match s {
            "rr" | "round-robin" => Ok(SchedPolicy::RoundRobin),
            "slack" => Ok(SchedPolicy::Slack),
            "weighted" => Ok(SchedPolicy::Weighted),
            other => bail!("unknown scheduling policy {other:?} (want rr|slack|weighted)"),
        }
    }

    /// The canonical spec/CLI name.
    pub fn name(self) -> &'static str {
        match self {
            SchedPolicy::RoundRobin => "rr",
            SchedPolicy::Slack => "slack",
            SchedPolicy::Weighted => "weighted",
        }
    }
}

/// One tenant in a serve spec.
pub struct SessionSpec {
    /// Unique tenant name (report files, log lines, spec-refresh identity).
    pub name: String,
    /// Explicit memory budget in bytes. `None` = draw from the spec-level
    /// pool when one is configured, else unbudgeted (always admitted).
    pub budget_bytes: Option<u64>,
    /// Share weight for `weighted` scheduling and pool-budget splitting.
    pub weight: u64,
    /// Target finish point on the global clock (total optimizer steps
    /// across all tenants), for `slack` scheduling and miss accounting.
    pub deadline: Option<u64>,
    /// The tenant's full training config.
    pub cfg: TrainConfig,
}

/// A parsed serve spec:
/// `{"slice_steps": 8, "sched": "slack", "total_budget_mb": 64.0,
///   "starvation_turns": 8, "sessions": [{"name": ..., "budget_mb": ...,
///   "weight": 2, "deadline": 40, "config": {<TrainConfig key>: value,
///   ...}}, ...]}`.
pub struct ServeSpec {
    /// Max optimizer steps per turn.
    pub slice_steps: usize,
    /// Turn-ordering policy.
    pub policy: SchedPolicy,
    /// Shared memory pool split among tenants without an explicit budget.
    pub total_budget_bytes: Option<u64>,
    /// Aging bound for deadline-less tenants under `slack`.
    pub starvation_turns: u64,
    /// The roster, in spec order.
    pub sessions: Vec<SessionSpec>,
}

impl ServeSpec {
    /// Parse and structurally validate a JSON serve spec.
    pub fn parse(src: &str) -> Result<ServeSpec> {
        let j = Json::parse(src).context("serve spec is not valid JSON")?;
        let slice_steps = match j.get("slice_steps") {
            Some(v) => v.as_usize().context("slice_steps")?,
            None => DEFAULT_SLICE_STEPS,
        };
        if slice_steps == 0 {
            bail!("slice_steps must be >= 1");
        }
        let policy = match j.get("sched") {
            Some(v) => SchedPolicy::parse(v.as_str().context("sched")?)?,
            None => SchedPolicy::RoundRobin,
        };
        let total_budget_bytes = match j.get("total_budget_mb") {
            Some(v) => {
                let mb = v.as_f64().context("total_budget_mb")?;
                if mb <= 0.0 {
                    bail!("total_budget_mb must be positive, got {mb}");
                }
                Some((mb * 1e6) as u64)
            }
            None => None,
        };
        let starvation_turns = match j.get("starvation_turns") {
            Some(v) => {
                let n = v.as_usize().context("starvation_turns")? as u64;
                if n == 0 {
                    bail!("starvation_turns must be >= 1");
                }
                n
            }
            None => DEFAULT_STARVATION_TURNS,
        };
        let mut sessions = Vec::new();
        for (i, s) in j.req("sessions")?.as_arr()?.iter().enumerate() {
            let name = s
                .req("name")
                .and_then(Json::as_str)
                .with_context(|| format!("sessions[{i}].name"))?
                .to_string();
            let budget_bytes = match s.get("budget_mb") {
                Some(v) => {
                    let mb = v.as_f64().with_context(|| format!("sessions[{i}].budget_mb"))?;
                    if mb <= 0.0 {
                        bail!("sessions[{i}] ({name}): budget_mb must be positive, got {mb}");
                    }
                    Some((mb * 1e6) as u64)
                }
                None => None,
            };
            let weight = match s.get("weight") {
                Some(v) => {
                    let w = v.as_usize().with_context(|| format!("sessions[{i}].weight"))? as u64;
                    if w == 0 {
                        bail!("sessions[{i}] ({name}): weight must be >= 1");
                    }
                    w
                }
                None => 1,
            };
            let deadline = match s.get("deadline") {
                Some(v) => Some(
                    v.as_usize().with_context(|| format!("sessions[{i}].deadline"))? as u64,
                ),
                None => None,
            };
            let mut cfg = TrainConfig::default();
            if let Some(c) = s.get("config") {
                for (k, v) in c.as_obj().with_context(|| format!("sessions[{i}].config"))? {
                    let val = match v {
                        Json::Str(x) => x.clone(),
                        // TrainConfig::set parses integer fields with
                        // parse::<usize>, which rejects "12.0" — print
                        // whole numbers without the fraction
                        Json::Num(x) if x.fract() == 0.0 && x.is_finite() => {
                            format!("{}", *x as i64)
                        }
                        Json::Num(x) => x.to_string(),
                        Json::Bool(b) => b.to_string(),
                        other => bail!(
                            "sessions[{i}] ({name}): config key {k:?} has unsupported \
                             value {other:?}"
                        ),
                    };
                    cfg.set(k, &val)
                        .with_context(|| format!("sessions[{i}] ({name}): config key {k:?}"))?;
                }
            }
            sessions.push(SessionSpec { name, budget_bytes, weight, deadline, cfg });
        }
        let spec = ServeSpec {
            slice_steps,
            policy,
            total_budget_bytes,
            starvation_turns,
            sessions,
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Structural checks: at least one session, unique names, and a model
    /// shape every tenant agrees on (one shared backend serves them all).
    pub fn validate(&self) -> Result<()> {
        if self.sessions.is_empty() {
            bail!("serve spec has no sessions");
        }
        for (i, s) in self.sessions.iter().enumerate() {
            if self.sessions[..i].iter().any(|t| t.name == s.name) {
                bail!("duplicate session name {:?}", s.name);
            }
        }
        let base = &self.sessions[0].cfg;
        for s in &self.sessions[1..] {
            shape_compatible(&s.cfg, base, &s.name, &self.sessions[0].name)?;
        }
        Ok(())
    }
}

/// One-shape-per-backend check, shared by parse-time validation and the
/// spec-refresh injection path.
fn shape_compatible(
    cfg: &TrainConfig,
    base: &TrainConfig,
    name: &str,
    base_name: &str,
) -> Result<()> {
    if cfg.preset != base.preset {
        bail!(
            "session {name:?} uses preset {:?} but {base_name:?} uses {:?} — all sessions must \
             share one model shape (one backend serves them all)",
            cfg.preset,
            base.preset
        );
    }
    if cfg.task != base.task {
        bail!(
            "session {name:?} task {} differs from {base_name:?} task {} — the shared backend \
             bakes in one head/batch shape",
            cfg.task_key(),
            base.task_key()
        );
    }
    if cfg.backend != base.backend {
        bail!("session {name:?} requests a different backend kind");
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Pure policy core: turn ordering and preemption over plain tenant facts,
// unit-testable without building a single model.
// ---------------------------------------------------------------------------

/// Scheduling-relevant facts about one tenant, decoupled from the live
/// [`Session`] so policy ordering is testable in isolation.
#[derive(Debug, Clone, Copy)]
pub struct TenantView {
    /// Admitted and waiting for (or holding) the backend.
    pub runnable: bool,
    /// Optimizer steps this tenant has executed.
    pub steps_run: u64,
    /// Share weight (`weighted` policy, pool-budget split).
    pub weight: u64,
    /// Target finish point on the global clock, if any.
    pub deadline: Option<u64>,
    /// Optimizer steps still to run.
    pub remaining: u64,
    /// Consecutive scheduling decisions this tenant was runnable but not
    /// chosen (the aging input for the starvation bound).
    pub waited: u64,
}

/// Deadline slack at global-clock time `clock`: how many steps of other
/// tenants' work can still be interleaved before this tenant's earliest
/// possible finish overshoots its deadline. Deadline-less tenants report
/// `i64::MAX`. Negative = already late.
pub fn slack_of(t: &TenantView, clock: u64) -> i64 {
    match t.deadline {
        Some(d) => d as i64 - (clock + t.remaining) as i64,
        None => i64::MAX,
    }
}

/// Stride-scheduling order: `a` runs before `b` when its virtual time
/// `steps_run / weight` is strictly lower (cross-multiplied, no floats).
fn weighted_before(a: &TenantView, b: &TenantView) -> bool {
    (a.steps_run as u128) * (b.weight as u128) < (b.steps_run as u128) * (a.weight as u128)
}

/// Choose the next tenant to run, or `None` when nothing is runnable.
/// Deterministic: every tie breaks toward the lower roster index.
pub fn pick_next(
    policy: SchedPolicy,
    tenants: &[TenantView],
    clock: u64,
    starvation_turns: u64,
) -> Option<usize> {
    let runnable = || tenants.iter().enumerate().filter(|(_, t)| t.runnable);
    runnable().next()?;
    match policy {
        // Max-waited = cyclic roster order (ties break toward the lower
        // index, and a just-run tenant has waited 0).
        SchedPolicy::RoundRobin => {
            runnable().max_by(|(ia, a), (ib, b)| {
                (a.waited, std::cmp::Reverse(ia)).cmp(&(b.waited, std::cmp::Reverse(ib)))
            })
        }
        SchedPolicy::Slack => {
            // Aging first: a deadline-less tenant skipped for a full
            // starvation window runs next regardless of slack.
            let starved = runnable()
                .filter(|(_, t)| t.deadline.is_none() && t.waited >= starvation_turns)
                .max_by(|(ia, a), (ib, b)| {
                    (a.waited, std::cmp::Reverse(ia)).cmp(&(b.waited, std::cmp::Reverse(ib)))
                });
            if starved.is_some() {
                return starved.map(|(i, _)| i);
            }
            runnable().min_by_key(|(i, t)| (slack_of(t, clock), *i))
        }
        SchedPolicy::Weighted => runnable().min_by(|(ia, a), (ib, b)| {
            if weighted_before(a, b) {
                std::cmp::Ordering::Less
            } else if weighted_before(b, a) {
                std::cmp::Ordering::Greater
            } else {
                ia.cmp(ib)
            }
        }),
    }
    .map(|(i, _)| i)
}

/// Whether some other runnable tenant now STRICTLY beats the runner on the
/// policy key — the mid-slice preemption trigger. Strictness (and
/// round-robin never preempting) keeps turns from thrashing on ties.
pub fn should_preempt(
    policy: SchedPolicy,
    tenants: &[TenantView],
    runner: usize,
    clock: u64,
) -> bool {
    let others = || {
        tenants.iter().enumerate().filter(move |(i, t)| *i != runner && t.runnable)
    };
    match policy {
        SchedPolicy::RoundRobin => false,
        SchedPolicy::Slack => {
            let mine = slack_of(&tenants[runner], clock);
            others().any(|(_, t)| slack_of(t, clock) < mine)
        }
        SchedPolicy::Weighted => others().any(|(_, t)| weighted_before(t, &tenants[runner])),
    }
}

// ---------------------------------------------------------------------------
// Outcomes + per-tenant schedule summary.
// ---------------------------------------------------------------------------

/// Per-tenant scheduling telemetry, surfaced in serve output and the JSON
/// reports (the per-session counterpart of the global obs counters).
#[derive(Debug, Clone, Default)]
pub struct SchedSummary {
    /// Policy the loop ran under.
    pub policy: String,
    /// The tenant's share weight.
    pub weight: u64,
    /// The tenant's deadline on the global clock, if any.
    pub deadline: Option<u64>,
    /// Turns this tenant was scheduled.
    pub turns: u64,
    /// Optimizer steps executed.
    pub steps: u64,
    /// Mid-slice preemptions suffered.
    pub preemptions: u64,
    /// Budget evictions suffered (each preserves a checkpoint).
    pub evictions: u64,
    /// Automatic re-admissions after an eviction.
    pub readmissions: u64,
    /// Global-clock value when the tenant finished, if it did.
    pub finished_clock: Option<u64>,
    /// `deadline - finish clock` (or `deadline - final clock` for tenants
    /// that never finished); negative = late. `None` without a deadline.
    pub final_slack: Option<i64>,
    /// Whether the deadline was missed (late finish, or no finish at all).
    pub missed_deadline: bool,
}

/// What happened to one tenant, in roster order.
pub struct ServeOutcome {
    /// Tenant name from the spec.
    pub name: String,
    /// false = rejected at admission, or never admitted before the loop
    /// drained (pool share stayed below the modeled footprint)
    pub admitted: bool,
    /// rejection/eviction explanation; None for a clean completion
    pub fate: Option<String>,
    /// the finished run (None when rejected or terminally evicted)
    pub result: Option<RunResult>,
    /// an evicted session's suspend checkpoint — the partial work survives
    /// and can be resumed later under a bigger budget
    pub checkpoint: Option<Vec<u8>>,
    /// per-tenant scheduling telemetry
    pub sched: SchedSummary,
}

// ---------------------------------------------------------------------------
// The serve loop.
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum TenantState {
    /// Queued: admitted to the roster but currently without enough budget
    /// (deferred admission or post-eviction). Re-planned every turn.
    Waiting,
    /// Holds a checkpoint and competes for turns.
    Runnable,
    /// Finished, terminally evicted, or abandoned.
    Done,
}

/// One live roster entry (rejected tenants never get a slot).
struct Slot {
    out_idx: usize,
    name: String,
    explicit_budget: Option<u64>,
    weight: u64,
    deadline: Option<u64>,
    /// Current effective budget: explicit, pool share, or None (unbudgeted).
    budget: Option<u64>,
    modeled: u64,
    /// Bytes the tenant is known to need: modeled before it first runs,
    /// the (monotonic) measured footprint afterwards.
    demand: u64,
    bytes: Vec<u8>,
    step: usize,
    target: usize,
    state: TenantState,
    waited: u64,
    turns: u64,
    preemptions: u64,
    evictions: u64,
    readmissions: u64,
    finished_clock: Option<u64>,
}

/// The policy-driven serve loop as a steppable object: [`ServeLoop::turn`]
/// runs one scheduling decision + one tenant turn, [`ServeLoop::refresh_spec`]
/// injects tenants into a running roster, [`ServeLoop::run`] drains
/// everything. Tests (and `--watch-spec`) drive turns one at a time; the
/// [`serve`] convenience wrapper is new-run-finish.
pub struct ServeLoop<'a> {
    rearm: &'a dyn Fn(),
    policy: SchedPolicy,
    slice_steps: usize,
    total_budget: Option<u64>,
    starvation_turns: u64,
    shared: Option<Box<dyn Backend>>,
    base_cfg: TrainConfig,
    slots: Vec<Slot>,
    outcomes: Vec<ServeOutcome>,
    clock: u64,
}

impl<'a> ServeLoop<'a> {
    /// Build the initial roster: construct every tenant once on the shared
    /// backend, checkpoint it, apply admission control, and plan budgets.
    /// `rearm` is called after each `reset_all_knobs()` so the serve CLI
    /// can re-apply its `--threads`/`--grad-stream`/... overrides (knob
    /// state is process-global; tests pass a no-op).
    pub fn new(spec: &ServeSpec, rearm: &'a dyn Fn()) -> Result<ServeLoop<'a>> {
        spec.validate()?;
        let mut lp = ServeLoop {
            rearm,
            policy: spec.policy,
            slice_steps: spec.slice_steps.max(1),
            total_budget: spec.total_budget_bytes,
            starvation_turns: spec.starvation_turns.max(1),
            shared: Some(backend::open(&spec.sessions[0].cfg)?),
            base_cfg: spec.sessions[0].cfg.clone(),
            slots: Vec::new(),
            outcomes: Vec::new(),
            clock: 0,
        };
        for s in &spec.sessions {
            lp.admit_spec(s)?;
        }
        lp.replan();
        Ok(lp)
    }

    /// Global clock: total optimizer steps executed across all tenants.
    pub fn clock(&self) -> u64 {
        self.clock
    }

    /// Build one tenant on the shared backend, checkpoint it, and either
    /// reject it (explicit budget below the modeled footprint — permanent,
    /// the budget can never grow) or queue it for planning.
    fn admit_spec(&mut self, s: &SessionSpec) -> Result<()> {
        let be = self.shared.take().expect("backend is lent to at most one session");
        let sess = Session::with_backend(be, &s.cfg, None)
            .with_context(|| format!("building session {:?}", s.name))?;
        let modeled = sess.modeled_footprint_bytes();
        let target = sess.target_steps();
        let (bytes, be) = sess.suspend_parts();
        self.shared = Some(be);
        let sched = SchedSummary {
            policy: self.policy.name().to_string(),
            weight: s.weight,
            deadline: s.deadline,
            ..SchedSummary::default()
        };
        if let Some(budget) = s.budget_bytes {
            if budget < modeled {
                println!(
                    "[serve] {}: REJECTED — budget {} B below modeled footprint {} B",
                    s.name, budget, modeled
                );
                self.outcomes.push(ServeOutcome {
                    name: s.name.clone(),
                    admitted: false,
                    fate: Some(format!(
                        "budget {budget} B below modeled footprint {modeled} B"
                    )),
                    result: None,
                    checkpoint: None,
                    sched,
                });
                return Ok(());
            }
        }
        self.slots.push(Slot {
            out_idx: self.outcomes.len(),
            name: s.name.clone(),
            explicit_budget: s.budget_bytes,
            weight: s.weight,
            deadline: s.deadline,
            budget: s.budget_bytes,
            modeled,
            demand: modeled,
            bytes,
            step: 0,
            target,
            state: TenantState::Waiting,
            waited: 0,
            turns: 0,
            preemptions: 0,
            evictions: 0,
            readmissions: 0,
            finished_clock: None,
        });
        self.outcomes.push(ServeOutcome {
            name: s.name.clone(),
            admitted: true,
            fate: None,
            result: None,
            checkpoint: None,
            sched,
        });
        Ok(())
    }

    /// Re-plan budgets and roster states. Pool tenants get a weight-
    /// proportional share of `total_budget_mb` over the LIVE pool cohort;
    /// a runnable tenant whose share dropped below its demand is evicted
    /// (queued), and a queued tenant whose share now covers its demand is
    /// re-admitted. Explicit budgets never move.
    fn replan(&mut self) {
        if let Some(total) = self.total_budget {
            let pool = |sl: &Slot| sl.state != TenantState::Done && sl.explicit_budget.is_none();
            let wsum: u128 =
                self.slots.iter().filter(|sl| pool(sl)).map(|sl| sl.weight as u128).sum();
            if wsum > 0 {
                for sl in self.slots.iter_mut() {
                    if pool(sl) {
                        sl.budget = Some(((total as u128) * (sl.weight as u128) / wsum) as u64);
                    }
                }
            }
        }
        for sl in self.slots.iter_mut() {
            match sl.state {
                TenantState::Done => {}
                TenantState::Runnable => {
                    if let Some(b) = sl.budget {
                        if b < sl.demand {
                            sl.state = TenantState::Waiting;
                            if sl.step > 0 {
                                sl.evictions += 1;
                                obs::add(Counter::SchedEvictions, 1);
                                println!(
                                    "[serve] {}: EVICTED at step {} — measured footprint {} B \
                                     exceeds budget {b} B (queued for re-admission)",
                                    sl.name, sl.step, sl.demand
                                );
                            }
                        }
                    }
                }
                TenantState::Waiting => {
                    if sl.budget.map_or(true, |b| b >= sl.demand) {
                        sl.state = TenantState::Runnable;
                        sl.waited = 0;
                        if sl.step > 0 {
                            let _sp = obs::span(Span::ServeReadmit);
                            sl.readmissions += 1;
                            obs::add(Counter::SchedReadmissions, 1);
                            println!(
                                "[serve] {}: re-admitted at step {} — budget {} B covers \
                                 measured footprint {} B",
                                sl.name,
                                sl.step,
                                sl.budget
                                    .map_or_else(|| "unbounded".to_string(), |b| b.to_string()),
                                sl.demand
                            );
                        }
                    }
                }
            }
        }
    }

    /// Inject tenants from a refreshed spec into the running roster: any
    /// session whose name is new is built, admission-checked, and planned
    /// in; existing tenants are left untouched (their configs, weights and
    /// deadlines are pinned at first admission). A changed
    /// `total_budget_mb` is adopted — shrinking the pool live is how an
    /// operator forces evictions. Policy/slice changes are ignored.
    /// Returns how many tenants were injected.
    pub fn refresh_spec(&mut self, spec: &ServeSpec) -> Result<usize> {
        spec.validate()?;
        if spec.total_budget_bytes != self.total_budget {
            if let Some(t) = spec.total_budget_bytes {
                println!("[serve] total budget re-planned to {t} B");
            }
            self.total_budget = spec.total_budget_bytes;
        }
        let mut injected = 0usize;
        for s in &spec.sessions {
            if self.outcomes.iter().any(|o| o.name == s.name) {
                continue;
            }
            shape_compatible(&s.cfg, &self.base_cfg, &s.name, "the running roster")?;
            self.admit_spec(s)?;
            println!("[serve] {}: injected via spec refresh", s.name);
            injected += 1;
        }
        self.replan();
        Ok(injected)
    }

    fn views(&self) -> Vec<TenantView> {
        self.slots
            .iter()
            .map(|sl| TenantView {
                runnable: sl.state == TenantState::Runnable,
                steps_run: sl.step as u64,
                weight: sl.weight,
                deadline: sl.deadline,
                remaining: sl.target.saturating_sub(sl.step) as u64,
                waited: sl.waited,
            })
            .collect()
    }

    /// One scheduling decision + one tenant turn (up to `slice_steps`
    /// optimizer steps, less on preemption/finish). Returns false when no
    /// tenant is runnable — the loop is drained or everyone left is queued
    /// without headroom.
    pub fn turn(&mut self) -> Result<bool> {
        let picked = {
            let _sp = obs::span(Span::ServeSchedule);
            pick_next(self.policy, &self.views(), self.clock, self.starvation_turns)
        };
        let Some(i) = picked else { return Ok(false) };
        for (j, sl) in self.slots.iter_mut().enumerate() {
            if sl.state == TenantState::Runnable {
                sl.waited = if j == i { 0 } else { sl.waited + 1 };
            }
        }
        self.slots[i].turns += 1;
        // knob hygiene between tenants: drop whatever the previous turn
        // resolved, re-resolve from env, re-apply CLI overrides
        crate::util::reset_all_knobs();
        (self.rearm)();
        let name = self.slots[i].name.clone();
        let be = self.shared.take().expect("backend is lent to at most one session");
        let mut sess = Session::resume_with_backend(be, &self.slots[i].bytes)
            .with_context(|| format!("resuming session {name:?}"))?;
        let mut ran_in_turn = 0usize;
        let mut preempted = false;
        while ran_in_turn < self.slice_steps && !sess.done() {
            let ran = sess.run_steps(1)?;
            if ran == 0 {
                break;
            }
            ran_in_turn += ran;
            self.clock += ran as u64;
            self.slots[i].step = sess.step();
            if ran_in_turn >= self.slice_steps || sess.done() {
                break;
            }
            if should_preempt(self.policy, &self.views(), i, self.clock) {
                let _sp = obs::span(Span::ServePreempt);
                self.slots[i].preemptions += 1;
                obs::add(Counter::SchedPreemptions, 1);
                preempted = true;
                break;
            }
        }
        self.slots[i].demand = self.slots[i].demand.max(sess.measured_footprint_bytes());
        if sess.done() {
            let (res, _store, be) = sess
                .finish_parts()
                .with_context(|| format!("finishing session {name:?}"))?;
            self.shared = Some(be);
            let sl = &mut self.slots[i];
            sl.state = TenantState::Done;
            sl.finished_clock = Some(self.clock);
            println!(
                "[serve] {name}: DONE at step {} (clock {}) — final train loss {:.4}",
                res.train_losses.len(),
                self.clock,
                res.final_train_loss
            );
            if let Some(d) = sl.deadline {
                if self.clock > d {
                    obs::add(Counter::SchedDeadlineMisses, 1);
                    obs::gauge_max(Gauge::SchedLatenessPeakSteps, self.clock - d);
                    println!("[serve] {name}: deadline {d} MISSED by {} steps", self.clock - d);
                }
            }
            self.outcomes[sl.out_idx].result = Some(res);
        } else {
            let step = sess.step();
            let target = sess.target_steps();
            let (bytes, be) = sess.suspend_parts();
            self.shared = Some(be);
            self.slots[i].bytes = bytes;
            let why = if preempted { "preempted" } else { "suspended" };
            println!("[serve] {name}: step {step}/{target}, {why}");
        }
        self.replan();
        Ok(true)
    }

    /// Give up on the first still-queued tenant (roster order): record a
    /// terminal fate, keep its checkpoint if it ran, free its pool share
    /// (which may re-admit other queued tenants). Returns false when
    /// nothing is queued. Drivers call this when [`ServeLoop::turn`]
    /// reports nothing runnable but the roster isn't drained.
    pub fn abandon_one_waiting(&mut self) -> bool {
        let Some(i) = self.slots.iter().position(|sl| sl.state == TenantState::Waiting) else {
            return false;
        };
        let sl = &mut self.slots[i];
        sl.state = TenantState::Done;
        let budget = sl.budget.map_or_else(|| "unbounded".to_string(), |b| b.to_string());
        if sl.step > 0 {
            println!(
                "[serve] {}: gave up at step {} — demand {} B never fit budget {} B",
                sl.name, sl.step, sl.demand, budget
            );
            self.outcomes[sl.out_idx].fate = Some(format!(
                "evicted at step {}: measured footprint {} B exceeds budget {} B and \
                 re-admission never became possible",
                sl.step, sl.demand, budget
            ));
            self.outcomes[sl.out_idx].checkpoint = Some(std::mem::take(&mut sl.bytes));
        } else {
            println!(
                "[serve] {}: never admitted — budget {} B below modeled footprint {} B",
                sl.name, budget, sl.modeled
            );
            self.outcomes[sl.out_idx].admitted = false;
            self.outcomes[sl.out_idx].fate = Some(format!(
                "budget {} B below modeled footprint {} B",
                budget, sl.modeled
            ));
        }
        if sl.deadline.is_some() {
            obs::add(Counter::SchedDeadlineMisses, 1);
        }
        self.replan();
        true
    }

    /// Drain the loop: run turns while anything is runnable, abandoning
    /// queued tenants that can never be re-admitted (their presence would
    /// otherwise deadlock the roster — giving one up frees its pool share,
    /// which can re-admit others).
    pub fn run(&mut self) -> Result<()> {
        loop {
            if self.turn()? {
                continue;
            }
            if !self.abandon_one_waiting() {
                return Ok(());
            }
        }
    }

    /// Consume the loop, filling in every tenant's schedule summary.
    pub fn finish(mut self) -> Vec<ServeOutcome> {
        for sl in &self.slots {
            let o = &mut self.outcomes[sl.out_idx];
            o.sched.turns = sl.turns;
            o.sched.steps = sl.step as u64;
            o.sched.preemptions = sl.preemptions;
            o.sched.evictions = sl.evictions;
            o.sched.readmissions = sl.readmissions;
            o.sched.finished_clock = sl.finished_clock;
            o.sched.final_slack = sl
                .deadline
                .map(|d| d as i64 - sl.finished_clock.unwrap_or(self.clock) as i64);
            o.sched.missed_deadline = sl
                .deadline
                .map_or(false, |d| sl.finished_clock.map_or(true, |c| c > d));
        }
        self.outcomes
    }

    /// Dry-run admission report for `serve --plan`: one line per tenant
    /// with its modeled footprint and current planned budget — the numbers
    /// an operator (or CI) needs to size `total_budget_mb`.
    pub fn plan_lines(&self) -> Vec<String> {
        let mut lines = Vec::new();
        for sl in &self.slots {
            lines.push(format!(
                "[plan] {}: modeled {} B, weight {}, deadline {}, budget {}, state {}",
                sl.name,
                sl.modeled,
                sl.weight,
                sl.deadline.map_or_else(|| "-".to_string(), |d| d.to_string()),
                sl.budget.map_or_else(|| "unbounded".to_string(), |b| b.to_string()),
                match sl.state {
                    TenantState::Runnable => "admitted",
                    TenantState::Waiting => "queued",
                    TenantState::Done => "done",
                },
            ));
        }
        for o in self.outcomes.iter().filter(|o| !o.admitted) {
            lines.push(format!(
                "[plan] {}: REJECTED ({})",
                o.name,
                o.fate.as_deref().unwrap_or("admission")
            ));
        }
        lines
    }
}

/// Run every session in `spec` to completion (or rejection/eviction) over
/// one shared backend: [`ServeLoop::new`] + [`ServeLoop::run`] +
/// [`ServeLoop::finish`].
pub fn serve(spec: &ServeSpec, rearm: &dyn Fn()) -> Result<Vec<ServeOutcome>> {
    let mut lp = ServeLoop::new(spec, rearm)?;
    lp.run()?;
    Ok(lp.finish())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Method;

    fn grain_spec(names_steps: &[(&str, usize)], budget_mb: Option<f64>) -> String {
        let sessions: Vec<String> = names_steps
            .iter()
            .map(|(name, steps)| {
                let budget = match budget_mb {
                    Some(mb) => format!(",\"budget_mb\":{mb}"),
                    None => String::new(),
                };
                format!(
                    "{{\"name\":\"{name}\"{budget},\"config\":{{\"preset\":\"grain\",\
                     \"steps\":{steps},\"eval-every\":0,\"eval-batches\":1,\"seed\":5}}}}"
                )
            })
            .collect();
        format!("{{\"slice_steps\":2,\"sessions\":[{}]}}", sessions.join(","))
    }

    fn view(
        runnable: bool,
        steps_run: u64,
        weight: u64,
        deadline: Option<u64>,
        remaining: u64,
        waited: u64,
    ) -> TenantView {
        TenantView { runnable, steps_run, weight, deadline, remaining, waited }
    }

    #[test]
    fn spec_parses_and_validates() {
        let spec = ServeSpec::parse(&grain_spec(&[("a", 4), ("b", 6)], None)).unwrap();
        assert_eq!(spec.slice_steps, 2);
        assert_eq!(spec.sessions.len(), 2);
        assert_eq!(spec.sessions[0].name, "a");
        assert_eq!(spec.sessions[1].cfg.steps, 6);
        assert!(spec.sessions[0].budget_bytes.is_none());
        assert_eq!(spec.policy, SchedPolicy::RoundRobin);
        assert_eq!(spec.sessions[0].weight, 1);
        assert!(spec.sessions[0].deadline.is_none());
        assert!(spec.total_budget_bytes.is_none());
        assert_eq!(spec.starvation_turns, DEFAULT_STARVATION_TURNS);
    }

    #[test]
    fn spec_parses_scheduler_fields() {
        let src = r#"{
            "slice_steps": 3, "sched": "slack", "total_budget_mb": 2.5,
            "starvation_turns": 4,
            "sessions": [
                {"name": "a", "weight": 3, "deadline": 40,
                 "config": {"preset": "grain", "steps": 8}},
                {"name": "b", "config": {"preset": "grain", "steps": 4}}
            ]
        }"#;
        let spec = ServeSpec::parse(src).unwrap();
        assert_eq!(spec.policy, SchedPolicy::Slack);
        assert_eq!(spec.total_budget_bytes, Some(2_500_000));
        assert_eq!(spec.starvation_turns, 4);
        assert_eq!(spec.sessions[0].weight, 3);
        assert_eq!(spec.sessions[0].deadline, Some(40));
        assert_eq!(spec.sessions[1].weight, 1);
        // bad values are rejected loudly
        assert!(ServeSpec::parse(&src.replace("\"slack\"", "\"sjf\"")).is_err());
        assert!(ServeSpec::parse(&src.replace("\"weight\": 3", "\"weight\": 0")).is_err());
        assert!(ServeSpec::parse(&src.replace("2.5", "-1")).is_err());
        assert!(SchedPolicy::parse("weighted").unwrap() == SchedPolicy::Weighted);
        assert_eq!(SchedPolicy::Slack.name(), "slack");
    }

    #[test]
    fn spec_rejects_duplicate_names_and_mixed_presets() {
        assert!(ServeSpec::parse(&grain_spec(&[("a", 4), ("a", 6)], None)).is_err());
        let mixed = "{\"sessions\":[\
            {\"name\":\"a\",\"config\":{\"preset\":\"grain\"}},\
            {\"name\":\"b\",\"config\":{\"preset\":\"nano\"}}]}";
        let err = ServeSpec::parse(mixed).unwrap_err();
        assert!(format!("{err:#}").contains("preset"), "{err:#}");
    }

    #[test]
    fn round_robin_pick_is_cyclic() {
        let mut waited = [0u64; 3];
        let mut order = Vec::new();
        for _ in 0..6 {
            let views: Vec<TenantView> =
                (0..3).map(|i| view(true, 0, 1, None, 4, waited[i])).collect();
            let i = pick_next(SchedPolicy::RoundRobin, &views, 0, 8).unwrap();
            order.push(i);
            for (j, w) in waited.iter_mut().enumerate() {
                *w = if j == i { 0 } else { *w + 1 };
            }
        }
        assert_eq!(order, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn slack_orders_by_earliest_slack_and_preempts_strictly() {
        // a: deadline 12, remaining 8 -> slack 4; b: deadline 10,
        // remaining 6 -> slack 4 (tie -> lower index); c: no deadline.
        let views = [
            view(true, 0, 1, Some(12), 8, 0),
            view(true, 0, 1, Some(10), 6, 0),
            view(true, 0, 1, None, 5, 0),
        ];
        assert_eq!(pick_next(SchedPolicy::Slack, &views, 0, 8), Some(0));
        // at clock 3 b's slack is 10-3-6=1 < a's 12-3-8=1 — tie, not strict
        assert!(!should_preempt(SchedPolicy::Slack, &views, 0, 3));
        // one more step of a: a slack 12-4-8=0... with a's remaining fixed
        // in this static view, b's slack 10-4-6=0 ties again; shrink a's
        // deadline pressure by moving clock so b strictly wins
        let late = [
            view(true, 1, 1, Some(12), 7, 0),
            view(true, 0, 1, Some(10), 6, 1),
            view(true, 0, 1, None, 5, 1),
        ];
        // runner a: slack 12-2-7=3; waiter b: 10-2-6=2 < 3 -> preempt
        assert!(should_preempt(SchedPolicy::Slack, &late, 0, 2));
        // the deadline-less tenant never preempts anyone
        assert_eq!(slack_of(&late[2], 2), i64::MAX);
        // non-runnable tenants are invisible to both decisions
        let parked = [view(false, 0, 1, Some(0), 1, 0), view(true, 0, 1, None, 4, 0)];
        assert_eq!(pick_next(SchedPolicy::Slack, &parked, 0, 8), Some(1));
        assert!(!should_preempt(SchedPolicy::Slack, &parked, 1, 50));
    }

    #[test]
    fn slack_starvation_bound_schedules_deadline_less_tenants() {
        // b has a deadline and would win every slack comparison forever;
        // after STARVATION turns of waiting, a must run anyway.
        let starvation = 4u64;
        let mut waited_a = 0u64;
        let mut picked_a_at = None;
        for turn in 0..10u64 {
            let views = [
                view(true, 0, 1, None, 50, waited_a),
                view(true, turn, 1, Some(1000), 100, 0),
            ];
            let i = pick_next(SchedPolicy::Slack, &views, turn, starvation).unwrap();
            if i == 0 {
                picked_a_at = Some(turn);
                break;
            }
            waited_a += 1;
        }
        let at = picked_a_at.expect("deadline-less tenant starved past the bound");
        assert_eq!(at, starvation, "aging must fire exactly at the bound");
    }

    #[test]
    fn weighted_pick_converges_to_weight_proportions() {
        // weights 3:1 over 200 single-step decisions: step counts must
        // track the 3:1 entitlement within one step at every prefix.
        let weights = [3u64, 1u64];
        let mut steps = [0u64; 2];
        for _ in 0..200 {
            let views: Vec<TenantView> =
                (0..2).map(|i| view(true, steps[i], weights[i], None, 1000, 0)).collect();
            let i = pick_next(SchedPolicy::Weighted, &views, 0, 8).unwrap();
            steps[i] += 1;
            let total = (steps[0] + steps[1]) as f64;
            let share = steps[0] as f64 / total;
            assert!(
                (share - 0.75).abs() <= 1.0 / total,
                "share {share} drifted from 3:1 at total {total}"
            );
        }
        assert_eq!(steps[0], 150);
        assert_eq!(steps[1], 50);
    }

    #[test]
    fn admission_rejects_budget_below_modeled_footprint() {
        let _g = crate::util::test_knob_lock();
        crate::util::reset_all_knobs();
        // 0.001 MB = 1000 bytes: far below any model's weights alone
        let spec = ServeSpec::parse(&grain_spec(&[("starved", 4)], Some(0.001))).unwrap();
        let out = serve(&spec, &|| {}).unwrap();
        assert_eq!(out.len(), 1);
        assert!(!out[0].admitted);
        assert!(out[0].result.is_none());
        assert!(out[0].fate.as_deref().unwrap().contains("modeled footprint"));
    }

    fn nano_cfg(steps: usize, seed: u64) -> TrainConfig {
        let mut cfg = TrainConfig::default();
        cfg.preset = "nano".into();
        cfg.method = Method::FullAdam;
        cfg.steps = steps;
        cfg.eval_every = 0;
        cfg.eval_batches = 1;
        cfg.seed = seed;
        cfg
    }

    /// The elastic round trip: a lone pool tenant is admitted under the
    /// full pool, a heavier tenant injected mid-run shrinks its share
    /// below the measured footprint (eviction, checkpoint queued), and
    /// once the intruder finishes the share grows back (automatic
    /// re-admission) — with the evicted tenant's results still bitwise
    /// identical to a solo run.
    #[test]
    fn evict_then_readmit_round_trip_is_bitwise() {
        let _g = crate::util::test_knob_lock();
        crate::util::reset_all_knobs();
        crate::obs::set_trace(true);
        let base = crate::obs::snapshot();
        let lo_cfg = nano_cfg(8, 9);
        let modeled = {
            let probe = Session::new(&lo_cfg, None).unwrap();
            probe.modeled_footprint_bytes()
        };
        // T = 2x modeled: lo alone fits (T >= modeled); after hi (weight 3)
        // joins, lo's share T/4 = modeled/2 < measured (~modeled) evicts
        // it, while hi's share 3T/4 = 1.5x modeled admits hi; hi finishing
        // returns the full pool to lo, re-admitting it.
        let total_mb = (2 * modeled) as f64 / 1e6;
        let tenant = |name: &str, steps: usize, weight: u64, seed: u64| {
            format!(
                "{{\"name\":\"{name}\",\"weight\":{weight},\"config\":{{\"preset\":\"nano\",\
                 \"method\":\"adam\",\"steps\":{steps},\"eval-every\":0,\"eval-batches\":1,\
                 \"seed\":{seed}}}}}"
            )
        };
        let spec1 = ServeSpec::parse(&format!(
            "{{\"slice_steps\":2,\"sched\":\"slack\",\"total_budget_mb\":{total_mb},\
             \"sessions\":[{}]}}",
            tenant("lo", 8, 1, 9)
        ))
        .unwrap();
        let spec2 = ServeSpec::parse(&format!(
            "{{\"slice_steps\":2,\"sched\":\"slack\",\"total_budget_mb\":{total_mb},\
             \"sessions\":[{},{}]}}",
            tenant("lo", 8, 1, 9),
            tenant("hi", 2, 3, 10)
        ))
        .unwrap();
        let mut lp = ServeLoop::new(&spec1, &|| {}).unwrap();
        assert!(lp.turn().unwrap(), "lo must get a first turn");
        assert_eq!(lp.slots[0].step, 2);
        assert_eq!(lp.refresh_spec(&spec2).unwrap(), 1, "hi must be injected");
        assert_eq!(lp.slots[0].state, TenantState::Waiting, "lo must be evicted");
        assert_eq!(lp.slots[0].evictions, 1);
        assert_eq!(lp.slots[1].state, TenantState::Runnable, "hi must be admitted");
        lp.run().unwrap();
        let outcomes = lp.finish();
        crate::obs::reset_trace();
        let lo = &outcomes[0];
        let hi = &outcomes[1];
        assert_eq!(lo.sched.evictions, 1);
        assert_eq!(lo.sched.readmissions, 1, "lo must be re-admitted after hi finishes");
        assert!(lo.fate.is_none(), "{:?}", lo.fate);
        assert!(hi.result.is_some());
        let d = crate::obs::delta(&base);
        assert!(d.counters[Counter::SchedEvictions as usize] >= 1);
        assert!(d.counters[Counter::SchedReadmissions as usize] >= 1);
        // the round trip must not have cost lo a single bit
        crate::util::reset_all_knobs();
        let mut solo = Session::new(&lo_cfg, None).unwrap();
        solo.run_to_completion().unwrap();
        let (want, _) = solo.finish().unwrap();
        let got = lo.result.as_ref().expect("lo must finish after re-admission");
        assert_eq!(want.train_losses.len(), got.train_losses.len());
        for (s, (x, y)) in want.train_losses.iter().zip(&got.train_losses).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "lo diverged from solo at step {s}");
        }
    }

    /// A pool too small for anyone must not deadlock the loop: the first
    /// queued tenant is abandoned, freeing the pool for the second.
    #[test]
    fn undersized_pool_abandons_without_deadlock() {
        let _g = crate::util::test_knob_lock();
        crate::util::reset_all_knobs();
        let modeled = {
            let probe = Session::new(&nano_cfg(2, 5), None).unwrap();
            probe.modeled_footprint_bytes()
        };
        // 1.5x modeled total: either tenant fits alone, both together
        // (shares 0.75x each) do not — roster order wins after the other
        // is given up.
        let total_mb = (modeled + modeled / 2) as f64 / 1e6;
        let spec = ServeSpec::parse(&format!(
            "{{\"slice_steps\":2,\"total_budget_mb\":{total_mb},\"sessions\":[\
             {{\"name\":\"a\",\"config\":{{\"preset\":\"nano\",\"method\":\"adam\",\
             \"steps\":2,\"eval-every\":0,\"eval-batches\":1,\"seed\":5}}}},\
             {{\"name\":\"b\",\"config\":{{\"preset\":\"nano\",\"method\":\"adam\",\
             \"steps\":2,\"eval-every\":0,\"eval-batches\":1,\"seed\":6}}}}]}}"
        ))
        .unwrap();
        let out = serve(&spec, &|| {}).unwrap();
        assert_eq!(out.len(), 2);
        let finished: Vec<bool> = out.iter().map(|o| o.result.is_some()).collect();
        assert_eq!(
            finished.iter().filter(|&&f| f).count(),
            1,
            "exactly one tenant fits the pool: {finished:?}"
        );
        let loser = out.iter().find(|o| o.result.is_none()).unwrap();
        assert!(!loser.admitted);
        assert!(loser.fate.as_deref().unwrap().contains("below modeled footprint"));
    }
}
