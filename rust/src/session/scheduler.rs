//! `pallas serve`: multiplex many named training sessions over ONE shared
//! execution backend.
//!
//! The scheduler is a round-robin fair-share loop: every live session gets
//! a time slice of `slice_steps` optimizer steps, then is suspended (via
//! the same [`Session::suspend`] checkpoint a crash-resume uses) and the
//! backend is lent to the next tenant. Because suspend/resume is bitwise,
//! a time-sliced session's losses and final parameters are identical to a
//! solo run of the same config (tests/session_resume.rs pins this for
//! three concurrent sessions).
//!
//! Memory budgets are enforced twice:
//! * **admission** — before a session runs a single step, its budget must
//!   cover [`Session::modeled_footprint_bytes`] (weights + the strategy's
//!   modeled gradient retention + modeled optimizer state + activations);
//!   an underprovisioned session is rejected up front, not OOM-killed
//!   mid-run.
//! * **runtime** — after every slice the budget is re-checked against
//!   [`Session::measured_footprint_bytes`], which swaps the modeled
//!   gradient term for the grads layer's MEASURED `peak_grad_bytes`; a
//!   session whose real retention exceeds its budget is evicted at the
//!   slice boundary (its checkpoint is preserved in the outcome, so the
//!   work isn't lost).
//!
//! One backend means one model shape: every session in a spec must agree
//! on preset, task, and backend kind (validated at parse time). Per-slice
//! knob hygiene — `util::reset_all_knobs()` plus the caller's `rearm`
//! closure (which re-applies CLI knob overrides) — guarantees no tenant
//! inherits another's thread-count or gradient-path resolution.

use anyhow::{bail, Context, Result};

use super::Session;
use crate::backend::{self, Backend};
use crate::config::TrainConfig;
use crate::trainer::RunResult;
use crate::util::json::Json;

/// Steps per turn when the spec doesn't say.
pub const DEFAULT_SLICE_STEPS: usize = 8;

/// One tenant in a serve spec.
pub struct SessionSpec {
    pub name: String,
    /// memory budget in bytes (None = unbudgeted: always admitted)
    pub budget_bytes: Option<u64>,
    pub cfg: TrainConfig,
}

/// A parsed serve spec: `{"slice_steps": 8, "sessions": [{"name": ...,
/// "budget_mb": ..., "config": {"<TrainConfig key>": value, ...}}, ...]}`.
pub struct ServeSpec {
    pub slice_steps: usize,
    pub sessions: Vec<SessionSpec>,
}

impl ServeSpec {
    pub fn parse(src: &str) -> Result<ServeSpec> {
        let j = Json::parse(src).context("serve spec is not valid JSON")?;
        let slice_steps = match j.get("slice_steps") {
            Some(v) => v.as_usize().context("slice_steps")?,
            None => DEFAULT_SLICE_STEPS,
        };
        if slice_steps == 0 {
            bail!("slice_steps must be >= 1");
        }
        let mut sessions = Vec::new();
        for (i, s) in j.req("sessions")?.as_arr()?.iter().enumerate() {
            let name = s
                .req("name")
                .and_then(Json::as_str)
                .with_context(|| format!("sessions[{i}].name"))?
                .to_string();
            let budget_bytes = match s.get("budget_mb") {
                Some(v) => {
                    let mb = v.as_f64().with_context(|| format!("sessions[{i}].budget_mb"))?;
                    if mb <= 0.0 {
                        bail!("sessions[{i}] ({name}): budget_mb must be positive, got {mb}");
                    }
                    Some((mb * 1e6) as u64)
                }
                None => None,
            };
            let mut cfg = TrainConfig::default();
            if let Some(c) = s.get("config") {
                for (k, v) in c.as_obj().with_context(|| format!("sessions[{i}].config"))? {
                    let val = match v {
                        Json::Str(x) => x.clone(),
                        // TrainConfig::set parses integer fields with
                        // parse::<usize>, which rejects "12.0" — print
                        // whole numbers without the fraction
                        Json::Num(x) if x.fract() == 0.0 && x.is_finite() => {
                            format!("{}", *x as i64)
                        }
                        Json::Num(x) => x.to_string(),
                        Json::Bool(b) => b.to_string(),
                        other => bail!(
                            "sessions[{i}] ({name}): config key {k:?} has unsupported \
                             value {other:?}"
                        ),
                    };
                    cfg.set(k, &val)
                        .with_context(|| format!("sessions[{i}] ({name}): config key {k:?}"))?;
                }
            }
            sessions.push(SessionSpec { name, budget_bytes, cfg });
        }
        let spec = ServeSpec { slice_steps, sessions };
        spec.validate()?;
        Ok(spec)
    }

    /// Structural checks: at least one session, unique names, and a model
    /// shape every tenant agrees on (one shared backend serves them all).
    pub fn validate(&self) -> Result<()> {
        if self.sessions.is_empty() {
            bail!("serve spec has no sessions");
        }
        for (i, s) in self.sessions.iter().enumerate() {
            if self.sessions[..i].iter().any(|t| t.name == s.name) {
                bail!("duplicate session name {:?}", s.name);
            }
        }
        let base = &self.sessions[0].cfg;
        for s in &self.sessions[1..] {
            if s.cfg.preset != base.preset {
                bail!(
                    "session {:?} uses preset {:?} but {:?} uses {:?} — all sessions must \
                     share one model shape (one backend serves them all)",
                    s.name,
                    s.cfg.preset,
                    self.sessions[0].name,
                    base.preset
                );
            }
            if s.cfg.task != base.task {
                bail!(
                    "session {:?} task {} differs from {:?} task {} — the shared backend \
                     bakes in one head/batch shape",
                    s.name,
                    s.cfg.task_key(),
                    self.sessions[0].name,
                    base.task_key()
                );
            }
            if s.cfg.backend != base.backend {
                bail!("session {:?} requests a different backend kind", s.name);
            }
        }
        Ok(())
    }
}

/// What happened to one tenant, in spec order.
pub struct ServeOutcome {
    pub name: String,
    /// false = rejected at admission (budget below modeled footprint)
    pub admitted: bool,
    /// rejection/eviction explanation; None for a clean completion
    pub fate: Option<String>,
    /// the finished run (None when rejected or evicted)
    pub result: Option<RunResult>,
    /// an evicted session's suspend checkpoint — the partial work survives
    /// and can be resumed later under a bigger budget
    pub checkpoint: Option<Vec<u8>>,
}

/// Run every session in `spec` to completion (or rejection/eviction) over
/// one shared backend. `rearm` is called after each `reset_all_knobs()` so
/// the serve CLI can re-apply its `--threads`/`--grad-stream`/... overrides
/// (knob state is process-global; tests pass a no-op).
pub fn serve(spec: &ServeSpec, rearm: &dyn Fn()) -> Result<Vec<ServeOutcome>> {
    spec.validate()?;
    let mut shared: Option<Box<dyn Backend>> = Some(backend::open(&spec.sessions[0].cfg)?);

    struct Slot {
        out_idx: usize,
        budget: Option<u64>,
        bytes: Vec<u8>,
        done: bool,
    }

    // Admission: build each tenant once on the shared backend, check its
    // budget against the modeled footprint, and immediately checkpoint it.
    let mut outcomes: Vec<ServeOutcome> = Vec::new();
    let mut slots: Vec<Slot> = Vec::new();
    for s in &spec.sessions {
        let be = shared.take().expect("backend is lent to at most one session");
        let sess = Session::with_backend(be, &s.cfg, None)
            .with_context(|| format!("building session {:?}", s.name))?;
        let modeled = sess.modeled_footprint_bytes();
        let (bytes, be) = sess.suspend_parts();
        shared = Some(be);
        if let Some(budget) = s.budget_bytes {
            if budget < modeled {
                println!(
                    "[serve] {}: REJECTED — budget {} B below modeled footprint {} B",
                    s.name, budget, modeled
                );
                outcomes.push(ServeOutcome {
                    name: s.name.clone(),
                    admitted: false,
                    fate: Some(format!(
                        "budget {budget} B below modeled footprint {modeled} B"
                    )),
                    result: None,
                    checkpoint: None,
                });
                continue;
            }
        }
        slots.push(Slot {
            out_idx: outcomes.len(),
            budget: s.budget_bytes,
            bytes,
            done: false,
        });
        outcomes.push(ServeOutcome {
            name: s.name.clone(),
            admitted: true,
            fate: None,
            result: None,
            checkpoint: None,
        });
    }

    // Round-robin: K steps per tenant per turn, suspend at the boundary.
    let slice = spec.slice_steps.max(1);
    while slots.iter().any(|sl| !sl.done) {
        for sl in slots.iter_mut() {
            if sl.done {
                continue;
            }
            // knob hygiene between tenants: drop whatever the previous
            // slice resolved, re-resolve from env, re-apply CLI overrides
            crate::util::reset_all_knobs();
            rearm();
            let name = outcomes[sl.out_idx].name.clone();
            let be = shared.take().expect("backend is lent to at most one session");
            let mut sess = Session::resume_with_backend(be, &sl.bytes)
                .with_context(|| format!("resuming session {name:?}"))?;
            sess.run_steps(slice)?;
            if let Some(budget) = sl.budget {
                let measured = sess.measured_footprint_bytes();
                if measured > budget {
                    let step = sess.step();
                    let (bytes, be) = sess.suspend_parts();
                    shared = Some(be);
                    sl.done = true;
                    println!(
                        "[serve] {name}: EVICTED at step {step} — measured footprint \
                         {measured} B exceeds budget {budget} B"
                    );
                    outcomes[sl.out_idx].fate = Some(format!(
                        "evicted at step {step}: measured footprint {measured} B exceeds \
                         budget {budget} B"
                    ));
                    outcomes[sl.out_idx].checkpoint = Some(bytes);
                    continue;
                }
            }
            if sess.done() {
                let (res, _store, be) = sess
                    .finish_parts()
                    .with_context(|| format!("finishing session {name:?}"))?;
                shared = Some(be);
                println!(
                    "[serve] {name}: DONE at step {} — final train loss {:.4}",
                    res.train_losses.len(),
                    res.final_train_loss
                );
                outcomes[sl.out_idx].result = Some(res);
                sl.done = true;
            } else {
                let step = sess.step();
                let target = sess.target_steps();
                let (bytes, be) = sess.suspend_parts();
                shared = Some(be);
                sl.bytes = bytes;
                println!("[serve] {name}: step {step}/{target}, suspended");
            }
        }
    }
    Ok(outcomes)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grain_spec(names_steps: &[(&str, usize)], budget_mb: Option<f64>) -> String {
        let sessions: Vec<String> = names_steps
            .iter()
            .map(|(name, steps)| {
                let budget = match budget_mb {
                    Some(mb) => format!(",\"budget_mb\":{mb}"),
                    None => String::new(),
                };
                format!(
                    "{{\"name\":\"{name}\"{budget},\"config\":{{\"preset\":\"grain\",\
                     \"steps\":{steps},\"eval-every\":0,\"eval-batches\":1,\"seed\":5}}}}"
                )
            })
            .collect();
        format!("{{\"slice_steps\":2,\"sessions\":[{}]}}", sessions.join(","))
    }

    #[test]
    fn spec_parses_and_validates() {
        let spec = ServeSpec::parse(&grain_spec(&[("a", 4), ("b", 6)], None)).unwrap();
        assert_eq!(spec.slice_steps, 2);
        assert_eq!(spec.sessions.len(), 2);
        assert_eq!(spec.sessions[0].name, "a");
        assert_eq!(spec.sessions[1].cfg.steps, 6);
        assert!(spec.sessions[0].budget_bytes.is_none());
    }

    #[test]
    fn spec_rejects_duplicate_names_and_mixed_presets() {
        assert!(ServeSpec::parse(&grain_spec(&[("a", 4), ("a", 6)], None)).is_err());
        let mixed = "{\"sessions\":[\
            {\"name\":\"a\",\"config\":{\"preset\":\"grain\"}},\
            {\"name\":\"b\",\"config\":{\"preset\":\"nano\"}}]}";
        let err = ServeSpec::parse(mixed).unwrap_err();
        assert!(format!("{err:#}").contains("preset"), "{err:#}");
    }

    #[test]
    fn admission_rejects_budget_below_modeled_footprint() {
        let _g = crate::util::test_knob_lock();
        crate::util::reset_all_knobs();
        // 0.001 MB = 1000 bytes: far below any model's weights alone
        let spec = ServeSpec::parse(&grain_spec(&[("starved", 4)], Some(0.001))).unwrap();
        let out = serve(&spec, &|| {}).unwrap();
        assert_eq!(out.len(), 1);
        assert!(!out[0].admitted);
        assert!(out[0].result.is_none());
        assert!(out[0].fate.as_deref().unwrap().contains("modeled footprint"));
    }
}
