//! The checkpoint substrate: a flat, typed state bag + versioned binary
//! codec.
//!
//! Everything a suspended session needs to resume bitwise — step counters,
//! rng positions, optimizer moments, masks, data cursors, parameters — is
//! written into ONE `StateBag`: small scalars/strings as JSON metadata,
//! bulk numeric state as raw little-endian blobs. Keys are namespaced by
//! convention ("session.*", "data.*", "param/<name>", and each strategy's
//! own prefix) so independently-written components can share the bag
//! without colliding.
//!
//! Two deliberate choices keep the format bit-exact:
//! - u64 values (rng words, Adam step counts, mask words) are stored as hex
//!   STRINGS in the JSON metadata or as `Blob::U64` — `util::json` numbers
//!   are f64 and silently lose precision past 2^53.
//! - f64 values that feed back into arithmetic (patience window, dict
//!   norms, loss history) ride in `Blob::F64`, never through JSON's
//!   decimal round-trip.
//!
//! File layout (version 1, magic `BLLMSES1` — distinct from the
//! `ParamStore` checkpoint's `BLLMCKP1`):
//!
//! ```text
//! [8]  magic "BLLMSES1"
//! [4]  u32 LE: JSON metadata byte length
//! [..] JSON metadata (must contain "version": "1")
//! [4]  u32 LE: blob count
//! per blob:
//!   [4]  u32 LE: name byte length
//!   [..] name (utf-8)
//!   [1]  dtype tag: 0 = f32, 1 = u64, 2 = f64
//!   [8]  u64 LE: element count
//!   [..] raw little-endian elements
//! ```
//!
//! Decoding is fully bounds-checked: a truncated or corrupt file yields a
//! clean `Err`, never a panic and never a partially-populated bag.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::util::json::Json;

/// Session-checkpoint format version. Bump on any layout or key-semantics
/// change; `StateBag::decode` rejects mismatches.
pub const CHECKPOINT_VERSION: u64 = 1;

const MAGIC: &[u8; 8] = b"BLLMSES1";

/// A bulk numeric payload. f32 for parameters/moments, u64 for mask words
/// and counters, f64 for loss histories and norms (bit-exactness).
#[derive(Debug, Clone, PartialEq)]
pub enum Blob {
    F32(Vec<f32>),
    U64(Vec<u64>),
    F64(Vec<f64>),
}

impl Blob {
    fn tag(&self) -> u8 {
        match self {
            Blob::F32(_) => 0,
            Blob::U64(_) => 1,
            Blob::F64(_) => 2,
        }
    }

    fn len(&self) -> usize {
        match self {
            Blob::F32(v) => v.len(),
            Blob::U64(v) => v.len(),
            Blob::F64(v) => v.len(),
        }
    }

    fn elem_bytes(tag: u8) -> usize {
        match tag {
            0 => 4,
            _ => 8,
        }
    }
}

/// A flat key-value store of everything one checkpoint holds.
#[derive(Debug, Default)]
pub struct StateBag {
    pub meta: BTreeMap<String, Json>,
    pub blobs: BTreeMap<String, Blob>,
}

impl StateBag {
    pub fn new() -> StateBag {
        StateBag::default()
    }

    // ---- metadata (JSON) --------------------------------------------------

    pub fn put_num(&mut self, key: &str, v: f64) {
        self.meta.insert(key.to_string(), Json::Num(v));
    }

    pub fn get_num(&self, key: &str) -> Result<f64> {
        self.meta.get(key).ok_or_else(|| anyhow!("checkpoint missing key {key:?}"))?.as_f64()
    }

    pub fn put_usize(&mut self, key: &str, v: usize) {
        // usizes in this codebase are step counts / indices, far below 2^53
        self.put_num(key, v as f64);
    }

    pub fn get_usize(&self, key: &str) -> Result<usize> {
        self.meta.get(key).ok_or_else(|| anyhow!("checkpoint missing key {key:?}"))?.as_usize()
    }

    pub fn put_str(&mut self, key: &str, v: impl Into<String>) {
        self.meta.insert(key.to_string(), Json::Str(v.into()));
    }

    pub fn get_str(&self, key: &str) -> Result<&str> {
        self.meta.get(key).ok_or_else(|| anyhow!("checkpoint missing key {key:?}"))?.as_str()
    }

    pub fn put_bool(&mut self, key: &str, v: bool) {
        self.meta.insert(key.to_string(), Json::Bool(v));
    }

    pub fn get_bool(&self, key: &str) -> Result<bool> {
        self.meta.get(key).ok_or_else(|| anyhow!("checkpoint missing key {key:?}"))?.as_bool()
    }

    /// Full-precision u64 as a hex string (JSON numbers are f64 and would
    /// corrupt rng words / large step counts past 2^53).
    pub fn put_u64(&mut self, key: &str, v: u64) {
        self.put_str(key, format!("{v:x}"));
    }

    pub fn get_u64(&self, key: &str) -> Result<u64> {
        let s = self.get_str(key)?;
        u64::from_str_radix(s, 16).map_err(|e| anyhow!("bad u64 hex for {key:?}: {e}"))
    }

    pub fn has(&self, key: &str) -> bool {
        self.meta.contains_key(key)
    }

    // ---- blobs ------------------------------------------------------------

    pub fn put_f32s(&mut self, key: &str, v: Vec<f32>) {
        self.blobs.insert(key.to_string(), Blob::F32(v));
    }

    pub fn f32s(&self, key: &str) -> Result<&[f32]> {
        match self.blobs.get(key) {
            Some(Blob::F32(v)) => Ok(v),
            Some(b) => bail!("checkpoint blob {key:?} has dtype tag {}, wanted f32", b.tag()),
            None => bail!("checkpoint missing blob {key:?}"),
        }
    }

    pub fn put_u64s(&mut self, key: &str, v: Vec<u64>) {
        self.blobs.insert(key.to_string(), Blob::U64(v));
    }

    pub fn u64s(&self, key: &str) -> Result<&[u64]> {
        match self.blobs.get(key) {
            Some(Blob::U64(v)) => Ok(v),
            Some(b) => bail!("checkpoint blob {key:?} has dtype tag {}, wanted u64", b.tag()),
            None => bail!("checkpoint missing blob {key:?}"),
        }
    }

    pub fn put_f64s(&mut self, key: &str, v: Vec<f64>) {
        self.blobs.insert(key.to_string(), Blob::F64(v));
    }

    pub fn f64s(&self, key: &str) -> Result<&[f64]> {
        match self.blobs.get(key) {
            Some(Blob::F64(v)) => Ok(v),
            Some(b) => bail!("checkpoint blob {key:?} has dtype tag {}, wanted f64", b.tag()),
            None => bail!("checkpoint missing blob {key:?}"),
        }
    }

    pub fn has_blob(&self, key: &str) -> bool {
        self.blobs.contains_key(key)
    }

    /// Keys of every blob starting with `prefix`, in sorted order (the
    /// param restore walks "param/").
    pub fn blob_keys_with_prefix(&self, prefix: &str) -> Vec<&str> {
        self.blobs.keys().filter(|k| k.starts_with(prefix)).map(String::as_str).collect()
    }

    // ---- codec ------------------------------------------------------------

    pub fn encode(&self) -> Vec<u8> {
        let mut meta = self.meta.clone();
        meta.insert("version".into(), Json::Str(format!("{CHECKPOINT_VERSION}")));
        let meta_bytes = Json::Obj(meta).to_string().into_bytes();
        let blob_cap: usize = self.blobs.values().map(|b| 32 + b.len() * 8).sum();
        let mut out = Vec::with_capacity(8 + 4 + meta_bytes.len() + 4 + blob_cap);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&(meta_bytes.len() as u32).to_le_bytes());
        out.extend_from_slice(&meta_bytes);
        out.extend_from_slice(&(self.blobs.len() as u32).to_le_bytes());
        for (name, blob) in &self.blobs {
            let nb = name.as_bytes();
            out.extend_from_slice(&(nb.len() as u32).to_le_bytes());
            out.extend_from_slice(nb);
            out.push(blob.tag());
            out.extend_from_slice(&(blob.len() as u64).to_le_bytes());
            match blob {
                Blob::F32(v) => {
                    for x in v {
                        out.extend_from_slice(&x.to_le_bytes());
                    }
                }
                Blob::U64(v) => {
                    for x in v {
                        out.extend_from_slice(&x.to_le_bytes());
                    }
                }
                Blob::F64(v) => {
                    for x in v {
                        out.extend_from_slice(&x.to_le_bytes());
                    }
                }
            }
        }
        out
    }

    pub fn decode(bytes: &[u8]) -> Result<StateBag> {
        let mut c = Cursor { b: bytes, i: 0 };
        let magic = c.take(8)?;
        if magic != MAGIC {
            bail!("not a session checkpoint (bad magic {magic:?})");
        }
        let meta_len = c.u32()? as usize;
        let meta_src = std::str::from_utf8(c.take(meta_len)?)
            .map_err(|e| anyhow!("checkpoint metadata is not utf-8: {e}"))?;
        let meta_json = Json::parse(meta_src)?;
        let version = meta_json.req("version")?.as_str()?;
        if version != format!("{CHECKPOINT_VERSION}") {
            bail!(
                "session checkpoint version {version:?} unsupported (this build reads \
                 version {CHECKPOINT_VERSION})"
            );
        }
        let mut meta = meta_json.as_obj()?.clone();
        meta.remove("version");
        let n_blobs = c.u32()? as usize;
        let mut blobs = BTreeMap::new();
        for _ in 0..n_blobs {
            let name_len = c.u32()? as usize;
            let name = std::str::from_utf8(c.take(name_len)?)
                .map_err(|e| anyhow!("checkpoint blob name is not utf-8: {e}"))?
                .to_string();
            let tag = c.take(1)?[0];
            if tag > 2 {
                bail!("checkpoint blob {name:?} has unknown dtype tag {tag}");
            }
            let n_elems = c.u64()? as usize;
            let n_bytes = n_elems
                .checked_mul(Blob::elem_bytes(tag))
                .ok_or_else(|| anyhow!("checkpoint blob {name:?} length overflows"))?;
            let raw = c.take(n_bytes)?;
            let w8 = |w: &[u8]| [w[0], w[1], w[2], w[3], w[4], w[5], w[6], w[7]];
            let blob = match tag {
                0 => Blob::F32(
                    raw.chunks_exact(4)
                        .map(|w| f32::from_le_bytes([w[0], w[1], w[2], w[3]]))
                        .collect(),
                ),
                1 => Blob::U64(raw.chunks_exact(8).map(|w| u64::from_le_bytes(w8(w))).collect()),
                _ => Blob::F64(raw.chunks_exact(8).map(|w| f64::from_le_bytes(w8(w))).collect()),
            };
            blobs.insert(name, blob);
        }
        if c.i != bytes.len() {
            bail!("checkpoint has {} trailing bytes after the last blob", bytes.len() - c.i);
        }
        Ok(StateBag { meta, blobs })
    }
}

/// Bounds-checked byte reader: every `take` validates the remaining length,
/// so truncation surfaces as an error naming the missing byte count.
struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .i
            .checked_add(n)
            .filter(|&e| e <= self.b.len())
            .ok_or_else(|| {
                anyhow!(
                    "truncated checkpoint: wanted {n} bytes at offset {}, file has {}",
                    self.i,
                    self.b.len()
                )
            })?;
        let s = &self.b[self.i..end];
        self.i = end;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        let w = self.take(4)?;
        Ok(u32::from_le_bytes([w[0], w[1], w[2], w[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let w = self.take(8)?;
        Ok(u64::from_le_bytes([w[0], w[1], w[2], w[3], w[4], w[5], w[6], w[7]]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_bag() -> StateBag {
        let mut b = StateBag::new();
        b.put_num("session.step", 42.0);
        b.put_str("session.method", "blockllm");
        b.put_bool("flag", true);
        b.put_u64("rng.word", 0xDEAD_BEEF_CAFE_F00D);
        b.put_f32s("param/w", vec![1.0, -2.5, f32::MIN_POSITIVE]);
        b.put_u64s("mask.words", vec![u64::MAX, 0, 0x8000_0000_0000_0001]);
        b.put_f64s("losses", vec![5.0, 4.999999999999999, -0.0]);
        b
    }

    #[test]
    fn roundtrip_preserves_every_bit() {
        let bag = sample_bag();
        let bytes = bag.encode();
        let back = StateBag::decode(&bytes).unwrap();
        assert_eq!(back.get_num("session.step").unwrap(), 42.0);
        assert_eq!(back.get_str("session.method").unwrap(), "blockllm");
        assert!(back.get_bool("flag").unwrap());
        assert_eq!(back.get_u64("rng.word").unwrap(), 0xDEAD_BEEF_CAFE_F00D);
        assert_eq!(back.f32s("param/w").unwrap(), bag.f32s("param/w").unwrap());
        assert_eq!(back.u64s("mask.words").unwrap(), bag.u64s("mask.words").unwrap());
        let (a, b) = (back.f64s("losses").unwrap(), bag.f64s("losses").unwrap());
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn u64_meta_survives_past_f64_precision() {
        // 2^53 + 1 is the first integer f64 cannot represent — the reason
        // u64s go through hex strings, not Json::Num
        let mut b = StateBag::new();
        b.put_u64("big", (1u64 << 53) + 1);
        let back = StateBag::decode(&b.encode()).unwrap();
        assert_eq!(back.get_u64("big").unwrap(), (1u64 << 53) + 1);
    }

    #[test]
    fn truncation_anywhere_is_a_clean_error() {
        let bytes = sample_bag().encode();
        // every strict prefix must fail with Err, never panic
        for cut in 0..bytes.len() {
            assert!(
                StateBag::decode(&bytes[..cut]).is_err(),
                "decode accepted a {cut}-byte truncation of a {}-byte file",
                bytes.len()
            );
        }
    }

    #[test]
    fn bad_magic_and_version_rejected() {
        let mut bytes = sample_bag().encode();
        let err = StateBag::decode(b"NOTACKPT").unwrap_err();
        assert!(format!("{err}").contains("magic"), "{err}");
        // corrupt the version string in the JSON metadata
        let json_start = 12;
        let json_len = u32::from_le_bytes([bytes[8], bytes[9], bytes[10], bytes[11]]) as usize;
        let meta = String::from_utf8(bytes[json_start..json_start + json_len].to_vec()).unwrap();
        assert!(meta.contains("\"version\":\"1\""));
        let bumped = meta.replace("\"version\":\"1\"", "\"version\":\"9\"");
        bytes.splice(json_start..json_start + json_len, bumped.into_bytes());
        let err = StateBag::decode(&bytes).unwrap_err();
        assert!(format!("{err}").contains("version"), "{err}");
    }

    #[test]
    fn unknown_dtype_and_trailing_bytes_rejected() {
        let mut bag = StateBag::new();
        bag.put_f32s("x", vec![1.0]);
        let mut bytes = bag.encode();
        // dtype tag byte sits right after the blob-name bytes
        let meta_len = u32::from_le_bytes([bytes[8], bytes[9], bytes[10], bytes[11]]) as usize;
        let tag_at = 12 + meta_len + 4 + 4 + 1; // n_blobs + name_len + "x"
        assert_eq!(bytes[tag_at], 0);
        bytes[tag_at] = 7;
        assert!(StateBag::decode(&bytes).is_err());
        bytes[tag_at] = 0;
        bytes.push(0xAB);
        let err = StateBag::decode(&bytes).unwrap_err();
        assert!(format!("{err}").contains("trailing"), "{err}");
    }

    #[test]
    fn typed_blob_access_rejects_wrong_dtype() {
        let bag = sample_bag();
        assert!(bag.u64s("param/w").is_err());
        assert!(bag.f32s("mask.words").is_err());
        assert!(bag.f64s("param/w").is_err());
        assert!(bag.f32s("no-such-key").is_err());
    }
}
