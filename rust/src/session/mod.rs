//! Sessions: the serializable unit of training.
//!
//! A [`Session`] owns everything `Trainer::train_lm`/`train_cls` used to
//! keep on the stack for the whole run — the trainer (backend, params,
//! strategy, memory tracker), the task's data streams, and the loop
//! accumulators (loss history, eval points, wall/exec time). Because that
//! state is an explicit object instead of local variables, a run can stop
//! after ANY optimizer step ([`Session::suspend`] → one versioned
//! [`state::StateBag`] checkpoint) and continue later ([`Session::resume`])
//! with bitwise-identical results: suspend-at-N + resume + train-to-2N
//! produces the same loss bits and parameter bits as an uninterrupted 2N
//! run (tests/session_resume.rs pins this across threads × grad-stream).
//!
//! [`TaskData`] is the one place the task → data-generator mapping lives;
//! the run driver, the eval command, and the serve scheduler all route
//! through it (this mapping used to be copy-pasted at three call sites).
//!
//! The [`scheduler`] submodule multiplexes many sessions over one shared
//! backend (`pallas serve`), suspending and resuming at slice boundaries —
//! which is exactly why resume must be bitwise: a time-sliced session must
//! be indistinguishable from a solo run.

pub mod scheduler;
pub mod state;

use anyhow::{bail, Context, Result};

use crate::backend::{self, Backend, Targets};
use crate::config::{Task, TrainConfig};
use crate::data::{alpacasim::AlpacaSim, c4sim::C4Sim, gluesim::GlueSim, ClsBatch, LmBatch};
use crate::data::{ClsSource, LmStream};
use crate::memory::{MemBreakdown, F32};
use crate::model::ParamStore;
use crate::runtime::ParamSpec;
use crate::trainer::{EvalPoint, RunResult, Trainer};
use crate::util::Stopwatch;
use state::StateBag;

/// The train/eval data streams for one task — the single source of truth
/// for which generator (and which seed stream) each `Task` trains on.
pub enum TaskData {
    C4 { train: C4Sim, eval: C4Sim },
    Alpaca { train: AlpacaSim, eval: AlpacaSim },
    /// GlueSim carries its own train and eval rng streams internally (it
    /// also serves DomainShift, which is GLUE task 4 — the IMDb stand-in).
    Glue(GlueSim),
}

impl TaskData {
    /// Fresh streams at the config's seed. Eval streams use `seed ^ 0xEEEE`
    /// so they never replay training batches.
    pub fn open(cfg: &TrainConfig) -> TaskData {
        let seed = cfg.seed;
        match cfg.task {
            Task::C4Pretrain => {
                TaskData::C4 { train: C4Sim::new(seed), eval: C4Sim::new(seed ^ 0xEEEE) }
            }
            Task::AlpacaFinetune => TaskData::Alpaca {
                train: AlpacaSim::new(seed),
                eval: AlpacaSim::new(seed ^ 0xEEEE),
            },
            Task::Glue(i) => TaskData::Glue(GlueSim::new(i, seed)),
            Task::DomainShift => TaskData::Glue(GlueSim::new(4, seed)),
        }
    }

    /// Serialize every stream cursor under the "data." namespace.
    pub fn state_save(&self, bag: &mut StateBag) {
        match self {
            TaskData::C4 { train, eval } => {
                train.state_save(bag, "data.train");
                eval.state_save(bag, "data.eval");
            }
            TaskData::Alpaca { train, eval } => {
                train.state_save(bag, "data.train");
                eval.state_save(bag, "data.eval");
            }
            TaskData::Glue(g) => g.state_save(bag, "data.glue"),
        }
    }

    /// Restore cursors written by [`Self::state_save`] (same task only —
    /// the config round-trip guarantees the variants line up).
    pub fn state_load(&mut self, bag: &StateBag) -> Result<()> {
        match self {
            TaskData::C4 { train, eval } => {
                train.state_load(bag, "data.train")?;
                eval.state_load(bag, "data.eval")?;
            }
            TaskData::Alpaca { train, eval } => {
                train.state_load(bag, "data.train")?;
                eval.state_load(bag, "data.eval")?;
            }
            TaskData::Glue(g) => g.state_load(bag, "data.glue")?,
        }
        Ok(())
    }
}

/// One optimizer step's microbatches, drawn up front (selection events may
/// replay them — see trainer::optim_step).
enum MicroBatches {
    Lm(Vec<LmBatch>),
    Cls(Vec<ClsBatch>, /* regression */ bool),
}

/// A training run as a first-class, suspendable object.
pub struct Session {
    trainer: Trainer,
    data: TaskData,
    train_losses: Vec<f64>,
    evals: Vec<EvalPoint>,
    /// wall/exec seconds accumulated across run_steps calls, so a
    /// suspended-and-resumed run still reports its total cost
    wall_accum: f64,
    exec_accum: f64,
}

impl Session {
    /// Open a fresh session: config-resolved backend, init (or warm-start)
    /// params, fresh data streams at the config's seed.
    pub fn new(cfg: &TrainConfig, warm: Option<&ParamStore>) -> Result<Session> {
        let be = backend::open(cfg)?;
        Self::with_backend(be, cfg, warm)
    }

    /// Like [`Self::new`] but over an explicit backend (the serve scheduler
    /// threads ONE backend through every session).
    pub fn with_backend(
        backend: Box<dyn Backend>,
        cfg: &TrainConfig,
        warm: Option<&ParamStore>,
    ) -> Result<Session> {
        let trainer = Trainer::new(backend, cfg.clone(), warm)?;
        let data = TaskData::open(cfg);
        Ok(Session {
            trainer,
            data,
            train_losses: Vec::new(),
            evals: Vec::new(),
            wall_accum: 0.0,
            exec_accum: 0.0,
        })
    }

    // ---- progress ---------------------------------------------------------

    /// 0-based optimizer steps completed so far.
    pub fn step(&self) -> usize {
        self.trainer.step()
    }

    /// Total steps this session will run.
    pub fn target_steps(&self) -> usize {
        self.trainer.cfg.steps
    }

    /// True once the session has reached its target step count.
    pub fn done(&self) -> bool {
        self.trainer.step() >= self.trainer.cfg.steps
    }

    /// The session's resolved training configuration.
    pub fn cfg(&self) -> &TrainConfig {
        &self.trainer.cfg
    }

    /// The live parameter store (read-only view).
    pub fn store(&self) -> &ParamStore {
        &self.trainer.store
    }

    /// Per-step training losses recorded so far (bitwise-pinned by CI).
    pub fn train_losses(&self) -> &[f64] {
        &self.train_losses
    }

    // ---- memory accounting (serve admission + enforcement) ----------------

    /// Bytes the session is MODELED to need at peak: dense weights + the
    /// strategy's gradient retention + its optimizer state + whatever
    /// activations the backend currently reports. Admission control checks
    /// budgets against this before a session has run a single step.
    pub fn modeled_footprint_bytes(&self) -> u64 {
        let n = self.trainer.store.n_params() as u64;
        let grads = self.trainer.strategy.modeled_grad_elems(n);
        let state = self.trainer.strategy.modeled_state_elems(n);
        (n + grads + state) * F32 + self.trainer.backend.activation_bytes()
    }

    /// Like the modeled footprint, but with the gradient term replaced by
    /// the MEASURED peak gradient bytes (grads layer, counted at consume
    /// time) — the scheduler re-checks budgets against this after every
    /// slice, catching strategies whose real retention exceeds the model.
    pub fn measured_footprint_bytes(&self) -> u64 {
        let n = self.trainer.store.n_params() as u64;
        let state = self.trainer.strategy.modeled_state_elems(n);
        (n + state) * F32
            + self.trainer.mem.peak_grad_measured
            + self.trainer.backend.activation_bytes()
    }

    // ---- the loop ---------------------------------------------------------

    /// Run up to `k` optimizer steps (stops early at the target step
    /// count), honoring grad accumulation and the eval cadence exactly as
    /// the old `train_lm`/`train_cls` loops did. Returns how many steps ran.
    pub fn run_steps(&mut self, k: usize) -> Result<usize> {
        let sw = Stopwatch::start();
        let exec0 = self.trainer.backend.exec_secs();
        let (b, t) = self.trainer.batch_shape();
        let accum = self.trainer.cfg.grad_accum.max(1);
        let mut ran = 0usize;
        while ran < k && self.trainer.step() < self.trainer.cfg.steps {
            let s = self.trainer.step();
            // draw the step's microbatches up front: selection events may
            // replay them (the data is tiny next to one gradient buffer)
            let mb = match &mut self.data {
                TaskData::C4 { train, .. } => {
                    MicroBatches::Lm((0..accum).map(|_| train.next_batch(b, t)).collect())
                }
                TaskData::Alpaca { train, .. } => {
                    MicroBatches::Lm((0..accum).map(|_| train.next_batch(b, t)).collect())
                }
                TaskData::Glue(g) => {
                    let reg = g.regression();
                    MicroBatches::Cls((0..accum).map(|_| g.batch(b, t, true)).collect(), reg)
                }
            };
            let mean_loss = match &mb {
                MicroBatches::Lm(batches) => {
                    let micro: Vec<(&[i32], Targets<'_>)> = batches
                        .iter()
                        .map(|ba| (ba.tokens.as_slice(), Targets::Lm(ba.targets.as_slice())))
                        .collect();
                    self.trainer.optim_step(&micro)?
                }
                MicroBatches::Cls(batches, regression) => {
                    let micro: Vec<(&[i32], Targets<'_>)> = batches
                        .iter()
                        .map(|ba| {
                            let tg = if *regression {
                                Targets::Reg(ba.labels_f.as_slice())
                            } else {
                                Targets::Cls(ba.labels_i.as_slice())
                            };
                            (ba.tokens.as_slice(), tg)
                        })
                        .collect();
                    self.trainer.optim_step(&micro)?
                }
            };
            self.train_losses.push(mean_loss);
            if self.trainer.cfg.eval_every > 0 && (s + 1) % self.trainer.cfg.eval_every == 0 {
                let ev = self.eval_now().context("eval")?;
                self.evals.push(ev);
            }
            ran += 1;
        }
        self.wall_accum += sw.secs();
        self.exec_accum += self.trainer.backend.exec_secs() - exec0;
        Ok(ran)
    }

    /// Run every remaining step.
    pub fn run_to_completion(&mut self) -> Result<()> {
        while !self.done() {
            self.run_steps(usize::MAX)?;
        }
        Ok(())
    }

    /// One eval on the session's eval stream at the current step.
    pub fn eval_now(&mut self) -> Result<EvalPoint> {
        match &mut self.data {
            TaskData::C4 { eval, .. } => self.trainer.eval_lm(eval),
            TaskData::Alpaca { eval, .. } => self.trainer.eval_lm(eval),
            TaskData::Glue(g) => self.trainer.eval_cls(g),
        }
    }

    /// Close out the run: final eval if the last step lacks one (same rule
    /// as the old train loops), then assemble the `RunResult`. Returns the
    /// trained parameters too.
    pub fn finish(self) -> Result<(RunResult, ParamStore)> {
        let (res, store, _backend) = self.finish_parts()?;
        Ok((res, store))
    }

    /// [`Self::finish`], also handing the backend back to the caller (the
    /// serve scheduler reuses it for the next session's slice).
    pub fn finish_parts(mut self) -> Result<(RunResult, ParamStore, Box<dyn Backend>)> {
        if self.evals.is_empty() || self.evals.last().map(|e| e.step) != Some(self.trainer.step())
        {
            let exec0 = self.trainer.backend.exec_secs();
            let ev = self.eval_now()?;
            self.evals.push(ev);
            self.exec_accum += self.trainer.backend.exec_secs() - exec0;
        }
        let mut tr = self.trainer;
        let res = tr.finish(self.train_losses, self.evals, self.wall_accum, self.exec_accum);
        Ok((res, tr.store, tr.backend))
    }

    // ---- suspend / resume -------------------------------------------------

    /// Serialize the ENTIRE session — config, step counter, loss/eval
    /// history, data cursors, strategy state (moments, masks, scorer, rng),
    /// memory peaks, timing accumulators, and every parameter tensor — into
    /// one versioned checkpoint. See `state` for the binary format.
    pub fn suspend(&self) -> Vec<u8> {
        let mut bag = StateBag::new();
        for (k, v) in self.trainer.cfg.to_kv() {
            bag.put_str(&format!("cfg.{k}"), v);
        }
        bag.put_usize("session.step", self.trainer.step());
        bag.put_f64s("session.losses", self.train_losses.clone());
        bag.put_usize("session.n_evals", self.evals.len());
        for (i, ev) in self.evals.iter().enumerate() {
            bag.put_f64s(&format!("session.eval/{i}"), vec![ev.step as f64, ev.loss, ev.metric]);
            bag.put_f64s(&format!("session.eval_preds/{i}"), ev.preds.clone());
            bag.put_f64s(&format!("session.eval_labels/{i}"), ev.labels.clone());
        }
        bag.put_f64s(
            "session.timing",
            vec![self.wall_accum, self.exec_accum, self.trainer.phase_strategy()],
        );
        let m = &self.trainer.mem;
        bag.put_u64s(
            "session.mem",
            vec![
                m.peak_total,
                m.peak_rss,
                m.peak_grad_measured,
                m.peak.weights,
                m.peak.grads,
                m.peak.optim_m,
                m.peak.optim_v,
                m.peak.extra,
                m.peak.activations,
                m.current.weights,
                m.current.grads,
                m.current.optim_m,
                m.current.optim_v,
                m.current.extra,
                m.current.activations,
                m.peak_state_shard_measured,
            ],
        );
        self.data.state_save(&mut bag);
        self.trainer.strategy.state_save(&mut bag);
        for (i, spec) in self.trainer.store.specs.iter().enumerate() {
            bag.put_f32s(&format!("param/{}", spec.name), self.trainer.store.bufs[i].clone());
            bag.put_u64s(
                &format!("param_shape/{}", spec.name),
                spec.shape.iter().map(|&d| d as u64).collect(),
            );
        }
        bag.encode()
    }

    /// [`Self::suspend`], consuming the session and handing the backend
    /// back (serve slice boundary: checkpoint this session, lend the
    /// backend to the next one).
    pub fn suspend_parts(self) -> (Vec<u8>, Box<dyn Backend>) {
        let bytes = self.suspend();
        (bytes, self.trainer.backend)
    }

    /// Rebuild a session from a [`Self::suspend`] checkpoint, opening a
    /// config-resolved backend.
    pub fn resume(bytes: &[u8]) -> Result<Session> {
        let bag = StateBag::decode(bytes)?;
        let cfg = cfg_from_bag(&bag)?;
        let be = backend::open(&cfg)?;
        Self::resume_from_bag(be, &bag)
    }

    /// Rebuild over an explicit (possibly shared) backend.
    pub fn resume_with_backend(backend: Box<dyn Backend>, bytes: &[u8]) -> Result<Session> {
        let bag = StateBag::decode(bytes)?;
        Self::resume_from_bag(backend, &bag)
    }

    fn resume_from_bag(backend: Box<dyn Backend>, bag: &StateBag) -> Result<Session> {
        let cfg = cfg_from_bag(bag)?;

        // Rebuild the checkpointed parameters as a standalone store, then
        // adopt them through the warm-start path. `Trainer::new` bails when
        // the overlap is EMPTY (wrong model entirely); the coverage check
        // below bails unless the overlap is TOTAL — resume never silently
        // mixes checkpointed tensors with fresh init.
        let mut specs: Vec<ParamSpec> = Vec::new();
        let mut bufs: Vec<Vec<f32>> = Vec::new();
        for key in bag.blob_keys_with_prefix("param/") {
            let name = key.strip_prefix("param/").expect("prefix-filtered").to_string();
            let shape: Vec<usize> = bag
                .u64s(&format!("param_shape/{name}"))
                .with_context(|| format!("shape for checkpointed tensor {name:?}"))?
                .iter()
                .map(|&d| d as usize)
                .collect();
            let data = bag.f32s(key)?.to_vec();
            let numel: usize = shape.iter().product();
            if numel != data.len() {
                bail!(
                    "checkpointed tensor {name:?} has {} elements but shape {shape:?} \
                     wants {numel}",
                    data.len()
                );
            }
            specs.push(ParamSpec { name, shape });
            bufs.push(data);
        }
        if specs.is_empty() {
            bail!("session checkpoint holds no parameter tensors");
        }
        let mut saved = ParamStore::zeros(&specs);
        saved.bufs = bufs;

        let mut trainer = Trainer::new(backend, cfg.clone(), Some(&saved))
            .context("rebuilding trainer from checkpoint")?;
        let covered = trainer.store.load_overlapping(&saved);
        if covered != trainer.store.n_tensors() {
            bail!(
                "checkpoint parameters cover {covered} of {} model tensors — refusing a \
                 partial resume (preset/config mismatch?)",
                trainer.store.n_tensors()
            );
        }

        trainer
            .strategy
            .state_load(bag)
            .context("restoring optimizer/strategy state")?;
        trainer.set_step(bag.get_usize("session.step")?);

        let timing = bag.f64s("session.timing")?;
        if timing.len() != 3 {
            bail!("session.timing wants 3 entries, checkpoint has {}", timing.len());
        }
        trainer.set_phase_strategy(timing[2]);

        // 16 entries since the dist layer added peak_state_shard_measured;
        // 15-entry checkpoints (pre-dist) are still accepted, the new peak
        // simply restarts at 0
        let mw = bag.u64s("session.mem")?;
        if mw.len() != 15 && mw.len() != 16 {
            bail!("session.mem wants 15 or 16 entries, checkpoint has {}", mw.len());
        }
        trainer.mem.peak_total = mw[0];
        trainer.mem.peak_rss = mw[1];
        trainer.mem.peak_grad_measured = mw[2];
        trainer.mem.peak_state_shard_measured = mw.get(15).copied().unwrap_or(0);
        trainer.mem.peak = MemBreakdown {
            weights: mw[3],
            grads: mw[4],
            optim_m: mw[5],
            optim_v: mw[6],
            extra: mw[7],
            activations: mw[8],
        };
        trainer.mem.current = MemBreakdown {
            weights: mw[9],
            grads: mw[10],
            optim_m: mw[11],
            optim_v: mw[12],
            extra: mw[13],
            activations: mw[14],
        };

        let train_losses = bag.f64s("session.losses")?.to_vec();
        let n_evals = bag.get_usize("session.n_evals")?;
        let mut evals = Vec::with_capacity(n_evals);
        for i in 0..n_evals {
            let hdr = bag.f64s(&format!("session.eval/{i}"))?;
            if hdr.len() != 3 {
                bail!("session.eval/{i} wants 3 entries, checkpoint has {}", hdr.len());
            }
            evals.push(EvalPoint {
                step: hdr[0] as usize,
                loss: hdr[1],
                metric: hdr[2],
                preds: bag.f64s(&format!("session.eval_preds/{i}"))?.to_vec(),
                labels: bag.f64s(&format!("session.eval_labels/{i}"))?.to_vec(),
            });
        }

        let mut data = TaskData::open(&cfg);
        data.state_load(bag).context("restoring data-stream cursors")?;

        // the adopted backend may have cached another session's device
        // params — invalidate everything (empty slice = all layers)
        trainer.backend.params_updated(&[]);
        // per-session obs scoping: profile deltas start at THIS resume, so
        // a slice's profile never charges work from co-scheduled sessions
        trainer.rebase_obs();

        Ok(Session {
            trainer,
            data,
            train_losses,
            evals,
            wall_accum: timing[0],
            exec_accum: timing[1],
        })
    }
}

/// Rebuild the config embedded in a checkpoint ("cfg.<key>" metadata,
/// values exactly as `TrainConfig::set` accepts them).
fn cfg_from_bag(bag: &StateBag) -> Result<TrainConfig> {
    let mut pairs: Vec<(String, String)> = Vec::new();
    for (k, v) in &bag.meta {
        if let Some(key) = k.strip_prefix("cfg.") {
            pairs.push((key.to_string(), v.as_str()?.to_string()));
        }
    }
    if pairs.is_empty() {
        bail!("session checkpoint carries no embedded config");
    }
    TrainConfig::from_kv(&pairs).context("rebuilding config from checkpoint")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Method;

    fn tiny_cfg(method: Method, steps: usize) -> TrainConfig {
        let mut cfg = TrainConfig::default();
        cfg.preset = "nano".into();
        cfg.method = method;
        cfg.steps = steps;
        cfg.eval_every = 0;
        cfg.eval_batches = 1;
        cfg.seed = 7;
        cfg
    }

    #[test]
    fn session_matches_trainer_loop_bitwise() {
        let _k = crate::util::test_knob_lock();
        crate::util::reset_all_knobs();
        let cfg = tiny_cfg(Method::FullAdam, 4);
        // old-style loop
        let mut tr = Trainer::open(cfg.clone(), None).unwrap();
        let mut train = C4Sim::new(cfg.seed);
        let mut eval = C4Sim::new(cfg.seed ^ 0xEEEE);
        let want = tr.train_lm(&mut train, &mut eval).unwrap();
        // session loop
        let mut sess = Session::new(&cfg, None).unwrap();
        sess.run_to_completion().unwrap();
        let (got, store) = sess.finish().unwrap();
        assert_eq!(want.train_losses.len(), got.train_losses.len());
        for (a, b) in want.train_losses.iter().zip(&got.train_losses) {
            assert_eq!(a.to_bits(), b.to_bits(), "train loss bits diverged");
        }
        assert_eq!(want.evals.len(), got.evals.len());
        for (a, b) in want.evals.iter().zip(&got.evals) {
            assert_eq!(a.step, b.step);
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "eval loss bits diverged");
        }
        for (i, buf) in tr.store.bufs.iter().enumerate() {
            for (x, y) in buf.iter().zip(&store.bufs[i]) {
                assert_eq!(x.to_bits(), y.to_bits(), "param bits diverged");
            }
        }
    }

    #[test]
    fn resume_rejects_wrong_preset() {
        let _k = crate::util::test_knob_lock();
        crate::util::reset_all_knobs();
        let cfg = tiny_cfg(Method::FullAdam, 2);
        let mut sess = Session::new(&cfg, None).unwrap();
        sess.run_steps(1).unwrap();
        let bytes = sess.suspend();
        // corrupt the embedded config's preset: the rebuilt model shares no
        // tensors with the checkpoint, which must trip the zero-overlap
        // bail in the warm-start path, not load garbage
        let bag = StateBag::decode(&bytes).unwrap();
        let mut tampered = StateBag::new();
        tampered.meta = bag.meta.clone();
        tampered.blobs = bag.blobs.clone();
        tampered.put_str("cfg.preset", "tiny");
        let err = Session::resume(&tampered.encode()).unwrap_err();
        let msg = format!("{err:#}");
        assert!(
            msg.contains("no tensors") || msg.contains("cover"),
            "unexpected resume error: {msg}"
        );
    }

    #[test]
    fn resume_rejects_missing_param_tensor() {
        let _k = crate::util::test_knob_lock();
        crate::util::reset_all_knobs();
        let cfg = tiny_cfg(Method::FullAdam, 2);
        let mut sess = Session::new(&cfg, None).unwrap();
        sess.run_steps(1).unwrap();
        let bytes = sess.suspend();
        let bag = StateBag::decode(&bytes).unwrap();
        let mut tampered = StateBag::new();
        tampered.meta = bag.meta.clone();
        tampered.blobs = bag.blobs.clone();
        // drop one tensor: partial coverage must be refused outright
        let victim = bag.blob_keys_with_prefix("param/")[0].to_string();
        tampered.blobs.remove(&victim);
        tampered.blobs.remove(&victim.replace("param/", "param_shape/"));
        let err = Session::resume(&tampered.encode()).unwrap_err();
        assert!(format!("{err:#}").contains("cover"), "{err:#}");
    }
}
