//! Host f32 tensor substrate.
//!
//! `Tensor` owns its buffer (activations, optimizer math); [`View`] borrows
//! one (parameter tensors read straight out of the `ParamStore`, no per-use
//! clone); [`BatchView`] borrows a strided BATCH of equally-shaped matrices
//! (per-head attention operands fed to `linalg::gemm_batched`). All feed
//! the matmul family, which delegates to the blocked multi-threaded kernel
//! layer in `linalg::gemm` — the native backend's model fwd/bwd and the
//! optimizer-side algebra (GaLore projections, LoRA adapters, gradient
//! statistics) all run on the same kernels.

use anyhow::{bail, Result};

use crate::linalg::gemm::{self, Mat};
use crate::util;

/// Dense row-major f32 tensor, rank 1 or 2 in practice.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elems, got {}", shape, n, data.len());
        }
        Ok(Tensor { shape: shape.to_vec(), data })
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rows(&self) -> usize {
        match self.shape.len() {
            1 => 1,
            2 => self.shape[0],
            r => panic!("rank {r} tensor has no rows"),
        }
    }

    pub fn cols(&self) -> usize {
        match self.shape.len() {
            1 => self.shape[0],
            2 => self.shape[1],
            r => panic!("rank {r} tensor has no cols"),
        }
    }

    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols() + j]
    }

    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        let c = self.cols();
        self.data[i * c + j] = v;
    }

    // ---- elementwise ------------------------------------------------------

    pub fn scale(&mut self, a: f32) {
        for x in &mut self.data {
            *x *= a;
        }
    }

    /// self += a * other
    pub fn axpy(&mut self, a: f32, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        for (x, y) in self.data.iter_mut().zip(&other.data) {
            *x += a * y;
        }
    }

    // ---- reductions ------------------------------------------------------

    pub fn sq_sum(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.sq_sum().sqrt()
    }

    /// Root-mean-square norm: ||x||_F / sqrt(n). Size-invariant layer score.
    pub fn rms_norm(&self) -> f64 {
        (self.sq_sum() / self.numel() as f64).sqrt()
    }

    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |a, &x| a.max(x.abs()))
    }

    // ---- matmul family -----------------------------------------------------
    // All three delegate to the blocked multi-threaded kernels in
    // `linalg::gemm` (thread count: util::num_threads()). `b` is any `Mat`,
    // so parameter `View`s plug in without cloning.

    /// C = A @ B for A [m,k], B [k,n].
    pub fn matmul<B: Mat + ?Sized>(&self, b: &B) -> Tensor {
        gemm::matmul(self, b)
    }

    /// C = Aᵀ @ B for A [k,m], B [k,n] (no explicit transpose).
    pub fn matmul_tn<B: Mat + ?Sized>(&self, b: &B) -> Tensor {
        gemm::matmul_tn(self, b)
    }

    /// C = A @ Bᵀ for A [m,k], B [n,k].
    pub fn matmul_nt<B: Mat + ?Sized>(&self, b: &B) -> Tensor {
        gemm::matmul_nt(self, b)
    }

    /// Borrow this tensor as a zero-copy matrix view.
    pub fn view(&self) -> View<'_> {
        View { rows: self.rows(), cols: self.cols(), data: &self.data }
    }

    pub fn transpose(&self) -> Tensor {
        let (m, n) = (self.rows(), self.cols());
        let mut t = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                t[j * m + i] = self.data[i * n + j];
            }
        }
        Tensor { shape: vec![n, m], data: t }
    }

    // ---- rowwise ops (native-backend substrate) ---------------------------

    /// In-place numerically-stable softmax over each row. Rows that are
    /// entirely -inf (fully masked) become all-zero rather than NaN.
    /// Parallelizes over rows when the tensor is large enough.
    pub fn softmax_rows(&mut self) {
        self.softmax_rows_threads(util::num_threads());
    }

    /// `softmax_rows` at an explicit worker-count budget (callers inside an
    /// already-parallel region pass their leftover threads). Small tensors
    /// stay serial (`util::par_min_elems`). Each row is self-contained, so
    /// any thread count computes identical bits.
    pub fn softmax_rows_threads(&mut self, threads: usize) {
        let n = self.cols();
        if n == 0 {
            return;
        }
        let threads = if self.numel() < util::par_min_elems() { 1 } else { threads };
        let rows = self.rows();
        gemm::par_rows(&mut self.data, rows, n, threads, |_i0, _i1, chunk| {
            for row in chunk.chunks_mut(n) {
                let m = row.iter().fold(f32::NEG_INFINITY, |a, &x| a.max(x));
                if m == f32::NEG_INFINITY {
                    row.fill(0.0);
                    continue;
                }
                let mut sum = 0.0f32;
                for x in row.iter_mut() {
                    *x = (*x - m).exp();
                    sum += *x;
                }
                let inv = 1.0 / sum;
                for x in row.iter_mut() {
                    *x *= inv;
                }
            }
        });
    }

    /// Gather rows by index: self [N, D] -> [idx.len(), D]. Panics on an
    /// out-of-range index (the embedding table owns range checking upstream).
    pub fn gather_rows(&self, idx: &[usize]) -> Tensor {
        gather_rows_impl(&self.data, self.rows(), self.cols(), idx)
    }

    /// Scatter-add rows: self[idx[j]] += rows[j] (embedding gradient).
    ///
    /// Parallelized by DESTINATION row ranges: each worker scans the full
    /// index list in order and applies only the rows it owns, so every
    /// destination element accumulates its duplicates in ascending-j order
    /// regardless of the thread count — the same bits as the serial sweep.
    pub fn scatter_rows_add(&mut self, idx: &[usize], rows: &Tensor) {
        let d = self.cols();
        assert_eq!(rows.cols(), d, "scatter_rows_add: col mismatch");
        assert_eq!(rows.rows(), idx.len(), "scatter_rows_add: row count mismatch");
        let n = self.rows();
        for &i in idx {
            assert!(i < n, "scatter_rows_add: row {i} out of {n}");
        }
        let work = idx.len().saturating_mul(d);
        let threads = if work < util::par_min_elems() { 1 } else { util::num_threads() };
        if threads <= 1 || n <= 1 {
            for (j, &i) in idx.iter().enumerate() {
                let dst = &mut self.data[i * d..(i + 1) * d];
                let src = &rows.data[j * d..(j + 1) * d];
                for (x, y) in dst.iter_mut().zip(src) {
                    *x += y;
                }
            }
            return;
        }
        let src_data = &rows.data;
        gemm::par_rows(&mut self.data, n, d, threads, |i0, i1, dst_rows| {
            for (j, &i) in idx.iter().enumerate() {
                if i >= i0 && i < i1 {
                    let dst = &mut dst_rows[(i - i0) * d..(i - i0 + 1) * d];
                    let src = &src_data[j * d..(j + 1) * d];
                    for (x, y) in dst.iter_mut().zip(src) {
                        *x += y;
                    }
                }
            }
        });
    }
}

impl Mat for Tensor {
    fn rows(&self) -> usize {
        Tensor::rows(self)
    }
    fn cols(&self) -> usize {
        Tensor::cols(self)
    }
    fn data(&self) -> &[f32] {
        &self.data
    }
}

/// Zero-copy row-major matrix view over a borrowed buffer — how the native
/// backend reads parameter tensors straight out of the `ParamStore` (the
/// fwd/bwd pass allocates only activations, never parameter copies).
#[derive(Debug, Clone, Copy)]
pub struct View<'a> {
    pub rows: usize,
    pub cols: usize,
    pub data: &'a [f32],
}

impl<'a> View<'a> {
    /// View a raw buffer under a spec shape (rank 1 = one row, like Tensor).
    pub fn new(shape: &[usize], data: &'a [f32]) -> View<'a> {
        let (rows, cols) = match shape.len() {
            1 => (1, shape[0]),
            2 => (shape[0], shape[1]),
            r => panic!("rank {r} buffer has no matrix view"),
        };
        assert_eq!(rows * cols, data.len(), "view shape {shape:?} vs len {}", data.len());
        View { rows, cols, data }
    }

    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    /// Gather rows by index: [N, D] -> owned [idx.len(), D].
    pub fn gather_rows(&self, idx: &[usize]) -> Tensor {
        gather_rows_impl(self.data, self.rows, self.cols, idx)
    }

    /// Materialize the view as an owned tensor.
    pub fn to_tensor(&self) -> Tensor {
        Tensor { shape: vec![self.rows, self.cols], data: self.data.to_vec() }
    }
}

impl Mat for View<'_> {
    fn rows(&self) -> usize {
        self.rows
    }
    fn cols(&self) -> usize {
        self.cols
    }
    fn data(&self) -> &[f32] {
        self.data
    }
}

/// Zero-copy view of a BATCH of equally-shaped row-major matrices carved
/// out of one borrowed buffer: matrix `i` starts at `offsets[i]` and its
/// rows are `row_stride` elements apart (`row_stride >= cols`, so a matrix
/// can be a column slice of a wider tensor — e.g. one attention head's
/// [t, d_head] block inside an interleaved [b*t, h*d_head] activation).
/// This is the operand type of `linalg::gemm_batched`; bounds are checked
/// once at construction so the kernels can slice without re-validating.
#[derive(Debug, Clone)]
pub struct BatchView<'a> {
    pub data: &'a [f32],
    offsets: Vec<usize>,
    pub rows: usize,
    pub cols: usize,
    pub row_stride: usize,
}

impl<'a> BatchView<'a> {
    /// Batch from explicit per-matrix offsets (the fully general form —
    /// `heads` uses it for the two-level (batch, head) stride pattern).
    pub fn from_offsets(
        data: &'a [f32],
        offsets: Vec<usize>,
        rows: usize,
        cols: usize,
        row_stride: usize,
    ) -> BatchView<'a> {
        assert!(rows > 0 && cols > 0, "BatchView: empty matrix shape {rows}x{cols}");
        assert!(row_stride >= cols, "BatchView: row stride {row_stride} < cols {cols}");
        for &off in &offsets {
            let last = off + (rows - 1) * row_stride + cols;
            assert!(
                last <= data.len(),
                "BatchView: matrix at offset {off} overruns buffer ({last} > {})",
                data.len()
            );
        }
        BatchView { data, offsets, rows, cols, row_stride }
    }

    /// Regularly strided batch: matrix `i` starts at `base + i * batch_stride`.
    #[allow(clippy::too_many_arguments)]
    pub fn strided(
        data: &'a [f32],
        batch: usize,
        rows: usize,
        cols: usize,
        row_stride: usize,
        base: usize,
        batch_stride: usize,
    ) -> BatchView<'a> {
        let offsets = (0..batch).map(|i| base + i * batch_stride).collect();
        Self::from_offsets(data, offsets, rows, cols, row_stride)
    }

    /// Dense batch: `batch` matrices packed back to back ([batch, rows, cols]).
    pub fn dense(data: &'a [f32], batch: usize, rows: usize, cols: usize) -> BatchView<'a> {
        assert_eq!(data.len(), batch * rows * cols, "BatchView::dense: buffer len");
        Self::strided(data, batch, rows, cols, cols, 0, rows * cols)
    }

    /// The b·h per-head [t, dh] matrices of an interleaved [b*t, h*dh]
    /// activation tensor, in `bh = bi*h + hi` order — the attention path's
    /// Q/K/V operands, viewed with zero copies.
    pub fn heads(x: &'a Tensor, b: usize, t: usize, h: usize, dh: usize) -> BatchView<'a> {
        let d = h * dh;
        assert_eq!(x.rows(), b * t, "BatchView::heads: rows {} != b*t {}", x.rows(), b * t);
        assert_eq!(x.cols(), d, "BatchView::heads: cols {} != h*dh {d}", x.cols());
        let offsets = (0..b * h).map(|bh| (bh / h) * t * d + (bh % h) * dh).collect();
        Self::from_offsets(&x.data, offsets, t, dh, d)
    }

    pub fn batch(&self) -> usize {
        self.offsets.len()
    }

    /// The buffer tail starting at matrix `i`'s first element (the kernels
    /// address rows relative to this; construction validated the extent).
    pub fn slice(&self, i: usize) -> &'a [f32] {
        &self.data[self.offsets[i]..]
    }

    /// Materialize matrix `i` as an owned contiguous tensor (tests and the
    /// per-head reference path).
    pub fn to_tensor(&self, i: usize) -> Tensor {
        let mut out = Tensor::zeros(&[self.rows, self.cols]);
        let src = self.slice(i);
        for r in 0..self.rows {
            out.data[r * self.cols..(r + 1) * self.cols]
                .copy_from_slice(&src[r * self.row_stride..r * self.row_stride + self.cols]);
        }
        out
    }
}

/// Row gather, parallelized over OUTPUT rows (pure copies, so any thread
/// count produces identical bits). Bounds are checked up front so the
/// parallel path can never partially fill the output.
fn gather_rows_impl(data: &[f32], n: usize, d: usize, idx: &[usize]) -> Tensor {
    for &i in idx {
        assert!(i < n, "gather_rows: row {i} out of {n}");
    }
    let mut out = vec![0.0f32; idx.len() * d];
    if d > 0 {
        let threads = if out.len() < util::par_min_elems() { 1 } else { util::num_threads() };
        gemm::par_rows(&mut out, idx.len(), d, threads, |i0, i1, rows| {
            for (li, &src) in idx[i0..i1].iter().enumerate() {
                rows[li * d..(li + 1) * d].copy_from_slice(&data[src * d..(src + 1) * d]);
            }
        });
    }
    Tensor { shape: vec![idx.len(), d], data: out }
}

/// Exact k-th largest |value| in a slice, O(n) via quickselect.
/// Returns the threshold t such that exactly >= k entries satisfy |x| >= t
/// (ties may admit more). k must satisfy 1 <= k <= len.
pub fn kth_largest_abs(xs: &[f32], k: usize) -> f32 {
    assert!(k >= 1 && k <= xs.len());
    let mut a: Vec<f32> = xs.iter().map(|x| x.abs()).collect();
    let idx = k - 1;
    // select_nth_unstable puts the idx-th *smallest* at idx; we want the
    // idx-th largest, i.e. (len - k)-th smallest.
    let pos = a.len() - k;
    let (_, v, _) = a.select_nth_unstable_by(pos, |x, y| x.partial_cmp(y).unwrap());
    let _ = idx;
    *v
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t2(rows: usize, cols: usize, v: Vec<f32>) -> Tensor {
        Tensor::from_vec(&[rows, cols], v).unwrap()
    }

    #[test]
    fn matmul_small() {
        let a = t2(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = t2(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        assert_eq!(a.matmul(&b).data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_identity() {
        let a = t2(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let id = t2(3, 3, vec![1.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 1.0]);
        assert_eq!(a.matmul(&id).data, a.data);
    }

    #[test]
    fn matmul_tn_matches_explicit_transpose() {
        let a = t2(3, 2, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let b = t2(3, 4, (0..12).map(|x| x as f32).collect());
        let got = a.matmul_tn(&b);
        let want = a.transpose().matmul(&b);
        assert_eq!(got, want);
    }

    #[test]
    fn matmul_nt_matches_explicit_transpose() {
        let a = t2(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let b = t2(4, 3, (0..12).map(|x| x as f32).collect());
        let got = a.matmul_nt(&b);
        let want = a.matmul(&b.transpose());
        assert_eq!(got, want);
    }

    #[test]
    fn views_are_zero_copy_twins_of_owned_tensors() {
        let a = t2(3, 4, (0..12).map(|x| x as f32).collect());
        let w = t2(4, 2, (0..8).map(|x| x as f32).collect());
        let v = View::new(&[4, 2], &w.data);
        assert_eq!(a.matmul(&v), a.matmul(&w), "View operand must match Tensor operand");
        assert_eq!(v.at(1, 1), w.at(1, 1));
        assert_eq!(v.to_tensor(), w);
        // rank-1 buffers view as a single row, like Tensor::rows
        let bias = [1.0f32, 2.0, 3.0];
        let bv = View::new(&[3], &bias);
        assert_eq!((bv.rows, bv.cols), (1, 3));
        assert_eq!(a.gather_rows(&[2, 0]), a.view().gather_rows(&[2, 0]));
    }

    #[test]
    fn transpose_involutive() {
        let a = t2(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn norms() {
        let a = Tensor::from_vec(&[4], vec![3.0, 4.0, 0.0, 0.0]).unwrap();
        assert!((a.fro_norm() - 5.0).abs() < 1e-9);
        assert!((a.rms_norm() - 2.5).abs() < 1e-9);
        assert_eq!(a.abs_max(), 4.0);
    }

    #[test]
    fn axpy_scale() {
        let mut a = Tensor::from_vec(&[3], vec![1.0, 2.0, 3.0]).unwrap();
        let b = Tensor::from_vec(&[3], vec![1.0, 1.0, 1.0]).unwrap();
        a.axpy(2.0, &b);
        assert_eq!(a.data, vec![3.0, 4.0, 5.0]);
        a.scale(0.5);
        assert_eq!(a.data, vec![1.5, 2.0, 2.5]);
    }

    #[test]
    fn softmax_rows_normalizes_and_handles_mask() {
        let mut a = t2(2, 3, vec![1.0, 2.0, 3.0, f32::NEG_INFINITY, 0.0, 0.0]);
        a.softmax_rows();
        let s0: f32 = a.data[0..3].iter().sum();
        assert!((s0 - 1.0).abs() < 1e-6);
        assert!(a.data[2] > a.data[1] && a.data[1] > a.data[0]);
        assert_eq!(a.data[3], 0.0); // masked entry
        assert!((a.data[4] - 0.5).abs() < 1e-6);
        // fully-masked row -> zeros, not NaN
        let mut b = t2(1, 2, vec![f32::NEG_INFINITY, f32::NEG_INFINITY]);
        b.softmax_rows();
        assert_eq!(b.data, vec![0.0, 0.0]);
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let emb = t2(4, 2, vec![0.0, 1.0, 10.0, 11.0, 20.0, 21.0, 30.0, 31.0]);
        let g = emb.gather_rows(&[2, 0, 2]);
        assert_eq!(g.shape, vec![3, 2]);
        assert_eq!(g.data, vec![20.0, 21.0, 0.0, 1.0, 20.0, 21.0]);
        let mut acc = Tensor::zeros(&[4, 2]);
        acc.scatter_rows_add(&[2, 0, 2], &g);
        // row 2 accumulated twice
        assert_eq!(acc.data, vec![0.0, 1.0, 0.0, 0.0, 40.0, 42.0, 0.0, 0.0]);
    }

    #[test]
    fn parallel_rowwise_paths_match_serial_bits() {
        // force every rowwise sweep parallel on small tensors; the chunked
        // paths must reproduce the serial bits exactly (restored below)
        let _g = crate::util::test_knob_lock();
        crate::util::set_par_min(0);
        let d = 7;
        let nrows = 23;
        let mut emb = Tensor::zeros(&[nrows, d]);
        for (i, x) in emb.data.iter_mut().enumerate() {
            *x = ((i * 37 % 101) as f32) * 0.3 - 5.0;
        }
        let idx: Vec<usize> = (0..64).map(|j| (j * 13 + 5) % nrows).collect();
        // serial reference computed by hand (duplicates accumulate in j order)
        let g = emb.gather_rows(&idx);
        let mut want_g = Vec::new();
        for &i in &idx {
            want_g.extend_from_slice(&emb.data[i * d..(i + 1) * d]);
        }
        assert_eq!(g.data, want_g);
        let mut acc = Tensor::zeros(&[nrows, d]);
        acc.scatter_rows_add(&idx, &g);
        let mut want = vec![0.0f32; nrows * d];
        for (j, &i) in idx.iter().enumerate() {
            for c in 0..d {
                want[i * d + c] += g.data[j * d + c];
            }
        }
        assert_eq!(acc.data, want, "parallel scatter must match serial bits");
        // softmax: parallel-over-rows equals per-row serial math
        let mut s = g.clone();
        s.softmax_rows();
        let mut s1 = g.clone();
        s1.softmax_rows_threads(1);
        assert_eq!(s.data, s1.data, "softmax thread count changed bits");
        crate::util::reset_par_min();
    }

    #[test]
    fn batch_view_slices_strided_matrices() {
        // interleaved [b*t, h*dh] layout: heads() must carve out the same
        // blocks as an explicit per-head copy loop
        let (b, t, h, dh) = (2usize, 3usize, 2usize, 4usize);
        let d = h * dh;
        let x = t2(b * t, d, (0..b * t * d).map(|v| v as f32).collect());
        let bv = BatchView::heads(&x, b, t, h, dh);
        assert_eq!(bv.batch(), b * h);
        assert_eq!((bv.rows, bv.cols, bv.row_stride), (t, dh, d));
        for bi in 0..b {
            for hi in 0..h {
                let got = bv.to_tensor(bi * h + hi);
                for ti in 0..t {
                    for j in 0..dh {
                        assert_eq!(got.at(ti, j), x.at(bi * t + ti, hi * dh + j));
                    }
                }
            }
        }
        // dense batches are contiguous blocks
        let y = t2(6, 2, (0..12).map(|v| v as f32).collect());
        let dv = BatchView::dense(&y.data, 3, 2, 2);
        assert_eq!(dv.to_tensor(1).data, &y.data[4..8]);
    }

    #[test]
    #[should_panic(expected = "overruns buffer")]
    fn batch_view_rejects_out_of_bounds_matrices() {
        let data = vec![0.0f32; 10];
        let _ = BatchView::strided(&data, 2, 2, 3, 3, 0, 6); // last elem at 11
    }

    #[test]
    fn kth_largest_abs_basics() {
        let xs = [1.0f32, -5.0, 3.0, -2.0, 4.0];
        assert_eq!(kth_largest_abs(&xs, 1), 5.0);
        assert_eq!(kth_largest_abs(&xs, 2), 4.0);
        assert_eq!(kth_largest_abs(&xs, 5), 1.0);
    }

}
