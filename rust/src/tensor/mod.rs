//! Host f32 tensor substrate.
//!
//! The L3 coordinator only needs host-side tensor math for the *optimizer*
//! layer (GaLore projections, LoRA adapter algebra, gradient statistics) —
//! model fwd/bwd runs inside the AOT XLA artifact. Shapes here are small
//! (at most d_model x d_ff), so a cache-blocked native matmul is plenty.

use anyhow::{bail, Result};

/// Dense row-major f32 tensor, rank 1 or 2 in practice.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elems, got {}", shape, n, data.len());
        }
        Ok(Tensor { shape: shape.to_vec(), data })
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rows(&self) -> usize {
        match self.shape.len() {
            1 => 1,
            2 => self.shape[0],
            r => panic!("rank {r} tensor has no rows"),
        }
    }

    pub fn cols(&self) -> usize {
        match self.shape.len() {
            1 => self.shape[0],
            2 => self.shape[1],
            r => panic!("rank {r} tensor has no cols"),
        }
    }

    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols() + j]
    }

    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        let c = self.cols();
        self.data[i * c + j] = v;
    }

    // ---- elementwise ------------------------------------------------------

    pub fn scale(&mut self, a: f32) {
        for x in &mut self.data {
            *x *= a;
        }
    }

    /// self += a * other
    pub fn axpy(&mut self, a: f32, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        for (x, y) in self.data.iter_mut().zip(&other.data) {
            *x += a * y;
        }
    }

    // ---- reductions ------------------------------------------------------

    pub fn sq_sum(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.sq_sum().sqrt()
    }

    /// Root-mean-square norm: ||x||_F / sqrt(n). Size-invariant layer score.
    pub fn rms_norm(&self) -> f64 {
        (self.sq_sum() / self.numel() as f64).sqrt()
    }

    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |a, &x| a.max(x.abs()))
    }

    // ---- matmul family -----------------------------------------------------

    /// C = A @ B for A [m,k], B [k,n]. Cache-friendly i-k-j loop order.
    pub fn matmul(&self, b: &Tensor) -> Tensor {
        let (m, k) = (self.rows(), self.cols());
        let (k2, n) = (b.rows(), b.cols());
        assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            let arow = &self.data[i * k..(i + 1) * k];
            let crow = &mut c[i * n..(i + 1) * n];
            for (kk, &a) in arow.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let brow = &b.data[kk * n..(kk + 1) * n];
                for (cv, &bv) in crow.iter_mut().zip(brow) {
                    *cv += a * bv;
                }
            }
        }
        Tensor { shape: vec![m, n], data: c }
    }

    /// C = Aᵀ @ B for A [k,m], B [k,n] (no explicit transpose).
    pub fn matmul_tn(&self, b: &Tensor) -> Tensor {
        let (k, m) = (self.rows(), self.cols());
        let (k2, n) = (b.rows(), b.cols());
        assert_eq!(k, k2, "matmul_tn inner dims {k} vs {k2}");
        let mut c = vec![0.0f32; m * n];
        for kk in 0..k {
            let arow = &self.data[kk * m..(kk + 1) * m];
            let brow = &b.data[kk * n..(kk + 1) * n];
            for (i, &a) in arow.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let crow = &mut c[i * n..(i + 1) * n];
                for (cv, &bv) in crow.iter_mut().zip(brow) {
                    *cv += a * bv;
                }
            }
        }
        Tensor { shape: vec![m, n], data: c }
    }

    /// C = A @ Bᵀ for A [m,k], B [n,k].
    pub fn matmul_nt(&self, b: &Tensor) -> Tensor {
        let (m, k) = (self.rows(), self.cols());
        let (n, k2) = (b.rows(), b.cols());
        assert_eq!(k, k2, "matmul_nt inner dims {k} vs {k2}");
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            let arow = &self.data[i * k..(i + 1) * k];
            for j in 0..n {
                let brow = &b.data[j * k..(j + 1) * k];
                let mut acc = 0.0f32;
                for (av, bv) in arow.iter().zip(brow) {
                    acc += av * bv;
                }
                c[i * n + j] = acc;
            }
        }
        Tensor { shape: vec![m, n], data: c }
    }

    pub fn transpose(&self) -> Tensor {
        let (m, n) = (self.rows(), self.cols());
        let mut t = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                t[j * m + i] = self.data[i * n + j];
            }
        }
        Tensor { shape: vec![n, m], data: t }
    }

    // ---- rowwise ops (native-backend substrate) ---------------------------

    /// In-place numerically-stable softmax over each row. Rows that are
    /// entirely -inf (fully masked) become all-zero rather than NaN.
    pub fn softmax_rows(&mut self) {
        let n = self.cols();
        for row in self.data.chunks_mut(n) {
            let m = row.iter().fold(f32::NEG_INFINITY, |a, &x| a.max(x));
            if m == f32::NEG_INFINITY {
                row.fill(0.0);
                continue;
            }
            let mut sum = 0.0f32;
            for x in row.iter_mut() {
                *x = (*x - m).exp();
                sum += *x;
            }
            let inv = 1.0 / sum;
            for x in row.iter_mut() {
                *x *= inv;
            }
        }
    }

    /// Gather rows by index: self [N, D] -> [idx.len(), D]. Panics on an
    /// out-of-range index (the embedding table owns range checking upstream).
    pub fn gather_rows(&self, idx: &[usize]) -> Tensor {
        let d = self.cols();
        let n = self.rows();
        let mut out = Vec::with_capacity(idx.len() * d);
        for &i in idx {
            assert!(i < n, "gather_rows: row {i} out of {n}");
            out.extend_from_slice(&self.data[i * d..(i + 1) * d]);
        }
        Tensor { shape: vec![idx.len(), d], data: out }
    }

    /// Scatter-add rows: self[idx[j]] += rows[j] (embedding gradient).
    pub fn scatter_rows_add(&mut self, idx: &[usize], rows: &Tensor) {
        let d = self.cols();
        assert_eq!(rows.cols(), d, "scatter_rows_add: col mismatch");
        assert_eq!(rows.rows(), idx.len(), "scatter_rows_add: row count mismatch");
        let n = self.rows();
        for (j, &i) in idx.iter().enumerate() {
            assert!(i < n, "scatter_rows_add: row {i} out of {n}");
            let dst = &mut self.data[i * d..(i + 1) * d];
            let src = &rows.data[j * d..(j + 1) * d];
            for (x, y) in dst.iter_mut().zip(src) {
                *x += y;
            }
        }
    }
}

/// Exact k-th largest |value| in a slice, O(n) via quickselect.
/// Returns the threshold t such that exactly >= k entries satisfy |x| >= t
/// (ties may admit more). k must satisfy 1 <= k <= len.
pub fn kth_largest_abs(xs: &[f32], k: usize) -> f32 {
    assert!(k >= 1 && k <= xs.len());
    let mut a: Vec<f32> = xs.iter().map(|x| x.abs()).collect();
    let idx = k - 1;
    // select_nth_unstable puts the idx-th *smallest* at idx; we want the
    // idx-th largest, i.e. (len - k)-th smallest.
    let pos = a.len() - k;
    let (_, v, _) = a.select_nth_unstable_by(pos, |x, y| x.partial_cmp(y).unwrap());
    let _ = idx;
    *v
}

/// The (1-zeta) upper-quantile of |xs| (zeta in [0,1]): the threshold tau
/// keeping ~zeta fraction of entries. zeta=1 keeps everything (tau=0).
pub fn abs_quantile_keep(xs: &[f32], zeta: f64) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    let zeta = zeta.clamp(0.0, 1.0);
    let keep = ((xs.len() as f64) * zeta).round() as usize;
    if keep == 0 {
        return f32::INFINITY;
    }
    if keep >= xs.len() {
        return 0.0;
    }
    kth_largest_abs(xs, keep)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t2(rows: usize, cols: usize, v: Vec<f32>) -> Tensor {
        Tensor::from_vec(&[rows, cols], v).unwrap()
    }

    #[test]
    fn matmul_small() {
        let a = t2(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = t2(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        assert_eq!(a.matmul(&b).data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_identity() {
        let a = t2(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let id = t2(3, 3, vec![1.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 1.0]);
        assert_eq!(a.matmul(&id).data, a.data);
    }

    #[test]
    fn matmul_tn_matches_explicit_transpose() {
        let a = t2(3, 2, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let b = t2(3, 4, (0..12).map(|x| x as f32).collect());
        let got = a.matmul_tn(&b);
        let want = a.transpose().matmul(&b);
        assert_eq!(got, want);
    }

    #[test]
    fn matmul_nt_matches_explicit_transpose() {
        let a = t2(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let b = t2(4, 3, (0..12).map(|x| x as f32).collect());
        let got = a.matmul_nt(&b);
        let want = a.matmul(&b.transpose());
        assert_eq!(got, want);
    }

    #[test]
    fn transpose_involutive() {
        let a = t2(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn norms() {
        let a = Tensor::from_vec(&[4], vec![3.0, 4.0, 0.0, 0.0]).unwrap();
        assert!((a.fro_norm() - 5.0).abs() < 1e-9);
        assert!((a.rms_norm() - 2.5).abs() < 1e-9);
        assert_eq!(a.abs_max(), 4.0);
    }

    #[test]
    fn axpy_scale() {
        let mut a = Tensor::from_vec(&[3], vec![1.0, 2.0, 3.0]).unwrap();
        let b = Tensor::from_vec(&[3], vec![1.0, 1.0, 1.0]).unwrap();
        a.axpy(2.0, &b);
        assert_eq!(a.data, vec![3.0, 4.0, 5.0]);
        a.scale(0.5);
        assert_eq!(a.data, vec![1.5, 2.0, 2.5]);
    }

    #[test]
    fn softmax_rows_normalizes_and_handles_mask() {
        let mut a = t2(2, 3, vec![1.0, 2.0, 3.0, f32::NEG_INFINITY, 0.0, 0.0]);
        a.softmax_rows();
        let s0: f32 = a.data[0..3].iter().sum();
        assert!((s0 - 1.0).abs() < 1e-6);
        assert!(a.data[2] > a.data[1] && a.data[1] > a.data[0]);
        assert_eq!(a.data[3], 0.0); // masked entry
        assert!((a.data[4] - 0.5).abs() < 1e-6);
        // fully-masked row -> zeros, not NaN
        let mut b = t2(1, 2, vec![f32::NEG_INFINITY, f32::NEG_INFINITY]);
        b.softmax_rows();
        assert_eq!(b.data, vec![0.0, 0.0]);
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let emb = t2(4, 2, vec![0.0, 1.0, 10.0, 11.0, 20.0, 21.0, 30.0, 31.0]);
        let g = emb.gather_rows(&[2, 0, 2]);
        assert_eq!(g.shape, vec![3, 2]);
        assert_eq!(g.data, vec![20.0, 21.0, 0.0, 1.0, 20.0, 21.0]);
        let mut acc = Tensor::zeros(&[4, 2]);
        acc.scatter_rows_add(&[2, 0, 2], &g);
        // row 2 accumulated twice
        assert_eq!(acc.data, vec![0.0, 1.0, 0.0, 0.0, 40.0, 42.0, 0.0, 0.0]);
    }

    #[test]
    fn kth_largest_abs_basics() {
        let xs = [1.0f32, -5.0, 3.0, -2.0, 4.0];
        assert_eq!(kth_largest_abs(&xs, 1), 5.0);
        assert_eq!(kth_largest_abs(&xs, 2), 4.0);
        assert_eq!(kth_largest_abs(&xs, 5), 1.0);
    }

    #[test]
    fn abs_quantile_keep_semantics() {
        let xs: Vec<f32> = (1..=100).map(|i| i as f32).collect();
        // keep top 10% -> threshold 91; count(|x| >= 91) == 10
        let tau = abs_quantile_keep(&xs, 0.10);
        let kept = xs.iter().filter(|x| x.abs() >= tau).count();
        assert_eq!(kept, 10);
        assert_eq!(abs_quantile_keep(&xs, 1.0), 0.0);
        assert_eq!(abs_quantile_keep(&xs, 0.0), f32::INFINITY);
    }

    #[test]
    fn quantile_keep_counts_randomised() {
        let mut rng = crate::util::rng::Pcg64::new(17);
        for _ in 0..20 {
            let n = 1 + rng.below(2000);
            let xs: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let zeta = rng.uniform();
            let tau = abs_quantile_keep(&xs, zeta);
            let kept = xs.iter().filter(|x| x.abs() >= tau).count();
            let want = ((n as f64) * zeta).round() as usize;
            // ties can only add; quickselect threshold keeps at least `want`
            assert!(kept >= want, "kept {kept} < want {want} (n={n})");
        }
    }
}
