//! Parameter store: the host-side source of truth for model weights.
//!
//! The Rust coordinator owns all parameters as host f32 buffers keyed by the
//! manifest's canonical order; each step they are marshaled into literals
//! for the AOT executable. Init mirrors python/compile/model.py::init_params
//! (norms=1, biases=0, embeddings/heads ~ N(0, 0.02), matrices ~
//! N(0, 1/sqrt(fan_in))) and checkpoints round-trip through a simple binary
//! format (`store.rs` would be overkill as a separate module — everything
//! parameter-shaped lives here).

use std::collections::HashMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, bail, Result};

use crate::runtime::ParamSpec;
use crate::util::rng::Pcg64;

/// Named, ordered parameter tensors.
pub struct ParamStore {
    pub specs: Vec<ParamSpec>,
    pub bufs: Vec<Vec<f32>>,
    index: HashMap<String, usize>,
}

impl ParamStore {
    pub fn zeros(specs: &[ParamSpec]) -> ParamStore {
        let bufs = specs.iter().map(|s| vec![0.0; s.numel()]).collect();
        Self::with_bufs(specs, bufs)
    }

    fn with_bufs(specs: &[ParamSpec], bufs: Vec<Vec<f32>>) -> ParamStore {
        let index = specs
            .iter()
            .enumerate()
            .map(|(i, s)| (s.name.clone(), i))
            .collect();
        ParamStore { specs: specs.to_vec(), bufs, index }
    }

    /// Random init mirroring the python reference scheme.
    pub fn init(specs: &[ParamSpec], seed: u64) -> ParamStore {
        let mut store = Self::zeros(specs);
        let mut rng = Pcg64::with_stream(seed, 0x1417);
        for (spec, buf) in store.specs.iter().zip(store.bufs.iter_mut()) {
            if spec.name.contains("norm") {
                buf.fill(1.0);
            } else if spec.name.ends_with("bias") {
                buf.fill(0.0);
            } else if spec.name == "tok_emb" || spec.name == "lm_head" || spec.name == "cls_head" {
                rng.fill_normal(buf, 0.02);
            } else {
                let fan_in = spec.shape[0] as f32;
                rng.fill_normal(buf, 1.0 / fan_in.sqrt());
            }
        }
        store
    }

    /// Deterministic filler matching aot.py::filler_params — used by the
    /// golden ABI test: w[j] = 0.02*sin(0.1*(j + 31*param_index)).
    pub fn fill_deterministic(specs: &[ParamSpec]) -> ParamStore {
        let mut store = Self::zeros(specs);
        for (pi, (spec, buf)) in store.specs.iter().zip(store.bufs.iter_mut()).enumerate() {
            if spec.name.contains("norm") {
                buf.fill(1.0);
            } else if spec.name.ends_with("bias") {
                buf.fill(0.0);
            } else {
                for (j, x) in buf.iter_mut().enumerate() {
                    *x = 0.02 * (0.1 * (j as f32 + 31.0 * pi as f32)).sin();
                }
            }
        }
        store
    }

    pub fn n_params(&self) -> usize {
        self.bufs.iter().map(Vec::len).sum()
    }

    pub fn n_tensors(&self) -> usize {
        self.specs.len()
    }

    pub fn by_name(&self, name: &str) -> Option<&[f32]> {
        self.index.get(name).map(|&i| self.bufs[i].as_slice())
    }

    pub fn idx(&self, name: &str) -> Option<usize> {
        self.index.get(name).copied()
    }

    /// Marshal every parameter into literals in canonical order.
    pub fn to_literals(&self) -> Result<Vec<xla::Literal>> {
        self.specs
            .iter()
            .zip(&self.bufs)
            .map(|(s, b)| crate::runtime::lit_f32(b, &s.shape))
            .collect()
    }

    /// L2 distance to another store (tests, Fig.3 histogram tooling).
    pub fn l2_distance(&self, other: &ParamStore) -> f64 {
        assert_eq!(self.specs.len(), other.specs.len());
        let mut acc = 0.0f64;
        for (a, b) in self.bufs.iter().zip(&other.bufs) {
            for (x, y) in a.iter().zip(b) {
                let d = (*x - *y) as f64;
                acc += d * d;
            }
        }
        acc.sqrt()
    }

    pub fn clone_store(&self) -> ParamStore {
        Self::with_bufs(&self.specs, self.bufs.clone())
    }

    // -- checkpointing -------------------------------------------------------

    const MAGIC: &'static [u8; 8] = b"BLLMCKP1";

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(Self::MAGIC)?;
        f.write_all(&(self.specs.len() as u32).to_le_bytes())?;
        for (spec, buf) in self.specs.iter().zip(&self.bufs) {
            let name = spec.name.as_bytes();
            f.write_all(&(name.len() as u32).to_le_bytes())?;
            f.write_all(name)?;
            f.write_all(&(spec.shape.len() as u32).to_le_bytes())?;
            for &d in &spec.shape {
                f.write_all(&(d as u32).to_le_bytes())?;
            }
            // raw little-endian f32
            let bytes: &[u8] = unsafe {
                std::slice::from_raw_parts(buf.as_ptr() as *const u8, buf.len() * 4)
            };
            f.write_all(bytes)?;
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<ParamStore> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != Self::MAGIC {
            bail!("bad checkpoint magic in {path:?}");
        }
        let mut u32buf = [0u8; 4];
        f.read_exact(&mut u32buf)?;
        let n = u32::from_le_bytes(u32buf) as usize;
        let mut specs = Vec::with_capacity(n);
        let mut bufs = Vec::with_capacity(n);
        for _ in 0..n {
            f.read_exact(&mut u32buf)?;
            let name_len = u32::from_le_bytes(u32buf) as usize;
            let mut name = vec![0u8; name_len];
            f.read_exact(&mut name)?;
            f.read_exact(&mut u32buf)?;
            let rank = u32::from_le_bytes(u32buf) as usize;
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                f.read_exact(&mut u32buf)?;
                shape.push(u32::from_le_bytes(u32buf) as usize);
            }
            let numel: usize = shape.iter().product();
            let mut data = vec![0f32; numel];
            let bytes: &mut [u8] = unsafe {
                std::slice::from_raw_parts_mut(data.as_mut_ptr() as *mut u8, numel * 4)
            };
            f.read_exact(bytes)?;
            specs.push(ParamSpec {
                name: String::from_utf8(name).map_err(|e| anyhow!("bad name: {e}"))?,
                shape,
            });
            bufs.push(data);
        }
        Ok(Self::with_bufs(&specs, bufs))
    }

    /// Verify shapes match another spec table (loading a checkpoint into a
    /// differently-headed model must fail loudly).
    pub fn check_compatible(&self, specs: &[ParamSpec]) -> Result<()> {
        if self.specs.len() != specs.len() {
            bail!("checkpoint has {} tensors, model wants {}", self.specs.len(), specs.len());
        }
        for (a, b) in self.specs.iter().zip(specs) {
            if a != b {
                bail!("tensor mismatch: checkpoint {a:?} vs model {b:?}");
            }
        }
        Ok(())
    }

    /// Copy overlapping tensors (by name+shape) from `other` — the
    /// pretrain->finetune trunk transfer (LM checkpoint into a CLS model).
    pub fn load_overlapping(&mut self, other: &ParamStore) -> usize {
        let mut n = 0;
        for (i, spec) in self.specs.iter().enumerate() {
            if let Some(j) = other.idx(&spec.name) {
                if other.specs[j].shape == spec.shape {
                    self.bufs[i].copy_from_slice(&other.bufs[j]);
                    n += 1;
                }
            }
        }
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_specs() -> Vec<ParamSpec> {
        vec![
            ParamSpec { name: "tok_emb".into(), shape: vec![16, 8] },
            ParamSpec { name: "layers.0.attn_norm".into(), shape: vec![8] },
            ParamSpec { name: "layers.0.wq".into(), shape: vec![8, 8] },
            ParamSpec { name: "cls_bias".into(), shape: vec![2] },
        ]
    }

    #[test]
    fn init_scheme() {
        let s = ParamStore::init(&toy_specs(), 1);
        assert!(s.by_name("layers.0.attn_norm").unwrap().iter().all(|&x| x == 1.0));
        assert!(s.by_name("cls_bias").unwrap().iter().all(|&x| x == 0.0));
        let emb = s.by_name("tok_emb").unwrap();
        let std = (emb.iter().map(|&x| (x as f64).powi(2)).sum::<f64>() / emb.len() as f64).sqrt();
        assert!((std - 0.02).abs() < 0.01, "emb std {std}");
        assert_eq!(s.n_params(), 16 * 8 + 8 + 64 + 2);
    }

    #[test]
    fn init_is_seed_deterministic() {
        let a = ParamStore::init(&toy_specs(), 7);
        let b = ParamStore::init(&toy_specs(), 7);
        let c = ParamStore::init(&toy_specs(), 8);
        assert_eq!(a.bufs, b.bufs);
        assert_ne!(a.bufs, c.bufs);
    }

    #[test]
    fn deterministic_filler_matches_formula() {
        let s = ParamStore::fill_deterministic(&toy_specs());
        let wq = s.by_name("layers.0.wq").unwrap();
        // param index of wq in toy_specs is 2
        let want = 0.02 * (0.1f32 * (5.0 + 31.0 * 2.0)).sin();
        assert!((wq[5] - want).abs() < 1e-7);
    }

    #[test]
    fn checkpoint_roundtrip() {
        let s = ParamStore::init(&toy_specs(), 3);
        let path = std::env::temp_dir().join("blockllm_test_ckpt.bin");
        s.save(&path).unwrap();
        let l = ParamStore::load(&path).unwrap();
        assert_eq!(s.bufs, l.bufs);
        l.check_compatible(&toy_specs()).unwrap();
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn incompatible_checkpoint_rejected() {
        let s = ParamStore::init(&toy_specs(), 3);
        let mut other = toy_specs();
        other[0].shape = vec![16, 9];
        assert!(s.check_compatible(&other).is_err());
    }

    #[test]
    fn overlapping_transfer() {
        let lm = ParamStore::init(&toy_specs(), 4);
        let mut cls_specs = toy_specs();
        cls_specs[3] = ParamSpec { name: "cls_head".into(), shape: vec![8, 2] };
        let mut cls = ParamStore::init(&cls_specs, 99);
        let n = cls.load_overlapping(&lm);
        assert_eq!(n, 3); // everything except the head
        assert_eq!(cls.by_name("tok_emb").unwrap(), lm.by_name("tok_emb").unwrap());
    }
}
