//! The streaming gradient layer: sinks that decide what survives backward.
//!
//! BlockLLM's memory claim is about the *optimization process*: gradients
//! for inactive coordinates never need to exist all at once. The old
//! `Backend::forward_backward(.., grads_out: &mut [Vec<f32>])` contract
//! contradicted that — every engine materialized a dense gradient for every
//! parameter, so the runtime's O(n) grad residency belied what
//! `memory::profiles::blockllm` models as `grad_live`. This module replaces
//! the dense output table with a visitor: the backward pass emits each
//! parameter's gradient shard (`param index, &[f32]`) the moment it is
//! finalized in reverse-layer order, and a [`GradSink`] decides what to
//! keep. The engine itself only ever holds ONE dense shard (its reusable
//! scratch buffer), so total gradient residency is
//! `retained-by-the-sink + largest tensor` — the paper's bound (GaLore,
//! arXiv:2403.03507, likewise pays only a transient full gradient per
//! layer; AdaRankGrad, arXiv:2410.17881, streams per-layer processed
//! gradients).
//!
//! Four sinks ship:
//! * [`DenseSink`] — legacy behavior: copy every shard into caller-owned
//!   dense buffers. The bitwise parity reference (`--grad-stream 0`) and
//!   the convenience path behind `Backend::forward_backward_dense`.
//! * [`AccumSink`] — scaled in-place accumulation over grad-accum
//!   microbatches (`g = s·x` on the first, `g += s·x` after), straight into
//!   the trainer's staging buffers. Kills the trainer's former full
//!   `scratch` copy: accumulation happens at shard-consume time.
//! * [`MaskedSink`] — retains only `BitMask`-active coordinates into
//!   compact per-layer buffers, plus per-layer squared norms (via an
//!   embedded [`NormProbeSink`]), so BlockLLM/magnitude strategies never
//!   see dense gradients. Also supports building the mask *on arrival*
//!   (exact top-k over the live shard — how selection events stay within
//!   the streaming bound) and dense retention for designated layers (probe
//!   norms under grad accumulation).
//! * [`NormProbeSink`] — norms only, nothing retained: the scorer's
//!   p-sampled dictionary refresh as a pure streaming reduction.
//!
//! Invariant the whole layer leans on: shard VALUES are identical no matter
//! which sink consumes them (the backward pass does not change), so the
//! streaming and dense retention paths are bit-for-bit interchangeable —
//! only residency differs. `tests/grad_check.rs` pins this across the
//! {1,4 threads} × {accum 1,4} grid.

use crate::obs::{self, Counter, Gauge, Span};
use crate::optim::masked_adam::BitMask;

/// Open the per-shard [`Span::SinkConsume`] span and count the call +
/// streamed elements. Purely observational — inert unless `--trace`.
fn sink_probe(grad: &[f32]) -> obs::SpanGuard {
    obs::add(Counter::SinkConsumeCalls, 1);
    obs::add(Counter::SinkConsumedElems, grad.len() as u64);
    obs::span(Span::SinkConsume)
}

/// Consumer side of the streaming gradient contract.
///
/// `consume(idx, grad)` is called exactly once per parameter tensor per
/// microbatch, in the order the backward pass finalizes them (reverse-layer
/// order on the native engine; spec-table order on PJRT, which untuples a
/// device result). `idx` indexes the backend's `param_specs` table; `grad`
/// is the full dense gradient of the *mean* microbatch loss for that tensor
/// and is only valid for the duration of the call — the backend reuses the
/// underlying buffer for the next shard.
pub trait GradSink {
    fn consume(&mut self, idx: usize, grad: &[f32]);

    /// Arm the next microbatch before its fwd/bwd (`first` resets any
    /// accumulators). Stateless sinks ignore it.
    fn begin_micro(&mut self, _first: bool) {}
}

/// Legacy dense retention: every shard copied into a caller-owned buffer.
///
/// This is the `--grad-stream 0` parity reference: with identical inputs
/// the copied bits equal what the pre-streaming API wrote in place.
pub struct DenseSink<'a> {
    bufs: &'a mut [Vec<f32>],
    retained: u64,
    peak: u64,
}

impl<'a> DenseSink<'a> {
    /// `bufs[idx]` must already be sized to the idx-th tensor's numel.
    pub fn new(bufs: &'a mut [Vec<f32>]) -> DenseSink<'a> {
        let retained: u64 = bufs.iter().map(|b| b.len() as u64).sum();
        DenseSink { bufs, retained, peak: retained }
    }

    /// Peak simultaneously-live gradient f32 elements (retained buffers +
    /// the transient shard) — the measured counterpart of the modeled
    /// `MemBreakdown::grads`.
    pub fn peak_grad_elems(&self) -> u64 {
        self.peak
    }
}

impl GradSink for DenseSink<'_> {
    fn consume(&mut self, idx: usize, grad: &[f32]) {
        let _sp = sink_probe(grad);
        self.bufs[idx].copy_from_slice(grad);
        self.peak = self.peak.max(self.retained + grad.len() as u64);
    }
}

/// Scaled in-place gradient accumulation over microbatches.
///
/// Reproduces the trainer's historical accumulation arithmetic exactly:
/// the first microbatch writes `scale·x` (a plain copy when `scale == 1`,
/// bitwise-equal to the old in-place fast path), later microbatches add
/// `scale·x`, per coordinate in ascending order.
pub struct AccumSink<'a> {
    bufs: &'a mut [Vec<f32>],
    scale: f32,
    first: bool,
    retained: u64,
    peak: u64,
}

impl<'a> AccumSink<'a> {
    /// Wrap caller-owned accumulation buffers; `scale` multiplies every
    /// incoming shard (1/accum for mean-of-microbatches semantics).
    pub fn new(bufs: &'a mut [Vec<f32>], scale: f32) -> AccumSink<'a> {
        let retained: u64 = bufs.iter().map(|b| b.len() as u64).sum();
        AccumSink { bufs, scale, first: true, retained, peak: retained }
    }

    /// Peak simultaneously-live gradient elements (buffers + transient shard).
    pub fn peak_grad_elems(&self) -> u64 {
        self.peak
    }
}

impl GradSink for AccumSink<'_> {
    fn begin_micro(&mut self, first: bool) {
        self.first = first;
    }

    fn consume(&mut self, idx: usize, grad: &[f32]) {
        let _sp = sink_probe(grad);
        let b = &mut self.bufs[idx];
        debug_assert_eq!(b.len(), grad.len(), "accum buffer {idx} size mismatch");
        if self.first && self.scale == 1.0 {
            b.copy_from_slice(grad);
        } else if self.first {
            for (d, &x) in b.iter_mut().zip(grad) {
                *d = self.scale * x;
            }
        } else {
            for (d, &x) in b.iter_mut().zip(grad) {
                *d += self.scale * x;
            }
        }
        self.peak = self.peak.max(self.retained + grad.len() as u64);
    }
}

/// Norms only: per-tensor Σg² of the most recent microbatch's shard,
/// computed in ascending coordinate order in f64 — bitwise the same sum
/// `blockllm::scorer::NormDictionary::record` folds over a dense vector,
/// so a dictionary refresh from these sums is indistinguishable from one
/// computed on materialized gradients. Nothing is retained.
///
/// Validity: each `consume` overwrites the slot, so the sums describe one
/// microbatch. With grad accumulation the *accumulated* gradient's norm has
/// cross-microbatch terms these sums cannot reconstruct — accumulating
/// consumers retain the layers they need densely instead (see
/// [`Retain::Dense`]).
pub struct NormProbeSink {
    /// Σ g² per param table slot (last consumed microbatch)
    pub sq: Vec<f64>,
    max_shard: u64,
}

impl NormProbeSink {
    /// Probe sized for `n_params` parameter-table slots, sums zeroed.
    pub fn new(n_params: usize) -> NormProbeSink {
        NormProbeSink { sq: vec![0.0; n_params], max_shard: 0 }
    }

    /// Peak simultaneously-live gradient elements (transient shard only).
    pub fn peak_grad_elems(&self) -> u64 {
        // nothing retained: only the engine's transient shard is ever live
        self.max_shard
    }
}

impl NormProbeSink {
    /// The reduction itself, uninstrumented: [`MaskedSink`] embeds it
    /// inside an already-probed consume (counting it again would double
    /// the per-shard call/element totals).
    fn record(&mut self, idx: usize, grad: &[f32]) {
        let mut s = 0.0f64;
        for &x in grad {
            s += (x as f64) * (x as f64);
        }
        self.sq[idx] = s;
        self.max_shard = self.max_shard.max(grad.len() as u64);
    }
}

impl GradSink for NormProbeSink {
    fn consume(&mut self, idx: usize, grad: &[f32]) {
        let _sp = sink_probe(grad);
        self.record(idx, grad);
    }
}

/// Per-layer retention rule for a [`MaskedSink`].
#[derive(Debug, Clone)]
pub enum Retain {
    /// Keep the coordinates this mask selects, packed in ascending
    /// coordinate order (the order `masked_adam_step` visits them).
    Mask(BitMask),
    /// Build the mask on arrival: exact top-k by |g| over the live shard,
    /// then pack. Only meaningful when the shard IS the step gradient
    /// (accum == 1) — selection replays use this to stay within the
    /// streaming memory bound.
    TopK(usize),
    /// All-set mask built on arrival (MaskMode::DenseLayers selections).
    All,
    /// Keep the full dense (accumulated) shard — probe-norm layers under
    /// grad accumulation, where a streamed Σg² cannot describe the
    /// accumulated vector.
    Dense,
}

/// [`Retain`] with the `Mask` payload moved out (the resolved mask lives in
/// `MaskedEntry::mask` for every masked rule).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Rule {
    Compact,
    TopK(usize),
    AllSet,
    Dense,
}

/// One retained layer inside a [`MaskedSink`].
#[derive(Debug)]
pub struct MaskedEntry {
    pub idx: usize,
    rule: Rule,
    /// resolved coordinate mask (None for `Retain::Dense`; resolved on
    /// first arrival for `TopK`/`All`)
    pub mask: Option<BitMask>,
    /// compact values in mask order, or the dense buffer for `Dense`
    pub values: Vec<f32>,
}

/// Compact retention: per-layer masked coordinates + streaming norms.
///
/// This is what makes the paper's gradient-memory argument real in this
/// codebase: with an active-block plan, total retention is
/// `active coords (+ any dense probe layers)`, and the engine's transient
/// shard adds at most one largest-tensor buffer on top.
pub struct MaskedSink {
    /// param idx -> entries slot (usize::MAX = shard dropped after norms)
    slot: Vec<usize>,
    pub entries: Vec<MaskedEntry>,
    /// embedded norms-only reduction over EVERY shard (retained or not)
    pub norms: NormProbeSink,
    scale: f32,
    first: bool,
    retained: u64,
    peak: u64,
}

impl MaskedSink {
    /// `retain` pairs param indices with their retention rule; every other
    /// shard is dropped after its norm is taken. `scale` = 1/grad_accum.
    pub fn new(n_params: usize, retain: Vec<(usize, Retain)>, scale: f32) -> MaskedSink {
        let mut slot = vec![usize::MAX; n_params];
        let mut entries = Vec::with_capacity(retain.len());
        for (idx, rule) in retain {
            assert!(idx < n_params, "retained idx {idx} outside param table {n_params}");
            assert_eq!(slot[idx], usize::MAX, "duplicate retention for param {idx}");
            slot[idx] = entries.len();
            let (rule, mask) = match rule {
                Retain::Mask(m) => (Rule::Compact, Some(m)),
                Retain::TopK(k) => (Rule::TopK(k), None),
                Retain::All => (Rule::AllSet, None),
                Retain::Dense => (Rule::Dense, None),
            };
            entries.push(MaskedEntry { idx, rule, mask, values: Vec::new() });
        }
        MaskedSink {
            slot,
            entries,
            norms: NormProbeSink::new(n_params),
            scale,
            first: true,
            retained: 0,
            peak: 0,
        }
    }

    /// Retained values for a param: compact (mask order) for masked rules,
    /// dense for `Retain::Dense`. None if the layer was not retained.
    pub fn values(&self, idx: usize) -> Option<&[f32]> {
        let s = *self.slot.get(idx)?;
        if s == usize::MAX {
            return None;
        }
        Some(&self.entries[s].values)
    }

    /// Streaming Σg² of the last consumed microbatch for a param (the step
    /// gradient's sum when accum == 1).
    pub fn norm_sq(&self, idx: usize) -> f64 {
        self.norms.sq[idx]
    }

    /// Peak simultaneously-live gradient f32 elements: retained values
    /// plus the engine's transient shard, maximized over all consumes.
    pub fn peak_grad_elems(&self) -> u64 {
        self.peak
    }

    /// Move the retained entries out (selection consumers take the masks
    /// and compact values by value).
    pub fn into_entries(self) -> Vec<MaskedEntry> {
        self.entries
    }
}

/// Pack `grad`'s mask-selected coordinates into `values` in ascending
/// coordinate order — the exact order `masked_adam_step` visits set bits —
/// overwriting (`first`) or accumulating, scaled. `scale == 1.0` on the
/// first microbatch preserves shard bits exactly.
fn pack_masked(mask: &BitMask, grad: &[f32], values: &mut Vec<f32>, first: bool, scale: f32) {
    debug_assert_eq!(mask.len, grad.len(), "mask/shard length mismatch");
    if first {
        values.clear();
        values.reserve(mask.popcount);
    } else {
        debug_assert_eq!(values.len(), mask.popcount);
    }
    let mut p = 0usize;
    for (wi, &word) in mask.words.iter().enumerate() {
        if word == 0 {
            continue;
        }
        let base = wi * 64;
        let mut bits = word;
        while bits != 0 {
            let b = bits.trailing_zeros() as usize;
            bits &= bits - 1;
            let i = base + b;
            if first {
                if scale == 1.0 {
                    values.push(grad[i]);
                } else {
                    values.push(scale * grad[i]);
                }
            } else {
                values[p] += scale * grad[i];
                p += 1;
            }
        }
    }
}

impl GradSink for MaskedSink {
    fn begin_micro(&mut self, first: bool) {
        self.first = first;
    }

    fn consume(&mut self, idx: usize, grad: &[f32]) {
        let _sp = sink_probe(grad);
        self.norms.record(idx, grad);
        let s = self.slot[idx];
        if s != usize::MAX {
            let e = &mut self.entries[s];
            let before = e.values.len() as u64;
            match e.rule {
                Rule::Compact => {
                    let mask = e.mask.as_ref().expect("Mask rule resolves at construction");
                    pack_masked(mask, grad, &mut e.values, self.first, self.scale);
                }
                Rule::TopK(k) => {
                    assert!(
                        self.first,
                        "TopK retention is single-microbatch only (selection \
                         replays run at accum == 1)"
                    );
                    let mask = BitMask::top_k(grad, k);
                    pack_masked(&mask, grad, &mut e.values, true, self.scale);
                    e.mask = Some(mask);
                }
                Rule::AllSet | Rule::Dense => {
                    // identical dense value retention; AllSet additionally
                    // resolves an all-set mask (a DenseLayers selection)
                    if self.first && e.rule == Rule::AllSet {
                        e.mask = Some(BitMask::all_set(grad.len()));
                    }
                    if self.first {
                        e.values.clear();
                        if self.scale == 1.0 {
                            e.values.extend_from_slice(grad);
                        } else {
                            e.values.extend(grad.iter().map(|&x| self.scale * x));
                        }
                    } else {
                        debug_assert_eq!(e.values.len(), grad.len());
                        for (d, &x) in e.values.iter_mut().zip(grad) {
                            *d += self.scale * x;
                        }
                    }
                }
            }
            self.retained += e.values.len() as u64 - before;
            obs::gauge_max(Gauge::SinkRetainedPeakBytes, 4 * self.retained);
            obs::sample("sink.retained_bytes", 4 * self.retained);
        }
        self.peak = self.peak.max(self.retained + grad.len() as u64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn shards(sizes: &[usize], seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Pcg64::new(seed);
        sizes.iter().map(|&n| (0..n).map(|_| rng.normal_f32()).collect()).collect()
    }

    #[test]
    fn dense_sink_copies_every_shard() {
        let sizes = [5usize, 130, 7];
        let g = shards(&sizes, 1);
        let mut bufs: Vec<Vec<f32>> = sizes.iter().map(|&n| vec![9.0; n]).collect();
        let mut sink = DenseSink::new(&mut bufs);
        for (i, s) in g.iter().enumerate() {
            sink.consume(i, s);
        }
        let peak = sink.peak_grad_elems();
        assert_eq!(peak, (5 + 130 + 7 + 130) as u64, "retained + largest shard");
        assert_eq!(bufs, g);
    }

    #[test]
    fn accum_sink_matches_manual_accumulation() {
        let sizes = [66usize, 3];
        let mb: Vec<Vec<Vec<f32>>> = (0..3).map(|k| shards(&sizes, 10 + k)).collect();
        let scale = 1.0f32 / 3.0;
        // manual reference: the trainer's historical loop
        let mut want: Vec<Vec<f32>> = sizes.iter().map(|&n| vec![0.0; n]).collect();
        for (k, m) in mb.iter().enumerate() {
            for (w, s) in want.iter_mut().zip(m) {
                if k == 0 {
                    w.iter_mut().zip(s).for_each(|(d, &x)| *d = scale * x);
                } else {
                    w.iter_mut().zip(s).for_each(|(d, &x)| *d += scale * x);
                }
            }
        }
        let mut bufs: Vec<Vec<f32>> = sizes.iter().map(|&n| vec![0.0; n]).collect();
        let mut sink = AccumSink::new(&mut bufs, scale);
        for (k, m) in mb.iter().enumerate() {
            sink.begin_micro(k == 0);
            for (i, s) in m.iter().enumerate() {
                sink.consume(i, s);
            }
        }
        for (a, b) in bufs.iter().zip(&want) {
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn norm_probe_matches_dense_reduction_bitwise() {
        let sizes = [200usize, 31];
        let g = shards(&sizes, 2);
        let mut sink = NormProbeSink::new(2);
        for (i, s) in g.iter().enumerate() {
            sink.consume(i, s);
        }
        for (i, s) in g.iter().enumerate() {
            let want: f64 = s.iter().map(|&x| (x as f64) * (x as f64)).sum();
            assert_eq!(sink.sq[i].to_bits(), want.to_bits(), "tensor {i}");
        }
        assert_eq!(sink.peak_grad_elems(), 200);
    }

    #[test]
    fn masked_sink_packs_in_mask_order_and_keeps_bits() {
        let n = 140usize; // crosses word boundaries
        let g = shards(&[n], 3).pop().unwrap();
        let maskv: Vec<f32> = (0..n).map(|i| if i % 3 == 0 { 1.0 } else { 0.0 }).collect();
        let mask = BitMask::from_threshold(&maskv, 0.5);
        let mut sink = MaskedSink::new(1, vec![(0, Retain::Mask(mask.clone()))], 1.0);
        sink.begin_micro(true);
        sink.consume(0, &g);
        let vals = sink.values(0).unwrap();
        assert_eq!(vals.len(), mask.popcount);
        let mut p = 0;
        for i in 0..n {
            if mask.get(i) {
                assert_eq!(vals[p].to_bits(), g[i].to_bits(), "coord {i}");
                p += 1;
            }
        }
        // the transient shard + the compact retention bound the peak
        assert_eq!(sink.peak_grad_elems(), (mask.popcount + n) as u64);
        // non-retained norms still streamed
        let want: f64 = g.iter().map(|&x| (x as f64) * (x as f64)).sum();
        assert_eq!(sink.norm_sq(0).to_bits(), want.to_bits());
    }

    #[test]
    fn masked_sink_accumulates_compact_coords() {
        let n = 70usize;
        let m1 = shards(&[n], 4).pop().unwrap();
        let m2 = shards(&[n], 5).pop().unwrap();
        let mask = BitMask::top_k(&m1, 20);
        let scale = 0.5f32;
        let mut sink = MaskedSink::new(1, vec![(0, Retain::Mask(mask.clone()))], scale);
        sink.begin_micro(true);
        sink.consume(0, &m1);
        sink.begin_micro(false);
        sink.consume(0, &m2);
        let vals = sink.values(0).unwrap();
        let mut p = 0;
        for i in 0..n {
            if mask.get(i) {
                let want = scale * m1[i] + scale * m2[i];
                assert_eq!(vals[p].to_bits(), want.to_bits(), "coord {i}");
                p += 1;
            }
        }
    }

    #[test]
    fn topk_rule_builds_the_same_mask_as_offline_topk() {
        let n = 90usize;
        let g = shards(&[n], 6).pop().unwrap();
        let mut sink = MaskedSink::new(1, vec![(0, Retain::TopK(13))], 1.0);
        sink.begin_micro(true);
        sink.consume(0, &g);
        let want = BitMask::top_k(&g, 13);
        let e = &sink.entries[0];
        assert_eq!(e.mask.as_ref().unwrap(), &want);
        assert_eq!(e.values.len(), 13);
    }

    #[test]
    fn dense_rule_retains_scaled_accumulated_shards() {
        let n = 40usize;
        let m1 = shards(&[n], 7).pop().unwrap();
        let m2 = shards(&[n], 8).pop().unwrap();
        let scale = 0.25f32;
        let mut sink = MaskedSink::new(2, vec![(1, Retain::Dense)], scale);
        sink.begin_micro(true);
        sink.consume(0, &m2); // dropped (only norms)
        sink.consume(1, &m1);
        sink.begin_micro(false);
        sink.consume(0, &m1);
        sink.consume(1, &m2);
        assert!(sink.values(0).is_none());
        let vals = sink.values(1).unwrap();
        for i in 0..n {
            let want = scale * m1[i] + scale * m2[i];
            assert_eq!(vals[i].to_bits(), want.to_bits());
        }
    }

    #[test]
    fn all_rule_is_an_all_set_mask() {
        let n = 33usize;
        let g = shards(&[n], 9).pop().unwrap();
        let mut sink = MaskedSink::new(1, vec![(0, Retain::All)], 1.0);
        sink.begin_micro(true);
        sink.consume(0, &g);
        let e = &sink.entries[0];
        assert_eq!(e.mask.as_ref().unwrap().popcount, n);
        assert_eq!(e.values, g);
    }
}
