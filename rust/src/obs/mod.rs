//! Observability: hierarchical span tracer + process-global metrics
//! registry + trace-event export (DESIGN: measurement never feeds back).
//!
//! Three layers, all off by default (`PALLAS_TRACE` / `--trace`):
//!
//! 1. **Spans** — scoped wall-clock timers ([`span`] returns a
//!    [`SpanGuard`]; drop closes the span). Each thread keeps its own open
//!    stack, so a span's *self* time is its total minus the time spent in
//!    child spans opened on the SAME thread. The kernel layer opens its
//!    spans at the dispatch site (never inside `util::pool` workers or
//!    scoped threads), so span COUNTS are thread-count-invariant even
//!    though wall-clock attribution is not. Pool workers are LONG-LIVED:
//!    they keep stable trace TIDs across dispatches, and the pool clears
//!    each worker's open-span stack after every dispatch
//!    (`reset_thread_spans`, crate-internal) so one dispatch's bookkeeping can never
//!    skew a later dispatch's self-time — scoped threads got that hygiene
//!    for free by dying.
//! 2. **Counters/gauges** — relaxed `AtomicU64` cells ([`add`],
//!    [`gauge_max`]). A designated subset is deterministic across the CI
//!    matrix legs (see [`Counter::leg_invariant`]); throughput-shaped ones
//!    (per-path call splits, `par_rows` chunk counts, pack bytes) are not
//!    and are documented as such.
//! 3. **Trace events** — a bounded in-memory buffer of Chrome
//!    trace-event records, armed separately by `--trace-out`
//!    ([`arm_events`]) and flushed by [`export::write_trace`]. Overflow
//!    drops events and counts them ([`Counter::TraceEventsDropped`]) —
//!    never blocks.
//!
//! The contract with the kernel layer: instrumentation reads clocks and
//! bumps atomics but NEVER branches the math. Tracing on/off cannot change
//! a single bit of any result (pinned by `tests/obs_trace.rs`).

pub mod export;

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

// ---------------------------------------------------------------------------
// The master switch (follows util's knob pattern: 0 = unresolved sentinel,
// resolved value stored +1 so an explicit 0 is representable).
// ---------------------------------------------------------------------------

static TRACE: AtomicUsize = AtomicUsize::new(0);

/// Whether span/counter collection is live (`PALLAS_TRACE` / `--trace`;
/// default off). When off, every probe is a single relaxed load + branch.
pub fn on() -> bool {
    let cur = TRACE.load(Ordering::Relaxed);
    if cur != 0 {
        return cur - 1 != 0;
    }
    let n = crate::util::env_knob("PALLAS_TRACE").unwrap_or(0);
    let stored = n.saturating_add(1);
    match TRACE.compare_exchange(0, stored, Ordering::Relaxed, Ordering::Relaxed) {
        Ok(_) => n != 0,
        Err(winner) => winner - 1 != 0,
    }
}

/// Override the tracing switch (CLI `--trace`, tests).
pub fn set_trace(on: bool) {
    TRACE.store(usize::from(on) + 1, Ordering::Relaxed);
}

/// Restore the tracing knob to its unresolved state: the next read
/// re-resolves `PALLAS_TRACE` (same env-re-arming contract as the util
/// knobs, so a CI leg running with tracing keeps its setting after a
/// knob-flipping test finishes).
pub fn reset_trace() {
    TRACE.store(0, Ordering::Relaxed);
}

// ---------------------------------------------------------------------------
// Span + counter site tables. The enums index fixed atomic arrays; the
// parallel *_NAMES tables are the export vocabulary.
// ---------------------------------------------------------------------------

/// Instrumented sites, one per scoped-timer location. Keep in sync with
/// [`SPAN_NAMES`] (pinned by a unit test).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(usize)]
pub enum Span {
    TrainStep,
    FwdBwd,
    FwdEmbed,
    FwdAttn,
    FwdMlp,
    FwdHeadLoss,
    BwdHead,
    BwdMlp,
    BwdAttn,
    BwdEmbed,
    Eval,
    Strategy,
    Replay,
    SinkConsume,
    AdamStep,
    GemmDirect,
    GemmPacked,
    GemmPack,
    GemmBatchedDirect,
    GemmBatchedPacked,
    GemmBatchedPack,
    ServeSchedule,
    ServePreempt,
    ServeReadmit,
    DistReduce,
}

/// Number of `Span` variants (array sizes below are pinned to this).
pub const NSPANS: usize = 25;

/// Export names, indexed by `Span as usize`. Dotted segments group related
/// phases in the profile table and Perfetto categories.
pub const SPAN_NAMES: [&str; NSPANS] = [
    "train_step",
    "fwd_bwd",
    "fwd.embed",
    "fwd.attn",
    "fwd.mlp",
    "fwd.head_loss",
    "bwd.head",
    "bwd.mlp",
    "bwd.attn",
    "bwd.embed",
    "eval",
    "strategy",
    "replay",
    "sink.consume",
    "adam.step",
    "gemm.direct",
    "gemm.packed",
    "gemm.pack",
    "gemm_batched.direct",
    "gemm_batched.packed",
    "gemm_batched.pack",
    "serve.schedule",
    "serve.preempt",
    "serve.readmit",
    "dist.reduce",
];

/// Monotonic counters. Keep in sync with [`COUNTER_NAMES`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(usize)]
pub enum Counter {
    /// GEMM calls taking the direct (unpacked) kernels. Leg-variant: the
    /// {direct, packed} CI legs split calls differently — only the SUM
    /// with [`Counter::GemmPackedCalls`] is invariant.
    GemmDirectCalls,
    /// GEMM calls taking the packed-panel microkernel path (leg-variant).
    GemmPackedCalls,
    /// Batched-GEMM calls on the direct path (leg-variant).
    GemmBatchedDirectCalls,
    /// Batched-GEMM calls on the packed path (leg-variant).
    GemmBatchedPackedCalls,
    /// Total multiply-add FLOPs (2·m·n·k per call, summed over batch).
    /// Identical on every leg: both paths compute the same contraction.
    GemmFlops,
    /// Bytes staged into packed B panels (leg-variant: zero on direct legs).
    PackBytes,
    /// Row chunks fanned out by `par_rows`/`par_rows2` (leg-variant: scales
    /// with the thread count).
    ParChunks,
    /// `GradSink::consume` invocations (one per emitted layer shard).
    SinkConsumeCalls,
    /// Gradient elements streamed through `GradSink::consume`.
    SinkConsumedElems,
    /// BlockLLM block (re)selection events.
    SelectionEvents,
    /// Streaming-route sparse replays (second pass with a retention sink).
    ReplayEvents,
    /// Streaming-route dense fallbacks (replay into a dense accumulator).
    ReplayDenseEvents,
    /// `RunLogger` records lost to I/O errors (counted even with tracing
    /// off — losing data silently is a bug, not a metric).
    LogWritesDropped,
    /// Trace events dropped because the event buffer hit its cap.
    TraceEventsDropped,
    /// Multi-chunk dispatches handed to the persistent worker pool
    /// (`util::pool`). Leg-variant: scales with the thread count (more
    /// threads = more multi-chunk calls) and is zero on `PALLAS_POOL=0`
    /// legs. Chunk counting itself ([`Counter::ParChunks`]) stays at the
    /// dispatch site, so its totals are identical whether chunks run
    /// pooled or scoped.
    PoolDispatches,
    /// Serve-loop mid-slice preemptions (a runnable tenant strictly beat
    /// the runner on the policy key). Leg-variant: scheduling interleaves
    /// with measured footprints, which differ across grad-stream legs.
    SchedPreemptions,
    /// Serve-loop budget evictions (checkpoint queued for re-admission).
    /// Leg-variant for the same reason.
    SchedEvictions,
    /// Serve-loop automatic re-admissions after headroom freed up
    /// (leg-variant).
    SchedReadmissions,
    /// Tenants that finished past their deadline — or never finished at
    /// all while holding one (leg-variant).
    SchedDeadlineMisses,
    /// Microbatches folded by the dist reducer (replicated steps only;
    /// leg-variant: zero whenever `--replicas` is 1 or the step fell back
    /// to the sequential path).
    DistMicros,
    /// Gradient bytes shipped replica → reducer and folded (leg-variant
    /// like [`Counter::DistMicros`]).
    DistReducedBytes,
}

/// Number of `Counter` variants.
pub const NCOUNTERS: usize = 21;

/// Export names, indexed by `Counter as usize`.
pub const COUNTER_NAMES: [&str; NCOUNTERS] = [
    "gemm.direct_calls",
    "gemm.packed_calls",
    "gemm_batched.direct_calls",
    "gemm_batched.packed_calls",
    "gemm.flops",
    "gemm.pack_bytes",
    "par_rows.chunks",
    "sink.consume_calls",
    "sink.consumed_elems",
    "select.events",
    "replay.events",
    "replay.dense_events",
    "log.writes_dropped",
    "trace.events_dropped",
    "pool.dispatches",
    "sched.preemptions",
    "sched.evictions",
    "sched.readmissions",
    "sched.deadline_misses",
    "dist.micros",
    "dist.reduced_bytes",
];

impl Counter {
    /// Whether this counter's total is deterministic across the CI matrix
    /// ({1,4} threads × {direct,packed} × {gs0,gs1}). The invariance test
    /// asserts equality over exactly this subset.
    pub fn leg_invariant(self) -> bool {
        matches!(
            self,
            Counter::GemmFlops
                | Counter::SinkConsumeCalls
                | Counter::SinkConsumedElems
                | Counter::SelectionEvents
        )
    }
}

/// Max-tracked gauges. Keep in sync with [`GAUGE_NAMES`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(usize)]
pub enum Gauge {
    /// High-water mark of bytes retained inside a masked streaming sink.
    SinkRetainedPeakBytes,
    /// Worst deadline overshoot (global-clock steps) across all serve
    /// tenants. Per-tenant slack lives in each outcome's schedule summary;
    /// the gauge registry is static-named, so only the fleet-wide
    /// high-water mark is tracked here.
    SchedLatenessPeakSteps,
}

/// Number of `Gauge` variants.
pub const NGAUGES: usize = 2;

/// Export names, indexed by `Gauge as usize`.
pub const GAUGE_NAMES: [&str; NGAUGES] =
    ["sink.retained_peak_bytes", "sched.lateness_peak_steps"];

// ---------------------------------------------------------------------------
// The registry: fixed arrays of relaxed atomics. Const-init keeps this in
// .bss — no lazy allocation on the hot path.
// ---------------------------------------------------------------------------

#[allow(clippy::declare_interior_mutable_const)]
const ZERO: AtomicU64 = AtomicU64::new(0);

static SPAN_COUNT: [AtomicU64; NSPANS] = [ZERO; NSPANS];
static SPAN_TOTAL_NS: [AtomicU64; NSPANS] = [ZERO; NSPANS];
static SPAN_SELF_NS: [AtomicU64; NSPANS] = [ZERO; NSPANS];
static COUNTERS: [AtomicU64; NCOUNTERS] = [ZERO; NCOUNTERS];
static GAUGES: [AtomicU64; NGAUGES] = [ZERO; NGAUGES];

/// Bump a counter by `v` (no-op with tracing off).
#[inline]
pub fn add(c: Counter, v: u64) {
    if on() {
        COUNTERS[c as usize].fetch_add(v, Ordering::Relaxed);
    }
}

/// Bump a counter unconditionally — for error accounting that must not be
/// lost just because profiling is off ([`Counter::LogWritesDropped`]).
#[inline]
pub fn add_always(c: Counter, v: u64) {
    COUNTERS[c as usize].fetch_add(v, Ordering::Relaxed);
}

/// Read a counter's current raw total (test + warn-at-exit hook).
pub fn counter(c: Counter) -> u64 {
    COUNTERS[c as usize].load(Ordering::Relaxed)
}

/// Raise a high-water-mark gauge to at least `v` (no-op with tracing off).
#[inline]
pub fn gauge_max(g: Gauge, v: u64) {
    if on() {
        GAUGES[g as usize].fetch_max(v, Ordering::Relaxed);
    }
}

// ---------------------------------------------------------------------------
// Span guard + per-thread open-span stack.
// ---------------------------------------------------------------------------

struct Frame {
    child_ns: u64,
}

thread_local! {
    static STACK: RefCell<Vec<Frame>> = const { RefCell::new(Vec::new()) };
}

/// Open a scoped span; the returned guard closes it on drop. With tracing
/// off the guard is inert (one relaxed load, no clock read).
#[inline]
pub fn span(s: Span) -> SpanGuard {
    if !on() {
        return SpanGuard { start: None, span: s as u16 };
    }
    STACK.with(|st| st.borrow_mut().push(Frame { child_ns: 0 }));
    SpanGuard { start: Some(Instant::now()), span: s as u16 }
}

/// Clear the calling thread's open-span stack. Called by `util::pool`
/// workers after each dispatch: workers are long-lived, so — unlike
/// scoped threads, whose stacks died with them — a span guard leaked
/// inside one job body (e.g. via `mem::forget`) would otherwise skew
/// parent/child self-time attribution for every later dispatch run on
/// that worker. Balanced guards leave the stack empty already; this is
/// the per-dispatch reset that makes that a guarantee instead of a hope.
pub(crate) fn reset_thread_spans() {
    STACK.with(|st| st.borrow_mut().clear());
}

/// RAII handle for one open span (see [`span`]). Not `Send`: a span must
/// close on the thread that opened it.
pub struct SpanGuard {
    start: Option<Instant>,
    span: u16,
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let Some(start) = self.start else { return };
        let dur_ns = start.elapsed().as_nanos() as u64;
        let child_ns = STACK.with(|st| {
            let mut st = st.borrow_mut();
            let child = st.pop().map_or(0, |f| f.child_ns);
            if let Some(parent) = st.last_mut() {
                parent.child_ns = parent.child_ns.saturating_add(dur_ns);
            }
            child
        });
        let i = self.span as usize;
        SPAN_COUNT[i].fetch_add(1, Ordering::Relaxed);
        SPAN_TOTAL_NS[i].fetch_add(dur_ns, Ordering::Relaxed);
        SPAN_SELF_NS[i].fetch_add(dur_ns.saturating_sub(child_ns), Ordering::Relaxed);
        if events_armed() {
            push_span_event(i, start, dur_ns);
        }
    }
}

// ---------------------------------------------------------------------------
// Snapshots: the registry is process-global and cumulative; per-run scoping
// is snapshot-at-start, delta-at-end.
// ---------------------------------------------------------------------------

/// Point-in-time copy of the whole registry.
#[derive(Debug, Clone)]
pub struct Snapshot {
    pub span_count: [u64; NSPANS],
    pub span_total_ns: [u64; NSPANS],
    pub span_self_ns: [u64; NSPANS],
    pub counters: [u64; NCOUNTERS],
    pub gauges: [u64; NGAUGES],
}

/// Copy the registry's current totals.
pub fn snapshot() -> Snapshot {
    let load = |a: &AtomicU64| a.load(Ordering::Relaxed);
    let mut s = Snapshot {
        span_count: [0; NSPANS],
        span_total_ns: [0; NSPANS],
        span_self_ns: [0; NSPANS],
        counters: [0; NCOUNTERS],
        gauges: [0; NGAUGES],
    };
    for i in 0..NSPANS {
        s.span_count[i] = load(&SPAN_COUNT[i]);
        s.span_total_ns[i] = load(&SPAN_TOTAL_NS[i]);
        s.span_self_ns[i] = load(&SPAN_SELF_NS[i]);
    }
    for i in 0..NCOUNTERS {
        s.counters[i] = load(&COUNTERS[i]);
    }
    for i in 0..NGAUGES {
        s.gauges[i] = load(&GAUGES[i]);
    }
    s
}

/// Registry activity since `since`: monotonic cells subtract; gauges are
/// high-water marks, so the delta keeps the current (larger) value.
pub fn delta(since: &Snapshot) -> Snapshot {
    let mut now = snapshot();
    for i in 0..NSPANS {
        now.span_count[i] = now.span_count[i].saturating_sub(since.span_count[i]);
        now.span_total_ns[i] = now.span_total_ns[i].saturating_sub(since.span_total_ns[i]);
        now.span_self_ns[i] = now.span_self_ns[i].saturating_sub(since.span_self_ns[i]);
    }
    for i in 0..NCOUNTERS {
        now.counters[i] = now.counters[i].saturating_sub(since.counters[i]);
    }
    now
}

// ---------------------------------------------------------------------------
// Trace-event buffer (chrome://tracing / Perfetto). Armed separately from
// the counters: span math is cheap, a million heap events is not.
// ---------------------------------------------------------------------------

/// One buffered trace record. `dur_ns == u64::MAX` marks a counter sample
/// (Perfetto `"ph":"C"`), with the value in `value`.
#[derive(Debug, Clone, Copy)]
pub(crate) struct Event {
    pub name: &'static str,
    pub tid: u64,
    pub ts_ns: u64,
    pub dur_ns: u64,
    pub value: u64,
}

/// Buffer cap: ~1M events ≈ 40 MiB. Overflow counts, never blocks.
const EVENT_CAP: usize = 1 << 20;

static EVENTS_ARMED: AtomicBool = AtomicBool::new(false);
static EVENTS: Mutex<Vec<Event>> = Mutex::new(Vec::new());
static EPOCH: OnceLock<Instant> = OnceLock::new();
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    // One trace TID per OS thread, assigned on first use. Persistent pool
    // workers therefore keep STABLE TIDs across dispatches — a Perfetto
    // timeline shows one lane per worker instead of the old
    // one-lane-per-spawn confetti from scoped threads.
    static TID: u64 = NEXT_TID.fetch_add(1, Ordering::Relaxed);
}

/// Arm (or disarm) trace-event buffering (`--trace-out`). Arming implies
/// nothing about the counter switch — callers also [`set_trace`] — but
/// pins the timestamp epoch so the first event lands near ts=0.
pub fn arm_events(on: bool) {
    if on {
        let _ = EPOCH.set(Instant::now());
    }
    EVENTS_ARMED.store(on, Ordering::Relaxed);
}

#[inline]
fn events_armed() -> bool {
    EVENTS_ARMED.load(Ordering::Relaxed)
}

fn push_event(ev: Event) {
    let mut buf = EVENTS.lock().unwrap_or_else(|e| e.into_inner());
    if buf.len() >= EVENT_CAP {
        drop(buf);
        add_always(Counter::TraceEventsDropped, 1);
        return;
    }
    buf.push(ev);
}

fn push_span_event(span_idx: usize, start: Instant, dur_ns: u64) {
    let epoch = *EPOCH.get_or_init(Instant::now);
    // saturating: an event can only race the epoch init by nanoseconds
    let ts_ns = start.duration_since(epoch).as_nanos() as u64;
    push_event(Event {
        name: SPAN_NAMES[span_idx],
        tid: TID.with(|t| *t),
        ts_ns,
        dur_ns,
        value: 0,
    });
}

/// Record a named counter sample for the trace timeline (e.g. sink
/// retention bytes over time). No-op unless events are armed.
pub fn sample(name: &'static str, value: u64) {
    if !events_armed() {
        return;
    }
    let epoch = *EPOCH.get_or_init(Instant::now);
    let ts_ns = epoch.elapsed().as_nanos() as u64;
    push_event(Event { name, tid: TID.with(|t| *t), ts_ns, dur_ns: u64::MAX, value });
}

/// Drain the buffered trace events (export + tests).
pub(crate) fn take_events() -> Vec<Event> {
    std::mem::take(&mut *EVENTS.lock().unwrap_or_else(|e| e.into_inner()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn name_tables_cover_every_variant() {
        assert_eq!(Span::DistReduce as usize, NSPANS - 1);
        assert_eq!(Counter::DistReducedBytes as usize, NCOUNTERS - 1);
        assert_eq!(Gauge::SchedLatenessPeakSteps as usize, NGAUGES - 1);
        assert_eq!(SPAN_NAMES.len(), NSPANS);
        assert_eq!(COUNTER_NAMES.len(), NCOUNTERS);
        assert_eq!(GAUGE_NAMES.len(), NGAUGES);
        let mut seen: Vec<&str> = Vec::new();
        for n in SPAN_NAMES.iter().chain(COUNTER_NAMES.iter()).chain(GAUGE_NAMES.iter()) {
            assert!(!seen.contains(n), "duplicate export name {n}");
            seen.push(n);
        }
    }

    #[test]
    fn spans_and_counters_aggregate() {
        let _g = crate::util::test_knob_lock();
        set_trace(true);
        let base = snapshot();
        {
            let _outer = span(Span::TrainStep);
            std::thread::sleep(std::time::Duration::from_millis(2));
            {
                let _inner = span(Span::FwdBwd);
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            add(Counter::GemmFlops, 123);
        }
        let d = delta(&base);
        assert_eq!(d.span_count[Span::TrainStep as usize], 1);
        assert_eq!(d.span_count[Span::FwdBwd as usize], 1);
        let outer_total = d.span_total_ns[Span::TrainStep as usize];
        let outer_self = d.span_self_ns[Span::TrainStep as usize];
        let inner_total = d.span_total_ns[Span::FwdBwd as usize];
        // child self-time sums <= parent total; parent self excludes child
        assert!(inner_total <= outer_total);
        assert!(outer_self <= outer_total - inner_total + 1);
        assert!(d.counters[Counter::GemmFlops as usize] >= 123);
        set_trace(false);
        let quiet = snapshot();
        {
            let _s = span(Span::Eval);
            add(Counter::GemmFlops, 1);
        }
        let dq = delta(&quiet);
        assert_eq!(dq.span_count[Span::Eval as usize], 0, "disabled spans must be inert");
        assert_eq!(dq.counters[Counter::GemmFlops as usize], 0);
        reset_trace();
    }

    #[test]
    fn cross_thread_counts_aggregate() {
        let _g = crate::util::test_knob_lock();
        set_trace(true);
        let base = snapshot();
        // spans opened INSIDE pool jobs (long-lived workers and/or the
        // dispatching thread) must aggregate into the same registry and
        // leave every worker's span stack balanced for the next dispatch
        crate::util::pool::run(4, &|_i| {
            let _sp = span(Span::SinkConsume);
            add(Counter::SinkConsumeCalls, 1);
        });
        let d = delta(&base);
        assert_eq!(d.span_count[Span::SinkConsume as usize], 4);
        assert_eq!(d.counters[Counter::SinkConsumeCalls as usize], 4);
        reset_trace();
    }

    #[test]
    fn event_buffer_records_and_drains() {
        let _g = crate::util::test_knob_lock();
        set_trace(true);
        arm_events(true);
        let _ = take_events(); // drop anything a prior test buffered
        {
            let _sp = span(Span::Strategy);
        }
        sample("sink.retained_bytes", 4096);
        arm_events(false);
        let evs = take_events();
        assert!(evs.iter().any(|e| e.name == "strategy" && e.dur_ns != u64::MAX));
        assert!(evs
            .iter()
            .any(|e| e.name == "sink.retained_bytes" && e.dur_ns == u64::MAX && e.value == 4096));
        {
            let _sp = span(Span::Strategy);
        }
        assert!(take_events().is_empty(), "disarmed buffer must stay empty");
        reset_trace();
    }
}
