//! Export layers for the obs registry: stderr profile table, a JSON
//! `profile` block (RunLogger JSONL / bench rows), and a chrome://tracing
//! (Perfetto) trace-event file.

use std::io::{BufWriter, Write};
use std::path::Path;

use crate::util::json::Json;

use super::{
    take_events, Snapshot, COUNTER_NAMES, GAUGE_NAMES, NCOUNTERS, NGAUGES, NSPANS, SPAN_NAMES,
};

/// Build the per-run `profile` block from a registry delta. Zero rows are
/// omitted so JSONL records stay compact; times are exported in
/// milliseconds (JSON doubles carry ns-resolution exactly up to ~104 days).
pub fn profile_json(d: &Snapshot) -> Json {
    let mut spans: Vec<(&str, Json)> = Vec::new();
    for i in 0..NSPANS {
        if d.span_count[i] == 0 {
            continue;
        }
        spans.push((
            SPAN_NAMES[i],
            Json::obj(vec![
                ("count", Json::num(d.span_count[i] as f64)),
                ("total_ms", Json::num(d.span_total_ns[i] as f64 / 1e6)),
                ("self_ms", Json::num(d.span_self_ns[i] as f64 / 1e6)),
            ]),
        ));
    }
    let mut counters: Vec<(&str, Json)> = Vec::new();
    for i in 0..NCOUNTERS {
        if d.counters[i] != 0 {
            counters.push((COUNTER_NAMES[i], Json::num(d.counters[i] as f64)));
        }
    }
    let mut gauges: Vec<(&str, Json)> = Vec::new();
    for i in 0..NGAUGES {
        if d.gauges[i] != 0 {
            gauges.push((GAUGE_NAMES[i], Json::num(d.gauges[i] as f64)));
        }
    }
    Json::obj(vec![
        ("spans", Json::obj(spans)),
        ("counters", Json::obj(counters)),
        ("gauges", Json::obj(gauges)),
    ])
}

/// Fraction of `wall_secs` accounted for by top-level span self-time: the
/// sum over spans of self ns (each span's total minus same-thread children)
/// for the coordinator-thread phase spans. Used by the ≥90%-coverage
/// acceptance check and printed under the table.
pub fn coverage(d: &Snapshot, wall_secs: f64) -> f64 {
    if wall_secs <= 0.0 {
        return 0.0;
    }
    // Roots of the span forest on the coordinator thread: train_step and
    // eval cover a run's wall-clock between them (everything else nests).
    let accounted_ns = d.span_total_ns[super::Span::TrainStep as usize]
        + d.span_total_ns[super::Span::Eval as usize];
    (accounted_ns as f64 / 1e9) / wall_secs
}

/// Print the end-of-run profile table on stderr, spans sorted by self time.
pub fn print_table(d: &Snapshot, wall_secs: f64) {
    let mut order: Vec<usize> = (0..NSPANS).filter(|&i| d.span_count[i] != 0).collect();
    order.sort_by_key(|&i| std::cmp::Reverse(d.span_self_ns[i]));
    if order.is_empty() {
        eprintln!("[obs] no spans recorded (is --trace on?)");
        return;
    }
    let wall_ns = (wall_secs * 1e9).max(1.0);
    eprintln!("\n[obs] profile ({:.3}s wall)", wall_secs);
    eprintln!("{:<22} {:>10} {:>12} {:>12} {:>7}", "span", "count", "total_ms", "self_ms", "self%");
    for i in order {
        eprintln!(
            "{:<22} {:>10} {:>12.3} {:>12.3} {:>6.1}%",
            SPAN_NAMES[i],
            d.span_count[i],
            d.span_total_ns[i] as f64 / 1e6,
            d.span_self_ns[i] as f64 / 1e6,
            d.span_self_ns[i] as f64 / wall_ns * 100.0,
        );
    }
    for i in 0..NCOUNTERS {
        if d.counters[i] != 0 {
            eprintln!("{:<22} {:>10}", COUNTER_NAMES[i], d.counters[i]);
        }
    }
    for i in 0..NGAUGES {
        if d.gauges[i] != 0 {
            eprintln!("{:<22} {:>10}", GAUGE_NAMES[i], d.gauges[i]);
        }
    }
    eprintln!("{:<22} {:>9.1}%", "span coverage", coverage(d, wall_secs) * 100.0);
}

/// Drain the buffered trace events into a chrome://tracing JSON file
/// (load via Perfetto's "Open trace file" or chrome://tracing). Duration
/// events use `"ph":"X"`, counter samples `"ph":"C"`; timestamps are
/// microseconds since the epoch pinned by [`super::arm_events`].
///
/// Written with a streaming writer, not the [`Json`] tree: the buffer can
/// hold ~1M events and building a tree would double peak memory.
pub fn write_trace(path: &Path) -> std::io::Result<usize> {
    let events = take_events();
    let file = std::fs::File::create(path)?;
    let mut w = BufWriter::new(file);
    write!(w, "{{\"displayTimeUnit\":\"ms\",\"traceEvents\":[")?;
    for (i, e) in events.iter().enumerate() {
        if i > 0 {
            write!(w, ",")?;
        }
        let ts_us = e.ts_ns as f64 / 1e3;
        if e.dur_ns == u64::MAX {
            // counter sample
            write!(
                w,
                "\n{{\"name\":\"{}\",\"cat\":\"blockllm\",\"ph\":\"C\",\"pid\":1,\"tid\":{},\
                 \"ts\":{ts_us:.3},\"args\":{{\"value\":{}}}}}",
                e.name, e.tid, e.value
            )?;
        } else {
            write!(
                w,
                "\n{{\"name\":\"{}\",\"cat\":\"blockllm\",\"ph\":\"X\",\"pid\":1,\"tid\":{},\
                 \"ts\":{ts_us:.3},\"dur\":{:.3}}}",
                e.name,
                e.tid,
                e.dur_ns as f64 / 1e3
            )?;
        }
    }
    writeln!(w, "\n]}}")?;
    w.flush()?;
    Ok(events.len())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{self, Counter, Span};

    #[test]
    fn profile_json_shape_and_omission() {
        // synthesize a delta without touching the live registry
        let mut d = obs::Snapshot {
            span_count: [0; obs::NSPANS],
            span_total_ns: [0; obs::NSPANS],
            span_self_ns: [0; obs::NSPANS],
            counters: [0; obs::NCOUNTERS],
            gauges: [0; obs::NGAUGES],
        };
        d.span_count[Span::FwdAttn as usize] = 4;
        d.span_total_ns[Span::FwdAttn as usize] = 2_500_000;
        d.span_self_ns[Span::FwdAttn as usize] = 1_500_000;
        d.counters[Counter::GemmFlops as usize] = 1 << 40;
        let j = profile_json(&d);
        let spans = j.req("spans").unwrap().as_obj().unwrap();
        assert_eq!(spans.len(), 1, "zero rows must be omitted");
        let attn = j.req("spans").unwrap().req("fwd.attn").unwrap();
        assert_eq!(attn.req("count").unwrap().as_usize().unwrap(), 4);
        assert!((attn.req("total_ms").unwrap().as_f64().unwrap() - 2.5).abs() < 1e-9);
        let flops = j.req("counters").unwrap().req("gemm.flops").unwrap();
        assert_eq!(flops.as_f64().unwrap(), (1u64 << 40) as f64);
        // the block must survive a JSONL round-trip bit-exactly
        let reparsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(reparsed, j);
    }

    #[test]
    fn trace_file_is_valid_json() {
        let _g = crate::util::test_knob_lock();
        obs::set_trace(true);
        obs::arm_events(true);
        let _ = obs::take_events();
        {
            let _sp = obs::span(Span::GemmPacked);
        }
        obs::sample("sink.retained_bytes", 12345);
        obs::arm_events(false);
        let dir = std::env::temp_dir().join("blockllm_obs_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.json");
        write_trace(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let v = Json::parse(&text).unwrap();
        let evs = v.req("traceEvents").unwrap().as_arr().unwrap();
        assert!(evs.len() >= 2);
        let span_ev = evs
            .iter()
            .find(|e| e.req("name").unwrap().as_str().unwrap() == "gemm.packed")
            .expect("span event present");
        assert_eq!(span_ev.req("ph").unwrap().as_str().unwrap(), "X");
        assert!(span_ev.req("dur").unwrap().as_f64().unwrap() >= 0.0);
        let ctr_ev = evs
            .iter()
            .find(|e| e.req("name").unwrap().as_str().unwrap() == "sink.retained_bytes")
            .expect("counter event present");
        assert_eq!(ctr_ev.req("ph").unwrap().as_str().unwrap(), "C");
        assert_eq!(
            ctr_ev.req("args").unwrap().req("value").unwrap().as_usize().unwrap(),
            12345
        );
        obs::reset_trace();
    }
}
