//! LoRA baseline (Hu et al., 2021): rank-r adapters on every 2-D matrix.
//!
//! W_eff = W₀ + (α/r)·B A with A [r, n] ~ N(0, 1/r), B [m, r] = 0. The
//! frozen base W₀ never moves; Adam runs over (A, B) only. Because the AOT
//! artifact consumes full weight matrices, the strategy materializes W_eff
//! into the store each step — the accounting charges LoRA for base+adapters
//! exactly as the paper does (adapters add parameters, §1 "PEFT methods").
//!
//! Gradients w.r.t. adapters follow from the chain rule on the full-matrix
//! gradient G the artifact returns: ∂L/∂B = (α/r)·G Aᵀ, ∂L/∂A = (α/r)·Bᵀ G.
//! 1-D parameters (norms, biases) are frozen, as in standard LoRA practice.

use anyhow::{bail, Result};

use super::{StepInfo, Strategy};
use crate::memory::profiles;
use crate::model::ParamStore;
use crate::optim::AdamHypers;
use crate::session::state::StateBag;
use crate::tensor::Tensor;
use crate::util::rng::Pcg64;

struct Adapter {
    a: Tensor, // [r, n]
    b: Tensor, // [m, r]
    m_a: Vec<f32>,
    v_a: Vec<f32>,
    m_b: Vec<f32>,
    v_b: Vec<f32>,
    w0: Vec<f32>, // frozen base
}

pub struct LoRa {
    adapters: Vec<Option<Adapter>>,
    rank: usize,
    alpha: f64,
    hypers: AdamHypers,
    step: u64,
    n_params: u64,
    initialized: bool,
    seed: u64,
}

impl LoRa {
    pub fn new(
        sizes: &[usize],
        _names: &[String],
        rank: usize,
        alpha: f64,
        hypers: AdamHypers,
        seed: u64,
    ) -> LoRa {
        LoRa {
            adapters: (0..sizes.len()).map(|_| None).collect(),
            rank: rank.max(1),
            alpha,
            hypers,
            step: 0,
            n_params: sizes.iter().map(|&s| s as u64).sum(),
            initialized: false,
            seed,
        }
    }

    fn init_adapters(&mut self, store: &ParamStore) {
        let mut rng = Pcg64::with_stream(self.seed, 0x10FA);
        for (li, spec) in store.specs.iter().enumerate() {
            if spec.shape.len() != 2 {
                continue;
            }
            let (m, n) = (spec.shape[0], spec.shape[1]);
            let r = self.rank.min(m).min(n);
            let mut a = Tensor::zeros(&[r, n]);
            rng.fill_normal(&mut a.data, 1.0 / (r as f32).sqrt());
            let b = Tensor::zeros(&[m, r]);
            self.adapters[li] = Some(Adapter {
                m_a: vec![0.0; a.numel()],
                v_a: vec![0.0; a.numel()],
                m_b: vec![0.0; b.numel()],
                v_b: vec![0.0; b.numel()],
                w0: store.bufs[li].clone(),
                a,
                b,
            });
        }
        self.initialized = true;
    }

    pub fn adapter_elems(&self) -> u64 {
        self.adapters
            .iter()
            .flatten()
            .map(|ad| (ad.a.numel() + ad.b.numel()) as u64)
            .sum()
    }
}

fn adam_inplace(
    w: &mut [f32],
    g: &[f32],
    m: &mut [f32],
    v: &mut [f32],
    step: u64,
    lr: f32,
    h: &AdamHypers,
) {
    let b1 = h.beta1 as f32;
    let b2 = h.beta2 as f32;
    let eps = h.eps as f32;
    let (bc1, bc2) = crate::optim::masked_adam::bias_corrections(h, step);
    for i in 0..w.len() {
        m[i] = b1 * m[i] + (1.0 - b1) * g[i];
        v[i] = b2 * v[i] + (1.0 - b2) * g[i] * g[i];
        w[i] -= lr * (m[i] / bc1) / ((v[i] / bc2).sqrt() + eps);
    }
}

impl Strategy for LoRa {
    fn step(
        &mut self,
        store: &mut ParamStore,
        grads: &[Vec<f32>],
        _loss: f64,
        lr: f64,
        _step: usize,
    ) -> StepInfo {
        if !self.initialized {
            self.init_adapters(store);
        }
        self.step += 1;
        let mut updated = 0u64;
        let scale = (self.alpha / self.rank as f64) as f32;

        for (li, spec) in store.specs.iter().enumerate() {
            let Some(ad) = self.adapters[li].as_mut() else { continue };
            let (rows, cols) = (spec.shape[0], spec.shape[1]);
            let g = Tensor::from_vec(&[rows, cols], grads[li].clone()).expect("grad shape");

            // chain rule through W_eff = W0 + scale * B A
            let gb = g.matmul_nt(&ad.a); // [m, r] = G Aᵀ
            let ga = ad.b.matmul_tn(&g); // [r, n] = Bᵀ G
            let lr_f = lr as f32;
            let gb_s: Vec<f32> = gb.data.iter().map(|x| x * scale).collect();
            let ga_s: Vec<f32> = ga.data.iter().map(|x| x * scale).collect();
            let t = self.step;
            adam_inplace(&mut ad.b.data, &gb_s, &mut ad.m_b, &mut ad.v_b, t, lr_f, &self.hypers);
            adam_inplace(&mut ad.a.data, &ga_s, &mut ad.m_a, &mut ad.v_a, t, lr_f, &self.hypers);
            updated += (ad.a.numel() + ad.b.numel()) as u64;

            // materialize W_eff for the next artifact execution
            let ba = ad.b.matmul(&ad.a); // [m, n]
            let w = &mut store.bufs[li];
            for i in 0..w.len() {
                w[i] = ad.w0[i] + scale * ba.data[i];
            }
        }

        StepInfo {
            updated_coords: updated,
            reselected: false,
            mem: profiles::lora(self.n_params, self.adapter_elems()),
            active_layers: Vec::new(),
        }
    }

    fn name(&self) -> &'static str {
        "lora"
    }

    /// Only adapter gradients need to persist on-device (the full G is
    /// consumed layer-by-layer during backward in a GPU implementation).
    fn modeled_grad_elems(&self, _n: u64) -> u64 {
        self.adapter_elems()
    }

    fn modeled_state_elems(&self, _n: u64) -> u64 {
        2 * self.adapter_elems()
    }

    fn state_save(&self, bag: &mut StateBag) {
        bag.put_u64("lora.step", self.step);
        bag.put_bool("lora.initialized", self.initialized);
        bag.put_usize("lora.n_layers", self.adapters.len());
        for (i, ad) in self.adapters.iter().enumerate() {
            let Some(ad) = ad else { continue };
            bag.put_u64s(
                &format!("lora.a_shape/{i}"),
                ad.a.shape.iter().map(|&d| d as u64).collect(),
            );
            bag.put_u64s(
                &format!("lora.b_shape/{i}"),
                ad.b.shape.iter().map(|&d| d as u64).collect(),
            );
            bag.put_f32s(&format!("lora.a/{i}"), ad.a.data.clone());
            bag.put_f32s(&format!("lora.b/{i}"), ad.b.data.clone());
            bag.put_f32s(&format!("lora.m_a/{i}"), ad.m_a.clone());
            bag.put_f32s(&format!("lora.v_a/{i}"), ad.v_a.clone());
            bag.put_f32s(&format!("lora.m_b/{i}"), ad.m_b.clone());
            bag.put_f32s(&format!("lora.v_b/{i}"), ad.v_b.clone());
            bag.put_f32s(&format!("lora.w0/{i}"), ad.w0.clone());
        }
    }

    fn state_load(&mut self, bag: &StateBag) -> Result<()> {
        let n_layers = bag.get_usize("lora.n_layers")?;
        if n_layers != self.adapters.len() {
            bail!("lora checkpoint has {n_layers} layers, model has {}", self.adapters.len());
        }
        let mut adapters: Vec<Option<Adapter>> = Vec::with_capacity(n_layers);
        for i in 0..n_layers {
            if !bag.has_blob(&format!("lora.a/{i}")) {
                adapters.push(None);
                continue;
            }
            let a_shape: Vec<usize> =
                bag.u64s(&format!("lora.a_shape/{i}"))?.iter().map(|&d| d as usize).collect();
            let b_shape: Vec<usize> =
                bag.u64s(&format!("lora.b_shape/{i}"))?.iter().map(|&d| d as usize).collect();
            adapters.push(Some(Adapter {
                a: Tensor::from_vec(&a_shape, bag.f32s(&format!("lora.a/{i}"))?.to_vec())?,
                b: Tensor::from_vec(&b_shape, bag.f32s(&format!("lora.b/{i}"))?.to_vec())?,
                m_a: bag.f32s(&format!("lora.m_a/{i}"))?.to_vec(),
                v_a: bag.f32s(&format!("lora.v_a/{i}"))?.to_vec(),
                m_b: bag.f32s(&format!("lora.m_b/{i}"))?.to_vec(),
                v_b: bag.f32s(&format!("lora.v_b/{i}"))?.to_vec(),
                w0: bag.f32s(&format!("lora.w0/{i}"))?.to_vec(),
            }));
        }
        self.step = bag.get_u64("lora.step")?;
        self.initialized = bag.get_bool("lora.initialized")?;
        self.adapters = adapters;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil;
    use super::*;

    #[test]
    fn first_step_keeps_weights_at_base() {
        // B starts at 0 so W_eff == W0 before any update; after one step
        // with nonzero grads, B moves and W_eff != W0.
        let specs = testutil::toy_specs();
        let sizes: Vec<usize> = specs.iter().map(|s| s.numel()).collect();
        let names: Vec<String> = specs.iter().map(|s| s.name.clone()).collect();
        let mut s = LoRa::new(&sizes, &names, 2, 8.0, AdamHypers::default(), 1);
        let mut store = ParamStore::init(&specs, 2);
        let w0 = store.bufs[0].clone();
        let grads = testutil::rand_grads(&sizes, 3);
        s.step(&mut store, &grads, 1.0, 1e-2, 0);
        assert_ne!(store.bufs[0], w0, "adapters had no effect");
    }

    #[test]
    fn norm_params_frozen() {
        let specs = testutil::toy_specs();
        let sizes: Vec<usize> = specs.iter().map(|s| s.numel()).collect();
        let names: Vec<String> = specs.iter().map(|s| s.name.clone()).collect();
        let mut s = LoRa::new(&sizes, &names, 2, 8.0, AdamHypers::default(), 1);
        let mut store = ParamStore::init(&specs, 2);
        let norm_idx = store.idx("layers.0.attn_norm").unwrap();
        let before = store.bufs[norm_idx].clone();
        let grads = testutil::rand_grads(&sizes, 3);
        for t in 0..5 {
            s.step(&mut store, &grads, 1.0, 1e-2, t);
        }
        assert_eq!(store.bufs[norm_idx], before, "frozen 1-D param moved");
    }

    #[test]
    fn update_is_low_rank() {
        let specs = vec![crate::runtime::ParamSpec { name: "w".into(), shape: vec![8, 8] }];
        let sizes = vec![64usize];
        let names = vec!["w".to_string()];
        let mut s = LoRa::new(&sizes, &names, 2, 2.0, AdamHypers::default(), 1);
        let mut store = ParamStore::zeros(&specs);
        let grads = testutil::rand_grads(&sizes, 4);
        for t in 0..10 {
            s.step(&mut store, &grads, 1.0, 1e-2, t);
        }
        // ΔW = W - 0 lives in the span of B (rank <= 2): check via Gram rank
        let w = Tensor::from_vec(&[8, 8], store.bufs[0].clone()).unwrap();
        let gram = w.matmul_nt(&w);
        // eigenvalues beyond the 2nd must be ~0; proxy: trace of gram minus
        // top-2 power-iteration estimates stays tiny
        let mut rng = Pcg64::new(5);
        let s1 = crate::linalg::spectral_norm_est(&w, 40, &mut rng);
        let tr: f32 = (0..8).map(|i| gram.at(i, i)).sum();
        assert!(tr as f64 <= 2.0 * s1 * s1 + 1e-4, "rank escape: tr={tr} s1²={}", s1 * s1);
    }

    #[test]
    fn memory_charges_adapters_not_base_state() {
        let specs = testutil::toy_specs();
        let sizes: Vec<usize> = specs.iter().map(|s| s.numel()).collect();
        let names: Vec<String> = specs.iter().map(|s| s.name.clone()).collect();
        let mut s = LoRa::new(&sizes, &names, 2, 8.0, AdamHypers::default(), 1);
        let mut store = ParamStore::init(&specs, 2);
        let grads = testutil::rand_grads(&sizes, 3);
        let info = s.step(&mut store, &grads, 1.0, 1e-2, 0);
        let n: u64 = sizes.iter().map(|&x| x as u64).sum();
        assert!(info.mem.optim_m < n * 4, "optimizer state must cover adapters only");
        assert!(info.mem.weights > n * 4, "weights must include adapters");
    }

    #[test]
    fn descends_quadratic_within_subspace() {
        let specs = testutil::toy_specs();
        let sizes: Vec<usize> = specs.iter().map(|s| s.numel()).collect();
        let names: Vec<String> = specs.iter().map(|s| s.name.clone()).collect();
        let mut s = LoRa::new(&sizes, &names, 4, 8.0, AdamHypers::default(), 1);
        let (before, after) = testutil::quadratic_descends(&mut s, 300);
        // LoRA can't reach zero (rank limit + frozen vectors) but must drop
        assert!(after < before, "before={before} after={after}");
    }
}
