//! Magnitude-pruning analysis optimizer (paper §2 / §2.1, Tables 2-5).
//!
//! Updates only the top-k coordinates by |W| (k = (1-s)·n), either fixed
//! from W⁰ (Table 2 protocol) or re-selected from |Wᵗ| every `update_every`
//! steps (§2.1, Tables 3-5). Tracks q — the fraction of UNIQUE coordinates
//! ever updated — which is the quantity the paper analyses.

use anyhow::{bail, Result};

use super::{SparseOutcome, SparsePlan, StepInfo, Strategy};
use crate::grads::{MaskedSink, Retain};
use crate::memory::MemBreakdown;
use crate::model::ParamStore;
use crate::optim::masked_adam::{masked_adam_step, masked_adam_step_compact, BitMask, LayerState};
use crate::optim::AdamHypers;
use crate::session::state::StateBag;
use crate::tensor::kth_largest_abs;

pub struct Magnitude {
    sizes: Vec<usize>,
    /// layers always kept fully active (task heads: standard practice is to
    /// train the new head densely; magnitude ranking applies to the trunk)
    always_active: Vec<usize>,
    sparsity: f64,
    update_every: usize, // 0 = select once at t=0
    hypers: AdamHypers,
    states: Vec<LayerState>,
    /// union of every mask ever active (for q)
    ever_updated: Vec<BitMask>,
    adam_step: u64,
    n_params: u64,
    selected_once: bool,
    /// whether the live streaming plan's `sparse_plan` ran a re-selection
    /// (carried into `step_sparse`'s StepInfo)
    pending_reselect: bool,
}

impl Magnitude {
    pub fn new(
        sizes: &[usize],
        sparsity: f64,
        update_every: usize,
        hypers: AdamHypers,
    ) -> Magnitude {
        Magnitude {
            sizes: sizes.to_vec(),
            always_active: Vec::new(),
            sparsity,
            update_every,
            hypers,
            states: Vec::new(),
            ever_updated: sizes
                .iter()
                .map(|&n| BitMask::from_threshold(&vec![0.0; n], 1.0))
                .collect(),
            adam_step: 0,
            n_params: sizes.iter().map(|&s| s as u64).sum(),
            selected_once: false,
            pending_reselect: false,
        }
    }

    /// Mark head layers (by index) as always fully trainable.
    pub fn with_always_active(mut self, idx: Vec<usize>) -> Magnitude {
        self.always_active = idx;
        self
    }

    /// Global top-k by |W|: one threshold across ALL coordinates (the §2
    /// protocol prunes globally, not per layer).
    fn select(&mut self, store: &ParamStore) {
        let k = (((1.0 - self.sparsity) * self.n_params as f64).round() as usize).max(1);
        let mut all: Vec<f32> = Vec::with_capacity(self.n_params as usize);
        for b in &store.bufs {
            all.extend_from_slice(b);
        }
        let tau = kth_largest_abs(&all, k);
        self.states = store
            .bufs
            .iter()
            .enumerate()
            .map(|(li, b)| {
                let mask = if self.always_active.contains(&li) {
                    BitMask::all_set(b.len())
                } else {
                    BitMask::from_threshold(b, tau)
                };
                LayerState { m: vec![0.0; b.len()], v: vec![0.0; b.len()], mask }
            })
            .collect();
        // accumulate into ever_updated
        for (ever, st) in self.ever_updated.iter_mut().zip(&self.states) {
            let mut pop = 0;
            for (w, s) in ever.words.iter_mut().zip(&st.mask.words) {
                *w |= s;
                pop += w.count_ones() as usize;
            }
            ever.popcount = pop;
        }
        self.adam_step = 0;
        self.selected_once = true;
    }

    /// q: fraction of unique coordinates updated so far (paper §2.1).
    pub fn unique_updated_frac(&self) -> f64 {
        let q: usize = self.ever_updated.iter().map(|m| m.popcount).sum();
        q as f64 / self.n_params as f64
    }

    pub fn active_coords(&self) -> u64 {
        self.states.iter().map(|s| s.mask.popcount as u64).sum()
    }

    /// §2.1 re-selection cadence: once at t=0, then every `update_every`
    /// steps (0 = fixed selection). Depends only on the step counter — and
    /// `select` reads weights, not gradients — which is why the streaming
    /// route can re-select BEFORE the fwd/bwd and retain exactly the new
    /// masks' coordinates.
    fn reselect_due(&self, step: usize) -> bool {
        !self.selected_once
            || (self.update_every > 0 && step > 0 && step % self.update_every == 0)
    }

    fn mem_breakdown(&self) -> MemBreakdown {
        let active = self.active_coords();
        MemBreakdown {
            weights: self.n_params * 4,
            grads: active * 4,
            optim_m: active * 4,
            optim_v: active * 4,
            extra: self.ever_updated.iter().map(|m| m.bytes()).sum(),
            activations: 0,
        }
    }
}

impl Strategy for Magnitude {
    fn step(
        &mut self,
        store: &mut ParamStore,
        grads: &[Vec<f32>],
        _loss: f64,
        lr: f64,
        step: usize,
    ) -> StepInfo {
        let reselect = self.reselect_due(step);
        if reselect {
            self.select(store);
        }
        self.adam_step += 1;
        let mut updated = 0u64;
        for (li, st) in self.states.iter_mut().enumerate() {
            updated += masked_adam_step(
                &mut store.bufs[li],
                &grads[li],
                st,
                self.adam_step,
                lr,
                &self.hypers,
            ) as u64;
        }
        StepInfo {
            updated_coords: updated,
            reselected: reselect,
            mem: self.mem_breakdown(),
            active_layers: Vec::new(),
        }
    }

    /// Magnitude's masks come from |W|, never from gradients, so the whole
    /// step fits the compact streaming route at any grad_accum: re-select
    /// from the (pre-step) weights here, then retain exactly the masked
    /// coordinates. Identical masks and update bits to the dense path,
    /// which re-selects from the same pre-update weights inside `step`.
    fn sparse_plan(
        &mut self,
        store: &ParamStore,
        _grad_accum: usize,
        step: usize,
    ) -> Option<SparsePlan> {
        let reselect = self.reselect_due(step);
        if reselect {
            self.select(store);
        }
        self.pending_reselect = reselect;
        Some(SparsePlan {
            retain: self
                .states
                .iter()
                .enumerate()
                .map(|(li, st)| (li, Retain::Mask(st.mask.clone())))
                .collect(),
        })
    }

    fn step_sparse(
        &mut self,
        store: &mut ParamStore,
        sink: &MaskedSink,
        _loss: f64,
        lr: f64,
        _step: usize,
    ) -> SparseOutcome {
        self.adam_step += 1;
        let mut updated = 0u64;
        for (li, st) in self.states.iter_mut().enumerate() {
            let gc = sink.values(li).expect("every layer is masked-retained");
            updated += masked_adam_step_compact(
                &mut store.bufs[li],
                gc,
                st,
                self.adam_step,
                lr,
                &self.hypers,
            ) as u64;
        }
        SparseOutcome::Done(StepInfo {
            updated_coords: updated,
            reselected: self.pending_reselect,
            mem: self.mem_breakdown(),
            active_layers: Vec::new(),
        })
    }

    fn name(&self) -> &'static str {
        "magnitude"
    }

    fn modeled_grad_elems(&self, _n: u64) -> u64 {
        self.active_coords()
    }

    /// M+V only over the retained coordinates: the global top-k plus any
    /// always-active head layers (upper bound — the sets may overlap).
    fn modeled_state_elems(&self, n: u64) -> u64 {
        let k = (((1.0 - self.sparsity) * n as f64).round() as u64).max(1);
        let heads: u64 = self
            .always_active
            .iter()
            .map(|&li| self.sizes.get(li).copied().unwrap_or(0) as u64)
            .sum();
        2 * (k + heads).min(n)
    }

    fn state_save(&self, bag: &mut StateBag) {
        bag.put_u64("mag.adam_step", self.adam_step);
        bag.put_bool("mag.selected_once", self.selected_once);
        bag.put_usize("mag.n_layers", self.sizes.len());
        bag.put_bool("mag.has_states", !self.states.is_empty());
        for (i, st) in self.states.iter().enumerate() {
            bag.put_f32s(&format!("mag.m/{i}"), st.m.clone());
            bag.put_f32s(&format!("mag.v/{i}"), st.v.clone());
            bag.put_u64s(&format!("mag.mask/{i}"), st.mask.words.clone());
        }
        for (i, ever) in self.ever_updated.iter().enumerate() {
            bag.put_u64s(&format!("mag.ever/{i}"), ever.words.clone());
        }
        // pending_reselect is intra-step scratch (set by sparse_plan, read by
        // the same step's step_sparse) — never live at a suspend boundary
    }

    fn state_load(&mut self, bag: &StateBag) -> Result<()> {
        let n_layers = bag.get_usize("mag.n_layers")?;
        if n_layers != self.sizes.len() {
            bail!("magnitude checkpoint has {n_layers} layers, model has {}", self.sizes.len());
        }
        let load_mask = |key: &str, n: usize| -> Result<BitMask> {
            let words = bag.u64s(key)?;
            if words.len() != n.div_ceil(64) {
                bail!("{key}: {} mask words, layer of {n} wants {}", words.len(), n.div_ceil(64));
            }
            let popcount = words.iter().map(|w| w.count_ones() as usize).sum();
            Ok(BitMask { words: words.to_vec(), len: n, popcount })
        };
        let mut states = Vec::new();
        if bag.get_bool("mag.has_states")? {
            for (i, &n) in self.sizes.iter().enumerate() {
                let m = bag.f32s(&format!("mag.m/{i}"))?.to_vec();
                let v = bag.f32s(&format!("mag.v/{i}"))?.to_vec();
                if m.len() != n || v.len() != n {
                    bail!("magnitude checkpoint layer {i} has {} elems, model wants {n}", m.len());
                }
                states.push(LayerState { m, v, mask: load_mask(&format!("mag.mask/{i}"), n)? });
            }
        }
        let mut ever = Vec::new();
        for (i, &n) in self.sizes.iter().enumerate() {
            ever.push(load_mask(&format!("mag.ever/{i}"), n)?);
        }
        self.adam_step = bag.get_u64("mag.adam_step")?;
        self.selected_once = bag.get_bool("mag.selected_once")?;
        self.states = states;
        self.ever_updated = ever;
        self.pending_reselect = false;
        Ok(())
    }

    fn telemetry(&self) -> Vec<(String, f64)> {
        vec![("unique_updated_frac".into(), self.unique_updated_frac())]
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil;
    use super::*;

    fn setup(sparsity: f64, update_every: usize) -> (Magnitude, ParamStore, Vec<usize>) {
        let specs = testutil::toy_specs();
        let sizes: Vec<usize> = specs.iter().map(|s| s.numel()).collect();
        let store = ParamStore::init(&specs, 3);
        (Magnitude::new(&sizes, sparsity, update_every, AdamHypers::default()), store, sizes)
    }

    #[test]
    fn selects_top_k_by_weight_magnitude() {
        let (mut m, mut store, sizes) = setup(0.9, 0);
        let grads = testutil::rand_grads(&sizes, 1);
        let info = m.step(&mut store, &grads, 1.0, 1e-3, 0);
        assert!(info.reselected);
        let n: u64 = sizes.iter().map(|&x| x as u64).sum();
        let want = ((0.1 * n as f64).round()) as u64;
        let active = m.active_coords();
        assert!(active >= want && active <= want + 8, "active={active} want≈{want}");
    }

    #[test]
    fn fixed_selection_keeps_q_at_one_minus_s() {
        let (mut m, mut store, sizes) = setup(0.8, 0);
        let grads = testutil::rand_grads(&sizes, 2);
        for t in 0..10 {
            m.step(&mut store, &grads, 1.0, 1e-3, t);
        }
        let q = m.unique_updated_frac();
        assert!((q - 0.2).abs() < 0.02, "q={q}");
    }

    #[test]
    fn adaptive_selection_grows_q() {
        let (mut m, mut store, sizes) = setup(0.8, 3);
        // strong gradients move weights so the top-k set churns
        for t in 0..30 {
            let grads = testutil::rand_grads(&sizes, 100 + t as u64);
            m.step(&mut store, &grads, 1.0, 5e-2, t);
        }
        let q = m.unique_updated_frac();
        assert!(q > 0.22, "q={q} did not grow beyond 1-s=0.2");
    }

    #[test]
    fn descends_quadratic_on_active_set() {
        let (mut m, _, _) = setup(0.5, 0);
        let (before, after) = testutil::quadratic_descends(&mut m, 300);
        assert!(after < before * 0.8, "before={before} after={after}");
    }

    /// Streaming-vs-dense parity: identical shards through a MaskedSink
    /// must update the same coordinates to the same bits as the dense
    /// path, across re-selection boundaries and grad accumulation.
    #[test]
    fn streaming_route_matches_dense_route_bitwise() {
        use crate::grads::{GradSink, MaskedSink};
        let specs = testutil::toy_specs();
        let sizes: Vec<usize> = specs.iter().map(|s| s.numel()).collect();
        for accum in [1usize, 2] {
            let (mut dense, mut store_d, _) = setup(0.8, 3);
            let (mut sparse, mut store_s, _) = setup(0.8, 3);
            let scale = 1.0 / accum as f32;
            for t in 0..8 {
                let micros: Vec<Vec<Vec<f32>>> = (0..accum)
                    .map(|k| testutil::rand_grads(&sizes, 40 + (t * accum + k) as u64))
                    .collect();
                let acc = testutil::accum_reference(&micros, &sizes);
                let id = dense.step(&mut store_d, &acc, 1.0, 5e-2, t);
                let plan = sparse.sparse_plan(&store_s, accum, t).expect("magnitude streams");
                let mut sink = MaskedSink::new(sizes.len(), plan.retain, scale);
                for (k, m) in micros.iter().enumerate() {
                    sink.begin_micro(k == 0);
                    for (l, g) in m.iter().enumerate() {
                        sink.consume(l, g);
                    }
                }
                let is = match sparse.step_sparse(&mut store_s, &sink, 1.0, 5e-2, t) {
                    crate::baselines::SparseOutcome::Done(info) => info,
                    _ => panic!("magnitude never replays"),
                };
                assert_eq!(id.reselected, is.reselected, "step {t} accum {accum}");
                assert_eq!(id.updated_coords, is.updated_coords, "step {t} accum {accum}");
                assert_eq!(id.mem, is.mem, "step {t} accum {accum}");
                for (li, (a, b)) in store_d.bufs.iter().zip(&store_s.bufs).enumerate() {
                    for (i, (x, y)) in a.iter().zip(b).enumerate() {
                        assert_eq!(
                            x.to_bits(),
                            y.to_bits(),
                            "param {li}[{i}] diverged at step {t} (accum {accum})"
                        );
                    }
                }
            }
            assert_eq!(dense.unique_updated_frac(), sparse.unique_updated_frac());
        }
    }
}
