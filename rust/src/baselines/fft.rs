//! Full-parameter Adam — the paper's "FFT" baseline (Tables 7/8) and the
//! memory ceiling every other method is compared against.

use anyhow::{bail, Result};

use super::{StepInfo, Strategy};
use crate::memory::profiles;
use crate::model::ParamStore;
use crate::optim::{AdamHypers, DenseAdam};
use crate::session::state::StateBag;

pub struct FftAdam {
    opt: DenseAdam,
    n_params: u64,
}

impl FftAdam {
    pub fn new(sizes: &[usize], h: AdamHypers) -> FftAdam {
        FftAdam {
            opt: DenseAdam::new(sizes, h),
            n_params: sizes.iter().map(|&s| s as u64).sum(),
        }
    }
}

impl Strategy for FftAdam {
    fn step(
        &mut self,
        store: &mut ParamStore,
        grads: &[Vec<f32>],
        _loss: f64,
        lr: f64,
        _step: usize,
    ) -> StepInfo {
        let grad_refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
        self.opt.step(&mut store.bufs, &grad_refs, lr);
        StepInfo {
            updated_coords: self.n_params,
            reselected: false,
            mem: profiles::full_adam(self.n_params),
            active_layers: Vec::new(),
        }
    }

    fn name(&self) -> &'static str {
        "adam"
    }

    fn state_save(&self, bag: &mut StateBag) {
        bag.put_u64("fft.step", self.opt.step);
        bag.put_usize("fft.n_layers", self.opt.m.len());
        for (i, (m, v)) in self.opt.m.iter().zip(&self.opt.v).enumerate() {
            bag.put_f32s(&format!("fft.m/{i}"), m.clone());
            bag.put_f32s(&format!("fft.v/{i}"), v.clone());
        }
    }

    fn state_load(&mut self, bag: &StateBag) -> Result<()> {
        let n_layers = bag.get_usize("fft.n_layers")?;
        if n_layers != self.opt.m.len() {
            bail!("fft checkpoint has {n_layers} layers, model has {}", self.opt.m.len());
        }
        // stage into locals first: a bad blob must not leave moments half-set
        let mut ms = Vec::with_capacity(n_layers);
        let mut vs = Vec::with_capacity(n_layers);
        for i in 0..n_layers {
            let m = bag.f32s(&format!("fft.m/{i}"))?;
            let v = bag.f32s(&format!("fft.v/{i}"))?;
            if m.len() != self.opt.m[i].len() || v.len() != self.opt.v[i].len() {
                bail!(
                    "fft checkpoint layer {i} has {} elems, model wants {}",
                    m.len(),
                    self.opt.m[i].len()
                );
            }
            ms.push(m.to_vec());
            vs.push(v.to_vec());
        }
        self.opt.step = bag.get_u64("fft.step")?;
        self.opt.m = ms;
        self.opt.v = vs;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil;
    use super::*;

    #[test]
    fn descends_quadratic() {
        let sizes: Vec<usize> = testutil::toy_specs().iter().map(|s| s.numel()).collect();
        let mut s = FftAdam::new(&sizes, AdamHypers::default());
        let (before, after) = testutil::quadratic_descends(&mut s, 300);
        assert!(after < before * 0.05, "before={before} after={after}");
    }

    #[test]
    fn memory_is_4n() {
        let sizes = vec![100usize, 50];
        let mut s = FftAdam::new(&sizes, AdamHypers::default());
        let specs = vec![
            crate::runtime::ParamSpec { name: "a".into(), shape: vec![100] },
            crate::runtime::ParamSpec { name: "b".into(), shape: vec![50] },
        ];
        let mut store = ParamStore::init(&specs, 1);
        let grads = testutil::rand_grads(&sizes, 2);
        let info = s.step(&mut store, &grads, 1.0, 1e-3, 0);
        assert_eq!(info.mem.total(), 4 * 150 * 4);
        assert_eq!(info.updated_coords, 150);
    }
}
