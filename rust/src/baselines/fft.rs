//! Full-parameter Adam — the paper's "FFT" baseline (Tables 7/8) and the
//! memory ceiling every other method is compared against.

use super::{StepInfo, Strategy};
use crate::memory::profiles;
use crate::model::ParamStore;
use crate::optim::{AdamHypers, DenseAdam};

pub struct FftAdam {
    opt: DenseAdam,
    n_params: u64,
}

impl FftAdam {
    pub fn new(sizes: &[usize], h: AdamHypers) -> FftAdam {
        FftAdam {
            opt: DenseAdam::new(sizes, h),
            n_params: sizes.iter().map(|&s| s as u64).sum(),
        }
    }
}

impl Strategy for FftAdam {
    fn step(
        &mut self,
        store: &mut ParamStore,
        grads: &[Vec<f32>],
        _loss: f64,
        lr: f64,
        _step: usize,
    ) -> StepInfo {
        let grad_refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
        self.opt.step(&mut store.bufs, &grad_refs, lr);
        StepInfo {
            updated_coords: self.n_params,
            reselected: false,
            mem: profiles::full_adam(self.n_params),
            active_layers: Vec::new(),
        }
    }

    fn name(&self) -> &'static str {
        "adam"
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil;
    use super::*;

    #[test]
    fn descends_quadratic() {
        let sizes: Vec<usize> = testutil::toy_specs().iter().map(|s| s.numel()).collect();
        let mut s = FftAdam::new(&sizes, AdamHypers::default());
        let (before, after) = testutil::quadratic_descends(&mut s, 300);
        assert!(after < before * 0.05, "before={before} after={after}");
    }

    #[test]
    fn memory_is_4n() {
        let sizes = vec![100usize, 50];
        let mut s = FftAdam::new(&sizes, AdamHypers::default());
        let specs = vec![
            crate::runtime::ParamSpec { name: "a".into(), shape: vec![100] },
            crate::runtime::ParamSpec { name: "b".into(), shape: vec![50] },
        ];
        let mut store = ParamStore::init(&specs, 1);
        let grads = testutil::rand_grads(&sizes, 2);
        let info = s.step(&mut store, &grads, 1.0, 1e-3, 0);
        assert_eq!(info.mem.total(), 4 * 150 * 4);
        assert_eq!(info.updated_coords, 150);
    }
}
