//! Optimization strategies: the paper's comparison set behind one trait.
//!
//! The trainer executes the AOT fwd/bwd artifact and hands each strategy the
//! full gradient set; the strategy owns *which* coordinates move and what
//! optimizer state exists — that difference is exactly what the paper
//! compares (loss, peak memory, wall-clock).

pub mod badam;
pub mod fft;
pub mod galore;
pub mod lora;
pub mod magnitude;

use crate::memory::MemBreakdown;
use crate::model::ParamStore;

/// Telemetry returned by each optimizer step.
#[derive(Debug, Clone, Default)]
pub struct StepInfo {
    /// coordinates actually updated this step
    pub updated_coords: u64,
    /// whether the block/selection changed this step
    pub reselected: bool,
    /// modeled memory this step (weights+grads+state+extras; DESIGN.md §5)
    pub mem: MemBreakdown,
    /// layers in the active block (empty = all)
    pub active_layers: Vec<usize>,
}

/// A training method (BlockLLM or a baseline).
pub trait Strategy {
    /// Consume this step's loss + full gradient set, update `store` in
    /// place. `lr` already includes the schedule; `step` is 0-based.
    fn step(
        &mut self,
        store: &mut ParamStore,
        grads: &[Vec<f32>],
        loss: f64,
        lr: f64,
        step: usize,
    ) -> StepInfo;

    fn name(&self) -> &'static str;

    /// Gradient elements the method must materialize simultaneously on the
    /// accelerator (the paper's memory model; the CPU artifact always
    /// returns all grads — see DESIGN.md §5 "VRAM" row).
    fn modeled_grad_elems(&self, n_params: u64) -> u64 {
        n_params
    }

    /// Method-specific end-of-run telemetry (e.g. Magnitude's unique-update
    /// fraction q, BlockLLM's selection count).
    fn telemetry(&self) -> Vec<(String, f64)> {
        Vec::new()
    }
}

/// Build a strategy from a config + the model's parameter sizes.
pub fn build(
    cfg: &crate::config::TrainConfig,
    sizes: &[usize],
    names: &[String],
) -> Box<dyn Strategy> {
    use crate::config::Method;
    let h = crate::optim::AdamHypers {
        beta1: cfg.beta1,
        beta2: cfg.beta2,
        eps: cfg.eps,
        weight_decay: cfg.weight_decay,
    };
    match cfg.method {
        Method::FullAdam => Box::new(fft::FftAdam::new(sizes, h)),
        Method::BlockLlm | Method::BlockLlmSubOpt | Method::BlockLlmNoFreq => Box::new(
            crate::blockllm::strategy::BlockLlmStrategy::from_config(cfg, sizes, h),
        ),
        Method::GaLore => Box::new(galore::GaLore::new(
            sizes,
            names,
            cfg.rank,
            cfg.galore_scale,
            cfg.galore_refresh,
            h,
            cfg.seed,
        )),
        Method::LoRa => Box::new(lora::LoRa::new(sizes, names, cfg.rank, cfg.lora_alpha, h, cfg.seed)),
        Method::BAdam => Box::new(badam::BAdam::new(sizes, cfg.badam_k, h)),
        Method::Magnitude => {
            let heads: Vec<usize> = names
                .iter()
                .enumerate()
                .filter(|(_, n)| n.starts_with("cls_"))
                .map(|(i, _)| i)
                .collect();
            Box::new(
                magnitude::Magnitude::new(sizes, cfg.sparsity, cfg.mag_update_every, h)
                    .with_always_active(heads),
            )
        }
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use crate::runtime::ParamSpec;
    use crate::util::rng::Pcg64;

    /// A toy 4-tensor "model" used across strategy tests.
    pub fn toy_specs() -> Vec<ParamSpec> {
        vec![
            ParamSpec { name: "tok_emb".into(), shape: vec![32, 8] },
            ParamSpec { name: "layers.0.wq".into(), shape: vec![8, 8] },
            ParamSpec { name: "layers.0.attn_norm".into(), shape: vec![8] },
            ParamSpec { name: "lm_head".into(), shape: vec![8, 32] },
        ]
    }

    pub fn rand_grads(sizes: &[usize], seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Pcg64::new(seed);
        sizes.iter().map(|&n| (0..n).map(|_| rng.normal_f32()).collect()).collect()
    }

    /// Quadratic bowl: loss = 0.5||W||², grad = W. Any sane optimizer must
    /// shrink the params.
    pub fn quadratic_descends(strategy: &mut dyn super::Strategy, steps: usize) -> (f64, f64) {
        let specs = toy_specs();
        let mut store = crate::model::ParamStore::init(&specs, 7);
        // overwrite with larger values so descent is visible
        for b in &mut store.bufs {
            for x in b.iter_mut() {
                *x = (*x) * 10.0 + 0.5;
            }
        }
        let before: f64 = store.bufs.iter().map(|b| b.iter().map(|&x| (x as f64).powi(2)).sum::<f64>()).sum();
        for t in 0..steps {
            let grads: Vec<Vec<f32>> = store.bufs.clone();
            let loss: f64 = 0.5 * store.bufs.iter().map(|b| b.iter().map(|&x| (x as f64).powi(2)).sum::<f64>()).sum::<f64>();
            strategy.step(&mut store, &grads, loss, 0.05, t);
        }
        let after: f64 = store.bufs.iter().map(|b| b.iter().map(|&x| (x as f64).powi(2)).sum::<f64>()).sum();
        (before, after)
    }
}
