//! Optimization strategies: the paper's comparison set behind one trait.
//!
//! The trainer drives the execution backend's fwd/bwd and routes gradients
//! to the strategy; the strategy owns *which* coordinates move and what
//! optimizer state exists — that difference is exactly what the paper
//! compares (loss, peak memory, wall-clock). Two gradient routes exist:
//! the dense path (`step`, full gradient set staged by `grads::AccumSink` —
//! what FFT/GaLore/LoRA/BAdam consume, since their math wants whole
//! tensors) and the streaming path (`sparse_plan`/`step_sparse`, compact
//! `grads::MaskedSink` retention — BlockLLM and Magnitude, whose updates
//! only ever read masked coordinates). Both routes are bitwise-identical in
//! what they compute; they differ only in gradient residency.

pub mod badam;
pub mod fft;
pub mod galore;
pub mod lora;
pub mod magnitude;

use anyhow::Result;

use crate::grads::{MaskedSink, Retain};
use crate::memory::MemBreakdown;
use crate::model::ParamStore;
use crate::session::state::StateBag;

/// Telemetry returned by each optimizer step.
#[derive(Debug, Clone, Default)]
pub struct StepInfo {
    /// coordinates actually updated this step
    pub updated_coords: u64,
    /// whether the block/selection changed this step
    pub reselected: bool,
    /// modeled memory this step (weights+grads+state+extras; DESIGN.md §5)
    pub mem: MemBreakdown,
    /// layers in the active block (empty = all)
    pub active_layers: Vec<usize>,
}

/// Retention plan for the streaming-gradient path (`PALLAS_GRAD_STREAM=1`):
/// which layers a `grads::MaskedSink` must keep across the upcoming step's
/// microbatches, and how. Layers absent from the plan are dropped after
/// their streaming norm is taken.
#[derive(Debug)]
pub struct SparsePlan {
    pub retain: Vec<(usize, Retain)>,
}

/// Outcome of a streamed optimizer step.
pub enum SparseOutcome {
    /// The step completed from the sink's compact retention alone.
    Done(StepInfo),
    /// Selection event (accum == 1): the caller must replay the step's
    /// microbatches into a `MaskedSink` with this retention — masks built
    /// on arrival, so residency stays within the streaming bound — and
    /// finish via [`Strategy::step_selected`].
    Replay(Vec<(usize, Retain)>),
    /// Selection event under grad accumulation: accumulated-gradient norms
    /// have cross-microbatch terms no streaming reduction can reconstruct,
    /// so the caller must replay into dense staging buffers and finish via
    /// [`Strategy::step_selected_dense`]. Costs one step of dense-path
    /// memory, only on (patience-gated, rare) selection events.
    ReplayDense,
}

/// A training method (BlockLLM or a baseline).
pub trait Strategy {
    /// Consume this step's loss + full gradient set, update `store` in
    /// place. `lr` already includes the schedule; `step` is 0-based.
    fn step(
        &mut self,
        store: &mut ParamStore,
        grads: &[Vec<f32>],
        loss: f64,
        lr: f64,
        step: usize,
    ) -> StepInfo;

    fn name(&self) -> &'static str;

    /// Streaming-gradient support. A strategy that can consume compact
    /// shards returns the retention plan for the upcoming step (called
    /// BEFORE the fwd/bwd; `store` holds the pre-step weights); `None` —
    /// the default, used by the dense baselines — keeps the trainer on the
    /// dense staging path via `grads::AccumSink`.
    fn sparse_plan(
        &mut self,
        _store: &ParamStore,
        _grad_accum: usize,
        _step: usize,
    ) -> Option<SparsePlan> {
        None
    }

    /// One optimizer step from a `MaskedSink`'s retained data (only called
    /// after `sparse_plan` returned `Some`). Must produce bitwise the same
    /// parameter updates as `step` fed dense gradients — the streaming
    /// contract `tests/grad_check.rs` pins.
    fn step_sparse(
        &mut self,
        _store: &mut ParamStore,
        _sink: &MaskedSink,
        _loss: f64,
        _lr: f64,
        _step: usize,
    ) -> SparseOutcome {
        unreachable!("{}: step_sparse without a sparse_plan", self.name())
    }

    /// Finish a `SparseOutcome::Replay` selection step from the replay
    /// sink's on-arrival masks + compact values.
    fn step_selected(
        &mut self,
        _store: &mut ParamStore,
        _sink: MaskedSink,
        _loss: f64,
        _lr: f64,
        _step: usize,
    ) -> StepInfo {
        unreachable!("{}: step_selected without a Replay outcome", self.name())
    }

    /// Finish a `SparseOutcome::ReplayDense` selection step from dense
    /// accumulated gradients (the loss was already observed by
    /// `step_sparse`; implementations must not re-observe it).
    fn step_selected_dense(
        &mut self,
        _store: &mut ParamStore,
        _grads: &[Vec<f32>],
        _loss: f64,
        _lr: f64,
        _step: usize,
    ) -> StepInfo {
        unreachable!("{}: step_selected_dense without a ReplayDense outcome", self.name())
    }

    /// Gradient elements the method must materialize simultaneously on the
    /// accelerator (the paper's memory model; the CPU artifact always
    /// returns all grads — see DESIGN.md §5 "VRAM" row).
    fn modeled_grad_elems(&self, n_params: u64) -> u64 {
        n_params
    }

    /// Modeled optimizer-state elements (M + V together) the method holds
    /// between steps — the admission-control basis for `pallas serve`
    /// memory budgets. Default is full dense Adam (2n); sparse/low-rank
    /// methods override with their actual state footprint.
    fn modeled_state_elems(&self, n_params: u64) -> u64 {
        2 * n_params
    }

    /// Per-replica optimizer-state bytes under the dist layer's ZeRO-style
    /// moment sharding at `replicas` data-parallel workers: the LARGEST
    /// single replica's share (replica 0's, with even chunking). Default:
    /// an even split of `modeled_state_elems` — methods whose actual state
    /// layout shards unevenly (BlockLLM's per-layer compact masks) override
    /// with their exact number. At `replicas == 1` this must equal the full
    /// state bytes.
    fn state_shard_bytes(&self, n_params: u64, replicas: usize) -> u64 {
        crate::memory::F32 * self.modeled_state_elems(n_params).div_ceil(replicas.max(1) as u64)
    }

    /// Serialize EVERY piece of method-owned mutable state — optimizer
    /// moments, masks, selection bookkeeping, rng positions, step counters
    /// — into `bag` under a method-unique key prefix. Together with
    /// `state_load` this is the suspend/resume contract: a strategy
    /// restored from its own `state_save` output must continue producing
    /// bitwise-identical updates to one that never suspended.
    fn state_save(&self, bag: &mut StateBag);

    /// Restore state previously written by `state_save`. Errors (missing
    /// keys, shape mismatches) must leave no partial mutation the caller
    /// could mistake for a successful load — Session treats any `Err` as
    /// fatal and discards the strategy.
    fn state_load(&mut self, bag: &StateBag) -> Result<()>;

    /// Method-specific end-of-run telemetry (e.g. Magnitude's unique-update
    /// fraction q, BlockLLM's selection count).
    fn telemetry(&self) -> Vec<(String, f64)> {
        Vec::new()
    }
}

/// Build a strategy from a config + the model's parameter sizes.
pub fn build(
    cfg: &crate::config::TrainConfig,
    sizes: &[usize],
    names: &[String],
) -> Box<dyn Strategy> {
    use crate::config::Method;
    let h = crate::optim::AdamHypers {
        beta1: cfg.beta1,
        beta2: cfg.beta2,
        eps: cfg.eps,
        weight_decay: cfg.weight_decay,
    };
    match cfg.method {
        Method::FullAdam => Box::new(fft::FftAdam::new(sizes, h)),
        Method::BlockLlm | Method::BlockLlmSubOpt | Method::BlockLlmNoFreq => Box::new(
            crate::blockllm::strategy::BlockLlmStrategy::from_config(cfg, sizes, h),
        ),
        Method::GaLore => Box::new(galore::GaLore::new(
            sizes,
            names,
            cfg.rank,
            cfg.galore_scale,
            cfg.galore_refresh,
            h,
            cfg.seed,
        )),
        Method::LoRa => {
            Box::new(lora::LoRa::new(sizes, names, cfg.rank, cfg.lora_alpha, h, cfg.seed))
        }
        Method::BAdam => Box::new(badam::BAdam::new(sizes, cfg.badam_k, h)),
        Method::Magnitude => {
            let heads: Vec<usize> = names
                .iter()
                .enumerate()
                .filter(|(_, n)| n.starts_with("cls_"))
                .map(|(i, _)| i)
                .collect();
            Box::new(
                magnitude::Magnitude::new(sizes, cfg.sparsity, cfg.mag_update_every, h)
                    .with_always_active(heads),
            )
        }
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use crate::runtime::ParamSpec;
    use crate::util::rng::Pcg64;

    /// A toy 4-tensor "model" used across strategy tests.
    pub fn toy_specs() -> Vec<ParamSpec> {
        vec![
            ParamSpec { name: "tok_emb".into(), shape: vec![32, 8] },
            ParamSpec { name: "layers.0.wq".into(), shape: vec![8, 8] },
            ParamSpec { name: "layers.0.attn_norm".into(), shape: vec![8] },
            ParamSpec { name: "lm_head".into(), shape: vec![8, 32] },
        ]
    }

    pub fn rand_grads(sizes: &[usize], seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Pcg64::new(seed);
        sizes.iter().map(|&n| (0..n).map(|_| rng.normal_f32()).collect()).collect()
    }

    /// Dense reference for the trainer's `grads::AccumSink` arithmetic
    /// (first microbatch: plain copy at accum 1, else `scale·g`; later
    /// microbatches: `+= scale·g`). The streaming-vs-dense parity tests
    /// feed their dense route through this so both strategy suites pin
    /// against ONE accumulation contract.
    pub fn accum_reference(micros: &[Vec<Vec<f32>>], sizes: &[usize]) -> Vec<Vec<f32>> {
        let scale = 1.0 / micros.len() as f32;
        let mut acc: Vec<Vec<f32>> = sizes.iter().map(|&n| vec![0.0; n]).collect();
        for (k, m) in micros.iter().enumerate() {
            for (a, g) in acc.iter_mut().zip(m) {
                if k == 0 && micros.len() == 1 {
                    a.copy_from_slice(g);
                } else if k == 0 {
                    a.iter_mut().zip(g).for_each(|(x, &v)| *x = scale * v);
                } else {
                    a.iter_mut().zip(g).for_each(|(x, &v)| *x += scale * v);
                }
            }
        }
        acc
    }

    /// Quadratic bowl: loss = 0.5||W||², grad = W. Any sane optimizer must
    /// shrink the params.
    pub fn quadratic_descends(strategy: &mut dyn super::Strategy, steps: usize) -> (f64, f64) {
        let specs = toy_specs();
        let mut store = crate::model::ParamStore::init(&specs, 7);
        // overwrite with larger values so descent is visible
        for b in &mut store.bufs {
            for x in b.iter_mut() {
                *x = (*x) * 10.0 + 0.5;
            }
        }
        fn sq_norm(bufs: &[Vec<f32>]) -> f64 {
            bufs.iter()
                .map(|b| b.iter().map(|&x| (x as f64).powi(2)).sum::<f64>())
                .sum()
        }
        let before: f64 = sq_norm(&store.bufs);
        for t in 0..steps {
            let grads: Vec<Vec<f32>> = store.bufs.clone();
            let loss: f64 = 0.5 * sq_norm(&store.bufs);
            strategy.step(&mut store, &grads, loss, 0.05, t);
        }
        let after: f64 = sq_norm(&store.bufs);
        (before, after)
    }
}
