//! BAdam baseline (Luo et al., 2024): block coordinate Adam with a CYCLIC
//! block schedule — the contrast the paper draws in §1: blocks are visited
//! round-robin regardless of importance, K steps per block, optimizer state
//! only for the active block (reset on switch).

use anyhow::{bail, Result};

use super::{StepInfo, Strategy};
use crate::memory::profiles;
use crate::model::ParamStore;
use crate::optim::masked_adam::{masked_adam_step, BitMask, LayerState};
use crate::optim::AdamHypers;
use crate::session::state::StateBag;

pub struct BAdam {
    sizes: Vec<usize>,
    k: usize,
    hypers: AdamHypers,
    /// current block = one layer index (BAdam's unit is a transformer block;
    /// here the selectable unit is a parameter tensor, matching how the
    /// other methods are scored — see DESIGN.md §3 "layer granularity")
    current: usize,
    steps_in_block: usize,
    state: Option<LayerState>,
    adam_step: u64,
    n_params: u64,
}

impl BAdam {
    pub fn new(sizes: &[usize], k: usize, hypers: AdamHypers) -> BAdam {
        BAdam {
            sizes: sizes.to_vec(),
            k: k.max(1),
            hypers,
            current: 0,
            steps_in_block: 0,
            state: None,
            adam_step: 0,
            n_params: sizes.iter().map(|&s| s as u64).sum(),
        }
    }

    fn max_block(&self) -> u64 {
        self.sizes.iter().map(|&s| s as u64).max().unwrap_or(0)
    }
}

impl Strategy for BAdam {
    fn step(
        &mut self,
        store: &mut ParamStore,
        grads: &[Vec<f32>],
        _loss: f64,
        lr: f64,
        _step: usize,
    ) -> StepInfo {
        let mut reselected = false;
        if self.state.is_none() || self.steps_in_block >= self.k {
            if self.state.is_some() {
                self.current = (self.current + 1) % self.sizes.len();
            }
            let n = self.sizes[self.current];
            // state reset on block switch (BAdam semantics)
            self.state = Some(LayerState {
                m: vec![0.0; n],
                v: vec![0.0; n],
                mask: BitMask::all_set(n),
            });
            self.steps_in_block = 0;
            self.adam_step = 0;
            reselected = true;
        }
        self.steps_in_block += 1;
        self.adam_step += 1;
        let li = self.current;
        let st = self.state.as_mut().expect("state set above");
        let updated =
            masked_adam_step(&mut store.bufs[li], &grads[li], st, self.adam_step, lr, &self.hypers);

        StepInfo {
            updated_coords: updated as u64,
            reselected,
            mem: profiles::badam(self.n_params, self.max_block()),
            active_layers: vec![li],
        }
    }

    fn name(&self) -> &'static str {
        "badam"
    }

    /// BAdam only needs the active block's gradient on-device.
    fn modeled_grad_elems(&self, _n: u64) -> u64 {
        self.max_block()
    }

    fn modeled_state_elems(&self, _n: u64) -> u64 {
        2 * self.max_block()
    }

    fn state_save(&self, bag: &mut StateBag) {
        bag.put_usize("badam.current", self.current);
        bag.put_usize("badam.steps_in_block", self.steps_in_block);
        bag.put_u64("badam.adam_step", self.adam_step);
        if let Some(st) = &self.state {
            bag.put_f32s("badam.m", st.m.clone());
            bag.put_f32s("badam.v", st.v.clone());
            // the mask is always all_set(sizes[current]) — rebuilt on load
        }
    }

    fn state_load(&mut self, bag: &StateBag) -> Result<()> {
        let current = bag.get_usize("badam.current")?;
        if current >= self.sizes.len() {
            bail!("badam checkpoint block index {current} out of range ({})", self.sizes.len());
        }
        let state = if bag.has_blob("badam.m") {
            let m = bag.f32s("badam.m")?.to_vec();
            let v = bag.f32s("badam.v")?.to_vec();
            let n = self.sizes[current];
            if m.len() != n || v.len() != n {
                bail!("badam checkpoint moments have {} elems, block wants {n}", m.len());
            }
            Some(LayerState { m, v, mask: BitMask::all_set(n) })
        } else {
            None
        };
        self.current = current;
        self.steps_in_block = bag.get_usize("badam.steps_in_block")?;
        self.adam_step = bag.get_u64("badam.adam_step")?;
        self.state = state;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil;
    use super::*;

    #[test]
    fn cycles_through_blocks() {
        let sizes = vec![10usize, 20, 30];
        let mut b = BAdam::new(&sizes, 2, AdamHypers::default());
        let specs: Vec<crate::runtime::ParamSpec> = sizes
            .iter()
            .enumerate()
            .map(|(i, &n)| crate::runtime::ParamSpec { name: format!("p{i}"), shape: vec![n] })
            .collect();
        let mut store = ParamStore::init(&specs, 1);
        let grads = testutil::rand_grads(&sizes, 2);
        let mut actives = Vec::new();
        for t in 0..6 {
            let info = b.step(&mut store, &grads, 1.0, 1e-3, t);
            actives.push(info.active_layers[0]);
        }
        assert_eq!(actives, vec![0, 0, 1, 1, 2, 2]);
    }

    #[test]
    fn only_active_block_moves() {
        let sizes = vec![10usize, 20];
        let mut b = BAdam::new(&sizes, 100, AdamHypers::default());
        let specs: Vec<crate::runtime::ParamSpec> = sizes
            .iter()
            .enumerate()
            .map(|(i, &n)| crate::runtime::ParamSpec { name: format!("p{i}"), shape: vec![n] })
            .collect();
        let mut store = ParamStore::init(&specs, 1);
        let before1 = store.bufs[1].clone();
        let grads = testutil::rand_grads(&sizes, 3);
        b.step(&mut store, &grads, 1.0, 1e-2, 0);
        assert_eq!(store.bufs[1], before1, "inactive block moved");
    }

    #[test]
    fn descends_quadratic_eventually() {
        let sizes: Vec<usize> = testutil::toy_specs().iter().map(|s| s.numel()).collect();
        let mut s = BAdam::new(&sizes, 20, AdamHypers::default());
        let (before, after) = testutil::quadratic_descends(&mut s, 400);
        assert!(after < before * 0.6, "before={before} after={after}");
    }

    #[test]
    fn memory_charges_one_block() {
        let sizes = vec![1000usize, 10];
        let mut b = BAdam::new(&sizes, 5, AdamHypers::default());
        let specs: Vec<crate::runtime::ParamSpec> = sizes
            .iter()
            .enumerate()
            .map(|(i, &n)| crate::runtime::ParamSpec { name: format!("p{i}"), shape: vec![n] })
            .collect();
        let mut store = ParamStore::init(&specs, 1);
        let grads = testutil::rand_grads(&sizes, 4);
        let info = b.step(&mut store, &grads, 1.0, 1e-3, 0);
        // weights 1010 + (g+m+v) * max block 1000, in f32 bytes
        assert_eq!(info.mem.total(), (1010 + 3 * 1000) * 4);
    }
}
