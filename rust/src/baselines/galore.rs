//! GaLore baseline (Zhao et al., 2024): Gradient Low-Rank Projection.
//!
//! For each 2-D weight W [m,n], project the gradient onto a rank-r subspace
//! (left projection Pᵀ G for m <= n, right projection G Q for m > n), run
//! Adam in the low-rank space, and project the update back scaled by α.
//! Projections refresh every T steps from the current gradient — the paper
//! uses a truncated SVD; we use a randomized range finder with power
//! iterations (DESIGN.md §6.6). 1-D parameters (norms, biases) fall back to
//! dense Adam, as in the reference implementation.

use anyhow::{bail, Result};

use super::{StepInfo, Strategy};
use crate::linalg::range_finder;
use crate::memory::{profiles, MemBreakdown};
use crate::model::ParamStore;
use crate::optim::AdamHypers;
use crate::session::state::StateBag;
use crate::tensor::Tensor;
use crate::util::rng::Pcg64;

struct LayerGalore {
    /// projection with orthonormal columns; `left` decides which side
    proj: Option<Tensor>,
    left: bool,
    /// Adam moments in low-rank space
    m: Vec<f32>,
    v: Vec<f32>,
    shape: Vec<usize>,
}

pub struct GaLore {
    layers: Vec<LayerGalore>,
    /// dense Adam moments for non-projected (1-D) params
    dense_m: Vec<Vec<f32>>,
    dense_v: Vec<Vec<f32>>,
    rank: usize,
    scale: f64,
    refresh: usize,
    hypers: AdamHypers,
    step: u64,
    rng: Pcg64,
    n_params: u64,
}

impl GaLore {
    pub fn new(
        sizes: &[usize],
        names: &[String],
        rank: usize,
        scale: f64,
        refresh: usize,
        hypers: AdamHypers,
        seed: u64,
    ) -> GaLore {
        // shapes are recovered lazily from the store at first step; allocate
        // placeholders here
        let layers = sizes
            .iter()
            .zip(names)
            .map(|(&n, _)| LayerGalore {
                proj: None,
                left: true,
                m: Vec::new(),
                v: Vec::new(),
                shape: vec![n],
            })
            .collect();
        GaLore {
            layers,
            dense_m: sizes.iter().map(|&n| vec![0.0; n]).collect(),
            dense_v: sizes.iter().map(|&n| vec![0.0; n]).collect(),
            rank: rank.max(1),
            scale,
            refresh: refresh.max(1),
            hypers,
            step: 0,
            rng: Pcg64::with_stream(seed, 0x6A10),
            n_params: sizes.iter().map(|&s| s as u64).sum(),
        }
    }

    /// Low-rank optimizer state elements currently held (memory accounting).
    fn lowrank_state_elems(&self) -> u64 {
        self.layers.iter().map(|l| (l.m.len() + l.v.len()) as u64).sum()
    }

    fn proj_elems(&self) -> u64 {
        self.layers
            .iter()
            .filter_map(|l| l.proj.as_ref().map(|p| p.numel() as u64))
            .sum()
    }

    fn dense_state_elems(&self, store: &ParamStore) -> u64 {
        store
            .specs
            .iter()
            .enumerate()
            .filter(|(_, s)| s.shape.len() < 2)
            .map(|(i, _)| 2 * self.dense_m[i].len() as u64)
            .sum()
    }
}

fn dense_adam_update(
    w: &mut [f32],
    g: &[f32],
    m: &mut [f32],
    v: &mut [f32],
    step: u64,
    lr: f64,
    h: &AdamHypers,
) {
    let b1 = h.beta1 as f32;
    let b2 = h.beta2 as f32;
    let eps = h.eps as f32;
    let lr = lr as f32;
    let (bc1, bc2) = crate::optim::masked_adam::bias_corrections(h, step);
    for i in 0..w.len() {
        m[i] = b1 * m[i] + (1.0 - b1) * g[i];
        v[i] = b2 * v[i] + (1.0 - b2) * g[i] * g[i];
        w[i] -= lr * (m[i] / bc1) / ((v[i] / bc2).sqrt() + eps);
    }
}

impl Strategy for GaLore {
    fn step(
        &mut self,
        store: &mut ParamStore,
        grads: &[Vec<f32>],
        _loss: f64,
        lr: f64,
        _step: usize,
    ) -> StepInfo {
        self.step += 1;
        let mut reselected = false;
        let mut updated = 0u64;

        for (li, spec) in store.specs.iter().enumerate() {
            if spec.shape.len() < 2 {
                // dense Adam fallback for vectors
                let (m, v) = (&mut self.dense_m[li], &mut self.dense_v[li]);
                dense_adam_update(
                    &mut store.bufs[li],
                    &grads[li],
                    m,
                    v,
                    self.step,
                    lr,
                    &self.hypers,
                );
                updated += grads[li].len() as u64;
                continue;
            }
            let (rows, cols) = (spec.shape[0], spec.shape[1]);
            let g = Tensor::from_vec(&[rows, cols], grads[li].clone()).expect("grad shape");
            let lg = &mut self.layers[li];
            lg.shape = spec.shape.clone();
            lg.left = rows <= cols;
            let r = self.rank.min(rows).min(cols);

            // projection refresh (paper: every T steps, from the current grad)
            if lg.proj.is_none() || (self.step - 1) % self.refresh as u64 == 0 {
                let p = if lg.left {
                    range_finder(&g, r, 2, &mut self.rng) // [rows, r]
                } else {
                    range_finder(&g.transpose(), r, 2, &mut self.rng) // [cols, r]
                };
                lg.proj = Some(p);
                let state_n = if lg.left { r * cols } else { rows * r };
                // state reset on projection change (as in reference GaLore)
                lg.m = vec![0.0; state_n];
                lg.v = vec![0.0; state_n];
                reselected = true;
            }
            let p = lg.proj.as_ref().expect("projection set above");

            // low-rank gradient
            let lowg = if lg.left { p.matmul_tn(&g) } else { g.matmul(p) };

            // Adam in low-rank space
            let b1 = self.hypers.beta1 as f32;
            let b2 = self.hypers.beta2 as f32;
            let eps = self.hypers.eps as f32;
            let (bc1, bc2) =
                crate::optim::masked_adam::bias_corrections(&self.hypers, self.step);
            let mut dir = vec![0.0f32; lowg.numel()];
            for i in 0..lowg.numel() {
                let gi = lowg.data[i];
                lg.m[i] = b1 * lg.m[i] + (1.0 - b1) * gi;
                lg.v[i] = b2 * lg.v[i] + (1.0 - b2) * gi * gi;
                dir[i] = (lg.m[i] / bc1) / ((lg.v[i] / bc2).sqrt() + eps);
            }
            let dir_shape = if lg.left { [r, cols] } else { [rows, r] };
            let dir_t = Tensor::from_vec(&dir_shape, dir).expect("dir shape");

            // project back: ΔW = α · P dir (left) or dir Pᵀ (right)
            let full = if lg.left { p.matmul(&dir_t) } else { dir_t.matmul_nt(p) };
            let eta = (lr * self.scale) as f32;
            let w = &mut store.bufs[li];
            let wd = self.hypers.weight_decay as f32;
            for i in 0..w.len() {
                w[i] -= eta * full.data[i] + (lr as f32) * wd * w[i];
            }
            updated += w.len() as u64;
        }

        let mem: MemBreakdown = profiles::galore(
            self.n_params,
            self.lowrank_state_elems() + self.dense_state_elems(store),
            self.proj_elems(),
        );
        StepInfo { updated_coords: updated, reselected, mem, active_layers: Vec::new() }
    }

    fn name(&self) -> &'static str {
        "galore"
    }

    fn modeled_state_elems(&self, n_params: u64) -> u64 {
        // low-rank moments + projections + dense fallback for 1-D params;
        // before the first step the projections don't exist yet, so model
        // the post-warmup steady state from allocated buffers when present
        let lowrank = self.lowrank_state_elems() + self.proj_elems();
        if lowrank > 0 {
            lowrank + 2 * self.dense_m.iter().map(|b| b.len() as u64).sum::<u64>()
        } else {
            2 * n_params // pre-step upper bound: dense moments everywhere
        }
    }

    fn state_save(&self, bag: &mut StateBag) {
        bag.put_u64("galore.step", self.step);
        bag.put_u64s("galore.rng", self.rng.to_parts().to_vec());
        bag.put_usize("galore.n_layers", self.layers.len());
        for (i, lg) in self.layers.iter().enumerate() {
            bag.put_bool(&format!("galore.left/{i}"), lg.left);
            bag.put_f32s(&format!("galore.m/{i}"), lg.m.clone());
            bag.put_f32s(&format!("galore.v/{i}"), lg.v.clone());
            bag.put_u64s(
                &format!("galore.shape/{i}"),
                lg.shape.iter().map(|&d| d as u64).collect(),
            );
            if let Some(p) = &lg.proj {
                bag.put_f32s(&format!("galore.proj/{i}"), p.data.clone());
                bag.put_u64s(
                    &format!("galore.proj_shape/{i}"),
                    p.shape.iter().map(|&d| d as u64).collect(),
                );
            }
        }
        for (i, (m, v)) in self.dense_m.iter().zip(&self.dense_v).enumerate() {
            bag.put_f32s(&format!("galore.dense_m/{i}"), m.clone());
            bag.put_f32s(&format!("galore.dense_v/{i}"), v.clone());
        }
    }

    fn state_load(&mut self, bag: &StateBag) -> Result<()> {
        let n_layers = bag.get_usize("galore.n_layers")?;
        if n_layers != self.layers.len() {
            bail!("galore checkpoint has {n_layers} layers, model has {}", self.layers.len());
        }
        let mut layers = Vec::with_capacity(n_layers);
        let mut dense_m = Vec::with_capacity(n_layers);
        let mut dense_v = Vec::with_capacity(n_layers);
        for i in 0..n_layers {
            let shape: Vec<usize> =
                bag.u64s(&format!("galore.shape/{i}"))?.iter().map(|&d| d as usize).collect();
            let proj = if bag.has_blob(&format!("galore.proj/{i}")) {
                let pshape: Vec<usize> = bag
                    .u64s(&format!("galore.proj_shape/{i}"))?
                    .iter()
                    .map(|&d| d as usize)
                    .collect();
                Some(Tensor::from_vec(&pshape, bag.f32s(&format!("galore.proj/{i}"))?.to_vec())?)
            } else {
                None
            };
            layers.push(LayerGalore {
                proj,
                left: bag.get_bool(&format!("galore.left/{i}"))?,
                m: bag.f32s(&format!("galore.m/{i}"))?.to_vec(),
                v: bag.f32s(&format!("galore.v/{i}"))?.to_vec(),
                shape,
            });
            let dm = bag.f32s(&format!("galore.dense_m/{i}"))?;
            let dv = bag.f32s(&format!("galore.dense_v/{i}"))?;
            if dm.len() != self.dense_m[i].len() || dv.len() != self.dense_v[i].len() {
                bail!("galore checkpoint dense moments for layer {i} have wrong length");
            }
            dense_m.push(dm.to_vec());
            dense_v.push(dv.to_vec());
        }
        self.step = bag.get_u64("galore.step")?;
        let rng = bag.u64s("galore.rng")?;
        if rng.len() != 4 {
            bail!("galore checkpoint rng wants 4 words, got {}", rng.len());
        }
        self.rng = Pcg64::from_parts([rng[0], rng[1], rng[2], rng[3]]);
        self.layers = layers;
        self.dense_m = dense_m;
        self.dense_v = dense_v;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil;
    use super::*;

    fn make(sizes: &[usize], names: &[&str], rank: usize) -> GaLore {
        let names: Vec<String> = names.iter().map(|s| s.to_string()).collect();
        GaLore::new(sizes, &names, rank, 1.0, 50, AdamHypers::default(), 1)
    }

    #[test]
    fn descends_quadratic() {
        let specs = testutil::toy_specs();
        let sizes: Vec<usize> = specs.iter().map(|s| s.numel()).collect();
        let names: Vec<&str> = specs.iter().map(|s| s.name.as_str()).collect();
        let mut s = make(&sizes, &names, 4);
        let (before, after) = testutil::quadratic_descends(&mut s, 300);
        assert!(after < before * 0.5, "before={before} after={after}");
    }

    #[test]
    fn lowrank_state_is_smaller_than_dense() {
        let specs = testutil::toy_specs();
        let sizes: Vec<usize> = specs.iter().map(|s| s.numel()).collect();
        let names: Vec<&str> = specs.iter().map(|s| s.name.as_str()).collect();
        let mut s = make(&sizes, &names, 2);
        let mut store = ParamStore::init(&specs, 1);
        let grads = testutil::rand_grads(&sizes, 2);
        let info = s.step(&mut store, &grads, 1.0, 1e-3, 0);
        let n: u64 = sizes.iter().map(|&x| x as u64).sum();
        let dense_state = 2 * n * 4;
        assert!(
            info.mem.optim_m + info.mem.optim_v < dense_state,
            "low-rank state {} not below dense {}",
            info.mem.optim_m + info.mem.optim_v,
            dense_state
        );
    }

    #[test]
    fn projection_refresh_resets_state() {
        let sizes = vec![64usize]; // one 8x8 matrix
        let specs = vec![crate::runtime::ParamSpec { name: "w".into(), shape: vec![8, 8] }];
        let names = vec!["w".to_string()];
        let mut s = GaLore::new(&sizes, &names, 2, 1.0, 3, AdamHypers::default(), 1);
        let mut store = ParamStore::init(&specs, 1);
        let grads = testutil::rand_grads(&sizes, 2);
        let i0 = s.step(&mut store, &grads, 1.0, 1e-3, 0);
        assert!(i0.reselected);
        let i1 = s.step(&mut store, &grads, 1.0, 1e-3, 1);
        assert!(!i1.reselected);
        let i2 = s.step(&mut store, &grads, 1.0, 1e-3, 2);
        assert!(!i2.reselected);
        let i3 = s.step(&mut store, &grads, 1.0, 1e-3, 3); // step 4: (4-1)%3==0
        assert!(i3.reselected);
    }

    #[test]
    fn update_stays_in_projected_subspace() {
        // With a rank-1 gradient, the first update must be rank-1 too.
        let specs = vec![crate::runtime::ParamSpec { name: "w".into(), shape: vec![6, 6] }];
        let sizes = vec![36usize];
        let names = vec!["w".to_string()];
        let mut s = GaLore::new(&sizes, &names, 1, 1.0, 100, AdamHypers::default(), 2);
        let mut store = ParamStore::zeros(&specs);
        // rank-1 grad u vᵀ
        let u = [1.0f32, 2.0, -1.0, 0.5, 0.0, 1.5];
        let v = [0.3f32, -0.7, 1.1, 0.0, 0.9, -0.2];
        let mut g = vec![0.0f32; 36];
        for i in 0..6 {
            for j in 0..6 {
                g[i * 6 + j] = u[i] * v[j];
            }
        }
        s.step(&mut store, &[g], 1.0, 1e-2, 0);
        // resulting W must be (numerically) rank 1: second singular value ~ 0
        let w = Tensor::from_vec(&[6, 6], store.bufs[0].clone()).unwrap();
        let mut rng = Pcg64::new(3);
        let s1 = crate::linalg::spectral_norm_est(&w, 30, &mut rng);
        // deflate: W2 = W - s1 * u1 v1ᵀ is hard without full svd; instead
        // check row space dimension via Gram matrix rank proxy:
        let gram = w.matmul_nt(&w); // [6,6]
        let tr: f32 = (0..6).map(|i| gram.at(i, i)).sum();
        // for rank-1, trace == spectral norm of gram == s1^2
        assert!(
            (tr as f64 - s1 * s1).abs() < 1e-3 * (tr as f64).max(1e-12),
            "tr={tr} s1^2={}",
            s1 * s1
        );
    }
}
