//! Metrics substrate: run logging (JSONL), evaluation statistics
//! (perplexity, accuracy, Matthews/Spearman correlation), moving averages,
//! and the weight-change histograms behind Fig. 3/8.

use std::fs;
use std::io::Write as _;
use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::util::json::Json;

// ---------------------------------------------------------------------------
// Run logger
// ---------------------------------------------------------------------------

/// Append-only JSONL logger; one file per run under results/.
pub struct RunLogger {
    path: PathBuf,
    file: Option<fs::File>,
    /// Records that failed to write (disk full, closed fd, ...). Counted so a
    /// run can't silently lose its log; warned about once on drop.
    dropped: u64,
}

impl RunLogger {
    pub fn create(dir: &Path, run_name: &str) -> Result<RunLogger> {
        fs::create_dir_all(dir)?;
        let path = dir.join(format!("{run_name}.jsonl"));
        let file = fs::File::create(&path)?;
        Ok(RunLogger { path, file: Some(file), dropped: 0 })
    }

    /// A sink that discards everything (unit tests, quick runs).
    pub fn null() -> RunLogger {
        RunLogger { path: PathBuf::new(), file: None, dropped: 0 }
    }

    /// Wrap an already-open file (tests inject read-only handles here).
    #[cfg(test)]
    fn from_file(path: PathBuf, file: fs::File) -> RunLogger {
        RunLogger { path, file: Some(file), dropped: 0 }
    }

    pub fn log(&mut self, record: &Json) {
        if let Some(f) = &mut self.file {
            if writeln!(f, "{}", record.to_string()).is_err() {
                self.dropped += 1;
                crate::obs::add_always(crate::obs::Counter::LogWritesDropped, 1);
            }
        }
    }

    /// Write failures so far (a null logger never drops: it has no file).
    pub fn dropped_writes(&self) -> u64 {
        self.dropped
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for RunLogger {
    fn drop(&mut self) {
        if self.dropped > 0 {
            eprintln!(
                "warning: run log {} dropped {} record(s) on write errors",
                self.path.display(),
                self.dropped
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Moving statistics
// ---------------------------------------------------------------------------

/// Fixed-window moving average over the last `cap` values (the paper's loss
/// history H with patience m).
#[derive(Debug, Clone)]
pub struct MovingWindow {
    cap: usize,
    buf: Vec<f64>,
}

impl MovingWindow {
    pub fn new(cap: usize) -> Self {
        MovingWindow { cap: cap.max(1), buf: Vec::new() }
    }

    pub fn push(&mut self, v: f64) {
        if self.buf.len() == self.cap {
            self.buf.remove(0);
        }
        self.buf.push(v);
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn full(&self) -> bool {
        self.buf.len() == self.cap
    }

    pub fn mean(&self) -> f64 {
        if self.buf.is_empty() {
            f64::NAN
        } else {
            self.buf.iter().sum::<f64>() / self.buf.len() as f64
        }
    }

    /// The retained values, oldest first (checkpointing: the window is
    /// rebuilt by pushing these back in order, so `mean()` — an
    /// insertion-ordered f64 sum — reproduces the exact same bits).
    pub fn values(&self) -> &[f64] {
        &self.buf
    }

    pub fn clear(&mut self) {
        self.buf.clear();
    }
}

// ---------------------------------------------------------------------------
// Evaluation statistics
// ---------------------------------------------------------------------------

/// exp(total_nll / total_tokens) — perplexity from summed eval terms.
pub fn perplexity(loss_sum: f64, token_count: f64) -> f64 {
    if token_count <= 0.0 {
        return f64::NAN;
    }
    (loss_sum / token_count).exp()
}

/// Matthews correlation coefficient for binary predictions (CoLA metric).
pub fn matthews_corr(preds: &[u32], labels: &[u32]) -> f64 {
    let (mut tp, mut tn, mut fp, mut fn_) = (0f64, 0f64, 0f64, 0f64);
    for (&p, &l) in preds.iter().zip(labels) {
        match (p, l) {
            (1, 1) => tp += 1.0,
            (0, 0) => tn += 1.0,
            (1, 0) => fp += 1.0,
            (0, 1) => fn_ += 1.0,
            _ => {}
        }
    }
    let denom = ((tp + fp) * (tp + fn_) * (tn + fp) * (tn + fn_)).sqrt();
    if denom == 0.0 {
        0.0
    } else {
        (tp * tn - fp * fn_) / denom
    }
}

fn ranks(xs: &[f64]) -> Vec<f64> {
    let n = xs.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| xs[a].partial_cmp(&xs[b]).unwrap());
    let mut r = vec![0.0; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0; // average rank for ties
        for k in i..=j {
            r[idx[k]] = avg;
        }
        i = j + 1;
    }
    r
}

/// Spearman rank correlation (STS-B metric). Handles ties by average rank.
pub fn spearman_corr(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.len() < 2 {
        return 0.0;
    }
    let ra = ranks(a);
    let rb = ranks(b);
    pearson(&ra, &rb)
}

/// Pearson correlation.
pub fn pearson(a: &[f64], b: &[f64]) -> f64 {
    let n = a.len() as f64;
    let ma = a.iter().sum::<f64>() / n;
    let mb = b.iter().sum::<f64>() / n;
    let (mut sab, mut saa, mut sbb) = (0.0, 0.0, 0.0);
    for (&x, &y) in a.iter().zip(b) {
        sab += (x - ma) * (y - mb);
        saa += (x - ma) * (x - ma);
        sbb += (y - mb) * (y - mb);
    }
    if saa == 0.0 || sbb == 0.0 {
        0.0
    } else {
        sab / (saa * sbb).sqrt()
    }
}

// ---------------------------------------------------------------------------
// Histograms (Fig. 3 / Fig. 8)
// ---------------------------------------------------------------------------

/// Fixed-bin histogram over [lo, hi); values outside clamp to edge bins.
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Histogram { lo, hi, counts: vec![0; bins] }
    }

    pub fn add(&mut self, v: f64) {
        let bins = self.counts.len();
        let t = ((v - self.lo) / (self.hi - self.lo) * bins as f64).floor();
        let b = (t.max(0.0) as usize).min(bins - 1);
        self.counts[b] += 1;
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("lo", Json::num(self.lo)),
            ("hi", Json::num(self.hi)),
            ("counts", Json::Arr(self.counts.iter().map(|&c| Json::num(c as f64)).collect())),
        ])
    }

    /// ASCII rendering for terminal reports (the repo's "figures").
    pub fn render(&self, width: usize) -> String {
        let max = self.counts.iter().copied().max().unwrap_or(1).max(1);
        let bins = self.counts.len();
        let mut out = String::new();
        for (i, &c) in self.counts.iter().enumerate() {
            let a = self.lo + (self.hi - self.lo) * i as f64 / bins as f64;
            let b = self.lo + (self.hi - self.lo) * (i + 1) as f64 / bins as f64;
            let bar = "#".repeat(((c as f64 / max as f64) * width as f64).round() as usize);
            out.push_str(&format!("[{a:9.4},{b:9.4}) {c:>8} {bar}\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn moving_window_mean_and_eviction() {
        let mut w = MovingWindow::new(3);
        for v in [1.0, 2.0, 3.0, 4.0] {
            w.push(v);
        }
        assert_eq!(w.len(), 3);
        assert!((w.mean() - 3.0).abs() < 1e-12);
        assert!(w.full());
    }

    #[test]
    fn perplexity_of_uniform() {
        // uniform over 256 symbols: nll = ln 256 per token -> ppl = 256
        let nll = (256f64).ln() * 100.0;
        assert!((perplexity(nll, 100.0) - 256.0).abs() < 1e-9);
    }

    #[test]
    fn matthews_perfect_and_inverted() {
        let l = [0, 1, 0, 1, 1, 0];
        assert!((matthews_corr(&l, &l) - 1.0).abs() < 1e-12);
        let inv: Vec<u32> = l.iter().map(|&x| 1 - x).collect();
        assert!((matthews_corr(&inv, &l) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn matthews_degenerate_is_zero() {
        assert_eq!(matthews_corr(&[1, 1, 1], &[0, 1, 0]), 0.0);
    }

    #[test]
    fn spearman_monotone_is_one() {
        let a = [1.0, 2.0, 3.0, 4.0, 5.0];
        let b = [10.0, 100.0, 1000.0, 1e4, 1e5];
        assert!((spearman_corr(&a, &b) - 1.0).abs() < 1e-12);
        let c = [5.0, 4.0, 3.0, 2.0, 1.0];
        assert!((spearman_corr(&a, &c) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_handles_ties() {
        let a = [1.0, 1.0, 2.0, 3.0];
        let b = [1.0, 1.0, 2.0, 3.0];
        assert!((spearman_corr(&a, &b) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_bins_and_clamping() {
        let mut h = Histogram::new(0.0, 1.0, 4);
        for v in [-1.0, 0.1, 0.3, 0.6, 0.9, 2.0] {
            h.add(v);
        }
        assert_eq!(h.counts, vec![2, 1, 1, 2]);
        assert_eq!(h.total(), 6);
        assert!(h.render(10).lines().count() == 4);
    }

    #[test]
    fn logger_counts_dropped_writes() {
        // A read-only handle makes every writeln! fail with EBADF; the logger
        // must count each miss instead of swallowing it.
        let dir = std::env::temp_dir().join("blockllm_test_logs_ro");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ro.jsonl");
        std::fs::write(&path, "").unwrap();
        let ro = std::fs::OpenOptions::new().read(true).open(&path).unwrap();
        let mut lg = RunLogger::from_file(path.clone(), ro);
        lg.log(&Json::obj(vec![("step", Json::num(1.0))]));
        lg.log(&Json::obj(vec![("step", Json::num(2.0))]));
        assert_eq!(lg.dropped_writes(), 2);
        drop(lg); // exercises the warn-once path
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn logger_writes_jsonl() {
        let dir = std::env::temp_dir().join("blockllm_test_logs");
        let mut lg = RunLogger::create(&dir, "t").unwrap();
        lg.log(&Json::obj(vec![("step", Json::num(1.0))]));
        lg.log(&Json::obj(vec![("step", Json::num(2.0))]));
        let content = std::fs::read_to_string(lg.path()).unwrap();
        assert_eq!(content.lines().count(), 2);
        std::fs::remove_dir_all(&dir).ok();
    }
}
