//! BlockLLM: memory-efficient LLM adaptation by selecting and optimizing the
//! right coordinate blocks — a full-system reproduction of Ramesh et al.
//! (2024) as a layered Rust + JAX + Pallas stack.
//!
//! Layers (DESIGN.md §2):
//! * **L3 (this crate)** — the training coordinator: BlockLLM's greedy block
//!   selection, masked sparse Adam, patience controller, plus the GaLore /
//!   LoRA / BAdam / full-Adam baselines, data substrates, memory accounting,
//!   and one experiment harness per paper table/figure.
//! * **L2.5 (`backend`)** — the pluggable execution layer: one `Backend`
//!   trait owning "params + batch -> loss + grads", with two engines:
//!   `NativeBackend` (the LLaMA-style model fwd/bwd in pure Rust on
//!   `tensor::Tensor` — the self-verifying reference path, no Python or
//!   artifacts needed) and `PjrtBackend` (executes the AOT HLO artifacts
//!   via `runtime`). Selected per run with `--backend {auto|native|pjrt}`;
//!   `auto` uses PJRT when artifacts exist and falls back to native.
//! * **L2 (python/compile/model.py)** — the same model in JAX, AOT-lowered
//!   once to HLO text by `make artifacts` and executed here via PJRT; also
//!   the oracle the native engine is validated against
//!   (python/tests/test_native_mirror.py).
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the attention
//!   hot-spot and the fused masked-Adam update, validated against pure-jnp
//!   oracles and (for nano) lowered into the shipped artifacts.

// Kernel-heavy numeric code: index-driven loops over multiple slices and
// wide kernel signatures are the house style (see linalg::gemm's summation
// contract — rewriting loops as iterator chains obscures the per-element
// order the bitwise pins rely on). CI lints with `-D warnings`; these
// style lints are opted out wholesale rather than per-site.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::manual_memcpy,
    clippy::type_complexity
)]

pub mod backend;
pub mod baselines;
pub mod blockllm;
pub mod cli;
pub mod config;
pub mod data;
pub mod dist;
pub mod experiments;
pub mod grads;
pub mod linalg;
pub mod memory;
pub mod metrics;
pub mod model;
pub mod obs;
pub mod optim;
pub mod runtime;
pub mod session;
pub mod tensor;
pub mod trainer;
pub mod util;
