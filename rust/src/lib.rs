//! BlockLLM: memory-efficient LLM adaptation by selecting and optimizing the
//! right coordinate blocks — a full-system reproduction of Ramesh et al.
//! (2024) as a three-layer Rust + JAX + Pallas stack.
//!
//! Layers (DESIGN.md §2):
//! * **L3 (this crate)** — the training coordinator: BlockLLM's greedy block
//!   selection, masked sparse Adam, patience controller, plus the GaLore /
//!   LoRA / BAdam / full-Adam baselines, data substrates, memory accounting,
//!   and one experiment harness per paper table/figure.
//! * **L2 (python/compile/model.py)** — the LLaMA-style model fwd/bwd,
//!   AOT-lowered once to HLO text and executed here via PJRT (`runtime`).
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the attention
//!   hot-spot and the fused masked-Adam update, validated against pure-jnp
//!   oracles and (for nano) lowered into the shipped artifacts.

pub mod baselines;
pub mod blockllm;
pub mod cli;
pub mod config;
pub mod data;
pub mod experiments;
pub mod linalg;
pub mod memory;
pub mod metrics;
pub mod model;
pub mod optim;
pub mod runtime;
pub mod tensor;
pub mod trainer;
pub mod util;
