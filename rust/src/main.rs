//! `blockllm` — the L3 coordinator CLI.
//!
//! Subcommands: `train` (one run, any method/task/preset), `exp` (paper
//! table/figure harnesses), `eval` (checkpoint evaluation), `info`
//! (artifact inventory). See cli::USAGE.

use anyhow::{bail, Result};

use blockllm::cli::{Args, USAGE};
use blockllm::config::{Task, TrainConfig};
use blockllm::experiments;
use blockllm::runtime::Runtime;
use blockllm::util::human_bytes;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    if let Some(v) = args.get("threads") {
        let n: usize =
            v.parse().map_err(|_| anyhow::anyhow!("--threads wants a number, got {v:?}"))?;
        blockllm::util::set_num_threads(n);
    }
    if let Some(v) = args.get("pack-min") {
        let n: usize =
            v.parse().map_err(|_| anyhow::anyhow!("--pack-min wants a number, got {v:?}"))?;
        blockllm::util::set_pack_min(n);
    }
    if let Some(v) = args.get("par-min") {
        let n: usize =
            v.parse().map_err(|_| anyhow::anyhow!("--par-min wants a number, got {v:?}"))?;
        blockllm::util::set_par_min(n);
    }
    if let Some(v) = args.get("attn-batched") {
        let n: usize = v
            .parse()
            .map_err(|_| anyhow::anyhow!("--attn-batched wants 0 or 1, got {v:?}"))?;
        blockllm::util::set_attn_batched(n != 0);
    }
    if let Some(v) = args.get("grad-stream") {
        let n: usize = v
            .parse()
            .map_err(|_| anyhow::anyhow!("--grad-stream wants 0 or 1, got {v:?}"))?;
        blockllm::util::set_grad_stream(n != 0);
    }
    if let Some(v) = args.get("pool") {
        let n: usize =
            v.parse().map_err(|_| anyhow::anyhow!("--pool wants 0 or 1, got {v:?}"))?;
        blockllm::util::set_pool(n != 0);
    }
    if let Some(v) = args.get("trace") {
        let n: usize =
            v.parse().map_err(|_| anyhow::anyhow!("--trace wants 0 or 1, got {v:?}"))?;
        blockllm::obs::set_trace(n != 0);
    }
    let trace_out = args.get("trace-out").map(String::from);
    if trace_out.is_some() {
        // --trace-out implies tracing on and arms the trace-event buffer.
        blockllm::obs::set_trace(true);
        blockllm::obs::arm_events(true);
    }
    let out = match args.command.as_str() {
        "train" => cmd_train(&args),
        "exp" => cmd_exp(&args),
        "eval" => cmd_eval(&args),
        "info" => cmd_info(),
        "help" | "" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{USAGE}"),
    };
    if let Some(path) = &trace_out {
        let n = blockllm::obs::export::write_trace(std::path::Path::new(path))?;
        eprintln!("trace: {n} events -> {path} (load in chrome://tracing or ui.perfetto.dev)");
    }
    out
}

fn cfg_from_args(args: &Args) -> Result<TrainConfig> {
    let mut cfg = TrainConfig::default();
    for (k, v) in &args.kv {
        // non-config keys: checkpoint paths, experiment id, kernel knobs
        if k == "ckpt"
            || k == "save"
            || k == "id"
            || k == "threads"
            || k == "pack-min"
            || k == "par-min"
            || k == "attn-batched"
            || k == "grad-stream"
            || k == "pool"
            || k == "trace"
            || k == "trace-out"
        {
            continue;
        }
        cfg.set(k, v)?;
    }
    Ok(cfg)
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = cfg_from_args(args)?;
    let warm = match args.get("ckpt") {
        Some(p) => Some(blockllm::model::ParamStore::load(std::path::Path::new(p))?),
        None => None,
    };
    println!("config: {}", cfg.to_json().to_string());
    let (res, store) =
        blockllm::experiments::common::run_config_with_params(&cfg, warm.as_ref())?;
    println!(
        "\n{} [{} backend]: {} steps | final train loss {:.4} | eval loss {:.4} | metric {:.4}",
        res.method,
        res.backend,
        res.train_losses.len(),
        res.final_train_loss,
        res.final_eval_loss(),
        res.final_metric()
    );
    println!(
        "peak modeled memory {} | wall {:.1}s ({:.2} steps/s, {:.0}% in backend exec)",
        human_bytes(res.peak_mem_bytes),
        res.wall_secs,
        res.steps_per_sec,
        100.0 * res.exec_secs / res.wall_secs.max(1e-9)
    );
    let [up, ex, dl, st] = res.phase_secs;
    println!(
        "phase breakdown: upload {up:.2}s | execute {ex:.2}s | grad-download {dl:.2}s | strategy {st:.2}s"
    );
    for (k, v) in &res.telemetry {
        println!("  {k} = {v}");
    }
    if let Some(path) = args.get("save") {
        store.save(std::path::Path::new(path))?;
        println!("checkpoint saved to {path}");
    }
    Ok(())
}

fn cmd_exp(args: &Args) -> Result<()> {
    let quick = args.flag("quick");
    if args.flag("all") {
        for id in experiments::ALL_IDS {
            println!("\n######## experiment {id} ########");
            experiments::run(id, quick)?;
        }
        return Ok(());
    }
    let id = args
        .get("id")
        .ok_or_else(|| anyhow::anyhow!("exp needs --id <experiment> or --all\n{USAGE}"))?;
    experiments::run(id, quick)
}

fn cmd_eval(args: &Args) -> Result<()> {
    let cfg = cfg_from_args(args)?;
    let ckpt = args
        .get("ckpt")
        .ok_or_else(|| anyhow::anyhow!("eval needs --ckpt <path>"))?;
    let store = blockllm::model::ParamStore::load(std::path::Path::new(ckpt))?;
    let mut tr = blockllm::trainer::Trainer::open(cfg.clone(), Some(&store))?;
    let ev = match cfg.task {
        Task::C4Pretrain => {
            let mut s = blockllm::data::c4sim::C4Sim::new(cfg.seed ^ 0xEEEE);
            tr.eval_lm(&mut s)?
        }
        Task::AlpacaFinetune => {
            let mut s = blockllm::data::alpacasim::AlpacaSim::new(cfg.seed ^ 0xEEEE);
            tr.eval_lm(&mut s)?
        }
        Task::Glue(i) => {
            let mut s = blockllm::data::gluesim::GlueSim::new(i, cfg.seed);
            tr.eval_cls(&mut s)?
        }
        Task::DomainShift => {
            let mut s = blockllm::data::gluesim::GlueSim::new(4, cfg.seed);
            tr.eval_cls(&mut s)?
        }
    };
    println!("eval loss {:.4} | metric {:.4}", ev.loss, ev.metric);
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("presets (native registry):");
    for p in &blockllm::config::presets::PRESETS {
        println!(
            "  {:6} d={} L={} h={} ff={} params={}",
            p.name,
            p.d_model,
            p.n_layers,
            p.n_heads,
            p.d_ff,
            p.param_count()
        );
    }
    match Runtime::open_default() {
        Ok(rt) => {
            println!("artifacts (PJRT backend available):");
            for (id, a) in &rt.manifest.artifacts {
                println!("  {id:40} kind={:12} pallas={}", a.kind, a.pallas);
            }
        }
        Err(e) => {
            println!("artifacts: none usable ({e})");
            println!("  -> runs fall back to the pure-Rust native backend (--backend native)");
        }
    }
    Ok(())
}
