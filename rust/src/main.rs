//! `blockllm` — the L3 coordinator CLI.
//!
//! Subcommands: `train` (one run, any method/task/preset; `--suspend-at`
//! checkpoints mid-run), `resume` (continue a suspended session), `serve`
//! (policy-scheduled multi-tenant loop over one backend: `--sched
//! rr|slack|weighted`, elastic budgets, `--watch-spec` live injection),
//! `exp` (paper table/figure harnesses), `eval` (checkpoint evaluation),
//! `info` (artifact inventory). See cli::USAGE.

use anyhow::{anyhow, bail, Result};

use blockllm::cli::{Args, USAGE};
use blockllm::config::TrainConfig;
use blockllm::experiments;
use blockllm::runtime::Runtime;
use blockllm::session::scheduler::{SchedPolicy, ServeLoop, ServeOutcome, ServeSpec};
use blockllm::session::Session;
use blockllm::trainer::RunResult;
use blockllm::util::human_bytes;
use blockllm::util::json::Json;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// The CLI's kernel-knob overrides, parsed once so they can be re-applied
/// after every `util::reset_all_knobs()` (the serve scheduler resets knob
/// state at each slice boundary; without re-arming, `--threads` etc. would
/// silently stop applying after the first tenant).
#[derive(Clone, Copy, Default)]
struct KnobOverrides {
    threads: Option<usize>,
    pack_min: Option<usize>,
    par_min: Option<usize>,
    attn_batched: Option<bool>,
    grad_stream: Option<bool>,
    pool: Option<bool>,
    replicas: Option<usize>,
}

impl KnobOverrides {
    fn from_args(args: &Args) -> Result<KnobOverrides> {
        let num = |key: &str| -> Result<Option<usize>> {
            match args.get(key) {
                Some(v) => Ok(Some(
                    v.parse().map_err(|_| anyhow!("--{key} wants a number, got {v:?}"))?,
                )),
                None => Ok(None),
            }
        };
        let bit = |key: &str| -> Result<Option<bool>> {
            match args.get(key) {
                Some(v) => {
                    let n: usize =
                        v.parse().map_err(|_| anyhow!("--{key} wants 0 or 1, got {v:?}"))?;
                    Ok(Some(n != 0))
                }
                None => Ok(None),
            }
        };
        Ok(KnobOverrides {
            threads: num("threads")?,
            pack_min: num("pack-min")?,
            par_min: num("par-min")?,
            attn_batched: bit("attn-batched")?,
            grad_stream: bit("grad-stream")?,
            pool: bit("pool")?,
            replicas: num("replicas")?,
        })
    }

    fn apply(&self) {
        if let Some(n) = self.threads {
            blockllm::util::set_num_threads(n);
        }
        if let Some(n) = self.pack_min {
            blockllm::util::set_pack_min(n);
        }
        if let Some(n) = self.par_min {
            blockllm::util::set_par_min(n);
        }
        if let Some(b) = self.attn_batched {
            blockllm::util::set_attn_batched(b);
        }
        if let Some(b) = self.grad_stream {
            blockllm::util::set_grad_stream(b);
        }
        if let Some(b) = self.pool {
            blockllm::util::set_pool(b);
        }
        if let Some(n) = self.replicas {
            blockllm::util::set_replicas(n);
        }
    }
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    let knobs = KnobOverrides::from_args(&args)?;
    knobs.apply();
    if let Some(v) = args.get("trace") {
        let n: usize =
            v.parse().map_err(|_| anyhow::anyhow!("--trace wants 0 or 1, got {v:?}"))?;
        blockllm::obs::set_trace(n != 0);
    }
    let trace_out = args.get("trace-out").map(String::from);
    if trace_out.is_some() {
        // --trace-out implies tracing on and arms the trace-event buffer.
        blockllm::obs::set_trace(true);
        blockllm::obs::arm_events(true);
    }
    let out = match args.command.as_str() {
        "train" => cmd_train(&args),
        "resume" => cmd_resume(&args),
        "serve" => cmd_serve(&args, &knobs),
        "exp" => cmd_exp(&args),
        "eval" => cmd_eval(&args),
        "info" => cmd_info(),
        "help" | "" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{USAGE}"),
    };
    if let Some(path) = &trace_out {
        let n = blockllm::obs::export::write_trace(std::path::Path::new(path))?;
        eprintln!("trace: {n} events -> {path} (load in chrome://tracing or ui.perfetto.dev)");
    }
    out
}

fn cfg_from_args(args: &Args) -> Result<TrainConfig> {
    let mut cfg = TrainConfig::default();
    for (k, v) in &args.kv {
        // non-config keys: checkpoint/session paths, experiment id,
        // serve-spec paths, kernel knobs
        if k == "ckpt"
            || k == "save"
            || k == "id"
            || k == "session"
            || k == "suspend-at"
            || k == "spec"
            || k == "slice"
            || k == "sched"
            || k == "watch-spec"
            || k == "out"
            || k == "threads"
            || k == "pack-min"
            || k == "par-min"
            || k == "attn-batched"
            || k == "grad-stream"
            || k == "pool"
            || k == "replicas"
            || k == "trace"
            || k == "trace-out"
        {
            continue;
        }
        cfg.set(k, v)?;
    }
    Ok(cfg)
}

fn print_run_summary(res: &RunResult) {
    println!(
        "\n{} [{} backend]: {} steps | final train loss {:.4} | eval loss {:.4} | metric {:.4}",
        res.method,
        res.backend,
        res.train_losses.len(),
        res.final_train_loss,
        res.final_eval_loss(),
        res.final_metric()
    );
    println!(
        "peak modeled memory {} | wall {:.1}s ({:.2} steps/s, {:.0}% in backend exec)",
        human_bytes(res.peak_mem_bytes),
        res.wall_secs,
        res.steps_per_sec,
        100.0 * res.exec_secs / res.wall_secs.max(1e-9)
    );
    let [up, ex, dl, st] = res.phase_secs;
    println!(
        "phase breakdown: upload {up:.2}s | execute {ex:.2}s | \
         grad-download {dl:.2}s | strategy {st:.2}s"
    );
    for (k, v) in &res.telemetry {
        println!("  {k} = {v}");
    }
}

/// One line of raw loss bits (f64 → hex), the thing CI diffs to prove a
/// suspended-and-resumed run matches its uninterrupted twin bit for bit.
fn print_loss_bits(losses: &[f64]) {
    let bits: Vec<String> = losses.iter().map(|l| format!("{:016x}", l.to_bits())).collect();
    println!("train_loss_bits: {}", bits.join(","));
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = cfg_from_args(args)?;
    let warm = match args.get("ckpt") {
        Some(p) => Some(blockllm::model::ParamStore::load(std::path::Path::new(p))?),
        None => None,
    };
    println!("config: {}", cfg.to_json().to_string());
    if let Some(v) = args.get("suspend-at") {
        let n: usize =
            v.parse().map_err(|_| anyhow!("--suspend-at wants a step count, got {v:?}"))?;
        let path = args
            .get("session")
            .ok_or_else(|| anyhow!("--suspend-at needs --session <path> for the checkpoint"))?;
        let mut sess = Session::new(&cfg, warm.as_ref())?;
        sess.run_steps(n)?;
        let bytes = sess.suspend();
        std::fs::write(path, &bytes)?;
        println!(
            "suspended at step {}/{} -> {path} ({} bytes)",
            sess.step(),
            sess.target_steps(),
            bytes.len()
        );
        print_loss_bits(sess.train_losses());
        return Ok(());
    }
    let (res, store) =
        blockllm::experiments::common::run_config_with_params(&cfg, warm.as_ref())?;
    print_run_summary(&res);
    print_loss_bits(&res.train_losses);
    if let Some(path) = args.get("save") {
        store.save(std::path::Path::new(path))?;
        println!("checkpoint saved to {path}");
    }
    Ok(())
}

fn cmd_resume(args: &Args) -> Result<()> {
    let path = args.get("session").ok_or_else(|| anyhow!("resume needs --session <path>"))?;
    let bytes = std::fs::read(path)?;
    let mut sess = Session::resume(&bytes)?;
    println!(
        "resumed {path} at step {}/{} (config: {})",
        sess.step(),
        sess.target_steps(),
        sess.cfg().to_json().to_string()
    );
    sess.run_to_completion()?;
    let (res, store) = sess.finish()?;
    print_run_summary(&res);
    print_loss_bits(&res.train_losses);
    if let Some(p) = args.get("save") {
        store.save(std::path::Path::new(p))?;
        println!("checkpoint saved to {p}");
    }
    Ok(())
}

fn serve_outcome_json(o: &ServeOutcome) -> Json {
    let s = &o.sched;
    let sched = Json::obj(vec![
        ("policy", Json::str(&s.policy)),
        ("weight", Json::num(s.weight as f64)),
        ("deadline", s.deadline.map_or(Json::Null, |d| Json::num(d as f64))),
        ("turns", Json::num(s.turns as f64)),
        ("steps", Json::num(s.steps as f64)),
        ("preemptions", Json::num(s.preemptions as f64)),
        ("evictions", Json::num(s.evictions as f64)),
        ("readmissions", Json::num(s.readmissions as f64)),
        ("finished_clock", s.finished_clock.map_or(Json::Null, |c| Json::num(c as f64))),
        ("final_slack", s.final_slack.map_or(Json::Null, |v| Json::num(v as f64))),
        ("missed_deadline", Json::Bool(s.missed_deadline)),
    ]);
    let result = match &o.result {
        Some(r) => Json::obj(vec![
            ("method", Json::str(&r.method)),
            ("backend", Json::str(&r.backend)),
            ("steps", Json::num(r.train_losses.len() as f64)),
            ("final_train_loss", Json::num(r.final_train_loss)),
            ("final_eval_loss", Json::num(r.final_eval_loss())),
            ("final_metric", Json::num(r.final_metric())),
            ("peak_mem_bytes", Json::num(r.peak_mem_bytes as f64)),
            ("peak_grad_bytes", Json::num(r.peak_grad_bytes as f64)),
            ("state_shard_bytes", Json::num(r.state_shard_bytes as f64)),
            ("train_losses", Json::Arr(r.train_losses.iter().map(|&l| Json::num(l)).collect())),
        ]),
        None => Json::Null,
    };
    Json::obj(vec![
        ("name", Json::str(&o.name)),
        ("admitted", Json::Bool(o.admitted)),
        (
            "fate",
            match &o.fate {
                Some(f) => Json::str(f),
                None => Json::Null,
            },
        ),
        ("sched", sched),
        ("result", result),
    ])
}

/// Between-turns poll of a watched spec file: on a content change, parse
/// and inject new tenants. Parse or shape errors are warnings — a running
/// roster must not die because an operator saved a half-edited file.
fn poll_watched_spec(lp: &mut ServeLoop<'_>, watch: &str, last: &mut String) {
    let cur = match std::fs::read_to_string(watch) {
        Ok(cur) => cur,
        Err(_) => return,
    };
    if cur == *last {
        return;
    }
    *last = cur.clone();
    match ServeSpec::parse(&cur) {
        Ok(new_spec) => match lp.refresh_spec(&new_spec) {
            Ok(n) if n > 0 => println!("[serve] spec refresh admitted {n} session(s)"),
            Ok(_) => {}
            Err(e) => eprintln!("[serve] spec refresh failed: {e:#}"),
        },
        Err(e) => eprintln!("[serve] ignoring unparsable spec update: {e:#}"),
    }
}

fn cmd_serve(args: &Args, knobs: &KnobOverrides) -> Result<()> {
    let path = args.get("spec").ok_or_else(|| anyhow!("serve needs --spec <path>"))?;
    let src = std::fs::read_to_string(path)?;
    let mut spec = ServeSpec::parse(&src)?;
    if let Some(v) = args.get("slice") {
        let k: usize = v.parse().map_err(|_| anyhow!("--slice wants a step count, got {v:?}"))?;
        if k == 0 {
            bail!("--slice must be >= 1");
        }
        spec.slice_steps = k;
    }
    if let Some(v) = args.get("sched") {
        spec.policy = SchedPolicy::parse(v)?;
    }
    println!(
        "serving {} sessions, {} steps per slice, policy {}",
        spec.sessions.len(),
        spec.slice_steps,
        spec.policy.name()
    );
    let knobs = *knobs;
    let rearm = move || knobs.apply();
    let mut lp = ServeLoop::new(&spec, &rearm)?;
    if args.flag("plan") {
        // dry run: report modeled footprints + planned budgets and exit —
        // the numbers an operator needs to size total_budget_mb
        for line in lp.plan_lines() {
            println!("{line}");
        }
        return Ok(());
    }
    match args.get("watch-spec") {
        Some(w) => {
            let watch = w.to_string();
            let mut last = src.clone();
            loop {
                let progressed = lp.turn()?;
                poll_watched_spec(&mut lp, &watch, &mut last);
                if progressed {
                    continue;
                }
                // idle: a refresh may have just injected runnable work;
                // otherwise give up one queued tenant (frees its share)
                // and keep draining until the roster is empty
                if lp.turn()? {
                    continue;
                }
                if !lp.abandon_one_waiting() {
                    break;
                }
            }
        }
        None => lp.run()?,
    }
    let outcomes = lp.finish();
    for o in &outcomes {
        match (&o.result, &o.fate) {
            (Some(r), _) => println!(
                "{:20} done: final train loss {:.4} | eval loss {:.4} | peak grad {}",
                o.name,
                r.final_train_loss,
                r.final_eval_loss(),
                human_bytes(r.peak_grad_bytes)
            ),
            (None, Some(f)) => println!("{:20} {}", o.name, f),
            (None, None) => println!("{:20} (no result)", o.name),
        }
        let s = &o.sched;
        let deadline_note = match (s.deadline, s.final_slack) {
            (Some(d), Some(slack)) => format!(
                " | deadline {d} slack {slack}{}",
                if s.missed_deadline { " MISSED" } else { "" }
            ),
            _ => String::new(),
        };
        println!(
            "{:20} sched[{}]: turns {} | steps {} | preempt {} | evict {} | readmit {}{}",
            o.name,
            s.policy,
            s.turns,
            s.steps,
            s.preemptions,
            s.evictions,
            s.readmissions,
            deadline_note
        );
        if let Some(r) = &o.result {
            print_loss_bits(&r.train_losses);
        }
    }
    if let Some(dir) = args.get("out") {
        let dir = std::path::Path::new(dir);
        std::fs::create_dir_all(dir)?;
        for o in &outcomes {
            let report = dir.join(format!("{}.json", o.name));
            std::fs::write(report, serve_outcome_json(o).to_string())?;
            if let Some(ckpt) = &o.checkpoint {
                let p = dir.join(format!("{}.session", o.name));
                std::fs::write(&p, ckpt)?;
                println!("evicted session checkpoint -> {}", p.display());
            }
        }
        println!("per-session reports written to {}", dir.display());
    }
    Ok(())
}

fn cmd_exp(args: &Args) -> Result<()> {
    let quick = args.flag("quick");
    if args.flag("all") {
        for id in experiments::ALL_IDS {
            println!("\n######## experiment {id} ########");
            experiments::run(id, quick)?;
        }
        return Ok(());
    }
    let id = args
        .get("id")
        .ok_or_else(|| anyhow::anyhow!("exp needs --id <experiment> or --all\n{USAGE}"))?;
    experiments::run(id, quick)
}

fn cmd_eval(args: &Args) -> Result<()> {
    let cfg = cfg_from_args(args)?;
    let ckpt = args
        .get("ckpt")
        .ok_or_else(|| anyhow!("eval needs --ckpt <path>"))?;
    let store = blockllm::model::ParamStore::load(std::path::Path::new(ckpt))?;
    // the task -> eval-stream mapping lives in session::TaskData, shared
    // with the train driver and the serve scheduler
    let mut sess = Session::new(&cfg, Some(&store))?;
    let ev = sess.eval_now()?;
    println!("eval loss {:.4} | metric {:.4}", ev.loss, ev.metric);
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("presets (native registry):");
    for p in &blockllm::config::presets::PRESETS {
        println!(
            "  {:6} d={} L={} h={} ff={} params={}",
            p.name,
            p.d_model,
            p.n_layers,
            p.n_heads,
            p.d_ff,
            p.param_count()
        );
    }
    match Runtime::open_default() {
        Ok(rt) => {
            println!("artifacts (PJRT backend available):");
            for (id, a) in &rt.manifest.artifacts {
                println!("  {id:40} kind={:12} pallas={}", a.kind, a.pallas);
            }
        }
        Err(e) => {
            println!("artifacts: none usable ({e})");
            println!("  -> runs fall back to the pure-Rust native backend (--backend native)");
        }
    }
    Ok(())
}
