//! Fig. 1 / Fig. 5 — large-scale finetuning comparison, and Fig. 7-left —
//! the BlockLLM-SubOPT selection ablation.
//!
//! Paper workload: LLaMA-2 7B + Alpaca via Llama-factory on one H100; ours:
//! the `tiny` preset warm-started from a C4-sim checkpoint, finetuned on
//! Alpaca-sim (DESIGN.md §5). Hyperparameters follow App. A.6: BlockLLM
//! s=0.95, m=100; LoRA r=8; GaLore r=8; BAdam K=100; cosine LR to 0.
//!
//! Expected shape (paper Fig. 5): BlockLLM reaches the lowest train/eval
//! loss at the lowest peak memory; BAdam ~ BlockLLM in wall time; GaLore and
//! LoRA slower per step.

use anyhow::Result;

use super::common::{print_table, pretrained_checkpoint, run_config, save_json, sparkline};
use crate::config::{Method, Task, TrainConfig};
use crate::util::json::Json;

fn base_cfg(quick: bool) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.preset = if quick { "micro" } else { "tiny" }.into();
    cfg.task = Task::AlpacaFinetune;
    cfg.steps = if quick { 60 } else { 200 };
    cfg.eval_every = if quick { 20 } else { 50 };
    cfg.eval_batches = 4;
    cfg.lr = 1e-3;
    cfg.sparsity = 0.95;
    cfg.patience = 100;
    cfg.rank = 8;
    cfg.badam_k = 100;
    cfg.seed = 42;
    cfg
}

pub fn run_fig1_fig5(quick: bool) -> Result<()> {
    let cfg0 = base_cfg(quick);
    let warm = pretrained_checkpoint(&cfg0.preset, if quick { 40 } else { 150 }, 7)?;

    let methods = [Method::BlockLlm, Method::LoRa, Method::BAdam, Method::GaLore];
    let mut rows = Vec::new();
    let mut curves = Vec::new();
    let mut records = Vec::new();
    for m in methods {
        let mut cfg = cfg0.clone();
        cfg.method = m;
        println!("[fig5] {} ...", m.name());
        let res = run_config(&cfg, Some(&warm))?;
        println!(
            "  [{}] train loss {}  (final {:.4})",
            res.backend,
            sparkline(&res.train_losses, 40),
            res.final_train_loss
        );
        rows.push(vec![
            m.name().to_string(),
            format!("{:.4}", res.tail_train_loss(10)),
            format!("{:.4}", res.final_eval_loss()),
            super::common::fmt_mb(res.peak_mem_bytes),
            format!("{:.1}", res.wall_secs),
            format!("{:.2}", res.steps_per_sec),
        ]);
        records.push(Json::obj(vec![
            ("method", Json::str(m.name())),
            ("backend", Json::str(res.backend.clone())),
            ("train_losses", Json::arr_f64(&res.train_losses)),
            (
                "evals",
                Json::Arr(
                    res.evals
                        .iter()
                        .map(|e| {
                            Json::obj(vec![
                                ("step", Json::num(e.step as f64)),
                                ("loss", Json::num(e.loss)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("peak_mem_bytes", Json::num(res.peak_mem_bytes as f64)),
            ("wall_secs", Json::num(res.wall_secs)),
        ]));
        curves.push((m.name(), res));
    }

    print_table(
        "Fig 1 / Fig 5 — Alpaca-sim finetune (paper: LLaMA-2 7B + Alpaca)",
        &["method", "train loss", "eval loss", "peak mem (MB)", "time (s)", "steps/s"],
        &rows,
    );
    println!(
        "shape check (paper): blockllm lowest loss+memory; badam≈blockllm time; galore/lora slower"
    );
    save_json("fig5_finetune", &Json::Arr(records))?;

    // Fig. 1 is the scatter summary of the same runs
    let fig1: Vec<Json> = curves
        .iter()
        .map(|(name, r)| {
            Json::obj(vec![
                ("method", Json::str(*name)),
                ("eval_loss", Json::num(r.final_eval_loss())),
                ("mem_mb", Json::num(r.peak_mem_bytes as f64 / 1e6)),
                ("time_s", Json::num(r.wall_secs)),
            ])
        })
        .collect();
    save_json("fig1_summary", &Json::Arr(fig1))?;
    Ok(())
}

/// Fig. 7-left: BlockLLM vs BlockLLM-SubOPT (smallest-gradient selection)
/// on the finetune workload; Fig. 7-right handled by pretrain::fig9-style
/// harness but included here for the finetune side.
pub fn run_fig7_ablation(quick: bool) -> Result<()> {
    let cfg0 = base_cfg(quick);
    let warm = pretrained_checkpoint(&cfg0.preset, if quick { 40 } else { 150 }, 7)?;

    // left panel: selection direction
    let mut rows = Vec::new();
    let mut rec = Vec::new();
    for m in [Method::BlockLlm, Method::BlockLlmSubOpt] {
        let mut cfg = cfg0.clone();
        cfg.method = m;
        println!("[fig7-left] {} ...", m.name());
        let res = run_config(&cfg, Some(&warm))?;
        println!("  {}", sparkline(&res.train_losses, 40));
        rows.push(vec![
            m.name().to_string(),
            format!("{:.4}", res.tail_train_loss(10)),
            format!("{:.4}", res.final_eval_loss()),
        ]);
        rec.push(Json::obj(vec![
            ("method", Json::str(m.name())),
            ("train_losses", Json::arr_f64(&res.train_losses)),
        ]));
    }
    print_table(
        "Fig 7 (left) — selection criterion ablation (Alpaca-sim)",
        &["method", "train loss", "eval loss"],
        &rows,
    );
    println!("shape check (paper): subopt converges visibly slower / higher");

    // right panel: visit-frequency ablation on the pretraining workload
    let mut rows2 = Vec::new();
    for m in [Method::BlockLlm, Method::BlockLlmNoFreq] {
        let mut cfg = cfg0.clone();
        cfg.preset = "micro".into();
        cfg.task = Task::C4Pretrain;
        cfg.method = m;
        cfg.lr = 1e-3;
        cfg.sparsity = 0.5;
        cfg.patience = if quick { 10 } else { 50 };
        cfg.steps = if quick { 60 } else { 200 };
        println!("[fig7-right] {} ...", m.name());
        let res = run_config(&cfg, None)?;
        println!("  {}", sparkline(&res.train_losses, 40));
        let early: f64 = res.train_losses.iter().take(res.train_losses.len() / 3).sum::<f64>()
            / (res.train_losses.len() / 3).max(1) as f64;
        rows2.push(vec![
            m.name().to_string(),
            format!("{:.4}", early),
            format!("{:.4}", res.tail_train_loss(10)),
            format!("{:.3}", res.final_metric()),
        ]);
        rec.push(Json::obj(vec![
            ("method", Json::str(m.name())),
            ("train_losses", Json::arr_f64(&res.train_losses)),
        ]));
    }
    print_table(
        "Fig 7 (right) — layer-visit-frequency ablation (C4-sim pretrain)",
        &["method", "early loss", "late loss", "final ppl"],
        &rows2,
    );
    println!("shape check (paper): no-freq higher loss early, gap narrows late");
    save_json("fig7_ablation", &Json::Arr(rec))?;
    Ok(())
}
