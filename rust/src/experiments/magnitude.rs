//! The §2 analysis suite: Table 2 (sparsity-accuracy trade-off), Fig. 3/8
//! (weight-change histograms), Tables 3/4/5 (adaptive reduced-parameter
//! training: update frequency m vs unique-update fraction q).
//!
//! Paper protocol: DistilBERT pretrained on IMDb, magnitude-pruned, then
//! finetuned on GLUE-CoLA (a domain shift). Ours: the `nano` classifier
//! pretrained on sst2-sim at vocab offset 0, finetuned on the shifted task
//! (DESIGN.md §5 "DistilBERT+IMDb->CoLA" row).

use anyhow::Result;

use super::common::{fmt_mb, pretrained_cls_checkpoint, print_table, save_json};
use crate::config::{Method, Task, TrainConfig};
use crate::data::gluesim::GlueSim;
use crate::metrics::{matthews_corr, spearman_corr, Histogram};
use crate::trainer::{RunResult, Trainer};
use crate::util::json::Json;

const SHIFT_OFFSET: i32 = 48;

/// Finetune the warm-started classifier on the shifted target task with a
/// given strategy config; returns the result and final params.
fn finetune_shifted(
    cfg: &TrainConfig,
    warm: &crate::model::ParamStore,
    target_task: usize,
) -> Result<(RunResult, crate::model::ParamStore)> {
    let mut tr = Trainer::open(cfg.clone(), Some(warm))?;
    let mut src = GlueSim::new(target_task, cfg.seed).with_offset(SHIFT_OFFSET);
    let res = tr.train_cls(&mut src)?;
    Ok((res, tr.store))
}

fn base_cfg(quick: bool, steps: usize) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.preset = "nano".into();
    cfg.task = Task::DomainShift; // resolves the cls artifact
    cfg.method = Method::Magnitude;
    cfg.steps = if quick { steps.min(40) } else { steps };
    cfg.eval_every = 0;
    cfg.eval_batches = 16;
    cfg.lr = 3e-4;
    cfg.cosine_lr = true;
    cfg.seed = 42;
    cfg
}

/// Table 2: magnitude pruning at fixed sparsity levels.
pub fn run_table2(quick: bool) -> Result<()> {
    let warm = pretrained_cls_checkpoint("nano", if quick { 60 } else { 200 }, 9)?;

    // source-task accuracy before / after the shift (the paper's 92% -> 48%)
    {
        let mut cfg = base_cfg(quick, 0);
        cfg.steps = 1;
        cfg.lr = 0.0;
        let mut tr = Trainer::open(cfg.clone(), Some(&warm))?;
        let mut src_a = GlueSim::new(4, cfg.seed);
        let ev_a = tr.eval_cls(&mut src_a)?;
        let mut src_b = GlueSim::new(1, cfg.seed).with_offset(SHIFT_OFFSET);
        let ev_b = tr.eval_cls(&mut src_b)?;
        println!(
            "[table2] source-task acc {:.1}% -> shifted-task zero-shot acc {:.1}% (paper: 92.0 -> 47.7)",
            ev_a.metric * 100.0,
            ev_b.metric * 100.0
        );
    }

    let levels: &[f64] = if quick { &[0.0, 0.5, 0.9] } else { &[0.0, 0.5, 0.6, 0.7, 0.8, 0.9] };
    let mut rows = Vec::new();
    let mut rec = Vec::new();
    for &s in levels {
        let mut cfg = base_cfg(quick, 150);
        cfg.sparsity = s;
        cfg.mag_update_every = 0; // Table 2: selection fixed from W^0
        if s == 0.0 {
            cfg.method = Method::FullAdam; // s=0 row is plain finetuning
        }
        println!("[table2] s={s} ...");
        let (res, _) = finetune_shifted(&cfg, &warm, 1)?;
        rows.push(vec![format!("{s:.1}"), format!("{:.2}", res.final_metric() * 100.0)]);
        rec.push(Json::obj(vec![
            ("sparsity", Json::num(s)),
            ("accuracy", Json::num(res.final_metric() * 100.0)),
        ]));
    }
    print_table("Table 2 — pruned-finetune accuracy vs sparsity (paper: DistilBERT IMDb->CoLA)",
        &["sparsity", "accuracy"], &rows);
    println!("shape check (paper): mild drop to s=0.5, cliff by s=0.7, flat after");
    save_json("table2_magnitude", &Json::Arr(rec))?;
    Ok(())
}

/// Fig. 3 / Fig. 8: histograms of the weight changes during the shifted
/// finetune — most |δ| are tiny; changed weights are low-magnitude.
pub fn run_fig3_histograms(quick: bool) -> Result<()> {
    let warm = pretrained_cls_checkpoint("nano", if quick { 60 } else { 200 }, 9)?;
    let mut cfg = base_cfg(quick, 200);
    cfg.sparsity = 0.7; // the paper's Fig. 8 setting
    cfg.mag_update_every = 0;
    println!("[fig3] finetuning s=0.7 for histogram capture ...");
    // snapshot W^0 (post warm start, pre finetune)
    let tr = Trainer::open(cfg.clone(), Some(&warm))?;
    let w0 = tr.store.clone_store();
    drop(tr);
    let (_res, wt) = finetune_shifted(&cfg, &warm, 1)?;

    let eta = 1e-4; // change threshold (paper uses 1e-3 at DistilBERT scale)
    let mut h_mag = Histogram::new(0.0, 0.5, 20); // |w^t| of changed params
    let mut h_delta = Histogram::new(0.0, 2e-3, 20); // δ distribution
    let mut changed = 0u64;
    let mut total = 0u64;
    for (a, b) in w0.bufs.iter().zip(&wt.bufs) {
        for (&x0, &x1) in a.iter().zip(b) {
            let d = (x0 - x1).abs() as f64;
            total += 1;
            h_delta.add(d);
            if d > eta {
                changed += 1;
                h_mag.add(x1.abs() as f64);
            }
        }
    }
    println!("\n== Fig 3a — |w^t| of parameters with δ > {eta} ({changed}/{total} changed) ==");
    print!("{}", h_mag.render(50));
    println!("\n== Fig 3b — distribution of δ = |w^0 - w^t| ==");
    print!("{}", h_delta.render(50));
    println!("shape check (paper): δ mass concentrated near zero; changed weights skew low-magnitude");

    save_json(
        "fig3_histograms",
        &Json::obj(vec![
            ("changed", Json::num(changed as f64)),
            ("total", Json::num(total as f64)),
            ("hist_mag", h_mag.to_json()),
            ("hist_delta", h_delta.to_json()),
        ]),
    )?;
    Ok(())
}

/// Tables 3/4/5: adaptive selection — vary (1-s) and update period m, track
/// q (unique-update fraction), score, and modeled VRAM.
/// `which`: 0 = Table 3 (CoLA-sim / accuracy+Matthews), 1 = Table 4
/// (STS-B-sim / Spearman), 2 = Table 5 (SST2-sim / accuracy+VRAM).
pub fn run_table3_5(which: usize, quick: bool) -> Result<()> {
    let warm = pretrained_cls_checkpoint("nano", if quick { 60 } else { 200 }, 9)?;

    let (title, target_task, combos): (&str, usize, Vec<(f64, usize)>) = match which {
        0 => (
            "Table 3 — update frequency & sparsity on CoLA-sim",
            1,
            vec![(0.1, 50), (0.02, 50), (0.02, 100), (0.02, 200)],
        ),
        1 => (
            "Table 4 — update frequency & sparsity on STSB-sim",
            2,
            vec![(0.01, 100), (0.01, 200)],
        ),
        _ => (
            "Table 5 — update frequency, sparsity & VRAM on SST2-sim",
            4,
            vec![(0.008, 60), (0.01, 80), (0.02, 50), (0.02, 100)],
        ),
    };

    // Regression needs the reg artifact; only nano_reg exists -> fine.
    let mut rows = Vec::new();
    let mut rec = Vec::new();
    for (one_minus_s, m) in combos {
        let mut cfg = base_cfg(quick, 250);
        cfg.sparsity = 1.0 - one_minus_s;
        cfg.mag_update_every = m.min(cfg.steps.saturating_sub(1)).max(1);
        if which == 1 {
            // STS-B-sim is a regression task -> reg head artifact
            cfg.task = Task::Glue(2);
        }
        println!("[{title}] 1-s={one_minus_s} m={m} ...");
        let (res, _) = if which == 1 {
            // regression target uses its own generator (no shift offset:
            // Table 4 in the paper is plain STS-B finetuning on a
            // pretrained trunk — warm-start the trunk, fresh reg head)
            cfg.lr = 1e-3;
            let mut tr = Trainer::open(cfg.clone(), Some(&warm))?;
            let mut src = GlueSim::new(2, cfg.seed);
            let r = tr.train_cls(&mut src)?;
            (r, tr.store)
        } else {
            finetune_shifted(&cfg, &warm, target_task)?
        };
        let q = res.telem("unique_updated_frac").unwrap_or(f64::NAN);
        let last = res.evals.last().expect("eval");
        let score = match which {
            0 => {
                let preds: Vec<u32> = last.preds.iter().map(|&p| p as u32).collect();
                let labels: Vec<u32> = last.labels.iter().map(|&l| l as u32).collect();
                format!(
                    "{:.2} / {:.4}",
                    res.final_metric() * 100.0,
                    matthews_corr(&preds, &labels)
                )
            }
            1 => format!("{:.2}", spearman_corr(&last.preds, &last.labels) * 100.0),
            _ => format!("{:.2}", res.final_metric() * 100.0),
        };
        let mut row = vec![
            format!("{one_minus_s}"),
            format!("{q:.3}"),
            format!("{m}"),
            score.clone(),
        ];
        if which == 2 {
            row.push(fmt_mb(res.peak_mem_bytes));
        }
        rows.push(row);
        rec.push(Json::obj(vec![
            ("one_minus_s", Json::num(one_minus_s)),
            ("m", Json::num(m as f64)),
            ("q", Json::num(q)),
            ("score", Json::str(score)),
            ("mem_bytes", Json::num(res.peak_mem_bytes as f64)),
        ]));
    }

    let headers: Vec<&str> = if which == 2 {
        vec!["1-s", "q", "m", "accuracy", "VRAM (MB)"]
    } else if which == 1 {
        vec!["1-s", "q", "m", "spearman"]
    } else {
        vec!["1-s", "q", "m", "acc / matthews"]
    };
    print_table(title, &headers, &rows);
    println!("shape check (paper): lower s or smaller m -> larger q; extreme m degrades score");
    save_json(&format!("table{}_reduced_param", which + 3), &Json::Arr(rec))?;
    Ok(())
}
