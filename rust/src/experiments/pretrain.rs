//! Table 1 — pretraining perplexity/memory ladder vs GaLore; Fig. 6 —
//! sparsity sweep; Fig. 9 — patience ablation.
//!
//! Paper workload: LLaMA 60M/130M/350M on C4, BlockLLM s=0.5, m=50, cosine
//! LR to 10%, GaLore with 10% warmup (App. A.7). Ours: the nano/micro/tiny
//! preset ladder on C4-sim (DESIGN.md §5).
//!
//! Expected shape (paper Table 1 / Fig. 6): BlockLLM's perplexity ≈ GaLore's
//! at visibly lower memory on every rung; higher sparsity trades more steps
//! for less memory.

use anyhow::Result;

use super::common::{fmt_mb, print_table, run_config, save_json, sparkline};
use crate::config::{presets, Method, Task, TrainConfig};
use crate::util::json::Json;

fn base_cfg(preset: &str, quick: bool) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.preset = preset.into();
    cfg.task = Task::C4Pretrain;
    cfg.steps = if quick { 60 } else { 300 };
    cfg.eval_every = 0; // final eval only; curves come from train loss
    cfg.eval_batches = 8;
    cfg.lr = 1e-3; // paper App. A.7
    cfg.sparsity = 0.5;
    cfg.patience = 50;
    cfg.seed = 42;
    cfg
}

/// Table 1: the model-size ladder. nano/micro/tiny stand in for 60/130/350M.
pub fn run_table1(quick: bool) -> Result<()> {
    let ladder: &[(&str, &str)] =
        &[("nano", "60M"), ("micro", "130M"), ("tiny", "350M")];
    let ladder = if quick { &ladder[..2] } else { ladder };

    let mut rows = Vec::new();
    let mut rec = Vec::new();
    for (preset, paper_size) in ladder {
        for method in [Method::BlockLlm, Method::GaLore] {
            let mut cfg = base_cfg(preset, quick);
            cfg.method = method;
            if method == Method::GaLore {
                cfg.warmup_frac = 0.1; // paper: GaLore warms up 10%
                let d = presets::get(preset).expect("ladder preset").d_model;
                cfg.rank = (d / 4).max(4); // paper uses rank ~ d/4 for pretraining
            }
            println!("[table1] {preset} ({paper_size}) {} ...", method.name());
            let res = run_config(&cfg, None)?;
            println!("  [{}] {}", res.backend, sparkline(&res.train_losses, 40));
            rows.push(vec![
                format!("{preset} (paper {paper_size})"),
                method.name().into(),
                format!("{:.2}", res.final_metric()),
                fmt_mb(res.peak_mem_bytes),
                format!("{:.1}", res.wall_secs),
            ]);
            rec.push(Json::obj(vec![
                ("preset", Json::str(*preset)),
                ("method", Json::str(method.name())),
                ("backend", Json::str(res.backend.clone())),
                ("perplexity", Json::num(res.final_metric())),
                ("mem_bytes", Json::num(res.peak_mem_bytes as f64)),
                ("train_losses", Json::arr_f64(&res.train_losses)),
            ]));
        }
    }
    print_table(
        "Table 1 — C4-sim pretraining ladder (paper: LLaMA 60M/130M/350M on C4)",
        &["model", "method", "perplexity", "peak mem (MB)", "time (s)"],
        &rows,
    );
    println!("shape check (paper): blockllm ppl ≈ galore ppl, at lower memory on every rung");
    save_json("table1_pretrain", &Json::Arr(rec))?;
    Ok(())
}

/// Fig. 6: sparsity sweep s ∈ {0.5, 0.7, 0.9} vs GaLore on one model.
pub fn run_fig6_sparsity(quick: bool) -> Result<()> {
    let preset = if quick { "nano" } else { "micro" };
    let mut rows = Vec::new();
    let mut rec = Vec::new();

    for s in [0.5, 0.7, 0.9] {
        let mut cfg = base_cfg(preset, quick);
        cfg.sparsity = s;
        println!("[fig6] blockllm s={s} ...");
        let res = run_config(&cfg, None)?;
        println!("  {}", sparkline(&res.train_losses, 40));
        rows.push(vec![
            format!("blockllm s={s}"),
            format!("{:.2}", res.final_metric()),
            fmt_mb(res.peak_mem_bytes),
        ]);
        rec.push(Json::obj(vec![
            ("method", Json::str(format!("blockllm-s{s}"))),
            ("perplexity", Json::num(res.final_metric())),
            ("mem_bytes", Json::num(res.peak_mem_bytes as f64)),
            ("train_losses", Json::arr_f64(&res.train_losses)),
        ]));
    }
    let mut cfg = base_cfg(preset, quick);
    cfg.method = Method::GaLore;
    cfg.warmup_frac = 0.1;
    cfg.rank = (presets::get(preset).expect("preset").d_model / 4).max(4);
    println!("[fig6] galore ...");
    let res = run_config(&cfg, None)?;
    rows.push(vec![
        "galore".into(),
        format!("{:.2}", res.final_metric()),
        fmt_mb(res.peak_mem_bytes),
    ]);
    rec.push(Json::obj(vec![
        ("method", Json::str("galore")),
        ("perplexity", Json::num(res.final_metric())),
        ("mem_bytes", Json::num(res.peak_mem_bytes as f64)),
        ("train_losses", Json::arr_f64(&res.train_losses)),
    ]));

    print_table(
        "Fig 6 — sparsity vs perplexity/memory (paper: LLaMA 60M)",
        &["method", "perplexity", "peak mem (MB)"],
        &rows,
    );
    println!("shape check (paper): higher s -> less memory, more steps for the same ppl; blockllm < galore memory");
    save_json("fig6_sparsity", &Json::Arr(rec))?;
    Ok(())
}

/// Fig. 9: patience m ablation — pretraining is m-sensitive, finetuning not.
pub fn run_fig9_patience(quick: bool) -> Result<()> {
    let preset = if quick { "nano" } else { "micro" };
    let ms: &[usize] = if quick { &[5, 50] } else { &[5, 50, 200] };

    let mut rows = Vec::new();
    let mut rec = Vec::new();
    for &task in &[Task::C4Pretrain, Task::AlpacaFinetune] {
        let warm = if matches!(task, Task::AlpacaFinetune) {
            Some(super::common::pretrained_checkpoint(
                preset,
                if quick { 40 } else { 200 },
                7,
            )?)
        } else {
            None
        };
        for &m in ms {
            let mut cfg = base_cfg(preset, quick);
            cfg.task = task;
            cfg.patience = m;
            cfg.steps = if quick { 60 } else { 200 };
            if matches!(task, Task::AlpacaFinetune) {
                cfg.lr = 1e-3;
                cfg.sparsity = 0.95;
            }
            println!("[fig9] {} m={m} ...", cfg.task.name());
            let res = run_config(&cfg, warm.as_ref())?;
            println!("  {}", sparkline(&res.train_losses, 40));
            rows.push(vec![
                cfg.task.name(),
                format!("{m}"),
                format!("{:.4}", res.tail_train_loss(10)),
                format!("{:.3}", res.final_metric()),
            ]);
            rec.push(Json::obj(vec![
                ("task", Json::str(cfg.task.name())),
                ("m", Json::num(m as f64)),
                ("train_losses", Json::arr_f64(&res.train_losses)),
                ("final_metric", Json::num(res.final_metric())),
            ]));
        }
    }
    print_table(
        "Fig 9 — patience (m) ablation",
        &["task", "m", "train loss", "final metric"],
        &rows,
    );
    println!("shape check (paper): small m converges faster in pretraining; finetuning insensitive to m");
    save_json("fig9_patience", &Json::Arr(rec))?;
    Ok(())
}
