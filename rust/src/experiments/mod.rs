//! Experiment harnesses — one per paper table/figure (DESIGN.md §4).
//!
//! Each harness builds the workload the paper used (scaled per DESIGN.md §5),
//! runs every method the paper compares, and prints the same rows/series the
//! paper reports, plus a JSON record under results/.

pub mod common;
pub mod finetune; // fig1 + fig5 (+ fig7-left ablation workload)
pub mod glue; // table7 + table8 (+ fig9-finetune)
pub mod magnitude; // table2 + fig3/fig8 + table3/4/5
pub mod pretrain; // table1 + fig6 (+ fig7-right, fig9-pretrain)

use anyhow::{bail, Result};

/// Registry: experiment id -> runner.
pub fn run(id: &str, quick: bool) -> Result<()> {
    match id {
        "fig1" => finetune::run_fig1_fig5(true),
        "fig5" => finetune::run_fig1_fig5(quick),
        "fig7" => finetune::run_fig7_ablation(quick),
        "table1" => pretrain::run_table1(quick),
        "fig6" => pretrain::run_fig6_sparsity(quick),
        "fig9" => pretrain::run_fig9_patience(quick),
        "table2" => magnitude::run_table2(quick),
        "fig3" | "fig8" => magnitude::run_fig3_histograms(quick),
        "table3" => magnitude::run_table3_5(0, quick),
        "table4" => magnitude::run_table3_5(1, quick),
        "table5" => magnitude::run_table3_5(2, quick),
        "table7" | "table8" => glue::run_table7_table8(quick),
        _ => bail!("unknown experiment id {id:?}; see `blockllm help`"),
    }
}

pub const ALL_IDS: [&str; 12] = [
    "table2", "fig3", "table3", "table4", "table5", "fig1", "fig5", "fig7", "table1", "fig6",
    "fig9", "table7",
];
