//! Tables 7 & 8 — the GLUE suite: VRAM + score for BlockLLM vs GaLore
//! (rank 8 / rank 4) vs full finetuning (FFT).
//!
//! Paper workload: pretrained RoBERTa-base finetuned per GLUE task,
//! BlockLLM s=0.95, m = total_steps/4, per-task LRs (App. A.5). Ours: the
//! `micro` preset warm-started from a C4-sim checkpoint, finetuned on each
//! GLUE-sim task (DESIGN.md §5). Scores: accuracy (most), Matthews (CoLA),
//! Spearman (STS-B) — the GLUE convention the paper's Table 8 follows.
//!
//! Expected shape (paper Tables 7/8): BlockLLM matches-or-beats the
//! baselines' scores with ~13.5% average memory reduction.

use anyhow::Result;

use super::common::{fmt_mb, print_table, pretrained_checkpoint, run_config, save_json};
use crate::config::{Method, Task, TrainConfig};
use crate::data::gluesim::TASK_NAMES;
use crate::metrics::{matthews_corr, spearman_corr};
use crate::trainer::RunResult;
use crate::util::json::Json;

/// Per-task learning rates (paper Table 6, scaled one decade up for our
/// smaller models).
const TASK_LRS: [f64; 8] = [3e-4, 5e-4, 3e-4, 3e-4, 3e-4, 3e-4, 1e-4, 3e-4];

fn score(task: usize, res: &RunResult) -> f64 {
    let last = res.evals.last().expect("eval point");
    match task {
        1 => {
            // CoLA -> Matthews correlation * 100
            let preds: Vec<u32> = last.preds.iter().map(|&p| p as u32).collect();
            let labels: Vec<u32> = last.labels.iter().map(|&l| l as u32).collect();
            matthews_corr(&preds, &labels) * 100.0
        }
        2 => spearman_corr(&last.preds, &last.labels) * 100.0, // STS-B
        _ => last.metric * 100.0,                              // accuracy
    }
}

pub fn run_table7_table8(quick: bool) -> Result<()> {
    let preset = "micro";
    let warm = pretrained_checkpoint(preset, if quick { 40 } else { 200 }, 7)?;

    // (label, method, rank)
    let variants: &[(&str, Method, usize)] = &[
        ("Block-LLM", Method::BlockLlm, 0),
        ("GaLore (rank=8)", Method::GaLore, 8),
        ("GaLore (rank=4)", Method::GaLore, 4),
        ("FFT", Method::FullAdam, 0),
    ];
    let tasks: Vec<usize> = if quick { vec![1, 4] } else { (0..8).collect() };

    // rows keyed [variant][task]
    let mut mem_rows: Vec<Vec<String>> = variants.iter().map(|v| vec![v.0.to_string()]).collect();
    let mut score_rows: Vec<Vec<String>> = variants.iter().map(|v| vec![v.0.to_string()]).collect();
    let mut rec = Vec::new();

    for &task in &tasks {
        // steps scale mildly with paper dataset size
        let size_k = crate::data::gluesim::TASK_SIZES_K[task];
        let steps = if quick {
            40
        } else {
            (80 + (size_k as f64).sqrt() as usize * 4).min(160)
        };
        for (vi, (label, method, rank)) in variants.iter().enumerate() {
            let mut cfg = TrainConfig::default();
            cfg.preset = preset.into();
            cfg.task = Task::Glue(task);
            cfg.method = *method;
            cfg.steps = steps;
            cfg.eval_every = 0;
            cfg.eval_batches = 16;
            cfg.lr = TASK_LRS[task];
            cfg.sparsity = 0.95; // paper App. A.5
            cfg.patience = (steps / 4).max(1); // paper: m = total/4
            if *rank > 0 {
                cfg.rank = *rank;
            }
            println!("[table7/8] {} on {} ({steps} steps) ...", label, TASK_NAMES[task]);
            let res = run_config(&cfg, Some(&warm))?;
            let sc = score(task, &res);
            mem_rows[vi].push(fmt_mb(res.peak_mem_bytes));
            score_rows[vi].push(format!("{sc:.2}"));
            rec.push(Json::obj(vec![
                ("task", Json::str(TASK_NAMES[task])),
                ("method", Json::str(*label)),
                ("score", Json::num(sc)),
                ("mem_bytes", Json::num(res.peak_mem_bytes as f64)),
                ("eval_loss", Json::num(res.final_eval_loss())),
            ]));
        }
    }

    // averages
    for rows in [&mut mem_rows, &mut score_rows] {
        for row in rows.iter_mut() {
            let vals: Vec<f64> = row[1..].iter().filter_map(|c| c.parse().ok()).collect();
            let avg = vals.iter().sum::<f64>() / vals.len().max(1) as f64;
            row.push(format!("{avg:.2}"));
        }
    }

    let mut headers: Vec<&str> = vec![""];
    let names: Vec<&str> = tasks.iter().map(|&t| TASK_NAMES[t]).collect();
    headers.extend(names.iter());
    headers.push("Avg.");
    print_table(
        "Table 7 — peak training memory (MB; paper reports GB for RoBERTa-base)",
        &headers,
        &mem_rows,
    );
    print_table(
        "Table 8 — GLUE-sim scores (acc / Matthews / Spearman × 100)",
        &headers,
        &score_rows,
    );
    println!("shape check (paper): Block-LLM ≥ baseline scores at ~13.5% less memory than FFT/GaLore");
    save_json("table7_table8_glue", &Json::Arr(rec))?;
    Ok(())
}
