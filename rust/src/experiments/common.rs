//! Shared experiment machinery: result persistence, table rendering, run
//! drivers, and the pretrain-checkpoint cache used by finetune experiments.

use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::config::{Task, TrainConfig};
use crate::model::ParamStore;
use crate::session::Session;
use crate::trainer::RunResult;
use crate::util::json::Json;

/// results/ directory at the repo root, found by walking up from cwd to the
/// first directory holding artifacts/manifest.json or a .git. (Not keyed on
/// Cargo.toml: the crate dir rust/ and the vendored crates have their own,
/// which would split the results/checkpoint caches between test and CLI
/// runs.)
pub fn results_dir() -> PathBuf {
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        if dir.join("artifacts").join("manifest.json").exists() || dir.join(".git").exists() {
            return dir.join("results");
        }
        if !dir.pop() {
            return PathBuf::from("results");
        }
    }
}

pub fn save_json(name: &str, v: &Json) -> Result<()> {
    let dir = results_dir();
    std::fs::create_dir_all(&dir)?;
    std::fs::write(dir.join(format!("{name}.json")), v.to_string())?;
    Ok(())
}

/// Render an aligned ASCII table.
pub fn print_table(title: &str, headers: &[&str], rows: &[Vec<String>]) {
    println!("\n== {title} ==");
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, c) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(c.len());
            }
        }
    }
    let line = |cells: Vec<String>| {
        let mut s = String::new();
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!("{:<w$}  ", c, w = widths.get(i).copied().unwrap_or(8)));
        }
        println!("{}", s.trim_end());
    };
    line(headers.iter().map(|s| s.to_string()).collect());
    line(widths.iter().map(|w| "-".repeat(*w)).collect());
    for row in rows {
        line(row.clone());
    }
}

/// ASCII sparkline of a series (the repo's "figure" rendering).
pub fn sparkline(series: &[f64], width: usize) -> String {
    if series.is_empty() {
        return String::new();
    }
    const GLYPHS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let stride = (series.len() as f64 / width as f64).max(1.0);
    let samples: Vec<f64> = (0..series.len().min(width))
        .map(|i| series[(((i as f64) * stride) as usize).min(series.len() - 1)])
        .collect();
    let lo = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    samples
        .iter()
        .map(|&v| {
            let t = if hi > lo { (v - lo) / (hi - lo) } else { 0.5 };
            GLYPHS[((t * 7.0).round() as usize).min(7)]
        })
        .collect()
}

/// Run one config end-to-end on its task's data. The execution backend is
/// resolved per run from `cfg.backend` (auto: PJRT artifacts when present,
/// pure-Rust native engine otherwise). `warm` optionally seeds the trunk.
pub fn run_config(cfg: &TrainConfig, warm: Option<&ParamStore>) -> Result<RunResult> {
    Ok(run_config_with_params(cfg, warm)
        .with_context(|| format!("run for {:?}", cfg.method))?
        .0)
}

/// Like `run_config` but returns the trained parameters too. The run is a
/// `Session` driven to completion in one go — the task → data-stream
/// mapping lives in `session::TaskData`, shared with `eval` and `serve`.
pub fn run_config_with_params(
    cfg: &TrainConfig,
    warm: Option<&ParamStore>,
) -> Result<(RunResult, ParamStore)> {
    let mut sess =
        Session::new(cfg, warm).with_context(|| format!("session for {:?}", cfg.method))?;
    sess.run_to_completion()?;
    sess.finish()
}

/// Pretrain (or load a cached) LM checkpoint for warm starts.
pub fn pretrained_checkpoint(preset: &str, steps: usize, seed: u64) -> Result<ParamStore> {
    let dir = results_dir().join("ckpt");
    let path = dir.join(format!("{preset}_c4_{steps}_{seed}.bin"));
    if path.exists() {
        return ParamStore::load(&path);
    }
    let mut cfg = TrainConfig::default();
    cfg.preset = preset.to_string();
    cfg.task = Task::C4Pretrain;
    cfg.method = crate::config::Method::FullAdam;
    cfg.steps = steps;
    cfg.eval_every = 0;
    cfg.seed = seed;
    cfg.lr = 1e-3;
    println!("[common] pretraining {preset} checkpoint for {steps} steps (cached at {path:?})");
    let (_res, store) = run_config_with_params(&cfg, None)?;
    store.save(&path)?;
    Ok(store)
}

/// Pretrain (or load) a *classifier* checkpoint on the DomainShift source
/// task — the DistilBERT-on-IMDb stand-in for the §2 analyses.
pub fn pretrained_cls_checkpoint(preset: &str, steps: usize, seed: u64) -> Result<ParamStore> {
    let dir = results_dir().join("ckpt");
    let path = dir.join(format!("{preset}_cls_{steps}_{seed}.bin"));
    if path.exists() {
        return ParamStore::load(&path);
    }
    let mut cfg = TrainConfig::default();
    cfg.preset = preset.to_string();
    cfg.task = Task::DomainShift;
    cfg.method = crate::config::Method::FullAdam;
    cfg.steps = steps;
    cfg.eval_every = 0;
    cfg.seed = seed;
    cfg.lr = 3e-4;
    println!("[common] pretraining {preset} classifier checkpoint ({steps} steps)");
    let (_res, store) = run_config_with_params(&cfg, None)?;
    store.save(&path)?;
    Ok(store)
}

pub fn fmt_mb(bytes: u64) -> String {
    format!("{:.2}", bytes as f64 / 1e6)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sparkline_shape() {
        let s = sparkline(&[1.0, 2.0, 3.0, 2.0, 1.0], 5);
        assert_eq!(s.chars().count(), 5);
    }

    #[test]
    fn table_renders_without_panic() {
        print_table(
            "t",
            &["a", "bb"],
            &[vec!["1".into(), "2".into()], vec!["333".into(), "4".into()]],
        );
    }
}
