//! The loss-plateau patience controller (Alg. 1 lines 5-8).
//!
//! Keep a loss history H; once it holds m entries, trigger re-selection
//! whenever the current loss φ_t fails to improve on the mean of the last m
//! losses — then reset H (so selections last at least m further steps).
//! t = 0 always triggers (the initial selection).

use crate::metrics::MovingWindow;

#[derive(Debug)]
pub struct PatienceController {
    window: MovingWindow,
    m: usize,
    /// number of re-selections triggered (telemetry / tests)
    pub triggers: u64,
    started: bool,
}

impl PatienceController {
    pub fn new(m: usize) -> Self {
        PatienceController {
            window: MovingWindow::new(m.max(1)),
            m: m.max(1),
            triggers: 0,
            started: false,
        }
    }

    /// Feed the step loss; returns true if the block should be re-selected.
    pub fn observe(&mut self, loss: f64) -> bool {
        if !self.started {
            // t=0: initial selection, history starts empty afterwards
            self.started = true;
            self.triggers += 1;
            self.window.push(loss);
            return true;
        }
        let trigger = self.window.len() >= self.m && loss >= self.window.mean();
        if trigger {
            self.triggers += 1;
            self.window.clear();
        }
        self.window.push(loss);
        trigger
    }

    pub fn history_len(&self) -> usize {
        self.window.len()
    }

    /// A fresh controller with the same window size — the staging target
    /// for an all-or-nothing `state_load`.
    pub fn new_like(other: &PatienceController) -> PatienceController {
        PatienceController::new(other.m)
    }

    /// Serialize the controller's mutable state (loss history, trigger
    /// count, started flag) under `prefix`. `m` comes from config.
    pub fn state_save(&self, bag: &mut crate::session::state::StateBag, prefix: &str) {
        bag.put_f64s(&format!("{prefix}.hist"), self.window.values().to_vec());
        bag.put_u64(&format!("{prefix}.triggers"), self.triggers);
        bag.put_bool(&format!("{prefix}.started"), self.started);
    }

    /// Restore state written by [`Self::state_save`]. The history is
    /// replayed in insertion order so the window's mean (an ordered f64
    /// sum) reproduces the pre-suspend bits exactly.
    pub fn state_load(
        &mut self,
        bag: &crate::session::state::StateBag,
        prefix: &str,
    ) -> anyhow::Result<()> {
        let hist = bag.f64s(&format!("{prefix}.hist"))?;
        if hist.len() > self.m {
            anyhow::bail!("patience checkpoint has {} losses, window holds {}", hist.len(), self.m);
        }
        let triggers = bag.get_u64(&format!("{prefix}.triggers"))?;
        let started = bag.get_bool(&format!("{prefix}.started"))?;
        self.window.clear();
        for &l in hist {
            self.window.push(l);
        }
        self.triggers = triggers;
        self.started = started;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_step_always_triggers() {
        let mut p = PatienceController::new(5);
        assert!(p.observe(10.0));
        assert!(!p.observe(9.0));
    }

    #[test]
    fn monotone_decrease_never_retriggers() {
        let mut p = PatienceController::new(4);
        p.observe(100.0);
        for i in 1..200 {
            assert!(!p.observe(100.0 - i as f64 * 0.5), "step {i} retriggered");
        }
        assert_eq!(p.triggers, 1);
    }

    #[test]
    fn plateau_triggers_after_m_steps() {
        let mut p = PatienceController::new(3);
        p.observe(5.0); // initial
        assert!(!p.observe(5.0)); // history len 1 < m
        assert!(!p.observe(5.0)); // len 2 < m
        assert!(p.observe(5.0)); // len 3, loss == mean -> trigger
    }

    #[test]
    fn history_resets_after_trigger_giving_m_step_grace() {
        let mut p = PatienceController::new(3);
        p.observe(5.0);
        p.observe(5.0);
        p.observe(5.0);
        assert!(p.observe(5.0)); // trigger, reset
        // grace period: needs m=3 fresh entries before it can trigger again
        assert!(!p.observe(5.0));
        assert!(!p.observe(5.0));
        assert!(p.observe(5.0));
    }

    #[test]
    fn state_roundtrip_resumes_identical_decisions() {
        let mut a = PatienceController::new(3);
        for l in [5.0, 4.9, 4.9, 4.9] {
            a.observe(l);
        }
        let mut bag = crate::session::state::StateBag::new();
        a.state_save(&mut bag, "pat");
        let mut b = PatienceController::new(3);
        b.state_load(&bag, "pat").unwrap();
        assert_eq!(a.triggers, b.triggers);
        assert_eq!(a.history_len(), b.history_len());
        for l in [4.9, 4.9, 4.9, 4.8, 5.1] {
            assert_eq!(a.observe(l), b.observe(l), "decision diverged at loss {l}");
        }
    }

    #[test]
    fn spike_above_mean_triggers() {
        let mut p = PatienceController::new(3);
        p.observe(5.0);
        p.observe(4.0);
        p.observe(3.9);
        p.observe(3.8);
        // mean of last 3 ≈ 3.9; a spike to 6 must trigger
        assert!(p.observe(6.0));
    }
}
