//! Layer scoring: the gradient-norm dictionary + visit frequency.
//!
//! The paper's selection criterion is ||G̃_l|| / f_l where G̃ is the Adam
//! processed gradient and f_l the sum-normalized visit frequency. Computing
//! ||G̃_l|| for every layer would require optimizer state for all layers —
//! exactly what BlockLLM avoids — so the paper samples p extra layers per
//! iteration and keeps their norms in a dictionary (§2.2 "Memory
//! Efficiency"). This module is that dictionary.
//!
//! Processed-gradient caveat (DESIGN.md §6.2): for layers *outside* the
//! active block there is no (M, V) state, so their entries are raw-gradient
//! norms (bias-correction-scaled); for active layers the caller may refresh
//! entries with true processed-gradient norms (`ScorerMode::Adamized`).

use crate::config::NormKind;
use crate::util::rng::Pcg64;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScorerMode {
    /// Raw gradient norms for everything (default; what a fresh Adam state
    /// would yield up to the elementwise normalization).
    Raw,
    /// Active layers use their true processed-gradient norms.
    Adamized,
}

/// Per-layer norm dictionary with staleness tracking and visit counts.
#[derive(Debug, Clone)]
pub struct NormDictionary {
    pub norms: Vec<f64>,
    /// step at which each norm was last refreshed (usize::MAX = never)
    pub last_update: Vec<usize>,
    /// number of times each layer was part of the active selection
    visit_counts: Vec<u64>,
    total_selections: u64,
    norm_kind: NormKind,
    rng: Pcg64,
}

impl NormDictionary {
    pub fn new(n_layers: usize, norm_kind: NormKind, seed: u64) -> Self {
        NormDictionary {
            norms: vec![0.0; n_layers],
            last_update: vec![usize::MAX; n_layers],
            visit_counts: vec![0; n_layers],
            total_selections: 0,
            norm_kind,
            rng: Pcg64::with_stream(seed, 0xD1C7),
        }
    }

    pub fn n_layers(&self) -> usize {
        self.norms.len()
    }

    /// Layers whose norms should be (re)computed this step: the active set
    /// plus p sampled others, preferring never/least-recently scored layers.
    pub fn layers_to_probe(&mut self, active: &[usize], p: usize, step: usize) -> Vec<usize> {
        let n = self.norms.len();
        let mut probe: Vec<usize> = active.to_vec();
        let mut is_active = vec![false; n];
        for &a in active {
            is_active[a] = true;
        }
        // stale-first: sort inactive layers by last_update, break ties randomly
        let mut inactive: Vec<usize> = (0..n).filter(|&l| !is_active[l]).collect();
        self.rng.shuffle(&mut inactive);
        inactive.sort_by_key(|&l| self.last_update[l]); // MAX (never) sorts last
        // pick never-scored first (from the back), else the stalest
        let mut never: Vec<usize> =
            inactive.iter().copied().filter(|&l| self.last_update[l] == usize::MAX).collect();
        let mut picked = Vec::with_capacity(p);
        while picked.len() < p && !never.is_empty() {
            picked.push(never.remove(0));
        }
        for &l in &inactive {
            if picked.len() >= p {
                break;
            }
            if self.last_update[l] != usize::MAX && !picked.contains(&l) {
                picked.push(l);
            }
        }
        let _ = step;
        probe.extend(picked);
        probe
    }

    /// Record a freshly-computed gradient for layer `l` at `step`.
    pub fn record(&mut self, l: usize, grad: &[f32], step: usize) {
        let sq: f64 = grad.iter().map(|&x| (x as f64) * (x as f64)).sum();
        self.record_sq(l, sq, grad.len(), step);
    }

    /// Record from a precomputed Σg² over a `len`-element gradient — the
    /// streaming sinks' reduction (`grads::NormProbeSink` folds the same
    /// ascending-order f64 sum `record` does, so the resulting norm is
    /// bitwise identical to one computed on a materialized vector).
    pub fn record_sq(&mut self, l: usize, sq: f64, len: usize, step: usize) {
        let norm = match self.norm_kind {
            NormKind::Fro => sq.sqrt(),
            NormKind::Rms => (sq / len.max(1) as f64).sqrt(),
        };
        self.norms[l] = norm;
        self.last_update[l] = step;
    }

    /// What [`Self::layers_to_probe`] WOULD return, without advancing the
    /// dictionary's rng or touching staleness state. The streaming trainer
    /// peeks the probe set before the fwd/bwd (to plan dense retention under
    /// grad accumulation); the real call happens after the loss is known —
    /// and only on non-selection steps, exactly as the dense path does — so
    /// the rng consumption sequence stays bitwise identical between paths.
    pub fn peek_layers_to_probe(&self, active: &[usize], p: usize, step: usize) -> Vec<usize> {
        self.clone().layers_to_probe(active, p, step)
    }

    /// Record a precomputed norm (used when the caller already reduced).
    pub fn record_norm(&mut self, l: usize, norm: f64, step: usize) {
        self.norms[l] = norm;
        self.last_update[l] = step;
    }

    /// Mark a selection event: bump visit counts for the chosen layers.
    pub fn mark_selected(&mut self, selected: &[usize]) {
        self.total_selections += 1;
        for &l in selected {
            self.visit_counts[l] += 1;
        }
    }

    /// Laplace-smoothed visit frequency f_l (DESIGN.md §6.4): strictly
    /// positive even at t=0, sums to 1 over layers.
    pub fn visit_freq(&self, l: usize) -> f64 {
        // f_l = (1 + c_l) / (T + |L|): T selection events so far, |L| layers
        let n = self.norms.len() as f64;
        (1.0 + self.visit_counts[l] as f64) / (self.total_selections as f64 + n)
    }

    /// Selection score ||G̃_l|| / f_l (paper §2.2). `use_freq=false` gives
    /// the no-frequency ablation (Fig. 7 right).
    pub fn score(&self, l: usize, use_freq: bool) -> f64 {
        if use_freq {
            self.norms[l] / self.visit_freq(l)
        } else {
            self.norms[l]
        }
    }

    pub fn visit_count(&self, l: usize) -> u64 {
        self.visit_counts[l]
    }

    /// Serialize every mutable field (norms, staleness, visit counts,
    /// selection total, rng position) under `prefix`. `norm_kind` and the
    /// layer count come from config at reconstruction time.
    pub fn state_save(&self, bag: &mut crate::session::state::StateBag, prefix: &str) {
        bag.put_f64s(&format!("{prefix}.norms"), self.norms.clone());
        // usize::MAX ("never scored") survives the u64 round-trip exactly
        bag.put_u64s(
            &format!("{prefix}.last"),
            self.last_update.iter().map(|&s| s as u64).collect(),
        );
        bag.put_u64s(&format!("{prefix}.visits"), self.visit_counts.clone());
        bag.put_u64(&format!("{prefix}.total"), self.total_selections);
        bag.put_u64s(&format!("{prefix}.rng"), self.rng.to_parts().to_vec());
    }

    /// Restore state written by [`Self::state_save`]. Errors leave the
    /// dictionary untouched.
    pub fn state_load(
        &mut self,
        bag: &crate::session::state::StateBag,
        prefix: &str,
    ) -> anyhow::Result<()> {
        let n = self.norms.len();
        let norms = bag.f64s(&format!("{prefix}.norms"))?;
        let last = bag.u64s(&format!("{prefix}.last"))?;
        let visits = bag.u64s(&format!("{prefix}.visits"))?;
        if norms.len() != n || last.len() != n || visits.len() != n {
            anyhow::bail!("scorer checkpoint covers {} layers, model has {n}", norms.len());
        }
        let total = bag.get_u64(&format!("{prefix}.total"))?;
        let rng = bag.u64s(&format!("{prefix}.rng"))?;
        if rng.len() != 4 {
            anyhow::bail!("scorer rng state wants 4 words, checkpoint has {}", rng.len());
        }
        self.norms = norms.to_vec();
        self.last_update = last.iter().map(|&s| s as usize).collect();
        self.visit_counts = visits.to_vec();
        self.total_selections = total;
        self.rng = Pcg64::from_parts([rng[0], rng[1], rng[2], rng[3]]);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dict(n: usize) -> NormDictionary {
        NormDictionary::new(n, NormKind::Rms, 1)
    }

    #[test]
    fn rms_vs_fro_norms() {
        let mut d = NormDictionary::new(2, NormKind::Fro, 1);
        d.record(0, &[3.0, 4.0], 0);
        assert!((d.norms[0] - 5.0).abs() < 1e-9);
        let mut d = dict(2);
        d.record(0, &[3.0, 4.0], 0);
        assert!((d.norms[0] - (12.5f64).sqrt()).abs() < 1e-9);
    }

    #[test]
    fn probe_includes_active_and_p_extras() {
        let mut d = dict(10);
        let probe = d.layers_to_probe(&[2, 3], 3, 0);
        assert!(probe.len() == 5);
        assert!(probe.contains(&2) && probe.contains(&3));
        let extras: Vec<_> = probe.iter().filter(|&&l| l != 2 && l != 3).collect();
        assert_eq!(extras.len(), 3);
    }

    #[test]
    fn probe_prefers_never_scored_layers() {
        let mut d = dict(6);
        for l in [0usize, 1, 2] {
            d.record(l, &[1.0], 5);
        }
        // layers 3,4,5 never scored; p=3 must pick exactly those
        let probe = d.layers_to_probe(&[0], 3, 6);
        let extras: Vec<usize> = probe.into_iter().filter(|&l| l != 0).collect();
        let mut e = extras.clone();
        e.sort_unstable();
        assert_eq!(e, vec![3, 4, 5]);
    }

    #[test]
    fn record_sq_matches_record_bitwise() {
        for kind in [NormKind::Fro, NormKind::Rms] {
            let mut a = NormDictionary::new(1, kind, 1);
            let mut b = NormDictionary::new(1, kind, 1);
            let g = [0.3f32, -1.7, 0.0, 4.2, -0.001];
            a.record(0, &g, 3);
            let sq: f64 = g.iter().map(|&x| (x as f64) * (x as f64)).sum();
            b.record_sq(0, sq, g.len(), 3);
            assert_eq!(a.norms[0].to_bits(), b.norms[0].to_bits());
            assert_eq!(b.last_update[0], 3);
        }
    }

    #[test]
    fn peek_probe_matches_real_probe_and_leaves_rng_untouched() {
        let mut d = dict(12);
        for l in 0..4 {
            d.record(l, &[1.0], 1);
        }
        let peek1 = d.peek_layers_to_probe(&[0], 3, 2);
        let peek2 = d.peek_layers_to_probe(&[0], 3, 2);
        assert_eq!(peek1, peek2, "peek must not advance the rng");
        let real = d.layers_to_probe(&[0], 3, 2);
        assert_eq!(peek1, real, "peek must predict the committed probe set");
    }

    #[test]
    fn state_roundtrip_resumes_identical_probe_sequence() {
        let mut a = dict(12);
        for l in 0..5 {
            a.record(l, &[0.5; 8], l);
        }
        a.mark_selected(&[1, 3]);
        a.layers_to_probe(&[1], 3, 6); // advance the rng
        let mut bag = crate::session::state::StateBag::new();
        a.state_save(&mut bag, "dict");
        let mut b = dict(12);
        b.state_load(&bag, "dict").unwrap();
        assert_eq!(a.norms, b.norms);
        assert_eq!(a.last_update, b.last_update);
        for step in 7..12 {
            assert_eq!(
                a.layers_to_probe(&[2], 3, step),
                b.layers_to_probe(&[2], 3, step),
                "probe set diverged at step {step}"
            );
        }
        assert_eq!(a.visit_count(3), b.visit_count(3));
        assert_eq!(a.score(1, true).to_bits(), b.score(1, true).to_bits());
    }

    #[test]
    fn visit_freq_laplace_smoothed() {
        let mut d = dict(4);
        // at t=0 all frequencies equal and positive
        for l in 0..4 {
            assert!((d.visit_freq(l) - 0.25).abs() < 1e-12);
        }
        d.mark_selected(&[0]);
        d.mark_selected(&[0]);
        assert!(d.visit_freq(0) > d.visit_freq(1));
        assert_eq!(d.visit_count(0), 2);
    }

    #[test]
    fn score_downweights_frequent_layers() {
        let mut d = dict(2);
        d.record(0, &[1.0], 0);
        d.record(1, &[1.0], 0);
        for _ in 0..5 {
            d.mark_selected(&[0]);
        }
        assert!(d.score(1, true) > d.score(0, true));
        // ablation: without frequency they tie
        assert_eq!(d.score(0, false), d.score(1, false));
    }
}
