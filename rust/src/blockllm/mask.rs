//! Intra-layer coordinate masks (Alg. 2 lines 11-18).
//!
//! For each selected layer, keep exactly the top `floor(n_l · keep_frac)`
//! coordinates by |G̃| with `keep_frac = n_s / Σ_p` (see selector.rs for why
//! that's the well-defined reading of the paper's ζ). Using an exact top-k
//! (`BitMask::top_k`, ties broken by index) instead of a percentile
//! threshold makes the sparsity level a HARD bound: Σ_l floor(n_l·n_s/Σ_p)
//! <= n_s <= (1−s)·n, property-tested in tests/blockllm_props.rs. Three
//! policies are exposed for the ablation bench (DESIGN.md §6.1).

use crate::config::MaskMode;
use crate::optim::masked_adam::BitMask;

use super::selector::Selection;

/// Per-layer mask recipe, decided by selection geometry alone (layer sizes
/// + budget — no gradient values needed). The streaming path resolves each
/// rule against a layer's gradient shard the moment it arrives
/// (`grads::Retain::{All, TopK}`), so selection events never require every
/// selected layer's dense gradient to coexist.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MaskRule {
    /// keep every coordinate (all-set mask, zeros included)
    All,
    /// keep exactly the top-k coordinates by |G̃| (ties to lower index)
    TopK(usize),
}

/// The per-layer mask rules for a selection, in `sel.layers` order.
/// `sizes[l]` is layer l's coordinate count.
pub fn mask_plan(sel: &Selection, sizes: &[usize], mode: MaskMode) -> Vec<(usize, MaskRule)> {
    let mut out = Vec::with_capacity(sel.layers.len());
    match mode {
        MaskMode::DenseLayers => {
            for &l in &sel.layers {
                out.push((l, MaskRule::All));
            }
        }
        MaskMode::Alg2 => {
            // paper-literal: every selected layer masked with the same keep
            // fraction, exact top-k on its own |G̃| so the budget holds
            for &l in &sel.layers {
                let k = ((sizes[l] as f64) * sel.keep_frac).floor() as usize;
                out.push((l, MaskRule::TopK(k)));
            }
        }
        MaskMode::OvershootOnly => {
            // earlier layers dense; only the final (overshooting) layer is
            // trimmed so the total lands on the budget
            let mut covered = 0usize;
            for (i, &l) in sel.layers.iter().enumerate() {
                let n = sizes[l];
                if i + 1 < sel.layers.len() || covered + n <= sel.n_s {
                    out.push((l, MaskRule::All));
                    covered += n;
                } else {
                    let remaining = sel.n_s.saturating_sub(covered).max(1);
                    out.push((l, MaskRule::TopK(remaining)));
                    covered += remaining;
                }
            }
        }
    }
    out
}

/// Resolve one rule against a layer's gradient.
pub fn mask_from_rule(rule: MaskRule, grad: &[f32]) -> BitMask {
    match rule {
        MaskRule::All => BitMask::all_set(grad.len()),
        MaskRule::TopK(k) => BitMask::top_k(grad, k),
    }
}

/// Build per-layer masks for a selection. `grads[l]` must hold the gradient
/// buffer for each selected layer l (others may be empty). Equivalent to
/// resolving [`mask_plan`] layer by layer — the dense-path formulation.
pub fn build_masks(
    sel: &Selection,
    grads: &[Vec<f32>],
    mode: MaskMode,
) -> Vec<(usize, BitMask)> {
    let sizes: Vec<usize> = grads.iter().map(Vec::len).collect();
    mask_plan(sel, &sizes, mode)
        .into_iter()
        .map(|(l, rule)| (l, mask_from_rule(rule, &grads[l])))
        .collect()
}

/// Total active coordinates across a mask set.
pub fn active_coords(masks: &[(usize, BitMask)]) -> usize {
    masks.iter().map(|(_, m)| m.popcount).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blockllm::selector::Selection;
    use crate::util::rng::Pcg64;

    fn toy_selection(layers: Vec<usize>, sigma_p: usize, n_s: usize) -> Selection {
        Selection {
            layers,
            sigma_p,
            n_s,
            zeta: (((sigma_p as f64 - n_s as f64) / n_s as f64).max(0.0)).min(1.0),
            keep_frac: (n_s as f64 / sigma_p as f64).min(1.0),
        }
    }

    fn rand_grads(sizes: &[usize], seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Pcg64::new(seed);
        sizes
            .iter()
            .map(|&n| (0..n).map(|_| rng.normal_f32()).collect())
            .collect()
    }

    #[test]
    fn alg2_hits_the_budget_approximately() {
        let sizes = [1000usize, 500];
        let grads = rand_grads(&sizes, 1);
        let sel = toy_selection(vec![0, 1], 1500, 600);
        let masks = build_masks(&sel, &grads, crate::config::MaskMode::Alg2);
        let active = active_coords(&masks);
        // keep_frac = 0.4 -> exactly floor(1000*.4) + floor(500*.4) = 600,
        // and never above the budget (exact top-k)
        assert_eq!(active, 600);
        assert!(active <= sel.n_s, "active={active} > budget {}", sel.n_s);
    }

    #[test]
    fn alg2_keeps_largest_magnitude_coords() {
        let grads = vec![vec![0.1f32, -9.0, 0.2, 8.0, -0.3, 7.0, 0.1, -6.0]];
        let sel = toy_selection(vec![0], 8, 4);
        let masks = build_masks(&sel, &grads, crate::config::MaskMode::Alg2);
        let m = &masks[0].1;
        assert_eq!(m.popcount, 4);
        for (i, want) in [false, true, false, true, false, true, false, true].iter().enumerate() {
            assert_eq!(m.get(i), *want, "coord {i}");
        }
    }

    #[test]
    fn dense_layers_mode_masks_nothing() {
        let sizes = [100usize, 50];
        let grads = rand_grads(&sizes, 2);
        let sel = toy_selection(vec![0, 1], 150, 60);
        let masks = build_masks(&sel, &grads, crate::config::MaskMode::DenseLayers);
        assert_eq!(active_coords(&masks), 150);
    }

    #[test]
    fn overshoot_only_trims_just_the_last_layer() {
        let sizes = [100usize, 100];
        let grads = rand_grads(&sizes, 3);
        let sel = toy_selection(vec![0, 1], 200, 150);
        let masks = build_masks(&sel, &grads, crate::config::MaskMode::OvershootOnly);
        assert_eq!(masks[0].1.popcount, 100, "first layer must stay dense");
        let second = masks[1].1.popcount;
        assert!((45..=55).contains(&second), "second layer ~50, got {second}");
    }

    #[test]
    fn masks_pair_with_layer_indices() {
        let sizes = [10usize, 20, 30];
        let grads = rand_grads(&sizes, 4);
        let sel = toy_selection(vec![2, 0], 40, 40);
        let masks = build_masks(&sel, &grads, crate::config::MaskMode::Alg2);
        assert_eq!(masks[0].0, 2);
        assert_eq!(masks[1].0, 0);
        assert_eq!(masks[0].1.len, 30);
        assert_eq!(masks[1].1.len, 10);
    }
}
