//! Greedy layer selection (Alg. 2 lines 2-10).
//!
//! Sort layers by descending score ||G̃_l|| / f_l, take layers until the
//! cumulative parameter count Σ_p reaches the budget n_s = (1-s)·n, and
//! compute ζ = clamp((Σ_p − n_s)/n_s) — the overshoot fraction that the mask
//! stage trims back inside layers (paper's ζ definition; the clamp is
//! DESIGN.md §6.1).

use super::scorer::NormDictionary;

/// Ordering rule — the paper's greedy rule plus its §3.3 ablations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SelectionRule {
    /// Largest score first (BlockLLM).
    TopScore,
    /// Smallest score first (BlockLLM-SubOPT, Fig. 7 left).
    BottomScore,
    /// Largest raw norm, ignoring visit frequency (Fig. 7 right).
    TopScoreNoFreq,
}

/// Result of one selection event.
#[derive(Debug, Clone)]
pub struct Selection {
    /// chosen layer indices, in greedy order
    pub layers: Vec<usize>,
    /// Σ_p: parameters covered by the chosen layers
    pub sigma_p: usize,
    /// the budget n_s that was being filled
    pub n_s: usize,
    /// ζ = clamp((Σ_p − n_s)/n_s, 0, 1): fraction to mask away per layer.
    /// NOTE (paper literalism): Alg. 2 computes the keep-percentile from
    /// this ζ; we keep a fraction keep = n_s / Σ_p of each selected layer,
    /// which is the (1−ζ′) percentile with ζ′ = 1 − n_s/Σ_p — identical to
    /// the paper's intent of landing exactly on the sparsity budget and
    /// well-defined even when Σ_p > 2·n_s.
    pub zeta: f64,
    /// fraction of each selected layer's coordinates to KEEP
    pub keep_frac: f64,
}

/// Greedy selection until the parameter budget is covered.
///
/// `sizes[l]` = parameter count of layer l; `sparsity` = s in the paper;
/// returns at least one layer even if it overshoots the budget.
pub fn select_layers(
    dict: &NormDictionary,
    sizes: &[usize],
    sparsity: f64,
    rule: SelectionRule,
) -> Selection {
    let n: usize = sizes.iter().sum();
    // floor (not round): the budget may never exceed (1-s)·n, so the mask
    // stage can guarantee active_coords <= (1-s)·n exactly
    let n_s = (((1.0 - sparsity) * n as f64).floor() as usize).max(1);

    let mut order: Vec<usize> = (0..sizes.len()).collect();
    match rule {
        SelectionRule::TopScore => {
            order.sort_by(|&a, &b| dict.score(b, true).partial_cmp(&dict.score(a, true)).unwrap())
        }
        SelectionRule::BottomScore => {
            order.sort_by(|&a, &b| dict.score(a, true).partial_cmp(&dict.score(b, true)).unwrap())
        }
        SelectionRule::TopScoreNoFreq => {
            order.sort_by(|&a, &b| dict.score(b, false).partial_cmp(&dict.score(a, false)).unwrap())
        }
    }

    let mut layers = Vec::new();
    let mut sigma_p = 0usize;
    for l in order {
        sigma_p += sizes[l];
        layers.push(l);
        if sigma_p >= n_s {
            break;
        }
    }
    let zeta = (((sigma_p as f64 - n_s as f64) / n_s as f64).max(0.0)).min(1.0);
    let keep_frac = (n_s as f64 / sigma_p as f64).min(1.0);
    Selection { layers, sigma_p, n_s, zeta, keep_frac }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::NormKind;

    fn dict_with_norms(norms: &[f64]) -> NormDictionary {
        let mut d = NormDictionary::new(norms.len(), NormKind::Rms, 1);
        for (l, &n) in norms.iter().enumerate() {
            d.record_norm(l, n, 0);
        }
        d
    }

    #[test]
    fn picks_largest_norm_layers_first() {
        let d = dict_with_norms(&[0.1, 5.0, 0.2, 3.0]);
        let sizes = [100, 100, 100, 100];
        let sel = select_layers(&d, &sizes, 0.5, SelectionRule::TopScore);
        assert_eq!(sel.layers, vec![1, 3]);
        assert_eq!(sel.sigma_p, 200);
        assert_eq!(sel.n_s, 200);
        assert_eq!(sel.zeta, 0.0);
        assert!((sel.keep_frac - 1.0).abs() < 1e-12);
    }

    #[test]
    fn subopt_picks_smallest() {
        let d = dict_with_norms(&[0.1, 5.0, 0.2, 3.0]);
        let sizes = [100, 100, 100, 100];
        let sel = select_layers(&d, &sizes, 0.5, SelectionRule::BottomScore);
        assert_eq!(sel.layers, vec![0, 2]);
    }

    #[test]
    fn overshoot_produces_keep_frac() {
        let d = dict_with_norms(&[1.0, 0.5]);
        let sizes = [1000, 10];
        // budget n_s = 0.05*1010 ≈ 51; first layer (1000) overshoots hard
        let sel = select_layers(&d, &sizes, 0.95, SelectionRule::TopScore);
        assert_eq!(sel.layers, vec![0]);
        assert!(sel.sigma_p == 1000);
        assert!(sel.keep_frac > 0.04 && sel.keep_frac < 0.06, "{}", sel.keep_frac);
        assert_eq!(sel.zeta, 1.0); // clamped: raw (1000-51)/51 >> 1
    }

    #[test]
    fn always_selects_at_least_one_layer() {
        let d = dict_with_norms(&[0.0, 0.0]);
        let sizes = [50, 50];
        let sel = select_layers(&d, &sizes, 0.9999, SelectionRule::TopScore);
        assert_eq!(sel.layers.len(), 1);
        assert!(sel.n_s >= 1);
    }

    #[test]
    fn frequency_steers_selection() {
        let mut d = dict_with_norms(&[1.0, 1.0]);
        for _ in 0..10 {
            d.mark_selected(&[0]);
        }
        let sizes = [100, 100];
        let sel = select_layers(&d, &sizes, 0.5, SelectionRule::TopScore);
        assert_eq!(sel.layers[0], 1, "less-visited layer must win the tie");
        // ...but the no-freq ablation is indifferent (stable sort picks 0)
        let sel2 = select_layers(&d, &sizes, 0.5, SelectionRule::TopScoreNoFreq);
        assert_eq!(sel2.layers[0], 0);
    }

    #[test]
    fn budget_is_fraction_of_total() {
        let d = dict_with_norms(&[1.0; 8]);
        let sizes = [25usize; 8];
        let sel = select_layers(&d, &sizes, 0.75, SelectionRule::TopScore);
        assert_eq!(sel.n_s, 50);
        assert_eq!(sel.layers.len(), 2);
    }
}
