//! The BlockLLM strategy: Alg. 1 wired end-to-end.
//!
//! Per step: (1) refresh the gradient-norm dictionary for the active block
//! plus p sampled layers, (2) let the patience controller decide whether to
//! re-select, (3) on re-selection run greedy Alg. 2 + mask construction and
//! REBUILD the sparse Adam state (dropping the old block's state, as the
//! paper does), (4) run the masked Adam update over the active block, then
//! (5) refresh the active layers' dictionary entries with true
//! processed-gradient norms ||G̃|| (the paper's criterion; inactive layers
//! necessarily carry raw-gradient norms — DESIGN.md §6.2).

use crate::baselines::{StepInfo, Strategy};
use crate::config::{MaskMode, Method, NormKind, StatePolicy, TrainConfig};
use crate::memory::{profiles, MemBreakdown};
use crate::model::ParamStore;
use crate::optim::masked_adam::{masked_adam_step, LayerState};
use crate::optim::{AdamHypers, SparseAdamState};

use super::mask::build_masks;
use super::scorer::NormDictionary;
use super::selector::{select_layers, SelectionRule};
use super::PatienceController;

pub struct BlockLlmStrategy {
    pub dict: NormDictionary,
    pub patience: PatienceController,
    state: SparseAdamState,
    sizes: Vec<usize>,
    hypers: AdamHypers,
    sparsity: f64,
    rule: SelectionRule,
    mask_mode: MaskMode,
    sample_p: usize,
    norm_kind: NormKind,
    n_params: u64,
    /// paper §2.2: Reset drops deselected state (the paper's design);
    /// Offload stashes it host-side and restores on re-selection (tried and
    /// rejected by the paper — kept for the reproduction of that finding)
    pub state_policy: StatePolicy,
    /// host-side stash for Offload: layer -> (m, v)
    offloaded: std::collections::HashMap<usize, (Vec<f32>, Vec<f32>)>,
    /// telemetry: number of selection events
    pub n_selections: u64,
}

impl BlockLlmStrategy {
    pub fn new(
        sizes: &[usize],
        hypers: AdamHypers,
        sparsity: f64,
        patience_m: usize,
        sample_p: usize,
        rule: SelectionRule,
        mask_mode: MaskMode,
        norm_kind: NormKind,
        seed: u64,
    ) -> BlockLlmStrategy {
        BlockLlmStrategy {
            dict: NormDictionary::new(sizes.len(), norm_kind, seed),
            patience: PatienceController::new(patience_m),
            state: SparseAdamState::default(),
            sizes: sizes.to_vec(),
            hypers,
            sparsity,
            rule,
            mask_mode,
            sample_p,
            norm_kind,
            n_params: sizes.iter().map(|&s| s as u64).sum(),
            state_policy: StatePolicy::Reset,
            offloaded: std::collections::HashMap::new(),
            n_selections: 0,
        }
    }

    pub fn from_config(cfg: &TrainConfig, sizes: &[usize], h: AdamHypers) -> BlockLlmStrategy {
        let rule = match cfg.method {
            Method::BlockLlmSubOpt => SelectionRule::BottomScore,
            Method::BlockLlmNoFreq => SelectionRule::TopScoreNoFreq,
            _ => SelectionRule::TopScore,
        };
        let mut s = BlockLlmStrategy::new(
            sizes,
            h,
            cfg.sparsity,
            cfg.patience,
            cfg.sample_layers,
            rule,
            cfg.mask_mode,
            cfg.norm_kind,
            cfg.seed,
        );
        s.state_policy = cfg.state_policy;
        s
    }

    pub fn active_layers(&self) -> Vec<usize> {
        self.state.selected_layers()
    }

    /// ||G̃|| over the masked coordinates of a just-updated layer — the
    /// paper's processed-gradient norm, free to compute from (m, v).
    fn processed_norm(&self, st: &LayerState, step: u64) -> f64 {
        // shared f64 helper: the old f32/powi form drifted at large step
        // counts and `step as i32` wrapped past i32::MAX
        let (bc1, bc2) = crate::optim::masked_adam::bias_corrections_f64(&self.hypers, step);
        let eps = self.hypers.eps;
        let mut sq = 0.0f64;
        let mut cnt = 0usize;
        for (i, (&m, &v)) in st.m.iter().zip(&st.v).enumerate() {
            if st.mask.get(i) {
                let g = (m as f64 / bc1) / ((v as f64 / bc2).sqrt() + eps);
                sq += g * g;
                cnt += 1;
            }
        }
        match self.norm_kind {
            NormKind::Fro => sq.sqrt(),
            NormKind::Rms => (sq / cnt.max(1) as f64).sqrt(),
        }
    }
}

impl Strategy for BlockLlmStrategy {
    fn step(
        &mut self,
        store: &mut ParamStore,
        grads: &[Vec<f32>],
        loss: f64,
        lr: f64,
        step: usize,
    ) -> StepInfo {
        // (2) patience decides whether this is a selection event
        let will_select = self.patience.observe(loss);

        // (1) dictionary refresh. At selection events Alg. 2 scores EVERY
        // layer (||G_l|| is a streaming reduction during backward — no grad
        // storage needed); between events only the active block + p sampled
        // layers are refreshed.
        let active = self.state.selected_layers();
        let probes: Vec<usize> = if will_select {
            (0..self.sizes.len()).collect()
        } else {
            self.dict.layers_to_probe(&active, self.sample_p, step)
        };
        for &l in &probes {
            self.dict.record(l, &grads[l], step);
        }
        // modeled grad residency: active coords + the largest probed layer
        let probe_max = probes.iter().map(|&l| self.sizes[l] as u64).max().unwrap_or(0);

        // (3) re-selection
        let mut reselected = false;
        if will_select {
            let sel = select_layers(&self.dict, &self.sizes, self.sparsity, self.rule);
            let masks = build_masks(&sel, grads, self.mask_mode);
            self.dict.mark_selected(&sel.layers);
            let prev_step = self.state.step;
            if self.state_policy == StatePolicy::Offload {
                // stash the outgoing block's moments host-side (paper §2.2:
                // the rejected alternative)
                let old = std::mem::take(&mut self.state);
                for (li, lst) in old.layers {
                    self.offloaded.insert(li, (lst.m, lst.v));
                }
            }
            // dropping the old state IS the paper's optimizer reset
            self.state = SparseAdamState::new(masks, &self.sizes);
            if self.state_policy == StatePolicy::Offload {
                for (li, lst) in self.state.layers.iter_mut() {
                    if let Some((m, v)) = self.offloaded.remove(li) {
                        lst.m = m;
                        lst.v = v;
                    }
                }
                // bias-correction step continues (restored moments are warm)
                self.state.step = prev_step;
            }
            self.n_selections += 1;
            reselected = true;
        }

        // (4) masked sparse Adam over the active block
        self.state.step += 1;
        let t = self.state.step;
        let mut updated = 0u64;
        for (li, lst) in self.state.layers.iter_mut() {
            updated +=
                masked_adam_step(&mut store.bufs[*li], &grads[*li], lst, t, lr, &self.hypers) as u64;
        }

        // (5) refresh active layers with processed-gradient norms
        let mut processed: Vec<(usize, f64)> = Vec::with_capacity(self.state.layers.len());
        for (li, lst) in self.state.layers.iter() {
            processed.push((*li, 0.0));
            let n = self.processed_norm(lst, t);
            processed.last_mut().expect("just pushed").1 = n;
        }
        for (li, n) in processed {
            self.dict.record_norm(li, n, step);
        }

        let active_coords = self.state.active_coords();
        let mask_elems: u64 = self.state.layers.iter().map(|(_, s)| s.mask.len as u64).sum();
        let mem: MemBreakdown =
            profiles::blockllm(self.n_params, active_coords, active_coords + probe_max, mask_elems);

        StepInfo {
            updated_coords: updated,
            reselected,
            mem,
            active_layers: self.state.selected_layers(),
        }
    }

    fn name(&self) -> &'static str {
        match self.rule {
            SelectionRule::TopScore => "blockllm",
            SelectionRule::BottomScore => "blockllm-subopt",
            SelectionRule::TopScoreNoFreq => "blockllm-nofreq",
        }
    }

    fn modeled_grad_elems(&self, _n: u64) -> u64 {
        self.state.active_coords() + self.sizes.iter().map(|&s| s as u64).max().unwrap_or(0)
    }

    fn telemetry(&self) -> Vec<(String, f64)> {
        let offload_bytes: usize = self.offloaded.values().map(|(m, v)| 4 * (m.len() + v.len())).sum();
        vec![
            ("n_selections".into(), self.n_selections as f64),
            ("active_coords".into(), self.state.active_coords() as f64),
            ("offloaded_host_bytes".into(), offload_bytes as f64),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::testutil;

    fn make(sparsity: f64, m: usize) -> BlockLlmStrategy {
        let sizes: Vec<usize> = testutil::toy_specs().iter().map(|s| s.numel()).collect();
        BlockLlmStrategy::new(
            &sizes,
            AdamHypers::default(),
            sparsity,
            m,
            1,
            SelectionRule::TopScore,
            MaskMode::Alg2,
            NormKind::Rms,
            1,
        )
    }

    #[test]
    fn first_step_selects_a_block() {
        let specs = testutil::toy_specs();
        let sizes: Vec<usize> = specs.iter().map(|s| s.numel()).collect();
        let mut s = make(0.8, 10);
        let mut store = ParamStore::init(&specs, 2);
        let grads = testutil::rand_grads(&sizes, 3);
        let info = s.step(&mut store, &grads, 5.0, 1e-3, 0);
        assert!(info.reselected);
        assert!(!info.active_layers.is_empty());
        let n: u64 = sizes.iter().map(|&x| x as u64).sum();
        let budget = (0.2 * n as f64) as u64;
        assert!(info.updated_coords <= budget + 64, "updated {} > budget {}", info.updated_coords, budget);
        assert!(info.updated_coords > budget / 2);
    }

    #[test]
    fn memory_scales_with_sparsity() {
        let specs = testutil::toy_specs();
        let sizes: Vec<usize> = specs.iter().map(|s| s.numel()).collect();
        let mut store = ParamStore::init(&specs, 2);
        let grads = testutil::rand_grads(&sizes, 3);
        let mut mems = Vec::new();
        for s_level in [0.5, 0.9, 0.99] {
            let mut s = make(s_level, 10);
            let info = s.step(&mut store.clone_store(), &grads, 5.0, 1e-3, 0);
            mems.push(info.mem.total());
        }
        assert!(mems[0] > mems[1] && mems[1] > mems[2], "{mems:?}");
    }

    #[test]
    fn only_selected_layers_move() {
        let specs = testutil::toy_specs();
        let sizes: Vec<usize> = specs.iter().map(|s| s.numel()).collect();
        let mut s = make(0.9, 100);
        let mut store = ParamStore::init(&specs, 2);
        let before: Vec<Vec<f32>> = store.bufs.clone();
        let grads = testutil::rand_grads(&sizes, 3);
        let info = s.step(&mut store, &grads, 5.0, 1e-2, 0);
        for li in 0..sizes.len() {
            if !info.active_layers.contains(&li) {
                assert_eq!(store.bufs[li], before[li], "inactive layer {li} moved");
            }
        }
    }

    #[test]
    fn plateau_triggers_reselection_and_state_reset() {
        let specs = testutil::toy_specs();
        let sizes: Vec<usize> = specs.iter().map(|s| s.numel()).collect();
        let mut s = make(0.8, 3);
        let mut store = ParamStore::init(&specs, 2);
        let mut reselections = 0;
        for t in 0..20 {
            let grads = testutil::rand_grads(&sizes, 50 + t as u64);
            // constant loss = permanent plateau
            let info = s.step(&mut store, &grads, 5.0, 1e-3, t);
            reselections += info.reselected as u32;
        }
        assert!(reselections >= 4, "plateau produced only {reselections} reselections");
        assert_eq!(s.n_selections as u32, reselections);
    }

    #[test]
    fn decreasing_loss_keeps_block_stable() {
        let specs = testutil::toy_specs();
        let sizes: Vec<usize> = specs.iter().map(|s| s.numel()).collect();
        let mut s = make(0.8, 3);
        let mut store = ParamStore::init(&specs, 2);
        let mut reselections = 0;
        for t in 0..30 {
            let grads = testutil::rand_grads(&sizes, 70 + t as u64);
            let info = s.step(&mut store, &grads, 10.0 - 0.3 * t as f64, 1e-3, t);
            reselections += info.reselected as u32;
        }
        assert_eq!(reselections, 1, "loss was strictly improving");
    }

    #[test]
    fn visit_frequency_rotates_blocks_under_plateau() {
        // under a plateau with symmetric gradients, the f_l term must make
        // selection visit different layers over time
        let specs = testutil::toy_specs();
        let sizes: Vec<usize> = specs.iter().map(|s| s.numel()).collect();
        let mut s = make(0.7, 1);
        let mut store = ParamStore::init(&specs, 2);
        let mut seen = std::collections::HashSet::new();
        for t in 0..40 {
            let grads = testutil::rand_grads(&sizes, 7); // same grads each step
            let info = s.step(&mut store, &grads, 5.0, 1e-9, t);
            for l in info.active_layers {
                seen.insert(l);
            }
        }
        assert!(seen.len() >= 3, "selection stuck on {seen:?}");
    }

    #[test]
    fn descends_quadratic() {
        let mut s = make(0.5, 10);
        let (before, after) = testutil::quadratic_descends(&mut s, 400);
        assert!(after < before * 0.7, "before={before} after={after}");
    }

    #[test]
    fn offload_policy_restores_state_reset_drops_it() {
        let specs = testutil::toy_specs();
        let sizes: Vec<usize> = specs.iter().map(|s| s.numel()).collect();
        // patience 1 so every plateau step reselects
        let run = |policy: StatePolicy| {
            let mut s = make(0.5, 1);
            s.state_policy = policy;
            let mut store = ParamStore::init(&specs, 2);
            let grads = testutil::rand_grads(&sizes, 3);
            for t in 0..6 {
                s.step(&mut store, &grads, 5.0, 1e-3, t); // constant loss
            }
            // moment magnitude of the active block after repeated resets
            let msum: f32 = s
                .state
                .layers
                .iter()
                .map(|(_, l)| l.m.iter().map(|x| x.abs()).sum::<f32>())
                .sum();
            (msum, s.offloaded.len(), s.state.step)
        };
        let (m_reset, stash_reset, _) = run(StatePolicy::Reset);
        let (m_off, _stash_off, step_off) = run(StatePolicy::Offload);
        assert_eq!(stash_reset, 0, "Reset must not stash anything");
        // warm restored moments accumulate across reselections -> larger
        assert!(m_off > m_reset, "offload {m_off} <= reset {m_reset}");
        assert!(step_off > 1, "offload must keep the Adam step counter");
    }

    #[test]
    fn subopt_picks_low_norm_layers() {
        let specs = testutil::toy_specs();
        let sizes: Vec<usize> = specs.iter().map(|s| s.numel()).collect();
        let mut top = make(0.8, 100);
        let mut bottom = make(0.8, 100);
        bottom.rule = SelectionRule::BottomScore;
        let mut store = ParamStore::init(&specs, 2);
        // layer 0 gets huge grads, others tiny
        let mut grads = testutil::rand_grads(&sizes, 3);
        for g in grads[0].iter_mut() {
            *g *= 100.0;
        }
        let it = top.step(&mut store.clone_store(), &grads, 5.0, 1e-3, 0);
        let ib = bottom.step(&mut store.clone_store(), &grads, 5.0, 1e-3, 0);
        assert!(it.active_layers.contains(&0));
        assert!(!ib.active_layers.contains(&0));
    }
}
