//! The BlockLLM strategy: Alg. 1 wired end-to-end.
//!
//! Per step: (1) refresh the gradient-norm dictionary for the active block
//! plus p sampled layers, (2) let the patience controller decide whether to
//! re-select, (3) on re-selection run greedy Alg. 2 + mask construction and
//! REBUILD the sparse Adam state (dropping the old block's state, as the
//! paper does), (4) run the masked Adam update over the active block, then
//! (5) refresh the active layers' dictionary entries with true
//! processed-gradient norms ||G̃|| (the paper's criterion; inactive layers
//! necessarily carry raw-gradient norms — DESIGN.md §6.2).
//!
//! Two gradient routes implement the identical math:
//! * **dense** (`step`): the trainer stages full gradients; the legacy
//!   parity reference (`--grad-stream 0`).
//! * **streaming** (`sparse_plan`/`step_sparse`/`step_selected`): gradients
//!   arrive as per-layer shards through a `grads::MaskedSink`. Non-selection
//!   steps consume only the active block's compact coordinates plus
//!   streaming norms — the paper's O(active + largest-layer) residency. A
//!   selection event (patience-gated) asks the trainer to REPLAY the step's
//!   microbatches: at accum == 1 the replay retains only each selected
//!   layer's top-k coordinates (mask built on the live shard), keeping the
//!   bound even while selecting; under grad accumulation the replay falls
//!   back to dense staging, because an accumulated gradient's norm has
//!   cross-microbatch terms no per-shard reduction can reconstruct. Both
//!   routes produce bit-for-bit identical losses, dictionary norms, rng
//!   consumption, and parameter updates — pinned by the unit tests below
//!   and end-to-end by tests/grad_check.rs.

use anyhow::{bail, Result};

use crate::baselines::{SparseOutcome, SparsePlan, StepInfo, Strategy};
use crate::config::{MaskMode, Method, NormKind, StatePolicy, TrainConfig};
use crate::grads::{MaskedSink, Retain};
use crate::memory::{profiles, MemBreakdown};
use crate::model::ParamStore;
use crate::optim::masked_adam::{
    masked_adam_step, masked_adam_step_compact, masked_adam_step_compact_range, BitMask,
    LayerState,
};
use crate::optim::{AdamHypers, SparseAdamState};
use crate::session::state::StateBag;

use super::mask::{build_masks, mask_plan, MaskRule};
use super::scorer::NormDictionary;
use super::selector::{select_layers, Selection, SelectionRule};
use super::PatienceController;

/// The compact masked-Adam update, ZeRO-sharded when the dist layer is
/// active: at `--replicas R > 1` the layer's update runs as R consecutive
/// compact-range calls over even `⌈c/R⌉` chunks — replica q's moment shard
/// — which is bitwise identical to one full compact call (Adam is
/// elementwise; `optim::masked_adam` pins the shard/full equivalence). The
/// in-process artifact runs the shards back to back on the calling thread;
/// the residency claim — each replica only ever needs ITS shard's moments —
/// is what `Strategy::state_shard_bytes` reports and what a process port
/// would allocate.
fn sharded_compact_step(
    w: &mut [f32],
    gc: &[f32],
    lst: &mut LayerState,
    t: u64,
    lr: f64,
    h: &AdamHypers,
) -> usize {
    let r = crate::util::replicas();
    if r <= 1 {
        return masked_adam_step_compact(w, gc, lst, t, lr, h);
    }
    let c = lst.mask.popcount;
    let chunk = c.div_ceil(r);
    let mut updated = 0usize;
    for q in 0..r {
        let lo = (q * chunk).min(c);
        let hi = ((q + 1) * chunk).min(c);
        if lo >= hi {
            break;
        }
        updated += masked_adam_step_compact_range(w, gc, lst, t, lr, h, lo, hi);
    }
    updated
}

pub struct BlockLlmStrategy {
    pub dict: NormDictionary,
    pub patience: PatienceController,
    state: SparseAdamState,
    sizes: Vec<usize>,
    hypers: AdamHypers,
    sparsity: f64,
    rule: SelectionRule,
    mask_mode: MaskMode,
    sample_p: usize,
    norm_kind: NormKind,
    n_params: u64,
    /// paper §2.2: Reset drops deselected state (the paper's design);
    /// Offload stashes it host-side and restores on re-selection (tried and
    /// rejected by the paper — kept for the reproduction of that finding)
    pub state_policy: StatePolicy,
    /// host-side stash for Offload: layer -> (m, v)
    offloaded: std::collections::HashMap<usize, (Vec<f32>, Vec<f32>)>,
    /// telemetry: number of selection events
    pub n_selections: u64,
    /// grad_accum the live streaming plan was built for (selects between
    /// compact-with-streamed-norms and dense-probe retention)
    plan_accum: usize,
    /// selection computed by `step_sparse`, consumed by `step_selected`
    pending: Option<Selection>,
}

impl BlockLlmStrategy {
    pub fn new(
        sizes: &[usize],
        hypers: AdamHypers,
        sparsity: f64,
        patience_m: usize,
        sample_p: usize,
        rule: SelectionRule,
        mask_mode: MaskMode,
        norm_kind: NormKind,
        seed: u64,
    ) -> BlockLlmStrategy {
        BlockLlmStrategy {
            dict: NormDictionary::new(sizes.len(), norm_kind, seed),
            patience: PatienceController::new(patience_m),
            state: SparseAdamState::default(),
            sizes: sizes.to_vec(),
            hypers,
            sparsity,
            rule,
            mask_mode,
            sample_p,
            norm_kind,
            n_params: sizes.iter().map(|&s| s as u64).sum(),
            state_policy: StatePolicy::Reset,
            offloaded: std::collections::HashMap::new(),
            n_selections: 0,
            plan_accum: 1,
            pending: None,
        }
    }

    pub fn from_config(cfg: &TrainConfig, sizes: &[usize], h: AdamHypers) -> BlockLlmStrategy {
        let rule = match cfg.method {
            Method::BlockLlmSubOpt => SelectionRule::BottomScore,
            Method::BlockLlmNoFreq => SelectionRule::TopScoreNoFreq,
            _ => SelectionRule::TopScore,
        };
        let mut s = BlockLlmStrategy::new(
            sizes,
            h,
            cfg.sparsity,
            cfg.patience,
            cfg.sample_layers,
            rule,
            cfg.mask_mode,
            cfg.norm_kind,
            cfg.seed,
        );
        s.state_policy = cfg.state_policy;
        s
    }

    pub fn active_layers(&self) -> Vec<usize> {
        self.state.selected_layers()
    }

    /// ||G̃|| over the masked coordinates of a just-updated layer — the
    /// paper's processed-gradient norm, free to compute from (m, v).
    fn processed_norm(&self, st: &LayerState, step: u64) -> f64 {
        // shared f64 helper: the old f32/powi form drifted at large step
        // counts and `step as i32` wrapped past i32::MAX
        let (bc1, bc2) = crate::optim::masked_adam::bias_corrections_f64(&self.hypers, step);
        let eps = self.hypers.eps;
        let mut sq = 0.0f64;
        let mut cnt = 0usize;
        for (i, (&m, &v)) in st.m.iter().zip(&st.v).enumerate() {
            if st.mask.get(i) {
                let g = (m as f64 / bc1) / ((v as f64 / bc2).sqrt() + eps);
                sq += g * g;
                cnt += 1;
            }
        }
        match self.norm_kind {
            NormKind::Fro => sq.sqrt(),
            NormKind::Rms => (sq / cnt.max(1) as f64).sqrt(),
        }
    }

    /// The paper's optimizer reset on re-selection (steps (3) of Alg. 1):
    /// drop — or under `StatePolicy::Offload`, stash and partially restore —
    /// the old block's moments, and rebuild the sparse state over the new
    /// masks. Shared verbatim by the dense and streaming routes so their
    /// state transitions cannot drift.
    fn apply_selection(&mut self, masks: Vec<(usize, BitMask)>) {
        let prev_step = self.state.step;
        if self.state_policy == StatePolicy::Offload {
            // stash the outgoing block's moments host-side (paper §2.2:
            // the rejected alternative)
            let old = std::mem::take(&mut self.state);
            for (li, lst) in old.layers {
                self.offloaded.insert(li, (lst.m, lst.v));
            }
        }
        // dropping the old state IS the paper's optimizer reset
        self.state = SparseAdamState::new(masks, &self.sizes);
        if self.state_policy == StatePolicy::Offload {
            for (li, lst) in self.state.layers.iter_mut() {
                if let Some((m, v)) = self.offloaded.remove(li) {
                    lst.m = m;
                    lst.v = v;
                }
            }
            // bias-correction step continues (restored moments are warm)
            self.state.step = prev_step;
        }
        self.n_selections += 1;
    }

    /// Step (5): refresh active layers with processed-gradient norms.
    fn refresh_processed_norms(&mut self, step: usize) {
        let t = self.state.step;
        let mut processed: Vec<(usize, f64)> = Vec::with_capacity(self.state.layers.len());
        for (li, lst) in self.state.layers.iter() {
            processed.push((*li, self.processed_norm(lst, t)));
        }
        for (li, n) in processed {
            self.dict.record_norm(li, n, step);
        }
    }

    fn step_info(&self, updated: u64, reselected: bool, probe_max: u64) -> StepInfo {
        let active_coords = self.state.active_coords();
        let mask_elems: u64 = self.state.layers.iter().map(|(_, s)| s.mask.len as u64).sum();
        // modeled grad residency: active coords + the largest probed layer
        let mem: MemBreakdown =
            profiles::blockllm(self.n_params, active_coords, active_coords + probe_max, mask_elems);
        StepInfo {
            updated_coords: updated,
            reselected,
            mem,
            active_layers: self.state.selected_layers(),
        }
    }

    /// The dense-gradient step with the patience decision already made —
    /// `step` observes the loss first; `step_selected_dense` (streaming
    /// route, accumulated selection replay) forces `will_select` without
    /// re-observing.
    fn step_inner(
        &mut self,
        store: &mut ParamStore,
        grads: &[Vec<f32>],
        will_select: bool,
        lr: f64,
        step: usize,
    ) -> StepInfo {
        // (1) dictionary refresh. At selection events Alg. 2 scores EVERY
        // layer (||G_l|| is a streaming reduction during backward — no grad
        // storage needed); between events only the active block + p sampled
        // layers are refreshed.
        let active = self.state.selected_layers();
        let probes: Vec<usize> = if will_select {
            (0..self.sizes.len()).collect()
        } else {
            self.dict.layers_to_probe(&active, self.sample_p, step)
        };
        for &l in &probes {
            self.dict.record(l, &grads[l], step);
        }
        let probe_max = probes.iter().map(|&l| self.sizes[l] as u64).max().unwrap_or(0);

        // (3) re-selection
        let mut reselected = false;
        if will_select {
            let sel = select_layers(&self.dict, &self.sizes, self.sparsity, self.rule);
            let masks = build_masks(&sel, grads, self.mask_mode);
            self.dict.mark_selected(&sel.layers);
            self.apply_selection(masks);
            reselected = true;
        }

        // (4) masked sparse Adam over the active block
        self.state.step += 1;
        let t = self.state.step;
        let mut updated = 0u64;
        for (li, lst) in self.state.layers.iter_mut() {
            let n = masked_adam_step(&mut store.bufs[*li], &grads[*li], lst, t, lr, &self.hypers);
            updated += n as u64;
        }

        self.refresh_processed_norms(step);
        self.step_info(updated, reselected, probe_max)
    }
}

impl Strategy for BlockLlmStrategy {
    fn step(
        &mut self,
        store: &mut ParamStore,
        grads: &[Vec<f32>],
        loss: f64,
        lr: f64,
        step: usize,
    ) -> StepInfo {
        // (2) patience decides whether this is a selection event
        let will_select = self.patience.observe(loss);
        self.step_inner(store, grads, will_select, lr, step)
    }

    /// Streaming retention plan. At accum == 1, compact masks over the
    /// active block suffice: every layer's norm streams through the sink's
    /// embedded `NormProbeSink`. Under accumulation, probe-layer norms need
    /// the ACCUMULATED vectors, so the probe candidates (always ⊇ the
    /// active block) are retained densely instead — the probe set is peeked
    /// with a cloned rng so the real rng advances exactly when the dense
    /// path's would (in `step_sparse`, and only on non-selection steps).
    fn sparse_plan(
        &mut self,
        _store: &ParamStore,
        grad_accum: usize,
        step: usize,
    ) -> Option<SparsePlan> {
        self.plan_accum = grad_accum.max(1);
        let retain: Vec<(usize, Retain)> = if self.plan_accum == 1 {
            self.state
                .layers
                .iter()
                .map(|(li, lst)| (*li, Retain::Mask(lst.mask.clone())))
                .collect()
        } else {
            let active = self.state.selected_layers();
            self.dict
                .peek_layers_to_probe(&active, self.sample_p, step)
                .into_iter()
                .map(|l| (l, Retain::Dense))
                .collect()
        };
        Some(SparsePlan { retain })
    }

    fn step_sparse(
        &mut self,
        store: &mut ParamStore,
        sink: &MaskedSink,
        loss: f64,
        lr: f64,
        step: usize,
    ) -> SparseOutcome {
        // (2) patience decides whether this is a selection event
        let will_select = self.patience.observe(loss);

        if will_select {
            if self.plan_accum > 1 {
                // accumulated selection: norms + masks need the accumulated
                // dense gradients — hand the step back for a dense replay
                return SparseOutcome::ReplayDense;
            }
            // (1) at selection events every layer is scored; the streamed
            // Σg² sums ARE the dense `record` reduction bit for bit
            for l in 0..self.sizes.len() {
                self.dict.record_sq(l, sink.norm_sq(l), self.sizes[l], step);
            }
            let sel = select_layers(&self.dict, &self.sizes, self.sparsity, self.rule);
            // per-layer mask recipes from selection geometry alone — the
            // replay sink resolves each against the live shard (exact
            // top-k on the same bits `build_masks` would see), so even a
            // selection step stays within active + largest-layer residency
            let retain: Vec<(usize, Retain)> = mask_plan(&sel, &self.sizes, self.mask_mode)
                .into_iter()
                .map(|(l, rule)| match rule {
                    MaskRule::All => (l, Retain::All),
                    MaskRule::TopK(k) => (l, Retain::TopK(k)),
                })
                .collect();
            self.pending = Some(sel);
            return SparseOutcome::Replay(retain);
        }

        // (1) non-selection refresh: active block + p sampled layers
        let active = self.state.selected_layers();
        let probes = self.dict.layers_to_probe(&active, self.sample_p, step);
        for &l in &probes {
            if self.plan_accum > 1 {
                let g = sink.values(l).expect("probe layer retained densely under accumulation");
                self.dict.record(l, g, step);
            } else {
                self.dict.record_sq(l, sink.norm_sq(l), self.sizes[l], step);
            }
        }
        let probe_max = probes.iter().map(|&l| self.sizes[l] as u64).max().unwrap_or(0);

        // (4) masked sparse Adam over the active block's retained coords
        self.state.step += 1;
        let t = self.state.step;
        let mut updated = 0u64;
        for (li, lst) in self.state.layers.iter_mut() {
            let g = sink.values(*li).expect("active layer retained by the plan");
            let w = &mut store.bufs[*li];
            updated += if self.plan_accum > 1 {
                masked_adam_step(w, g, lst, t, lr, &self.hypers)
            } else {
                sharded_compact_step(w, g, lst, t, lr, &self.hypers)
            } as u64;
        }

        self.refresh_processed_norms(step);
        SparseOutcome::Done(self.step_info(updated, false, probe_max))
    }

    fn step_selected(
        &mut self,
        store: &mut ParamStore,
        sink: MaskedSink,
        _loss: f64,
        lr: f64,
        step: usize,
    ) -> StepInfo {
        let sel = self.pending.take().expect("step_selected without a pending selection");
        // the replay sink resolved one mask per selected layer, in
        // mask_plan (= sel.layers) order — the list build_masks would
        // produce on the dense path, bit for bit
        let mut masks = Vec::new();
        let mut values = Vec::new();
        for e in sink.into_entries() {
            masks.push((e.idx, e.mask.expect("replay rules resolve masks on arrival")));
            values.push((e.idx, e.values));
        }
        self.dict.mark_selected(&sel.layers);
        self.apply_selection(masks);

        // (4) first masked update of the new block, from the compact values
        self.state.step += 1;
        let t = self.state.step;
        let mut updated = 0u64;
        for ((li, lst), (vi, vals)) in self.state.layers.iter_mut().zip(&values) {
            debug_assert_eq!(*li, *vi, "state/sink layer order mismatch");
            updated +=
                sharded_compact_step(&mut store.bufs[*li], vals, lst, t, lr, &self.hypers) as u64;
        }

        self.refresh_processed_norms(step);
        // selection probes every layer: the largest layer was transiently live
        let probe_max = self.sizes.iter().map(|&s| s as u64).max().unwrap_or(0);
        self.step_info(updated, true, probe_max)
    }

    fn step_selected_dense(
        &mut self,
        store: &mut ParamStore,
        grads: &[Vec<f32>],
        _loss: f64,
        lr: f64,
        step: usize,
    ) -> StepInfo {
        // the loss was observed in step_sparse; this IS the dense selection
        // branch, replayed on accumulated gradients
        self.step_inner(store, grads, true, lr, step)
    }

    fn name(&self) -> &'static str {
        match self.rule {
            SelectionRule::TopScore => "blockllm",
            SelectionRule::BottomScore => "blockllm-subopt",
            SelectionRule::TopScoreNoFreq => "blockllm-nofreq",
        }
    }

    fn modeled_grad_elems(&self, _n: u64) -> u64 {
        self.state.active_coords() + self.sizes.iter().map(|&s| s as u64).max().unwrap_or(0)
    }

    /// M+V over the sparsity budget (1-s)·n — the steady-state active set.
    /// The pre-selection state is empty, so this is the admission-control
    /// upper bound for the whole run.
    fn modeled_state_elems(&self, n: u64) -> u64 {
        2 * (((1.0 - self.sparsity) * n as f64).round() as u64).max(1)
    }

    /// Exact per-replica moment residency under the dist layer's ZeRO-style
    /// sharding, from the LIVE mask layout (not the modeled sparsity
    /// budget): each selected layer's compact state (m+v over its popcount
    /// coordinates) splits into `replicas` even `⌈c_l/r⌉` chunks, and
    /// replica 0 always holds the largest (first) chunk of every layer —
    /// so the largest single replica's share is `2·F32·Σ_l ⌈popcount_l/r⌉`.
    /// At `replicas == 1` this is the full active-state footprint; before
    /// the first selection it is 0 (no state exists yet).
    fn state_shard_bytes(&self, _n_params: u64, replicas: usize) -> u64 {
        let r = replicas.max(1) as u64;
        2 * crate::memory::F32
            * self
                .state
                .layers
                .iter()
                .map(|(_, s)| (s.mask.popcount as u64).div_ceil(r))
                .sum::<u64>()
    }

    fn state_save(&self, bag: &mut StateBag) {
        self.dict.state_save(bag, "bllm.dict");
        self.patience.state_save(bag, "bllm.pat");
        bag.put_u64("bllm.adam_step", self.state.step);
        bag.put_u64("bllm.n_selections", self.n_selections);
        bag.put_usize("bllm.n_active", self.state.layers.len());
        for (j, (li, lst)) in self.state.layers.iter().enumerate() {
            bag.put_usize(&format!("bllm.layer/{j}"), *li);
            bag.put_f32s(&format!("bllm.m/{j}"), lst.m.clone());
            bag.put_f32s(&format!("bllm.v/{j}"), lst.v.clone());
            bag.put_u64s(&format!("bllm.mask/{j}"), lst.mask.words.clone());
        }
        // Offload stash (empty under the paper's Reset policy)
        let mut off: Vec<usize> = self.offloaded.keys().copied().collect();
        off.sort_unstable();
        bag.put_u64s("bllm.off_layers", off.iter().map(|&l| l as u64).collect());
        for &li in &off {
            let (m, v) = &self.offloaded[&li];
            bag.put_f32s(&format!("bllm.off_m/{li}"), m.clone());
            bag.put_f32s(&format!("bllm.off_v/{li}"), v.clone());
        }
        // plan_accum and pending are intra-step scratch (written by
        // sparse_plan/step_sparse, consumed before the step returns) —
        // never live at a suspend boundary
    }

    fn state_load(&mut self, bag: &StateBag) -> Result<()> {
        let n_active = bag.get_usize("bllm.n_active")?;
        let mut layers = Vec::with_capacity(n_active);
        for j in 0..n_active {
            let li = bag.get_usize(&format!("bllm.layer/{j}"))?;
            let Some(&n) = self.sizes.get(li) else {
                bail!("blockllm checkpoint selects layer {li}, model has {}", self.sizes.len());
            };
            let m = bag.f32s(&format!("bllm.m/{j}"))?.to_vec();
            let v = bag.f32s(&format!("bllm.v/{j}"))?.to_vec();
            if m.len() != n || v.len() != n {
                bail!("blockllm checkpoint layer {li} has {} elems, model wants {n}", m.len());
            }
            let words = bag.u64s(&format!("bllm.mask/{j}"))?;
            if words.len() != n.div_ceil(64) {
                bail!(
                    "blockllm mask for layer {li}: {} words, want {}",
                    words.len(),
                    n.div_ceil(64)
                );
            }
            let popcount = words.iter().map(|w| w.count_ones() as usize).sum();
            let mask = BitMask { words: words.to_vec(), len: n, popcount };
            layers.push((li, LayerState { m, v, mask }));
        }
        let mut offloaded = std::collections::HashMap::new();
        for &li64 in bag.u64s("bllm.off_layers")? {
            let li = li64 as usize;
            let Some(&n) = self.sizes.get(li) else {
                bail!("blockllm offload stash names layer {li}, model has {}", self.sizes.len());
            };
            let m = bag.f32s(&format!("bllm.off_m/{li}"))?.to_vec();
            let v = bag.f32s(&format!("bllm.off_v/{li}"))?.to_vec();
            if m.len() != n || v.len() != n {
                bail!("blockllm offload stash layer {li} has {} elems, model wants {n}", m.len());
            }
            offloaded.insert(li, (m, v));
        }
        // stage dict/patience into fresh copies so an error mutates nothing
        let mut dict = self.dict.clone();
        dict.state_load(bag, "bllm.dict")?;
        let mut patience = PatienceController::new_like(&self.patience);
        patience.state_load(bag, "bllm.pat")?;
        let adam_step = bag.get_u64("bllm.adam_step")?;
        let n_selections = bag.get_u64("bllm.n_selections")?;
        self.dict = dict;
        self.patience = patience;
        self.state = SparseAdamState { layers, step: adam_step };
        self.n_selections = n_selections;
        self.offloaded = offloaded;
        self.plan_accum = 1;
        self.pending = None;
        Ok(())
    }

    fn telemetry(&self) -> Vec<(String, f64)> {
        let offload_bytes: usize =
            self.offloaded.values().map(|(m, v)| 4 * (m.len() + v.len())).sum();
        vec![
            ("n_selections".into(), self.n_selections as f64),
            ("active_coords".into(), self.state.active_coords() as f64),
            ("offloaded_host_bytes".into(), offload_bytes as f64),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::testutil;
    use crate::grads::GradSink;

    fn make(sparsity: f64, m: usize) -> BlockLlmStrategy {
        let sizes: Vec<usize> = testutil::toy_specs().iter().map(|s| s.numel()).collect();
        BlockLlmStrategy::new(
            &sizes,
            AdamHypers::default(),
            sparsity,
            m,
            1,
            SelectionRule::TopScore,
            MaskMode::Alg2,
            NormKind::Rms,
            1,
        )
    }

    #[test]
    fn first_step_selects_a_block() {
        let specs = testutil::toy_specs();
        let sizes: Vec<usize> = specs.iter().map(|s| s.numel()).collect();
        let mut s = make(0.8, 10);
        let mut store = ParamStore::init(&specs, 2);
        let grads = testutil::rand_grads(&sizes, 3);
        let info = s.step(&mut store, &grads, 5.0, 1e-3, 0);
        assert!(info.reselected);
        assert!(!info.active_layers.is_empty());
        let n: u64 = sizes.iter().map(|&x| x as u64).sum();
        let budget = (0.2 * n as f64) as u64;
        assert!(
            info.updated_coords <= budget + 64,
            "updated {} > budget {}",
            info.updated_coords,
            budget
        );
        assert!(info.updated_coords > budget / 2);
    }

    #[test]
    fn memory_scales_with_sparsity() {
        let specs = testutil::toy_specs();
        let sizes: Vec<usize> = specs.iter().map(|s| s.numel()).collect();
        let mut store = ParamStore::init(&specs, 2);
        let grads = testutil::rand_grads(&sizes, 3);
        let mut mems = Vec::new();
        for s_level in [0.5, 0.9, 0.99] {
            let mut s = make(s_level, 10);
            let info = s.step(&mut store.clone_store(), &grads, 5.0, 1e-3, 0);
            mems.push(info.mem.total());
        }
        assert!(mems[0] > mems[1] && mems[1] > mems[2], "{mems:?}");
    }

    #[test]
    fn only_selected_layers_move() {
        let specs = testutil::toy_specs();
        let sizes: Vec<usize> = specs.iter().map(|s| s.numel()).collect();
        let mut s = make(0.9, 100);
        let mut store = ParamStore::init(&specs, 2);
        let before: Vec<Vec<f32>> = store.bufs.clone();
        let grads = testutil::rand_grads(&sizes, 3);
        let info = s.step(&mut store, &grads, 5.0, 1e-2, 0);
        for li in 0..sizes.len() {
            if !info.active_layers.contains(&li) {
                assert_eq!(store.bufs[li], before[li], "inactive layer {li} moved");
            }
        }
    }

    #[test]
    fn plateau_triggers_reselection_and_state_reset() {
        let specs = testutil::toy_specs();
        let sizes: Vec<usize> = specs.iter().map(|s| s.numel()).collect();
        let mut s = make(0.8, 3);
        let mut store = ParamStore::init(&specs, 2);
        let mut reselections = 0;
        for t in 0..20 {
            let grads = testutil::rand_grads(&sizes, 50 + t as u64);
            // constant loss = permanent plateau
            let info = s.step(&mut store, &grads, 5.0, 1e-3, t);
            reselections += info.reselected as u32;
        }
        assert!(reselections >= 4, "plateau produced only {reselections} reselections");
        assert_eq!(s.n_selections as u32, reselections);
    }

    #[test]
    fn decreasing_loss_keeps_block_stable() {
        let specs = testutil::toy_specs();
        let sizes: Vec<usize> = specs.iter().map(|s| s.numel()).collect();
        let mut s = make(0.8, 3);
        let mut store = ParamStore::init(&specs, 2);
        let mut reselections = 0;
        for t in 0..30 {
            let grads = testutil::rand_grads(&sizes, 70 + t as u64);
            let info = s.step(&mut store, &grads, 10.0 - 0.3 * t as f64, 1e-3, t);
            reselections += info.reselected as u32;
        }
        assert_eq!(reselections, 1, "loss was strictly improving");
    }

    #[test]
    fn visit_frequency_rotates_blocks_under_plateau() {
        // under a plateau with symmetric gradients, the f_l term must make
        // selection visit different layers over time
        let specs = testutil::toy_specs();
        let sizes: Vec<usize> = specs.iter().map(|s| s.numel()).collect();
        let mut s = make(0.7, 1);
        let mut store = ParamStore::init(&specs, 2);
        let mut seen = std::collections::HashSet::new();
        for t in 0..40 {
            let grads = testutil::rand_grads(&sizes, 7); // same grads each step
            let info = s.step(&mut store, &grads, 5.0, 1e-9, t);
            for l in info.active_layers {
                seen.insert(l);
            }
        }
        assert!(seen.len() >= 3, "selection stuck on {seen:?}");
    }

    #[test]
    fn descends_quadratic() {
        let mut s = make(0.5, 10);
        let (before, after) = testutil::quadratic_descends(&mut s, 400);
        assert!(after < before * 0.7, "before={before} after={after}");
    }

    #[test]
    fn offload_policy_restores_state_reset_drops_it() {
        let specs = testutil::toy_specs();
        let sizes: Vec<usize> = specs.iter().map(|s| s.numel()).collect();
        // patience 1 so every plateau step reselects
        let run = |policy: StatePolicy| {
            let mut s = make(0.5, 1);
            s.state_policy = policy;
            let mut store = ParamStore::init(&specs, 2);
            let grads = testutil::rand_grads(&sizes, 3);
            for t in 0..6 {
                s.step(&mut store, &grads, 5.0, 1e-3, t); // constant loss
            }
            // moment magnitude of the active block after repeated resets
            let msum: f32 = s
                .state
                .layers
                .iter()
                .map(|(_, l)| l.m.iter().map(|x| x.abs()).sum::<f32>())
                .sum();
            (msum, s.offloaded.len(), s.state.step)
        };
        let (m_reset, stash_reset, _) = run(StatePolicy::Reset);
        let (m_off, _stash_off, step_off) = run(StatePolicy::Offload);
        assert_eq!(stash_reset, 0, "Reset must not stash anything");
        // warm restored moments accumulate across reselections -> larger
        assert!(m_off > m_reset, "offload {m_off} <= reset {m_reset}");
        assert!(step_off > 1, "offload must keep the Adam step counter");
    }

    /// THE streaming acceptance pin at the strategy level: fed identical
    /// per-microbatch shards, the dense route (`step` on accumulated
    /// gradients) and the streaming route (`sparse_plan`/`step_sparse`,
    /// with selection replays) must produce bitwise-identical parameters,
    /// dictionary norms, and telemetry — across selection events, at
    /// accum 1 (compact + streamed norms + top-k replay) and accum 3
    /// (dense probe retention + dense selection replay).
    #[test]
    fn streaming_route_matches_dense_route_bitwise() {
        let specs = testutil::toy_specs();
        let sizes: Vec<usize> = specs.iter().map(|s| s.numel()).collect();
        for accum in [1usize, 3] {
            // patience 2 + a plateau-heavy loss schedule forces several
            // mid-run selection events on both routes
            let mut dense = make(0.7, 2);
            let mut sparse = make(0.7, 2);
            let mut store_d = ParamStore::init(&specs, 2);
            let mut store_s = ParamStore::init(&specs, 2);
            let scale = 1.0 / accum as f32;
            for t in 0..12 {
                let micros: Vec<Vec<Vec<f32>>> = (0..accum)
                    .map(|k| testutil::rand_grads(&sizes, 100 + (t * accum + k) as u64))
                    .collect();
                let loss = if t % 4 == 0 { 5.0 } else { 5.0 - 0.01 * t as f64 };
                // dense route: the trainer's AccumSink arithmetic
                let acc = testutil::accum_reference(&micros, &sizes);
                let id = dense.step(&mut store_d, &acc, loss, 1e-2, t);
                // streaming route: plan -> shards through a MaskedSink
                let plan = sparse.sparse_plan(&store_s, accum, t).expect("blockllm streams");
                let mut sink = MaskedSink::new(sizes.len(), plan.retain, scale);
                for (k, m) in micros.iter().enumerate() {
                    sink.begin_micro(k == 0);
                    for (l, g) in m.iter().enumerate() {
                        sink.consume(l, g);
                    }
                }
                let is = match sparse.step_sparse(&mut store_s, &sink, loss, 1e-2, t) {
                    SparseOutcome::Done(info) => info,
                    SparseOutcome::Replay(retain) => {
                        assert_eq!(accum, 1, "compact replay only at accum 1");
                        let mut rsink = MaskedSink::new(sizes.len(), retain, scale);
                        rsink.begin_micro(true);
                        for (l, g) in micros[0].iter().enumerate() {
                            rsink.consume(l, g);
                        }
                        sparse.step_selected(&mut store_s, rsink, loss, 1e-2, t)
                    }
                    SparseOutcome::ReplayDense => {
                        assert!(accum > 1, "dense replay only under accumulation");
                        sparse.step_selected_dense(&mut store_s, &acc, loss, 1e-2, t)
                    }
                };
                assert_eq!(id.reselected, is.reselected, "step {t} accum {accum}");
                assert_eq!(id.updated_coords, is.updated_coords, "step {t} accum {accum}");
                assert_eq!(id.active_layers, is.active_layers, "step {t} accum {accum}");
                assert_eq!(id.mem, is.mem, "step {t} accum {accum}");
                for (li, (a, b)) in store_d.bufs.iter().zip(&store_s.bufs).enumerate() {
                    for (i, (x, y)) in a.iter().zip(b).enumerate() {
                        assert_eq!(
                            x.to_bits(),
                            y.to_bits(),
                            "param {li}[{i}] diverged at step {t} (accum {accum})"
                        );
                    }
                }
                for l in 0..sizes.len() {
                    assert_eq!(
                        dense.dict.norms[l].to_bits(),
                        sparse.dict.norms[l].to_bits(),
                        "dict norm {l} diverged at step {t} (accum {accum})"
                    );
                }
            }
            assert_eq!(dense.n_selections, sparse.n_selections, "accum {accum}");
            assert!(dense.n_selections >= 2, "schedule produced too few selections to test");
        }
    }

    /// Suspend/resume pin at the strategy level: save at step N (the loss
    /// schedule forces selection events both before AND after the boundary),
    /// restore into a FRESH strategy, and the resumed run must match the
    /// uninterrupted one bit for bit — params, dict norms, rng consumption,
    /// selection counts.
    #[test]
    fn state_roundtrip_is_bitwise_across_selection_events() {
        let specs = testutil::toy_specs();
        let sizes: Vec<usize> = specs.iter().map(|s| s.numel()).collect();
        for policy in [StatePolicy::Reset, StatePolicy::Offload] {
            let mut full = make(0.7, 2);
            full.state_policy = policy;
            let mut store_full = ParamStore::init(&specs, 2);
            let loss = |t: usize| if t % 4 == 0 { 5.0 } else { 5.0 - 0.01 * t as f64 };
            for t in 0..6 {
                let grads = testutil::rand_grads(&sizes, 100 + t as u64);
                full.step(&mut store_full, &grads, loss(t), 1e-2, t);
            }
            // suspend at t=6
            let mut bag = StateBag::new();
            full.state_save(&mut bag);
            let mut resumed = make(0.7, 2);
            resumed.state_policy = policy;
            resumed.state_load(&bag).unwrap();
            let mut store_res = store_full.clone_store();
            for t in 6..14 {
                let grads = testutil::rand_grads(&sizes, 100 + t as u64);
                let a = full.step(&mut store_full, &grads, loss(t), 1e-2, t);
                let b = resumed.step(&mut store_res, &grads, loss(t), 1e-2, t);
                assert_eq!(a.reselected, b.reselected, "step {t} ({policy:?})");
                assert_eq!(a.active_layers, b.active_layers, "step {t} ({policy:?})");
            }
            assert_eq!(full.n_selections, resumed.n_selections, "{policy:?}");
            assert!(full.n_selections >= 2, "schedule produced no post-resume selection");
            for (li, (a, b)) in store_full.bufs.iter().zip(&store_res.bufs).enumerate() {
                for (i, (x, y)) in a.iter().zip(b).enumerate() {
                    assert_eq!(x.to_bits(), y.to_bits(), "param {li}[{i}] diverged ({policy:?})");
                }
            }
            for l in 0..sizes.len() {
                assert_eq!(full.dict.norms[l].to_bits(), resumed.dict.norms[l].to_bits());
            }
        }
    }

    #[test]
    fn state_shard_bytes_tracks_the_live_mask_layout() {
        let specs = testutil::toy_specs();
        let sizes: Vec<usize> = specs.iter().map(|s| s.numel()).collect();
        let mut s = make(0.8, 10);
        assert_eq!(s.state_shard_bytes(0, 1), 0, "no selection yet, no state");
        let mut store = ParamStore::init(&specs, 2);
        let grads = testutil::rand_grads(&sizes, 3);
        s.step(&mut store, &grads, 5.0, 1e-3, 0);
        let full = s.state_shard_bytes(0, 1);
        let active = s.state.active_coords();
        assert_eq!(full, 2 * crate::memory::F32 * active, "r=1 is the full active state");
        let quarter = s.state_shard_bytes(0, 4);
        assert!(quarter < full, "sharding must shrink per-replica state");
        // per-layer ceil: replica 0's share exceeds an even split by at
        // most one coordinate (2 f32s) per selected layer
        let layers = s.state.layers.len() as u64;
        assert!(quarter <= full.div_ceil(4) + 2 * crate::memory::F32 * layers);
        // and r shards together always cover the whole state
        assert!(4 * quarter >= full);
    }

    #[test]
    fn subopt_picks_low_norm_layers() {
        let specs = testutil::toy_specs();
        let sizes: Vec<usize> = specs.iter().map(|s| s.numel()).collect();
        let mut top = make(0.8, 100);
        let mut bottom = make(0.8, 100);
        bottom.rule = SelectionRule::BottomScore;
        let mut store = ParamStore::init(&specs, 2);
        // layer 0 gets huge grads, others tiny
        let mut grads = testutil::rand_grads(&sizes, 3);
        for g in grads[0].iter_mut() {
            *g *= 100.0;
        }
        let it = top.step(&mut store.clone_store(), &grads, 5.0, 1e-3, 0);
        let ib = bottom.step(&mut store.clone_store(), &grads, 5.0, 1e-3, 0);
        assert!(it.active_layers.contains(&0));
        assert!(!ib.active_layers.contains(&0));
    }
}
