//! BlockLLM core (the paper's contribution, Alg. 1 + Alg. 2):
//!
//! * `scorer`   — per-layer gradient-norm dictionary with p-layer sampling
//!                and the visit-frequency term f_l,
//! * `selector` — greedy layer selection until the parameter budget
//!                n_s = (1-s)·n is covered (Alg. 2 l.2-10),
//! * `mask`     — the intra-layer top-|G̃| percentile masks (Alg. 2 l.11-18),
//! * `patience` — the loss-plateau controller that triggers re-selection
//!                (Alg. 1 l.5-8).
//!
//! The trainer wires these to the masked sparse Adam in `optim::masked_adam`.

pub mod mask;
pub mod patience;
pub mod scorer;
pub mod selector;
pub mod strategy;

pub use mask::build_masks;
pub use patience::PatienceController;
pub use scorer::{NormDictionary, ScorerMode};
pub use selector::{select_layers, Selection, SelectionRule};
