//! Byte-exact training-memory accounting — the paper's headline metric.
//!
//! The paper measures VRAM with nvidia-smi; its *argument* is arithmetic over
//! what each method materializes: weights + gradients + optimizer state
//! (+ method-specific extras like GaLore's projection matrices or LoRA's
//! adapters). We account those bytes exactly per step and report the peak,
//! which reproduces the comparison the paper makes (DESIGN.md §5).
//!
//! Two scopes are tracked:
//!   * `model`  — weights (+ LoRA adds adapter weights)
//!   * `optim`  — gradients the method must materialize simultaneously,
//!                optimizer moments, projections, masks
//! plus the actual process RSS for a ground-truth sanity line.

use crate::util::human_bytes;

pub const F32: u64 = 4;

/// One method-step's materialized-memory breakdown, in bytes.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct MemBreakdown {
    pub weights: u64,
    pub grads: u64,
    pub optim_m: u64,
    pub optim_v: u64,
    pub extra: u64, // projections (GaLore), adapters (LoRA), masks (BlockLLM)
    /// model activations the execution backend materializes host-side
    /// (native backend keeps fwd caches for its backward pass; 0 under
    /// PJRT, where they live in XLA's arena) — filled in by the trainer
    /// from `Backend::activation_bytes` so cross-backend peak-memory
    /// comparisons stay honest. Since the blocked-GEMM kernel layer the
    /// native engine reads parameters through borrowed views, so this
    /// number charges genuine activations only (weights live solely in
    /// `weights`; there are no per-use parameter clones left to model)
    pub activations: u64,
}

impl MemBreakdown {
    pub fn total(&self) -> u64 {
        self.weights + self.grads + self.optim_m + self.optim_v + self.extra + self.activations
    }
}

/// Tracks the peak breakdown over a run.
#[derive(Debug, Clone, Default)]
pub struct MemTracker {
    pub current: MemBreakdown,
    pub peak: MemBreakdown,
    pub peak_total: u64,
    pub peak_rss: u64,
    /// MEASURED peak gradient-buffer bytes: the largest number of gradient
    /// f32s simultaneously live in the trainer's sinks + the engine's
    /// transient shard, as counted by the `grads` layer at consume time.
    /// The ground-truth twin of the modeled `MemBreakdown::grads` — under
    /// the streaming path (`--grad-stream 1`) this measures
    /// ≈ active coords + largest layer for BlockLLM, vs ≈ n + largest
    /// layer on the dense path (asserted in tests/grad_check.rs).
    pub peak_grad_measured: u64,
    /// Per-replica optimizer-state bytes under the dist layer's ZeRO-style
    /// moment sharding: the LARGEST single replica's moment-shard
    /// residency at the run's `--replicas` setting (the full state bytes
    /// at `--replicas 1`). Peak over the run's steps, since a selection
    /// can change the active-coordinate layout mid-run.
    pub peak_state_shard_measured: u64,
}

impl MemTracker {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record this step's breakdown; keeps the max-total step as `peak`.
    pub fn record(&mut self, b: MemBreakdown) {
        self.current = b;
        let t = b.total();
        if t > self.peak_total {
            self.peak_total = t;
            self.peak = b;
        }
        let rss = crate::util::rss_bytes();
        if rss > self.peak_rss {
            self.peak_rss = rss;
        }
    }

    /// Record one step's measured gradient-buffer bytes (sink + shard).
    pub fn record_grad_bytes(&mut self, bytes: u64) {
        if bytes > self.peak_grad_measured {
            self.peak_grad_measured = bytes;
        }
    }

    /// Record one step's per-replica optimizer-state shard bytes (the
    /// largest replica's share at the step's replica count).
    pub fn record_state_shard_bytes(&mut self, bytes: u64) {
        if bytes > self.peak_state_shard_measured {
            self.peak_state_shard_measured = bytes;
        }
    }

    pub fn report(&self) -> String {
        let p = &self.peak;
        format!(
            "peak modeled: {} (weights {}, grads {}, m {}, v {}, extra {}, activations {}); \
             measured grad peak {}; state shard/replica {}; process RSS {}",
            human_bytes(self.peak_total),
            human_bytes(p.weights),
            human_bytes(p.grads),
            human_bytes(p.optim_m),
            human_bytes(p.optim_v),
            human_bytes(p.extra),
            human_bytes(p.activations),
            human_bytes(self.peak_grad_measured),
            human_bytes(self.peak_state_shard_measured),
            human_bytes(self.peak_rss),
        )
    }

    /// Peak modeled bytes scaled to "GB" as the paper's tables report.
    pub fn peak_gb(&self) -> f64 {
        self.peak_total as f64 / 1e9
    }
}

/// Convenience constructors for the standard method profiles. `n` = total
/// parameter count; all counts are f32 elements.
pub mod profiles {
    use super::*;

    /// Full Adam: w + g + m + v over all n.
    pub fn full_adam(n: u64) -> MemBreakdown {
        MemBreakdown {
            weights: n * F32,
            grads: n * F32,
            optim_m: n * F32,
            optim_v: n * F32,
            extra: 0,
            activations: 0,
        }
    }

    /// BlockLLM at the given active coordinate count. Gradients are
    /// materialized per-layer during the backward sweep; the simultaneous
    /// requirement is the active block's grads + the p sampled layers'
    /// largest layer (paper §Memory Efficiency). `active` = masked-in
    /// coordinates, `grad_live` = the max simultaneously-live gradient
    /// elements (active + sampled-layer), `mask_bits` over active layers.
    pub fn blockllm(n: u64, active: u64, grad_live: u64, mask_elems: u64) -> MemBreakdown {
        MemBreakdown {
            weights: n * F32,
            grads: grad_live * F32,
            optim_m: active * F32,
            optim_v: active * F32,
            extra: mask_elems / 8, // packed bitmask
            activations: 0,
        }
    }

    /// GaLore: full grads exist transiently per layer; moments live in
    /// rank-r space; projection P [m,r] per 2-D layer.
    pub fn galore(n: u64, lowrank_state: u64, proj: u64) -> MemBreakdown {
        MemBreakdown {
            weights: n * F32,
            grads: n * F32,
            optim_m: lowrank_state * F32,
            optim_v: lowrank_state * F32,
            extra: proj * F32,
            activations: 0,
        }
    }

    /// LoRA: frozen weights + adapters (weights+grads+moments on adapters
    /// only) + the materialized effective weight per step.
    pub fn lora(n: u64, adapter: u64) -> MemBreakdown {
        MemBreakdown {
            weights: (n + adapter) * F32,
            grads: adapter * F32,
            optim_m: adapter * F32,
            optim_v: adapter * F32,
            extra: 0,
            activations: 0,
        }
    }

    /// BAdam: one active block at a time, dense within the block.
    pub fn badam(n: u64, block: u64) -> MemBreakdown {
        MemBreakdown {
            weights: n * F32,
            grads: block * F32,
            optim_m: block * F32,
            optim_v: block * F32,
            extra: 0,
            activations: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::profiles::*;
    use super::*;

    #[test]
    fn full_adam_is_4n_words() {
        let b = full_adam(1000);
        assert_eq!(b.total(), 4 * 1000 * F32);
    }

    #[test]
    fn blockllm_beats_full_adam_at_sparsity() {
        let n = 1_000_000u64;
        let active = 50_000; // s = 0.95
        let bl = blockllm(n, active, active * 2, active);
        let fa = full_adam(n);
        assert!(bl.total() < fa.total() / 2, "{} vs {}", bl.total(), fa.total());
    }

    #[test]
    fn galore_between_blockllm_and_fft() {
        // at the paper's finetuning operating point (s=0.95) the ordering is
        // blockllm < galore < fft (Fig. 5)
        let n = 1_000_000u64;
        let bl = blockllm(n, 50_000, 120_000, 50_000);
        let ga = galore(n, 200_000, 60_000);
        let fa = full_adam(n);
        assert!(ga.total() < fa.total());
        assert!(bl.total() < ga.total(), "blockllm {} galore {}", bl.total(), ga.total());
    }

    #[test]
    fn tracker_keeps_peak() {
        let mut t = MemTracker::new();
        t.record(full_adam(10));
        t.record(full_adam(100));
        t.record(full_adam(50));
        assert_eq!(t.peak_total, full_adam(100).total());
        assert!(t.peak_rss > 0);
        assert!(t.report().contains("peak modeled"));
    }

    #[test]
    fn tracker_keeps_measured_grad_peak() {
        let mut t = MemTracker::new();
        t.record_grad_bytes(400);
        t.record_grad_bytes(1000);
        t.record_grad_bytes(700);
        assert_eq!(t.peak_grad_measured, 1000);
        assert!(t.report().contains("measured grad peak"));
    }

    #[test]
    fn tracker_keeps_state_shard_peak() {
        let mut t = MemTracker::new();
        t.record_state_shard_bytes(128);
        t.record_state_shard_bytes(512);
        t.record_state_shard_bytes(256);
        assert_eq!(t.peak_state_shard_measured, 512);
        assert!(t.report().contains("state shard/replica"));
    }

    #[test]
    fn activations_count_toward_total_and_preserve_ordering() {
        // the native backend charges the same activation bytes to every
        // method, so totals shift but the paper's ordering is preserved
        let act = 1_500_000u64;
        let mut bl = blockllm(1_000_000, 50_000, 120_000, 50_000);
        let mut fa = full_adam(1_000_000);
        let base_gap = fa.total() - bl.total();
        bl.activations = act;
        fa.activations = act;
        assert_eq!(bl.total(), bl.weights + bl.grads + bl.optim_m + bl.optim_v + bl.extra + act);
        assert_eq!(fa.total() - bl.total(), base_gap);
        let mut t = MemTracker::new();
        t.record(bl);
        assert_eq!(t.peak.activations, act);
        assert!(t.report().contains("activations"));
    }

    #[test]
    fn lora_charges_adapters_to_weights() {
        let b = lora(1000, 100);
        assert_eq!(b.weights, 1100 * F32);
        assert_eq!(b.grads, 100 * F32);
    }
}
