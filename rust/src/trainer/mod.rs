//! The L3 training coordinator: drives an execution `Backend` (PJRT
//! artifact or the pure-Rust native engine) for fwd/bwd, routes gradient
//! shards to the active strategy, applies updates, tracks memory and
//! wall-clock, and runs periodic evaluation.
//!
//! Gradient routing (the `grads` layer): each optimizer step collects its
//! `grad_accum` microbatches, then — when `PALLAS_GRAD_STREAM`/
//! `--grad-stream` is on and the strategy publishes a retention plan —
//! streams every backward through a compact `MaskedSink`, so gradient
//! residency is what the strategy retains plus one transient shard, never
//! an O(n) staging table. Selection events replay the step's microbatches
//! (the backend is bitwise-deterministic, so replayed shards are the same
//! bits) into whatever retention the strategy requests. Dense-math
//! strategies — and the whole trainer under `--grad-stream 0`, the parity
//! reference — stage full gradients via an `AccumSink` into lazily-
//! allocated dense buffers; the sink accumulates at shard-consume time, so
//! the former per-microbatch full `scratch` copy no longer exists on any
//! path. Both routes are bit-for-bit identical end to end
//! (tests/grad_check.rs pins loss bits + post-step params across the
//! {1,4 threads} × {accum 1,4} grid).
//!
//! The trainer is backend-agnostic: everything model-execution-specific
//! (literal marshaling, artifact resolution, activation storage) lives
//! behind `backend::Backend`. Python never runs here.

use anyhow::{Context, Result};

use crate::backend::{self, Backend, Targets};
use crate::baselines::{build, SparseOutcome, Strategy};
use crate::config::TrainConfig;
use crate::data::{ClsSource, LmStream};
use crate::grads::{AccumSink, MaskedSink};
use crate::memory::MemTracker;
use crate::metrics::{perplexity, RunLogger};
use crate::model::ParamStore;
use crate::obs::{self, Counter, Span};
use crate::optim::schedule::LrSchedule;
use crate::util::json::Json;
use crate::util::Stopwatch;

// One optimizer step's microbatches flow through `dist::drive_micros` —
// sequential at `--replicas 1` (the exact loop that used to live here),
// data-parallel over N worker replicas otherwise, bitwise identical either
// way. Every gradient route (main streaming pass, selection replays, dense
// staging) goes through that one entry point, so the per-microbatch
// protocol — and the replica fan-out — can never diverge between them.
use crate::dist::drive_micros;

/// One evaluation snapshot.
#[derive(Debug, Clone)]
pub struct EvalPoint {
    pub step: usize,
    /// mean per-token (LM) or per-example (cls) loss
    pub loss: f64,
    /// perplexity (LM) or accuracy (cls) or MSE (reg)
    pub metric: f64,
    pub preds: Vec<f64>,
    pub labels: Vec<f64>,
}

/// Everything a paper harness needs from one run.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub method: String,
    /// which execution backend ran the model ("native" | "pjrt")
    pub backend: String,
    pub train_losses: Vec<f64>,
    pub evals: Vec<EvalPoint>,
    pub peak_mem_gb: f64,
    pub peak_mem_bytes: u64,
    /// MEASURED peak gradient-buffer bytes (sink retention + the engine's
    /// transient shard, counted at consume time by the `grads` layer) —
    /// the ground-truth twin of the modeled `MemBreakdown::grads`
    pub peak_grad_bytes: u64,
    /// Per-replica optimizer-state bytes under ZeRO-style sharding: the
    /// LARGEST single replica's moment-shard residency at the run's
    /// `--replicas` setting (equals the full optimizer-state bytes at
    /// `--replicas 1`). Reported next to `peak_grad_bytes` in run JSONL,
    /// serve outcomes, and bench rows.
    pub state_shard_bytes: u64,
    /// per-run obs profile block (spans/counters/gauges since the trainer
    /// was built) — present only when `--trace`/`PALLAS_TRACE` is on
    pub profile: Option<Json>,
    pub wall_secs: f64,
    pub steps_per_sec: f64,
    pub exec_secs: f64,
    /// cumulative per-phase seconds: [param upload, backend execute,
    /// grad download, strategy update] — §Perf instrumentation
    pub phase_secs: [f64; 4],
    /// method-specific counters (Magnitude's q, BlockLLM's selection count)
    pub telemetry: Vec<(String, f64)>,
    pub final_train_loss: f64,
}

impl RunResult {
    pub fn final_eval_loss(&self) -> f64 {
        self.evals.last().map(|e| e.loss).unwrap_or(f64::NAN)
    }

    pub fn final_metric(&self) -> f64 {
        self.evals.last().map(|e| e.metric).unwrap_or(f64::NAN)
    }

    pub fn telem(&self, key: &str) -> Option<f64> {
        self.telemetry.iter().find(|(k, _)| k == key).map(|(_, v)| *v)
    }

    /// mean of the last k train losses (smoother headline number)
    pub fn tail_train_loss(&self, k: usize) -> f64 {
        let n = self.train_losses.len();
        if n == 0 {
            return f64::NAN;
        }
        let k = k.min(n);
        self.train_losses[n - k..].iter().sum::<f64>() / k as f64
    }
}

/// The trainer owns the backend, the parameter store and the strategy.
pub struct Trainer {
    pub backend: Box<dyn Backend>,
    pub cfg: TrainConfig,
    pub store: ParamStore,
    pub strategy: Box<dyn Strategy>,
    pub mem: MemTracker,
    pub logger: RunLogger,
    sched: LrSchedule,
    /// dense gradient staging, allocated LAZILY on the first step that
    /// actually takes the dense route — a streaming run (`--grad-stream 1`
    /// + a sparse-capable strategy) never materializes these O(n) buffers,
    /// which is what the measured-grad-bytes assertion in
    /// tests/grad_check.rs verifies
    grads: Vec<Vec<f32>>,
    phase_strategy: f64,
    step: usize,
    /// obs registry totals when this trainer was built — `finish` exports
    /// the delta so per-run profiles never bleed across runs in one process
    obs_base: obs::Snapshot,
}

impl Trainer {
    /// Build a trainer over a config-resolved backend (`--backend`), and
    /// initialize parameters (or adopt `warm_start`).
    pub fn open(cfg: TrainConfig, warm_start: Option<&ParamStore>) -> Result<Trainer> {
        let be = backend::open(&cfg)?;
        Self::new(be, cfg, warm_start)
    }

    /// Build a trainer over an explicit backend.
    pub fn new(
        backend: Box<dyn Backend>,
        cfg: TrainConfig,
        warm_start: Option<&ParamStore>,
    ) -> Result<Trainer> {
        let specs = backend.param_specs().to_vec();
        let mut store = ParamStore::init(&specs, cfg.seed);
        if let Some(w) = warm_start {
            let n = store.load_overlapping(w);
            if n == 0 {
                anyhow::bail!("warm start shared no tensors with the target model");
            }
        }

        let sizes: Vec<usize> = specs.iter().map(|p| p.numel()).collect();
        let names: Vec<String> = specs.iter().map(|p| p.name.clone()).collect();
        let strategy = build(&cfg, &sizes, &names);
        let sched = LrSchedule::from_config(&cfg);

        Ok(Trainer {
            backend,
            store,
            strategy,
            mem: MemTracker::new(),
            logger: RunLogger::null(),
            sched,
            grads: Vec::new(),
            phase_strategy: 0.0,
            step: 0,
            obs_base: obs::snapshot(),
            cfg,
        })
    }

    pub fn batch_shape(&self) -> (usize, usize) {
        self.backend.batch_shape()
    }

    /// 0-based optimizer step counter (the session/checkpoint position).
    pub fn step(&self) -> usize {
        self.step
    }

    /// Restore the step counter (session resume; also re-anchors the LR
    /// schedule, which is a pure function of the step).
    pub(crate) fn set_step(&mut self, s: usize) {
        self.step = s;
    }

    /// Accumulated strategy-phase seconds (session suspend carries it over).
    pub(crate) fn phase_strategy(&self) -> f64 {
        self.phase_strategy
    }

    pub(crate) fn set_phase_strategy(&mut self, secs: f64) {
        self.phase_strategy = secs;
    }

    /// Re-anchor the obs-registry baseline so per-session profiles exclude
    /// work done by OTHER sessions sharing this process (the serve
    /// scheduler re-baselines at every slice boundary).
    pub(crate) fn rebase_obs(&mut self) {
        self.obs_base = obs::snapshot();
    }

    /// Allocate the dense gradient staging table (only the dense route pays
    /// for it; streaming steps never call this).
    fn ensure_dense_grads(&mut self) {
        if self.grads.len() != self.backend.param_specs().len() {
            self.grads =
                self.backend.param_specs().iter().map(|s| vec![0.0f32; s.numel()]).collect();
        }
    }

    /// One full optimizer step over `micro` microbatches: fwd/bwd per
    /// microbatch through the gradient route the strategy supports, one
    /// strategy update, then the shared bookkeeping (memory, logging, LR
    /// schedule advance). Returns the mean microbatch loss.
    ///
    /// Routes:
    /// * **streaming** (`--grad-stream 1` + the strategy published a
    ///   `SparsePlan`): shards go through a compact `MaskedSink`; on a
    ///   selection event the strategy asks for a replay of the SAME
    ///   microbatches (deterministic backend → identical shard bits) into
    ///   either an on-arrival-masked sink or — under grad accumulation —
    ///   the dense staging table.
    /// * **dense** (everything else): an `AccumSink` accumulates scaled
    ///   shards straight into `self.grads` at consume time.
    pub(crate) fn optim_step(&mut self, micro: &[(&[i32], Targets<'_>)]) -> Result<f64> {
        let _sp_step = obs::span(Span::TrainStep);
        let accum = micro.len().max(1);
        let scale = 1.0 / accum as f32;
        let lr = self.sched.at(self.step);
        let mut grad_peak: u64 = 0;
        let mut strat_secs = 0.0f64;

        let plan = if crate::util::grad_stream() {
            self.strategy.sparse_plan(&self.store, accum, self.step)
        } else {
            None
        };

        let (mean_loss, info) = if let Some(plan) = plan {
            let n_params = self.backend.param_specs().len();
            let mut sink = MaskedSink::new(n_params, plan.retain, scale);
            let loss =
                drive_micros(self.backend.as_mut(), &self.store, micro, &mut sink)? / accum as f64;
            grad_peak = grad_peak.max(sink.peak_grad_elems());
            let t0 = std::time::Instant::now();
            let sp_strat = obs::span(Span::Strategy);
            let outcome = self.strategy.step_sparse(&mut self.store, &sink, loss, lr, self.step);
            drop(sp_strat);
            strat_secs += t0.elapsed().as_secs_f64();
            let info = match outcome {
                SparseOutcome::Done(info) => info,
                SparseOutcome::Replay(retain) => {
                    // selection event: replay into on-arrival masks so even
                    // this step stays within active + largest-layer bytes.
                    // On-arrival TopK masks only describe a step gradient
                    // when the shard IS the step gradient — the streaming
                    // contract routes accumulated selections through
                    // ReplayDense instead.
                    assert_eq!(micro.len(), 1, "SparseOutcome::Replay requires accum == 1");
                    // The first pass's retention is dead now — drop it
                    // BEFORE the replay sink exists, so the measured peak
                    // (max over sinks, never their sum) matches the true
                    // simultaneous residency
                    drop(sink);
                    obs::add(Counter::ReplayEvents, 1);
                    let sp_replay = obs::span(Span::Replay);
                    let mut rsink = MaskedSink::new(n_params, retain, scale);
                    drive_micros(self.backend.as_mut(), &self.store, micro, &mut rsink)?;
                    drop(sp_replay);
                    grad_peak = grad_peak.max(rsink.peak_grad_elems());
                    let t1 = std::time::Instant::now();
                    let sp_strat = obs::span(Span::Strategy);
                    let info =
                        self.strategy.step_selected(&mut self.store, rsink, loss, lr, self.step);
                    drop(sp_strat);
                    strat_secs += t1.elapsed().as_secs_f64();
                    info
                }
                SparseOutcome::ReplayDense => {
                    // accumulated selection: norms/masks need the
                    // accumulated dense gradients — one dense-path step
                    drop(sink);
                    obs::add(Counter::ReplayDenseEvents, 1);
                    self.ensure_dense_grads();
                    {
                        let _sp_replay = obs::span(Span::Replay);
                        let mut dsink = AccumSink::new(&mut self.grads, scale);
                        drive_micros(self.backend.as_mut(), &self.store, micro, &mut dsink)?;
                        grad_peak = grad_peak.max(dsink.peak_grad_elems());
                    }
                    let t1 = std::time::Instant::now();
                    let _sp_strat = obs::span(Span::Strategy);
                    let info = self.strategy.step_selected_dense(
                        &mut self.store,
                        &self.grads,
                        loss,
                        lr,
                        self.step,
                    );
                    strat_secs += t1.elapsed().as_secs_f64();
                    // a dense replay costs ONE step of dense-path memory:
                    // release the staging table so the streaming run
                    // returns to compact residency afterwards
                    self.grads = Vec::new();
                    info
                }
            };
            (loss, info)
        } else {
            self.ensure_dense_grads();
            let loss;
            {
                let mut dsink = AccumSink::new(&mut self.grads, scale);
                loss = drive_micros(self.backend.as_mut(), &self.store, micro, &mut dsink)?
                    / accum as f64;
                grad_peak = grad_peak.max(dsink.peak_grad_elems());
            }
            let t0 = std::time::Instant::now();
            let sp_strat = obs::span(Span::Strategy);
            let info = self.strategy.step(&mut self.store, &self.grads, loss, lr, self.step);
            drop(sp_strat);
            strat_secs += t0.elapsed().as_secs_f64();
            (loss, info)
        };

        self.phase_strategy += strat_secs;
        if info.reselected {
            obs::add(Counter::SelectionEvents, 1);
        }
        self.backend.params_updated(&info.active_layers);
        let mut mem = info.mem;
        mem.activations = self.backend.activation_bytes();
        self.mem.record(mem);
        let grad_bytes = grad_peak * crate::memory::F32;
        self.mem.record_grad_bytes(grad_bytes);
        let n_params: u64 = self.backend.param_specs().iter().map(|s| s.numel() as u64).sum();
        self.mem.record_state_shard_bytes(
            self.strategy.state_shard_bytes(n_params, crate::util::replicas()),
        );
        self.logger.log(&Json::obj(vec![
            ("step", Json::num(self.step as f64)),
            ("loss", Json::num(mean_loss)),
            ("lr", Json::num(lr)),
            ("updated", Json::num(info.updated_coords as f64)),
            ("reselected", Json::Bool(info.reselected)),
            ("mem_gb", Json::num(mem.total() as f64 / 1e9)),
            ("grad_bytes", Json::num(grad_bytes as f64)),
        ]));
        self.step += 1;
        Ok(mean_loss)
    }

    /// Single externally-driven LM step (bench harness entry point).
    pub fn bench_step(&mut self, batch: &crate::data::LmBatch) -> Result<f64> {
        self.optim_step(&[(batch.tokens.as_slice(), Targets::Lm(&batch.targets))])
    }

    /// Externally-driven accumulated LM step over the given microbatches
    /// (tests + bench harness). Returns the mean loss.
    pub fn bench_accum_step(&mut self, micro: &[crate::data::LmBatch]) -> Result<f64> {
        let step: Vec<(&[i32], Targets<'_>)> = micro
            .iter()
            .map(|b| (b.tokens.as_slice(), Targets::Lm(b.targets.as_slice())))
            .collect();
        self.optim_step(&step)
    }

    /// Train on an LM stream for `steps`, evaluating every `eval_every`.
    /// With cfg.grad_accum > 1 each optimizer step consumes that many
    /// microbatches (mean loss / mean gradients).
    pub fn train_lm(
        &mut self,
        train: &mut dyn LmStream,
        eval: &mut dyn LmStream,
    ) -> Result<RunResult> {
        let (b, t) = self.batch_shape();
        let sw = Stopwatch::start();
        let mut train_losses = Vec::with_capacity(self.cfg.steps);
        let mut evals = Vec::new();
        let exec0 = self.backend.exec_secs();
        let accum = self.cfg.grad_accum.max(1);
        for s in 0..self.cfg.steps {
            // draw the step's microbatches up front: selection events may
            // replay them (the data is tiny next to one gradient buffer)
            let batches: Vec<crate::data::LmBatch> =
                (0..accum).map(|_| train.next_batch(b, t)).collect();
            let micro: Vec<(&[i32], Targets<'_>)> = batches
                .iter()
                .map(|ba| (ba.tokens.as_slice(), Targets::Lm(ba.targets.as_slice())))
                .collect();
            let mean_loss = self.optim_step(&micro)?;
            train_losses.push(mean_loss);
            if self.cfg.eval_every > 0 && (s + 1) % self.cfg.eval_every == 0 {
                evals.push(self.eval_lm(eval).context("eval")?);
            }
        }
        if evals.is_empty() || evals.last().map(|e| e.step) != Some(self.step) {
            evals.push(self.eval_lm(eval)?);
        }
        Ok(self.finish(train_losses, evals, sw.secs(), self.backend.exec_secs() - exec0))
    }

    /// LM evaluation: aggregate (loss_sum, valid_count) over eval batches.
    pub fn eval_lm(&mut self, eval: &mut dyn LmStream) -> Result<EvalPoint> {
        let (b, t) = self.batch_shape();
        let mut loss_sum = 0.0f64;
        let mut count = 0.0f64;
        for _ in 0..self.cfg.eval_batches {
            let batch = eval.next_batch(b, t);
            let out = self
                .backend
                .eval_batch(&self.store, &batch.tokens, Targets::Lm(&batch.targets))?;
            loss_sum += out.loss_sum;
            count += out.aux;
        }
        let mean = loss_sum / count.max(1.0);
        Ok(EvalPoint {
            step: self.step,
            loss: mean,
            metric: perplexity(loss_sum, count),
            preds: Vec::new(),
            labels: Vec::new(),
        })
    }

    /// Train on a classification/regression source. Honors
    /// `cfg.grad_accum` exactly like `train_lm` (each optimizer step
    /// consumes that many microbatches, mean loss / mean gradients — this
    /// path used to silently hardcode accumulation off).
    pub fn train_cls(&mut self, src: &mut dyn ClsSource) -> Result<RunResult> {
        let (b, t) = self.batch_shape();
        let sw = Stopwatch::start();
        let mut train_losses = Vec::with_capacity(self.cfg.steps);
        let mut evals = Vec::new();
        let exec0 = self.backend.exec_secs();
        let regression = src.regression();
        let accum = self.cfg.grad_accum.max(1);
        for s in 0..self.cfg.steps {
            let batches: Vec<crate::data::ClsBatch> =
                (0..accum).map(|_| src.batch(b, t, true)).collect();
            let micro: Vec<(&[i32], Targets<'_>)> = batches
                .iter()
                .map(|ba| {
                    let tg = if regression {
                        Targets::Reg(ba.labels_f.as_slice())
                    } else {
                        Targets::Cls(ba.labels_i.as_slice())
                    };
                    (ba.tokens.as_slice(), tg)
                })
                .collect();
            let loss = self.optim_step(&micro)?;
            train_losses.push(loss);
            if self.cfg.eval_every > 0 && (s + 1) % self.cfg.eval_every == 0 {
                evals.push(self.eval_cls(src)?);
            }
        }
        if evals.is_empty() || evals.last().map(|e| e.step) != Some(self.step) {
            evals.push(self.eval_cls(src)?);
        }
        Ok(self.finish(train_losses, evals, sw.secs(), self.backend.exec_secs() - exec0))
    }

    /// Classification eval: (loss_sum, metric_sum, preds) per batch.
    pub fn eval_cls(&mut self, src: &mut dyn ClsSource) -> Result<EvalPoint> {
        let (b, t) = self.batch_shape();
        let regression = src.regression();
        let mut loss_sum = 0.0;
        let mut metric_sum = 0.0;
        let mut n = 0.0;
        let mut preds = Vec::new();
        let mut labels = Vec::new();
        for _ in 0..self.cfg.eval_batches {
            let batch = src.batch(b, t, false);
            let out = if regression {
                self.backend
                    .eval_batch(&self.store, &batch.tokens, Targets::Reg(&batch.labels_f))?
            } else {
                self.backend
                    .eval_batch(&self.store, &batch.tokens, Targets::Cls(&batch.labels_i))?
            };
            loss_sum += out.loss_sum;
            metric_sum += out.aux;
            preds.extend(out.preds.iter().map(|&x| x as f64));
            if regression {
                labels.extend(batch.labels_f.iter().map(|&x| x as f64));
            } else {
                labels.extend(batch.labels_i.iter().map(|&x| x as f64));
            }
            n += b as f64;
        }
        // metric: accuracy (cls) or MSE (reg) — both are sum / n
        let metric = metric_sum / n;
        Ok(EvalPoint { step: self.step, loss: loss_sum / n, metric, preds, labels })
    }

    pub(crate) fn finish(
        &mut self,
        train_losses: Vec<f64>,
        evals: Vec<EvalPoint>,
        wall: f64,
        exec_secs: f64,
    ) -> RunResult {
        let bp = self.backend.phase_secs();
        // per-run profile: registry delta since construction, exported as
        // the stderr table + a `profile` JSONL record + a RunResult block
        let profile = if obs::on() {
            let d = obs::delta(&self.obs_base);
            obs::export::print_table(&d, wall);
            let p = obs::export::profile_json(&d);
            self.logger.log(&Json::obj(vec![("profile", p.clone())]));
            Some(p)
        } else {
            None
        };
        RunResult {
            profile,
            method: self.strategy.name().to_string(),
            backend: self.backend.name().to_string(),
            final_train_loss: *train_losses.last().unwrap_or(&f64::NAN),
            steps_per_sec: train_losses.len() as f64 / wall.max(1e-9),
            peak_mem_gb: self.mem.peak_gb(),
            peak_mem_bytes: self.mem.peak_total,
            peak_grad_bytes: self.mem.peak_grad_measured,
            state_shard_bytes: self.mem.peak_state_shard_measured,
            wall_secs: wall,
            exec_secs,
            phase_secs: [bp[0], bp[1], bp[2], self.phase_strategy],
            telemetry: self.strategy.telemetry(),
            train_losses,
            evals,
        }
    }
}
