//! The L3 training coordinator: executes the AOT fwd/bwd artifact, routes
//! gradients to the active strategy, applies updates, tracks memory and
//! wall-clock, and runs periodic evaluation.
//!
//! Python never runs here — the artifact was lowered once by `make
//! artifacts`; this loop is pure Rust + PJRT.

use anyhow::{bail, Context, Result};

use crate::baselines::{build, Strategy};
use crate::config::{Task, TrainConfig};
use crate::data::{ClsSource, LmStream};
use crate::memory::MemTracker;
use crate::metrics::{perplexity, RunLogger};
use crate::model::ParamStore;
use crate::optim::schedule::LrSchedule;
use crate::runtime::{copy_f32_into, lit_f32, lit_i32, scalar_f32, ArtifactInfo, Runtime};
use crate::util::json::Json;
use crate::util::Stopwatch;

/// One evaluation snapshot.
#[derive(Debug, Clone)]
pub struct EvalPoint {
    pub step: usize,
    /// mean per-token (LM) or per-example (cls) loss
    pub loss: f64,
    /// perplexity (LM) or accuracy (cls) or MSE (reg)
    pub metric: f64,
    pub preds: Vec<f64>,
    pub labels: Vec<f64>,
}

/// Everything a paper harness needs from one run.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub method: String,
    pub train_losses: Vec<f64>,
    pub evals: Vec<EvalPoint>,
    pub peak_mem_gb: f64,
    pub peak_mem_bytes: u64,
    pub wall_secs: f64,
    pub steps_per_sec: f64,
    pub exec_secs: f64,
    /// cumulative per-phase seconds: [param upload, XLA execute,
    /// grad download, strategy update] — §Perf instrumentation
    pub phase_secs: [f64; 4],
    /// method-specific counters (Magnitude's q, BlockLLM's selection count)
    pub telemetry: Vec<(String, f64)>,
    pub final_train_loss: f64,
}

impl RunResult {
    pub fn final_eval_loss(&self) -> f64 {
        self.evals.last().map(|e| e.loss).unwrap_or(f64::NAN)
    }

    pub fn final_metric(&self) -> f64 {
        self.evals.last().map(|e| e.metric).unwrap_or(f64::NAN)
    }

    pub fn telem(&self, key: &str) -> Option<f64> {
        self.telemetry.iter().find(|(k, _)| k == key).map(|(_, v)| *v)
    }

    /// mean of the last k train losses (smoother headline number)
    pub fn tail_train_loss(&self, k: usize) -> f64 {
        let n = self.train_losses.len();
        if n == 0 {
            return f64::NAN;
        }
        let k = k.min(n);
        self.train_losses[n - k..].iter().sum::<f64>() / k as f64
    }
}

/// The trainer owns the runtime, the parameter store and the strategy.
pub struct Trainer<'rt> {
    pub rt: &'rt mut Runtime,
    pub cfg: TrainConfig,
    pub store: ParamStore,
    pub strategy: Box<dyn Strategy>,
    pub mem: MemTracker,
    pub logger: RunLogger,
    train_art: ArtifactInfo,
    eval_art: ArtifactInfo,
    sched: LrSchedule,
    grads: Vec<Vec<f32>>,
    /// persistent input literals for the parameters: built once, refreshed
    /// in place (copy_raw_from) only for layers the strategy touched — the
    /// first hot-path optimization recorded in EXPERIMENTS.md §Perf
    param_lits: Vec<xla::Literal>,
    dirty: Vec<bool>,
    phase_secs: [f64; 4],
    step: usize,
}

impl<'rt> Trainer<'rt> {
    /// Build a trainer for a config; resolves artifacts from the manifest
    /// and initializes parameters (or adopts `warm_start`).
    pub fn new(
        rt: &'rt mut Runtime,
        cfg: TrainConfig,
        warm_start: Option<&ParamStore>,
    ) -> Result<Trainer<'rt>> {
        let head = match cfg.task {
            Task::C4Pretrain | Task::AlpacaFinetune => "lm".to_string(),
            Task::Glue(i) => {
                let g = crate::data::gluesim::GlueSim::new(i, cfg.seed);
                if g.regression() { "reg".into() } else { "cls".into() }
            }
            Task::DomainShift => "cls".into(),
        };
        let n_out = match cfg.task {
            Task::Glue(i) => crate::data::gluesim::GlueSim::new(i, cfg.seed).n_classes(),
            Task::DomainShift => 2,
            _ => 0,
        };
        let find = |phase: &str| -> Result<ArtifactInfo> {
            let cands: Vec<&ArtifactInfo> = rt
                .manifest
                .artifacts
                .values()
                .filter(|a| {
                    a.preset == cfg.preset
                        && a.head == head
                        && a.kind.ends_with(phase)
                        && a.pallas == cfg.use_pallas_artifact
                        && (head == "lm" || a.n_out == n_out.max(1))
                })
                .collect();
            match cands.first() {
                Some(a) => Ok((*a).clone()),
                None => bail!(
                    "no artifact preset={} head={head} n_out={n_out} phase={phase} pallas={} — run `make artifacts`",
                    cfg.preset, cfg.use_pallas_artifact
                ),
            }
        };
        let train_art = find("train")?;
        let eval_art = find("eval")?;

        let mut store = ParamStore::init(&train_art.params, cfg.seed);
        if let Some(w) = warm_start {
            let n = store.load_overlapping(w);
            if n == 0 {
                bail!("warm start shared no tensors with the target model");
            }
        }

        let sizes: Vec<usize> = train_art.params.iter().map(|p| p.numel()).collect();
        let names: Vec<String> = train_art.params.iter().map(|p| p.name.clone()).collect();
        let strategy = build(&cfg, &sizes, &names);
        let sched = if cfg.cosine_lr {
            let min_frac = match cfg.task {
                Task::C4Pretrain => 0.1, // paper App. A.7
                _ => 0.0,                // paper App. A.6
            };
            LrSchedule::cosine(cfg.lr, cfg.steps, cfg.warmup_frac, min_frac)
        } else {
            LrSchedule::constant(cfg.lr)
        };

        let param_lits = store.to_literals()?;
        let n_tensors = store.n_tensors();
        Ok(Trainer {
            rt,
            store,
            strategy,
            mem: MemTracker::new(),
            logger: RunLogger::null(),
            train_art,
            eval_art,
            sched,
            grads: sizes.iter().map(|&n| vec![0.0f32; n]).collect(),
            param_lits,
            dirty: vec![false; n_tensors],
            phase_secs: [0.0; 4],
            step: 0,
            cfg,
        })
    }

    /// Refresh the persistent parameter literals for layers marked dirty.
    fn sync_param_lits(&mut self) -> Result<()> {
        for (i, d) in self.dirty.iter_mut().enumerate() {
            if *d {
                self.param_lits[i]
                    .copy_raw_from::<f32>(&self.store.bufs[i])
                    .map_err(|e| anyhow::anyhow!("param upload {i}: {e}"))?;
                *d = false;
            }
        }
        Ok(())
    }

    /// Mark layers updated by the strategy (empty slice = all layers).
    fn mark_dirty(&mut self, active: &[usize]) {
        if active.is_empty() {
            self.dirty.iter_mut().for_each(|d| *d = true);
        } else {
            for &l in active {
                self.dirty[l] = true;
            }
        }
    }

    pub fn batch_shape(&self) -> (usize, usize) {
        (self.train_art.batch, self.train_art.seq)
    }

    /// Single externally-driven LM step (bench harness entry point).
    pub fn bench_step(&mut self, batch: &crate::data::LmBatch) -> Result<f64> {
        let (b, t) = self.batch_shape();
        let tgt = lit_i32(&batch.targets, &[b, t])?;
        self.step_lm_like(&batch.tokens, tgt)
    }

    /// Externally-driven accumulated LM step over the given microbatches
    /// (tests + bench harness). Returns the mean loss.
    pub fn bench_accum_step(&mut self, micro: &[crate::data::LmBatch]) -> Result<f64> {
        let (b, t) = self.batch_shape();
        let scale = 1.0 / micro.len() as f32;
        let mut mean_loss = 0.0;
        for (k, batch) in micro.iter().enumerate() {
            let tgt = lit_i32(&batch.targets, &[b, t])?;
            mean_loss += self.forward_backward(&batch.tokens, &tgt, k == 0, scale)?;
        }
        mean_loss /= micro.len() as f64;
        let t3 = std::time::Instant::now();
        let lr = self.sched.at(self.step);
        let info = self.strategy.step(&mut self.store, &self.grads, mean_loss, lr, self.step);
        self.phase_secs[3] += t3.elapsed().as_secs_f64();
        self.mark_dirty(&info.active_layers);
        self.mem.record(info.mem);
        self.step += 1;
        Ok(mean_loss)
    }

    /// One fwd/bwd microbatch: execute the train artifact and accumulate
    /// the scaled gradients into `self.grads` (`first` resets the
    /// accumulator; `scale` = 1/grad_accum). Returns the microbatch loss.
    fn forward_backward(
        &mut self,
        tokens: &[i32],
        tgt_lit: &xla::Literal,
        first: bool,
        scale: f32,
    ) -> Result<f64> {
        let (b, t) = (self.train_art.batch, self.train_art.seq);
        let t0 = std::time::Instant::now();
        self.sync_param_lits()?;
        let tok_lit = lit_i32(tokens, &[b, t])?;
        let t1 = std::time::Instant::now();
        let outs = {
            let mut inputs: Vec<&xla::Literal> = self.param_lits.iter().collect();
            inputs.push(&tok_lit);
            inputs.push(tgt_lit);
            self.rt.execute(&self.train_art.id, &inputs)?
        };
        let t2 = std::time::Instant::now();
        if outs.len() != 1 + self.grads.len() {
            bail!("artifact returned {} outputs, want {}", outs.len(), 1 + self.grads.len());
        }
        let loss = scalar_f32(&outs[0])? as f64;
        let mut tmp = Vec::new();
        for (g, o) in self.grads.iter_mut().zip(&outs[1..]) {
            if first && scale == 1.0 {
                copy_f32_into(o, g)?;
            } else {
                copy_f32_into(o, &mut tmp)?;
                if first {
                    g.iter_mut().zip(&tmp).for_each(|(gi, &x)| *gi = scale * x);
                } else {
                    g.iter_mut().zip(&tmp).for_each(|(gi, &x)| *gi += scale * x);
                }
            }
        }
        let t3 = std::time::Instant::now();
        self.phase_secs[0] += (t1 - t0).as_secs_f64();
        self.phase_secs[1] += (t2 - t1).as_secs_f64();
        self.phase_secs[2] += (t3 - t2).as_secs_f64();
        Ok(loss)
    }

    /// Execute the train artifact on (tokens, targets-as-i32) and apply one
    /// strategy step. Returns the train loss.
    fn step_lm_like(&mut self, tokens: &[i32], tgt_lit: xla::Literal) -> Result<f64> {
        let loss = self.forward_backward(tokens, &tgt_lit, true, 1.0)?;
        let t3 = std::time::Instant::now();
        let lr = self.sched.at(self.step);
        let info = self.strategy.step(&mut self.store, &self.grads, loss, lr, self.step);
        let t4 = std::time::Instant::now();
        self.phase_secs[3] += (t4 - t3).as_secs_f64();
        self.mark_dirty(&info.active_layers);
        self.mem.record(info.mem);
        self.logger.log(&Json::obj(vec![
            ("step", Json::num(self.step as f64)),
            ("loss", Json::num(loss)),
            ("lr", Json::num(lr)),
            ("updated", Json::num(info.updated_coords as f64)),
            ("reselected", Json::Bool(info.reselected)),
            ("mem_gb", Json::num(info.mem.total() as f64 / 1e9)),
        ]));
        self.step += 1;
        Ok(loss)
    }

    /// Train on an LM stream for `steps`, evaluating every `eval_every`.
    /// With cfg.grad_accum > 1 each optimizer step consumes that many
    /// microbatches (mean loss / mean gradients).
    pub fn train_lm(
        &mut self,
        train: &mut dyn LmStream,
        eval: &mut dyn LmStream,
    ) -> Result<RunResult> {
        let (b, t) = self.batch_shape();
        let sw = Stopwatch::start();
        let mut train_losses = Vec::with_capacity(self.cfg.steps);
        let mut evals = Vec::new();
        let exec0 = self.rt.exec_secs;
        let accum = self.cfg.grad_accum.max(1);
        for s in 0..self.cfg.steps {
            let loss = if accum == 1 {
                let batch = train.next_batch(b, t);
                let tgt = lit_i32(&batch.targets, &[b, t])?;
                self.step_lm_like(&batch.tokens, tgt)?
            } else {
                let scale = 1.0 / accum as f32;
                let mut mean_loss = 0.0;
                for k in 0..accum {
                    let batch = train.next_batch(b, t);
                    let tgt = lit_i32(&batch.targets, &[b, t])?;
                    mean_loss += self.forward_backward(&batch.tokens, &tgt, k == 0, scale)?;
                }
                mean_loss /= accum as f64;
                let t3 = std::time::Instant::now();
                let lr = self.sched.at(self.step);
                let info =
                    self.strategy.step(&mut self.store, &self.grads, mean_loss, lr, self.step);
                self.phase_secs[3] += t3.elapsed().as_secs_f64();
                self.mark_dirty(&info.active_layers);
                self.mem.record(info.mem);
                self.step += 1;
                mean_loss
            };
            train_losses.push(loss);
            if self.cfg.eval_every > 0 && (s + 1) % self.cfg.eval_every == 0 {
                evals.push(self.eval_lm(eval).context("eval")?);
            }
        }
        if evals.is_empty() || evals.last().map(|e| e.step) != Some(self.step) {
            evals.push(self.eval_lm(eval)?);
        }
        Ok(self.finish(train_losses, evals, sw.secs(), self.rt.exec_secs - exec0))
    }

    /// LM evaluation: aggregate (loss_sum, valid_count) over eval batches.
    pub fn eval_lm(&mut self, eval: &mut dyn LmStream) -> Result<EvalPoint> {
        let (b, t) = (self.eval_art.batch, self.eval_art.seq);
        let mut loss_sum = 0.0f64;
        let mut count = 0.0f64;
        self.sync_param_lits()?;
        for _ in 0..self.cfg.eval_batches {
            let batch = eval.next_batch(b, t);
            let tok_lit = lit_i32(&batch.tokens, &[b, t])?;
            let tgt_lit = lit_i32(&batch.targets, &[b, t])?;
            let mut inputs: Vec<&xla::Literal> = self.param_lits.iter().collect();
            inputs.push(&tok_lit);
            inputs.push(&tgt_lit);
            let outs = self.rt.execute(&self.eval_art.id, &inputs)?;
            loss_sum += scalar_f32(&outs[0])? as f64;
            count += scalar_f32(&outs[1])? as f64;
        }
        let mean = loss_sum / count.max(1.0);
        Ok(EvalPoint {
            step: self.step,
            loss: mean,
            metric: perplexity(loss_sum, count),
            preds: Vec::new(),
            labels: Vec::new(),
        })
    }

    /// Train on a classification/regression source.
    pub fn train_cls(&mut self, src: &mut dyn ClsSource) -> Result<RunResult> {
        let (b, t) = self.batch_shape();
        let sw = Stopwatch::start();
        let mut train_losses = Vec::with_capacity(self.cfg.steps);
        let mut evals = Vec::new();
        let exec0 = self.rt.exec_secs;
        let regression = src.regression();
        for s in 0..self.cfg.steps {
            let batch = src.batch(b, t, true);
            let tgt = if regression {
                lit_f32(&batch.labels_f, &[b])?
            } else {
                lit_i32(&batch.labels_i, &[b])?
            };
            let loss = self.step_lm_like(&batch.tokens, tgt)?;
            train_losses.push(loss);
            if self.cfg.eval_every > 0 && (s + 1) % self.cfg.eval_every == 0 {
                evals.push(self.eval_cls(src)?);
            }
        }
        if evals.is_empty() || evals.last().map(|e| e.step) != Some(self.step) {
            evals.push(self.eval_cls(src)?);
        }
        Ok(self.finish(train_losses, evals, sw.secs(), self.rt.exec_secs - exec0))
    }

    /// Classification eval: (loss_sum, metric_sum, preds) per batch.
    pub fn eval_cls(&mut self, src: &mut dyn ClsSource) -> Result<EvalPoint> {
        let (b, t) = (self.eval_art.batch, self.eval_art.seq);
        let regression = src.regression();
        let mut loss_sum = 0.0;
        let mut metric_sum = 0.0;
        let mut n = 0.0;
        let mut preds = Vec::new();
        let mut labels = Vec::new();
        self.sync_param_lits()?;
        for _ in 0..self.cfg.eval_batches {
            let batch = src.batch(b, t, false);
            let tok_lit = lit_i32(&batch.tokens, &[b, t])?;
            let tgt_lit = if regression {
                lit_f32(&batch.labels_f, &[b])?
            } else {
                lit_i32(&batch.labels_i, &[b])?
            };
            let mut inputs: Vec<&xla::Literal> = self.param_lits.iter().collect();
            inputs.push(&tok_lit);
            inputs.push(&tgt_lit);
            let outs = self.rt.execute(&self.eval_art.id, &inputs)?;
            loss_sum += scalar_f32(&outs[0])? as f64;
            metric_sum += scalar_f32(&outs[1])? as f64;
            let p = outs[2].to_vec::<f32>().map_err(|e| anyhow::anyhow!("preds: {e}"))?;
            preds.extend(p.iter().map(|&x| x as f64));
            if regression {
                labels.extend(batch.labels_f.iter().map(|&x| x as f64));
            } else {
                labels.extend(batch.labels_i.iter().map(|&x| x as f64));
            }
            n += b as f64;
        }
        let metric = if regression {
            metric_sum / n // MSE
        } else {
            metric_sum / n // accuracy
        };
        Ok(EvalPoint { step: self.step, loss: loss_sum / n, metric, preds, labels })
    }

    fn finish(
        &mut self,
        train_losses: Vec<f64>,
        evals: Vec<EvalPoint>,
        wall: f64,
        exec_secs: f64,
    ) -> RunResult {
        RunResult {
            method: self.strategy.name().to_string(),
            final_train_loss: *train_losses.last().unwrap_or(&f64::NAN),
            steps_per_sec: train_losses.len() as f64 / wall.max(1e-9),
            peak_mem_gb: self.mem.peak_gb(),
            peak_mem_bytes: self.mem.peak_total,
            wall_secs: wall,
            exec_secs,
            phase_secs: self.phase_secs,
            telemetry: self.strategy.telemetry(),
            train_losses,
            evals,
        }
    }
}
