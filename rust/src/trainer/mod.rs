//! The L3 training coordinator: drives an execution `Backend` (PJRT
//! artifact or the pure-Rust native engine) for fwd/bwd, routes gradients
//! to the active strategy, applies updates, tracks memory and wall-clock,
//! and runs periodic evaluation.
//!
//! The trainer is backend-agnostic: everything model-execution-specific
//! (literal marshaling, artifact resolution, activation storage) lives
//! behind `backend::Backend`. Python never runs here.

use anyhow::{Context, Result};

use crate::backend::{self, Backend, Targets};
use crate::baselines::{build, Strategy};
use crate::config::{Task, TrainConfig};
use crate::data::{ClsSource, LmStream};
use crate::memory::MemTracker;
use crate::metrics::{perplexity, RunLogger};
use crate::model::ParamStore;
use crate::optim::schedule::LrSchedule;
use crate::util::json::Json;
use crate::util::Stopwatch;

/// One evaluation snapshot.
#[derive(Debug, Clone)]
pub struct EvalPoint {
    pub step: usize,
    /// mean per-token (LM) or per-example (cls) loss
    pub loss: f64,
    /// perplexity (LM) or accuracy (cls) or MSE (reg)
    pub metric: f64,
    pub preds: Vec<f64>,
    pub labels: Vec<f64>,
}

/// Everything a paper harness needs from one run.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub method: String,
    /// which execution backend ran the model ("native" | "pjrt")
    pub backend: String,
    pub train_losses: Vec<f64>,
    pub evals: Vec<EvalPoint>,
    pub peak_mem_gb: f64,
    pub peak_mem_bytes: u64,
    pub wall_secs: f64,
    pub steps_per_sec: f64,
    pub exec_secs: f64,
    /// cumulative per-phase seconds: [param upload, backend execute,
    /// grad download, strategy update] — §Perf instrumentation
    pub phase_secs: [f64; 4],
    /// method-specific counters (Magnitude's q, BlockLLM's selection count)
    pub telemetry: Vec<(String, f64)>,
    pub final_train_loss: f64,
}

impl RunResult {
    pub fn final_eval_loss(&self) -> f64 {
        self.evals.last().map(|e| e.loss).unwrap_or(f64::NAN)
    }

    pub fn final_metric(&self) -> f64 {
        self.evals.last().map(|e| e.metric).unwrap_or(f64::NAN)
    }

    pub fn telem(&self, key: &str) -> Option<f64> {
        self.telemetry.iter().find(|(k, _)| k == key).map(|(_, v)| *v)
    }

    /// mean of the last k train losses (smoother headline number)
    pub fn tail_train_loss(&self, k: usize) -> f64 {
        let n = self.train_losses.len();
        if n == 0 {
            return f64::NAN;
        }
        let k = k.min(n);
        self.train_losses[n - k..].iter().sum::<f64>() / k as f64
    }
}

/// The trainer owns the backend, the parameter store and the strategy.
pub struct Trainer {
    pub backend: Box<dyn Backend>,
    pub cfg: TrainConfig,
    pub store: ParamStore,
    pub strategy: Box<dyn Strategy>,
    pub mem: MemTracker,
    pub logger: RunLogger,
    sched: LrSchedule,
    grads: Vec<Vec<f32>>,
    /// per-microbatch gradient staging, allocated lazily on the first
    /// accumulated step (the accum=1 hot path writes `grads` directly)
    scratch: Vec<Vec<f32>>,
    phase_strategy: f64,
    step: usize,
}

impl Trainer {
    /// Build a trainer over a config-resolved backend (`--backend`), and
    /// initialize parameters (or adopt `warm_start`).
    pub fn open(cfg: TrainConfig, warm_start: Option<&ParamStore>) -> Result<Trainer> {
        let be = backend::open(&cfg)?;
        Self::new(be, cfg, warm_start)
    }

    /// Build a trainer over an explicit backend.
    pub fn new(
        backend: Box<dyn Backend>,
        cfg: TrainConfig,
        warm_start: Option<&ParamStore>,
    ) -> Result<Trainer> {
        let specs = backend.param_specs().to_vec();
        let mut store = ParamStore::init(&specs, cfg.seed);
        if let Some(w) = warm_start {
            let n = store.load_overlapping(w);
            if n == 0 {
                anyhow::bail!("warm start shared no tensors with the target model");
            }
        }

        let sizes: Vec<usize> = specs.iter().map(|p| p.numel()).collect();
        let names: Vec<String> = specs.iter().map(|p| p.name.clone()).collect();
        let strategy = build(&cfg, &sizes, &names);
        let sched = if cfg.cosine_lr {
            let min_frac = match cfg.task {
                Task::C4Pretrain => 0.1, // paper App. A.7
                _ => 0.0,                // paper App. A.6
            };
            LrSchedule::cosine(cfg.lr, cfg.steps, cfg.warmup_frac, min_frac)
        } else {
            LrSchedule::constant(cfg.lr)
        };

        Ok(Trainer {
            backend,
            store,
            strategy,
            mem: MemTracker::new(),
            logger: RunLogger::null(),
            sched,
            grads: sizes.iter().map(|&n| vec![0.0f32; n]).collect(),
            scratch: Vec::new(),
            phase_strategy: 0.0,
            step: 0,
            cfg,
        })
    }

    pub fn batch_shape(&self) -> (usize, usize) {
        self.backend.batch_shape()
    }

    /// One fwd/bwd microbatch through the backend, accumulating the scaled
    /// gradients into `self.grads` (`first` resets the accumulator; `scale`
    /// = 1/grad_accum). Returns the microbatch loss.
    fn forward_backward(
        &mut self,
        tokens: &[i32],
        targets: Targets<'_>,
        first: bool,
        scale: f32,
    ) -> Result<f64> {
        if first && scale == 1.0 {
            // no accumulation: the backend writes the gradients in place
            return self
                .backend
                .forward_backward(&self.store, tokens, targets, &mut self.grads);
        }
        if self.scratch.len() != self.grads.len() {
            self.scratch = self.grads.iter().map(|g| vec![0.0f32; g.len()]).collect();
        }
        let loss = self
            .backend
            .forward_backward(&self.store, tokens, targets, &mut self.scratch)?;
        for (g, s) in self.grads.iter_mut().zip(&self.scratch) {
            if first {
                g.iter_mut().zip(s).for_each(|(gi, &x)| *gi = scale * x);
            } else {
                g.iter_mut().zip(s).for_each(|(gi, &x)| *gi += scale * x);
            }
        }
        Ok(loss)
    }

    /// Apply one strategy step on the accumulated gradients.
    fn apply_strategy(&mut self, loss: f64) -> Result<()> {
        let t0 = std::time::Instant::now();
        let lr = self.sched.at(self.step);
        let info = self.strategy.step(&mut self.store, &self.grads, loss, lr, self.step);
        self.phase_strategy += t0.elapsed().as_secs_f64();
        self.backend.params_updated(&info.active_layers);
        let mut mem = info.mem;
        mem.activations = self.backend.activation_bytes();
        self.mem.record(mem);
        self.logger.log(&Json::obj(vec![
            ("step", Json::num(self.step as f64)),
            ("loss", Json::num(loss)),
            ("lr", Json::num(lr)),
            ("updated", Json::num(info.updated_coords as f64)),
            ("reselected", Json::Bool(info.reselected)),
            ("mem_gb", Json::num(mem.total() as f64 / 1e9)),
        ]));
        self.step += 1;
        Ok(())
    }

    /// Single externally-driven LM step (bench harness entry point).
    pub fn bench_step(&mut self, batch: &crate::data::LmBatch) -> Result<f64> {
        let loss = self.forward_backward(&batch.tokens, Targets::Lm(&batch.targets), true, 1.0)?;
        self.apply_strategy(loss)?;
        Ok(loss)
    }

    /// Externally-driven accumulated LM step over the given microbatches
    /// (tests + bench harness). Returns the mean loss.
    pub fn bench_accum_step(&mut self, micro: &[crate::data::LmBatch]) -> Result<f64> {
        let scale = 1.0 / micro.len() as f32;
        let mut mean_loss = 0.0;
        for (k, batch) in micro.iter().enumerate() {
            mean_loss +=
                self.forward_backward(&batch.tokens, Targets::Lm(&batch.targets), k == 0, scale)?;
        }
        mean_loss /= micro.len() as f64;
        self.apply_strategy(mean_loss)?;
        Ok(mean_loss)
    }

    /// Train on an LM stream for `steps`, evaluating every `eval_every`.
    /// With cfg.grad_accum > 1 each optimizer step consumes that many
    /// microbatches (mean loss / mean gradients).
    pub fn train_lm(
        &mut self,
        train: &mut dyn LmStream,
        eval: &mut dyn LmStream,
    ) -> Result<RunResult> {
        let (b, t) = self.batch_shape();
        let sw = Stopwatch::start();
        let mut train_losses = Vec::with_capacity(self.cfg.steps);
        let mut evals = Vec::new();
        let exec0 = self.backend.exec_secs();
        let accum = self.cfg.grad_accum.max(1);
        for s in 0..self.cfg.steps {
            let scale = 1.0 / accum as f32;
            let mut mean_loss = 0.0;
            for k in 0..accum {
                let batch = train.next_batch(b, t);
                mean_loss +=
                    self.forward_backward(&batch.tokens, Targets::Lm(&batch.targets), k == 0, scale)?;
            }
            mean_loss /= accum as f64;
            self.apply_strategy(mean_loss)?;
            train_losses.push(mean_loss);
            if self.cfg.eval_every > 0 && (s + 1) % self.cfg.eval_every == 0 {
                evals.push(self.eval_lm(eval).context("eval")?);
            }
        }
        if evals.is_empty() || evals.last().map(|e| e.step) != Some(self.step) {
            evals.push(self.eval_lm(eval)?);
        }
        Ok(self.finish(train_losses, evals, sw.secs(), self.backend.exec_secs() - exec0))
    }

    /// LM evaluation: aggregate (loss_sum, valid_count) over eval batches.
    pub fn eval_lm(&mut self, eval: &mut dyn LmStream) -> Result<EvalPoint> {
        let (b, t) = self.batch_shape();
        let mut loss_sum = 0.0f64;
        let mut count = 0.0f64;
        for _ in 0..self.cfg.eval_batches {
            let batch = eval.next_batch(b, t);
            let out = self
                .backend
                .eval_batch(&self.store, &batch.tokens, Targets::Lm(&batch.targets))?;
            loss_sum += out.loss_sum;
            count += out.aux;
        }
        let mean = loss_sum / count.max(1.0);
        Ok(EvalPoint {
            step: self.step,
            loss: mean,
            metric: perplexity(loss_sum, count),
            preds: Vec::new(),
            labels: Vec::new(),
        })
    }

    /// Train on a classification/regression source.
    pub fn train_cls(&mut self, src: &mut dyn ClsSource) -> Result<RunResult> {
        let (b, t) = self.batch_shape();
        let sw = Stopwatch::start();
        let mut train_losses = Vec::with_capacity(self.cfg.steps);
        let mut evals = Vec::new();
        let exec0 = self.backend.exec_secs();
        let regression = src.regression();
        for s in 0..self.cfg.steps {
            let batch = src.batch(b, t, true);
            let loss = if regression {
                self.forward_backward(&batch.tokens, Targets::Reg(&batch.labels_f), true, 1.0)?
            } else {
                self.forward_backward(&batch.tokens, Targets::Cls(&batch.labels_i), true, 1.0)?
            };
            self.apply_strategy(loss)?;
            train_losses.push(loss);
            if self.cfg.eval_every > 0 && (s + 1) % self.cfg.eval_every == 0 {
                evals.push(self.eval_cls(src)?);
            }
        }
        if evals.is_empty() || evals.last().map(|e| e.step) != Some(self.step) {
            evals.push(self.eval_cls(src)?);
        }
        Ok(self.finish(train_losses, evals, sw.secs(), self.backend.exec_secs() - exec0))
    }

    /// Classification eval: (loss_sum, metric_sum, preds) per batch.
    pub fn eval_cls(&mut self, src: &mut dyn ClsSource) -> Result<EvalPoint> {
        let (b, t) = self.batch_shape();
        let regression = src.regression();
        let mut loss_sum = 0.0;
        let mut metric_sum = 0.0;
        let mut n = 0.0;
        let mut preds = Vec::new();
        let mut labels = Vec::new();
        for _ in 0..self.cfg.eval_batches {
            let batch = src.batch(b, t, false);
            let out = if regression {
                self.backend
                    .eval_batch(&self.store, &batch.tokens, Targets::Reg(&batch.labels_f))?
            } else {
                self.backend
                    .eval_batch(&self.store, &batch.tokens, Targets::Cls(&batch.labels_i))?
            };
            loss_sum += out.loss_sum;
            metric_sum += out.aux;
            preds.extend(out.preds.iter().map(|&x| x as f64));
            if regression {
                labels.extend(batch.labels_f.iter().map(|&x| x as f64));
            } else {
                labels.extend(batch.labels_i.iter().map(|&x| x as f64));
            }
            n += b as f64;
        }
        // metric: accuracy (cls) or MSE (reg) — both are sum / n
        let metric = metric_sum / n;
        Ok(EvalPoint { step: self.step, loss: loss_sum / n, metric, preds, labels })
    }

    fn finish(
        &mut self,
        train_losses: Vec<f64>,
        evals: Vec<EvalPoint>,
        wall: f64,
        exec_secs: f64,
    ) -> RunResult {
        let bp = self.backend.phase_secs();
        RunResult {
            method: self.strategy.name().to_string(),
            backend: self.backend.name().to_string(),
            final_train_loss: *train_losses.last().unwrap_or(&f64::NAN),
            steps_per_sec: train_losses.len() as f64 / wall.max(1e-9),
            peak_mem_gb: self.mem.peak_gb(),
            peak_mem_bytes: self.mem.peak_total,
            wall_secs: wall,
            exec_secs,
            phase_secs: [bp[0], bp[1], bp[2], self.phase_strategy],
            telemetry: self.strategy.telemetry(),
            train_losses,
            evals,
        }
    }
}
