//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute many.
//!
//! This is the only place the process touches XLA. The flow per artifact:
//! `HloModuleProto::from_text_file` -> `XlaComputation::from_proto` ->
//! `PjRtClient::compile` (cached) -> `execute` with host literals.
//!
//! Interchange is HLO *text* (see python/compile/aot.py and DESIGN.md §2) —
//! xla_extension 0.5.1 rejects jax>=0.5's 64-bit-id serialized protos; the
//! text parser reassigns ids.

pub mod manifest;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::{anyhow, bail, Context, Result};

pub use manifest::{ArtifactInfo, Manifest, ParamSpec};

/// Process-wide registry of shared runtimes, one per canonical artifacts
/// dir. Sharing the `Runtime` shares its compiled-executable cache: the
/// experiment harnesses open a fresh backend per run, and before this
/// registry existed each run re-parsed and re-compiled identical HLO
/// (the regression introduced when backends became per-run — ROADMAP).
static SHARED: OnceLock<Mutex<HashMap<PathBuf, Arc<Mutex<Runtime>>>>> = OnceLock::new();

/// Open (or fetch the already-open) shared runtime for an artifacts dir.
/// Every `PjrtBackend` in the process that points at the same dir gets the
/// same `Runtime`, so an artifact id compiles at most once per process.
/// Fails like [`Runtime::open`] (missing manifest / stubbed PJRT) without
/// poisoning the registry.
pub fn open_shared(artifacts_dir: impl AsRef<Path>) -> Result<Arc<Mutex<Runtime>>> {
    let dir = artifacts_dir.as_ref();
    let key = dir.canonicalize().unwrap_or_else(|_| dir.to_path_buf());
    let reg = SHARED.get_or_init(|| Mutex::new(HashMap::new()));
    let mut map = reg.lock().expect("runtime registry lock");
    if let Some(rt) = map.get(&key) {
        return Ok(rt.clone());
    }
    let rt = Arc::new(Mutex::new(Runtime::open(&key)?));
    map.insert(key, rt.clone());
    Ok(rt)
}

/// Shared-runtime twin of [`Runtime::open_default`]: walk up from cwd to
/// find artifacts/, then hand out the process-shared runtime for it.
pub fn open_default_shared() -> Result<Arc<Mutex<Runtime>>> {
    open_shared(find_default_artifacts_dir()?)
}

/// Locate the artifacts dir by walking up from cwd (so examples work from
/// any working directory inside the repo) — the single discovery rule used
/// by both the shared and exclusive open paths.
fn find_default_artifacts_dir() -> Result<PathBuf> {
    let mut dir = std::env::current_dir()?;
    loop {
        let cand = dir.join("artifacts");
        if cand.join("manifest.json").exists() {
            return Ok(cand);
        }
        if !dir.pop() {
            bail!("artifacts/manifest.json not found above cwd; run `make artifacts`");
        }
    }
}

/// Lazily-compiled executable registry over an artifacts directory.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    /// cumulative host<->device marshaling + execute time (perf accounting)
    pub exec_secs: f64,
    pub exec_calls: u64,
}

impl Runtime {
    pub fn open(artifacts_dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {dir:?} (run `make artifacts`)"))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        Ok(Runtime { client, dir, manifest, cache: HashMap::new(), exec_secs: 0.0, exec_calls: 0 })
    }

    /// Open an EXCLUSIVE runtime for the default artifacts dir (see
    /// [`open_default_shared`] for the cache-sharing variant backends use).
    pub fn open_default() -> Result<Runtime> {
        Runtime::open(find_default_artifacts_dir()?)
    }

    pub fn artifact(&self, id: &str) -> Result<&ArtifactInfo> {
        self.manifest
            .artifacts
            .get(id)
            .ok_or_else(|| anyhow!("artifact {id:?} not in manifest"))
    }

    /// Compile (or fetch the cached executable for) an artifact id.
    pub fn compile(&mut self, id: &str) -> Result<()> {
        if self.cache.contains_key(id) {
            return Ok(());
        }
        let info = self.artifact(id)?.clone();
        let path = self.dir.join(&info.file);
        let path_str = path
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 path {path:?}"))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .map_err(|e| anyhow!("parsing {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {id}: {e}"))?;
        self.cache.insert(id.to_string(), exe);
        Ok(())
    }

    /// Execute an artifact with the given input literals (owned or
    /// borrowed); returns the flattened output tuple.
    pub fn execute<L: std::borrow::Borrow<xla::Literal>>(
        &mut self,
        id: &str,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        self.compile(id)?;
        let exe = self.cache.get(id).expect("compiled above");
        let t0 = std::time::Instant::now();
        let result = exe
            .execute::<L>(inputs)
            .map_err(|e| anyhow!("executing {id}: {e}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {id}: {e}"))?;
        let out = lit.to_tuple().map_err(|e| anyhow!("untupling result of {id}: {e}"))?;
        self.exec_secs += t0.elapsed().as_secs_f64();
        self.exec_calls += 1;
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// Literal marshaling helpers
// ---------------------------------------------------------------------------

/// f32 host buffer -> shaped literal.
pub fn lit_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        bail!("lit_f32 shape {shape:?} wants {n}, got {}", data.len());
    }
    let l = xla::Literal::vec1(data);
    if shape.len() == 1 {
        return Ok(l);
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    l.reshape(&dims).map_err(|e| anyhow!("reshape: {e}"))
}

/// i32 host buffer -> shaped literal.
pub fn lit_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        bail!("lit_i32 shape {shape:?} wants {n}, got {}", data.len());
    }
    let l = xla::Literal::vec1(data);
    if shape.len() == 1 {
        return Ok(l);
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    l.reshape(&dims).map_err(|e| anyhow!("reshape: {e}"))
}

/// Scalar f32 from a literal (loss outputs).
pub fn scalar_f32(l: &xla::Literal) -> Result<f32> {
    l.get_first_element::<f32>().map_err(|e| anyhow!("scalar: {e}"))
}

/// Copy a literal's f32 payload into a reusable scratch buffer.
pub fn copy_f32_into(l: &xla::Literal, buf: &mut Vec<f32>) -> Result<()> {
    let n = l.element_count();
    buf.resize(n, 0.0);
    l.copy_raw_to::<f32>(buf).map_err(|e| anyhow!("copy_raw_to: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shared_registry_fails_cleanly_and_stays_usable() {
        // no artifacts dir: every call must surface the open error without
        // caching a broken runtime (a later `make artifacts` must be able to
        // succeed in the same process)
        let missing = std::path::Path::new("/nonexistent/blockllm-artifacts");
        assert!(open_shared(missing).is_err());
        assert!(open_shared(missing).is_err(), "registry cached a failed open");
        // with a manifest but the stubbed PJRT client the open still fails
        // (falls back to native upstream); the full reuse path — two
        // backends sharing one compiled executable — runs under the real
        // xla_extension binding, like the pjrt parity test in grad_check.rs
        let dir = std::env::temp_dir().join("blockllm_shared_rt_test");
        let _ = std::fs::create_dir_all(&dir);
        let _ = std::fs::write(
            dir.join("manifest.json"),
            "{\"version\": 1, \"presets\": {}, \"artifacts\": {}}",
        );
        let first = open_shared(&dir).err().map(|e| e.to_string());
        let second = open_shared(&dir).err().map(|e| e.to_string());
        assert_eq!(first, second, "repeated opens must behave identically");
    }
}
