//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute many.
//!
//! This is the only place the process touches XLA. The flow per artifact:
//! `HloModuleProto::from_text_file` -> `XlaComputation::from_proto` ->
//! `PjRtClient::compile` (cached) -> `execute` with host literals.
//!
//! Interchange is HLO *text* (see python/compile/aot.py and DESIGN.md §2) —
//! xla_extension 0.5.1 rejects jax>=0.5's 64-bit-id serialized protos; the
//! text parser reassigns ids.

pub mod manifest;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

pub use manifest::{ArtifactInfo, Manifest, ParamSpec};

/// Lazily-compiled executable registry over an artifacts directory.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    /// cumulative host<->device marshaling + execute time (perf accounting)
    pub exec_secs: f64,
    pub exec_calls: u64,
}

impl Runtime {
    pub fn open(artifacts_dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {dir:?} (run `make artifacts`)"))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        Ok(Runtime { client, dir, manifest, cache: HashMap::new(), exec_secs: 0.0, exec_calls: 0 })
    }

    /// Locate the artifacts dir by walking up from cwd (so examples work
    /// from any working directory inside the repo).
    pub fn open_default() -> Result<Runtime> {
        let mut dir = std::env::current_dir()?;
        loop {
            let cand = dir.join("artifacts");
            if cand.join("manifest.json").exists() {
                return Runtime::open(cand);
            }
            if !dir.pop() {
                bail!("artifacts/manifest.json not found above cwd; run `make artifacts`");
            }
        }
    }

    pub fn artifact(&self, id: &str) -> Result<&ArtifactInfo> {
        self.manifest
            .artifacts
            .get(id)
            .ok_or_else(|| anyhow!("artifact {id:?} not in manifest"))
    }

    /// Compile (or fetch the cached executable for) an artifact id.
    pub fn compile(&mut self, id: &str) -> Result<()> {
        if self.cache.contains_key(id) {
            return Ok(());
        }
        let info = self.artifact(id)?.clone();
        let path = self.dir.join(&info.file);
        let path_str = path
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 path {path:?}"))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .map_err(|e| anyhow!("parsing {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {id}: {e}"))?;
        self.cache.insert(id.to_string(), exe);
        Ok(())
    }

    /// Execute an artifact with the given input literals (owned or
    /// borrowed); returns the flattened output tuple.
    pub fn execute<L: std::borrow::Borrow<xla::Literal>>(
        &mut self,
        id: &str,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        self.compile(id)?;
        let exe = self.cache.get(id).expect("compiled above");
        let t0 = std::time::Instant::now();
        let result = exe
            .execute::<L>(inputs)
            .map_err(|e| anyhow!("executing {id}: {e}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {id}: {e}"))?;
        let out = lit.to_tuple().map_err(|e| anyhow!("untupling result of {id}: {e}"))?;
        self.exec_secs += t0.elapsed().as_secs_f64();
        self.exec_calls += 1;
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// Literal marshaling helpers
// ---------------------------------------------------------------------------

/// f32 host buffer -> shaped literal.
pub fn lit_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        bail!("lit_f32 shape {shape:?} wants {n}, got {}", data.len());
    }
    let l = xla::Literal::vec1(data);
    if shape.len() == 1 {
        return Ok(l);
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    l.reshape(&dims).map_err(|e| anyhow!("reshape: {e}"))
}

/// i32 host buffer -> shaped literal.
pub fn lit_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        bail!("lit_i32 shape {shape:?} wants {n}, got {}", data.len());
    }
    let l = xla::Literal::vec1(data);
    if shape.len() == 1 {
        return Ok(l);
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    l.reshape(&dims).map_err(|e| anyhow!("reshape: {e}"))
}

/// Scalar f32 from a literal (loss outputs).
pub fn scalar_f32(l: &xla::Literal) -> Result<f32> {
    l.get_first_element::<f32>().map_err(|e| anyhow!("scalar: {e}"))
}

/// Copy a literal's f32 payload into a reusable scratch buffer.
pub fn copy_f32_into(l: &xla::Literal, buf: &mut Vec<f32>) -> Result<()> {
    let n = l.element_count();
    buf.resize(n, 0.0);
    l.copy_raw_to::<f32>(buf).map_err(|e| anyhow!("copy_raw_to: {e}"))
}
