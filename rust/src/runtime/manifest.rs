//! Manifest parsing — the ABI between aot.py and the Rust runtime.
//!
//! `artifacts/manifest.json` records, per artifact: the HLO file, the model
//! preset, the ordered parameter table (names + shapes = the exact order of
//! input literals), the batch shape, and the output signature.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactInfo {
    pub id: String,
    pub file: String,
    pub kind: String,
    pub preset: String,
    pub head: String,
    pub n_out: usize,
    pub batch: usize,
    pub seq: usize,
    pub pallas: bool,
    pub params: Vec<ParamSpec>,
    pub outputs: Vec<String>,
}

#[derive(Debug, Clone)]
pub struct PresetInfo {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub param_count: usize,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub presets: BTreeMap<String, PresetInfo>,
    pub artifacts: BTreeMap<String, ArtifactInfo>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let root = Json::parse(text)?;
        let version = root.req("version")?.as_usize()?;
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }

        let mut presets = BTreeMap::new();
        for (name, p) in root.req("presets")?.as_obj()? {
            presets.insert(
                name.clone(),
                PresetInfo {
                    vocab: p.req("vocab")?.as_usize()?,
                    d_model: p.req("d_model")?.as_usize()?,
                    n_layers: p.req("n_layers")?.as_usize()?,
                    n_heads: p.req("n_heads")?.as_usize()?,
                    d_ff: p.req("d_ff")?.as_usize()?,
                    param_count: p.req("param_count")?.as_usize()?,
                },
            );
        }

        let mut artifacts = BTreeMap::new();
        for a in root.req("artifacts")?.as_arr()? {
            let id = a.req("id")?.as_str()?.to_string();
            let kind = a.req("kind")?.as_str()?.to_string();
            if kind == "masked_adam" {
                // kernel artifact: no params table; expose with empty specs
                artifacts.insert(
                    id.clone(),
                    ArtifactInfo {
                        id,
                        file: a.req("file")?.as_str()?.to_string(),
                        kind,
                        preset: String::new(),
                        head: String::new(),
                        n_out: 0,
                        batch: 0,
                        seq: a.req("n")?.as_usize()?,
                        pallas: true,
                        params: Vec::new(),
                        outputs: vec!["w".into(), "m".into(), "v".into()],
                    },
                );
                continue;
            }
            let mut params = Vec::new();
            for ps in a.req("params")?.as_arr()? {
                let shape = ps
                    .req("shape")?
                    .as_arr()?
                    .iter()
                    .map(|d| d.as_usize())
                    .collect::<Result<Vec<_>>>()?;
                params.push(ParamSpec { name: ps.req("name")?.as_str()?.to_string(), shape });
            }
            let outputs = a
                .req("outputs")?
                .as_arr()?
                .iter()
                .map(|o| o.as_str().map(str::to_string))
                .collect::<Result<Vec<_>>>()?;
            artifacts.insert(
                id.clone(),
                ArtifactInfo {
                    id,
                    file: a.req("file")?.as_str()?.to_string(),
                    kind,
                    preset: a.req("preset")?.as_str()?.to_string(),
                    head: a.req("head")?.as_str()?.to_string(),
                    n_out: a.req("n_out")?.as_usize()?,
                    batch: a.req("batch")?.as_usize()?,
                    seq: a.req("seq")?.as_usize()?,
                    pallas: a.req("pallas")?.as_bool()?,
                    params,
                    outputs,
                },
            );
        }
        Ok(Manifest { presets, artifacts })
    }

    /// Find the train/eval artifact pair for a preset+head (+pallas flag).
    pub fn find(
        &self,
        preset: &str,
        head: &str,
        phase: &str,
        pallas: bool,
    ) -> Result<&ArtifactInfo> {
        self.artifacts
            .values()
            .find(|a| {
                a.preset == preset
                    && a.head == head
                    && a.kind.ends_with(phase)
                    && a.pallas == pallas
            })
            .ok_or_else(|| {
                anyhow::anyhow!("no artifact for preset={preset} head={head} phase={phase} pallas={pallas}; rebuild with `make artifacts` (--full for base preset)")
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI: &str = r#"{
      "version": 1,
      "presets": {"nano": {"vocab": 256, "d_model": 64, "n_layers": 2,
                   "n_heads": 2, "d_ff": 176, "max_seq": 64, "param_count": 133440}},
      "artifacts": [
        {"id": "nano_lm_train_b8t64", "file": "x.hlo.txt", "kind": "lm_train",
         "preset": "nano", "head": "lm", "n_out": 0, "batch": 8, "seq": 64,
         "pallas": false,
         "params": [{"name": "tok_emb", "shape": [256, 64]},
                     {"name": "lm_head", "shape": [64, 256]}],
         "outputs": ["loss", "grad:tok_emb", "grad:lm_head"]},
        {"id": "masked_adam_64", "file": "ma.hlo.txt", "kind": "masked_adam",
         "n": 64, "outputs": ["w", "m", "v"]}
      ]
    }"#;

    #[test]
    fn parses_model_artifact() {
        let m = Manifest::parse(MINI).unwrap();
        let a = &m.artifacts["nano_lm_train_b8t64"];
        assert_eq!(a.batch, 8);
        assert_eq!(a.params.len(), 2);
        assert_eq!(a.params[0].numel(), 256 * 64);
        assert_eq!(a.outputs.len(), 3);
        assert_eq!(m.presets["nano"].param_count, 133440);
    }

    #[test]
    fn parses_kernel_artifact() {
        let m = Manifest::parse(MINI).unwrap();
        let k = &m.artifacts["masked_adam_64"];
        assert_eq!(k.kind, "masked_adam");
        assert_eq!(k.seq, 64);
    }

    #[test]
    fn find_matches_phase_and_pallas() {
        let m = Manifest::parse(MINI).unwrap();
        assert!(m.find("nano", "lm", "train", false).is_ok());
        assert!(m.find("nano", "lm", "eval", false).is_err());
        assert!(m.find("nano", "lm", "train", true).is_err());
    }

    #[test]
    fn rejects_bad_version() {
        let bad = MINI.replace("\"version\": 1", "\"version\": 9");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn real_manifest_parses_if_present() {
        // integration-ish: if `make artifacts` has run, the real manifest
        // must parse and contain the nano pallas twin. artifacts/ lives at
        // the repo root, one level above this crate.
        let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .expect("crate dir has a parent")
            .join("artifacts/manifest.json");
        if p.exists() {
            let m = Manifest::load(&p).unwrap();
            assert!(m.find("nano", "lm", "train", true).is_ok());
            assert!(m.find("tiny", "lm", "train", false).is_ok());
            let a = m.find("nano", "lm", "train", false).unwrap();
            let total: usize = a.params.iter().map(|p| p.numel()).sum();
            assert_eq!(total, m.presets["nano"].param_count);
        }
    }
}
