//! Learning-rate schedules. The paper uses cosine annealing decaying to 10%
//! of the initial LR for pretraining (App. A.7: no warmup for BlockLLM,
//! 10% warmup for GaLore) and cosine-to-zero for the Alpaca finetune
//! (App. A.6).

#[derive(Debug, Clone, Copy)]
pub struct LrSchedule {
    pub base_lr: f64,
    pub total_steps: usize,
    pub warmup_steps: usize,
    pub cosine: bool,
    /// final LR as a fraction of base (0.1 for pretraining, 0.0 finetune)
    pub min_frac: f64,
}

impl LrSchedule {
    pub fn constant(lr: f64) -> LrSchedule {
        LrSchedule { base_lr: lr, total_steps: 1, warmup_steps: 0, cosine: false, min_frac: 1.0 }
    }

    pub fn cosine(lr: f64, total_steps: usize, warmup_frac: f64, min_frac: f64) -> LrSchedule {
        LrSchedule {
            base_lr: lr,
            total_steps: total_steps.max(1),
            warmup_steps: ((total_steps as f64) * warmup_frac) as usize,
            cosine: true,
            min_frac,
        }
    }

    /// Resolve the schedule a `TrainConfig` implies. The task-dependent
    /// cosine floor lives HERE and only here: C4 pretraining decays to 10%
    /// of base LR (paper App. A.7), every other task decays to zero (App.
    /// A.6). Trainer and Session both call this, so the paper-appendix
    /// constants can never drift between the two construction paths.
    pub fn from_config(cfg: &crate::config::TrainConfig) -> LrSchedule {
        if cfg.cosine_lr {
            let min_frac = match cfg.task {
                crate::config::Task::C4Pretrain => 0.1,
                _ => 0.0,
            };
            LrSchedule::cosine(cfg.lr, cfg.steps, cfg.warmup_frac, min_frac)
        } else {
            LrSchedule::constant(cfg.lr)
        }
    }

    /// LR at 0-based step t.
    pub fn at(&self, t: usize) -> f64 {
        if !self.cosine {
            return self.base_lr;
        }
        if self.warmup_steps > 0 && t < self.warmup_steps {
            return self.base_lr * (t as f64 + 1.0) / self.warmup_steps as f64;
        }
        let prog = ((t - self.warmup_steps) as f64
            / (self.total_steps.saturating_sub(self.warmup_steps)).max(1) as f64)
            .min(1.0);
        let cos = 0.5 * (1.0 + (std::f64::consts::PI * prog).cos());
        self.base_lr * (self.min_frac + (1.0 - self.min_frac) * cos)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_is_constant() {
        let s = LrSchedule::constant(0.1);
        assert_eq!(s.at(0), 0.1);
        assert_eq!(s.at(10_000), 0.1);
    }

    #[test]
    fn cosine_decays_to_min_frac() {
        let s = LrSchedule::cosine(1.0, 100, 0.0, 0.1);
        assert!((s.at(0) - 1.0).abs() < 1e-9);
        assert!((s.at(100) - 0.1).abs() < 1e-9);
        assert!(s.at(50) < s.at(10));
        assert!(s.at(50) > s.at(90));
    }

    #[test]
    fn warmup_ramps_linearly() {
        let s = LrSchedule::cosine(1.0, 100, 0.1, 0.0);
        assert!((s.at(0) - 0.1).abs() < 1e-9);
        assert!((s.at(4) - 0.5).abs() < 1e-9);
        assert!((s.at(9) - 1.0).abs() < 1e-9);
        // monotone decay after warmup
        assert!(s.at(20) > s.at(60));
    }

    #[test]
    fn beyond_total_clamps() {
        let s = LrSchedule::cosine(1.0, 100, 0.0, 0.1);
        assert!((s.at(500) - 0.1).abs() < 1e-9);
    }

    #[test]
    fn from_config_resolves_task_dependent_floor() {
        let mut cfg = crate::config::TrainConfig::default();
        cfg.steps = 100;
        cfg.cosine_lr = true;
        cfg.task = crate::config::Task::C4Pretrain;
        assert_eq!(LrSchedule::from_config(&cfg).min_frac, 0.1);
        cfg.task = crate::config::Task::AlpacaFinetune;
        assert_eq!(LrSchedule::from_config(&cfg).min_frac, 0.0);
        cfg.cosine_lr = false;
        let s = LrSchedule::from_config(&cfg);
        assert!(!s.cosine);
        assert_eq!(s.at(0), s.at(99));
    }
}
