//! Masked sparse Adam — BlockLLM's inner update (paper eq. 1 + Alg. 1 l.10-15).
//!
//! State (M, V) is materialized ONLY for the currently-selected layers and
//! thrown away when the selection changes (the paper found CPU-offloading
//! old state not worth it — §2.2 "Memory Efficiency"). Within a selected
//! layer, a packed bitmask restricts the update to the top coordinates by
//! processed-gradient magnitude.
//!
//! This is the L3 hot path: it runs every step over the active block. The
//! Pallas kernel python/compile/kernels/masked_adam.py implements identical
//! semantics (asserted via artifacts/golden.json in tests/golden.rs and the
//! runtime parity test) — this native version exists so the request path
//! never pays a PJRT dispatch for an elementwise update.

/// Adam hyperparameters.
#[derive(Debug, Clone, Copy)]
pub struct AdamHypers {
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    pub weight_decay: f64,
}

impl Default for AdamHypers {
    fn default() -> Self {
        AdamHypers { beta1: 0.9, beta2: 0.999, eps: 1e-8, weight_decay: 0.0 }
    }
}

/// Packed bitmask over a tensor's coordinates.
#[derive(Debug, Clone, PartialEq)]
pub struct BitMask {
    pub words: Vec<u64>,
    pub len: usize,
    pub popcount: usize,
}

impl BitMask {
    pub fn all_set(len: usize) -> BitMask {
        let mut words = vec![u64::MAX; len.div_ceil(64)];
        if len % 64 != 0 {
            if let Some(last) = words.last_mut() {
                *last = (1u64 << (len % 64)) - 1;
            }
        }
        BitMask { words, len, popcount: len }
    }

    /// Build from a threshold test |g[i]| >= tau. Exact zeros are never
    /// selected: "top coordinates by |G̃|" cannot include zero-magnitude
    /// entries (this matters for embedding rows of tokens absent from the
    /// selection batch, whose gradients are exactly 0 — without the
    /// exclusion a tau of 0 would admit the whole layer and blow the
    /// sparsity budget).
    pub fn from_threshold(g: &[f32], tau: f32) -> BitMask {
        let len = g.len();
        let mut words = vec![0u64; len.div_ceil(64)];
        let mut pop = 0usize;
        for (i, &x) in g.iter().enumerate() {
            if x.abs() >= tau && x != 0.0 {
                words[i / 64] |= 1u64 << (i % 64);
                pop += 1;
            }
        }
        BitMask { words, len, popcount: pop }
    }

    /// Exactly the top-k coordinates by |g| (fewer if the tensor has fewer
    /// than k nonzeros — zero-magnitude coordinates are never selected, same
    /// rationale as `from_threshold`). Ties break toward the lower index, so
    /// the popcount is exact and the result deterministic — this is what
    /// lets `blockllm::mask` honor the sparsity budget as a hard bound.
    pub fn top_k(g: &[f32], k: usize) -> BitMask {
        let len = g.len();
        let mut words = vec![0u64; len.div_ceil(64)];
        let nz = g.iter().filter(|x| **x != 0.0).count();
        let k = k.min(nz);
        if k == 0 {
            return BitMask { words, len, popcount: 0 };
        }
        let mut pop = 0usize;
        if k == nz {
            for (i, &x) in g.iter().enumerate() {
                if x != 0.0 {
                    words[i / 64] |= 1u64 << (i % 64);
                    pop += 1;
                }
            }
            return BitMask { words, len, popcount: pop };
        }
        // k < nz: threshold at the k-th largest |g|, then admit strict
        // winners and fill remaining slots with ties in index order
        let tau = crate::tensor::kth_largest_abs(g, k);
        for (i, &x) in g.iter().enumerate() {
            if x.abs() > tau {
                words[i / 64] |= 1u64 << (i % 64);
                pop += 1;
            }
        }
        for (i, &x) in g.iter().enumerate() {
            if pop == k {
                break;
            }
            if x != 0.0 && x.abs() == tau {
                words[i / 64] |= 1u64 << (i % 64);
                pop += 1;
            }
        }
        BitMask { words, len, popcount: pop }
    }

    #[inline]
    pub fn get(&self, i: usize) -> bool {
        (self.words[i / 64] >> (i % 64)) & 1 == 1
    }

    /// Bytes of storage (the memory accounting charge for masks).
    pub fn bytes(&self) -> u64 {
        (self.words.len() * 8) as u64
    }
}

/// Optimizer state for ONE selected layer.
#[derive(Debug)]
pub struct LayerState {
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub mask: BitMask,
}

/// Sparse Adam state over the active block: one `LayerState` per selected
/// layer, plus the shared step counter. Dropping and rebuilding this struct
/// IS the paper's "reset the optimizer with the new layers".
#[derive(Debug, Default)]
pub struct SparseAdamState {
    /// (layer index in the param table) -> state
    pub layers: Vec<(usize, LayerState)>,
    pub step: u64,
}

impl SparseAdamState {
    /// Fresh state for a new selection. `masks` pairs each selected layer
    /// index with its coordinate mask.
    pub fn new(masks: Vec<(usize, BitMask)>, sizes: &[usize]) -> SparseAdamState {
        let layers = masks
            .into_iter()
            .map(|(li, mask)| {
                let n = sizes[li];
                debug_assert_eq!(mask.len, n);
                (li, LayerState { m: vec![0.0; n], v: vec![0.0; n], mask })
            })
            .collect();
        SparseAdamState { layers, step: 0 }
    }

    /// Active (masked-in) coordinate count — the memory accounting basis.
    pub fn active_coords(&self) -> u64 {
        self.layers.iter().map(|(_, s)| s.mask.popcount as u64).sum()
    }

    /// Allocated state elements (m+v). The implementation allocates dense
    /// per-layer buffers for speed; *modeled* memory (what a production
    /// GPU port would allocate, and what the paper charges) is
    /// 2*active_coords. Both are reported by the memory tracker.
    pub fn allocated_elems(&self) -> u64 {
        self.layers.iter().map(|(_, s)| 2 * s.m.len() as u64).sum()
    }

    pub fn selected_layers(&self) -> Vec<usize> {
        self.layers.iter().map(|(li, _)| *li).collect()
    }
}

/// Adam bias corrections 1 - βᵗ, computed in f64 and cast to f32 ONCE.
///
/// The former f32 `powi(step as i32)` had two failure modes: f32
/// accumulation drifts from the dense f64 Adam reference at large step
/// counts, and `step as i32` wraps past `i32::MAX` (flipping the exponent
/// sign). `DenseAdam` and the masked step share this helper so the
/// sparse-vs-dense parity holds at every step count.
pub fn bias_corrections(h: &AdamHypers, step: u64) -> (f32, f32) {
    let (bc1, bc2) = bias_corrections_f64(h, step);
    (bc1 as f32, bc2 as f32)
}

/// Full-precision variant for consumers that stay in f64 (the BlockLLM
/// strategy's processed-gradient norms).
pub fn bias_corrections_f64(h: &AdamHypers, step: u64) -> (f64, f64) {
    (1.0 - h.beta1.powf(step as f64), 1.0 - h.beta2.powf(step as f64))
}

/// One masked Adam step for a single layer. Returns the number of
/// coordinates updated.
pub fn masked_adam_step(
    w: &mut [f32],
    g: &[f32],
    st: &mut LayerState,
    step: u64,
    lr: f64,
    h: &AdamHypers,
) -> usize {
    let _sp = crate::obs::span(crate::obs::Span::AdamStep);
    debug_assert_eq!(w.len(), g.len());
    debug_assert_eq!(w.len(), st.mask.len);
    let b1 = h.beta1 as f32;
    let b2 = h.beta2 as f32;
    let eps = h.eps as f32;
    let wd = h.weight_decay as f32;
    let lr = lr as f32;
    let (bc1, bc2) = bias_corrections(h, step);
    let mut updated = 0usize;

    // word-at-a-time: skip 64 coordinates per zero word (cheap at high
    // sparsity, which is BlockLLM's operating point s>=0.5)
    for (wi, &word) in st.mask.words.iter().enumerate() {
        if word == 0 {
            continue;
        }
        let base = wi * 64;
        if word == u64::MAX && base + 64 <= w.len() {
            // dense fast path for full words
            for i in base..base + 64 {
                let gi = g[i] + wd * w[i];
                st.m[i] = b1 * st.m[i] + (1.0 - b1) * gi;
                st.v[i] = b2 * st.v[i] + (1.0 - b2) * gi * gi;
                w[i] -= lr * (st.m[i] / bc1) / ((st.v[i] / bc2).sqrt() + eps);
            }
            updated += 64;
            continue;
        }
        let mut bits = word;
        while bits != 0 {
            let b = bits.trailing_zeros() as usize;
            bits &= bits - 1;
            let i = base + b;
            let gi = g[i] + wd * w[i];
            st.m[i] = b1 * st.m[i] + (1.0 - b1) * gi;
            st.v[i] = b2 * st.v[i] + (1.0 - b2) * gi * gi;
            w[i] -= lr * (st.m[i] / bc1) / ((st.v[i] / bc2).sqrt() + eps);
            updated += 1;
        }
    }
    updated
}

/// One masked Adam step from COMPACT gradient values: `gc` holds exactly
/// the masked-in coordinates' gradients, packed in ascending coordinate
/// order (the order a `grads::MaskedSink` retains them). Visits words in
/// the identical sequence as [`masked_adam_step`] and performs the same
/// arithmetic on the same bits, so the two are bitwise interchangeable —
/// this is what lets the streaming trainer update the active block without
/// ever materializing a dense gradient. Returns the coordinate count
/// updated.
pub fn masked_adam_step_compact(
    w: &mut [f32],
    gc: &[f32],
    st: &mut LayerState,
    step: u64,
    lr: f64,
    h: &AdamHypers,
) -> usize {
    let _sp = crate::obs::span(crate::obs::Span::AdamStep);
    debug_assert_eq!(w.len(), st.mask.len);
    debug_assert_eq!(gc.len(), st.mask.popcount, "compact grads must match the mask popcount");
    let b1 = h.beta1 as f32;
    let b2 = h.beta2 as f32;
    let eps = h.eps as f32;
    let wd = h.weight_decay as f32;
    let lr = lr as f32;
    let (bc1, bc2) = bias_corrections(h, step);
    let mut p = 0usize;

    for (wi, &word) in st.mask.words.iter().enumerate() {
        if word == 0 {
            continue;
        }
        let base = wi * 64;
        if word == u64::MAX && base + 64 <= w.len() {
            for i in base..base + 64 {
                let gi = gc[p] + wd * w[i];
                p += 1;
                st.m[i] = b1 * st.m[i] + (1.0 - b1) * gi;
                st.v[i] = b2 * st.v[i] + (1.0 - b2) * gi * gi;
                w[i] -= lr * (st.m[i] / bc1) / ((st.v[i] / bc2).sqrt() + eps);
            }
            continue;
        }
        let mut bits = word;
        while bits != 0 {
            let b = bits.trailing_zeros() as usize;
            bits &= bits - 1;
            let i = base + b;
            let gi = gc[p] + wd * w[i];
            p += 1;
            st.m[i] = b1 * st.m[i] + (1.0 - b1) * gi;
            st.v[i] = b2 * st.v[i] + (1.0 - b2) * gi * gi;
            w[i] -= lr * (st.m[i] / bc1) / ((st.v[i] / bc2).sqrt() + eps);
        }
    }
    p
}

/// [`masked_adam_step_compact`] restricted to the compact-coordinate range
/// `[lo, hi)` — the dist layer's ZeRO-style moment-shard update. Shard `q`
/// of `r` owns compact elements `[q·⌈c/r⌉, min((q+1)·⌈c/r⌉, c))`, and `r`
/// consecutive calls covering `[0, c)` in order perform exactly the
/// per-coordinate arithmetic of ONE full compact call (Adam is elementwise;
/// the bias corrections depend only on `step`), so the sharded update is
/// bitwise interchangeable with the unsharded one. Coordinates outside the
/// range are skipped without touching `w`, `m`, or `v` — a replica's moment
/// residency is exactly its shard. Returns the coordinate count updated.
pub fn masked_adam_step_compact_range(
    w: &mut [f32],
    gc: &[f32],
    st: &mut LayerState,
    step: u64,
    lr: f64,
    h: &AdamHypers,
    lo: usize,
    hi: usize,
) -> usize {
    let _sp = crate::obs::span(crate::obs::Span::AdamStep);
    debug_assert_eq!(w.len(), st.mask.len);
    debug_assert_eq!(gc.len(), st.mask.popcount, "compact grads must match the mask popcount");
    debug_assert!(lo <= hi && hi <= st.mask.popcount, "shard range out of bounds");
    let b1 = h.beta1 as f32;
    let b2 = h.beta2 as f32;
    let eps = h.eps as f32;
    let wd = h.weight_decay as f32;
    let lr = lr as f32;
    let (bc1, bc2) = bias_corrections(h, step);
    let mut p = 0usize;
    let mut updated = 0usize;

    for (wi, &word) in st.mask.words.iter().enumerate() {
        if p >= hi {
            break;
        }
        if word == 0 {
            continue;
        }
        let pop = word.count_ones() as usize;
        if p + pop <= lo {
            // word wholly below the shard: skip it, advancing the compact
            // offset past its coordinates
            p += pop;
            continue;
        }
        let base = wi * 64;
        if word == u64::MAX && base + 64 <= w.len() && lo <= p && p + 64 <= hi {
            // full word entirely inside the shard: dense fast path
            for i in base..base + 64 {
                let gi = gc[p] + wd * w[i];
                p += 1;
                st.m[i] = b1 * st.m[i] + (1.0 - b1) * gi;
                st.v[i] = b2 * st.v[i] + (1.0 - b2) * gi * gi;
                w[i] -= lr * (st.m[i] / bc1) / ((st.v[i] / bc2).sqrt() + eps);
            }
            updated += 64;
            continue;
        }
        // word straddles a shard edge (or is sparse): walk its bits,
        // updating only compact positions inside [lo, hi)
        let mut bits = word;
        while bits != 0 {
            let b = bits.trailing_zeros() as usize;
            bits &= bits - 1;
            if p >= hi {
                return updated;
            }
            if p < lo {
                p += 1;
                continue;
            }
            let i = base + b;
            let gi = gc[p] + wd * w[i];
            p += 1;
            st.m[i] = b1 * st.m[i] + (1.0 - b1) * gi;
            st.v[i] = b2 * st.v[i] + (1.0 - b2) * gi * gi;
            w[i] -= lr * (st.m[i] / bc1) / ((st.v[i] / bc2).sqrt() + eps);
            updated += 1;
        }
    }
    updated
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn bitmask_all_set_and_partial_word() {
        let m = BitMask::all_set(70);
        assert_eq!(m.popcount, 70);
        assert!(m.get(0) && m.get(69));
        assert_eq!(m.words.len(), 2);
        assert_eq!(m.words[1], (1u64 << 6) - 1);
    }

    #[test]
    fn bitmask_threshold() {
        let g = [0.1f32, -0.5, 0.3, -0.05, 0.5];
        let m = BitMask::from_threshold(&g, 0.3);
        assert_eq!(m.popcount, 3);
        assert!(!m.get(0) && m.get(1) && m.get(2) && !m.get(3) && m.get(4));
    }

    #[test]
    fn masked_step_touches_only_masked() {
        let n = 200;
        let mut rng = Pcg64::new(1);
        let mut w: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
        let g: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
        let w0 = w.clone();
        let mask = BitMask::from_threshold(&g, 0.5);
        let mut st = LayerState { m: vec![0.0; n], v: vec![0.0; n], mask };
        let updated = masked_adam_step(&mut w, &g, &mut st, 1, 1e-2, &AdamHypers::default());
        assert_eq!(updated, st.mask.popcount);
        for i in 0..n {
            if st.mask.get(i) {
                assert_ne!(w[i], w0[i], "masked coord {i} not updated");
                assert_ne!(st.m[i], 0.0);
            } else {
                assert_eq!(w[i], w0[i], "unmasked coord {i} moved");
                assert_eq!(st.m[i], 0.0);
                assert_eq!(st.v[i], 0.0);
            }
        }
    }

    #[test]
    fn full_mask_equals_dense_adam() {
        let n = 130; // crosses a word boundary
        let mut rng = Pcg64::new(2);
        let mut w: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
        let mut w2 = vec![w.clone()];
        let g: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();

        let mut st = LayerState {
            m: vec![0.0; n],
            v: vec![0.0; n],
            mask: BitMask::all_set(n),
        };
        let h = AdamHypers::default();
        let mut dense = crate::optim::DenseAdam::new(&[n], h);
        for step in 1..=5 {
            masked_adam_step(&mut w, &g, &mut st, step, 1e-2, &h);
            let gg = g.clone();
            dense.step(&mut w2, &[&gg], 1e-2);
        }
        for i in 0..n {
            assert!((w[i] - w2[0][i]).abs() < 1e-6, "coord {i}: {} vs {}", w[i], w2[0][i]);
        }
    }

    #[test]
    fn bias_corrections_are_exact_at_large_steps_and_never_wrap() {
        let h = AdamHypers::default();
        // step 1: bc = 1 - beta exactly (up to one f64->f32 rounding)
        let (bc1, bc2) = bias_corrections(&h, 1);
        assert!((bc1 as f64 - (1.0 - h.beta1)).abs() < 1e-7);
        assert!((bc2 as f64 - (1.0 - h.beta2)).abs() < 1e-9);
        // past i32::MAX the old `step as i32` wrapped negative, flipping the
        // exponent sign; the f64 path must saturate cleanly to 1.0
        let big = i32::MAX as u64 + 12_345;
        let (bc1, bc2) = bias_corrections(&h, big);
        assert!(bc1 > 0.0 && bc2 > 0.0, "wrapped bias correction went non-positive");
        assert!((bc1 - 1.0).abs() < 1e-6 && (bc2 - 1.0).abs() < 1e-6);
        // monotone in step (sanity across the whole range)
        let mut last = 0.0f32;
        for step in [1u64, 10, 1_000, 1_000_000, 1 << 40] {
            let (_, bc2) = bias_corrections(&h, step);
            assert!(bc2 >= last, "bc2 not monotone at step {step}");
            last = bc2;
        }
    }

    #[test]
    fn full_mask_matches_dense_adam_in_the_large_step_regime() {
        // sparse-vs-dense parity where the old f32 powi drifted and the
        // i32 cast wrapped: both paths share bias_corrections, so the
        // updates must agree exactly
        let n = 130;
        let big = i32::MAX as u64 + 7; // would wrap as `step as i32`
        let mut rng = Pcg64::new(6);
        let mut w: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
        let mut w2 = vec![w.clone()];
        let g: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
        let h = AdamHypers::default();
        let mut st = LayerState { m: vec![0.0; n], v: vec![0.0; n], mask: BitMask::all_set(n) };
        let mut dense = crate::optim::DenseAdam::new(&[n], h);
        dense.step = big - 1; // DenseAdam increments before it uses the count
        for k in 0..3u64 {
            masked_adam_step(&mut w, &g, &mut st, big + k, 1e-2, &h);
            let gg = g.clone();
            dense.step(&mut w2, &[&gg], 1e-2);
        }
        for i in 0..n {
            assert_eq!(
                w[i].to_bits(),
                w2[0][i].to_bits(),
                "coord {i}: sparse {} vs dense {}",
                w[i],
                w2[0][i]
            );
        }
    }

    #[test]
    fn compact_step_matches_dense_masked_step_bitwise() {
        // crosses word boundaries AND exercises the full-word fast path
        let n = 200;
        let mut rng = Pcg64::new(9);
        let mut w: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
        let mut w2 = w.clone();
        let g: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
        // mask: first 64 coords dense (full word), then scattered
        let maskv: Vec<f32> =
            (0..n).map(|i| if i < 64 || i % 5 == 2 { 1.0 } else { 0.0 }).collect();
        let mask = BitMask::from_threshold(&maskv, 0.5);
        let gc: Vec<f32> = (0..n).filter(|&i| mask.get(i)).map(|i| g[i]).collect();
        let h = AdamHypers { weight_decay: 0.01, ..AdamHypers::default() };
        let mut st1 = LayerState { m: vec![0.0; n], v: vec![0.0; n], mask: mask.clone() };
        let mut st2 = LayerState { m: vec![0.0; n], v: vec![0.0; n], mask };
        for step in 1..=4 {
            let u1 = masked_adam_step(&mut w, &g, &mut st1, step, 3e-3, &h);
            let u2 = masked_adam_step_compact(&mut w2, &gc, &mut st2, step, 3e-3, &h);
            assert_eq!(u1, u2);
        }
        for i in 0..n {
            assert_eq!(w[i].to_bits(), w2[i].to_bits(), "coord {i}");
            assert_eq!(st1.m[i].to_bits(), st2.m[i].to_bits(), "m {i}");
            assert_eq!(st1.v[i].to_bits(), st2.v[i].to_bits(), "v {i}");
        }
    }

    #[test]
    fn compact_range_shards_match_full_step_bitwise() {
        // R consecutive range calls over even compact chunks must be bitwise
        // identical to ONE full compact step — the dist layer's ZeRO-style
        // moment-sharding contract. The mask covers a dense full word (fast
        // path), straddled words, and scattered bits; the shard counts
        // include ones that don't divide the popcount evenly.
        let n = 300;
        let mut rng = Pcg64::new(11);
        let w0: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
        let g: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
        let maskv: Vec<f32> =
            (0..n).map(|i| if i < 64 || i % 3 == 1 { 1.0 } else { 0.0 }).collect();
        let mask = BitMask::from_threshold(&maskv, 0.5);
        let gc: Vec<f32> = (0..n).filter(|&i| mask.get(i)).map(|i| g[i]).collect();
        let c = mask.popcount;
        let h = AdamHypers { weight_decay: 0.02, ..AdamHypers::default() };
        let mut w_ref = w0.clone();
        let mut st_ref = LayerState { m: vec![0.0; n], v: vec![0.0; n], mask: mask.clone() };
        for step in 1..=3 {
            masked_adam_step_compact(&mut w_ref, &gc, &mut st_ref, step, 2e-3, &h);
        }
        for r in [1usize, 2, 3, 4, 7] {
            let mut w = w0.clone();
            let mut st = LayerState { m: vec![0.0; n], v: vec![0.0; n], mask: mask.clone() };
            let chunk = c.div_ceil(r);
            for step in 1..=3 {
                let mut total = 0usize;
                for q in 0..r {
                    let lo = (q * chunk).min(c);
                    let hi = ((q + 1) * chunk).min(c);
                    total += masked_adam_step_compact_range(
                        &mut w, &gc, &mut st, step, 2e-3, &h, lo, hi,
                    );
                }
                assert_eq!(total, c, "shards at r={r} must cover every active coord");
            }
            for i in 0..n {
                assert_eq!(w[i].to_bits(), w_ref[i].to_bits(), "r={r} coord {i}");
                assert_eq!(st.m[i].to_bits(), st_ref.m[i].to_bits(), "r={r} m {i}");
                assert_eq!(st.v[i].to_bits(), st_ref.v[i].to_bits(), "r={r} v {i}");
            }
        }
    }

    #[test]
    fn sparse_state_accounting() {
        let sizes = vec![100, 200, 50];
        let masks = vec![
            (0, BitMask::from_threshold(&vec![1.0; 100], 0.5)), // all pass
            (2, BitMask::from_threshold(&vec![0.0; 50], 0.5)),  // none pass
        ];
        let st = SparseAdamState::new(masks, &sizes);
        assert_eq!(st.active_coords(), 100);
        assert_eq!(st.allocated_elems(), 2 * 150);
        assert_eq!(st.selected_layers(), vec![0, 2]);
    }

    #[test]
    fn matches_golden_semantics() {
        // Mirror of python ref.masked_adam_ref on a deterministic vector
        // (the full golden cross-check against aot.py's vectors lives in
        // tests/golden.rs; this is the in-crate version).
        let n = 64;
        let j: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let mut w: Vec<f32> = j.iter().map(|x| (0.05 * x).sin()).collect();
        let m: Vec<f32> = j.iter().map(|x| 0.01 * (0.07 * x).cos()).collect();
        let v: Vec<f32> = j.iter().map(|x| 0.001 * (1.0 + (0.11 * x).sin().powi(2))).collect();
        let g: Vec<f32> = j.iter().map(|x| 0.5 * (0.13 * x).cos()).collect();
        let maskv: Vec<f32> = (0..n).map(|i| if i % 3 == 0 { 1.0 } else { 0.0 }).collect();
        let mask = BitMask::from_threshold(&maskv, 0.5);
        let mut st = LayerState { m: m.clone(), v: v.clone(), mask };
        let h = AdamHypers { beta1: 0.9, beta2: 0.999, eps: 1e-8, weight_decay: 0.0 };
        masked_adam_step(&mut w, &g, &mut st, 7, 1e-3, &h);
        // recompute coordinate 0 by hand
        let m0 = 0.9f32 * m[0] + 0.1 * g[0];
        let v0 = 0.999f32 * v[0] + 0.001 * g[0] * g[0];
        let mh = m0 / (1.0 - 0.9f32.powi(7));
        let vh = v0 / (1.0 - 0.999f32.powi(7));
        let want = (0.0f32).sin() - 1e-3 * mh / (vh.sqrt() + 1e-8);
        assert!((w[0] - want).abs() < 1e-6);
        // coordinate 1 untouched
        assert_eq!(w[1], (0.05f32).sin());
    }
}
