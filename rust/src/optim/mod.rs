//! Optimizer substrate: dense Adam (the FFT baseline), the masked sparse
//! Adam that powers BlockLLM, and LR schedules.

pub mod masked_adam;
pub mod schedule;

pub use masked_adam::{masked_adam_step, AdamHypers, SparseAdamState};

/// Dense Adam state over a set of parameter tensors (full-parameter
/// training; the paper's "FFT"/Adam baseline).
#[derive(Debug)]
pub struct DenseAdam {
    pub m: Vec<Vec<f32>>,
    pub v: Vec<Vec<f32>>,
    pub step: u64,
    pub hypers: AdamHypers,
}

impl DenseAdam {
    pub fn new(sizes: &[usize], hypers: AdamHypers) -> DenseAdam {
        DenseAdam {
            m: sizes.iter().map(|&n| vec![0.0; n]).collect(),
            v: sizes.iter().map(|&n| vec![0.0; n]).collect(),
            step: 0,
            hypers,
        }
    }

    /// One Adam step over all tensors. `lr` already includes the schedule.
    pub fn step(&mut self, params: &mut [Vec<f32>], grads: &[&[f32]], lr: f64) {
        let _sp = crate::obs::span(crate::obs::Span::AdamStep);
        self.step += 1;
        let h = self.hypers;
        // f64 bias corrections shared with the masked step: exact at large
        // step counts, no i32 wrap (see masked_adam::bias_corrections)
        let (bc1, bc2) = masked_adam::bias_corrections(&h, self.step);
        for ((p, g), (m, v)) in params
            .iter_mut()
            .zip(grads)
            .zip(self.m.iter_mut().zip(self.v.iter_mut()))
        {
            debug_assert_eq!(p.len(), g.len());
            let (b1, b2) = (h.beta1 as f32, h.beta2 as f32);
            let lr = lr as f32;
            let eps = h.eps as f32;
            let wd = h.weight_decay as f32;
            for i in 0..p.len() {
                let gi = g[i] + wd * p[i];
                m[i] = b1 * m[i] + (1.0 - b1) * gi;
                v[i] = b2 * v[i] + (1.0 - b2) * gi * gi;
                let mh = m[i] / bc1;
                let vh = v[i] / bc2;
                p[i] -= lr * mh / (vh.sqrt() + eps);
            }
        }
    }

    /// Modeled optimizer-state footprint in f32 elements.
    pub fn state_elems(&self) -> u64 {
        self.m.iter().map(|b| b.len() as u64).sum::<u64>() * 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_adam_descends_quadratic() {
        // minimize f(x) = 0.5*||x||^2; grad = x
        let mut p = vec![vec![5.0f32; 16]];
        let mut opt = DenseAdam::new(&[16], AdamHypers::default());
        for _ in 0..2000 {
            let g: Vec<f32> = p[0].clone();
            opt.step(&mut p, &[&g], 0.05);
        }
        let norm: f32 = p[0].iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!(norm < 0.1, "did not converge: {norm}");
    }

    #[test]
    fn dense_adam_first_step_magnitude() {
        // classic property: first Adam step ~= lr * sign(g)
        let mut p = vec![vec![0.0f32; 4]];
        let g = vec![0.3f32, -2.0, 0.001, 10.0];
        let mut opt = DenseAdam::new(&[4], AdamHypers::default());
        opt.step(&mut p, &[&g], 0.01);
        for (x, gi) in p[0].iter().zip(&g) {
            assert!((x.abs() - 0.01).abs() < 1e-3, "x={x} g={gi}");
            assert_eq!(x.signum(), -gi.signum());
        }
    }

    #[test]
    fn weight_decay_shrinks_params() {
        let mut h = AdamHypers::default();
        h.weight_decay = 0.1;
        let mut p = vec![vec![1.0f32; 8]];
        let g = vec![0.0f32; 8];
        let mut opt = DenseAdam::new(&[8], h);
        for _ in 0..100 {
            let gg = g.clone();
            opt.step(&mut p, &[&gg], 0.01);
        }
        assert!(p[0][0] < 0.9, "decay had no effect: {}", p[0][0]);
    }
}
